"""End-to-end serving driver (the paper's kind): build a FusionANNS index
and serve batched query traffic, reporting recall / simulated-I/O / modelled
QPS-vs-threads — the full online pipeline of paper §3.

    PYTHONPATH=src python examples/serve_anns.py --n 30000 --queries 64

``--edge PORT`` instead serves the index over HTTP (the PR-7 front door:
tenant auth, request coalescing, elastic autoscaling) and fires a few demo
requests at itself; add ``--hold`` to keep serving until Ctrl-C so you can
drive it yourself:

    PYTHONPATH=src python examples/serve_anns.py --edge 8080 --hold
    curl -s -X POST http://127.0.0.1:8080/v1/search \\
      -H 'x-api-key: demo-key' -H 'content-type: application/json' \\
      -d "{\\"query\\": $(python -c 'print([0.1]*96)'), \\"k\\": 10}"
    curl -s http://127.0.0.1:8080/v1/stats -H 'x-api-key: demo-key'
"""

import argparse
import dataclasses
import json
import time

import numpy as np

from repro.configs.anns_datasets import SIFT_SMALL
from repro.core.engine import FusionANNSIndex, ground_truth, recall_at_k
from repro.core.perf_model import DeviceModel, QueryDemand, sweep_threads
from repro.data.synthetic import clustered_vectors


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=30_000)
    ap.add_argument("--dim", type=int, default=96)
    ap.add_argument("--queries", type=int, default=64)
    ap.add_argument("--producers", type=int, default=4,
                    help="submitter threads for the threaded-service demo")
    ap.add_argument("--replicas", type=int, default=2,
                    help="serving replicas behind the JSQ router demo")
    ap.add_argument("--inflight", type=int, default=64,
                    help="AsyncANNSClient max in-flight requests")
    ap.add_argument("--policy", default="jsq",
                    choices=("round_robin", "jsq", "deadline"),
                    help="ReplicaRouter routing policy")
    ap.add_argument("--edge", type=int, default=None, metavar="PORT",
                    help="serve over HTTP on this port instead of running "
                         "the in-process demos (see module docstring)")
    ap.add_argument("--hold", action="store_true",
                    help="with --edge: keep serving until Ctrl-C")
    args = ap.parse_args()

    cfg = dataclasses.replace(SIFT_SMALL, n_vectors=args.n, dim=args.dim,
                              pq_m=args.dim // 4, n_posting_fraction=0.02,
                              top_m=24, top_n=256)
    rng = np.random.default_rng(0)
    everything = clustered_vectors(rng, args.n + args.queries, args.dim,
                                   n_clusters=max(16, args.n // 400))
    data, queries = everything[:args.n], everything[args.n:]

    t0 = time.time()
    index = FusionANNSIndex.build(data, cfg)
    print(f"# build {time.time()-t0:.1f}s")
    if args.edge is not None:
        serve_edge(index, queries, args)
        return
    gt = ground_truth(data, queries, 10)

    # futures-first path: host traversal + async dispatch of the first
    # inflight_depth windows happens inside submit(); results() pipelines
    # each window's rerank against the next windows' in-flight scans
    t0 = time.time()
    ticket = index.submit(queries, window=1, inflight_depth=2)
    results = ticket.results()
    wall = time.time() - t0
    rec = recall_at_k(np.stack([r.ids for r in results]), gt, 10)

    # serving front-end on the same API: typed requests in, typed
    # responses out (SearchRequest -> QueryFuture -> SearchResponse)
    from repro.serve.anns_service import BatchingANNSService
    from repro.serve.client import (ANNSClient, AsyncANNSClient,
                                    SearchRequest)
    svc = BatchingANNSService(index, max_batch=16, max_wait_s=0.0,
                              scan_window=8, inflight_depth=2)
    futs = [svc.submit(SearchRequest(query=q, tag=i))
            for i, q in enumerate(queries)]
    svc.drain()
    assert all(f.done() for f in futs)
    pct = svc.latency_percentiles()

    # shared producer harness for the threaded-service and router demos:
    # N submitter threads behind the sync client (which blocks through
    # backpressure instead of surfacing BackpressureError)
    import threading

    def drive_producers(backend):
        client = ANNSClient(backend)

        def produce(i):
            client.search_many(
                [SearchRequest(query=q)
                 for q in queries[i::args.producers]], timeout=300)

        workers = [threading.Thread(target=produce, args=(i,))
                   for i in range(args.producers)]
        for w in workers:
            w.start()
        for w in workers:
            w.join()

    # threaded runtime: a pump thread + out-of-order ticker per replica,
    # traffic from N producer threads (the deployment shape — DESIGN.md
    # §"Threading model")
    tsvc = BatchingANNSService(index, max_batch=16, max_wait_s=0.0005,
                               scan_window=8, inflight_depth=2,
                               threaded=True)
    drive_producers(tsvc)
    tsvc.stop()
    tpct = tsvc.latency_percentiles()

    # multi-replica routing: N threaded replicas behind one futures-first
    # submit() (each replica would own a disjoint sub-mesh on a multi-chip
    # host — launch.mesh.split_mesh; on one device the router is a pure
    # concurrency layer)
    from repro.core.perf_model import sweep_replicas
    from repro.serve.stack import make_serving_stack
    router = make_serving_stack(index, n_replicas=args.replicas,
                                policy=args.policy)
    drive_producers(router)

    # the asyncio front door (DESIGN.md §6): ONE event loop drives the
    # whole workload over the same router — thousands of in-flight
    # coroutines instead of a thread per producer; backpressure is an
    # awaited admission, never an exception
    import asyncio

    async def drive_async():
        async with AsyncANNSClient(router,
                                   max_inflight=args.inflight) as client:
            reqs = [SearchRequest(query=q, tag=i)
                    for i, q in enumerate(queries)]
            t0 = time.perf_counter()
            lat = [r.latency_s async for r in client.search_many(reqs)]
            return (time.perf_counter() - t0, lat, dict(client.stats))

    awall, alat, astats = asyncio.run(drive_async())
    router.stop()
    rpct = router.latency_percentiles()
    rollup = router.stats_rollup()
    rsweep = sweep_replicas(router.measured_demand(), DeviceModel(),
                            (1, args.replicas, 2 * args.replicas))

    stats = [r.stats for r in results]
    demand = QueryDemand(
        ssd_ios=float(np.mean([s.ios for s in stats])),
        ssd_bytes=float(np.mean([s.ssd_bytes for s in stats])),
        h2d_bytes=float(np.mean([s.h2d_bytes for s in stats])),
        gpu_lookups=float(np.mean([s.candidates_scanned for s in stats]))
        * cfg.pq_m,
        cpu_dist_ops=float(np.mean([s.rerank_scored for s in stats]))
        * args.dim,
        graph_hops=2.0 * cfg.top_m)
    sweep = sweep_threads(demand, DeviceModel())

    print(json.dumps({
        "recall@10": round(rec, 4),
        "host_wall_ms_per_query": round(1e3 * wall / len(queries), 2),
        "mean_ssd_ios": round(demand.ssd_ios, 1),
        "mean_h2d_bytes": int(demand.h2d_bytes),
        "early_stop_rate": round(float(np.mean(
            [s.early_stopped for s in stats])), 3),
        "service_p50_ms": round(pct["p50"] * 1e3, 2),
        "service_p99_ms": round(pct["p99"] * 1e3, 2),
        "threaded_p50_ms": round(tpct["p50"] * 1e3, 2),
        "threaded_p99_ms": round(tpct["p99"] * 1e3, 2),
        "threaded_producers": args.producers,
        "router_policy": args.policy,
        "router_replicas": args.replicas,
        "router_p50_ms": round(rpct["p50"] * 1e3, 2),
        "router_p99_ms": round(rpct["p99"] * 1e3, 2),
        "router_routed": rollup["routed"],
        "router_spills": rollup["spills"],
        "async_client_wall_ms": round(awall * 1e3, 1),
        "async_client_p50_ms": round(
            float(np.percentile(alat, 50)) * 1e3, 2),
        "async_client_p99_ms": round(
            float(np.percentile(alat, 99)) * 1e3, 2),
        "async_client_admission_waits": astats["admission_waits"],
        "router_modelled_qps": {f"r{n}": round(v)
                                for n, v in rsweep.items()},
        "modelled_qps": {f"t{t}": round(v["qps"]) for t, v in sweep.items()},
        "modelled_latency_ms": {f"t{t}": round(v["latency_ms"], 2)
                                for t, v in sweep.items()},
    }, indent=2))


def serve_edge(index, queries, args) -> None:
    """The PR-7 deployment shape: HTTP edge -> coalescing async client ->
    elastic JSQ router, with the autoscaler re-carving replicas under
    load.  Fires a few requests at itself so a bare run shows the whole
    path; ``--hold`` keeps the server up for external curl traffic."""
    import asyncio

    from repro.serve.autoscaler import ReplicaAutoscaler
    from repro.serve.edge import (AnnsEdge, EdgeConfig, HttpConn,
                                  TenantConfig)
    from repro.serve.stack import make_serving_stack

    router = make_serving_stack(index, n_replicas=args.replicas,
                                policy=args.policy)
    scaler = ReplicaAutoscaler(router, min_replicas=1,
                               max_replicas=2 * args.replicas).start()

    async def run() -> None:
        cfg = EdgeConfig(port=args.edge,
                         tenants=[TenantConfig("demo", "demo-key",
                                               rate_qps=0.0)],
                         max_inflight=args.inflight)
        async with AnnsEdge(router, cfg, own_backend=True) as edge:
            print(f"# edge serving on http://{cfg.host}:{edge.port} "
                  f"(x-api-key: demo-key)")
            conn = await HttpConn.open(cfg.host, edge.port)
            for i, q in enumerate(queries[:4]):
                status, doc = await conn.request(
                    "POST", "/v1/search",
                    {"query": q.tolist(), "k": 10, "tag": i},
                    {"x-api-key": "demo-key"})
                print(f"# HTTP {status} tag={doc['tag']} "
                      f"ids[:5]={doc['ids'][:5]}")
            _, stats = await conn.request("GET", "/v1/stats")
            print(json.dumps(stats, indent=2))
            await conn.aclose()
            if args.hold:
                print("# serving until Ctrl-C ...")
                try:
                    await asyncio.Event().wait()
                except (KeyboardInterrupt, asyncio.CancelledError):
                    pass

    try:
        asyncio.run(run())
    except KeyboardInterrupt:
        pass
    finally:
        scaler.stop()


if __name__ == "__main__":
    main()
