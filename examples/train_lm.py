"""Train a small LM end-to-end with the full substrate (checkpointing +
fault supervisor + optional int8-EF gradient compression).

    PYTHONPATH=src python examples/train_lm.py --steps 60
"""

import argparse
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.data.synthetic import lm_batch
from repro.models import transformer as tfm
from repro.models.layers import LOCAL_CTX
from repro.optim.adamw import OptimizerConfig
from repro.train.fault import FaultInjector, supervise
from repro.train.loop import TrainConfig, init_state, make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--inject-failure", action="store_true",
                    help="kill the worker mid-run and watch it recover")
    args = ap.parse_args()

    cfg = get_config("qwen3-0.6b", reduced=True)
    n_params = sum(int(np.prod(p.shape)) for p in
                   jax.tree_util.tree_leaves(tfm.init_lm(jax.random.key(0),
                                                         cfg)))
    print(f"model: {cfg.name} ({n_params/1e6:.2f}M params)")

    ckpt_dir = tempfile.mkdtemp(prefix="repro_ckpt_")
    tcfg = TrainConfig(
        opt=OptimizerConfig(lr=3e-3, warmup_steps=5, total_steps=args.steps),
        ckpt_every=10, ckpt_dir=ckpt_dir, grad_compress_bits=8)

    def loss_fn(p, batch):
        return tfm.lm_loss(p, batch, cfg, LOCAL_CTX, dtype=jnp.float32)

    rng = np.random.default_rng(0)

    def batches(n):
        for _ in range(n):
            b = lm_batch(rng, args.batch, args.seq, cfg.vocab_size)
            yield {k: jnp.asarray(v) for k, v in b.items()}

    injector = FaultInjector(
        fail_at_steps=[args.steps // 2] if args.inject_failure else [])
    state, restarts, history = supervise(
        lambda: jax.jit(make_train_step(loss_fn, tcfg)),
        lambda: init_state(tfm.init_lm(jax.random.key(0), cfg), tcfg),
        batches, tcfg, total_steps=args.steps, on_step=injector)
    for h in history:
        print(f"step {h['step']:4d}  loss {h['loss']:.4f}  "
              f"acc {h['accuracy']:.3f}  lr {h['lr']:.2e}")
    print(f"done: {restarts} restarts, "
          f"loss {history[0]['loss']:.3f} -> {history[-1]['loss']:.3f}, "
          f"checkpoints in {ckpt_dir}")


if __name__ == "__main__":
    main()
