"""RecSys candidate retrieval (the ``retrieval_cand`` cell): score a user
embedding against an item corpus — exact batched-dot vs FusionANNS ANN path
(the paper's technique applied to the recsys serving stack).

    PYTHONPATH=src python examples/recsys_retrieval.py
"""

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.anns_datasets import SIFT_SMALL
from repro.configs.registry import get_config
from repro.core.engine import FusionANNSIndex
from repro.models import recsys


def main() -> None:
    rng = np.random.default_rng(0)
    cfg = get_config("bert4rec", reduced=True)
    params = recsys.init_bert4rec(jax.random.key(0), cfg)

    # user embedding from interaction history
    hist = jnp.asarray(rng.integers(0, cfg.vocab_size, (4, cfg.seq_len)),
                       jnp.int32)
    user = recsys.bert4rec_user_embedding(params, hist, cfg)
    print(f"user embeddings: {user.shape}")

    # corpus = item embedding table (L2-ANN over it after norm trick)
    items = np.asarray(params["item_embed"], np.float32)
    k = 10

    # 1) exact batched dot (the dry-run retrieval cell's dense path)
    t0 = time.time()
    vals, ids = recsys.score_all_items(user, params["item_embed"], k,
                                       recsys.LOCAL_CTX)
    t_exact = time.time() - t0
    print(f"exact top-{k}: {np.asarray(ids[0])[:5]}...  ({t_exact*1e3:.1f} ms)")

    # 2) FusionANNS path: MIPS -> L2 via the augmented-vector trick
    norms = np.sum(items ** 2, axis=1)
    phi = float(norms.max())
    aug = np.concatenate([items, np.sqrt(np.maximum(phi - norms, 0))[:, None]],
                         axis=1).astype(np.float32)
    acfg = dataclasses.replace(
        SIFT_SMALL, n_vectors=len(aug), dim=aug.shape[1],
        pq_m=max(4, (aug.shape[1]) // 4 // 4 * 4), n_posting_fraction=0.05,
        top_m=16, top_n=128)
    # pad dim to a multiple of pq_m for sub-space splitting
    pad = (-aug.shape[1]) % acfg.pq_m
    if pad:
        aug = np.pad(aug, ((0, 0), (0, pad)))
        acfg = dataclasses.replace(acfg, dim=aug.shape[1])
    index = FusionANNSIndex.build(aug, acfg)
    q = np.asarray(user[0], np.float32)
    q_aug = np.pad(q, (0, aug.shape[1] - len(q)))
    t0 = time.time()
    res = index.query(q_aug, k=k)
    t_ann = time.time() - t0
    exact_set = set(np.asarray(ids[0]).tolist())
    overlap = len(exact_set & set(res.ids.tolist())) / k
    print(f"FusionANNS top-{k}: {res.ids[:5]}...  ({t_ann*1e3:.1f} ms host)")
    print(f"recall vs exact: {overlap:.2f}; candidates scanned: "
          f"{res.stats.candidates_scanned} / {len(aug)} "
          f"({100*res.stats.candidates_scanned/len(aug):.1f}% of corpus), "
          f"SSD I/Os {res.stats.ios}")


if __name__ == "__main__":
    main()
