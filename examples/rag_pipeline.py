"""RAG demo (paper Fig. 1): FusionANNS retrieval feeding an LM decode.

    PYTHONPATH=src python examples/rag_pipeline.py
"""

import dataclasses

import jax
import numpy as np

from repro.configs.anns_datasets import SIFT_SMALL
from repro.configs.registry import get_config
from repro.core.engine import FusionANNSIndex
from repro.data.synthetic import clustered_vectors
from repro.models import transformer as tfm
from repro.serve.engine import LMServer, RAGPipeline, ServeConfig
from repro.serve.stack import make_serving_stack


def main() -> None:
    rng = np.random.default_rng(0)
    # knowledge base: 5k vectors ("document embeddings")
    acfg = dataclasses.replace(SIFT_SMALL, n_vectors=5_000, dim=32,
                               pq_m=8, n_posting_fraction=0.02)
    docs = clustered_vectors(rng, acfg.n_vectors, acfg.dim, n_clusters=32)
    index = FusionANNSIndex.build(docs, acfg)
    print(f"knowledge base indexed: {acfg.n_vectors} docs")

    cfg = get_config("qwen3-0.6b", reduced=True)
    params = tfm.init_lm(jax.random.key(0), cfg)
    server = LMServer(params, cfg, ServeConfig(max_len=64))
    # retrieval runs through the SAME serving stack as serve_anns.py
    # (one constructor, one shape): typed requests into a JSQ router
    router = make_serving_stack(index, n_replicas=2)
    ragp = RAGPipeline(index, server, router=router)

    query_vec = docs[42] + 0.05 * rng.standard_normal(acfg.dim) \
        .astype(np.float32)
    prompt = rng.integers(0, cfg.vocab_size, (1, 6), dtype=np.int32)
    out = ragp.answer(query_vec, prompt, n_tokens=12)
    router.stop()
    print(f"retrieved docs: {out['retrieved_ids'].tolist()}")
    print(f"retrieval I/Os: {out['retrieval_stats'].ios}, "
          f"h2d bytes: {out['retrieval_stats'].h2d_bytes}")
    print(f"generated tokens: {out['tokens'][0].tolist()}")
    print(f"decode throughput: {out['tokens_per_s']:.1f} tok/s (CPU)")


if __name__ == "__main__":
    main()
