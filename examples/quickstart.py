"""Quickstart: build a FusionANNS index and run queries.

    PYTHONPATH=src python examples/quickstart.py
"""

import dataclasses
import time

import numpy as np

from repro.configs.anns_datasets import SIFT_SMALL
from repro.core.engine import FusionANNSIndex, ground_truth, recall_at_k
from repro.data.synthetic import clustered_vectors


def main() -> None:
    rng = np.random.default_rng(0)
    cfg = dataclasses.replace(SIFT_SMALL, n_vectors=10_000, dim=64,
                              pq_m=16, n_posting_fraction=0.02)
    print(f"dataset: {cfg.n_vectors} x {cfg.dim} (PQ M={cfg.pq_m})")
    everything = clustered_vectors(rng, cfg.n_vectors + 20, cfg.dim,
                                   n_clusters=64)
    data, queries = everything[:cfg.n_vectors], everything[cfg.n_vectors:]

    t0 = time.time()
    index = FusionANNSIndex.build(data, cfg)
    print(f"offline build: {time.time()-t0:.1f}s — "
          f"{index.posting.n_clusters} posting lists, "
          f"replication {index.posting.replication_factor():.2f}x, "
          f"SSD pages {index.ssd.layout.n_pages}")

    gt = ground_truth(data, queries, cfg.top_k)
    results = index.batch_query(queries)
    rec = recall_at_k(np.stack([r.ids for r in results]), gt, cfg.top_k)
    s = results[0].stats
    print(f"recall@{cfg.top_k} = {rec:.3f}")
    print(f"query 0: {s.candidates_scanned} candidates scanned on the "
          f"accelerator tier, {s.h2d_bytes} B host->device (IDs only), "
          f"{s.ios} SSD I/Os for re-ranking "
          f"({s.rerank_batches} mini-batches, "
          f"early_stopped={s.early_stopped})")


if __name__ == "__main__":
    main()
