"""Quickstart: build a FusionANNS index and serve typed queries.

Uses the unified client API (DESIGN.md §6): a ``SearchRequest`` per
query through an ``ANNSClient`` over the batching service, responses as
``SearchResponse`` (ids / dists / QueryStats / latency).

    PYTHONPATH=src python examples/quickstart.py
"""

import dataclasses
import time

import numpy as np

from repro.configs.anns_datasets import SIFT_SMALL
from repro.core.engine import FusionANNSIndex, ground_truth, recall_at_k
from repro.data.synthetic import clustered_vectors
from repro.serve.anns_service import BatchingANNSService
from repro.serve.client import ANNSClient, SearchRequest


def main() -> None:
    rng = np.random.default_rng(0)
    cfg = dataclasses.replace(SIFT_SMALL, n_vectors=10_000, dim=64,
                              pq_m=16, n_posting_fraction=0.02)
    print(f"dataset: {cfg.n_vectors} x {cfg.dim} (PQ M={cfg.pq_m})")
    everything = clustered_vectors(rng, cfg.n_vectors + 20, cfg.dim,
                                   n_clusters=64)
    data, queries = everything[:cfg.n_vectors], everything[cfg.n_vectors:]

    t0 = time.time()
    index = FusionANNSIndex.build(data, cfg)
    print(f"offline build: {time.time()-t0:.1f}s — "
          f"{index.posting.n_clusters} posting lists, "
          f"replication {index.posting.replication_factor():.2f}x, "
          f"SSD pages {index.ssd.layout.n_pages}")

    # one serving API: a typed request per query, dynamic batching under
    # the hood, a typed response back (ids/dists/stats/latency)
    client = ANNSClient(BatchingANNSService(index, max_batch=8,
                                            max_wait_s=0.0))
    responses = client.search_many(
        [SearchRequest(query=q, tag=i) for i, q in enumerate(queries)])

    gt = ground_truth(data, queries, cfg.top_k)
    rec = recall_at_k(np.stack([r.ids for r in responses]), gt, cfg.top_k)
    r0 = responses[0]
    print(f"recall@{cfg.top_k} = {rec:.3f}")
    print(f"query 0 ({r0.latency_s*1e3:.1f} ms, batch of "
          f"{r0.batch_size}): {r0.stats.candidates_scanned} candidates "
          f"scanned on the accelerator tier, {r0.stats.h2d_bytes} B "
          f"host->device (IDs only), {r0.stats.ios} SSD I/Os for "
          f"re-ranking ({r0.stats.rerank_batches} mini-batches, "
          f"early_stopped={r0.stats.early_stopped})")

    # the same request type works against the index directly (no service)
    direct = index.search(SearchRequest(query=queries[0], k=cfg.top_k))
    assert (direct.ids == r0.ids).all()


if __name__ == "__main__":
    main()
