"""Multi-tenant filtered serving (DESIGN.md §11): one index, two
workloads that must never see each other.

A "shop" tenant runs recsys retrieval (category-filtered, tight
deadlines with adaptive accuracy) while a "docs" tenant runs RAG
retrieval (freshness-windowed) — both over the SAME sealed/delta
segments, separated only by per-tenant base predicates stamped by the
:class:`~repro.serve.tenants.TenantManager`.  The demo bursts past the
docs tenant's admission quota, mutates the index mid-stream (attributed
inserts, deletes, a compaction), and then audits: every returned row
belongs to the requesting tenant, and the per-tenant books never mix.

    PYTHONPATH=src python examples/multi_tenant.py
"""

import dataclasses
import time

import numpy as np

from repro.configs.anns_datasets import SIFT_SMALL
from repro.core.engine import FusionANNSIndex
from repro.core.filters import Eq, In, Range
from repro.data.synthetic import clustered_vectors
from repro.serve.anns_service import BatchingANNSService
from repro.serve.client import SearchRequest
from repro.serve.tenants import QuotaExceeded, TenantConfig, TenantManager

SHOP, DOCS = 0, 1


def main() -> None:
    rng = np.random.default_rng(0)
    cfg = dataclasses.replace(SIFT_SMALL, n_vectors=8_000, dim=64,
                              pq_m=16, n_posting_fraction=0.02)
    n = cfg.n_vectors
    everything = clustered_vectors(rng, n + 48, cfg.dim, n_clusters=64)
    data, queries = everything[:n], everything[n:]

    # one corpus, two namespaces: even rows are the shop's products
    # (with a category), odd rows the docs tenant's passages (with an
    # ingest day for freshness windows)
    tenant_col = np.arange(n) % 2
    category = np.where(tenant_col == SHOP, rng.integers(0, 16, n), -1)
    day = np.where(tenant_col == DOCS, rng.integers(0, 30, n), -1)
    t0 = time.time()
    index = FusionANNSIndex.build(data, cfg, attributes={
        "tenant": tenant_col, "category": category, "day": day})
    print(f"build: {time.time()-t0:.1f}s — {n} rows, "
          f"{index.posting.n_clusters} posting lists, 2 namespaces")

    index.query(queries[0], k=10)            # JIT warmup before deadlines
    svc = BatchingANNSService(index, threaded=True, max_batch=8,
                              max_wait_s=0.001)
    mgr = TenantManager(svc, (
        TenantConfig("shop", "key-shop", rate_qps=0.0,
                     filter=Eq("tenant", SHOP)),
        TenantConfig("docs", "key-docs", rate_qps=50.0, burst=16,
                     filter=Eq("tenant", DOCS)),
    ))

    def audit(tag, futs):
        leaked = served = 0
        for tenant, fut in futs:
            resp = fut.result()
            served += 1
            want = SHOP if tenant == "shop" else DOCS
            leaked += int((tenant_col[np.asarray(resp.ids)] != want).any())
        sel = [f.result().stats for _, f in futs[:1]]
        print(f"{tag}: {served} served, cross-tenant leaks: {leaked}"
              + (f", selectivity {sel[0].candidates_scanned}"
                 f"/{sel[0].candidates_prefilter}" if sel else ""))
        assert leaked == 0

    # ---- mixed burst: recsys with adaptive deadlines + RAG freshness
    futs, quota_hits = [], 0
    for i, q in enumerate(queries):
        if i % 2 == SHOP:
            req = SearchRequest(query=q, k=10, tenant="shop",
                                filter=In("category", tuple(
                                    rng.integers(0, 16, 4).tolist())),
                                deadline_s=0.5, adaptive=True)
        else:
            req = SearchRequest(query=q, k=8, tenant="docs",
                                filter=Range("day", 23, 30))
        try:
            futs.append((req.tenant, mgr.submit(req)))
        except QuotaExceeded as exc:
            quota_hits += 1
            time.sleep(exc.retry_after)      # honest backoff, then retry
            futs.append((req.tenant, mgr.submit(req)))
    audit("mixed burst", futs)
    print(f"docs quota rejections absorbed with Retry-After: {quota_hits}")

    # ---- mutations mid-stream: fresh docs arrive, stale shop rows go
    fresh = clustered_vectors(rng, 64, cfg.dim, n_clusters=4)
    new_ids = index.insert(fresh, attributes={
        "tenant": np.full(64, DOCS), "day": np.full(64, 30)})
    stale = np.flatnonzero(tenant_col == SHOP)[:40]
    index.delete(stale)
    index.compact()                          # seal + purge tombstones
    tenant_col2 = np.concatenate([tenant_col, np.full(64, DOCS)])

    futs = [("docs", mgr.submit(SearchRequest(
        query=q, k=8, tenant="docs", filter=Range("day", 28, 31))))
        for q in fresh[:12]]
    for _, f in futs:
        ids = np.asarray(f.result().ids)
        assert (tenant_col2[ids] != SHOP).all()
        assert not (set(ids.tolist()) & set(stale.tolist()))
    hits = sum(int(f.result().ids[0] in set(new_ids.tolist()))
               for _, f in futs)
    print(f"post-mutation: {hits}/12 fresh-doc queries hit the new rows, "
          f"0 purged/foreign rows returned")

    roll = mgr.tenant_rollup()
    for name in mgr.tenant_names():
        book = roll[name]
        print(f"  {name}: ok={book['ok']} quota_rejected="
              f"{book['quota_rejected']} p99="
              f"{book['latency']['p99']*1e3:.1f}ms scanned="
              f"{book['query_stats']['candidates_scanned']}")
    svc.stop()
    print("OK")


if __name__ == "__main__":
    main()
