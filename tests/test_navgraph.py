"""Navigation graph build + search quality."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import navgraph as ng
from repro.data.synthetic import clustered_vectors


@pytest.fixture(scope="module")
def graph():
    rng = np.random.default_rng(0)
    pts = clustered_vectors(rng, 400, 16, n_clusters=12)
    return pts, ng.build_navgraph(pts, degree=16)


def test_graph_structure(graph):
    pts, g = graph
    assert g.neighbors.shape[0] == 400
    assert (g.neighbors < 400).all()
    # every non-entry vertex has at least one neighbour
    assert ((g.neighbors >= 0).sum(1)[1:] >= 1).all()


def test_search_recall_vs_bruteforce(graph):
    pts, g = graph
    rng = np.random.default_rng(1)
    hits, total = 0, 0
    for _ in range(20):
        q = pts[rng.integers(0, 400)] + 0.05 * rng.standard_normal(16) \
            .astype(np.float32)
        found = ng.search(g, q, top_m=10)
        exact = np.argsort(np.sum((pts - q) ** 2, -1))[:10]
        hits += len(set(found.tolist()) & set(exact.tolist()))
        total += 10
    assert hits / total >= 0.85


def test_search_returns_sorted_by_distance(graph):
    pts, g = graph
    q = pts[7]
    found = ng.search(g, q, top_m=8)
    d = np.sum((pts[found] - q) ** 2, -1)
    assert (np.diff(d) >= -1e-5).all()


def test_jax_search_matches_host_quality(graph):
    pts, g = graph
    rng = np.random.default_rng(2)
    q = pts[rng.integers(0, 400)] + 0.05 * rng.standard_normal(16) \
        .astype(np.float32)
    ids_host = ng.search(g, q, top_m=10)
    seeds = jnp.arange(0, len(pts), 8)      # stratified device-side seeds
    ids_dev, _ = ng.search_jax(jnp.asarray(pts), jnp.asarray(g.neighbors),
                               g.entry, jnp.asarray(q), 10, seeds=seeds)
    exact = set(np.argsort(np.sum((pts - q) ** 2, -1))[:10].tolist())
    dev_hits = len(set(np.asarray(ids_dev).tolist()) & exact)
    host_hits = len(set(ids_host.tolist()) & exact)
    assert dev_hits >= host_hits - 3      # same ballpark quality
