"""Segmented streaming index (DESIGN.md §10): delta segment semantics,
epoch-stamped view publication, background compaction, and snapshot
checkpoint/restore parity."""

import copy
import time

import numpy as np
import pytest

from repro.core.engine import FusionANNSIndex
from repro.core.segments import DeltaSegment, IndexView


@pytest.fixture()
def index_and_data(anns_bundle, fresh_index):
    b = anns_bundle
    return b.cfg, b.data, b.new_vecs, b.queries, fresh_index


# ---------------------------------------------------------------------------
# DeltaSegment
# ---------------------------------------------------------------------------

def test_delta_segment_is_functional():
    d0 = DeltaSegment.empty(100, 4)
    d1 = d0.append(np.ones((3, 4), np.float32))
    assert len(d0) == 0 and len(d1) == 3          # d0 untouched
    assert d1.ids.tolist() == [100, 101, 102]
    d2 = d1.tombstone(np.array([1]))
    assert not d1.tombstoned.any()                # d1 untouched
    assert d2.tombstoned.tolist() == [False, True, False]
    assert d2.live_count() == 2
    d3 = d2.drop_prefix(2)
    assert d3.base == 102 and d3.ids.tolist() == [102]


def test_delta_scan_is_exact_squared_l2():
    d = DeltaSegment.empty(10, 3).append(
        np.array([[1, 0, 0], [0, 2, 0]], np.float32))
    ids, dists = d.scan(np.zeros(3, np.float32))
    assert ids.tolist() == [10, 11]
    np.testing.assert_allclose(dists, [1.0, 4.0])
    ids2, _ = d.tombstone(np.array([0])).scan(np.zeros(3, np.float32))
    assert ids2.tolist() == [11]


# ---------------------------------------------------------------------------
# View publication
# ---------------------------------------------------------------------------

def test_views_are_immutable_and_epoch_stamped(index_and_data):
    cfg, data, new_vecs, queries, index = index_and_data
    v0 = index.view()
    ids = index.insert(new_vecs)
    v1 = index.view()
    assert v1 is not v0 and v1.epoch == v0.epoch + 1
    assert len(v0.delta) == 0 and len(v1.delta) == len(new_vecs)
    assert v1.n_total == v0.n_total + len(new_vecs)
    index.delete(ids[:1])
    v2 = index.view()
    assert v2.epoch == v1.epoch + 1
    assert not v1.delta.tombstoned.any()          # old view untouched
    assert v2.delta.tombstoned[0]
    index.compact()
    v3 = index.view()
    assert v3.epoch == v2.epoch + 1
    assert v1.codes.shape[0] == v0.n_sealed       # old binding preserved
    # seal-time purge (PR 10): the row tombstoned in the delta is DROPPED
    # at the seal instead of being encoded — one fewer physical row than
    # sealed ids
    assert v3.codes.shape[0] == v0.n_sealed + len(new_vecs) - 1
    assert v3.n_rows == v3.codes.shape[0]
    assert v3.n_sealed == v0.n_sealed + len(new_vecs)   # ids never recycle


def test_candidate_ids_never_exceed_sealed_prefix(index_and_data):
    cfg, data, new_vecs, queries, index = index_and_data
    index.insert(new_vecs)
    view = index.view()
    for q in queries:
        ids = view.candidate_ids(q, cfg.top_m)
        if len(ids):
            assert ids.max() < view.n_sealed == view.codes.shape[0]


def test_compaction_purges_tombstoned_delta_rows(index_and_data):
    """Rows tombstoned before the seal never enter the posting lists.
    Posting members are physical ROW indices since the PR-10 purge; the
    view's ``id_of`` maps them back to global ids."""
    cfg, data, new_vecs, queries, index = index_and_data
    ids = index.insert(new_vecs)
    index.delete(ids[:3])
    index.compact()
    view = index.view()
    member_ids = view.id_of[np.concatenate(index.posting.members)]
    assert not (set(ids[:3].tolist()) & set(member_ids.tolist()))
    # surviving rows ARE reachable through the sealed tiers
    assert set(ids[3:].tolist()) <= set(member_ids.tolist())


def test_seal_time_purge_accounting(index_and_data):
    """The purge's whole ledger: physical rows, SSD rows, id maps, and
    the n_sealed/n_rows split all agree after sealing a delta with
    tombstoned rows — and purged ids stay tombstoned forever (they can
    never resurface through row arithmetic)."""
    cfg, data, new_vecs, queries, index = index_and_data
    n0 = index.view().n_sealed
    ids = index.insert(new_vecs)
    index.delete(ids[5:9])                         # 4 of 20 purged at seal
    sealed = index.compact()
    assert sealed == len(new_vecs)                 # delta rows consumed
    view = index.view()
    n_live = len(new_vecs) - 4
    assert view.n_sealed == n0 + len(new_vecs)
    assert view.n_rows == n0 + n_live
    assert view.codes.shape[0] == view.n_rows
    assert len(index.ssd.vectors) >= view.n_rows   # SSD rows track rows,
    #                                                not ids
    # id_of is strictly increasing (order-preserving seal) and row_of is
    # its exact inverse, with purged ids mapped to -1
    assert (np.diff(view.id_of) > 0).all()
    np.testing.assert_array_equal(view.row_of[view.id_of],
                                  np.arange(view.n_rows))
    assert (view.row_of[ids[5:9]] == -1).all()
    assert view.tombstones[ids[5:9]].all()
    # survivors stay queryable under their ORIGINAL global ids
    for j in list(range(5)) + list(range(9, len(new_vecs))):
        assert int(index.query(new_vecs[j], k=1).ids[0]) == int(ids[j])
    # purged ids never appear in any result
    for q in list(queries[:4]) + list(new_vecs[5:9]):
        got = index.query(q, k=10).ids
        assert not (set(got.tolist()) & set(ids[5:9].tolist()))


def test_concurrent_compact_serializes(index_and_data):
    cfg, data, new_vecs, queries, index = index_and_data
    index.insert(new_vecs)
    assert index.compact(wait=False) == len(new_vecs)
    assert index.compact(wait=False) == 0          # nothing left to seal


def test_background_compactor_seals_while_serving(index_and_data):
    cfg, data, new_vecs, queries, index = index_and_data
    index.start_compactor(min_delta=8, poll_s=0.01)
    try:
        ids = index.insert(new_vecs)               # 20 >= threshold
        deadline = time.time() + 20.0
        while index.delta_size and time.time() < deadline:
            index.query(queries[0], k=5)           # serve during the seal
            time.sleep(0.01)
        assert index.delta_size == 0
        assert index.codes.shape[0] == index.n_total
        hits = sum(int(index.query(v, k=1).ids[0] == nid)
                   for v, nid in zip(new_vecs, ids))
        assert hits >= 18
    finally:
        index.stop_compactor()


def test_deepcopy_gets_fresh_locks_and_no_compactor(index_and_data):
    cfg, data, new_vecs, queries, index = index_and_data
    index.start_compactor(min_delta=10**6)
    try:
        clone = copy.deepcopy(index)
    finally:
        index.stop_compactor()
    assert clone._compactor is None
    assert clone._mut_lock is not index._mut_lock
    clone.insert(new_vecs)
    assert clone.delta_size == len(new_vecs) and index.delta_size == 0


# ---------------------------------------------------------------------------
# Snapshots
# ---------------------------------------------------------------------------

def _assert_bit_identical(a: FusionANNSIndex, b: FusionANNSIndex, queries):
    for q in queries:
        ra, rb = a.query(q), b.query(q)
        np.testing.assert_array_equal(ra.ids, rb.ids)
        np.testing.assert_array_equal(ra.dists, rb.dists)


def test_snapshot_roundtrip_sealed_only(index_and_data, tmp_path):
    cfg, data, new_vecs, queries, index = index_and_data
    index.save_snapshot(str(tmp_path / "snap"))
    restored = FusionANNSIndex.load_snapshot(str(tmp_path / "snap"))
    assert restored.epoch == index.epoch
    assert restored.n_total == index.n_total
    _assert_bit_identical(index, restored, queries)


def test_snapshot_roundtrip_with_delta_and_tombstones(index_and_data,
                                                      tmp_path):
    """The acceptance bar: a replica restored from save_snapshot returns
    bit-identical top-k ids to the live index it was taken from — sealed
    tiers, unsealed delta rows, and tombstones in both segments."""
    cfg, data, new_vecs, queries, index = index_and_data
    ids = index.insert(new_vecs[:12])
    index.compact()                                # some sealed inserts
    ids2 = index.insert(new_vecs[12:])             # plus a live delta
    index.delete(np.array([ids[0], ids2[0], 3]))   # both segments + base
    index.save_snapshot(str(tmp_path / "snap"))
    restored = FusionANNSIndex.load_snapshot(str(tmp_path / "snap"))
    assert restored.epoch == index.epoch
    assert restored.delta_size == index.delta_size == len(new_vecs) - 12
    _assert_bit_identical(index, restored, queries)
    _assert_bit_identical(index, restored, new_vecs)
    # and the restored copy keeps evolving correctly on its own
    both = [index, restored]
    for ix in both:
        ix.insert(new_vecs[:4])
        ix.compact()
    _assert_bit_identical(index, restored, queries)


def test_snapshot_excludes_unpublished_ssd_rows(index_and_data, tmp_path):
    """save during the compaction gap: the SSD tier is truncated to the
    captured view's sealed prefix, so restore + compact never duplicates
    rows."""
    cfg, data, new_vecs, queries, index = index_and_data
    index.insert(new_vecs)
    index.save_snapshot(str(tmp_path / "snap"))
    restored = FusionANNSIndex.load_snapshot(str(tmp_path / "snap"))
    assert len(restored.ssd.vectors) == restored.view().n_sealed
    restored.compact()
    index.compact()
    assert len(restored.ssd.vectors) == len(index.ssd.vectors)
    _assert_bit_identical(index, restored, new_vecs)


def test_stack_boots_from_snapshot(index_and_data, tmp_path):
    from repro.serve.client import as_request
    from repro.serve.stack import make_serving_stack
    cfg, data, new_vecs, queries, index = index_and_data
    index.insert(new_vecs)
    index.save_snapshot(str(tmp_path / "snap"))
    want = [index.query(q, k=5).ids for q in queries[:4]]
    router = make_serving_stack(index=None, n_replicas=2, threaded=False,
                                snapshot_dir=str(tmp_path / "snap"))
    try:
        futs = [router.submit(as_request(q, k=5)) for q in queries[:4]]
        router.drain()
        got = [f.result().ids for f in futs]
    finally:
        router.stop()
    for w, g in zip(want, got):
        np.testing.assert_array_equal(w, g)
