import os
import sys

# smoke tests / benches must see exactly 1 device (dryrun.py alone forces
# 512); keep any user XLA_FLAGS out of the test environment.
os.environ.pop("XLA_FLAGS", None)

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
