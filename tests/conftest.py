"""Shared fixtures.

Policy notes
------------
* ``hypothesis`` is OPTIONAL: property tests import ``given``/``settings``/
  ``strategies`` from ``tests/_propshim.py``, which uses the real package
  when installed and otherwise falls back to a small deterministic
  generator covering the strategy subset this suite uses.  Tier-1 must
  collect and pass with no ``hypothesis`` in the environment.
* One small FusionANNS index is built ONCE per session (``anns_bundle``)
  and shared by the engine / system / executor / service / updates
  modules; tests that mutate the index (insert/delete) take the
  ``fresh_index`` deep copy instead of rebuilding.
* Heavy system tests carry ``@pytest.mark.slow`` and are deselected by
  default via pytest.ini; run them with ``-m ""`` or
  ``scripts/check.sh full``.
"""

import dataclasses
import os
import sys

# smoke tests / benches must see exactly 1 device (dryrun.py alone forces
# 512); keep any user XLA_FLAGS out of the test environment.
os.environ.pop("XLA_FLAGS", None)

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))      # for _propshim

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


@pytest.fixture(autouse=True)
def _witness_guard():
    """When LINT_LOCKS is set, the serving stack's locks are OrderedLock
    witnesses recording nested acquisitions.  Fail any test whose body
    produced a hierarchy inversion (record mode collects instead of
    raising so the offending test — not a later one — gets the blame)."""
    from repro.analysis.concurrency import witness
    if not witness.enabled():
        yield
        return
    witness.WITNESS.drain_violations()
    yield
    bad = witness.WITNESS.drain_violations()
    assert not bad, ("lock-order violations witnessed:\n"
                     + "\n".join(map(str, bad)))


@dataclasses.dataclass
class ANNSBundle:
    """One built index + held-out data shared across test modules."""

    cfg: object
    data: np.ndarray          # the indexed vectors
    new_vecs: np.ndarray      # held-out rows for insert tests (never indexed)
    queries: np.ndarray       # held-out query rows
    gt: np.ndarray            # exact top-10 ids for ``queries`` over ``data``
    index: object


@pytest.fixture(scope="session")
def anns_bundle() -> ANNSBundle:
    from repro.configs.anns_datasets import SIFT_SMALL
    from repro.core.engine import FusionANNSIndex, ground_truth
    from repro.data.synthetic import clustered_vectors

    rng = np.random.default_rng(0)
    n, dim = 2500, 32
    cfg = dataclasses.replace(SIFT_SMALL, n_vectors=n, dim=dim,
                              n_posting_fraction=0.02)
    everything = clustered_vectors(rng, n + 40, dim, n_clusters=24)
    data, new_vecs, queries = (everything[:n], everything[n:n + 20],
                               everything[n + 20:])
    index = FusionANNSIndex.build(data, cfg)
    gt = ground_truth(data, queries, 10)
    return ANNSBundle(cfg=cfg, data=data, new_vecs=new_vecs,
                      queries=queries, gt=gt, index=index)


@pytest.fixture
def fresh_index(anns_bundle):
    """Mutable deep copy of the shared index (for insert/delete tests)."""
    import copy
    return copy.deepcopy(anns_bundle.index)
