"""One serving API (ISSUE 5 acceptance): typed ``SearchRequest``/
``SearchResponse``, the ``Backend`` protocol, and the sync/async front
doors.

Contract under test:
* executor, batching service, and replica router all implement the
  ``Backend`` protocol — typed ``submit()`` futures resolving directly to
  ``SearchResponse``, ``drain()`` returning the served responses on every
  backend (the pre-PR-5 router returned ``None``), the shared
  ``stats_rollup()`` shape;
* bit-identical ids across all four public paths for the same queries:
  ``FusionANNSIndex.query``, the sync ``ANNSClient`` over the service,
  the ``AsyncANNSClient`` over the router, and legacy ``executor.run()``;
* the asyncio front door AWAITS admission instead of raising
  ``BackpressureError``, maps deadlines to asyncio timeouts, streams
  ``search_many()`` results in completion order, and leaks zero futures
  across ``aclose()`` — including under ≥200 concurrent coroutines over a
  2-replica router.
"""

import asyncio
import time

import numpy as np
import pytest

from repro.core.futures import (BackpressureError, DeadlineExceeded,
                                QueryFuture)
from repro.serve.anns_service import BatchingANNSService
from repro.serve.client import (ANNSClient, AsyncANNSClient, Backend,
                                SearchRequest, SearchResponse, as_request)
from repro.serve.router import ReplicaRouter


@pytest.fixture(scope="module")
def ref_ids(anns_bundle):
    """index.query ids per held-out query — the parity baseline."""
    return [anns_bundle.index.query(q).ids for q in anns_bundle.queries]


# ------------------------------------------------------------ typed surface

def test_backend_protocol_conformance(anns_bundle):
    """Executor, service, and router all satisfy the runtime-checkable
    protocol AND the behavioural contract: typed submit -> SearchResponse
    future, drain() -> served responses."""
    b = anns_bundle
    backends = {
        "executor": b.index.executor,
        "service": BatchingANNSService(b.index, max_batch=4, max_wait_s=0.0),
        "router": ReplicaRouter(b.index, n_replicas=2, threaded=False,
                                max_batch=4, max_wait_s=0.0),
    }
    for name, backend in backends.items():
        assert isinstance(backend, Backend), name
        fut = backend.submit(SearchRequest(query=b.queries[0], tag="t0"))
        assert isinstance(fut, QueryFuture), name
        drained = backend.drain()
        assert isinstance(drained, list) and len(drained) == 1, name
        assert isinstance(drained[0], SearchResponse), name
        resp = fut.result()
        assert resp is drained[0], name    # future and drain agree
        np.testing.assert_array_equal(resp.ids, b.index.query(
            b.queries[0]).ids, err_msg=name)
        roll = backend.stats_rollup()
        assert roll["served"] >= 1, name
        assert roll["query_stats"]["candidates_scanned"] > 0, name
        pct = backend.latency_percentiles()
        assert pct["n"] >= 1 and pct["p50"] > 0, name
        assert backend.live_load() == 0, name
        backend.stop()


def test_search_request_response_types(anns_bundle):
    b = anns_bundle
    svc = BatchingANNSService(b.index, max_batch=4, max_wait_s=0.0)
    req = SearchRequest(query=b.queries[0], k=5, tag="abc")
    fut = svc.submit(req)
    assert fut.tag == "abc"                # tag rides to the future
    resp = fut.result()
    assert isinstance(resp, SearchResponse)
    assert resp.tag == "abc" and resp.rid == 0
    assert len(resp.ids) == 5 and resp.latency_s > 0
    assert resp.t_serve_s > 0 and resp.batch_size == 1
    np.testing.assert_array_equal(resp.ids, b.index.query(
        b.queries[0], k=5).ids)
    # the PR-5 migration shims (positional submit, Response alias,
    # resp.result) are gone: backend submit is SearchRequest-only
    with pytest.raises(TypeError):
        svc.submit(b.queries[0])
    assert not hasattr(resp, "result")
    # as_request builds a request from the raw front-door form, and passes
    # a ready-made request through untouched
    legacy = as_request(b.queries[0], 5, tag="abc")
    assert legacy.k == 5 and legacy.tag == "abc"
    assert as_request(req) is req
    # explicit kwargs riding along with a ready-made request OVERRIDE its
    # fields (fresh request, original untouched) — never silently dropped
    riding = as_request(req, 3, deadline_s=0.5)
    assert riding is not req and riding.k == 3 and riding.deadline_s == 0.5
    assert riding.tag == "abc" and req.k == 5


def test_index_search_typed_entrypoint(anns_bundle):
    b = anns_bundle
    resp = b.index.search(SearchRequest(query=b.queries[1], k=7))
    assert isinstance(resp, SearchResponse) and len(resp.ids) == 7
    np.testing.assert_array_equal(resp.ids,
                                  b.index.query(b.queries[1], k=7).ids)


# --------------------------------------------------------- executor backend

def test_executor_backend_async_and_cancel(anns_bundle):
    """The executor's request path is a real submission: the future is
    pending on return (scan in flight), result() drives retirement, and
    cancelling the client-facing future skips the query's re-rank."""
    b = anns_bundle
    ex = b.index.executor
    fut = ex.submit(SearchRequest(query=b.queries[2], tag="x"))
    assert not fut.done() and ex.live_load() == 1
    np.testing.assert_array_equal(fut.result().ids,
                                  b.index.query(b.queries[2]).ids)
    victim = ex.submit(SearchRequest(query=b.queries[3]))
    assert victim.cancel() and victim.cancelled()
    ex.drain()                             # retires the cancelled ticket
    assert ex.live_load() == 0


# ----------------------------------------------------------- 6-path parity

def test_six_path_id_parity(anns_bundle, ref_ids):
    """Bit-identical ids across index.query, legacy executor.run(), the
    sync ANNSClient over the service, the AsyncANNSClient over a
    2-replica router, the fused scan pipeline, and the HTTP edge over a
    real socket."""
    b = anns_bundle
    # path 2: legacy executor.run (per-query windows, like index.query)
    run_res = b.index.executor.run(b.queries, b.index.plan(window=1))
    for ref, rr in zip(ref_ids, run_res):
        np.testing.assert_array_equal(ref, rr.ids)
    # path 3: sync client over the (sync-harness) batching service
    client = ANNSClient(BatchingANNSService(b.index, max_batch=8,
                                            max_wait_s=0.0))
    resps = client.search_many(
        [SearchRequest(query=q, tag=i) for i, q in enumerate(b.queries)])
    for ref, resp in zip(ref_ids, resps):
        np.testing.assert_array_equal(ref, resp.ids)
    # path 4: asyncio front door over a threaded 2-replica router
    router = ReplicaRouter(b.index, n_replicas=2, policy="jsq",
                           threaded=True, max_batch=8, max_wait_s=0.0005)

    async def drive():
        async with AsyncANNSClient(router, max_inflight=32) as ac:
            reqs = [SearchRequest(query=q, tag=i)
                    for i, q in enumerate(b.queries)]
            return {r.tag: r.ids async for r in ac.search_many(reqs)}

    try:
        by_tag = asyncio.run(drive())
    finally:
        router.stop()
    assert len(by_tag) == len(b.queries)
    for i, ref in enumerate(ref_ids):
        np.testing.assert_array_equal(ref, by_tag[i])
    # path 5: the fused LUT→ADC→top-k scan pipeline (ISSUE-6 tentpole)
    # through the sync client over a fused-plan service — same ids again
    fused_client = ANNSClient(BatchingANNSService(
        b.index, max_batch=8, max_wait_s=0.0, fused=True))
    fused_resps = fused_client.search_many(
        [SearchRequest(query=q, tag=i) for i, q in enumerate(b.queries)])
    for ref, resp in zip(ref_ids, fused_resps):
        np.testing.assert_array_equal(ref, resp.ids)
    # path 6: the HTTP edge (PR-7 tentpole) — the same ids through a real
    # socket: JSON in, JSON out, bit-identical to index.query
    from repro.serve.edge import AnnsEdge, EdgeConfig, HttpConn

    async def drive_http():
        svc = BatchingANNSService(b.index, threaded=True, max_batch=8,
                                  max_wait_s=0.0005)
        async with AnnsEdge(svc, EdgeConfig(), own_backend=True) as edge:
            conn = await HttpConn.open("127.0.0.1", edge.port)
            out = []
            for q in b.queries:
                status, payload = await conn.request(
                    "POST", "/v1/search", {"query": q.tolist()})
                assert status == 200
                out.append(payload["ids"])
            await conn.aclose()
            return out

    for ref, ids in zip(ref_ids, asyncio.run(drive_http())):
        np.testing.assert_array_equal(ref, np.asarray(ids))


# ------------------------------------------------------------ asyncio doors

def test_async_stress_200_coroutines(anns_bundle, ref_ids):
    """≥200 concurrent search() coroutines over a 2-replica router:
    bit-identical ids vs run(), zero leaked futures on aclose()."""
    b = anns_bundle
    n_req = 200
    router = ReplicaRouter(b.index, n_replicas=2, policy="jsq",
                           threaded=True, max_batch=16, max_wait_s=0.0005,
                           scan_window=8, inflight_depth=2, max_queue=64)
    client = AsyncANNSClient(router, max_inflight=128)

    async def one(i):
        return await client.search(SearchRequest(
            query=b.queries[i % len(b.queries)], tag=i))

    async def drive():
        out = await asyncio.gather(*[one(i) for i in range(n_req)])
        await client.aclose()
        return out

    try:
        resps = asyncio.run(drive())
    finally:
        router.stop()
    assert len(resps) == n_req
    for resp in resps:
        np.testing.assert_array_equal(resp.ids,
                                      ref_ids[resp.tag % len(b.queries)])
    # zero leaks: nothing pending anywhere after aclose()
    assert client.stats["completed"] == n_req
    assert not client._inflight
    assert router.live_load() == 0
    roll = router.stats_rollup()
    assert roll["served"] == n_req
    assert sum(roll["routed"]) == n_req


class _StubBackend:
    """Minimal Backend whose futures resolve when the test says so —
    deterministic probe for the bridge/ordering/deadline contracts (and
    proof that ANY protocol implementation composes with the client)."""

    def __init__(self):
        self.futs = {}

    def submit(self, request: SearchRequest) -> QueryFuture:
        fut = QueryFuture(tag=request.tag, blocking=True)
        self.futs[request.tag] = fut
        return fut

    def resolve(self, tag):
        self.futs[tag]._set_result(SearchResponse(
            ids=np.array([tag]), dists=np.zeros(1), stats=None, tag=tag))

    def drain(self):
        return []

    def stop(self):
        return self

    def live_load(self):
        return sum(1 for f in self.futs.values() if not f.done())

    def latency_percentiles(self):
        return {"p50": 0.0, "p99": 0.0, "n": 0}

    def stats_rollup(self):
        return {"served": 0, "query_stats": {}}


def test_as_completed_streaming_order():
    """search_many yields in COMPLETION order, not submission order."""
    stub = _StubBackend()
    assert isinstance(stub, Backend)
    completion = [2, 0, 1]

    async def drive():
        client = AsyncANNSClient(stub, max_inflight=8)

        async def resolver():
            while len(stub.futs) < 3:
                await asyncio.sleep(0.001)
            for tag in completion:
                stub.resolve(tag)
                await asyncio.sleep(0.02)  # let the stream consume it

        task = asyncio.ensure_future(resolver())
        reqs = [SearchRequest(query=np.zeros(4, np.float32), tag=i)
                for i in range(3)]
        got = [r.tag async for r in client.search_many(reqs)]
        await task
        await client.aclose()
        return got

    assert asyncio.run(drive()) == completion


def test_async_deadline_maps_to_asyncio_timeout():
    """A request whose backend never answers times out on the LOOP side:
    DeadlineExceeded (not asyncio.TimeoutError), backend future
    cancelled — so loop-side and rerank-side expiry look identical."""
    stub = _StubBackend()

    async def drive():
        client = AsyncANNSClient(stub)
        with pytest.raises(DeadlineExceeded):
            await client.search(SearchRequest(
                query=np.zeros(4, np.float32), deadline_s=0.05, tag="slow"))
        assert client.stats["deadline_timeouts"] == 1
        await client.aclose()

    asyncio.run(drive())
    assert stub.futs["slow"].cancelled()   # no orphaned backend work


def test_async_deadline_bounds_admission_wait():
    """deadline_s counts admission time too: a request that can never be
    admitted (every submit backpressured) still expires at its deadline
    instead of waiting indefinitely for a slot."""

    class _FullBackend(_StubBackend):
        def submit(self, request):
            raise BackpressureError("always full")

    stub = _FullBackend()

    async def drive():
        client = AsyncANNSClient(stub, admission_poll_s=1e-3)
        t0 = time.perf_counter()
        with pytest.raises(DeadlineExceeded):
            await client.search(SearchRequest(
                query=np.zeros(4, np.float32), deadline_s=0.05))
        assert time.perf_counter() - t0 < 2.0   # not the admission forever
        assert client.stats["admission_waits"] > 0
        await client.aclose()

    asyncio.run(drive())


def test_search_many_consumer_break_cancels_backend():
    """A consumer bailing out of search_many mid-stream must not orphan
    admitted backend work: every already-submitted backend future is
    cancelled (or resolved) — nothing stays pending past the stream."""
    stub = _StubBackend()

    async def drive():
        client = AsyncANNSClient(stub, max_inflight=8)

        async def resolver():
            while not stub.futs:
                await asyncio.sleep(0.001)
            stub.resolve(0)

        task = asyncio.ensure_future(resolver())
        reqs = [SearchRequest(query=np.zeros(4, np.float32), tag=i)
                for i in range(3)]
        async for _ in client.search_many(reqs):
            break                          # bail after the first response
        await task
        await client.aclose()

    asyncio.run(drive())
    assert stub.futs                       # something was admitted
    assert all(f.done() for f in stub.futs.values())
    assert stub.live_load() == 0


def test_async_awaits_admission_instead_of_raising(anns_bundle, ref_ids):
    """Backpressure never reaches an async caller: a full replica queue
    makes the coroutine WAIT for admission, and every request is still
    served exactly once."""
    b = anns_bundle
    svc = BatchingANNSService(b.index, threaded=True, max_batch=4,
                              max_wait_s=0.0005, max_queue=1)
    client = AsyncANNSClient(svc, max_inflight=32)
    n_req = 24

    async def drive():
        resps = await asyncio.gather(*[
            client.search(SearchRequest(
                query=b.queries[i % len(b.queries)], tag=i))
            for i in range(n_req)])
        await client.aclose()
        return resps

    try:
        resps = asyncio.run(drive())
    finally:
        svc.stop()
    for resp in resps:
        np.testing.assert_array_equal(resp.ids,
                                      ref_ids[resp.tag % len(b.queries)])
    # the queue DID reject submissions (backpressure engaged) ...
    assert svc.stats["rejected"] > 0
    # ... and the client absorbed every rejection as an awaited retry
    assert client.stats["admission_waits"] > 0
    assert client.stats["completed"] == n_req


def test_sync_client_blocks_through_admission(anns_bundle, ref_ids):
    """The sync front door has the same guarantee: search() blocks
    through a full queue instead of surfacing BackpressureError."""
    b = anns_bundle
    svc = BatchingANNSService(b.index, max_batch=2, max_wait_s=0.0,
                              max_queue=1)
    client = ANNSClient(svc)
    resps = client.search_many(
        [SearchRequest(query=q, tag=i) for i, q in enumerate(b.queries[:6])])
    for ref, resp in zip(ref_ids, resps):
        np.testing.assert_array_equal(ref, resp.ids)
    assert client.stats["admission_waits"] > 0
    assert svc.stats["rejected"] > 0


def test_async_client_over_sync_backend(anns_bundle, ref_ids):
    """Any front end composes with any backend: the asyncio door over the
    caller-driven sync harness (no pump thread) — futures are driven from
    the loop's thread pool, serialized for the single-driver harness."""
    b = anns_bundle
    svc = BatchingANNSService(b.index, max_batch=4, max_wait_s=0.0)

    async def drive():
        async with AsyncANNSClient(svc, max_inflight=8) as client:
            reqs = [SearchRequest(query=q, tag=i)
                    for i, q in enumerate(b.queries[:8])]
            return {r.tag: r.ids async for r in client.search_many(reqs)}

    by_tag = asyncio.run(drive())
    for i in range(8):
        np.testing.assert_array_equal(ref_ids[i], by_tag[i])


def test_router_drain_returns_responses(anns_bundle):
    """Satellite bugfix: ReplicaRouter.drain() returns the served
    responses (it returned None pre-PR-5), matching the service."""
    b = anns_bundle
    router = ReplicaRouter(b.index, n_replicas=2, threaded=False,
                           max_batch=4, max_wait_s=0.0)
    futs = [router.submit(SearchRequest(query=q, tag=i))
            for i, q in enumerate(b.queries[:6])]
    drained = router.drain()
    assert len(drained) == 6
    assert all(isinstance(r, SearchResponse) for r in drained)
    by_tag = {r.tag: r for r in drained}
    for f in futs:
        assert f.result() is by_tag[f.tag]  # same objects, both surfaces
    assert router.drain() == []            # nothing new since last drain
    router.stop()
