"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs pure-jnp oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.pq_adc import pq_adc, pq_adc_topk, pq_adc_ref
from repro.kernels.pq_adc.pq_adc import pq_adc_scan, pq_adc_scan_topk
from repro.kernels.l2dist import l2_distances, l2dist_ref
from repro.kernels.l2dist.l2dist import l2dist


@pytest.mark.parametrize("n,m,block", [
    (64, 8, 64), (256, 16, 64), (1000, 32, 128), (4096, 25, 1024),
    (100, 8, 1024),   # n < block
])
def test_pq_adc_matches_ref(rng, n, m, block):
    codes = jnp.asarray(rng.integers(0, 256, (n, m)), jnp.uint8)
    lut = jnp.asarray(rng.random((m, 256)), jnp.float32)
    out = pq_adc(codes, lut, block_n=block)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(pq_adc_ref(codes, lut)), rtol=1e-6)


@pytest.mark.parametrize("k_entries", [16, 64, 256])
def test_pq_adc_lut_widths(rng, k_entries):
    # nbits < 8 style LUTs (fewer centroids) must still index correctly
    n, m = 128, 8
    codes = jnp.asarray(rng.integers(0, k_entries, (n, m)), jnp.uint8)
    lut = jnp.asarray(rng.random((m, k_entries)), jnp.float32)
    out = pq_adc_scan(codes, lut, block_n=64, interpret=True)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(pq_adc_ref(codes, lut)), rtol=1e-6)


@pytest.mark.parametrize("n,m,topk,block", [
    (256, 8, 10, 64), (1024, 16, 50, 256), (555, 8, 10, 128),
])
def test_pq_adc_topk_fused(rng, n, m, topk, block):
    codes = jnp.asarray(rng.integers(0, 256, (n, m)), jnp.uint8)
    lut = jnp.asarray(rng.random((m, 256)), jnp.float32)
    vals, ids = pq_adc_topk(codes, lut, topk, block_n=block)
    ref = np.asarray(pq_adc_ref(codes, lut))
    ref_sorted = np.sort(ref)[:topk]
    np.testing.assert_allclose(np.sort(np.asarray(vals)), ref_sorted,
                               rtol=1e-5)
    # ids must actually achieve those distances
    np.testing.assert_allclose(np.sort(ref[np.asarray(ids)]), ref_sorted,
                               rtol=1e-5)


@pytest.mark.parametrize("b,n,d,dtype", [
    (1, 64, 32, jnp.float32), (8, 256, 96, jnp.float32),
    (16, 100, 128, jnp.bfloat16), (128, 1000, 100, jnp.float32),
])
def test_l2dist_matches_ref(rng, b, n, d, dtype):
    q = jnp.asarray(rng.standard_normal((b, d)), dtype)
    v = jnp.asarray(rng.standard_normal((n, d)), dtype)
    out = l2_distances(q, v, block_q=32, block_n=128)
    ref = l2dist_ref(q, v)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=tol, atol=tol)


def test_l2dist_self_distance_zero(rng):
    v = jnp.asarray(rng.standard_normal((32, 64)), jnp.float32)
    d = np.asarray(l2_distances(v, v, block_q=32, block_n=32))
    np.testing.assert_allclose(np.diag(d), 0.0, atol=1e-3)


@pytest.mark.parametrize("n,b,m", [(512, 4, 8), (1000, 8, 16), (2048, 16, 32)])
def test_pq_adc_batch_matches_ref(rng, n, b, m):
    from repro.kernels.pq_adc import pq_adc_batch, pq_adc_batch_ref
    codes = jnp.asarray(rng.integers(0, 256, (n, m)), jnp.uint8)
    luts = jnp.asarray(rng.random((b, m, 256)), jnp.float32)
    out = pq_adc_batch(codes, luts, block_n=256)
    ref = pq_adc_batch_ref(codes, luts)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-6)


@pytest.mark.parametrize("B,S,H,Hk,dh,causal,bq,bk", [
    (2, 16, 4, 2, 8, True, 8, 8),
    (1, 32, 2, 2, 16, False, 16, 8),
    (2, 64, 6, 3, 8, True, 16, 16),
    (1, 24, 4, 1, 8, True, 8, 8),       # MQA
    (1, 16, 2, 2, 8, True, 16, 16),     # single block
])
def test_flash_attention_kernel_matches_ref(rng, B, S, H, Hk, dh, causal,
                                            bq, bk):
    from repro.kernels.flash_attn import flash_attention, flash_attn_ref
    q = jnp.asarray(rng.standard_normal((B, S, H, dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, Hk, dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, Hk, dh)), jnp.float32)
    out = flash_attention(q, k, v, causal=causal, block_q=bq, block_k=bk)
    ref = flash_attn_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_kernel_bf16(rng):
    from repro.kernels.flash_attn import flash_attention, flash_attn_ref
    q = jnp.asarray(rng.standard_normal((1, 32, 4, 8)), jnp.bfloat16)
    k = jnp.asarray(rng.standard_normal((1, 32, 2, 8)), jnp.bfloat16)
    v = jnp.asarray(rng.standard_normal((1, 32, 2, 8)), jnp.bfloat16)
    out = flash_attention(q, k, v, block_q=16, block_k=16)
    ref = flash_attn_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=5e-2, atol=5e-2)
