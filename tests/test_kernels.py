"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs pure-jnp oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.pq_adc import pq_adc, pq_adc_topk, pq_adc_ref
from repro.kernels.pq_adc.pq_adc import pq_adc_scan, pq_adc_scan_topk
from repro.kernels.l2dist import l2_distances, l2dist_ref
from repro.kernels.l2dist.l2dist import l2dist


@pytest.mark.parametrize("n,m,block", [
    (64, 8, 64), (256, 16, 64), (1000, 32, 128), (4096, 25, 1024),
    (100, 8, 1024),   # n < block
])
def test_pq_adc_matches_ref(rng, n, m, block):
    codes = jnp.asarray(rng.integers(0, 256, (n, m)), jnp.uint8)
    lut = jnp.asarray(rng.random((m, 256)), jnp.float32)
    out = pq_adc(codes, lut, block_n=block)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(pq_adc_ref(codes, lut)), rtol=1e-6)


@pytest.mark.parametrize("k_entries", [16, 64, 256])
def test_pq_adc_lut_widths(rng, k_entries):
    # nbits < 8 style LUTs (fewer centroids) must still index correctly
    n, m = 128, 8
    codes = jnp.asarray(rng.integers(0, k_entries, (n, m)), jnp.uint8)
    lut = jnp.asarray(rng.random((m, k_entries)), jnp.float32)
    out = pq_adc_scan(codes, lut, block_n=64, interpret=True)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(pq_adc_ref(codes, lut)), rtol=1e-6)


@pytest.mark.parametrize("n,m,topk,block", [
    (256, 8, 10, 64), (1024, 16, 50, 256), (555, 8, 10, 128),
])
def test_pq_adc_topk_fused(rng, n, m, topk, block):
    codes = jnp.asarray(rng.integers(0, 256, (n, m)), jnp.uint8)
    lut = jnp.asarray(rng.random((m, 256)), jnp.float32)
    vals, ids = pq_adc_topk(codes, lut, topk, block_n=block)
    ref = np.asarray(pq_adc_ref(codes, lut))
    ref_sorted = np.sort(ref)[:topk]
    np.testing.assert_allclose(np.sort(np.asarray(vals)), ref_sorted,
                               rtol=1e-5)
    # ids must actually achieve those distances
    np.testing.assert_allclose(np.sort(ref[np.asarray(ids)]), ref_sorted,
                               rtol=1e-5)


def test_pq_adc_topk_padding_block_does_not_evict(rng):
    """ISSUE-6 regression: a final block that is MOSTLY padding (more
    padding rows than topk) must not evict genuine candidates — the pad
    mask has to run inside each block BEFORE its partial top-k."""
    n, m, block, topk = 2048 + 7, 8, 2048, 32   # final block: 2041 pads
    codes = jnp.asarray(rng.integers(0, 256, (n, m)), jnp.uint8)
    # zero LUT rows for code 0 would hide the bug (pads score 0 and win);
    # random LUTs + offset make padding rows score LOW so eviction shows
    lut = jnp.asarray(rng.random((m, 256)) + 1.0, jnp.float32)
    vals, ids = pq_adc_topk(codes, lut, topk, block_n=block)
    ref_v, ref_i = pq_adc_topk(codes, lut, topk, use_kernel=False)
    np.testing.assert_allclose(np.asarray(vals), np.asarray(ref_v),
                               rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(ids), np.asarray(ref_i))


@pytest.mark.parametrize("n,topk", [(5, 16), (1, 8), (100, 256)])
@pytest.mark.parametrize("use_kernel", [True, False])
def test_pq_adc_topk_n_below_topk_returns_only_real_rows(rng, n, topk,
                                                         use_kernel):
    """ISSUE-6 regression: with n < topk the output is truncated to n —
    all distances finite, every id a real row (no +inf padding ids can
    leak into a rerank candidate list)."""
    m = 8
    codes = jnp.asarray(rng.integers(0, 256, (n, m)), jnp.uint8)
    lut = jnp.asarray(rng.random((m, 256)), jnp.float32)
    vals, ids = pq_adc_topk(codes, lut, topk, use_kernel=use_kernel)
    assert vals.shape == (min(topk, n),)
    assert np.all(np.isfinite(np.asarray(vals)))
    assert np.all((np.asarray(ids) >= 0) & (np.asarray(ids) < n))


def _fused_rows_case(rng, n, m, b, S, k=256, dsub=4):
    codes = jnp.asarray(rng.integers(0, k, (n, m)), jnp.uint8)
    cb = jnp.asarray(rng.standard_normal((m, k, dsub)), jnp.float32)
    q = jnp.asarray(rng.standard_normal((b, m * dsub)), jnp.float32)
    rows = np.full((b, S), -1, np.int32)
    for i in range(b):
        cnt = int(rng.integers(1, min(n, S) + 1))
        rows[i, :cnt] = np.sort(rng.choice(n, cnt, replace=False))
    return codes, cb, q, jnp.asarray(rows)


@pytest.mark.parametrize("use_kernel", [True, False])
@pytest.mark.parametrize("n,b,S,topk", [
    (555, 3, 64, 16), (2048, 4, 128, 128), (300, 2, 512, 16),
])
def test_pq_adc_fused_topk_matches_rows_ref(rng, use_kernel, n, b, S, topk):
    """Fused LUT→ADC→top-k vs the segmented jnp oracle: identical
    distances and ids (incl. (+inf, -1) at empty slots) on both the
    Pallas interpret path and the jnp hot path."""
    from repro.kernels.pq_adc import (build_luts_ref, pq_adc_fused_topk,
                                      pq_adc_rows_ref)
    codes, cb, q, rows = _fused_rows_case(rng, n, 8, b, S)
    luts = build_luts_ref(cb, q)
    d_ref = np.asarray(pq_adc_rows_ref(codes, luts, rows))
    order = np.argsort(d_ref, axis=1, kind="stable")[:, :topk]
    ref_v = np.take_along_axis(d_ref, order, axis=1)
    ref_i = np.take_along_axis(np.asarray(rows), order, axis=1)
    ref_i[~np.isfinite(ref_v)] = -1
    vals, ids = pq_adc_fused_topk(codes, q, cb, rows, topk,
                                  use_kernel=use_kernel)
    fin = np.isfinite(np.asarray(vals))
    np.testing.assert_array_equal(fin, np.isfinite(ref_v))
    np.testing.assert_allclose(np.asarray(vals)[fin], ref_v[fin], rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(ids), ref_i)


@pytest.mark.parametrize("use_kernel", [True, False])
def test_pq_adc_fused_topk_int8_lut_tolerance(rng, use_kernel):
    """fig10 int8-LUT accuracy level: quantized distances stay within the
    asymmetric-quantization error bound of the fp32 oracle (per-element
    max error is scale/2 per subquantizer, fp32 merge adds m of them)."""
    from repro.kernels.pq_adc import build_luts_ref, pq_adc_fused_topk
    n, m, b, S, topk = 800, 8, 3, 128, 32
    codes, cb, q, rows = _fused_rows_case(rng, n, m, b, S)
    luts = np.asarray(build_luts_ref(cb, q))
    # bound: sum over m of (per-table scale)/2
    scale = (luts.max(-1) - luts.min(-1)) / 255.0          # (b, m)
    bound = (scale / 2).sum(-1).max() + 1e-5
    v32, i32 = pq_adc_fused_topk(codes, q, cb, rows, topk,
                                 use_kernel=use_kernel)
    v8, i8 = pq_adc_fused_topk(codes, q, cb, rows, topk,
                               use_kernel=use_kernel, lut_int8=True)
    fin = np.isfinite(np.asarray(v32))
    np.testing.assert_array_equal(fin, np.isfinite(np.asarray(v8)))
    assert np.max(np.abs(np.asarray(v8)[fin] - np.asarray(v32)[fin])) \
        <= bound
    # near-lossless at these shapes: top-k sets overlap almost entirely
    for qi in range(b):
        a = set(np.asarray(i32)[qi][fin[qi]].tolist())
        c = set(np.asarray(i8)[qi][np.isfinite(np.asarray(v8))[qi]].tolist())
        inter = len(a & c) / max(len(a), 1)
        assert inter >= 0.9, (qi, inter)


@pytest.mark.parametrize("b,n,d,dtype", [
    (1, 64, 32, jnp.float32), (8, 256, 96, jnp.float32),
    (16, 100, 128, jnp.bfloat16), (128, 1000, 100, jnp.float32),
])
def test_l2dist_matches_ref(rng, b, n, d, dtype):
    q = jnp.asarray(rng.standard_normal((b, d)), dtype)
    v = jnp.asarray(rng.standard_normal((n, d)), dtype)
    out = l2_distances(q, v, block_q=32, block_n=128)
    ref = l2dist_ref(q, v)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=tol, atol=tol)


def test_l2dist_self_distance_zero(rng):
    v = jnp.asarray(rng.standard_normal((32, 64)), jnp.float32)
    d = np.asarray(l2_distances(v, v, block_q=32, block_n=32))
    np.testing.assert_allclose(np.diag(d), 0.0, atol=1e-3)


@pytest.mark.parametrize("n,b,m", [(512, 4, 8), (1000, 8, 16), (2048, 16, 32)])
def test_pq_adc_batch_matches_ref(rng, n, b, m):
    from repro.kernels.pq_adc import pq_adc_batch, pq_adc_batch_ref
    codes = jnp.asarray(rng.integers(0, 256, (n, m)), jnp.uint8)
    luts = jnp.asarray(rng.random((b, m, 256)), jnp.float32)
    out = pq_adc_batch(codes, luts, block_n=256)
    ref = pq_adc_batch_ref(codes, luts)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-6)


@pytest.mark.parametrize("B,S,H,Hk,dh,causal,bq,bk", [
    (2, 16, 4, 2, 8, True, 8, 8),
    (1, 32, 2, 2, 16, False, 16, 8),
    (2, 64, 6, 3, 8, True, 16, 16),
    (1, 24, 4, 1, 8, True, 8, 8),       # MQA
    (1, 16, 2, 2, 8, True, 16, 16),     # single block
])
def test_flash_attention_kernel_matches_ref(rng, B, S, H, Hk, dh, causal,
                                            bq, bk):
    from repro.kernels.flash_attn import flash_attention, flash_attn_ref
    q = jnp.asarray(rng.standard_normal((B, S, H, dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, Hk, dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, Hk, dh)), jnp.float32)
    out = flash_attention(q, k, v, causal=causal, block_q=bq, block_k=bk)
    ref = flash_attn_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_kernel_bf16(rng):
    from repro.kernels.flash_attn import flash_attention, flash_attn_ref
    q = jnp.asarray(rng.standard_normal((1, 32, 4, 8)), jnp.bfloat16)
    k = jnp.asarray(rng.standard_normal((1, 32, 2, 8)), jnp.bfloat16)
    v = jnp.asarray(rng.standard_normal((1, 32, 2, 8)), jnp.bfloat16)
    out = flash_attention(q, k, v, block_q=16, block_k=16)
    ref = flash_attn_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=5e-2, atol=5e-2)
