"""Filtered search (PR 10 — DESIGN.md §11).

The acceptance bar, verified end to end:

* filtered top-k ids are BIT-IDENTICAL to an exact brute-force
  post-filter oracle (numpy squared-L2 over the matching rows, ties
  broken by smaller id) under an exhaustive plan — across a selectivity
  sweep, for empty-result predicates, delta-only matches, tombstoned
  rows, and through a snapshot round-trip;
* ``QueryStats`` proves the predicate ran at candidate COLLECTION:
  ``candidates_scanned`` equals the matching-row count exactly while
  ``candidates_prefilter`` holds the unfiltered union — scanned/prefilter
  IS the selectivity, and it shrinks proportionally with the predicate;
* the fused LUT→ADC→top-k path honors the same predicate bit-identically
  to the dense path.

Plus unit coverage for the building blocks: :class:`AttributeTable`
functional semantics, fail-closed UNSET handling in every predicate, and
the JSON wire grammar.
"""

import copy
from types import SimpleNamespace

import numpy as np
import pytest

from repro.core.engine import FusionANNSIndex
from repro.core.filters import (UNSET, And, AttributeTable, Eq, In, Range,
                                combine, predicate_from_json,
                                predicate_to_json)

# ---------------------------------------------------------------------------
# AttributeTable
# ---------------------------------------------------------------------------


def test_attribute_table_is_functional():
    t0 = AttributeTable.from_columns(3, {"cat": [1, 2, 3]})
    t1 = t0.append(2, {"cat": [4, 5], "ts": [10, 20]})
    # t0 untouched; t1 backfills the new column with UNSET for old rows
    assert t0.n == 3 and set(t0.columns) == {"cat"}
    assert t1.n == 5
    assert t1.lookup("cat", np.arange(5)).tolist() == [1, 2, 3, 4, 5]
    assert t1.lookup("ts", np.arange(5)).tolist() == [UNSET] * 3 + [10, 20]
    # append WITHOUT the old column backfills it too
    t2 = t1.append(1)
    assert t2.lookup("cat", np.array([5])).tolist() == [UNSET]
    # head / drop_prefix slice rows, extend concatenates tables
    assert t1.head(2).lookup("cat", np.arange(2)).tolist() == [1, 2]
    assert t1.drop_prefix(3).lookup("ts", np.arange(2)).tolist() == [10, 20]
    t3 = t0.extend(t1.drop_prefix(3))
    assert t3.n == 5
    assert t3.lookup("cat", np.arange(5)).tolist() == [1, 2, 3, 4, 5]


def test_attribute_table_rejects_bad_shapes():
    with pytest.raises(ValueError, match="shape"):
        AttributeTable.from_columns(3, {"cat": [1, 2]})
    with pytest.raises(ValueError, match="shape"):
        AttributeTable.empty(2).append(2, {"cat": [1, 2, 3]})


def test_unknown_column_reads_unset():
    t = AttributeTable.from_columns(2, {"cat": [1, 2]})
    assert t.lookup("nope", np.arange(2)).tolist() == [UNSET, UNSET]


# ---------------------------------------------------------------------------
# Predicates: masks fail closed on UNSET
# ---------------------------------------------------------------------------


def _table():
    return AttributeTable.from_columns(
        5, {"cat": [0, 1, 2, UNSET, 1], "ts": [10, 20, 30, 40, UNSET]})


def test_masks_and_unset_fail_closed():
    t, rows = _table(), np.arange(5)
    assert Eq("cat", 1).mask(t, rows).tolist() == \
        [False, True, False, False, True]
    assert In("cat", (0, 2)).mask(t, rows).tolist() == \
        [True, False, True, False, False]
    assert Range("ts", 10, 30).mask(t, rows).tolist() == \
        [True, True, False, False, False]          # half-open: 30 excluded
    # UNSET never matches, even via Eq(col, UNSET) or a Range spanning it
    assert not Eq("cat", UNSET).mask(t, rows).any()
    assert not Range("cat", -5, 5).mask(t, rows)[3]
    assert not In("ts", (UNSET,)).mask(t, rows).any()
    # a column nobody ever wrote matches nothing at all
    assert not Eq("ghost", 0).mask(t, rows).any()
    # And = intersection
    both = And((Eq("cat", 1), Range("ts", 0, 25)))
    assert both.mask(t, rows).tolist() == [False, True, False, False, False]


def test_in_canonicalizes_and_hashes():
    assert In("c", (2, 1, 2)) == In("c", (1, 2))
    assert hash(In("c", (2, 1, 2))) == hash(In("c", (1, 2)))
    assert len({Eq("c", 1), Eq("c", 1), Eq("c", 2)}) == 2


def test_combine_none_semantics():
    p = Eq("c", 1)
    assert combine(None, None) is None
    assert combine(p, None) is p and combine(None, p) is p
    assert combine(p, Eq("d", 2)) == And((p, Eq("d", 2)))


def test_predicate_json_roundtrip():
    preds = [Eq("cat", 3), In("cat", (5, 1, 3)), Range("ts", 0, 100),
             And((Eq("tenant", 2), Range("ts", 10, 20),
                  In("cat", (0, 1)))), None]
    for p in preds:
        assert predicate_from_json(predicate_to_json(p)) == p


@pytest.mark.parametrize("doc", [
    ["eq", "cat", 1],                 # not a dict
    {"eq": ["cat"]},                  # arity
    {"eq": ["cat", "notanint"]},      # type
    {"range": ["ts", 1]},             # arity
    {"bogus": ["cat", 1]},            # unknown kind
    {"and": [None]},                  # null child
    {"and": [{"eq": ["c", 1]}, {"nope": []}]},
    {"eq": ["a", 1], "in": ["b", [1]]},   # two keys
])
def test_malformed_predicate_json_rejected(doc):
    with pytest.raises(ValueError):
        predicate_from_json(doc)


# ---------------------------------------------------------------------------
# End-to-end: exhaustive filtered queries vs the brute-force oracle
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def fmod(anns_bundle):
    """An attributed build over the bundle's data: deterministic columns
    (``cat = id % 8``, ``tenant = id % 3``, ``ts = id % 100``) so every
    oracle mask is computable by hand."""
    b = anns_bundle
    ids = np.arange(len(b.data))
    cats, tens, ts = ids % 8, ids % 3, ids % 100
    index = FusionANNSIndex.build(
        b.data, b.cfg, attributes={"cat": cats, "tenant": tens, "ts": ts})
    return SimpleNamespace(index=index, data=b.data, cats=cats, tens=tens,
                           ts=ts, queries=b.queries, new_vecs=b.new_vecs)


@pytest.fixture()
def fidx(fmod):
    """Private deepcopy for mutation tests (fresh locks, no shared state)."""
    return copy.deepcopy(fmod.index)


def _exhaustive_plan(index, pred, k=10, fused=False):
    """Visit every posting list, disable the rerank early stop, and set
    ``top_n`` past the row count: the pipeline exactly-scores EVERY
    matching row, so the result must be bit-identical to brute force."""
    view = index.view()
    return index.plan(k=k, top_m=len(index.posting.members),
                      top_n=view.n_rows + len(view.delta),
                      disable_early_stop=True, filter=pred, fused=fused)


def _run_filtered(index, pred, q, k=10, fused=False):
    return index.executor.run_one(q, _exhaustive_plan(index, pred,
                                                      k=k, fused=fused))


def _oracle(vecs, ids, keep, q, k):
    """Brute-force post-filter top-k: exact float32 squared L2 over the
    kept rows, ties broken by smaller id (the engine's tie-break)."""
    sel = np.flatnonzero(keep)
    d2 = np.sum((vecs[sel].astype(np.float32)
                 - q.astype(np.float32)[None]) ** 2, axis=1)
    order = np.lexsort((ids[sel], d2))[:k]
    return ids[sel][order], d2[order]


def _sealed_preds(f):
    """(predicate, oracle row mask) pairs spanning a selectivity sweep."""
    return [
        (None, np.ones(len(f.data), bool)),                       # 1.0
        (In("cat", (0, 1, 2, 3)), f.cats < 4),                    # 0.5
        (Range("ts", 0, 25), f.ts < 25),                          # 0.25
        (Eq("cat", 0), f.cats == 0),                              # 0.125
        (And((Eq("cat", 0), Range("ts", 0, 50))),                 # ~0.065
         (f.cats == 0) & (f.ts < 50)),
    ]


def test_filtered_topk_matches_post_filter_oracle(fmod):
    f = fmod
    ids_all = np.arange(len(f.data))
    for pred, keep in _sealed_preds(f):
        for q in f.queries[:6]:
            res = _run_filtered(f.index, pred, q, k=10)
            want_ids, want_d2 = _oracle(f.data, ids_all, keep, q, k=10)
            np.testing.assert_array_equal(np.asarray(res.ids, np.int64),
                                          want_ids)
            np.testing.assert_allclose(res.dists, want_d2, rtol=1e-4)


def test_selectivity_shrinks_candidates_proportionally(fmod):
    """The isolation of WHERE filtering happens: ``candidates_scanned``
    equals the number of union candidates the predicate kept EXACTLY,
    ``candidates_prefilter`` holds the unfiltered union, and their ratio
    tracks the predicate's selectivity — proof the mask ran before the
    ADC scan, not after top-k.  (The union is the graph-reachable row
    set, not all of ``n``: coverage is the traversal's business, the
    filter's job is only to shrink whatever was collected.)"""
    f = fmod
    q = f.queries[0]
    view = f.index.view()
    top_m = len(f.index.posting.members)
    union = view.collect_candidates(q, top_m)[1]     # unfiltered union ids
    prev = len(union) + 1
    for pred, keep in _sealed_preds(f):
        res = _run_filtered(f.index, pred, q, k=10)
        assert res.stats.candidates_prefilter == len(union)
        assert res.stats.candidates_scanned == int(keep[union].sum())
        ratio = res.stats.candidates_scanned / res.stats.candidates_prefilter
        # attrs are uniform mod-patterns, so the union's selectivity sits
        # within a few percent of the whole-index selectivity
        assert abs(ratio - keep.mean()) < 0.05
        assert res.stats.candidates_scanned < prev   # sweep is monotone
        prev = res.stats.candidates_scanned
    # the default (non-exhaustive) plan keeps the invariant too
    res = f.index.executor.run_one(f.queries[0],
                                   f.index.plan(filter=Eq("cat", 0)))
    assert 0 < res.stats.candidates_scanned <= res.stats.candidates_prefilter


def test_empty_result_predicate(fmod):
    f = fmod
    res = _run_filtered(f.index, Eq("cat", 99), f.queries[0], k=10)
    assert len(res.ids) == 0 and len(res.dists) == 0
    assert res.stats.candidates_scanned == 0
    assert res.stats.candidates_prefilter > 0      # the union existed;
    #                                                the predicate emptied it


def test_fewer_matches_than_k_returns_all_matches(fmod):
    f = fmod
    pred = And((Eq("cat", 0), Eq("ts", 0)))     # ids ≡ 0 (mod 200) → 13 rows
    keep = (f.cats == 0) & (f.ts == 0)
    res = _run_filtered(f.index, pred, f.queries[0], k=50)
    want_ids, _ = _oracle(f.data, np.arange(len(f.data)), keep,
                          f.queries[0], k=50)
    assert len(res.ids) == int(keep.sum()) < 50
    np.testing.assert_array_equal(np.asarray(res.ids, np.int64), want_ids)


def test_fused_path_honors_filter_bit_identically(fmod):
    f = fmod
    for pred in (Eq("cat", 0), Range("ts", 0, 25)):
        for q in f.queries[:3]:
            dense = _run_filtered(f.index, pred, q, k=10, fused=False)
            fused = _run_filtered(f.index, pred, q, k=10, fused=True)
            np.testing.assert_array_equal(dense.ids, fused.ids)
            np.testing.assert_allclose(dense.dists, fused.dists, rtol=1e-4)
            assert fused.stats.candidates_prefilter \
                == dense.stats.candidates_prefilter > 0


# ---------------------------------------------------------------------------
# Mutations: delta-only matches, tombstones, purge, snapshots
# ---------------------------------------------------------------------------


def test_delta_only_matches(fmod, fidx):
    """A predicate only the unsealed delta satisfies: every sealed
    candidate is filtered out at collection and the answer comes purely
    from the delta scan — still oracle-exact."""
    f = fmod
    new_ids = fidx.insert(f.new_vecs,
                          attributes={"cat": np.full(len(f.new_vecs), 42)})
    q = f.queries[0]
    res = _run_filtered(fidx, Eq("cat", 42), q, k=30)
    want_ids, want_d2 = _oracle(f.new_vecs, new_ids,
                                np.ones(len(new_ids), bool), q, k=30)
    np.testing.assert_array_equal(np.asarray(res.ids, np.int64), want_ids)
    np.testing.assert_allclose(res.dists, want_d2, rtol=1e-4)
    # sealed rows contributed zero scanned candidates
    assert res.stats.candidates_scanned == 0     # delta rows are counted
    #                                              by the delta scan path


def test_delta_rows_with_unset_attrs_never_match(fmod, fidx):
    f = fmod
    fidx.insert(f.new_vecs)                      # no attributes: all UNSET
    res = _run_filtered(fidx, Eq("cat", 0), f.queries[0], k=10)
    assert all(int(i) < len(f.data) for i in res.ids)   # sealed rows only
    ids_all = np.arange(len(f.data))
    want_ids, _ = _oracle(f.data, ids_all, f.cats == 0, f.queries[0], k=10)
    np.testing.assert_array_equal(np.asarray(res.ids, np.int64), want_ids)


def test_tombstones_respected_through_filter_and_purge(fmod, fidx):
    """Delete rows a predicate matches — in the sealed base AND the
    attributed delta — then verify oracle equality both before compaction
    (tombstone masks) and after (seal-time purge + id remap)."""
    f = fmod
    q = f.queries[1]
    new_ids = fidx.insert(
        f.new_vecs, attributes={"cat": np.asarray(new_cats := np.arange(
            len(f.new_vecs)) % 8)})
    keep_sealed = f.cats == 0
    want_pre, _ = _oracle(f.data, np.arange(len(f.data)), keep_sealed, q, 60)
    sealed_hits = set(want_pre[:3].tolist())          # 3 best sealed rows
    delta_hits = set(new_ids[new_cats == 0][:2].tolist())
    fidx.delete(np.asarray(sorted(sealed_hits | delta_hits)))

    all_vecs = np.concatenate([f.data, f.new_vecs])
    all_ids = np.arange(len(all_vecs))
    keep = np.concatenate([keep_sealed, new_cats == 0])
    keep[list(sealed_hits | delta_hits)] = False      # the oracle drops
    #                                                   tombstoned rows too
    want_ids, want_d2 = _oracle(all_vecs, all_ids, keep, q, k=10)

    res = _run_filtered(fidx, Eq("cat", 0), q, k=10)
    np.testing.assert_array_equal(np.asarray(res.ids, np.int64), want_ids)
    np.testing.assert_allclose(res.dists, want_d2, rtol=1e-4)

    fidx.compact()                                    # purge + id remap
    res2 = _run_filtered(fidx, Eq("cat", 0), q, k=10)
    np.testing.assert_array_equal(np.asarray(res2.ids, np.int64), want_ids)
    np.testing.assert_allclose(res2.dists, want_d2, rtol=1e-4)
    # post-purge stats: scanned still counts exactly the union candidates
    # the predicate kept — purged rows are gone from both sides
    view = fidx.view()
    filt_ids, pre_ids = view.collect_candidates(
        q, len(fidx.posting.members), filt=Eq("cat", 0))
    assert res2.stats.candidates_prefilter == len(pre_ids)
    assert res2.stats.candidates_scanned == len(filt_ids)
    assert not (set(np.asarray(filt_ids).tolist())
                & (sealed_hits | delta_hits))


def test_attributes_survive_snapshot_roundtrip(fmod, fidx, tmp_path):
    """Sealed attrs, delta attrs, and tombstones all round-trip through
    save_snapshot/load_snapshot: filtered results stay bit-identical."""
    f = fmod
    fidx.insert(f.new_vecs[:10],
                attributes={"cat": np.full(10, 5), "ts": np.arange(10)})
    fidx.delete(np.array([0, 8]))                 # sealed rows with cat==0
    fidx.save_snapshot(str(tmp_path / "snap"))
    restored = FusionANNSIndex.load_snapshot(str(tmp_path / "snap"))
    for pred in (Eq("cat", 0), Eq("cat", 5), Range("ts", 0, 5),
                 And((Eq("cat", 5), Range("ts", 0, 5))), None):
        for q in f.queries[:3]:
            a = _run_filtered(fidx, pred, q, k=10)
            b = _run_filtered(restored, pred, q, k=10)
            np.testing.assert_array_equal(a.ids, b.ids)
            np.testing.assert_array_equal(a.dists, b.dists)
    # deleted sealed rows stay invisible to the restored filter too
    got = _run_filtered(restored, Eq("cat", 0), f.queries[0], k=50)
    assert not ({0, 8} & set(np.asarray(got.ids).tolist()))
