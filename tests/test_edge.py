"""The HTTP edge (PR 7 tentpole — serve/edge.py over a REAL socket).

Every test drives ``AnnsEdge`` through ``asyncio.open_connection`` — no
in-process shortcuts — so routing, auth, rate limiting, coalescing,
drain, and the autoscaler ramp are all measured through actual HTTP
bytes.  Deterministic backend timing uses the event-gated serve path
(``_gate``) and an injectable ``FakeClock`` for the rate limiters and
the autoscaler.

Contract under test:
* structured errors with stable codes: 401 unauthorized, 429
  rate_limited (+ Retry-After), 400 bad_request, 404/405, 413
  body_too_large, 503 overloaded / draining, 504 deadline_exceeded;
* tenant auth stamps the tenant on the response and keeps per-tenant
  books; no tenants configured = an open edge; (PR 10) the tenant's base
  predicate is stamped SERVER-side, so no request body a tenant can send
  ever retrieves another tenant's rows through the socket;
* a burst of N identical HTTP requests costs exactly ONE backend
  submit, every response bit-identical with its own tag;
* ``aclose()`` drains gracefully: the in-flight response still flows,
  then the listener refuses new connections, zero futures leak at the
  edge OR router level;
* the acceptance ramp: doubled QPS grows the stack within one cooldown
  window (the fresh replica serves the second burst through HTTP while
  the old one is wedged), calm traffic shrinks it, books stay balanced.
"""

import asyncio
import json
import threading

import numpy as np
import pytest

from repro.serve.anns_service import BatchingANNSService
from repro.serve.autoscaler import AutoscalerConfig, ReplicaAutoscaler
from repro.serve.edge import (AnnsEdge, EdgeConfig, HttpConn, TenantConfig,
                              TokenBucket)
from repro.serve.stack import make_serving_stack


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        return self.t


def _gate(svc):
    """Wedge one replica's serve path on an event; returns (started,
    release) — the test_autoscaler pattern."""
    started, release = threading.Event(), threading.Event()
    orig = svc._serve_batch_inner

    def gated(batch):
        started.set()
        assert release.wait(timeout=60)
        return orig(batch)

    svc._serve_batch_inner = gated
    return started, release


def _svc(b, **kw):
    kw.setdefault("threaded", True)
    kw.setdefault("max_batch", 4)
    kw.setdefault("max_wait_s", 0.001)
    return BatchingANNSService(b.index, **kw)


async def _raw_request(host, port, raw: bytes):
    """Fire raw bytes at the edge and parse one response — for requests
    HttpConn itself refuses to produce (malformed line, oversized body)."""
    reader, writer = await asyncio.open_connection(host, port)
    writer.write(raw)
    await writer.drain()
    status = int((await reader.readline()).split()[1])
    n = 0
    while True:
        h = await reader.readline()
        if h in (b"\r\n", b"\n", b""):
            break
        if h.lower().startswith(b"content-length"):
            n = int(h.split(b":")[1].decode())
    payload = json.loads((await reader.readexactly(n)).decode()) if n else None
    writer.close()
    return status, payload


# ------------------------------------------------------------- token bucket

def test_token_bucket_refill_and_retry_after():
    clk = FakeClock()
    bucket = TokenBucket(rate=2.0, burst=1, clock=clk)
    assert bucket.try_acquire()
    assert not bucket.try_acquire()              # burst spent
    assert bucket.retry_after() == pytest.approx(0.5)   # 1 token / 2 qps
    clk.t = 0.5
    assert bucket.try_acquire()                  # refilled exactly
    # rate <= 0 means unlimited: never blocks, never asks for a wait
    free = TokenBucket(rate=0.0, burst=1, clock=clk)
    assert all(free.try_acquire() for _ in range(100))
    assert free.retry_after() == 0.0


# ---------------------------------------------------------------- auth

def test_auth_unknown_key_401_and_tenant_stamp(anns_bundle):
    b = anns_bundle
    svc = _svc(b)
    tenants = [TenantConfig("alice", "key-a"), TenantConfig("bob", "key-b")]

    async def drive():
        async with AnnsEdge(svc, EdgeConfig(tenants=tenants),
                            own_backend=True) as edge:
            conn = await HttpConn.open("127.0.0.1", edge.port)
            body = {"query": b.queries[0].tolist(), "k": 10, "tag": "t0"}
            status, payload = await conn.request("POST", "/v1/search", body)
            assert status == 401
            assert payload["error"]["code"] == "unauthorized"
            status, _ = await conn.request("POST", "/v1/search", body,
                                           headers={"x-api-key": "wrong"})
            assert status == 401
            status, payload = await conn.request("POST", "/v1/search", body,
                                                 headers={"x-api-key": "key-a"})
            assert status == 200
            assert payload["tenant"] == "alice" and payload["tag"] == "t0"
            np.testing.assert_array_equal(
                np.asarray(payload["ids"]),
                b.index.query(b.queries[0], k=10).ids)
            status, payload = await conn.request("POST", "/v1/search", body,
                                                 headers={"x-api-key": "key-b"})
            assert status == 200 and payload["tenant"] == "bob"
            assert edge.stats["auth_failures"] == 2
            assert edge.tenant_stats["alice"] == {
                "requests": 1, "ok": 1, "rate_limited": 0, "errors": 0}
            assert edge.tenant_stats["bob"]["ok"] == 1
            await conn.aclose()

    asyncio.run(drive())


def test_open_edge_needs_no_key(anns_bundle):
    b = anns_bundle
    svc = _svc(b)

    async def drive():
        async with AnnsEdge(svc, EdgeConfig(), own_backend=True) as edge:
            conn = await HttpConn.open("127.0.0.1", edge.port)
            status, payload = await conn.request(
                "POST", "/v1/search", {"query": b.queries[1].tolist()})
            assert status == 200 and payload["tenant"] is None
            await conn.aclose()

    asyncio.run(drive())


def test_rate_limit_429_with_deterministic_refill(anns_bundle):
    b = anns_bundle
    svc = _svc(b)
    clk = FakeClock()
    tenants = [TenantConfig("metered", "key-m", rate_qps=5.0, burst=2)]

    async def drive():
        async with AnnsEdge(svc, EdgeConfig(tenants=tenants),
                            own_backend=True, clock=clk) as edge:
            conn = await HttpConn.open("127.0.0.1", edge.port)
            body = {"query": b.queries[2].tolist()}
            hdr = {"x-api-key": "key-m"}
            for _ in range(2):                   # the burst allowance
                status, _ = await conn.request("POST", "/v1/search", body,
                                               headers=hdr)
                assert status == 200
            status, payload = await conn.request("POST", "/v1/search", body,
                                                 headers=hdr)
            assert status == 429
            assert payload["error"]["code"] == "rate_limited"
            clk.t = 0.25                         # 5 qps -> a token each 0.2s
            status, _ = await conn.request("POST", "/v1/search", body,
                                           headers=hdr)
            assert status == 200
            assert edge.stats["rate_limited"] == 1
            ts = edge.tenant_stats["metered"]
            assert ts["requests"] == 4 and ts["ok"] == 3
            assert ts["rate_limited"] == 1
            await conn.aclose()

    asyncio.run(drive())


def test_socket_level_tenant_isolation(anns_bundle):
    """Two tenants with disjoint base predicates sharing ONE index,
    driven through the real socket: no request body — bare, adversarially
    filtered for the OTHER namespace, or wide-open Range — ever returns a
    row outside the caller's namespace, because the edge stamps the base
    predicate server-side from the API key.  Rows without a tenant column
    are invisible to both (fail closed), a malformed predicate is a 400
    (not a filter bypass), and the per-tenant service books stay split in
    ``/v1/stats``."""
    import copy

    from repro.core.filters import Eq
    b = anns_bundle
    ix = copy.deepcopy(b.index)           # sealed rows: NO tenant column
    half = len(b.new_vecs) // 2
    ids_a = ix.insert(b.new_vecs[:half],
                      attributes={"tenant": np.zeros(half, np.int64)})
    ids_b = ix.insert(b.new_vecs[half:],
                      attributes={"tenant": np.ones(half, np.int64)})
    svc = BatchingANNSService(ix, threaded=True, max_batch=4,
                              max_wait_s=0.001)
    tenants = [TenantConfig("alice", "key-a", filter=Eq("tenant", 0)),
               TenantConfig("bob", "key-b", filter=Eq("tenant", 1))]
    own = {"key-a": set(ids_a.tolist()), "key-b": set(ids_b.tolist())}
    other = {"key-a": 1, "key-b": 0}

    async def drive():
        async with AnnsEdge(svc, EdgeConfig(tenants=tenants),
                            own_backend=True) as edge:
            conn = await HttpConn.open("127.0.0.1", edge.port)
            for key in ("key-a", "key-b"):
                for qv in (b.new_vecs[0], b.new_vecs[-1], b.queries[0]):
                    for filt in (None,
                                 {"eq": ["tenant", other[key]]},
                                 {"range": ["tenant", -5, 5]}):
                        body = {"query": qv.tolist(), "k": 10}
                        if filt is not None:
                            body["filter"] = filt
                        status, payload = await conn.request(
                            "POST", "/v1/search", body,
                            headers={"x-api-key": key})
                        assert status == 200
                        assert set(payload["ids"]) <= own[key]
            # a malformed predicate is a structured 400, never a bypass
            status, payload = await conn.request(
                "POST", "/v1/search",
                {"query": b.queries[0].tolist(), "filter": {"bogus": []}},
                headers={"x-api-key": "key-a"})
            assert status == 400
            assert payload["error"]["code"] == "bad_request"
            # the TenantManager books surface per tenant, never mixed
            status, stats = await conn.request("GET", "/v1/stats")
            assert status == 200
            ts = stats["tenant_service"]
            assert ts["alice"]["ok"] == 9 and ts["bob"]["ok"] == 9
            assert ts["alice"]["errors"] == ts["bob"]["errors"] == 0
            assert ts["alice"]["quota_rejected"] == 0
            await conn.aclose()

    asyncio.run(drive())


# ------------------------------------------------------------ error surface

def test_structured_error_codes_over_one_keepalive_conn(anns_bundle):
    b = anns_bundle
    svc = _svc(b)

    async def drive():
        async with AnnsEdge(svc, EdgeConfig(), own_backend=True) as edge:
            conn = await HttpConn.open("127.0.0.1", edge.port)
            # 404 / 405 / 400s all ride ONE keep-alive connection
            status, payload = await conn.request("GET", "/nope")
            assert status == 404 and payload["error"]["code"] == "not_found"
            status, payload = await conn.request("GET", "/v1/search")
            assert (status, payload["error"]["code"]) \
                == (405, "method_not_allowed")
            status, payload = await conn.request("POST", "/v1/search",
                                                 {"k": 5})
            assert (status, payload["error"]["code"]) == (400, "bad_request")
            status, payload = await conn.request(
                "POST", "/v1/search", {"query": [[1.0, 2.0], [3.0, 4.0]]})
            assert status == 400 and "1-D" in payload["error"]["message"]
            status, payload = await conn.request(
                "POST", "/v1/search",
                {"query": b.queries[0].tolist(), "k": "lots"})
            assert status == 400
            # ... and the connection still serves a good request after
            status, payload = await conn.request(
                "POST", "/v1/search", {"query": b.queries[0].tolist()})
            assert status == 200
            assert edge.stats["bad_requests"] == 3
            assert edge.stats["not_found"] == 1
            await conn.aclose()
            # invalid JSON body: structured 400, connection survives
            body = b"{oops"
            raw = (b"POST /v1/search HTTP/1.1\r\nHost: e\r\n"
                   + b"Content-Length: %d\r\n\r\n" % len(body) + body)
            status, payload = await _raw_request("127.0.0.1", edge.port, raw)
            assert status == 400 and "JSON" in payload["error"]["message"]
            # malformed request LINE: answered 400, then the conn is dropped
            status, payload = await _raw_request("127.0.0.1", edge.port,
                                                 b"GARBAGE\r\n\r\n")
            assert (status, payload["error"]["code"]) == (400, "bad_request")

    asyncio.run(drive())


def test_body_too_large_413_drops_conn(anns_bundle):
    b = anns_bundle
    svc = _svc(b)

    async def drive():
        async with AnnsEdge(svc, EdgeConfig(max_body_bytes=64),
                            own_backend=True) as edge:
            body = json.dumps(
                {"query": b.queries[0].tolist()}).encode()
            assert len(body) > 64
            raw = (b"POST /v1/search HTTP/1.1\r\nHost: e\r\n"
                   + b"Content-Length: %d\r\n\r\n" % len(body) + body)
            status, payload = await _raw_request("127.0.0.1", edge.port, raw)
            assert status == 413
            assert payload["error"]["code"] == "body_too_large"

    asyncio.run(drive())


def test_max_pending_guard_503(anns_bundle):
    b = anns_bundle
    svc = _svc(b)

    async def drive():
        # max_pending=0: the request itself trips the admission guard
        async with AnnsEdge(svc, EdgeConfig(max_pending=0),
                            own_backend=True) as edge:
            conn = await HttpConn.open("127.0.0.1", edge.port)
            status, payload = await conn.request(
                "POST", "/v1/search", {"query": b.queries[0].tolist()})
            assert (status, payload["error"]["code"]) == (503, "overloaded")
            assert edge.stats["overloaded"] == 1
            await conn.aclose()

    asyncio.run(drive())


def test_healthz_and_stats_routes(anns_bundle):
    b = anns_bundle
    router = make_serving_stack(b.index, n_replicas=2, max_batch=4,
                                max_wait_s=0.001)

    async def drive():
        async with AnnsEdge(router, EdgeConfig(
                tenants=[TenantConfig("t", "k")]), own_backend=True) as edge:
            conn = await HttpConn.open("127.0.0.1", edge.port)
            status, payload = await conn.request("GET", "/healthz")
            assert (status, payload["status"]) == (200, "serving")
            status, _ = await conn.request(
                "POST", "/v1/search", {"query": b.queries[0].tolist()},
                headers={"x-api-key": "k"})
            assert status == 200
            status, stats = await conn.request("GET", "/v1/stats")
            assert status == 200
            assert stats["edge"]["ok"] == 1
            assert stats["tenants"]["t"]["ok"] == 1
            assert stats["client"]["completed"] == 1
            assert stats["coalescer"]["live"] == 0
            # a router backend surfaces its scaling signals through /v1/stats
            assert stats["backend"]["n_replicas"] == 2
            assert stats["backend"]["submitted"] == 1
            await conn.aclose()

    asyncio.run(drive())


# ----------------------------------------------------------- deadline / 504

def test_deadline_maps_to_504_and_edge_stays_up(anns_bundle):
    b = anns_bundle
    svc = _svc(b)
    started, release = _gate(svc)

    async def drive():
        async with AnnsEdge(svc, EdgeConfig(), own_backend=True) as edge:
            conn = await HttpConn.open("127.0.0.1", edge.port)
            status, payload = await conn.request(
                "POST", "/v1/search",
                {"query": b.queries[0].tolist(), "deadline_s": 0.05})
            assert status == 504
            assert payload["error"]["code"] == "deadline_exceeded"
            assert edge.stats["deadline_expired"] == 1
            release.set()                        # un-wedge the backend ...
            status, payload = await conn.request(
                "POST", "/v1/search", {"query": b.queries[1].tolist()})
            assert status == 200                 # ... the edge never died
            np.testing.assert_array_equal(
                np.asarray(payload["ids"]), b.index.query(b.queries[1]).ids)
            await conn.aclose()

    try:
        asyncio.run(drive())
    finally:
        release.set()


# --------------------------------------------------------------- coalescing

def test_http_burst_coalesces_to_one_backend_submit(anns_bundle):
    """8 concurrent HTTP connections firing the SAME query: exactly one
    backend submit, 8 bit-identical responses each with its own tag —
    the serve path is gated so the overlap is deterministic."""
    b = anns_bundle
    svc = _svc(b)
    started, release = _gate(svc)
    n_burst = 8
    ref = b.index.query(b.queries[0], k=10).ids

    async def drive():
        async with AnnsEdge(svc, EdgeConfig(), own_backend=True) as edge:
            conns = [await HttpConn.open("127.0.0.1", edge.port)
                     for _ in range(n_burst)]
            tasks = [asyncio.ensure_future(c.request(
                "POST", "/v1/search",
                {"query": b.queries[0].tolist(), "k": 10, "tag": i}))
                for i, c in enumerate(conns)]
            # wait until every request has claimed the key (the gate keeps
            # the master future unresolved), then let the batch through
            cs = edge.client.stats
            while cs["submitted"] + cs["coalesced"] < n_burst:
                await asyncio.sleep(0.002)
            release.set()
            out = await asyncio.gather(*tasks)
            probe = await HttpConn.open("127.0.0.1", edge.port)
            _, stats = await probe.request("GET", "/v1/stats")
            await probe.aclose()
            for c in conns:
                await c.aclose()
            return out, stats

    try:
        out, stats = asyncio.run(drive())
    finally:
        release.set()
    assert stats["client"]["submitted"] == 1
    assert stats["client"]["coalesced"] == n_burst - 1
    assert stats["coalescer"] == {"leaders": 1, "attached": n_burst - 1,
                                  "live": 0}
    assert int(svc.stats["requests"]) == 1       # ONE scan for the burst
    assert sorted(p["tag"] for _, p in out) == list(range(n_burst))
    for status, payload in out:
        assert status == 200
        np.testing.assert_array_equal(np.asarray(payload["ids"]), ref)


# ------------------------------------------------------------ drain / close

def test_draining_rejects_new_searches_with_503(anns_bundle):
    b = anns_bundle
    svc = _svc(b)

    async def drive():
        async with AnnsEdge(svc, EdgeConfig(), own_backend=True) as edge:
            conn = await HttpConn.open("127.0.0.1", edge.port)
            status, payload = await conn.request("GET", "/healthz")
            assert payload["status"] == "serving"
            # park a second keep-alive conn in the read loop BEFORE the
            # drain flips — conns opened after it are simply closed
            conn2 = await HttpConn.open("127.0.0.1", edge.port)
            await conn2.request("GET", "/healthz")
            edge._draining = True                # the aclose() first step
            status, payload = await conn.request("GET", "/healthz")
            assert payload["status"] == "draining"
            # an in-the-pipe search during the drain gets a structured 503
            status, payload = await conn2.request(
                "POST", "/v1/search", {"query": b.queries[0].tolist()})
            assert (status, payload["error"]["code"]) == (503, "draining")
            assert edge.stats["draining_rejects"] == 1
            await conn.aclose()
            await conn2.aclose()

    asyncio.run(drive())


def test_graceful_drain_finishes_inflight_then_refuses(anns_bundle):
    """aclose() ordering: the wedged in-flight request still gets its 200
    over the socket, THEN the listener refuses connections, and nothing
    leaks at the edge or the service."""
    b = anns_bundle
    svc = _svc(b)
    started, release = _gate(svc)

    async def drive():
        edge = await AnnsEdge(svc, EdgeConfig(), own_backend=True).start()
        port = edge.port
        conn = await HttpConn.open("127.0.0.1", port)
        fut = asyncio.ensure_future(conn.request(
            "POST", "/v1/search", {"query": b.queries[0].tolist()}))
        await asyncio.to_thread(started.wait, 60)     # request is wedged
        closer = asyncio.ensure_future(edge.aclose())
        await asyncio.sleep(0.05)
        assert not closer.done()        # blocked on the in-flight request
        assert not fut.done()
        release.set()
        status, payload = await fut     # the response still flowed out
        assert status == 200
        np.testing.assert_array_equal(np.asarray(payload["ids"]),
                                      b.index.query(b.queries[0]).ids)
        await closer
        with pytest.raises((ConnectionError, OSError)):
            await HttpConn.open("127.0.0.1", port)
        assert edge._live_requests == 0
        assert not edge.client._inflight
        await conn.aclose()

    try:
        asyncio.run(drive())
    finally:
        release.set()
    assert svc._pump_thread is None and not svc._queue   # zero leaks


# ---------------------------------------------------------------- the soak

def test_soak_200_connections_zero_leaks(anns_bundle):
    b = anns_bundle
    router = make_serving_stack(b.index, n_replicas=2, policy="jsq",
                                max_batch=16, max_wait_s=0.0005,
                                scan_window=8, inflight_depth=2)
    n_conns, per_conn = 200, 2

    async def drive():
        async with AnnsEdge(router, EdgeConfig(max_inflight=128),
                            own_backend=True) as edge:
            async def one(ci):
                conn = await HttpConn.open("127.0.0.1", edge.port)
                out = []
                for r in range(per_conn):
                    qi = (ci + r * 7) % len(b.queries)
                    status, payload = await conn.request(
                        "POST", "/v1/search",
                        {"query": b.queries[qi].tolist(), "tag": qi})
                    assert status == 200
                    out.append((qi, payload["ids"]))
                await conn.aclose()
                return out

            res = await asyncio.gather(*[one(i) for i in range(n_conns)])
            assert edge.stats["conns"] >= n_conns
            assert edge.stats["ok"] == n_conns * per_conn
            assert edge._live_requests == 0
            assert not edge.client._inflight
            cs = dict(edge.client.stats)
            return res, cs

    res, cs = asyncio.run(drive())
    flat = [x for sub in res for x in sub]
    assert len(flat) == n_conns * per_conn
    # identical in-flight queries coalesce; every request still answered
    assert cs["submitted"] + cs["coalesced"] == n_conns * per_conn
    for qi, ids in flat[::17]:                   # sampled id parity
        np.testing.assert_array_equal(np.asarray(ids),
                                      b.index.query(b.queries[qi]).ids)
    assert router.live_load() == 0
    roll = router.stats_rollup()
    assert roll["submitted"] == sum(roll["routed"]) + roll["rejected"]
    for svc in router.replicas:
        assert not svc._queue and svc._pump_thread is None


# ----------------------------------------------------- the acceptance ramp

def test_edge_load_ramp_autoscales_through_http(anns_bundle):
    """PR-7 acceptance, measured END TO END through the socket: a wedged
    replica under a 4-request burst trips the autoscaler; the doubled
    burst is served by the NEW replica (HTTP 200s with bit-identical
    ids) while the old one is still stuck; calm ticks shrink the stack
    back, the victim drains, and zero futures leak at the edge or the
    router."""
    b = anns_bundle
    clk = FakeClock()
    router = make_serving_stack(b.index, n_replicas=1, policy="jsq",
                                max_batch=4, max_wait_s=0.001)
    started, release = _gate(router.replicas[0])
    asc = ReplicaAutoscaler(
        router, AutoscalerConfig(min_replicas=1, max_replicas=2,
                                 high_water=3.0, low_water=1.0,
                                 down_ticks=2, scale_up_cooldown_s=5.0,
                                 scale_down_cooldown_s=5.0,
                                 p99_bound_s=120.0),
        clock=clk)

    async def drive():
        async with AnnsEdge(router, EdgeConfig(), own_backend=True) as edge:
            conns = [await HttpConn.open("127.0.0.1", edge.port)
                     for _ in range(8)]
            burst1 = [asyncio.ensure_future(conns[i].request(
                "POST", "/v1/search",
                {"query": b.queries[i].tolist(), "tag": i}))
                for i in range(4)]
            await asyncio.to_thread(started.wait, 60)
            while router.live_load() < 4:        # all 4 admitted + wedged
                await asyncio.sleep(0.002)
            assert asc.tick() == "scale_up"      # 4 > 3.0 high water
            assert router.n_replicas == 2
            # burst 2 (QPS doubled): JSQ lands every request on the fresh
            # replica — grown capacity serves traffic during the wedge
            burst2 = [asyncio.ensure_future(conns[4 + j].request(
                "POST", "/v1/search",
                {"query": b.queries[4 + j].tolist(), "tag": 4 + j}))
                for j in range(4)]
            for j, fut in enumerate(burst2):
                status, payload = await fut
                assert status == 200
                np.testing.assert_array_equal(
                    np.asarray(payload["ids"]),
                    b.index.query(b.queries[4 + j]).ids)
            assert router.stats_rollup()["routed"][1] == 4
            release.set()                        # burst 1 completes too
            for i, fut in enumerate(burst1):
                status, payload = await fut
                assert status == 200
                np.testing.assert_array_equal(
                    np.asarray(payload["ids"]),
                    b.index.query(b.queries[i]).ids)
            # calm: consecutive calm ticks outside the cooldown -> shrink,
            # and the victim drains while the edge is still serving
            clk.t = 10.0
            assert asc.tick() is None
            clk.t = 11.0
            assert asc.tick() == "scale_down"
            assert router.n_replicas == 1
            status, _ = await conns[0].request("GET", "/healthz")
            assert status == 200                 # edge alive across resize
            assert edge._live_requests == 0
            assert not edge.client._inflight
            for c in conns:
                await c.aclose()
            return dict(edge.stats)

    try:
        stats = asyncio.run(drive())
    finally:
        release.set()
    assert stats["ok"] == 8
    assert len(asc.events) == 2                  # one up, one down
    roll = router.stats_rollup()
    assert roll["submitted"] == sum(roll["routed"]) + roll["rejected"] == 8
    pct = router.latency_percentiles()
    assert pct["n"] == 8 and pct["p99"] < 120.0
    for svc in router.replicas:                  # stopped by aclose()
        assert not svc._queue and svc._pump_thread is None
