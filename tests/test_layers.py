"""Model-layer correctness: flash attention VJP, RoPE, MoE, MLA decode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import LMConfig
from repro.models import layers as L


def _ref_attention(q, k, v, causal, scale=None):
    B, S, H, dh = q.shape
    T, Hk = k.shape[1], k.shape[2]
    G = H // Hk
    scale = scale or 1.0 / np.sqrt(dh)
    qf = q.reshape(B, S, Hk, G, dh) * scale
    s = jnp.einsum("bskgd,btkd->bkgst", qf, k)
    if causal:
        mask = jnp.arange(S)[:, None] >= jnp.arange(T)[None, :]
        s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgst,btkd->bskgd", p, v)
    return o.reshape(B, S, H, v.shape[-1])


@pytest.mark.parametrize("B,S,H,Hk,dh,causal,bs", [
    (2, 16, 4, 2, 8, True, 8),
    (1, 8, 2, 2, 16, False, 4),
    pytest.param(2, 32, 6, 3, 8, True, 16, marks=pytest.mark.slow),
    pytest.param(1, 24, 4, 1, 8, True, 8,  # MQA
                 marks=pytest.mark.slow),
])
def test_flash_attention_fwd_bwd(rng, B, S, H, Hk, dh, causal, bs):
    q = jnp.asarray(rng.standard_normal((B, S, H, dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, Hk, dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, Hk, dh)), jnp.float32)
    out = L.blockwise_attention(q, k, v, causal=causal, block_size=bs)
    ref = _ref_attention(q, k, v, causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)

    def loss_f(fn):
        return lambda *a: jnp.sum(jnp.sin(fn(*a)))
    g1 = jax.grad(loss_f(lambda q, k, v: L.blockwise_attention(
        q, k, v, causal=causal, block_size=bs)), argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss_f(lambda q, k, v: _ref_attention(
        q, k, v, causal)), argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=3e-4, atol=3e-4)


def test_flash_matches_naive_scan_path(rng):
    """FLASH_VJP=False (naive grad-of-scan) and the custom VJP agree."""
    q = jnp.asarray(rng.standard_normal((1, 16, 2, 2, 8))[0], jnp.float32)
    q = q.reshape(1, 16, 4, 8)[:, :, :2]
    q = jnp.asarray(rng.standard_normal((1, 16, 2, 8)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 16, 2, 8)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 16, 2, 8)), jnp.float32)

    def loss(q, k, v):
        return jnp.sum(L.blockwise_attention(q, k, v, block_size=8) ** 2)
    g_flash = jax.grad(loss)(q, k, v)
    L.FLASH_VJP = False
    try:
        g_naive = jax.grad(loss)(q, k, v)
    finally:
        L.FLASH_VJP = True
    np.testing.assert_allclose(np.asarray(g_flash), np.asarray(g_naive),
                               rtol=1e-4, atol=1e-4)


def test_rope_preserves_norm(rng):
    x = jnp.asarray(rng.standard_normal((2, 8, 4, 16)), jnp.float32)
    cos, sin = L.rope_tables(jnp.arange(8), 16, 10000.0)
    y = L.apply_rope(x, cos, sin)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(y), axis=-1),
                               np.linalg.norm(np.asarray(x), axis=-1),
                               rtol=1e-5)


def test_rope_relative_property(rng):
    """<rope(q,m), rope(k,n)> depends only on m-n."""
    q = jnp.asarray(rng.standard_normal((1, 1, 1, 16)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 1, 1, 16)), jnp.float32)

    def dot_at(m, n):
        cm, sm = L.rope_tables(jnp.asarray([m]), 16, 10000.0)
        cn, sn = L.rope_tables(jnp.asarray([n]), 16, 10000.0)
        qm = L.apply_rope(q, cm, sm)[0, 0, 0]
        kn = L.apply_rope(k, cn, sn)[0, 0, 0]
        return float(jnp.dot(qm, kn))
    assert abs(dot_at(3, 1) - dot_at(7, 5)) < 1e-4
    assert abs(dot_at(2, 2) - dot_at(9, 9)) < 1e-4


def test_partial_rope_leaves_tail_untouched(rng):
    x = jnp.asarray(rng.standard_normal((1, 4, 2, 16)), jnp.float32)
    cos, sin = L.rope_tables(jnp.arange(4), 8, 10000.0)
    y = L.apply_rope(x, cos, sin, fraction=0.5)
    np.testing.assert_array_equal(np.asarray(y[..., 8:]),
                                  np.asarray(x[..., 8:]))


def _moe_cfg():
    return LMConfig(name="t", n_layers=1, d_model=16, n_heads=2,
                    n_kv_heads=2, d_head=8, d_ff=32, vocab_size=64,
                    moe=True, n_experts=4, moe_top_k=2, moe_d_ff=32,
                    capacity_factor=8.0)   # high capacity => no drops


def test_moe_matches_dense_reference(rng):
    """With no capacity drops, sort-based dispatch == per-token dense mix."""
    cfg = _moe_cfg()
    B, S, D, E, F = 2, 8, 16, 4, 32
    x = jnp.asarray(rng.standard_normal((B, S, D)), jnp.float32)
    router = jnp.asarray(rng.standard_normal((D, E)), jnp.float32)
    w1 = jnp.asarray(0.2 * rng.standard_normal((E, D, 2 * F)), jnp.float32)
    w2 = jnp.asarray(0.2 * rng.standard_normal((E, F, D)), jnp.float32)
    out = L.moe_block(x, router, w1, w2, None, None, cfg=cfg, ctx=L.LOCAL_CTX)

    # dense reference
    logits = jnp.einsum("bsd,de->bse", x, router)
    probs = jax.nn.softmax(logits, -1)
    gates, eids = jax.lax.top_k(probs, 2)
    gates = gates / gates.sum(-1, keepdims=True)
    ref = jnp.zeros_like(x)
    for e in range(E):
        gu = jnp.einsum("bsd,df->bsf", x, w1[e])
        g, u = jnp.split(gu, 2, -1)
        y = jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * u, w2[e])
        w = jnp.sum(jnp.where(eids == e, gates, 0.0), -1)
        ref = ref + w[..., None] * y
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_moe_differentiable(rng):
    cfg = _moe_cfg()
    x = jnp.asarray(rng.standard_normal((2, 4, 16)), jnp.float32)
    router = jnp.asarray(rng.standard_normal((16, 4)), jnp.float32)
    w1 = jnp.asarray(0.2 * rng.standard_normal((4, 16, 64)), jnp.float32)
    w2 = jnp.asarray(0.2 * rng.standard_normal((4, 32, 16)), jnp.float32)

    def loss(w1):
        return jnp.sum(L.moe_block(x, router, w1, w2, None, None,
                                   cfg=cfg, ctx=L.LOCAL_CTX) ** 2)
    g = jax.grad(loss)(w1)
    assert np.isfinite(np.asarray(g)).all()
    assert float(jnp.sum(jnp.abs(g))) > 0


def test_mla_absorbed_decode_matches_expanded(rng):
    """Absorbed decode (c_kv cache) == expanded-KV attention at step t."""
    from repro.configs.deepseek_v2_lite_16b import REDUCED as cfg
    B, T = 2, 8
    D = cfg.d_model
    H = cfg.n_heads
    lr, rd, nd, vd = (cfg.kv_lora_rank, cfg.qk_rope_head_dim,
                      cfg.qk_nope_head_dim, cfg.v_head_dim)
    p = {
        "wq": jnp.asarray(0.1 * rng.standard_normal((D, H, nd + rd)),
                          jnp.float32),
        "wdkv": jnp.asarray(0.1 * rng.standard_normal((D, lr + rd)),
                            jnp.float32),
        "kv_norm": jnp.ones((lr,), jnp.float32),
        "wuk": jnp.asarray(0.1 * rng.standard_normal((lr, H, nd)),
                           jnp.float32),
        "wuv": jnp.asarray(0.1 * rng.standard_normal((lr, H, vd)),
                           jnp.float32),
    }
    xs = jnp.asarray(rng.standard_normal((B, T, D)), jnp.float32)
    # full-sequence (train form) attention output at the last position
    positions = jnp.arange(T)
    q, k, v, (ckv, kpe) = L.mla_qkv(xs, p, cfg, positions)
    import math
    scale = 1.0 / math.sqrt(nd + rd)
    ref = _ref_attention(np.asarray(q), np.asarray(k), np.asarray(v),
                         causal=True, scale=scale)
    # absorbed decode for the last token against the compressed cache
    out = L.mla_decode_absorbed(
        xs[:, -1:], p, cfg, ckv, kpe,
        jnp.full((B,), T, jnp.int32), jnp.full((B, 1), T - 1))
    np.testing.assert_allclose(np.asarray(out)[:, 0],
                               np.asarray(ref)[:, -1], rtol=2e-4, atol=2e-4)


def test_rms_norm_scale_invariance(rng):
    x = jnp.asarray(rng.standard_normal((4, 8)), jnp.float32)
    w = jnp.ones((8,), jnp.float32)
    y1 = L.rms_norm(x, w)
    y2 = L.rms_norm(3.0 * x, w)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-4,
                               atol=1e-5)
