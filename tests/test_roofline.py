"""Roofline machinery: the loop-aware HLO cost analyzer must multiply scan
bodies by trip count and attribute collectives correctly."""

import os
import subprocess
import sys

import numpy as np
import pytest

from repro.analysis import hlo_cost
from repro.analysis.roofline import Roofline, collective_bytes


SYNTH_HLO = """
HloModule test, num_partitions=4

%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]{1,0}) parameter(0)
  %iv = s32[] get-tuple-element(%p), index=0
  %x = f32[8,8]{1,0} get-tuple-element(%p), index=1
  %one = s32[] constant(1)
  %iv2 = s32[] add(%iv, %one)
  %dot.1 = f32[8,8]{1,0} dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,8]{1,0} all-reduce(%dot.1), replica_groups={}, to_apply=%body
  ROOT %t = (s32[], f32[8,8]{1,0}) tuple(%iv2, %ar)
}

%cond (p2: (s32[], f32[8,8])) -> pred[] {
  %p2 = (s32[], f32[8,8]{1,0}) parameter(0)
  %iv3 = s32[] get-tuple-element(%p2), index=0
  %n = s32[] constant(5)
  ROOT %lt = pred[] compare(%iv3, %n), direction=LT
}

ENTRY %main (a: f32[8,8]) -> f32[8,8] {
  %a = f32[8,8]{1,0} parameter(0)
  %zero = s32[] constant(0)
  %init = (s32[], f32[8,8]{1,0}) tuple(%zero, %a)
  %w = (s32[], f32[8,8]{1,0}) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"5"}}
  ROOT %out = f32[8,8]{1,0} get-tuple-element(%w), index=1
}
"""


def test_while_trip_count_multiplies_flops():
    c = hlo_cost.analyze(SYNTH_HLO)
    # dot: 2*8*8*8 = 1024 flops, x5 trips = 5120 (+5 adds +5 compares)
    assert 5120 <= c.flops <= 5120 + 64


def test_collectives_scaled_by_trips():
    c = hlo_cost.analyze(SYNTH_HLO)
    assert c.coll["all-reduce"] == 5 * 8 * 8 * 4


def test_roofline_terms_and_bottleneck():
    r = Roofline(flops=197e12, hbm_bytes=819e9 / 2, coll_bytes=0.0,
                 coll_breakdown={}, model_flops=197e12 * 4, n_chips=4)
    assert r.t_compute == pytest.approx(1.0)
    assert r.t_memory == pytest.approx(0.5)
    assert r.bottleneck == "compute"
    assert r.useful_flops_ratio == pytest.approx(1.0)
    assert r.roofline_fraction == pytest.approx(1.0)


def test_real_compiled_scan_costs():
    """Compile a tiny scan in a subprocess and verify flops scale with trip
    count (the XLA-cost-analysis bug this analyzer exists to fix)."""
    script = r"""
import sys
sys.path.insert(0, sys.argv[1])
import jax, jax.numpy as jnp
from repro.analysis import hlo_cost
import json

def run(n):
    def f(xs, w):
        def body(c, x):
            return c + x @ w, None
        out, _ = jax.lax.scan(body, jnp.zeros((4, 8)), xs)
        return out
    c = jax.jit(f).lower(
        jax.ShapeDtypeStruct((n, 4, 8), jnp.float32),
        jax.ShapeDtypeStruct((8, 8), jnp.float32)).compile()
    return hlo_cost.analyze(c.as_text()).flops

print(json.dumps({"f4": run(4), "f16": run(16)}))
"""
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", script,
                           os.path.abspath(src)],
                          capture_output=True, text=True, timeout=300,
                          env=env)
    assert proc.returncode == 0, proc.stderr[-2000:]
    import json as j
    r = j.loads(proc.stdout.strip().splitlines()[-1])
    assert r["f16"] >= 3.5 * r["f4"]    # flops scale ~linearly with trips


def test_model_flops_formulas():
    from repro.analysis.model_flops import model_flops
    from repro.configs.registry import get_config
    cfg = get_config("qwen3-0.6b")
    t = model_flops(cfg, "train_step", "train_4k",
                    dict(global_batch=256, seq_len=4096))
    p = model_flops(cfg, "prefill", "prefill_32k",
                    dict(global_batch=32, seq_len=32768))
    d = model_flops(cfg, "serve_step", "decode_32k",
                    dict(global_batch=128, seq_len=32768))
    assert t > p > d > 0
    # train ~ 6*N*D at minimum
    n_tokens = 256 * 4096
    assert t >= 6 * cfg.n_active_params() * n_tokens
