"""Training substrate: checkpoint roundtrip, fault-tolerant supervision,
microbatching, end-to-end loss decrease."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.data.synthetic import lm_batch
from repro.models import transformer as tfm
from repro.models.layers import LOCAL_CTX
from repro.optim.adamw import OptimizerConfig
from repro.train import checkpoint as ckpt
from repro.train.fault import FaultInjector, WorkerFailure, supervise
from repro.train.loop import TrainConfig, init_state, make_train_step, run


@pytest.fixture
def lm_setup():
    cfg = get_config("qwen3-0.6b", reduced=True)

    def loss_fn(p, batch):
        return tfm.lm_loss(p, batch, cfg, LOCAL_CTX, dtype=jnp.float32)
    return cfg, loss_fn


def _batches(cfg, n, batch=2, seq=16, seed=0):
    rng = np.random.default_rng(seed)
    for _ in range(n):
        b = lm_batch(rng, batch, seq, cfg.vocab_size)
        yield {k: jnp.asarray(v) for k, v in b.items()}


def test_checkpoint_roundtrip(tmp_path, lm_setup):
    cfg, _ = lm_setup
    params = tfm.init_lm(jax.random.key(0), cfg)
    tree = {"params": params, "step": jnp.asarray(7)}
    ckpt.save(str(tmp_path), 7, tree)
    proto = jax.eval_shape(lambda: tree)
    restored, step = ckpt.restore(str(tmp_path), proto)
    assert step == 7
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_gc_and_latest(tmp_path):
    tree = {"x": jnp.zeros(3)}
    for s in (1, 2, 3, 4, 5):
        ckpt.save(str(tmp_path), s, tree, keep=2)
    assert ckpt.latest_step(str(tmp_path)) == 5
    dirs = sorted(os.listdir(tmp_path))
    assert len([d for d in dirs if d.startswith("step_")]) == 2


def test_atomicity_tmp_never_latest(tmp_path):
    tree = {"x": jnp.zeros(3)}
    ckpt.save(str(tmp_path), 1, tree)
    os.makedirs(os.path.join(tmp_path, "step_00000002.tmp"))
    assert ckpt.latest_step(str(tmp_path)) == 1   # tmp dirs ignored


def test_loss_decreases(lm_setup):
    cfg, loss_fn = lm_setup
    tcfg = TrainConfig(opt=OptimizerConfig(lr=1e-3, warmup_steps=2,
                                           total_steps=40))
    step_fn = jax.jit(make_train_step(loss_fn, tcfg))
    state = init_state(tfm.init_lm(jax.random.key(0), cfg), tcfg)
    # repeat ONE batch -> loss must drop fast (memorisation)
    batch = next(_batches(cfg, 1))
    losses = []
    for _ in range(25):
        state, metrics = step_fn(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.5


@pytest.mark.slow
def test_microbatching_matches_full_batch(lm_setup):
    cfg, loss_fn = lm_setup
    base = TrainConfig(opt=OptimizerConfig(lr=1e-3, warmup_steps=0,
                                           total_steps=10))
    micro = TrainConfig(opt=base.opt, microbatches=2)
    params = tfm.init_lm(jax.random.key(0), cfg)
    batch = next(_batches(cfg, 1, batch=4))
    s1, m1 = jax.jit(make_train_step(loss_fn, base))(
        init_state(params, base), batch)
    s2, m2 = jax.jit(make_train_step(loss_fn, micro))(
        init_state(params, micro), batch)
    # grads averaged over microbatches == full-batch grads (same loss fn)
    for a, b in zip(jax.tree_util.tree_leaves(s1["params"]),
                    jax.tree_util.tree_leaves(s2["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=2e-5)


@pytest.mark.slow
def test_supervisor_survives_injected_failures(tmp_path, lm_setup):
    cfg, loss_fn = lm_setup
    tcfg = TrainConfig(
        opt=OptimizerConfig(lr=1e-3, warmup_steps=1, total_steps=30),
        ckpt_every=5, ckpt_dir=str(tmp_path))
    injector = FaultInjector(fail_at_steps=[7, 13])

    def make_step():
        return jax.jit(make_train_step(loss_fn, tcfg))

    def init_fn():
        return init_state(tfm.init_lm(jax.random.key(0), cfg), tcfg)

    def batches(n):
        return _batches(cfg, n)

    state, restarts, history = supervise(
        make_step, init_fn, batches, tcfg, total_steps=20,
        max_restarts=5, on_step=injector)
    assert restarts == 2
    assert int(state["opt"]["step"]) >= 20


@pytest.mark.slow
def test_supervisor_resumes_from_checkpoint_not_zero(tmp_path, lm_setup):
    """After a crash at step 7 with ckpt_every=5, training resumes from 5."""
    cfg, loss_fn = lm_setup
    tcfg = TrainConfig(
        opt=OptimizerConfig(lr=1e-3, warmup_steps=1, total_steps=30),
        ckpt_every=5, ckpt_dir=str(tmp_path))
    seen = []

    def on_step(step):
        seen.append(step)
        if step == 7 and 7 not in seen[:-1]:
            raise WorkerFailure("boom")

    state, restarts, _ = supervise(
        lambda: jax.jit(make_train_step(loss_fn, tcfg)),
        lambda: init_state(tfm.init_lm(jax.random.key(0), cfg), tcfg),
        lambda n: _batches(cfg, n), tcfg, total_steps=10,
        on_step=on_step)
    assert restarts == 1
    # resumed exactly at 5 (the checkpoint), not 0
    post = seen[seen.index(7) + 1]
    assert post == 5


def test_straggler_deadline():
    import time
    from repro.train.fault import StepDeadline, StragglerTimeout
    d = StepDeadline(deadline_s=0.01)
    d.start()
    time.sleep(0.03)
    with pytest.raises(StragglerTimeout):
        d.finish()
    assert d.p99() > 0.01
