"""GNN + recsys substrate specifics: segment-sum message passing, the
neighbor sampler, EmbeddingBag, capsule routing."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _propshim import given, settings, strategies as st

from repro.configs.graphsage_reddit import REDUCED as SAGE_CFG
from repro.data.graphs import (block_diagonal_batch, build_csr,
                               neighbor_sample, random_graph, sample_two_hop)
from repro.models import gnn, recsys


def test_mean_aggregate_matches_dense(rng):
    n, d = 20, 8
    feats = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)
    edges = jnp.asarray(rng.integers(0, n, (60, 2)), jnp.int32)
    agg = gnn._mean_aggregate(feats, edges, n, None)
    # dense reference via adjacency matrix
    A = np.zeros((n, n), np.float32)
    for s, t in np.asarray(edges):
        A[t, s] += 1.0
    deg = np.maximum(A.sum(1, keepdims=True), 1.0)
    ref = (A @ np.asarray(feats)) / deg
    np.testing.assert_allclose(np.asarray(agg), ref, rtol=1e-5, atol=1e-5)


def test_csr_roundtrip(rng):
    g = random_graph(rng, 50, 200, 4, 3)
    indptr, indices = build_csr(g["edges"], 50)
    assert indptr[-1] == 200
    # neighbors of node v are exactly the srcs of edges into v
    for v in (0, 7, 23):
        expect = sorted(g["edges"][g["edges"][:, 1] == v, 0].tolist())
        got = sorted(indices[indptr[v]:indptr[v + 1]].tolist())
        assert got == expect


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 999), fanout=st.integers(1, 8))
def test_neighbor_sampler_validity(seed, fanout):
    rng = np.random.default_rng(seed)
    g = random_graph(rng, 40, 120, 4, 3)
    indptr, indices = build_csr(g["edges"], 40)
    nodes = rng.integers(0, 40, 10)
    samp = neighbor_sample(rng, indptr, indices, nodes, fanout)
    assert samp.shape == (10, fanout)
    for i, v in enumerate(nodes):
        nbrs = set(indices[indptr[v]:indptr[v + 1]].tolist())
        for s in samp[i]:
            assert (int(s) in nbrs) or (not nbrs and s == v)


def test_sage_minibatch_forward_shapes(rng):
    g = random_graph(rng, 100, 400, SAGE_CFG.d_feat, SAGE_CFG.n_classes)
    indptr, indices = build_csr(g["edges"], 100)
    params = gnn.init_sage(jax.random.key(0), SAGE_CFG)
    batch_nodes = rng.integers(0, 100, 8)
    f0, f1, f2 = sample_two_hop(rng, indptr, indices, batch_nodes, (5, 3),
                                g["features"])
    logits = gnn.sage_forward_minibatch(
        params, jnp.asarray(f0), jnp.asarray(f1), jnp.asarray(f2), SAGE_CFG)
    assert logits.shape == (8, SAGE_CFG.n_classes)
    assert np.isfinite(np.asarray(logits)).all()


def test_sage_full_graph_learns(rng):
    """Full-batch training on a separable synthetic graph reduces loss."""
    from repro.optim.adamw import OptimizerConfig, adamw_init, adamw_update
    g = random_graph(rng, 60, 240, 16, 4)
    # make labels depend on features -> learnable
    w_true = rng.standard_normal((16, 4))
    g["labels"] = np.argmax(g["features"] @ w_true, -1).astype(np.int32)
    params = gnn.init_sage(jax.random.key(0), SAGE_CFG, d_feat=16,
                           n_classes=4)
    feats = jnp.asarray(g["features"])
    edges = jnp.asarray(g["edges"])
    labels = jnp.asarray(g["labels"])
    cfgo = OptimizerConfig(lr=1e-2, warmup_steps=2, total_steps=60)
    state = adamw_init(params)

    @jax.jit
    def step(params, state):
        def lf(p):
            logits = gnn.sage_forward_full(p, feats, edges, SAGE_CFG)
            return gnn.sage_loss(logits, labels)[0]
        loss, grads = jax.value_and_grad(lf)(params)
        params, state, _ = adamw_update(grads, state, params, cfgo)
        return params, state, loss
    losses = []
    for _ in range(40):
        params, state, loss = step(params, state)
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.3


def test_embedding_bag_ragged_matches_dense(rng):
    table = jnp.asarray(rng.standard_normal((50, 8)), jnp.float32)
    ids = rng.integers(0, 50, (6, 3))
    dense = recsys.embedding_bag_dense(table[None].repeat(1, 0),
                                       jnp.asarray(ids)[:, None, :])[:, 0]
    flat = jnp.asarray(ids.reshape(-1))
    seg = jnp.asarray(np.repeat(np.arange(6), 3))
    ragged = recsys.embedding_bag_ragged(table, flat, seg, 6)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(ragged),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("mode", ["sum", "mean", "max"])
def test_embedding_bag_modes(rng, mode):
    table = jnp.asarray(rng.standard_normal((20, 4)), jnp.float32)
    flat = jnp.asarray([0, 1, 2, 5, 5])
    seg = jnp.asarray([0, 0, 0, 1, 1])
    out = recsys.embedding_bag_ragged(table, flat, seg, 2, mode=mode)
    t = np.asarray(table)
    if mode == "sum":
        ref0 = t[[0, 1, 2]].sum(0)
    elif mode == "mean":
        ref0 = t[[0, 1, 2]].mean(0)
    else:
        ref0 = t[[0, 1, 2]].max(0)
    np.testing.assert_allclose(np.asarray(out[0]), ref0, rtol=1e-5)


def test_mind_capsules_shape_and_norm(rng):
    from repro.configs.mind import REDUCED as cfg
    params = recsys.init_mind(jax.random.key(0), cfg)
    hist = jnp.asarray(rng.integers(0, cfg.vocab_size, (4, cfg.hist_len)),
                       jnp.int32)
    interests = recsys.mind_interests(params, hist, cfg)
    assert interests.shape == (4, cfg.n_interests, cfg.embed_dim)
    assert np.isfinite(np.asarray(interests)).all()


def test_dlrm_interaction_symmetric_features(rng):
    """Pairwise-dot interaction: permuting sparse fields permutes nothing
    in the *set* of interaction values."""
    from repro.configs.dlrm_rm2 import REDUCED as cfg
    params = recsys.init_dlrm(jax.random.key(0), cfg)
    dense = jnp.asarray(rng.standard_normal((2, cfg.n_dense)), jnp.float32)
    ids = rng.integers(0, cfg.vocab_size, (2, cfg.n_sparse, 1))
    out = recsys.dlrm_forward(params, dense, jnp.asarray(ids), cfg)
    assert out.shape == (2,)
    assert np.isfinite(np.asarray(out)).all()


def test_block_diagonal_batch_isolated(rng):
    """No cross-graph edges in the molecule batch."""
    b = block_diagonal_batch(rng, 5, 10, 20, 4, 2)
    gid = b["graph_ids"]
    for s, t in b["edges"]:
        assert gid[s] == gid[t]
