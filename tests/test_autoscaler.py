"""Elastic replica autoscaling (PR 7 — serve/autoscaler.py).

Everything here is DETERMINISTIC: the control loop is driven by manual
``tick()`` calls on an injectable fake clock, and replica serve paths are
gated on events (the test_router saturation pattern), so load levels and
cooldown windows are exact, never scheduler luck.

Contract under test:
* per-replica load above ``high_water`` (or a spill/reject delta, or p99
  over bound) scales up; cooldowns and ``[min, max]`` bounds are honored;
* scale-down needs ``down_ticks`` CONSECUTIVE calm samples outside the
  cooldown window — a single calm tick (or calm right after a resize)
  never flaps;
* the analytic model's ``max_useful_replicas`` caps growth once measured
  demand exists;
* the full load-ramp: a burst doubles demand -> the autoscaler grows
  within its cooldown budget and the NEW replica serves traffic while
  the old one is still wedged; calm traffic -> scale-down drains the
  victim with zero leaked futures and balanced router books.
"""

import threading

import numpy as np
import pytest

from repro.core.futures import BackpressureError
from repro.serve.autoscaler import AutoscalerConfig, ReplicaAutoscaler
from repro.serve.client import SearchRequest
from repro.serve.router import ReplicaRouter


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        return self.t


def _gate(svc):
    """Wedge one replica's serve path on an event; returns (started,
    release)."""
    started, release = threading.Event(), threading.Event()
    orig = svc._serve_batch_inner

    def gated(batch):
        started.set()
        assert release.wait(timeout=60)
        return orig(batch)

    svc._serve_batch_inner = gated
    return started, release


def test_config_validation():
    with pytest.raises(ValueError, match="min_replicas"):
        AutoscalerConfig(min_replicas=0)
    with pytest.raises(ValueError, match="max_replicas"):
        AutoscalerConfig(min_replicas=3, max_replicas=2)
    with pytest.raises(ValueError, match="low_water"):
        AutoscalerConfig(low_water=8.0, high_water=8.0)


def test_scale_up_on_load_with_cooldown_and_max(anns_bundle):
    """High per-replica load scales up once per cooldown window, never
    past max_replicas.  Every replica is wedged as it appears, so load
    numbers are exact at each tick."""
    b = anns_bundle
    clk = FakeClock()
    router = ReplicaRouter(b.index, n_replicas=1, policy="jsq",
                           threaded=True, max_batch=8, max_wait_s=0.001)
    started, release = _gate(router.replicas[0])
    asc = ReplicaAutoscaler(
        router, AutoscalerConfig(min_replicas=1, max_replicas=3,
                                 high_water=2.0, low_water=0.5,
                                 scale_up_cooldown_s=5.0,
                                 scale_down_cooldown_s=5.0),
        clock=clk)
    futs = [router.submit(SearchRequest(query=q)) for q in b.queries[:4]]
    releases = [release]
    assert started.wait(timeout=60)
    assert router.live_load() == 4               # wedged: load is exact
    assert asc.tick() == "scale_up"              # 4/1 > 2.0
    assert router.n_replicas == 2
    s2, r2 = _gate(router.replicas[1])
    releases.append(r2)
    clk.t = 1.0
    assert asc.tick() is None                    # inside the cooldown
    # wedge 3 more onto the fresh replica: 7/2 = 3.5 > 2.0
    futs += [router.submit(SearchRequest(query=q)) for q in b.queries[4:7]]
    assert s2.wait(timeout=60)
    clk.t = 6.0
    assert asc.tick() == "scale_up"
    assert router.n_replicas == 3
    s3, r3 = _gate(router.replicas[2])
    releases.append(r3)
    futs += [router.submit(SearchRequest(query=q))
             for q in b.queries[7:14]]
    assert s3.wait(timeout=60)
    clk.t = 30.0                                 # at max: capped, no growth
    assert asc.tick() is None
    assert asc.stats["capped_by_max"] >= 1
    assert router.n_replicas == 3
    for r in releases:
        r.set()
    for f in futs:
        f.result(timeout=120)
    router.stop()


def test_spill_delta_triggers_scale_up(anns_bundle):
    """Rejected/spilled demand scales up even when live load looks calm
    (the queue was FULL, not busy)."""
    b = anns_bundle
    clk = FakeClock()
    router = ReplicaRouter(b.index, n_replicas=1, policy="round_robin",
                           threaded=False, max_batch=8, max_wait_s=10.0,
                           max_queue=1)
    asc = ReplicaAutoscaler(
        router, AutoscalerConfig(max_replicas=2, high_water=8.0,
                                 low_water=0.5), clock=clk)
    router.submit(SearchRequest(query=b.queries[0]))
    with pytest.raises(BackpressureError):
        router.submit(SearchRequest(query=b.queries[1]))
    assert asc.tick() == "scale_up"              # reject delta, load calm
    assert router.n_replicas == 2
    # the SAME counters do not re-trigger: deltas, not absolutes
    clk.t = 100.0
    assert asc.tick() is None
    router.drain()
    router.stop()


def test_scale_down_needs_consecutive_calm_ticks(anns_bundle):
    b = anns_bundle
    clk = FakeClock()
    router = ReplicaRouter(b.index, n_replicas=2, policy="jsq",
                           threaded=False, max_batch=4, max_wait_s=0.0)
    asc = ReplicaAutoscaler(
        router, AutoscalerConfig(min_replicas=1, max_replicas=4,
                                 high_water=4.0, low_water=1.0,
                                 down_ticks=3, scale_down_cooldown_s=2.0),
        clock=clk)
    for i in range(2):
        clk.t = float(i)
        assert asc.tick() is None                # calm ticks 1, 2
    clk.t = 2.5
    assert asc.tick() == "scale_down"            # 3rd consecutive calm
    assert router.n_replicas == 1
    clk.t = 2.6
    for _ in range(3):
        assert asc.tick() is None                # at min_replicas: floor
    assert router.n_replicas == 1
    router.stop()


def test_no_flap_after_scale_up(anns_bundle):
    """Calm ticks right after a scale-up sit inside the down-cooldown, so
    the fresh replica is never immediately torn back down."""
    b = anns_bundle
    clk = FakeClock()
    router = ReplicaRouter(b.index, n_replicas=1, policy="jsq",
                           threaded=True, max_batch=4, max_wait_s=0.001)
    started, release = _gate(router.replicas[0])
    asc = ReplicaAutoscaler(
        router, AutoscalerConfig(min_replicas=1, max_replicas=2,
                                 high_water=1.5, low_water=1.0,
                                 down_ticks=1, scale_down_cooldown_s=50.0),
        clock=clk)
    futs = [router.submit(SearchRequest(query=q)) for q in b.queries[:3]]
    assert started.wait(timeout=60)
    assert asc.tick() == "scale_up"
    release.set()
    for f in futs:
        f.result(timeout=120)
    for t in (1.0, 2.0, 3.0):                    # calm, but inside cooldown
        clk.t = t
        assert asc.tick() is None
    assert router.n_replicas == 2
    clk.t = 60.0                                  # cooldown over: now shrink
    assert asc.tick() == "scale_down"
    router.stop()


def test_model_cap_blocks_useless_growth(anns_bundle):
    """With measured demand and an impossible min_gain, the analytic model
    says extra replicas buy nothing — overload stops scaling."""
    b = anns_bundle
    clk = FakeClock()
    router = ReplicaRouter(b.index, n_replicas=1, policy="jsq",
                           threaded=False, max_batch=4, max_wait_s=0.0)
    # serve real traffic first so measured_demand() exists
    futs = [router.submit(SearchRequest(query=q)) for q in b.queries[:4]]
    router.drain()
    for f in futs:
        f.result()
    asc = ReplicaAutoscaler(
        router, AutoscalerConfig(max_replicas=4, high_water=0.5,
                                 low_water=0.1, model_min_gain=1e9),
        clock=clk)
    router.submit(SearchRequest(query=b.queries[4]))   # load 1 > 0.5
    assert asc.tick() is None
    assert asc.stats["capped_by_model"] == 1
    assert router.n_replicas == 1
    router.drain()
    router.stop()


def test_background_thread_start_stop(anns_bundle):
    b = anns_bundle
    router = ReplicaRouter(b.index, n_replicas=1, policy="jsq",
                           threaded=False, max_batch=4, max_wait_s=0.0)
    asc = ReplicaAutoscaler(router, AutoscalerConfig(interval_s=0.005))
    with asc:
        deadline = threading.Event()
        deadline.wait(0.1)
    assert asc.stats["ticks"] >= 2
    assert asc._thread is None
    router.stop()


# ------------------------------------------------------- the full ramp

def test_load_ramp_grows_then_drains_deterministically(anns_bundle):
    """The PR-7 acceptance ramp at router level: a wedged replica + a
    doubled burst -> scale-up within one cooldown window; the NEW replica
    serves the second burst while the old one is still wedged; calm ->
    scale-down drains the victim with zero leaked futures and balanced
    books."""
    b = anns_bundle
    clk = FakeClock()
    router = ReplicaRouter(b.index, n_replicas=1, policy="jsq",
                           threaded=True, max_batch=4, max_wait_s=0.001)
    started, release = _gate(router.replicas[0])
    asc = ReplicaAutoscaler(
        router, AutoscalerConfig(min_replicas=1, max_replicas=2,
                                 high_water=3.0, low_water=1.0,
                                 down_ticks=2, scale_up_cooldown_s=5.0,
                                 scale_down_cooldown_s=5.0,
                                 p99_bound_s=120.0),
        clock=clk)
    # burst 1: wedge the only replica under 4 live requests
    burst1 = [router.submit(SearchRequest(query=q)) for q in b.queries[:4]]
    assert started.wait(timeout=60)
    assert asc.tick() == "scale_up"              # 4 > 3.0 high water
    assert router.n_replicas == 2
    # burst 2 (QPS doubled): JSQ routes every new request onto the fresh
    # replica (load 0 vs 4) — capacity grew where the traffic goes
    burst2 = [router.submit(SearchRequest(query=q))
              for q in b.queries[4:8]]
    for q, f in zip(b.queries[4:8], burst2):
        np.testing.assert_array_equal(f.result(timeout=120).ids,
                                      b.index.query(q).ids)
    roll = router.stats_rollup()
    assert roll["routed"][1] == 4                # all of burst 2, new slot
    # un-wedge; burst 1 resolves on the old replica
    release.set()
    for q, f in zip(b.queries[:4], burst1):
        np.testing.assert_array_equal(f.result(timeout=120).ids,
                                      b.index.query(q).ids)
    # calm: two consecutive calm ticks outside the cooldown -> scale-down
    clk.t = 10.0
    assert asc.tick() is None
    clk.t = 11.0
    assert asc.tick() == "scale_down"
    assert router.n_replicas == 1
    # zero leaks: every future done, the victim's threads joined, books
    # balanced across the whole scaling history
    assert all(f.done() for f in burst1 + burst2)
    roll = router.stats_rollup()
    assert roll["submitted"] == sum(roll["routed"]) + roll["rejected"] == 8
    pct = router.latency_percentiles()
    assert pct["n"] == 8 and pct["p99"] < 120.0
    assert len(asc.events) == 2
    router.stop()
    for svc in router.replicas:
        assert not svc._queue and svc._pump_thread is None
