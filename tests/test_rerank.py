"""Heuristic re-ranking (Algorithm 1) invariants."""

import jax.numpy as jnp
import numpy as np
import pytest
from _propshim import given, settings, strategies as st

from repro.core.io_sim import SSDSim, StorageLayout
from repro.core.rerank import heuristic_rerank, heuristic_rerank_jax


def _setup(rng, n=200, d=16):
    data = rng.standard_normal((n, d)).astype(np.float32)
    primary = rng.integers(0, 8, n).astype(np.int64)
    lay = StorageLayout.build(primary, 8, 4 * d)
    return data, SSDSim(data, lay)


def test_full_rerank_equals_bruteforce(rng):
    data, ssd = _setup(rng)
    q = rng.standard_normal(16).astype(np.float32)
    cand = np.arange(200)
    rr = heuristic_rerank(q, cand, ssd, k=10, batch_size=32,
                          disable_early_stop=True)
    exact = np.argsort(np.sum((data - q) ** 2, -1))[:10]
    np.testing.assert_array_equal(np.sort(rr.ids), np.sort(exact))
    assert rr.batches_run == 200 // 32 + 1


def test_dists_ascending(rng):
    data, ssd = _setup(rng)
    q = rng.standard_normal(16).astype(np.float32)
    rr = heuristic_rerank(q, np.arange(100), ssd, k=10)
    assert (np.diff(rr.dists) >= -1e-6).all()


def test_early_stop_reduces_work(rng):
    data, ssd = _setup(rng)
    q = data[0] + 0.01 * rng.standard_normal(16).astype(np.float32)
    # candidates sorted by true distance => heap stabilises fast
    order = np.argsort(np.sum((data - q) ** 2, -1))
    rr_es = heuristic_rerank(q, order, ssd, k=10, batch_size=16,
                             eps=0.05, beta=2)
    rr_full = heuristic_rerank(q, order, ssd, k=10, batch_size=16,
                               disable_early_stop=True)
    assert rr_es.batches_run < rr_full.batches_run
    assert rr_es.early_stopped
    # early stop on sorted candidates must not hurt the result here
    np.testing.assert_array_equal(rr_es.ids, rr_full.ids)


def test_beta_delays_termination(rng):
    data, ssd = _setup(rng)
    q = rng.standard_normal(16).astype(np.float32)
    order = np.argsort(np.sum((data - q) ** 2, -1))
    b1 = heuristic_rerank(q, order, ssd, k=10, batch_size=16, beta=1)
    b3 = heuristic_rerank(q, order, ssd, k=10, batch_size=16, beta=3)
    assert b1.batches_run <= b3.batches_run


def test_jax_version_matches_host(rng):
    data, ssd = _setup(rng, n=128)
    q = rng.standard_normal(16).astype(np.float32)
    order = np.argsort(np.sum((data - q) ** 2, -1)).astype(np.int32)
    host = heuristic_rerank(q, order, ssd, k=8, batch_size=16, eps=0.05,
                            beta=2)
    ids, dists, batches = heuristic_rerank_jax(
        jnp.asarray(q), jnp.asarray(data[order]), jnp.asarray(order), 8,
        batch_size=16, eps=0.05, beta=2)
    assert int(batches) == host.batches_run
    np.testing.assert_array_equal(np.sort(np.asarray(ids)),
                                  np.sort(host.ids))


def test_jax_tail_batch_scored(rng):
    """Satellite regression: n % batch_size != 0 — the device version
    silently never scored the last partial batch.  Best candidates are
    placed IN the tail, so missing it provably corrupts the result."""
    data, ssd = _setup(rng, n=100)
    q = rng.standard_normal(16).astype(np.float32)
    # descending true distance: the k best candidates live at the end,
    # with the 4 very best inside the ragged tail batch
    order = np.argsort(np.sum((data - q) ** 2, -1))[::-1].astype(np.int32)
    ids, dists, batches = heuristic_rerank_jax(
        jnp.asarray(q), jnp.asarray(data[order]), jnp.asarray(order), 8,
        batch_size=16, eps=0.0, beta=2)       # eps=0: no early stop
    assert int(batches) == -(-100 // 16)      # ceil: the tail batch ran
    exact = np.argsort(np.sum((data - q) ** 2, -1))[:8]
    np.testing.assert_array_equal(np.sort(np.asarray(ids)), np.sort(exact))


def test_jax_matches_host_ragged(rng):
    """Host-vs-device parity (batch count + ids) with a ragged tail and
    early stopping enabled."""
    data, ssd = _setup(rng, n=120)
    q = rng.standard_normal(16).astype(np.float32)
    cand = rng.permutation(120).astype(np.int32)
    host = heuristic_rerank(q, cand, ssd, k=8, batch_size=32, eps=0.05,
                            beta=2)
    ids, dists, batches = heuristic_rerank_jax(
        jnp.asarray(q), jnp.asarray(data[cand]), jnp.asarray(cand), 8,
        batch_size=32, eps=0.05, beta=2)
    assert int(batches) == host.batches_run
    np.testing.assert_array_equal(np.sort(np.asarray(ids)),
                                  np.sort(host.ids))


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 999), k=st.integers(1, 20),
       batch=st.sampled_from([8, 16, 32]))
def test_topk_is_prefix_optimal(seed, k, batch):
    """Whatever prefix Alg. 1 scans, its output is the exact top-k of that
    prefix (the heap never loses a better candidate)."""
    rng = np.random.default_rng(seed)
    data, ssd = _setup(rng, n=160)
    q = rng.standard_normal(16).astype(np.float32)
    cand = rng.permutation(160)
    rr = heuristic_rerank(q, cand, ssd, k=k, batch_size=batch)
    scanned = cand[:rr.batches_run * batch]
    d = np.sum((data[scanned] - q) ** 2, -1)
    expect = scanned[np.argsort(d)[:k]]
    np.testing.assert_array_equal(np.sort(rr.ids), np.sort(expect))
