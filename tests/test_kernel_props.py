"""Property-based edge sweeps for the PQ ADC kernel wrappers (ISSUE 6
satellite).

Invariants under test (Pallas interpret path vs the jnp oracle):

* **ragged N** — for N % block_n ∈ {0, 1, block_n−1} (full blocks, one
  lonely row in the final block, one row short of full) the padded scan
  matches the oracle exactly: padding rows never surface and never evict
  real candidates from a per-block partial top-k;
* **topk ≥ N** — the fused top-k truncates to N real rows: all finite,
  no padding ids (the ISSUE-6 +inf-leak fix);
* **masked batch** — per-query membership masks: masked-out rows surface
  as +inf and every finite id is a member of that query's mask;
* **int8 LUT** — the fig10 accuracy level stays within the analytic
  asymmetric-quantization bound of the fp32 oracle, and its top-k ids
  keep high overlap.

Runs under ``hypothesis`` when installed, else the deterministic
``tests/_propshim.py`` fallback (tier-1 policy, see conftest.py).
"""

import jax.numpy as jnp
import numpy as np
from _propshim import given, settings, strategies as st

from repro.kernels.pq_adc import (build_luts_ref, pq_adc_batch_ref,
                                  pq_adc_fused_topk, pq_adc_ref,
                                  pq_adc_topk, pq_adc_topk_batch)

_M = 8
_BLOCK = st.sampled_from([64, 128, 256])
_REM = st.sampled_from(["zero", "one", "minus_one"])
_SEED = st.integers(0, 2 ** 16)


def _case(seed, n, m=_M, k=256):
    rng = np.random.default_rng(seed)
    codes = jnp.asarray(rng.integers(0, k, (n, m)), jnp.uint8)
    lut = jnp.asarray(rng.random((m, k)) + 0.5, jnp.float32)
    return codes, lut


@settings(max_examples=12, deadline=None)
@given(block=_BLOCK, rem=_REM, blocks=st.integers(1, 3), seed=_SEED)
def test_ragged_n_matches_oracle(block, rem, blocks, seed):
    n = blocks * block + {"zero": 0, "one": 1, "minus_one": block - 1}[rem]
    codes, lut = _case(seed, n)
    topk = min(n, 32)
    vals, ids = pq_adc_topk(codes, lut, topk, block_n=block)
    ref_v, ref_i = pq_adc_topk(codes, lut, topk, use_kernel=False)
    np.testing.assert_allclose(np.asarray(vals), np.asarray(ref_v),
                               rtol=1e-6)
    # equal-distance ties may order differently; the achieved distances
    # must match exactly and every id must be a real row
    d = np.asarray(pq_adc_ref(codes, lut))
    np.testing.assert_allclose(d[np.asarray(ids)], np.asarray(ref_v),
                               rtol=1e-6)
    assert np.all((np.asarray(ids) >= 0) & (np.asarray(ids) < n))


@settings(max_examples=10, deadline=None)
@given(n=st.integers(1, 40), topk=st.sampled_from([64, 100, 256]),
       seed=_SEED)
def test_topk_at_least_n_truncates_to_real_rows(n, topk, seed):
    codes, lut = _case(seed, n)
    for use_kernel in (True, False):
        vals, ids = pq_adc_topk(codes, lut, topk, use_kernel=use_kernel)
        assert vals.shape == (n,)
        assert np.all(np.isfinite(np.asarray(vals)))
        assert sorted(np.asarray(ids).tolist()) == list(range(n))


@settings(max_examples=8, deadline=None)
@given(n=st.integers(16, 300), b=st.integers(1, 5),
       density=st.sampled_from([0.0, 0.1, 0.5, 1.0]), seed=_SEED)
def test_masked_batch_only_members_finite(n, b, density, seed):
    rng = np.random.default_rng(seed)
    codes = jnp.asarray(rng.integers(0, 256, (n, _M)), jnp.uint8)
    luts = jnp.asarray(rng.random((b, _M, 256)), jnp.float32)
    mask = rng.random((b, n)) < density
    vals, ids = pq_adc_topk_batch(codes, luts, 32, mask=jnp.asarray(mask),
                                  use_kernel=False)
    d = np.asarray(pq_adc_batch_ref(codes, luts))
    v, i = np.asarray(vals), np.asarray(ids)
    for qi in range(b):
        fin = np.isfinite(v[qi])
        assert fin.sum() == min(32, mask[qi].sum())
        assert np.all(mask[qi][i[qi][fin]])        # members only
        np.testing.assert_allclose(d[qi][i[qi][fin]], v[qi][fin],
                                   rtol=1e-6)


@settings(max_examples=6, deadline=None)
@given(n=st.integers(100, 600), b=st.integers(1, 4),
       s=st.sampled_from([32, 128]), seed=_SEED)
def test_int8_lut_within_quantization_bound(n, b, s, seed):
    rng = np.random.default_rng(seed)
    dsub = 4
    codes = jnp.asarray(rng.integers(0, 256, (n, _M)), jnp.uint8)
    cb = jnp.asarray(rng.standard_normal((_M, 256, dsub)), jnp.float32)
    q = jnp.asarray(rng.standard_normal((b, _M * dsub)), jnp.float32)
    rows = np.full((b, s), -1, np.int32)
    for qi in range(b):
        cnt = int(rng.integers(1, min(n, s) + 1))
        rows[qi, :cnt] = np.sort(rng.choice(n, cnt, replace=False))
    rows = jnp.asarray(rows)
    luts = np.asarray(build_luts_ref(cb, q))
    bound = ((luts.max(-1) - luts.min(-1)) / 255.0 / 2).sum(-1).max() + 1e-5
    v32, _ = pq_adc_fused_topk(codes, q, cb, rows, 16, use_kernel=False)
    v8, i8 = pq_adc_fused_topk(codes, q, cb, rows, 16, use_kernel=False,
                               lut_int8=True)
    fin = np.isfinite(np.asarray(v32))
    np.testing.assert_array_equal(fin, np.isfinite(np.asarray(v8)))
    assert np.max(np.abs(np.asarray(v8)[fin] - np.asarray(v32)[fin]),
                  initial=0.0) <= bound
    assert np.all(np.asarray(i8)[~np.isfinite(np.asarray(v8))] == -1)
