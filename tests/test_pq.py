"""PQ properties (paper §2.2): codebook training, encode/decode, ADC."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _propshim import given, settings, strategies as st

from repro.core import pq


def _data(rng, n=512, d=32):
    return jnp.asarray(rng.standard_normal((n, d)), jnp.float32)


def test_encode_shape_dtype(rng):
    data = _data(rng)
    cb = pq.train_codebooks(jax.random.key(0), data, m=8, nbits=8, iters=4)
    codes = pq.encode(cb, data)
    assert codes.shape == (512, 8) and codes.dtype == jnp.uint8


def test_adc_equals_exact_on_centroids(rng):
    """A vector that IS a reconstruction has ADC distance == exact distance
    to the query (both measure query-to-centroid)."""
    data = _data(rng, 256, 16)
    cb = pq.train_codebooks(jax.random.key(0), data, m=4, nbits=4, iters=6)
    codes = pq.encode(cb, data)
    recon = pq.decode(cb, codes)
    q = jnp.asarray(rng.standard_normal(16), jnp.float32)
    lut = pq.adc_lut(cb, q)
    adc = pq.adc_distances_ref(lut, codes)
    exact_recon = pq.exact_l2(q, recon)
    np.testing.assert_allclose(np.asarray(adc), np.asarray(exact_recon),
                               rtol=1e-4, atol=1e-4)


def test_quantization_error_decreases_with_m(rng):
    data = _data(rng, 512, 32)
    errs = []
    for m in (2, 8, 32):
        cb = pq.train_codebooks(jax.random.key(0), data, m=m, iters=6)
        recon = pq.decode(cb, pq.encode(cb, data))
        errs.append(float(jnp.mean(jnp.sum((recon - data) ** 2, -1))))
    assert errs[0] >= errs[1] >= errs[2]


def test_adc_preserves_neighbor_ranking(rng):
    """PQ distances must correlate with exact distances (rank quality)."""
    data = _data(rng, 512, 32)
    cb = pq.train_codebooks(jax.random.key(0), data, m=16, iters=8)
    codes = pq.encode(cb, data)
    q = np.asarray(data[0])
    lut = pq.adc_lut(cb, jnp.asarray(q))
    adc = np.asarray(pq.adc_distances_ref(lut, codes))
    exact = np.asarray(pq.exact_l2(jnp.asarray(q), data))
    # top-10 exact neighbours should mostly be in ADC top-50
    top_exact = set(np.argsort(exact)[:10].tolist())
    top_adc = set(np.argsort(adc)[:50].tolist())
    assert len(top_exact & top_adc) >= 7


@settings(max_examples=20, deadline=None)
@given(m=st.sampled_from([2, 4, 8]), d_mult=st.integers(2, 6),
       seed=st.integers(0, 2 ** 16))
def test_lut_is_subspace_distance(m, d_mult, seed):
    """Property: LUT[i, j] == squared L2 between query sub-vector i and
    centroid j of sub-space i."""
    d = m * d_mult
    rng = np.random.default_rng(seed)
    data = jnp.asarray(rng.standard_normal((64, d)), jnp.float32)
    cb = pq.train_codebooks(jax.random.key(seed), data, m=m, nbits=4,
                            iters=2)
    q = jnp.asarray(rng.standard_normal(d), jnp.float32)
    lut = np.asarray(pq.adc_lut(cb, q))
    qs = np.asarray(q).reshape(m, d_mult)
    cbn = np.asarray(cb.codebooks)
    for i in range(m):
        ref = np.sum((cbn[i] - qs[i]) ** 2, -1)
        np.testing.assert_allclose(lut[i], ref, rtol=1e-4, atol=1e-4)


def test_codes_are_nearest_centroids(rng):
    data = _data(rng, 128, 16)
    cb = pq.train_codebooks(jax.random.key(0), data, m=4, iters=4)
    codes = np.asarray(pq.encode(cb, data))
    sub = np.asarray(data).reshape(128, 4, 4).transpose(1, 0, 2)
    cbs = np.asarray(cb.codebooks)
    for i in range(4):
        d2 = ((sub[i][:, None] - cbs[i][None]) ** 2).sum(-1)
        np.testing.assert_array_equal(codes[:, i], np.argmin(d2, -1))
