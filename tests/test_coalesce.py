"""Request coalescing (PR 7 satellite S3 — DESIGN.md §8).

Contract under test:
* the coalescing key distinguishes EVERY effective plan knob — ``k``,
  ``top_n``, ``deadline_s``, ``fused``, ``lut_int8``, and (PR 10) the
  ``filter`` predicate, ``tenant``, and ``adaptive`` flag — and the
  query bytes; only ``tag`` metadata is excluded (property test via
  tests/_propshim.py);
* a concurrent burst of N identical requests through a coalescing
  ``AsyncANNSClient`` costs exactly ONE backend submit (the serve path is
  event-gated so the overlap is deterministic, not scheduler luck), and
  every waiter resolves to bit-identical ids with its own tag;
* cancelling one attached waiter never cancels the shared backend future
  or any other waiter; the leader's resolution still fans out;
* a leader whose admission fails releases the key (followers fail with
  the same error, the next arrival becomes a fresh leader).
"""

import asyncio
import threading

import numpy as np
import pytest
from _propshim import given, settings, strategies as st

from repro.core.executor import QueryStats
from repro.core.futures import QueryFuture
from repro.serve.client import (AsyncANNSClient, RequestCoalescer,
                                SearchRequest, SearchResponse, coalesce_key)

# every draw below is one knob assignment; two draws collide iff equal
_KS = (None, 5, 10)
_TOP_NS = (None, 64, 128)
_DEADLINES = (None, 0.5, 2.0)
_BOOLS = (False, True)


def _key(q, k, top_n, deadline_s, fused, lut_int8):
    return coalesce_key(
        SearchRequest(query=q, k=k, top_n=top_n, deadline_s=deadline_s),
        fused=fused, lut_int8=lut_int8)


@settings(max_examples=25)
@given(ka=st.integers(0, 2), kb=st.integers(0, 2),
       na=st.integers(0, 2), nb=st.integers(0, 2),
       da=st.integers(0, 2), db=st.integers(0, 2),
       fa=st.integers(0, 1), fb=st.integers(0, 1),
       la=st.integers(0, 1), lb=st.integers(0, 1))
def test_key_distinguishes_every_plan_knob(ka, kb, na, nb, da, db,
                                           fa, fb, la, lb):
    """Keys are equal iff every result-affecting knob is equal."""
    q = np.arange(8, dtype=np.float32)
    knobs_a = (_KS[ka], _TOP_NS[na], _DEADLINES[da],
               _BOOLS[fa], _BOOLS[la])
    knobs_b = (_KS[kb], _TOP_NS[nb], _DEADLINES[db],
               _BOOLS[fb], _BOOLS[lb])
    assert (_key(q, *knobs_a) == _key(q, *knobs_b)) \
        == (knobs_a == knobs_b)


def test_key_separates_query_bytes_not_metadata():
    qa = np.arange(8, dtype=np.float32)
    qb = qa.copy()
    qb[3] += 1e-3
    assert _key(qa, 5, None, None, False, False) \
        != _key(qb, 5, None, None, False, False)
    # tag is correlation metadata, never part of work identity ...
    assert coalesce_key(SearchRequest(query=qa, k=5, tag="a")) \
        == coalesce_key(SearchRequest(query=qa, k=5, tag="b"))
    # ... but tenant IS (PR 10): two tenants' identical queries must
    # never share one scan — the tenant layer stamps a different base
    # predicate per namespace
    assert coalesce_key(SearchRequest(query=qa, k=5, tenant="x")) \
        != coalesce_key(SearchRequest(query=qa, k=5, tenant="y"))


@settings(max_examples=40)
@given(fa=st.integers(0, 3), fb=st.integers(0, 3),
       ta=st.integers(0, 2), tb=st.integers(0, 2),
       aa=st.integers(0, 1), ab=st.integers(0, 1))
def test_key_distinguishes_filter_tenant_adaptive(fa, fb, ta, tb, aa, ab):
    """PR 10: keys are equal iff (filter, tenant, adaptive) are equal —
    a filtered request can never attach to an unfiltered leader, and
    hashable-equal predicates (``In`` canonicalizes its values) DO
    coalesce."""
    from repro.core.filters import And, Eq, In, Range
    filters = (None, Eq("cat", 3), In("cat", (2, 1, 2)),
               And((Eq("tenant", 0), Range("ts", 10, 20))))
    # In("cat", (1, 2)) is value-equal to filters[2]: same key by hash
    equiv = (None, Eq("cat", 3), In("cat", (1, 2)),
             And((Eq("tenant", 0), Range("ts", 10, 20))))
    tenants = (None, "alice", "bob")
    q = np.arange(8, dtype=np.float32)
    ka = coalesce_key(SearchRequest(query=q, k=5, filter=filters[fa],
                                    tenant=tenants[ta], adaptive=bool(aa)))
    kb = coalesce_key(SearchRequest(query=q, k=5, filter=equiv[fb],
                                    tenant=tenants[tb], adaptive=bool(ab)))
    assert (ka == kb) == ((fa, ta, aa) == (fb, tb, ab))


# ------------------------------------------------------- attached waiters

def _resp(tag=None) -> SearchResponse:
    stats = QueryStats(*([0] * len(QueryStats.__dataclass_fields__)))
    return SearchResponse(ids=np.arange(5), dists=np.zeros(5),
                          stats=stats, tag=tag)


def test_cancelling_attached_waiter_never_touches_master():
    co = RequestCoalescer()
    req = SearchRequest(query=np.ones(4, np.float32), k=5, tag="leader")
    leader, key = co.claim(req)
    assert leader
    master = QueryFuture(tag="master", blocking=True)
    co.publish(key, master)
    w1 = co.claim(SearchRequest(query=np.ones(4, np.float32), k=5,
                                tag="w1"))[1]
    w2 = co.claim(SearchRequest(query=np.ones(4, np.float32), k=5,
                                tag="w2"))[1]
    assert co.stats == {"leaders": 1, "attached": 2}
    assert w1.cancel()
    assert not master.cancelled() and not master.done()
    assert not w2.done()
    master._set_result(_resp(tag="master"))
    # the cancelled waiter stays cancelled; the live one gets its OWN tag
    assert w1.cancelled()
    assert w2.result().tag == "w2"
    assert master.result().tag == "master"
    assert co.live() == 0                     # key retired with the master


def test_waiters_queued_during_admission_are_wired_at_publish():
    """Followers arriving while the leader is still mid-admission (no
    master future yet) park on the entry and get wired by publish()."""
    co = RequestCoalescer()
    req = SearchRequest(query=np.ones(4, np.float32))
    leader, key = co.claim(req)
    assert leader
    early = co.claim(SearchRequest(query=np.ones(4, np.float32),
                                   tag="early"))[1]
    assert not early.done()
    master = QueryFuture(blocking=True)
    co.publish(key, master)
    master._set_result(_resp())
    assert early.result().tag == "early"


def test_abandoned_leader_fails_waiters_and_frees_key():
    co = RequestCoalescer()
    req = SearchRequest(query=np.ones(4, np.float32))
    _, key = co.claim(req)
    w = co.claim(SearchRequest(query=np.ones(4, np.float32)))[1]
    co.abandon(key, RuntimeError("admission failed"))
    with pytest.raises(RuntimeError, match="admission failed"):
        w.result()
    # the key is free: the next identical request is a fresh leader
    leader, _key2 = co.claim(req)
    assert leader and co.live() == 1


# ---------------------------------------- epoch-aware keys (PR 9, S3)

def test_key_includes_index_epoch():
    """The key must distinguish index states: identical request bytes at
    different epochs are different units of work."""
    q = np.arange(8, dtype=np.float32)
    req = SearchRequest(query=q, k=5)
    assert coalesce_key(req, epoch=3) == coalesce_key(req, epoch=3)
    assert coalesce_key(req, epoch=3) != coalesce_key(req, epoch=4)
    # and the epoch-free key (no epoch_source wired) stays distinct too
    assert coalesce_key(req) != coalesce_key(req, epoch=0)


def test_mutation_mid_flight_is_not_coalesced(anns_bundle, fresh_index):
    """PR-9 regression: with an in-flight entry keyed before a mutation,
    a request submitted AFTER the insert/delete must not attach to it —
    attaching would hand the late arrival a pre-mutation result.  The
    coalescer samples the index epoch at claim time, so the same query
    bytes become a fresh leader once the index moves."""
    b = anns_bundle
    index = fresh_index
    co = RequestCoalescer(epoch_source=lambda: index.epoch)
    req = SearchRequest(query=b.queries[0], k=5)
    leader, key = co.claim(req)
    assert leader
    master = QueryFuture(blocking=True)
    co.publish(key, master)
    # identical request while in flight at the SAME epoch: attaches
    attached, waiter = co.claim(SearchRequest(query=b.queries[0], k=5,
                                              tag="same-epoch"))
    assert not attached
    # mutate the index mid-flight; the same bytes now claim a new key
    index.insert(b.new_vecs[:2])
    leader2, key2 = co.claim(SearchRequest(query=b.queries[0], k=5))
    assert leader2 and key2 != key
    assert co.live() == 2              # entries coexist, split by epoch
    master._set_result(_resp(tag="master"))
    assert waiter.result().tag == "same-epoch"   # old entry still fans out
    co.abandon(key2, None)
    index.delete(np.array([index.n_total - 1]))  # delete bumps epoch too
    leader3, key3 = co.claim(SearchRequest(query=b.queries[0], k=5))
    assert leader3 and key3 != key2


# ------------------------------------------ one backend submit per burst

def test_coalesced_burst_is_one_backend_submit(anns_bundle):
    """12 identical concurrent requests through a coalescing async client
    over a GATED threaded service: exactly one backend submit, twelve
    bit-identical responses, each with its own tag."""
    b = anns_bundle
    from repro.serve.anns_service import BatchingANNSService
    svc = BatchingANNSService(b.index, threaded=True, max_batch=4,
                              max_wait_s=0.001)
    started, release = threading.Event(), threading.Event()
    orig = svc._serve_batch_inner

    def gated(batch):
        started.set()
        assert release.wait(timeout=60)
        return orig(batch)

    svc._serve_batch_inner = gated
    n_burst = 12
    ref = b.index.query(b.queries[0], k=10).ids

    async def drive():
        client = AsyncANNSClient(svc, coalescer=RequestCoalescer())
        tasks = [asyncio.ensure_future(client.search(
            SearchRequest(query=b.queries[0], k=10, tag=i)))
            for i in range(n_burst)]
        # the leader's submit lands synchronously at task start; the gate
        # holds the batch open so every follower attaches to it
        await asyncio.sleep(0)
        release.set()
        resps = await asyncio.gather(*tasks)
        await client.aclose()
        return resps, dict(client.stats)

    try:
        resps, cstats = asyncio.run(drive())
    finally:
        release.set()
        svc.stop()
    assert cstats["submitted"] == 1
    assert cstats["coalesced"] == n_burst - 1
    assert int(svc.stats["requests"]) == 1
    assert sorted(r.tag for r in resps) == list(range(n_burst))
    for r in resps:
        np.testing.assert_array_equal(r.ids, ref)


def test_sequential_identical_requests_do_not_coalesce(anns_bundle):
    """Coalescing is an in-flight dedup, not a cache: the same query
    re-submitted after resolution is a fresh backend submit."""
    b = anns_bundle
    from repro.serve.anns_service import BatchingANNSService
    svc = BatchingANNSService(b.index, threaded=True, max_batch=4,
                              max_wait_s=0.0005)

    async def drive():
        client = AsyncANNSClient(svc, coalescer=RequestCoalescer())
        a = await client.search(SearchRequest(query=b.queries[1], k=5))
        bb = await client.search(SearchRequest(query=b.queries[1], k=5))
        await client.aclose()
        return a, bb, dict(client.stats)

    try:
        a, bb, cstats = asyncio.run(drive())
    finally:
        svc.stop()
    assert cstats["submitted"] == 2 and cstats["coalesced"] == 0
    np.testing.assert_array_equal(a.ids, bb.ids)
