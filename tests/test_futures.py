"""Futures-first query API (ISSUE 2 acceptance).

Contract under test:
* ``submit()``-then-``result()`` returns bit-identical ids to ``run()``
  for the same plan, at every window/overlap/depth combination;
* with inflight depth >= 2 the host dispatches window t+1 BEFORE blocking
  on window t's scan (asserted via the ticket's event-ordering probe, not
  wall-clock), and depth 1 stays strictly synchronous;
* per-request ``k`` is honored in mixed batches through one shared scan
  window — both at the executor (``PlanOverrides``) and through the
  serving front-end (the PR-1 ``pump()`` dropped ``Request.k``);
* cancellation skips the per-query re-rank and surfaces
  ``CancelledError``; deadlines surface ``DeadlineExceeded``; the serving
  queue applies backpressure at ``max_queue``.
"""

import numpy as np
import pytest

from repro.core.executor import PlanOverrides, QueryPlan
from repro.core.futures import (BackpressureError, CancelledError,
                                DeadlineExceeded, FutureError, QueryFuture)
from repro.serve.anns_service import BatchingANNSService
from repro.serve.client import SearchRequest


# --------------------------------------------------------------- executor

@pytest.fixture(scope="module")
def singles(anns_bundle):
    return [anns_bundle.index.query(q) for q in anns_bundle.queries]


def test_submit_result_matches_run(anns_bundle, singles):
    b = anns_bundle
    for kw in ({}, {"window": 4}, {"window": 4, "overlap_rerank": True},
               {"window": 3, "inflight_depth": 3}):
        plan = b.index.plan(**kw)
        run_res = b.index.executor.run(b.queries, plan)
        ticket = b.index.executor.submit(b.queries, plan)
        assert not ticket.done()          # per-query rerank still pending
        for one, rr, fut in zip(singles, run_res, ticket.futures):
            np.testing.assert_array_equal(rr.ids, fut.result().ids)
            np.testing.assert_array_equal(one.ids, rr.ids)
        assert ticket.done()


def test_overlap_true_false_id_parity(anns_bundle, singles):
    """Satellite: overlap_rerank=True vs False (and deeper pipelines)
    never change ids — pipelining is a scheduling choice, not a result
    knob."""
    b = anns_bundle
    base = None
    for overlap, depth in ((False, 0), (True, 0), (False, 1), (False, 2),
                           (False, 4)):
        res = b.index.executor.run(b.queries, b.index.plan(
            window=4, overlap_rerank=overlap, inflight_depth=depth))
        ids = np.stack([r.ids for r in res])
        if base is None:
            base = ids
        np.testing.assert_array_equal(base, ids)
    np.testing.assert_array_equal(
        base, np.stack([s.ids for s in singles]))


def _event_index(events, kind):
    return {wi: i for i, (k, wi) in enumerate(events) if k == kind}


def test_depth2_dispatches_ahead_of_blocking(anns_bundle):
    """Acceptance probe: with depth >= 2 the host dispatches window t+1
    before blocking on window t's scan — via event ordering, not
    wall-clock."""
    b = anns_bundle
    n_w = 4
    ticket = b.index.executor.submit(
        b.queries[:8], b.index.plan(window=2, inflight_depth=2))
    # eager phase already dispatched the first two windows
    assert ticket.events[:2] == [("dispatch", 0), ("dispatch", 1)]
    ticket.wait()
    disp = _event_index(ticket.events, "dispatch")
    fin = _event_index(ticket.events, "finish")
    assert len(disp) == len(fin) == n_w
    for t in range(n_w - 1):
        assert disp[t + 1] < fin[t], (t, ticket.events)


def test_depth1_is_synchronous(anns_bundle):
    b = anns_bundle
    ticket = b.index.executor.submit(
        b.queries[:8], b.index.plan(window=2, inflight_depth=1))
    ticket.wait()
    disp = _event_index(ticket.events, "dispatch")
    fin = _event_index(ticket.events, "finish")
    for t in range(3):
        assert fin[t] < disp[t + 1], ticket.events


def test_ticket_poll_makes_progress(anns_bundle):
    b = anns_bundle
    ticket = b.index.executor.submit(
        b.queries[:6], b.index.plan(window=2, inflight_depth=2))
    while not ticket.done():
        if not ticket.poll():        # scan not landed yet: block via pump
            ticket._pump()
    ids = np.stack([f.result().ids for f in ticket.futures])
    ref = np.stack([b.index.query(q).ids for q in b.queries[:6]])
    np.testing.assert_array_equal(ids, ref)


def test_mixed_k_overrides_one_window(anns_bundle):
    """Heterogeneous per-request k inside ONE shared scan window."""
    b = anns_bundle
    ks = [3, 7, 5, 10]
    ticket = b.index.executor.submit(
        b.queries[:4], b.index.plan(),
        overrides=[PlanOverrides(k=k) for k in ks])
    results = ticket.results()
    # one window => every member sees the same union scan
    u = results[0].stats.candidates_scanned
    assert all(r.stats.candidates_scanned == u for r in results)
    for q, k, r in zip(b.queries, ks, results):
        assert len(r.ids) == k
        np.testing.assert_array_equal(r.ids, b.index.query(q, k=k).ids)


def test_future_cancel_semantics(anns_bundle, singles):
    b = anns_bundle
    ticket = b.index.executor.submit(
        b.queries[:4], b.index.plan(window=1, inflight_depth=1))
    victim = ticket.futures[2]
    assert victim.cancel() is True
    assert victim.cancelled() and victim.done()
    assert victim.cancel() is True            # idempotent
    with pytest.raises(CancelledError):
        victim.result()
    # the rest of the batch is unaffected and bit-identical
    for qi in (0, 1, 3):
        np.testing.assert_array_equal(singles[qi].ids,
                                      ticket.futures[qi].result().ids)
    # cancel after resolution fails
    assert ticket.futures[0].cancel() is False


def test_future_deadline(anns_bundle):
    b = anns_bundle
    ticket = b.index.executor.submit(
        b.queries[:2], b.index.plan(),
        overrides=[PlanOverrides(deadline_s=0.0), None])
    with pytest.raises(DeadlineExceeded):
        ticket.futures[0].result()
    assert ticket.futures[0].exception() is not None
    ok = ticket.futures[1].result()           # neighbour is unaffected
    np.testing.assert_array_equal(ok.ids, b.index.query(b.queries[1]).ids)
    # plan-level deadline_s=0.0 is honored too (falsy-zero regression)
    t2 = b.index.executor.submit(b.queries[:1],
                                 b.index.plan(deadline_s=0.0))
    with pytest.raises(DeadlineExceeded):
        t2.futures[0].result()


def test_orphan_future_raises(anns_bundle):
    fut = QueryFuture()
    with pytest.raises(FutureError):
        fut.result()
    with pytest.raises(TimeoutError):
        QueryFuture(driver=lambda: True).result(timeout=0.0)


# ------------------------------------------------------------------- plan

def test_from_config_falsy_values(anns_bundle):
    """Satellite: explicit 0 must not fall back to the config default."""
    cfg = anns_bundle.cfg
    p = QueryPlan.from_config(cfg)
    assert (p.k, p.top_m, p.top_n) == (cfg.top_k, cfg.top_m, cfg.top_n)
    assert QueryPlan.from_config(cfg, k=0).k == 0
    assert QueryPlan.from_config(cfg, top_m=0).top_m == 0
    assert QueryPlan.from_config(cfg, top_n=0).top_n == 0


def test_plan_override_merge(anns_bundle):
    base = QueryPlan.from_config(anns_bundle.cfg)
    merged = PlanOverrides(k=3, deadline_s=1.5).merge_into(base)
    assert merged.k == 3 and merged.deadline_s == 1.5
    assert merged.top_n == base.top_n         # None keeps the base
    assert base.override(top_n=0).top_n == 0  # explicit zero wins
    assert base.override().k == base.k
    assert base.effective_depth() == 1
    assert base.override(overlap_rerank=True).effective_depth() == 2
    assert base.override(inflight_depth=3).effective_depth() == 3


# ------------------------------------------------------- done callbacks

def test_add_done_callback_fires_once_per_outcome():
    """PR-5 satellite: exactly-once callbacks on every terminal state,
    immediate fire when already resolved (the asyncio bridge's contract)."""
    calls = []
    fut = QueryFuture()
    fut.add_done_callback(lambda f: calls.append(("pre", f.result())))
    assert calls == []                    # pending: registered, not fired
    fut._set_result(41)
    assert calls == [("pre", 41)]
    fut._set_result(99)                   # resolution is one-way
    assert fut.result() == 41 and calls == [("pre", 41)]
    fut.add_done_callback(lambda f: calls.append(("post", f.result())))
    assert calls == [("pre", 41), ("post", 41)]   # immediate fire

    cancelled = QueryFuture()
    cancelled.add_done_callback(lambda f: calls.append(("c", f.cancelled())))
    assert cancelled.cancel() and calls[-1] == ("c", True)

    failed = QueryFuture()
    failed.add_done_callback(lambda f: calls.append(("e", f.exception())))
    boom = FutureError("boom")
    failed._set_exception(boom)
    assert calls[-1] == ("e", boom)


def test_add_done_callback_raising_does_not_poison():
    """A raising callback neither breaks the future nor starves later
    callbacks."""
    calls = []
    fut = QueryFuture()
    fut.add_done_callback(lambda f: 1 / 0)
    fut.add_done_callback(lambda f: calls.append(f.result()))
    fut._set_result(7)
    assert calls == [7] and fut.result() == 7
    fut.add_done_callback(lambda f: 1 / 0)    # immediate-fire path too
    assert fut.result() == 7


# ---------------------------------------------------------------- service

def test_service_per_request_k_regression(anns_bundle):
    """Satellite regression: pump() must honor Request.k (PR 1 stored it
    and then ran every request at the plan default)."""
    b = anns_bundle
    svc = BatchingANNSService(b.index, max_batch=8, max_wait_s=0.0)
    ks = [3, 5, 7, 10]
    futs = [svc.submit(SearchRequest(query=q, k=k)) for q, k in zip(b.queries, ks)]
    svc.drain()
    assert svc.stats["batches"] == 1          # ONE mixed-k scan window
    for q, k, f in zip(b.queries, ks, futs):
        resp = f.result()
        assert resp.batch_size == 4
        assert len(resp.ids) == k
        np.testing.assert_array_equal(resp.ids,
                                      b.index.query(q, k=k).ids)


def test_service_future_drives_pump(anns_bundle):
    """result() on a pending service future forces the pump itself."""
    b = anns_bundle
    svc = BatchingANNSService(b.index, max_batch=64, max_wait_s=10.0)
    fut = svc.submit(SearchRequest(query=b.queries[0]))
    assert not fut.done()
    resp = fut.result()                       # no explicit pump()/drain()
    np.testing.assert_array_equal(resp.ids,
                                  b.index.query(b.queries[0]).ids)
    assert svc.stats["requests"] == 1


def test_cancel_burst_frees_queue_slots(anns_bundle):
    """Satellite regression: cancelled requests must not occupy queue
    slots until the next pump — a cancel burst previously made fresh
    submits raise spurious BackpressureError."""
    b = anns_bundle
    svc = BatchingANNSService(b.index, max_batch=8, max_wait_s=10.0,
                              max_queue=3)
    futs = [svc.submit(SearchRequest(query=q)) for q in b.queries[:3]]
    for f in futs:
        assert f.cancel()
    fut = svc.submit(SearchRequest(query=b.queries[3]))            # must NOT be rejected
    assert svc.stats["rejected"] == 0
    assert svc.stats["cancelled"] == 3        # compacted out, counted once
    resp = fut.result()
    np.testing.assert_array_equal(resp.ids,
                                  b.index.query(b.queries[3]).ids)
    assert svc.stats["cancelled"] == 3        # pump never re-counts them


def test_service_backpressure(anns_bundle):
    b = anns_bundle
    svc = BatchingANNSService(b.index, max_batch=8, max_wait_s=0.0,
                              max_queue=2)
    svc.submit(SearchRequest(query=b.queries[0]))
    svc.submit(SearchRequest(query=b.queries[1]))
    with pytest.raises(BackpressureError):
        svc.submit(SearchRequest(query=b.queries[2]))
    assert svc.stats["rejected"] == 1
    svc.drain()                               # queue clears; admission again
    fut = svc.submit(SearchRequest(query=b.queries[2]))
    assert fut.result().ids is not None


def test_service_cancel_and_deadline(anns_bundle):
    b = anns_bundle
    svc = BatchingANNSService(b.index, max_batch=8, max_wait_s=0.0)
    live = svc.submit(SearchRequest(query=b.queries[0]))
    dead = svc.submit(SearchRequest(query=b.queries[1], deadline_s=0.0))
    gone = svc.submit(SearchRequest(query=b.queries[2]))
    assert gone.cancel()
    responses = svc.drain()
    assert [r.rid for r in responses] == [live.tag]
    with pytest.raises(DeadlineExceeded):
        dead.result()
    with pytest.raises(CancelledError):
        gone.result()
    assert svc.stats["expired"] == 1 and svc.stats["cancelled"] == 1
    np.testing.assert_array_equal(live.result().ids,
                                  b.index.query(b.queries[0]).ids)


def test_service_latency_percentiles(anns_bundle):
    b = anns_bundle
    svc = BatchingANNSService(b.index, max_batch=4, max_wait_s=0.0,
                              scan_window=2, inflight_depth=2)
    futs = [svc.submit(SearchRequest(query=q)) for q in b.queries[:8]]
    svc.drain()
    pct = svc.latency_percentiles()
    assert pct["n"] == 8
    assert 0 < pct["p50"] <= pct["p99"]
    ref = np.stack([b.index.query(q).ids for q in b.queries[:8]])
    got = np.stack([f.result().ids for f in futs])
    np.testing.assert_array_equal(ref, got)
