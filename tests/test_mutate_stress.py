"""Updates-while-serving stress (PR 9, satellite S5 — DESIGN.md §10).

A mutator thread drives deterministic insert/delete bursts (with
compaction sealing concurrently) against (a) a threaded
:class:`BatchingANNSService` and (b) a 2-replica :class:`ReplicaRouter`
with a snapshot-hydrated third replica, while the main thread keeps
submitting queries.  The contract:

* every submitted future resolves — zero leaked futures;
* after quiescing, the stressed index answers BIT-IDENTICALLY to a
  fresh index that replayed the same mutation log serially (compaction
  timing must not change results, only when rows seal);
* ``save_snapshot`` → ``load_snapshot`` of the quiesced index is
  bit-identical too (checkpoint/restore parity);
* under ``LINT_LOCKS=1`` the autouse witness guard (conftest.py) fails
  the test on ANY lock-order violation recorded during the churn.

Wired as ``scripts/check.sh mutate-stress`` (which exports LINT_LOCKS=1)
and a CI step.
"""

import copy
import threading

import numpy as np
import pytest

from repro.core.engine import FusionANNSIndex
from repro.serve.client import SearchRequest

_ROUNDS = 10
_BATCH = 4


def _mutation_log(seed: int, dim: int, rounds: int = _ROUNDS,
                  batch: int = _BATCH):
    """Deterministic op list: ("insert", vecs) / ("delete", slots) where
    a slot indexes the cumulative insert order (valid in any replay)."""
    rng = np.random.default_rng(seed)
    ops, n_inserted = [], 0
    for _ in range(rounds):
        ops.append(("insert",
                    rng.normal(size=(batch, dim)).astype(np.float32)))
        n_inserted += batch
        if rng.random() < 0.7:
            k = int(rng.integers(1, 3))
            ops.append(("delete",
                        rng.integers(0, n_inserted, size=k).tolist()))
    return ops


def _apply(target, ops, *, compact_every: int = 0) -> None:
    """Replay ``ops`` against anything exposing insert()/delete() —
    a bare index or a router.  Deletes resolve slots via the ids the
    TARGET returned, so replays stay valid whatever the base size."""
    inserted: list = []
    for i, (kind, payload) in enumerate(ops):
        if kind == "insert":
            inserted.extend(int(x) for x in target.insert(payload))
        else:
            target.delete(np.array([inserted[s] for s in payload]))
        if compact_every and (i + 1) % compact_every == 0:
            target.compact(wait=True)


def _top1_sets(index: FusionANNSIndex, queries, k: int = 10):
    return [index.query(q, k=k) for q in queries]


def _assert_bit_identical(a: FusionANNSIndex, b: FusionANNSIndex, queries):
    for ra, rb in zip(_top1_sets(a, queries), _top1_sets(b, queries)):
        np.testing.assert_array_equal(ra.ids, rb.ids)
        np.testing.assert_array_equal(ra.dists, rb.dists)


# ---------------------------------------------------------------------------
# (a) threaded service + background compactor
# ---------------------------------------------------------------------------

def test_threaded_service_under_mutation_bursts(anns_bundle, fresh_index,
                                                tmp_path):
    from repro.serve.anns_service import BatchingANNSService
    b = anns_bundle
    index = fresh_index
    ops = _mutation_log(11, b.data.shape[1])
    svc = BatchingANNSService(index, threaded=True, max_batch=8,
                              max_wait_s=0.001)
    index.start_compactor(min_delta=6, poll_s=0.002)
    errors: list = []

    def mutate():
        try:
            _apply(index, ops)
        except BaseException as exc:   # noqa: BLE001 — surfaced below
            errors.append(exc)

    futs = []
    t = threading.Thread(target=mutate, name="mutator")
    t.start()
    try:
        while t.is_alive() or len(futs) < 24:
            futs.extend(svc.submit(SearchRequest(query=q, k=10))
                        for q in b.queries[:4])
            for f in futs[-4:]:
                f.result(timeout=60)   # serving keeps up during churn
        t.join(60)
        assert not t.is_alive()
    finally:
        t.join(60)
        index.stop_compactor(flush=True)
        svc.stop()
    assert not errors, errors
    # zero leaked futures: everything submitted resolved with real ids
    assert all(f.done() for f in futs)
    assert all(len(f.result().ids) == 10 for f in futs)
    assert svc.live_load() == 0

    # quiesced run parity: a fresh index replaying the same log serially
    # (single thread, one final seal) answers bit-identically
    replay = copy.deepcopy(b.index)
    _apply(replay, ops)
    replay.compact()
    assert index.delta_size == 0               # flush=True sealed the tail
    assert replay.n_total == index.n_total
    _assert_bit_identical(index, replay, b.queries)

    # checkpoint/restore parity on the stressed index
    index.save_snapshot(str(tmp_path / "stressed"))
    restored = FusionANNSIndex.load_snapshot(str(tmp_path / "stressed"))
    _assert_bit_identical(index, restored, b.queries)


# ---------------------------------------------------------------------------
# (b) 2-replica router + snapshot-hydrated newcomer
# ---------------------------------------------------------------------------

def test_router_under_mutation_bursts_with_hydrated_replica(
        anns_bundle, fresh_index, tmp_path):
    from repro.serve.router import ReplicaRouter
    b = anns_bundle
    ops = _mutation_log(13, b.data.shape[1])
    router = ReplicaRouter(fresh_index, n_replicas=2, threaded=True,
                           max_batch=8, max_wait_s=0.001,
                           snapshot_dir=str(tmp_path / "hydrate"))
    router.start()
    errors: list = []

    def mutate():
        try:
            # mutations flow through the ROUTER so the hydrated replica's
            # private index stays in lockstep; periodic compaction
            # exercises sealing mid-traffic on every replica
            _apply(router, ops, compact_every=5)
        except BaseException as exc:   # noqa: BLE001 — surfaced below
            errors.append(exc)

    futs = []
    try:
        slot = router.add_replica()    # hydrates from a live snapshot
        assert slot >= 2
        t = threading.Thread(target=mutate, name="mutator")
        t.start()
        try:
            while t.is_alive() or len(futs) < 24:
                futs.extend(router.submit(SearchRequest(query=q, k=10))
                            for q in b.queries[:4])
                for f in futs[-4:]:
                    f.result(timeout=60)
            t.join(60)
            assert not t.is_alive()
        finally:
            t.join(60)
        assert not errors, errors
        router.compact(wait=True)      # quiesce: seal every replica
        for f in futs:                 # zero leaked futures
            assert len(f.result(timeout=60).ids) == 10
        assert router.live_load() == 0
        roll = router.stats_rollup()
        assert roll["submitted"] == len(futs)
        assert roll["submitted"] == sum(roll["routed"]) + roll["rejected"]

        # every replica index (shared founders + hydrated private copy)
        # is in bit-identical lockstep after quiescing
        distinct = {id(ix): ix for ix in [router.index, *router.indexes]}
        assert len(distinct) == 2      # founders share; newcomer private
        ixs = list(distinct.values())
        assert ixs[0].n_total == ixs[1].n_total
        _assert_bit_identical(ixs[0], ixs[1], b.queries)

        # quiesced-run parity vs a serial replay of the same log
        replay = copy.deepcopy(b.index)
        _apply(replay, ops)
        replay.compact()
        _assert_bit_identical(router.index, replay, b.queries)

        # checkpoint/restore parity straight off the live router
        router.index.save_snapshot(str(tmp_path / "final"))
        restored = FusionANNSIndex.load_snapshot(str(tmp_path / "final"))
        _assert_bit_identical(router.index, restored, b.queries)
    finally:
        router.stop()


def test_mutations_through_router_reach_hydrated_replica(anns_bundle,
                                                         fresh_index,
                                                         tmp_path):
    """Focused (non-threaded) check of the fan-out itself: an insert and
    a delete issued AFTER hydration are visible — and identical — on the
    newcomer's private index."""
    from repro.serve.router import ReplicaRouter
    b = anns_bundle
    router = ReplicaRouter(fresh_index, n_replicas=1, threaded=False,
                           snapshot_dir=str(tmp_path / "h"))
    try:
        router.add_replica()
        new_ids = router.insert(b.new_vecs[:6])
        router.delete(new_ids[:2])
        router.compact(wait=True)
        priv = router.indexes[-1]
        assert priv is not router.index
        assert priv.epoch == router.index.epoch
        assert priv.n_total == router.index.n_total
        _assert_bit_identical(router.index, priv, b.new_vecs[:6])
        _assert_bit_identical(router.index, priv, b.queries)
    finally:
        router.stop()
