"""End-to-end FusionANNS engine: recall, the paper's I/O claims at reduced
scale, and technique ablations (Fig. 12 shape)."""

import numpy as np
import pytest

from repro.core.baselines import HIPq, RummyLike, SpannLike
from repro.core.engine import FusionANNSIndex, recall_at_k

DIM = 32


@pytest.fixture(scope="module")
def setup(anns_bundle):
    b = anns_bundle        # session-scoped shared index (conftest.py)
    return b.cfg, b.data, b.index, b.queries, b.gt


def test_recall_meets_paper_bar(setup):
    cfg, data, index, queries, gt = setup
    res = index.batch_query(queries)
    rec = recall_at_k(np.stack([r.ids for r in res]), gt, 10)
    assert rec >= 0.90        # paper's operating point Recall@10 >= 0.9


def test_h2d_is_ids_only(setup):
    """Multi-tiered index invariant: host->device traffic is 4 B per
    candidate id, never vector payload."""
    cfg, data, index, queries, gt = setup
    r = index.query(queries[0])
    assert r.stats.h2d_bytes == 4 * r.stats.candidates_scanned
    # SPANN-equivalent would ship whole posting lists (>= dim bytes/vec)
    assert r.stats.h2d_bytes < r.stats.candidates_scanned * DIM


def test_fusionanns_fewer_ios_than_spann(setup):
    """Fig. 12c: multi-tiered indexing cuts I/O vs SPANN (3.2-3.8x at 1B;
    directionally at reduced scale)."""
    cfg, data, index, queries, gt = setup
    spann = SpannLike(index, data)
    f_ios = np.mean([index.query(q).stats.ios for q in queries])
    s_ios = np.mean([spann.query(q, 10, cfg.top_m).io.pages_requested
                     for q in queries])
    assert f_ios < s_ios


def test_heuristic_rerank_cuts_ios(setup):
    cfg, data, index, queries, gt = setup
    with_hr = [index.query(q) for q in queries]
    without = [index.query(q, disable_early_stop=True) for q in queries]
    assert (np.mean([r.stats.ios for r in with_hr])
            <= np.mean([r.stats.ios for r in without]))
    # accuracy preserved
    rec_hr = recall_at_k(np.stack([r.ids for r in with_hr]), gt, 10)
    rec_full = recall_at_k(np.stack([r.ids for r in without]), gt, 10)
    assert rec_hr >= rec_full - 0.05


def test_dedup_cuts_ios(setup):
    cfg, data, index, queries, gt = setup
    no_dedup = FusionANNSIndex(
        cfg=index.cfg, codebook=index.codebook, codes=index.codes,
        posting=index.posting, graph=index.graph,
        ssd=_clone_ssd(index, intra=False, buf=False))
    ios_opt = np.mean([index.query(q).stats.ios for q in queries])
    ios_raw = np.mean([no_dedup.query(q).stats.ios for q in queries])
    assert ios_opt <= ios_raw


def _clone_ssd(index, intra, buf):
    from repro.core.io_sim import SSDSim
    return SSDSim(index.ssd.vectors, index.ssd.layout,
                  buffer_pages=index.cfg.dram_buffer_pages,
                  intra_merge=intra, use_buffer=buf)


def test_baselines_reach_similar_recall(setup):
    """All systems searched with the same top_m must find similar
    neighbours (they share the IVF index)."""
    cfg, data, index, queries, gt = setup
    spann = SpannLike(index, data)
    rummy = RummyLike(index, data)
    r_s = np.stack([spann.query(q, 10, cfg.top_m).ids for q in queries])
    r_r = np.stack([rummy.query(q, 10, cfg.top_m).ids for q in queries])
    assert recall_at_k(r_s, gt, 10) >= 0.9
    assert recall_at_k(r_r, gt, 10) >= 0.9


def test_rummy_moves_vectors_fusionanns_moves_ids(setup):
    cfg, data, index, queries, gt = setup
    rummy = RummyLike(index, data)
    rd = rummy.query(queries[0], 10, cfg.top_m).demand
    fr = index.query(queries[0]).stats
    assert rd.h2d_bytes > fr.h2d_bytes      # PCIe traffic gap (Fig. 4d)


def test_fused_batch_matches_per_query(setup):
    """Beyond-paper fused batch scan returns the same neighbours as the
    per-query path, while scanning the candidate UNION once."""
    cfg, data, index, queries, gt = setup
    per = index.batch_query(queries[:8])
    fused = index.query_batch_fused(queries[:8])
    from repro.core.engine import recall_at_k
    r_per = recall_at_k(np.stack([r.ids for r in per]), gt[:8], 10)
    r_fused = recall_at_k(np.stack([r.ids for r in fused]), gt[:8], 10)
    assert r_fused >= r_per - 0.03
    # inter-query dedup: union scanned once < sum of per-query scans
    union_scans = fused[0].stats.candidates_scanned      # same for all
    total_per = sum(r.stats.candidates_scanned for r in per)
    assert union_scans < total_per
