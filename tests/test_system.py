"""End-to-end behaviour of the paper's system: the three techniques compose
into the claimed profile (high recall, ID-only PCIe traffic, few small I/Os,
adaptive re-rank) — the system-level contract of FusionANNS."""

import numpy as np
import pytest

from repro.core.engine import recall_at_k
from repro.core.perf_model import DeviceModel, QueryDemand, sweep_threads


@pytest.fixture(scope="module")
def system(anns_bundle):
    b = anns_bundle        # session-scoped shared index (conftest.py)
    results = b.index.batch_query(b.queries)
    return b.cfg, b.data, b.index, b.queries, b.gt, results


def test_recall_at_operating_point(system):
    cfg, data, index, queries, gt, results = system
    rec = recall_at_k(np.stack([r.ids for r in results]), gt, 10)
    assert rec >= 0.90


def test_accuracy_scales_with_top_m(system):
    """Paper Fig. 10 mechanism: larger search space -> higher recall."""
    cfg, data, index, queries, gt, _ = system
    recs = []
    for top_m in (2, 8, 24):
        res = [index.query(q, top_m=top_m) for q in queries]
        recs.append(recall_at_k(np.stack([r.ids for r in res]), gt, 10))
    assert recs[0] <= recs[1] <= recs[2] + 0.02


def test_rerank_improves_over_pq_only(system):
    """Re-ranking must beat raw PQ ordering (the reason stage 8 exists)."""
    cfg, data, index, queries, gt, results = system
    import jax.numpy as jnp
    from repro.core import pq
    pq_only = []
    for q in queries:
        ids = index.candidate_ids(q, cfg.top_m)
        lut = pq.adc_lut(index.codebook, jnp.asarray(q))
        codes = jnp.take(index.codes, jnp.asarray(ids), axis=0)
        d = np.asarray(pq.adc_distances_ref(lut, codes))
        pq_only.append(ids[np.argsort(d)[:10]])
    rec_pq = recall_at_k(np.stack(pq_only), gt, 10)
    rec_full = recall_at_k(np.stack([r.ids for r in results]), gt, 10)
    assert rec_full >= rec_pq


def test_variance_of_min_rerank_depth(system):
    """Fig. 5b: different queries stabilise after very different numbers of
    mini-batches -> a static re-rank budget wastes work."""
    cfg, data, index, queries, gt, results = system
    batches = [r.stats.rerank_batches for r in results]
    assert max(batches) > min(batches)


def test_perf_model_reproduces_scaling_shapes(system):
    """SPANN-like (bandwidth-heavy) saturates at few threads; FusionANNS-like
    (few small I/Os) scales to 64 (paper Figs. 3 & 11)."""
    hw = DeviceModel()
    # SPANN at 1B scale: ~64 posting lists x ~48 KB sequential reads
    spann = QueryDemand(ssd_ios=1220, ssd_requests=64, ssd_bytes=5e6,
                        cpu_dist_ops=1e6, graph_hops=128)
    fusion = QueryDemand(ssd_ios=8, ssd_bytes=8 * 4096, h2d_bytes=4 * 3000,
                         gpu_lookups=3000 * 32, cpu_dist_ops=3e5,
                         graph_hops=128)
    s = sweep_threads(spann, hw)
    f = sweep_threads(fusion, hw)
    assert f[64]["qps"] > s[64]["qps"]            # headline claim
    # SPANN saturates (SSD bandwidth): QPS(64) ~ QPS(8)
    assert s[64]["qps"] < 1.5 * s[8]["qps"]
    # FusionANNS keeps scaling into high thread counts
    assert f[64]["qps"] > 3.0 * f[8]["qps"]


def test_storage_footprint_smaller_than_spann(system):
    """§4.1: FusionANNS stores raw vectors once; SPANN's replicated posting
    lists inflate SSD footprint by the replication factor."""
    cfg, data, index, queries, gt, results = system
    raw_bytes = data.nbytes
    spann_bytes = sum(len(m) for m in index.posting.members) * \
        (data.dtype.itemsize * data.shape[1] + 4)
    fusion_bytes = index.ssd.layout.n_pages * cfg.page_bytes
    assert fusion_bytes < spann_bytes
    assert fusion_bytes < 1.5 * raw_bytes         # near-raw footprint
