"""Dynamic-batching service + OPQ + cluster-glue tests."""

import dataclasses

import jax
import numpy as np
import pytest

from repro.configs.anns_datasets import SIFT_SMALL
from repro.core import opq, pq
from repro.core.engine import FusionANNSIndex, ground_truth, recall_at_k
from repro.data.synthetic import clustered_vectors
from repro.serve.anns_service import BatchingANNSService
from repro.serve.client import SearchRequest


@pytest.fixture(scope="module")
def small_index(anns_bundle):
    b = anns_bundle        # session-scoped shared index (conftest.py)
    return b.cfg, b.data, b.queries, b.index


def test_service_batches_and_answers(small_index):
    cfg, data, queries, index = small_index
    svc = BatchingANNSService(index, max_batch=8, max_wait_s=0.0)
    futs = [svc.submit(SearchRequest(query=q)) for q in queries]   # QueryFuture per request
    responses = svc.drain()
    assert len(responses) == len(queries)
    gt = ground_truth(data, queries, 10)
    # futures resolve to the same Response objects drain() returned
    by_rid = {r.rid: r for r in responses}
    for f in futs:
        assert f.done()
        assert f.result() is by_rid[f.tag]
    ids = np.stack([f.result().ids for f in futs])
    assert recall_at_k(ids, gt, 10) >= 0.9
    assert svc.stats["batches"] >= 2          # 20 queries / window 8
    assert all(r.batch_size <= 8 for r in responses)


def test_service_window_semantics(small_index):
    cfg, data, queries, index = small_index
    svc = BatchingANNSService(index, max_batch=64, max_wait_s=10.0)
    svc.submit(SearchRequest(query=queries[0]))
    assert svc.pump() == []                   # window not full, not timed out
    out = svc.pump(force=True)
    assert len(out) == 1


def test_opq_beats_plain_pq_reconstruction(rng):
    # anisotropic data (random linear map) — where OPQ should win
    base = clustered_vectors(rng, 1500, 32, n_clusters=12)
    A = rng.standard_normal((32, 32)).astype(np.float32)
    A[:, :8] *= 4.0                           # skew energy into few dims
    data = base @ A
    key = jax.random.key(0)
    import jax.numpy as jnp
    cb = pq.train_codebooks(key, jnp.asarray(data), m=8, iters=8)
    recon = np.asarray(pq.decode(cb, pq.encode(cb, jnp.asarray(data))))
    err_pq = float(np.mean(np.sum((data - recon) ** 2, -1)))
    ocb, _ = opq.train_opq(key, data, m=8, iters=4)
    err_opq = opq.reconstruction_error(ocb, data)
    assert err_opq <= err_pq * 1.02           # never meaningfully worse
    assert err_opq < err_pq                   # and better on skewed data


def test_opq_rotation_orthonormal(rng):
    data = clustered_vectors(rng, 800, 16, n_clusters=8)
    ocb, _ = opq.train_opq(jax.random.key(1), data, m=4, iters=3)
    rtr = ocb.rotation.T @ ocb.rotation
    np.testing.assert_allclose(rtr, np.eye(16), atol=1e-4)


def test_opq_adc_estimates_true_distance(rng):
    data = clustered_vectors(rng, 1000, 16, n_clusters=8)
    ocb, _ = opq.train_opq(jax.random.key(2), data, m=4, iters=3)
    codes = opq.encode(ocb, data)
    q = data[7]
    lut = opq.adc_lut(ocb, q)
    adc = np.asarray(pq.adc_distances_ref(lut, codes))
    exact = np.sum((data - q) ** 2, -1)
    top_exact = set(np.argsort(exact)[:10].tolist())
    top_adc = set(np.argsort(adc)[:30].tolist())
    assert len(top_exact & top_adc) >= 7


def test_cluster_glue_single_process():
    from repro.launch import cluster
    cluster.init_distributed()                # no env -> no-op
    start, size = cluster.host_batch_slice(64)
    assert (start, size) == (0, 64)
    assert cluster.is_coordinator()


def test_engine_with_opq_recall(rng):
    cfg = dataclasses.replace(SIFT_SMALL, n_vectors=3000, dim=32,
                              n_posting_fraction=0.02)
    data = clustered_vectors(rng, 3016, cfg.dim, n_clusters=24)
    idx = FusionANNSIndex.build(data[:3000], cfg, use_opq=True)
    gt = ground_truth(data[:3000], data[3000:], 10)
    res = idx.batch_query(data[3000:])
    assert recall_at_k(np.stack([r.ids for r in res]), gt, 10) >= 0.9
