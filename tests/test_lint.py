"""Tests for the concurrency static-analysis suite and runtime witness.

Each pass family is exercised against a seeded fixture violation:

* guarded-by (GB01/GB02) — good/bad field access under a declared lock;
* lock-order (LO01/LO02/LO03) — an edge against the hierarchy and a
  deliberately seeded acquisition cycle;
* purity (PU01/PU02/PU03) — device sync under a lock, side effects in a
  traced function, bare ``threading.Lock()``;
* suppressions (LT00) — a ``# lint-ok`` without a reason is itself a
  finding;

plus runtime tests of :class:`OrderedLock` (strict inversion raises,
re-entrancy allowed, Condition integration keeps the held-stack honest)
and a repo-clean test pinning ``run_checks(["src"])`` to zero findings.
"""

import os
import textwrap
import threading

import pytest

from repro.analysis.concurrency import run_checks
from repro.analysis.concurrency import guarded, lockorder, purity
from repro.analysis.concurrency.diagnostics import SourceFile
from repro.analysis.concurrency.witness import (HIERARCHY, LEVEL,
                                                LockOrderViolation,
                                                OrderedLock, Witness)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def sf(code: str, path: str = "fixture.py") -> SourceFile:
    return SourceFile(path, textwrap.dedent(code))


def codes(diags):
    return [d.code for d in diags]


# ---------------------------------------------------------------------------
# guarded-by (GB01 / GB02)
# ---------------------------------------------------------------------------

GOOD_GUARDED = """
    from repro.analysis.concurrency.witness import make_lock

    class Box:
        def __init__(self):
            self._lock = make_lock("service")
            self.items = []          # guarded-by: _lock

        def add(self, x):
            with self._lock:
                self.items.append(x)

        def _drain_locked(self):     # holds: _lock
            out, self.items = self.items, []
            return out
    """

BAD_GUARDED = """
    from repro.analysis.concurrency.witness import make_lock

    class Box:
        def __init__(self):
            self._lock = make_lock("service")
            self.items = []          # guarded-by: _lock

        def racy(self):
            return len(self.items)
    """


class TestGuardedBy:
    def test_clean_access_under_with_and_holds(self):
        assert guarded.check_file(sf(GOOD_GUARDED)) == []

    def test_unguarded_read_flagged(self):
        diags = guarded.check_file(sf(BAD_GUARDED))
        assert codes(diags) == ["GB01"]
        assert diags[0].line == 10
        assert "self.items" in diags[0].message
        assert "racy()" in diags[0].message

    def test_unguarded_write_reports_write(self):
        code = """
            from repro.analysis.concurrency.witness import make_lock

            class Box:
                def __init__(self):
                    self._lock = make_lock("service")
                    self.items = []          # guarded-by: _lock

                def smash(self):
                    self.items = []
            """
        diags = guarded.check_file(sf(code))
        assert codes(diags) == ["GB01"]
        assert "write" in diags[0].message

    def test_unknown_lock_is_gb02(self):
        code = """
            class Box:
                def __init__(self):
                    self.items = []   # guarded-by: _mutex
            """
        diags = guarded.check_file(sf(code))
        assert codes(diags) == ["GB02"]
        assert "_mutex" in diags[0].message

    def test_condition_aliases_its_lock(self):
        code = """
            from repro.analysis.concurrency.witness import (make_condition,
                                                            make_rlock)

            class Svc:
                def __init__(self):
                    self._lock = make_rlock("service")
                    self._cv = make_condition("service", self._lock)
                    self.queue = []          # guarded-by: _lock

                def put(self, x):
                    with self._cv:           # cv wraps _lock: same guard
                        self.queue.append(x)
            """
        assert guarded.check_file(sf(code)) == []

    def test_nested_def_does_not_inherit_held(self):
        code = """
            from repro.analysis.concurrency.witness import make_lock

            class Box:
                def __init__(self):
                    self._lock = make_lock("service")
                    self.items = []          # guarded-by: _lock

                def schedule(self):
                    with self._lock:
                        def later():         # runs on another thread
                            return self.items
                        return later
            """
        diags = guarded.check_file(sf(code))
        assert codes(diags) == ["GB01"]

    def test_multiline_declaration_annotation(self):
        code = """
            from repro.analysis.concurrency.witness import make_lock

            class Box:
                def __init__(self):
                    self._lock = make_lock("service")
                    self.stats = {"a": 0,
                                  "b": 0}    # guarded-by: _lock

                def bump(self):
                    with self._lock:
                        self.stats["a"] += 1
            """
        assert guarded.check_file(sf(code)) == []


# ---------------------------------------------------------------------------
# lock-order (LO01 / LO02 / LO03)
# ---------------------------------------------------------------------------

LO_INVERSION = """
    from repro.analysis.concurrency.witness import make_lock

    class Upside:
        def __init__(self):
            self._svc = make_lock("service")
            self._rtr = make_lock("router")

        def wrong(self):
            with self._svc:          # level 5
                with self._rtr:      # level 6: ascending — illegal
                    pass
    """


class TestLockOrder:
    def test_descending_nesting_clean(self):
        code = """
            from repro.analysis.concurrency.witness import make_lock

            class Fine:
                def __init__(self):
                    self._rtr = make_lock("router")
                    self._svc = make_lock("service")

                def ok(self):
                    with self._rtr:
                        with self._svc:
                            pass
            """
        assert lockorder.check_files([sf(code)]) == []

    def test_ascending_nesting_is_lo01(self):
        diags = lockorder.check_files([sf(LO_INVERSION)])
        assert "LO01" in codes(diags)
        lo01 = next(d for d in diags if d.code == "LO01")
        assert "'router'" in lo01.message and "'service'" in lo01.message

    def test_seeded_cycle_is_lo02(self):
        # service -> executor in one class, executor -> service in another:
        # both edges are individually checked, and together they cycle.
        code = """
            from repro.analysis.concurrency.witness import make_lock

            class A:
                def __init__(self):
                    self._svc = make_lock("service")
                    self._exe = make_lock("executor")

                def down(self):
                    with self._svc:
                        with self._exe:
                            pass

            class B:
                def __init__(self):
                    self._svc = make_lock("service")
                    self._exe = make_lock("executor")

                def up(self):
                    with self._exe:
                        with self._svc:
                            pass
            """
        diags = lockorder.check_files([sf(code)])
        assert "LO02" in codes(diags)
        lo02 = next(d for d in diags if d.code == "LO02")
        assert "->" in lo02.message
        # the ascending half of the cycle is also an LO01 in its own right
        assert "LO01" in codes(diags)

    def test_unknown_rank_is_lo03(self):
        code = """
            from repro.analysis.concurrency.witness import make_lock

            class Off:
                def __init__(self):
                    self._l = make_lock("warp-core")
            """
        diags = lockorder.check_files([sf(code)])
        assert codes(diags) == ["LO03"]
        assert "warp-core" in diags[0].message

    def test_cross_method_summary_edge(self):
        # helper() takes the service lock; outer() calls it under the
        # executor lock -> ascending executor->service edge via summary.
        code = """
            from repro.analysis.concurrency.witness import make_lock

            class Chain:
                def __init__(self):
                    self._exe = make_lock("executor")
                    self._svc = make_lock("service")

                def helper(self):
                    with self._svc:
                        pass

                def outer(self):
                    with self._exe:
                        self.helper()
            """
        diags = lockorder.check_files([sf(code)])
        assert "LO01" in codes(diags)

    def test_acquires_annotation_resolves_opaque_call(self):
        code = """
            from repro.analysis.concurrency.witness import make_lock

            class Ann:
                def __init__(self):
                    self._svc = make_lock("service")

                def wrong(self, other):
                    with self._svc:
                        other.poke()         # acquires: router
            """
        diags = lockorder.check_files([sf(code)])
        assert "LO01" in codes(diags)

    def test_lock_primitive_methods_not_resolved(self):
        # self._cond.wait() is Condition.wait, not some repo method named
        # "wait" — must not produce a spurious edge.
        code = """
            from repro.analysis.concurrency.witness import make_condition

            class Waiter:
                def __init__(self):
                    self._cond = make_condition("future")

                def park(self):
                    with self._cond:
                        self._cond.wait(0.01)

            class Decoy:
                def __init__(self):
                    self._l = make_condition("router")

                def wait(self):
                    with self._l:
                        pass
            """
        assert lockorder.check_files([sf(code)]) == []


# ---------------------------------------------------------------------------
# purity (PU01 / PU02 / PU03)
# ---------------------------------------------------------------------------

SYNC_UNDER_LOCK = """
    import numpy as np
    from repro.analysis.concurrency.witness import make_lock

    class Stats:
        def __init__(self):
            self._lock = make_lock("service")
            self.lat = []

        def percentile(self):
            with self._lock:
                arr = np.asarray(self.lat)
            return arr
    """


class TestPurity:
    def test_sync_under_lock_is_pu01(self):
        diags = purity.check_file(sf(SYNC_UNDER_LOCK))
        assert codes(diags) == ["PU01"]
        assert diags[0].line == 12

    def test_snapshot_then_materialize_clean(self):
        code = """
            import numpy as np
            from repro.analysis.concurrency.witness import make_lock

            class Stats:
                def __init__(self):
                    self._lock = make_lock("service")
                    self.lat = []

                def percentile(self):
                    with self._lock:
                        snap = list(self.lat)
                    return np.asarray(snap)
            """
        assert purity.check_file(sf(code)) == []

    def test_item_under_holds_is_pu01(self):
        code = """
            from repro.analysis.concurrency.witness import make_lock

            class Stats:
                def __init__(self):
                    self._lock = make_lock("service")

                def peek(self, x):           # holds: _lock
                    return x.item()
            """
        assert codes(purity.check_file(sf(code))) == ["PU01"]

    def test_traced_side_effect_is_pu02(self):
        code = """
            import jax

            @jax.jit
            def _distance_kernel(q, base):
                print("tracing")
                return q @ base.T
            """
        diags = purity.check_file(sf(code, "src/repro/kernels/fx.py"),
                                  jit_scope=True)
        assert codes(diags) == ["PU02"]
        assert "print" in diags[0].message

    def test_lock_in_traced_fn_is_pu02(self):
        code = """
            import jax

            @jax.jit
            def _scan_kernel(q, lut, lock):
                with lock:
                    return q + lut
            """
        # "lock" matches the lock-ish name fragments
        diags = purity.check_file(sf(code, "src/repro/kernels/fx.py"),
                                  jit_scope=True)
        assert codes(diags) == ["PU02"]

    def test_pure_kernel_clean(self):
        code = """
            import jax
            import jax.numpy as jnp

            @jax.jit
            def _adc_kernel(lut, codes):
                return jnp.take_along_axis(lut, codes, axis=0).sum(0)
            """
        assert purity.check_file(sf(code, "src/repro/kernels/fx.py"),
                                 jit_scope=True) == []

    def test_bare_threading_lock_is_pu03(self):
        code = """
            import threading

            class Old:
                def __init__(self):
                    self._lock = threading.Lock()
            """
        diags = purity.check_file(sf(code))
        assert codes(diags) == ["PU03"]
        assert "make_lock" in diags[0].message

    def test_witness_module_exempt_from_pu03(self):
        code = """
            import threading

            def make_lock(rank):
                return threading.Lock()
            """
        path = os.path.join("src", "repro", "analysis", "concurrency",
                            "witness.py")
        assert purity.check_file(sf(code, path)) == []

    def test_threading_event_not_flagged(self):
        code = """
            import threading

            class Loop:
                def __init__(self):
                    self._stop = threading.Event()
            """
        assert purity.check_file(sf(code)) == []


# ---------------------------------------------------------------------------
# suppressions (LT00)
# ---------------------------------------------------------------------------

class TestSuppressions:
    def _box(self, tmp_path, lint_ok: str):
        code = textwrap.dedent(f"""
            from repro.analysis.concurrency.witness import make_lock

            class Box:
                def __init__(self):
                    self._lock = make_lock("service")
                    self.state = 0           # guarded-by: _lock

                def fast(self):
                    {lint_ok}
                    return self.state
            """)
        p = tmp_path / "box.py"
        p.write_text(code)
        return str(p)

    def test_reasoned_suppression_silences(self, tmp_path):
        path = self._box(tmp_path,
                         "# lint-ok: GB01 monotonic word, torn read benign")
        assert run_checks([path]) == []

    def test_reasonless_suppression_is_lt00(self, tmp_path):
        path = self._box(tmp_path, "# lint-ok: GB01")
        diags = run_checks([path])
        assert codes(diags) == ["LT00"]
        assert "reason" in diags[0].message

    def test_wrong_code_does_not_suppress(self, tmp_path):
        path = self._box(tmp_path, "# lint-ok: PU01 not the right code")
        diags = run_checks([path])
        assert "GB01" in codes(diags)

    def test_syntax_error_is_lt01(self, tmp_path):
        p = tmp_path / "broken.py"
        p.write_text("def nope(:\n")
        diags = run_checks([str(p)])
        assert codes(diags) == ["LT01"]


# ---------------------------------------------------------------------------
# runtime witness
# ---------------------------------------------------------------------------

class TestOrderedLock:
    def test_strict_inversion_raises(self):
        w = Witness(strict=True)
        svc = OrderedLock("service", w)
        rtr = OrderedLock("router", w)
        with rtr:            # descending: fine
            with svc:
                pass
        with svc:
            with pytest.raises(LockOrderViolation):
                rtr.acquire()

    def test_record_mode_collects_and_drains(self):
        w = Witness(strict=False)
        svc = OrderedLock("service", w)
        rtr = OrderedLock("router", w)
        with svc:
            with rtr:        # ascending, recorded not raised
                pass
        bad = w.drain_violations()
        assert len(bad) == 1
        assert bad[0]["acquiring"] == "router"
        assert bad[0]["held"] == ["service"]
        assert w.drain_violations() == []
        assert ("service", "router") in w.witnessed_edges()

    def test_same_rank_nesting_violates(self):
        w = Witness(strict=True)
        a = OrderedLock("service", w)
        b = OrderedLock("service", w)
        with a:
            with pytest.raises(LockOrderViolation):
                b.acquire()

    def test_rlock_reentrancy_allowed(self):
        w = Witness(strict=True)
        lk = OrderedLock("ticket", w, reentrant=True)
        with lk:
            with lk:         # same object: re-entrant, never an inversion
                assert w.held_count(lk) == 2
        assert w.held_count(lk) == 0
        assert w.drain_violations() == []

    def test_condition_wait_releases_held_stack(self):
        w = Witness(strict=True)
        lk = OrderedLock("service", w, reentrant=True)
        cond = threading.Condition(lk)
        hits = []

        def waiter():
            with cond:
                while not hits:
                    cond.wait(1.0)
                # after wake the lock must be re-held at full depth
                hits.append(w.held_count(lk))

        t = threading.Thread(target=waiter)
        t.start()
        # while the waiter is parked, this thread can take the lock
        with cond:
            hits.append(1)
            cond.notify_all()
        t.join(5.0)
        assert not t.is_alive()
        assert hits == [1, 1]
        assert w.drain_violations() == []

    def test_unknown_rank_rejected(self):
        with pytest.raises(ValueError):
            OrderedLock("warp-core")

    def test_hierarchy_shape(self):
        assert HIERARCHY[0] == "future" and HIERARCHY[-1] == "autoscaler"
        assert LEVEL["ticket"] < LEVEL["executor"] < LEVEL["service"] \
            < LEVEL["router"] < LEVEL["autoscaler"]
        # PR 9: the inflight queue got its own rank between ticket and
        # executor, and compaction slots below service so the sealing
        # thread can never wait on a lock a pump thread holds
        assert LEVEL["ticket"] < LEVEL["inflight"] < LEVEL["executor"]
        assert LEVEL["coalescer"] < LEVEL["compaction"] < LEVEL["service"]


class TestFactories:
    def test_plain_primitives_when_disabled(self, monkeypatch):
        monkeypatch.delenv("LINT_LOCKS", raising=False)
        from repro.analysis.concurrency.witness import (make_condition,
                                                        make_lock,
                                                        make_rlock)
        assert not isinstance(make_lock("service"), OrderedLock)
        assert isinstance(make_condition("service"), threading.Condition)
        rl = make_rlock("ticket")
        rl.acquire(); rl.acquire(); rl.release(); rl.release()

    def test_ordered_when_enabled(self, monkeypatch):
        monkeypatch.setenv("LINT_LOCKS", "1")
        from repro.analysis.concurrency.witness import (make_condition,
                                                        make_lock)
        lk = make_lock("service")
        assert isinstance(lk, OrderedLock) and not lk._reentrant
        cond = make_condition("service")
        assert isinstance(cond, threading.Condition)

    def test_unknown_rank_rejected_even_disabled(self, monkeypatch):
        monkeypatch.delenv("LINT_LOCKS", raising=False)
        from repro.analysis.concurrency.witness import make_lock
        with pytest.raises(ValueError):
            make_lock("warp-core")


# ---------------------------------------------------------------------------
# the repo itself
# ---------------------------------------------------------------------------

class TestRepoClean:
    def test_src_tree_is_clean(self):
        diags = run_checks([os.path.join(REPO, "src")])
        assert diags == [], "\n".join(map(str, diags))

    def test_serving_stack_is_annotated(self):
        """Non-vacuity: the passes must actually SEE the serving stack —
        guarded fields on every stateful class and real descending edges."""
        from repro.analysis.concurrency import collect_files
        from repro.analysis.concurrency.guarded import (_guarded_fields,
                                                        collect_class_locks)
        import ast
        n_fields = 0
        classes = set()
        for path in collect_files([os.path.join(REPO, "src", "repro")]):
            sf_ = SourceFile.load(path)
            if sf_.tree is None:
                continue
            for cls in [n for n in ast.walk(sf_.tree)
                        if isinstance(n, ast.ClassDef)]:
                locks = collect_class_locks(cls)
                fields, _ = _guarded_fields(cls, sf_, locks)
                if fields:
                    n_fields += len(fields)
                    classes.add(cls.name)
        assert n_fields >= 30
        assert {"QueryFuture", "BatchTicket", "QueryExecutor",
                "BatchingANNSService", "ReplicaRouter",
                "ReplicaAutoscaler", "FusionANNSIndex",
                "_InflightQueue"} <= classes

    def test_real_edges_descend(self):
        from repro.analysis.concurrency import collect_files
        files = collect_files([os.path.join(REPO, "src", "repro")])
        sources = [SourceFile.load(p) for p in files]
        diags = []
        edges = lockorder.extract_edges(sources, diags)
        pairs = {(o, i) for o, i, _, _ in edges if o != i}
        assert ("service", "future") in pairs
        assert ("router", "service") in pairs
        # PR 9 non-vacuity: the inflight-queue lock really nests the
        # ticket busy-accounting inside it, and the router really holds
        # its lock across compaction fan-out / snapshot hydration
        assert ("inflight", "ticket") in pairs
        assert ("router", "compaction") in pairs
        assert all(LEVEL[i] < LEVEL[o] for o, i in pairs)
