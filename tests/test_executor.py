"""Unified QueryExecutor: the three public query paths are one pipeline.

Contract under test (ISSUE 1 acceptance):
* ``query`` / ``batch_query`` / ``query_batch_fused`` return IDENTICAL ids
  (not merely similar recall) on a fixed seed — they are windows of the
  same stage list;
* the mesh-sharded ADC scan (>= 2 devices via the host platform override)
  matches the single-device scan exactly;
* window splitting and rerank/scan overlap never change results;
* shared QueryStats accounting invariants hold at every window size.
"""

import json
import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.engine import recall_at_k
from repro.core.executor import QueryPlan


@pytest.fixture(scope="module")
def paths(anns_bundle):
    b = anns_bundle
    single = [b.index.query(q) for q in b.queries]
    batch = b.index.batch_query(b.queries)
    fused = b.index.query_batch_fused(b.queries)
    return b, single, batch, fused


def test_three_paths_identical_ids(paths):
    b, single, batch, fused = paths
    for s, bb, f in zip(single, batch, fused):
        np.testing.assert_array_equal(s.ids, bb.ids)
        np.testing.assert_array_equal(s.ids, f.ids)
        np.testing.assert_allclose(s.dists, f.dists, rtol=0, atol=0)


def test_three_paths_recall(paths):
    b, single, batch, fused = paths
    recs = [recall_at_k(np.stack([r.ids for r in res]), b.gt, 10)
            for res in (single, batch, fused)]
    assert all(r >= 0.90 for r in recs)
    assert max(recs) - min(recs) < 1e-9     # identical ids => identical recall


def test_window_and_overlap_parity(paths):
    b, single, batch, fused = paths
    for window, overlap in ((4, False), (4, True), (7, True)):
        res = b.index.executor.run(
            b.queries, b.index.plan(window=window, overlap_rerank=overlap))
        for f, r in zip(single, res):
            np.testing.assert_array_equal(f.ids, r.ids)


def test_stats_accounting_invariants(paths):
    b, single, batch, fused = paths
    for s in single:        # window of 1: ids-only H2D, own candidates only
        assert s.stats.h2d_bytes == 4 * s.stats.candidates_scanned
    u = fused[0].stats.candidates_scanned
    assert all(f.stats.candidates_scanned == u for f in fused)
    # inter-query dedup: union scanned once < sum of per-query scans
    assert u < sum(s.stats.candidates_scanned for s in single)
    B = len(fused)
    assert fused[0].stats.h2d_bytes == 4 * u // B


def test_masked_topk_batch_matches_reference(rng):
    """pq_adc_topk_batch (the executor's single-device scan) == brute ref."""
    from repro.kernels.pq_adc.ops import pq_adc_topk_batch
    from repro.kernels.pq_adc.ref import pq_adc_batch_ref
    codes = jnp.asarray(rng.integers(0, 256, (512, 8)), jnp.uint8)
    luts = jnp.asarray(rng.random((3, 8, 256)), jnp.float32)
    mask = jnp.asarray(rng.random((3, 512)) < 0.5)
    vals, pos = pq_adc_topk_batch(codes, luts, 32, mask=mask,
                                  use_kernel=False)
    ref = np.asarray(pq_adc_batch_ref(codes, luts))
    ref = np.where(np.asarray(mask), ref, np.inf)
    for qb in range(3):
        expect = np.sort(ref[qb])[:32]
        np.testing.assert_allclose(np.sort(np.asarray(vals[qb])), expect,
                                   rtol=1e-6)
        np.testing.assert_allclose(
            np.sort(ref[qb][np.asarray(pos[qb])]), expect, rtol=1e-6)


_SHARDED_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import sys
sys.path.insert(0, sys.argv[1])
import dataclasses, json
import numpy as np
from repro.configs.anns_datasets import SIFT_SMALL
from repro.core.engine import FusionANNSIndex
from repro.data.synthetic import clustered_vectors
from repro.launch.mesh import make_test_mesh

rng = np.random.default_rng(0)
cfg = dataclasses.replace(SIFT_SMALL, n_vectors=800, dim=32,
                          n_posting_fraction=0.02)
data = clustered_vectors(rng, 808, 32, n_clusters=8)
index = FusionANNSIndex.build(data[:800], cfg)
queries = data[800:]

base = index.query_batch_fused(queries)
index.executor.attach_mesh(make_test_mesh(2))
assert index.executor._n_shards() == 2
sharded = index.query_batch_fused(queries)
singles = [index.query(q) for q in queries]     # sharded window-of-1

out = {"ids_exact": True, "dists_exact": True, "single_exact": True}
for b, s, one in zip(base, sharded, singles):
    out["ids_exact"] &= bool(np.array_equal(b.ids, s.ids))
    out["dists_exact"] &= bool(np.array_equal(b.dists, s.dists))
    out["single_exact"] &= bool(np.array_equal(b.ids, one.ids))
print(json.dumps(out))
"""


@pytest.fixture(scope="module")
def sharded_results():
    """mesh >= 2 needs the host platform override BEFORE jax import."""
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-c", _SHARDED_SCRIPT, os.path.abspath(src)],
        capture_output=True, text=True, timeout=600, env=env)
    assert proc.returncode == 0, proc.stderr[-3000:]
    return json.loads(proc.stdout.strip().splitlines()[-1])


@pytest.mark.parametrize("key", ["ids_exact", "dists_exact", "single_exact"])
def test_sharded_scan_matches_single_device(sharded_results, key):
    assert sharded_results[key], sharded_results
