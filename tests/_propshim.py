"""Property-testing shim: use ``hypothesis`` when installed, else a tiny
deterministic fallback with the same surface (tier-1 must collect and pass
without the package — see conftest.py for the policy).

The fallback implements exactly the strategy subset this suite uses —
``st.integers(lo, hi)``, ``st.sampled_from(seq)``, ``st.lists(elem,
min_size=, max_size=)`` — and draws a fixed number of examples from a
seeded generator keyed on the test's qualified name, so failures
reproduce run-to-run.  ``@settings(max_examples=N)`` is honored (capped
by ``PROPSHIM_MAX_EXAMPLES``, default 10, to keep tier-1 fast).
"""

from __future__ import annotations

try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import given, settings, strategies  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    import functools
    import inspect
    import os
    import zlib

    import numpy as np

    HAVE_HYPOTHESIS = False
    _MAX = int(os.environ.get("PROPSHIM_MAX_EXAMPLES", "8"))

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example(self, rng):
            return self._draw(rng)

    class strategies:  # noqa: N801 - mimics the hypothesis module name
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def sampled_from(options):
            opts = list(options)
            return _Strategy(lambda rng: opts[int(rng.integers(len(opts)))])

        @staticmethod
        def lists(elem, min_size=0, max_size=10):
            def draw(rng):
                n = int(rng.integers(min_size, max_size + 1))
                return [elem.example(rng) for _ in range(n)]
            return _Strategy(draw)

    def settings(max_examples=10, deadline=None, **_kw):
        def deco(fn):
            fn._propshim_max_examples = max_examples
            return fn
        return deco

    def given(**strats):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                # @settings sits above @given, so read the cap at call time
                n = min(getattr(wrapper, "_propshim_max_examples", 10), _MAX)
                rng = np.random.default_rng(
                    zlib.crc32(fn.__qualname__.encode()))
                for _ in range(n):
                    drawn = {k: s.example(rng) for k, s in strats.items()}
                    fn(*args, **drawn, **kwargs)
            # pytest must not mistake drawn params for fixtures: expose a
            # signature without them (and without __wrapped__, which
            # inspect.signature would otherwise follow).
            del wrapper.__wrapped__
            sig = inspect.signature(fn)
            wrapper.__signature__ = sig.replace(parameters=[
                p for name, p in sig.parameters.items()
                if name not in strats])
            return wrapper
        return deco
