"""Multi-replica routing over one mesh (ISSUE 4 acceptance).

Contract under test:
* every routing policy (round_robin / jsq / deadline) returns
  BIT-IDENTICAL ids to a single-replica ``run()`` — routing is a
  scheduling choice, never a result knob;
* 8 producer threads across 2 threaded replicas: id parity, zero leaked
  futures after ``stop()``, empty queues on every replica;
* JSQ probe: a saturated replica (its serve path gated on an event, so
  the probe does not depend on scheduler luck) is bypassed — all routed
  traffic lands on the idle replica;
* deadline policy: a request carrying a deadline spills to the
  least-loaded replica while deadline-free traffic follows round-robin
  into the loaded one;
* the fig9 ``router_jsq`` model: QPS on the demand measured THROUGH the
  router increases strictly monotonically from 1 -> 2 -> 4 replicas;
* updates propagate to every replica (test_updates semantics under
  routing);
* ``split_mesh`` carves one mesh into disjoint device groups and the
  routed sub-mesh scan matches the single-device scan exactly
  (subprocess with forced host devices, like test_executor's).
"""

import json
import os
import subprocess
import sys
import threading
import time

import numpy as np
import pytest

from repro.core.futures import BackpressureError
from repro.core.perf_model import DeviceModel, sweep_replicas
from repro.serve.client import SearchRequest
from repro.serve.router import POLICIES, ReplicaRouter


# ------------------------------------------------------------------ parity

@pytest.mark.parametrize("policy", POLICIES)
def test_policy_parity_with_single_replica_run(anns_bundle, policy):
    """Each policy, mixed k, 2 sync replicas: ids == index.query()."""
    b = anns_bundle
    ks = [1, 3, 5, 7, 10, 2, 4, 6]
    router = ReplicaRouter(b.index, n_replicas=2, policy=policy,
                           threaded=False, max_batch=4, max_wait_s=0.0)
    futs = [router.submit(SearchRequest(
                query=q, k=ks[i % len(ks)],
                deadline_s=30.0 if i % 2 else None))
            for i, q in enumerate(b.queries)]
    router.drain()
    for i, (q, f) in enumerate(zip(b.queries, futs)):
        np.testing.assert_array_equal(
            f.result().ids,
            b.index.query(q, k=ks[i % len(ks)]).ids)
    roll = router.stats_rollup()
    assert sum(roll["routed"]) == len(b.queries)
    assert roll["requests"] == len(b.queries)
    # the QueryStats rollup saw every request's re-rank traffic
    assert roll["query_stats"]["ios"] > 0
    assert roll["query_stats"]["rerank_scored"] > 0


def test_round_robin_spreads_evenly(anns_bundle):
    b = anns_bundle
    router = ReplicaRouter(b.index, n_replicas=2, policy="round_robin",
                           threaded=False, max_batch=4, max_wait_s=0.0)
    for q in b.queries[:8]:
        router.submit(SearchRequest(query=q))
    assert router.stats_rollup()["routed"] == [4, 4]
    router.drain()


# ------------------------------------------------------------------ stress

def test_router_stress_8_producers_2_replicas_zero_leaks(anns_bundle):
    b = anns_bundle
    n_producers, per_producer = 8, 5
    ks = [1, 3, 5, 10, 2, 7, 4, 6]
    router = ReplicaRouter(b.index, n_replicas=2, policy="jsq",
                           threaded=True, max_batch=8, max_wait_s=0.002,
                           scan_window=2, inflight_depth=2)
    futures = {}
    errors = []

    def producer(tid):
        for i in range(per_producer):
            qi = (tid * per_producer + i) % len(b.queries)
            k = ks[(tid + i) % len(ks)]
            while True:
                try:
                    futures[(tid, i)] = (qi, k, router.submit(
                        SearchRequest(query=b.queries[qi], k=k)))
                    break
                except BackpressureError:
                    time.sleep(1e-3)

    threads = [threading.Thread(target=producer, args=(t,))
               for t in range(n_producers)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    results = {}
    for key, (qi, k, fut) in futures.items():
        try:
            results[key] = (qi, k, fut.result(timeout=120).ids)
        except Exception as exc:              # noqa: BLE001 — fail the test
            errors.append((key, exc))
    assert not errors, errors
    router.stop()

    # bit-identical ids to the single-replica synchronous path
    for qi, k, ids in results.values():
        np.testing.assert_array_equal(ids, b.index.query(b.queries[qi],
                                                         k=k).ids)
    # zero leaked futures / requests anywhere after the fan-out drain
    assert all(fut.done() for _, _, fut in futures.values())
    for svc in router.replicas:
        assert not svc._queue and svc._serving == 0
        assert svc._pump_thread is None and svc._ticker_thread is None
    assert sum(router.stats_rollup()["routed"]) == n_producers * per_producer


# --------------------------------------------------------------- JSQ probe

def test_jsq_bypasses_saturated_replica(anns_bundle):
    """Gate replica 0's serve path on an event, park 3 live requests on
    it, then route through JSQ: every routed request must land on the
    idle replica 1 (live-request count, not round-robin)."""
    b = anns_bundle
    router = ReplicaRouter(b.index, n_replicas=2, policy="jsq",
                           threaded=True, max_batch=4, max_wait_s=0.001)
    svc0 = router.replicas[0]
    started, release = threading.Event(), threading.Event()
    orig = svc0._serve_batch_inner

    def gated(batch):
        started.set()
        assert release.wait(timeout=60)
        return orig(batch)

    svc0._serve_batch_inner = gated
    try:
        # saturate replica 0 below the router (its pump blocks in `gated`,
        # so its live_load stays at 3 for the whole probe)
        pre = [svc0.submit(SearchRequest(query=b.queries[i])) for i in range(3)]
        assert started.wait(timeout=60)
        assert svc0.live_load() == 3
        routed = []
        for q in b.queries[3:7]:
            fut = router.submit(SearchRequest(query=q))
            routed.append((q, fut.result(timeout=60).ids))
    finally:
        release.set()
    for f in pre:
        f.result(timeout=60)
    router.stop()
    assert router.stats_rollup()["routed"] == [0, 4]
    for q, ids in routed:
        np.testing.assert_array_equal(ids, b.index.query(q).ids)


def test_deadline_policy_spills_to_least_loaded(anns_bundle):
    """Deadline traffic jumps the round-robin line to the least-loaded
    replica; deadline-free traffic follows round-robin into the loaded
    one (sync harness: queues only drain when we say so)."""
    b = anns_bundle
    router = ReplicaRouter(b.index, n_replicas=2, policy="deadline",
                           threaded=False, max_batch=8, max_wait_s=10.0)
    # park 3 live requests on replica 0, below the router
    pre = [router.replicas[0].submit(SearchRequest(query=q)) for q in b.queries[:3]]
    # round-robin cursor is at 0, but the deadline spills to replica 1
    spilled = router.submit(SearchRequest(query=b.queries[3], deadline_s=30.0))
    assert router.stats_rollup()["routed"] == [0, 1]
    assert router.stats_rollup()["deadline_spills"] == 1
    # deadline-free traffic keeps round-robin order: cursor moved to 1,
    # then wraps INTO the loaded replica 0
    router.submit(SearchRequest(query=b.queries[4]))
    router.submit(SearchRequest(query=b.queries[5]))
    assert router.stats_rollup()["routed"] == [1, 2]
    router.drain()
    np.testing.assert_array_equal(spilled.result().ids,
                                  b.index.query(b.queries[3]).ids)
    for q, f in zip(b.queries[:3], pre):
        np.testing.assert_array_equal(f.result().ids,
                                      b.index.query(q).ids)


# ---------------------------------------------------------- backpressure

def test_router_spills_on_backpressure_then_rejects(anns_bundle):
    b = anns_bundle
    router = ReplicaRouter(b.index, n_replicas=2, policy="round_robin",
                           threaded=False, max_batch=8, max_wait_s=10.0,
                           max_queue=1)
    a = router.submit(SearchRequest(query=b.queries[0]))           # replica 0
    c = router.submit(SearchRequest(query=b.queries[1]))           # replica 1 (rr)
    assert router.stats_rollup()["routed"] == [1, 1]
    with pytest.raises(BackpressureError, match="all 2 replicas"):
        router.submit(SearchRequest(query=b.queries[2]))
    roll = router.stats_rollup()
    assert roll["rejected"] == 1
    router.drain()
    assert a.done() and c.done()
    # slots freed: admission works again
    d = router.submit(SearchRequest(query=b.queries[2]))
    router.drain()
    np.testing.assert_array_equal(d.result().ids,
                                  b.index.query(b.queries[2]).ids)


def test_spill_exhausted_counter_and_accounting_invariant(anns_bundle):
    """A spill chain that exhausts EVERY replica counts as
    ``spill_exhausted``, and the router's books always balance:
    ``submitted == sum(routed) + rejected`` — every submit() call is
    accounted exactly once, landed or rejected."""
    b = anns_bundle
    router = ReplicaRouter(b.index, n_replicas=2, policy="round_robin",
                           threaded=False, max_batch=8, max_wait_s=10.0,
                           max_queue=1)
    router.submit(SearchRequest(query=b.queries[0]))
    router.submit(SearchRequest(query=b.queries[1]))
    for _ in range(3):                       # every replica full: reject
        with pytest.raises(BackpressureError):
            router.submit(SearchRequest(query=b.queries[2]))
    roll = router.stats_rollup()
    assert roll["rejected"] == 3
    assert roll["spill_exhausted"] == 3
    assert roll["submitted"] == sum(roll["routed"]) + roll["rejected"]
    router.drain()
    router.submit(SearchRequest(query=b.queries[2]))
    router.drain()
    roll = router.stats_rollup()
    assert roll["submitted"] == sum(roll["routed"]) + roll["rejected"] == 6


# ------------------------------------------------------------- elastic set

def test_add_and_remove_replica_round_trip(anns_bundle):
    """Grow 2 -> 3, serve on all three, shrink back: stable slot ids,
    growing routed ledger, drained victim, and the accounting invariant
    across the whole scaling history."""
    b = anns_bundle
    router = ReplicaRouter(b.index, n_replicas=2, policy="round_robin",
                           threaded=False, max_batch=4, max_wait_s=0.0)
    slot = router.add_replica()
    assert slot == 2 and router.n_replicas == 3
    assert router.replica_ids == [0, 1, 2]
    futs = [router.submit(SearchRequest(query=q)) for q in b.queries[:9]]
    router.drain()
    assert router.stats_rollup()["routed"] == [3, 3, 3]
    removed = router.remove_replica()         # least-loaded: all idle -> 0
    assert removed == 0 and router.n_replicas == 2
    assert router.replica_ids == [1, 2]
    more = [router.submit(SearchRequest(query=q)) for q in b.queries[9:13]]
    router.drain()
    roll = router.stats_rollup()
    assert roll["routed"] == [3, 5, 5]        # slot 0 frozen, 1/2 grew
    assert roll["submitted"] == sum(roll["routed"]) + roll["rejected"]
    assert roll["scale_ups"] == 1 and roll["scale_downs"] == 1
    # percentiles still describe the whole stream (retired history kept)
    assert roll["requests"] == 13
    for q, f in zip(b.queries, futs + more):
        np.testing.assert_array_equal(f.result().ids,
                                      b.index.query(q).ids)
    with pytest.raises(ValueError, match="no replica with slot id"):
        router.remove_replica(0)              # already gone
    router.remove_replica(1)
    with pytest.raises(ValueError, match="last replica"):
        router.remove_replica()


def test_remove_replica_drains_victim_zero_leaks(anns_bundle):
    """Removal under live traffic: requests parked on the victim resolve
    (its pump drains them before exit) and no future leaks anywhere."""
    b = anns_bundle
    router = ReplicaRouter(b.index, n_replicas=2, policy="round_robin",
                           threaded=True, max_batch=4, max_wait_s=0.001)
    futs = [router.submit(SearchRequest(query=q)) for q in b.queries[:8]]
    victim_slot = router.remove_replica(0)
    assert victim_slot == 0 and router.n_replicas == 1
    for q, f in zip(b.queries[:8], futs):
        np.testing.assert_array_equal(f.result(timeout=120).ids,
                                      b.index.query(q).ids)
    assert all(f.done() for f in futs)
    router.stop()
    roll = router.stats_rollup()
    assert roll["requests"] == 8              # retired history folded in
    assert roll["submitted"] == sum(roll["routed"]) + roll["rejected"]
    assert router.latency_percentiles()["n"] == 8


def test_scaling_signals_snapshot(anns_bundle):
    b = anns_bundle
    router = ReplicaRouter(b.index, n_replicas=2, policy="jsq",
                           threaded=False, max_batch=8, max_wait_s=10.0)
    router.submit(SearchRequest(query=b.queries[0]))
    sig = router.scaling_signals()
    assert sig["n_replicas"] == 2 and sig["live_load"] == 1
    assert len(sig["per_replica_load"]) == 2
    assert sig["submitted"] == 1 and sig["rejected"] == 0
    router.drain()
    sig = router.scaling_signals()
    assert sig["live_load"] == 0 and sig["latency_n"] == 1


def test_recarve_mesh_unequal_groups():
    """recarve_mesh relaxes split_mesh's divisibility: 1 device still
    carves only into 1 group, and bad counts raise."""
    from repro.launch.mesh import make_test_mesh, recarve_mesh
    mesh = make_test_mesh(1)
    assert recarve_mesh(mesh, 1) == [mesh]
    with pytest.raises(ValueError, match="cannot carve"):
        recarve_mesh(mesh, 2)
    with pytest.raises(ValueError, match="n_groups"):
        recarve_mesh(mesh, 0)


# ------------------------------------------------------ fig9 replica model

def test_router_jsq_qps_model_monotonic_in_replicas(anns_bundle):
    """The fig9 ``router_jsq`` acceptance: on demand measured THROUGH the
    router, modelled QPS increases strictly 1 -> 2 -> 4 replicas."""
    b = anns_bundle
    router = ReplicaRouter(b.index, n_replicas=2, policy="jsq",
                           threaded=True, max_batch=8, max_wait_s=0.001)
    futs = [router.submit(SearchRequest(query=q)) for q in b.queries]
    for f in futs:
        f.result(timeout=120)
    router.stop()
    demand = router.measured_demand()
    assert demand.ssd_ios > 0 and demand.cpu_dist_ops > 0
    sweep = sweep_replicas(demand, DeviceModel(), (1, 2, 4))
    assert sweep[1] < sweep[2] < sweep[4], sweep


# ----------------------------------------------------------------- updates

def test_updates_propagate_to_every_replica(anns_bundle, fresh_index):
    """test_updates semantics hold under routing: inserts and tombstones
    land in the SHARED tiers, so both replicas see them (round-robin
    guarantees both actually serve post-update traffic)."""
    b = anns_bundle
    router = ReplicaRouter(fresh_index, n_replicas=2, policy="round_robin",
                           threaded=True, max_batch=4, max_wait_s=0.001)
    new_ids = router.insert(b.new_vecs)
    victim = new_ids[0]
    router.delete(np.array([victim]))
    futs = [router.submit(SearchRequest(query=v)) for v in b.new_vecs[:8]]
    responses = [f.result(timeout=120) for f in futs]
    router.stop()
    assert router.stats_rollup()["routed"] == [4, 4]
    for r in responses:
        assert victim not in set(r.ids.tolist())
    hits = sum(int(r.ids[0] == nid)
               for r, nid in zip(responses[1:], new_ids[1:8]))
    assert hits >= 5


# -------------------------------------------------------------- split_mesh

def test_split_mesh_validation():
    from repro.launch.mesh import make_test_mesh, split_mesh
    mesh = make_test_mesh(1)
    assert split_mesh(mesh, 1) == [mesh]          # identity
    with pytest.raises(ValueError, match="cannot split 1 device"):
        split_mesh(mesh, 2)
    with pytest.raises(ValueError, match="n_replicas"):
        split_mesh(mesh, 0)
    with pytest.raises(ValueError, match="n_replicas"):
        ReplicaRouter(None, n_replicas=0)
    with pytest.raises(ValueError, match="unknown policy"):
        ReplicaRouter(None, policy="nope")


_SUBMESH_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys
sys.path.insert(0, sys.argv[1])
import dataclasses, json
import numpy as np
from repro.configs.anns_datasets import SIFT_SMALL
from repro.core.engine import FusionANNSIndex
from repro.data.synthetic import clustered_vectors
from repro.launch.mesh import make_test_mesh, split_mesh
from repro.serve.client import SearchRequest
from repro.serve.router import ReplicaRouter

rng = np.random.default_rng(0)
cfg = dataclasses.replace(SIFT_SMALL, n_vectors=800, dim=32,
                          n_posting_fraction=0.02)
data = clustered_vectors(rng, 808, 32, n_clusters=8)
index = FusionANNSIndex.build(data[:800], cfg)
queries = data[800:]

mesh = make_test_mesh(4)
subs = split_mesh(mesh, 2)
dev_groups = [sorted(d.id for d in np.asarray(s.devices).ravel())
              for s in subs]
ref = [index.query(q, k=5).ids for q in queries]

router = ReplicaRouter(index, n_replicas=2, policy="jsq", mesh=mesh,
                       threaded=True, max_batch=4, max_wait_s=0.001)
shards = [svc.executor._n_shards() for svc in router.replicas]
futs = [router.submit(SearchRequest(query=q, k=5)) for q in queries]
ids = [f.result(timeout=120).ids for f in futs]
router.stop()

out = {
    "disjoint": not (set(dev_groups[0]) & set(dev_groups[1])),
    "covers": sorted(dev_groups[0] + dev_groups[1]) == [0, 1, 2, 3],
    "shards": shards,
    "parity": all(np.array_equal(a, b) for a, b in zip(ids, ref)),
    "served": int(sum(router.stats_rollup()["routed"])),
}
print(json.dumps(out))
"""


@pytest.fixture(scope="module")
def submesh_results():
    """Sub-mesh routing needs >= 4 devices: host platform override BEFORE
    jax import (same pattern as test_executor's sharded fixture)."""
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-c", _SUBMESH_SCRIPT, os.path.abspath(src)],
        capture_output=True, text=True, timeout=600, env=env)
    assert proc.returncode == 0, proc.stderr[-3000:]
    return json.loads(proc.stdout.strip().splitlines()[-1])


def test_split_mesh_groups_are_disjoint_and_cover(submesh_results):
    assert submesh_results["disjoint"] and submesh_results["covers"]


def test_submesh_replica_scan_matches_single_device(submesh_results):
    assert submesh_results["shards"] == [2, 2]
    assert submesh_results["parity"], submesh_results
    assert submesh_results["served"] == 8
