"""Multi-device semantics: runs a subprocess with 8 forced host devices and
asserts sharded results equal single-device references."""

import json
import os
import subprocess
import sys

import pytest

# full 8-device subprocess (LM train step, MoE, GNN, elastic ckpt): minutes
pytestmark = pytest.mark.slow

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, sys.argv[1])
import json
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core.topk import sharded_topk
from repro.core.distributed import sharded_adc_topn, sharded_adc_topn_batch
from repro.kernels.pq_adc.ref import pq_adc_ref
from repro.models.layers import ShardCtx
from repro.sharding.spec import rules_for_mesh
from repro.launch.mesh import make_test_mesh

out = {}
mesh = make_test_mesh(8)
ctx = ShardCtx(mesh=mesh, rules=rules_for_mesh(mesh))
rng = np.random.default_rng(0)

# --- sharded_topk == global top_k ---
scores = jnp.asarray(rng.standard_normal((4, 256)), jnp.float32)
s_sh = jax.device_put(scores, NamedSharding(mesh, P("data", "model")))
with mesh:
    v, i = jax.jit(lambda s: sharded_topk(s, 8, ctx, shard_axes="model",
                                          batch_axes="batch"))(s_sh)
rv, ri = jax.lax.top_k(scores, 8)
out["topk_vals_match"] = bool(np.allclose(np.asarray(v), np.asarray(rv), atol=1e-6))
gather_check = np.take_along_axis(np.asarray(scores), np.asarray(i), axis=1)
out["topk_ids_valid"] = bool(np.allclose(gather_check, np.asarray(rv), atol=1e-6))

# --- sharded ADC scan == reference scan ---
codes = jnp.asarray(rng.integers(0, 256, (1024, 8)), jnp.uint8)
lut = jnp.asarray(rng.random((8, 256)), jnp.float32)
codes_sh = jax.device_put(codes, NamedSharding(mesh, P(("data", "model"), None)))
with mesh:
    dv, di = jax.jit(lambda c, l: sharded_adc_topn(c, l, 32, ctx))(codes_sh, lut)
ref = np.asarray(pq_adc_ref(codes, lut))
out["adc_vals_match"] = bool(np.allclose(np.sort(np.asarray(dv)), np.sort(ref)[:32], rtol=1e-5))
out["adc_ids_match"] = bool(np.allclose(np.sort(ref[np.asarray(di)]), np.sort(ref)[:32], rtol=1e-5))

# --- batched scan ---
luts = jnp.asarray(rng.random((3, 8, 256)), jnp.float32)
with mesh:
    bv, bi = jax.jit(lambda c, l: sharded_adc_topn_batch(c, l, 16, ctx))(codes_sh, luts)
ok = True
for b in range(3):
    refb = np.sort(np.asarray(pq_adc_ref(codes, luts[b])))[:16]
    ok = ok and np.allclose(np.sort(np.asarray(bv[b])), refb, rtol=1e-5)
out["adc_batch_match"] = bool(ok)

# --- MoE under mesh == local ---
from repro.models import layers as L
from repro.configs.qwen3_moe_30b_a3b import REDUCED as moecfg
import dataclasses
cfg = dataclasses.replace(moecfg, capacity_factor=8.0)
x = jnp.asarray(rng.standard_normal((4, 8, cfg.d_model)), jnp.float32)
router = jnp.asarray(rng.standard_normal((cfg.d_model, cfg.n_experts)), jnp.float32)
w1 = jnp.asarray(0.1 * rng.standard_normal((cfg.n_experts, cfg.d_model, 2 * cfg.moe_d_ff)), jnp.float32)
w2 = jnp.asarray(0.1 * rng.standard_normal((cfg.n_experts, cfg.moe_d_ff, cfg.d_model)), jnp.float32)
local = L.moe_block(x, router, w1, w2, None, None, cfg=cfg, ctx=L.LOCAL_CTX)
x_sh = jax.device_put(x, NamedSharding(mesh, P("data", "model", None)))
with mesh:
    dist = jax.jit(lambda *a: L.moe_block(*a, None, None, cfg=cfg, ctx=ctx))(x_sh, router, w1, w2)
out["moe_match"] = bool(np.allclose(np.asarray(local), np.asarray(dist), rtol=5e-4, atol=5e-4))

# replicated (decode) MoE mode
with mesh:
    x_rep = jax.device_put(x[:, :1], NamedSharding(mesh, P("data", None, None)))
    dist2 = jax.jit(lambda *a: L.moe_block(*a, None, None, cfg=cfg, ctx=ctx,
                                           seq_sharded=False))(x_rep, router, w1, w2)
local2 = L.moe_block(x[:, :1], router, w1, w2, None, None, cfg=cfg, ctx=L.LOCAL_CTX)
out["moe_decode_match"] = bool(np.allclose(np.asarray(local2), np.asarray(dist2), rtol=5e-4, atol=5e-4))

# --- dst-partitioned GNN == baseline full-graph forward ---
from repro.models import gnn
from repro.data.partition import partition_edges_by_dst
from repro.data.graphs import random_graph
from repro.configs.graphsage_reddit import REDUCED as gcfg
g = random_graph(rng, 64, 200, 16, 4)
params_g = gnn.init_sage(jax.random.key(1), gcfg, d_feat=16, n_classes=4)
feats = jnp.asarray(g["features"])
base = gnn.sage_forward_full(params_g, feats, jnp.asarray(g["edges"]), gcfg)
pe, pw = partition_edges_by_dst(g["edges"], 64, 8)
pe_sh = jax.device_put(jnp.asarray(pe), NamedSharding(mesh, P(("data", "model"), None)))
pw_sh = jax.device_put(jnp.asarray(pw), NamedSharding(mesh, P(("data", "model"))))
with mesh:
    dstp = jax.jit(lambda p, f, e, w: gnn.sage_forward_full_dstpart(
        p, f, e, w, gcfg, ctx))(params_g, feats, pe_sh, pw_sh)
# h1 crosses the mesh as bf16 bit-patterns (iteration B2) -> bf16 tolerance
out["gnn_dstpart_match"] = bool(np.allclose(np.asarray(base), np.asarray(dstp),
                                            rtol=3e-2, atol=3e-2))

# --- blocked batched ADC scan == per-query map ---
with mesh:
    bv2, bi2 = jax.jit(lambda c, l: sharded_adc_topn_batch(
        c, l, 16, ctx, blocked=True))(codes_sh, luts)
ok2 = True
for b in range(3):
    refb = np.sort(np.asarray(pq_adc_ref(codes, luts[b])))[:16]
    ok2 = ok2 and np.allclose(np.sort(np.asarray(bv2[b])), refb, rtol=1e-5)
out["adc_blocked_match"] = bool(ok2)

# --- elastic resharding: checkpoint under (2,4), restore under (4,2) ---
import tempfile
from repro.train import checkpoint as ckpt
from jax.sharding import Mesh
big = jnp.asarray(rng.standard_normal((16, 32)), jnp.float32)
mesh_a = jax.make_mesh((2, 4), ("data", "model"))
mesh_b = jax.make_mesh((4, 2), ("data", "model"))   # data axis grew 2x
x_a = jax.device_put(big, NamedSharding(mesh_a, P("data", "model")))
d = tempfile.mkdtemp()
ckpt.save(d, 3, {"w": x_a})
proto = jax.eval_shape(lambda: {"w": big})
restored, step = ckpt.restore(
    d, proto, shardings={"w": NamedSharding(mesh_b, P("data", "model"))})
out["elastic_values_equal"] = bool(np.allclose(np.asarray(restored["w"]),
                                               np.asarray(big)))
out["elastic_resharded"] = bool(
    restored["w"].sharding.mesh.shape["data"] == 4 and step == 3)

# --- sharded LM train step runs + loss matches local ---
from repro.models.api import build_cell, realize
cell_l = build_cell("qwen3-0.6b", "train_4k", mesh=None, reduced=True)
args_l = realize(cell_l)
_, m_l = jax.jit(cell_l.fn)(*args_l)
cell_d = build_cell("qwen3-0.6b", "train_4k", mesh=mesh, reduced=True)
args_d = realize(cell_d)
args_d = jax.tree_util.tree_map(
    lambda a, s: jax.device_put(a, s) if s is not None else a,
    args_d, cell_d.in_shardings,
    is_leaf=lambda v: v is None or isinstance(v, jax.sharding.NamedSharding))
with mesh:
    _, m_d = jax.jit(cell_d.fn, in_shardings=cell_d.in_shardings)(*args_d)
out["lm_loss_match"] = bool(abs(float(m_l["loss"]) - float(m_d["loss"])) < 0.05)
print(json.dumps(out))
"""


@pytest.fixture(scope="module")
def results():
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT, os.path.abspath(src)],
        capture_output=True, text=True, timeout=900, env=env)
    assert proc.returncode == 0, proc.stderr[-3000:]
    return json.loads(proc.stdout.strip().splitlines()[-1])


@pytest.mark.parametrize("key", [
    "topk_vals_match", "topk_ids_valid", "adc_vals_match", "adc_ids_match",
    "adc_batch_match", "adc_blocked_match", "gnn_dstpart_match",
    "moe_match", "moe_decode_match", "lm_loss_match",
    "elastic_values_equal", "elastic_resharded",
])
def test_distributed(results, key):
    assert results[key], f"{key} failed: {results}"
