"""Posting-list construction invariants (paper §4.1, Eq. 2)."""

import numpy as np
import pytest
from _propshim import given, settings, strategies as st

from repro.core import clustering
from repro.data.synthetic import clustered_vectors


@pytest.fixture(scope="module")
def posting():
    rng = np.random.default_rng(0)
    data = clustered_vectors(rng, 2000, 16, n_clusters=20)
    return data, clustering.build_posting_lists(
        rng, data, n_clusters=24, eps=0.15, max_replicas=8)


def test_every_vector_assigned(posting):
    data, pl = posting
    seen = np.zeros(len(data), bool)
    for m in pl.members:
        seen[m] = True
    assert seen.all()


def test_primary_is_nearest_centroid(posting):
    data, pl = posting
    d2 = (np.sum(data ** 2, -1)[:, None] - 2 * data @ pl.centroids.T
          + np.sum(pl.centroids ** 2, -1)[None])
    np.testing.assert_array_equal(pl.primary, np.argmin(d2, -1))


def test_replication_cap(posting):
    data, pl = posting
    counts = np.zeros(len(data), np.int64)
    for m in pl.members:
        counts[m] += 1
    assert counts.max() <= 8
    assert counts.min() >= 1
    # replication factor in a sane band (paper reports up to 8x)
    assert 1.0 <= pl.replication_factor() <= 8.0


def test_eq2_epsilon_closure(posting):
    """v in C_i  iff  Dist(v,C_i) <= (1+eps) Dist(v,C_1) (within top-8)."""
    data, pl = posting
    eps = 0.15
    d = np.sqrt(np.maximum(
        np.sum(data ** 2, -1)[:, None] - 2 * data @ pl.centroids.T
        + np.sum(pl.centroids ** 2, -1)[None], 0))
    member_of = [set(m.tolist()) for m in pl.members]
    for v in range(0, len(data), 97):
        d1 = d[v].min()
        within = np.where(d[v] <= (1 + eps) * d1 + 1e-6)[0]
        assigned = {c for c in range(pl.n_clusters) if v in member_of[c]}
        # assigned set == top-(<=8) of the within set
        expect = set(within[np.argsort(d[v][within])][:8].tolist())
        assert assigned == expect


@settings(max_examples=10, deadline=None)
@given(n=st.integers(50, 300), k=st.integers(2, 12),
       seed=st.integers(0, 999))
def test_balanced_clustering_properties(n, k, seed):
    rng = np.random.default_rng(seed)
    data = rng.standard_normal((n, 8)).astype(np.float32)
    cents = clustering.hierarchical_balanced_clustering(rng, data, k)
    assert cents.shape == (k, 8)
    assert np.isfinite(cents).all()
