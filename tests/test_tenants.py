"""Tenant namespaces, quotas, and the deadline-adaptive planner (PR 10 —
DESIGN.md §11).

Contract under test:
* ``TenantManager`` stamps each tenant's base predicate UNDER the
  request's own filter (narrow, never widen), passes ``tenant=None``
  through untouched, and refuses unknown tenants (fail closed);
* quotas: a drained ``TokenBucket`` raises :class:`QuotaExceeded` (NOT a
  ``BackpressureError`` — it must surface, not spin) with an honest
  ``retry_after``; a token spent on a submit the backend refused is
  refunded;
* books: per-tenant submitted/ok/errors/quota_rejected counters, latency
  percentiles, and summed ``QueryStats`` — two tenants' rollups never
  mix, and the manager folds them into the Backend ``stats_rollup()``;
* end to end over a REAL executor: two tenants with disjoint base
  predicates sharing one index can never retrieve each other's rows;
* ``AdaptivePlanner``/``resolve_accuracy``: most-accurate level that
  fits the deadline, monotone in the deadline, cheapest level as the
  floor, and no suggestion before any traffic was observed.
"""

import numpy as np
import pytest

from repro.core.executor import QUERY_STATS_FIELDS, QueryStats
from repro.core.filters import And, Eq
from repro.core.futures import BackpressureError, QueryFuture
from repro.core.perf_model import (ACCURACY_LEVELS, AdaptivePlanner,
                                   DeviceModel, QueryDemand,
                                   resolve_accuracy, scale_demand)
from repro.serve.client import SearchRequest, SearchResponse
from repro.serve.tenants import (QuotaExceeded, TenantConfig, TenantManager,
                                 TokenBucket)


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        return self.t


def _stats(**kw) -> QueryStats:
    base = dict.fromkeys(QUERY_STATS_FIELDS, 0)
    base["early_stopped"] = False
    base.update(kw)
    return QueryStats(**base)


def _resp(latency_s=0.01, **stat_kw) -> SearchResponse:
    return SearchResponse(ids=np.arange(3), dists=np.zeros(3),
                          stats=_stats(**stat_kw), latency_s=latency_s)


class StubBackend:
    """Records submits; the test resolves the returned futures by hand."""

    def __init__(self):
        self.requests = []
        self.futures = []
        self.fail_next = None          # raise this on the next submit

    def submit(self, request: SearchRequest) -> QueryFuture:
        if self.fail_next is not None:
            exc, self.fail_next = self.fail_next, None
            raise exc
        fut = QueryFuture(tag=request.tag, blocking=True)
        self.requests.append(request)
        self.futures.append(fut)
        return fut

    def stats_rollup(self):
        return {"backend": "stub"}

    @property
    def epoch(self):
        return 7


def _mgr(*tenants, clock=None):
    be = StubBackend()
    mgr = TenantManager(be, tenants, clock=clock or FakeClock())
    return be, mgr


Q = np.ones(8, np.float32)


# ---------------------------------------------------------------------------
# Namespacing
# ---------------------------------------------------------------------------

def test_none_tenant_passes_through_untouched():
    be, mgr = _mgr(TenantConfig("a", "ka", filter=Eq("tenant", 0)))
    req = SearchRequest(query=Q, k=5)
    mgr.submit(req)
    assert be.requests[0] is req                  # not even copied
    assert mgr.tenant_rollup()["a"]["submitted"] == 0


def test_unknown_tenant_refused():
    be, mgr = _mgr(TenantConfig("a", "ka"))
    with pytest.raises(ValueError, match="unknown tenant"):
        mgr.submit(SearchRequest(query=Q, k=5, tenant="mallory"))
    assert not be.requests                        # fail closed: no submit


def test_base_predicate_stamped_under_request_filter():
    base = Eq("tenant", 0)
    be, mgr = _mgr(TenantConfig("a", "ka", filter=base),
                   TenantConfig("open", "ko"))    # no base predicate
    # no request filter -> the base predicate alone
    mgr.submit(SearchRequest(query=Q, k=5, tenant="a"))
    assert be.requests[-1].filter == base
    # a request filter NARROWS the namespace: And((base, request))
    mine = Eq("cat", 3)
    mgr.submit(SearchRequest(query=Q, k=5, tenant="a", filter=mine))
    assert be.requests[-1].filter == And((base, mine))
    # a tenant without a base predicate forwards the request filter as-is
    req = SearchRequest(query=Q, k=5, tenant="open", filter=mine)
    mgr.submit(req)
    assert be.requests[-1] is req                 # unchanged -> no copy
    assert mgr.base_filter("a") == base and mgr.base_filter("open") is None
    assert mgr.tenant_names() == ["a", "open"]


# ---------------------------------------------------------------------------
# Quotas
# ---------------------------------------------------------------------------

def test_quota_enforced_with_retry_after():
    clk = FakeClock()
    be, mgr = _mgr(TenantConfig("a", "ka", rate_qps=1.0, burst=2),
                   clock=clk)
    for _ in range(2):                            # burst admits
        mgr.submit(SearchRequest(query=Q, k=5, tenant="a"))
    with pytest.raises(QuotaExceeded) as ei:
        mgr.submit(SearchRequest(query=Q, k=5, tenant="a"))
    assert ei.value.tenant == "a"
    assert ei.value.retry_after == pytest.approx(1.0)
    assert not isinstance(ei.value, BackpressureError)   # must surface,
    #                                                      never spin
    book = mgr.tenant_rollup()["a"]
    assert book["submitted"] == 2 and book["quota_rejected"] == 1
    clk.t += 1.0                                  # one token re-accrues
    mgr.submit(SearchRequest(query=Q, k=5, tenant="a"))
    assert len(be.requests) == 3


def test_quota_is_per_tenant():
    clk = FakeClock()
    be, mgr = _mgr(TenantConfig("a", "ka", rate_qps=1.0, burst=1),
                   TenantConfig("b", "kb", rate_qps=1.0, burst=1),
                   clock=clk)
    mgr.submit(SearchRequest(query=Q, k=5, tenant="a"))
    with pytest.raises(QuotaExceeded):
        mgr.submit(SearchRequest(query=Q, k=5, tenant="a"))
    # a's drained bucket never touches b
    mgr.submit(SearchRequest(query=Q, k=5, tenant="b"))
    roll = mgr.tenant_rollup()
    assert roll["a"]["quota_rejected"] == 1
    assert roll["b"]["quota_rejected"] == 0


def test_backend_refusal_refunds_the_token():
    clk = FakeClock()
    be, mgr = _mgr(TenantConfig("a", "ka", rate_qps=1.0, burst=1),
                   clock=clk)
    be.fail_next = BackpressureError("queue full")
    with pytest.raises(BackpressureError):
        mgr.submit(SearchRequest(query=Q, k=5, tenant="a"))
    # the token came back: the retry is admitted with NO clock advance
    mgr.submit(SearchRequest(query=Q, k=5, tenant="a"))
    assert len(be.requests) == 1
    assert mgr.tenant_rollup()["a"]["submitted"] == 1


def test_token_bucket_refund_caps_at_burst():
    clk = FakeClock()
    b = TokenBucket(rate=1.0, burst=2, clock=clk)
    assert b.try_acquire() and b.try_acquire() and not b.try_acquire()
    b.refund()
    assert b.try_acquire() and not b.try_acquire()
    b.refund()
    b.refund()                                    # over-refund clamps
    b.refund()
    assert b.try_acquire() and b.try_acquire() and not b.try_acquire()


# ---------------------------------------------------------------------------
# Books
# ---------------------------------------------------------------------------

def test_per_tenant_books_and_stats_are_isolated():
    be, mgr = _mgr(TenantConfig("a", "ka"), TenantConfig("b", "kb"))
    for tenant, n in (("a", 3), ("b", 1)):
        for _ in range(n):
            mgr.submit(SearchRequest(query=Q, k=5, tenant=tenant))
    # resolve: a gets 2 oks + 1 error, b gets 1 ok
    be.futures[0]._set_result(_resp(latency_s=0.010, candidates_scanned=100,
                                    candidates_prefilter=400, ios=7))
    be.futures[1]._set_result(_resp(latency_s=0.030, candidates_scanned=50,
                                    candidates_prefilter=400))
    be.futures[2]._set_exception(RuntimeError("boom"))
    be.futures[3]._set_result(_resp(latency_s=0.500, candidates_scanned=9,
                                    candidates_prefilter=9))
    roll = mgr.tenant_rollup()
    a, b = roll["a"], roll["b"]
    assert (a["submitted"], a["ok"], a["errors"]) == (3, 2, 1)
    assert (b["submitted"], b["ok"], b["errors"]) == (1, 1, 0)
    assert a["query_stats"]["candidates_scanned"] == 150
    assert a["query_stats"]["candidates_prefilter"] == 800
    assert a["query_stats"]["ios"] == 7
    assert b["query_stats"]["candidates_scanned"] == 9   # never mixed
    assert a["latency"]["n"] == 2
    assert a["latency"]["p99"] < 0.1 < b["latency"]["p50"]
    # percentiles helper agrees with the rollup
    assert mgr.tenant_percentiles("b")["n"] == 1


def test_stats_rollup_folds_tenants_into_backend_rollup():
    be, mgr = _mgr(TenantConfig("a", "ka"))
    roll = mgr.stats_rollup()
    assert roll["backend"] == "stub"              # delegation preserved
    assert set(roll["tenants"]) == {"a"}
    assert mgr.epoch == 7                         # property delegation
    assert mgr.tenant_rollup()["a"]["ok"] == 0


def test_getattr_delegates_but_guards_reentry():
    be, mgr = _mgr()
    be.anything = "delegated"
    assert mgr.anything == "delegated"
    with pytest.raises(AttributeError):
        TenantManager.__getattr__(mgr, "backend")


# ---------------------------------------------------------------------------
# End to end: two tenants over one REAL index can never see each other
# ---------------------------------------------------------------------------

def test_tenants_cannot_retrieve_each_others_rows(anns_bundle, fresh_index):
    """Disjoint base predicates over one shared executor: every result id
    belongs to the requesting tenant's rows — even when the request
    carries an adversarially wide filter — and rows without a tenant
    column are invisible to BOTH (fail closed)."""
    b = anns_bundle
    index = fresh_index                     # sealed rows: NO tenant column
    half = len(b.new_vecs) // 2
    ids_a = index.insert(b.new_vecs[:half],
                         attributes={"tenant": np.zeros(half, np.int64)})
    ids_b = index.insert(b.new_vecs[half:],
                         attributes={"tenant": np.ones(half, np.int64)})
    mgr = TenantManager(index.executor,
                        (TenantConfig("alice", "ka", filter=Eq("tenant", 0)),
                         TenantConfig("bob", "kb", filter=Eq("tenant", 1))))
    own = {"alice": set(ids_a.tolist()), "bob": set(ids_b.tolist())}
    for tenant in ("alice", "bob"):
        for q in list(b.queries[:3]) + list(b.new_vecs[:2]):
            for filt in (None, Eq("tenant", 1 - (tenant == "bob"))):
                # the second filter ASKS for the other tenant's rows; the
                # conjunction with the base predicate yields nothing else
                got = mgr.submit(SearchRequest(
                    query=q, k=10, tenant=tenant, filter=filt)).result()
                assert set(np.asarray(got.ids).tolist()) <= own[tenant]
    roll = mgr.tenant_rollup()
    assert roll["alice"]["ok"] == roll["bob"]["ok"] == 10
    assert roll["alice"]["errors"] == roll["bob"]["errors"] == 0


# ---------------------------------------------------------------------------
# Deadline-adaptive accuracy
# ---------------------------------------------------------------------------

_DEMAND = QueryDemand(ssd_ios=64, ssd_bytes=64 * 4096, h2d_bytes=40_000,
                      gpu_lookups=5e5, cpu_dist_ops=2e5, graph_hops=100)


def test_resolve_accuracy_monotone_in_deadline():
    hw = DeviceModel()
    deadlines = (10.0, 1e-3, 3e-4, 1e-4, 1e-5, 1e-9)
    picked = [resolve_accuracy(dl, _DEMAND, hw) for dl in deadlines]
    order = {lvl.name: i for i, lvl in enumerate(ACCURACY_LEVELS)}
    ranks = [order[p.name] for p in picked]
    assert ranks == sorted(ranks)                 # tighter never finer
    assert picked[0].name == "full"               # easy deadline: full
    assert picked[-1].name == "turbo"             # hopeless: cheapest floor


def test_scale_demand_tracks_selectivity():
    lvl = ACCURACY_LEVELS[2]                      # balanced: 0.5 / 0.5
    d = scale_demand(_DEMAND, lvl, selectivity=0.25)
    assert d.gpu_lookups == pytest.approx(_DEMAND.gpu_lookups * 0.125)
    assert d.ssd_ios == pytest.approx(_DEMAND.ssd_ios * 0.125)
    # graph hops scale with top_m only — traversal cost ignores the filter
    assert d.graph_hops == pytest.approx(_DEMAND.graph_hops * 0.5)


def _planner(cfg):
    return AdaptivePlanner(cfg, DeviceModel(), dim=32)


def test_planner_suggests_nothing_without_traffic(anns_bundle):
    pl = _planner(anns_bundle.cfg)
    assert pl.suggest(0.001) is None              # nothing observed yet
    pl.observe(_stats(ios=10, ssd_bytes=40960, h2d_bytes=10_000,
                      candidates_scanned=1000, candidates_prefilter=1000))
    assert pl.suggest(None) is None               # no deadline, no change
    assert pl.suggest(10.0) is None               # full accuracy fits


def test_planner_descends_under_tight_deadlines(anns_bundle):
    cfg = anns_bundle.cfg
    pl = _planner(cfg)
    for _ in range(4):                            # heavy observed traffic
        pl.observe(_stats(ios=500, ssd_bytes=500 * 4096, h2d_bytes=4e6,
                          candidates_scanned=200_000,
                          candidates_prefilter=400_000))
    sug = pl.suggest(1e-4)
    assert sug is not None and sug["level"] != "full"
    assert 1 <= sug["top_m"] < cfg.top_m
    assert cfg.top_k <= sug["top_n"] < cfg.top_n
    assert sug["selectivity"] == pytest.approx(0.5)
    # a relaxed deadline at the same demand stays at full accuracy
    assert pl.suggest(60.0) is None
