"""Property-based tests for the layered-plan merge (ISSUE 4 satellite).

``PlanOverrides.merge_into`` / ``QueryPlan.override`` carry per-request
knobs into a shared scan window (PR 2); the algebra they must satisfy:

* **idempotence** — merging the same override twice is the first merge;
* **layering order** — for every field, the LAST non-``None`` layer wins
  (``ov2`` over ``ov1`` over the base plan; explicit ``kw`` over ``ov``);
* **None vs 0** — only ``None`` means "keep the base"; explicit zeros are
  honored, both in ``override()`` and ``QueryPlan.from_config`` (the PR-2
  ``is None`` fix — ``k=0`` must never be conflated with "default").

Runs under ``hypothesis`` when installed, else the deterministic
``tests/_propshim.py`` fallback (tier-1 policy, see conftest.py).
"""

from _propshim import given, settings, strategies as st

from repro.configs.anns_datasets import SIFT_SMALL
from repro.core.executor import PlanOverrides, QueryPlan

BASE = QueryPlan(k=10, top_m=24, top_n=256)

# None (keep), 0 (explicit zero — must NOT be conflated with None), and a
# few positive values
_knob = st.sampled_from([None, 0, 1, 7, 64])
_dl = st.sampled_from([None, 0.0, 0.25, 5.0])


@settings(max_examples=60, deadline=None)
@given(k=_knob, top_m=_knob, top_n=_knob, deadline_s=_dl)
def test_merge_into_is_idempotent(k, top_m, top_n, deadline_s):
    ov = PlanOverrides(k=k, top_m=top_m, top_n=top_n,
                       deadline_s=deadline_s)
    once = ov.merge_into(BASE)
    assert ov.merge_into(once) == once


@settings(max_examples=60, deadline=None)
@given(k1=_knob, n1=_knob, d1=_dl, k2=_knob, n2=_knob, d2=_dl)
def test_layering_last_non_none_wins(k1, n1, d1, k2, n2, d2):
    ov1 = PlanOverrides(k=k1, top_n=n1, deadline_s=d1)
    ov2 = PlanOverrides(k=k2, top_n=n2, deadline_s=d2)
    merged = ov2.merge_into(ov1.merge_into(BASE))

    def pick(a, b, base):
        return a if a is not None else (b if b is not None else base)

    assert merged.k == pick(k2, k1, BASE.k)
    assert merged.top_n == pick(n2, n1, BASE.top_n)
    assert merged.deadline_s == pick(d2, d1, BASE.deadline_s)
    # untouched fields ride through every layer
    assert merged.top_m == BASE.top_m
    assert merged.rerank_batch == BASE.rerank_batch


@settings(max_examples=40, deadline=None)
@given(k=_knob, kw_k=_knob)
def test_override_kwargs_layer_over_overrides(k, kw_k):
    """``plan.override(ov, k=...)``: the kw layer sits ABOVE the override
    layer — same last-non-None-wins rule."""
    merged = BASE.override(PlanOverrides(k=k), k=kw_k)
    expect = kw_k if kw_k is not None else (k if k is not None else BASE.k)
    assert merged.k == expect


def test_empty_override_is_identity():
    assert PlanOverrides().merge_into(BASE) == BASE
    assert BASE.override() == BASE


def test_zero_k_is_not_none():
    """The PR-2 edge case: k=0 / top_n=0 are real values, not defaults."""
    assert PlanOverrides(k=0).merge_into(BASE).k == 0
    assert PlanOverrides(top_n=0).merge_into(BASE).top_n == 0
    assert PlanOverrides(k=None).merge_into(BASE).k == BASE.k
    # from_config has the same contract (explicit ``is None`` checks)
    assert QueryPlan.from_config(SIFT_SMALL, k=0).k == 0
    assert QueryPlan.from_config(SIFT_SMALL).k == SIFT_SMALL.top_k
    assert QueryPlan.from_config(SIFT_SMALL, top_n=0).top_n == 0
