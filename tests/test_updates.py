"""Incremental index maintenance (SPFresh-style insert/delete)."""

import dataclasses

import numpy as np
import pytest

from repro.configs.anns_datasets import SIFT_SMALL
from repro.core.engine import FusionANNSIndex, ground_truth, recall_at_k
from repro.data.synthetic import clustered_vectors


@pytest.fixture()
def index_and_data(rng):
    cfg = dataclasses.replace(SIFT_SMALL, n_vectors=3000, dim=32,
                              n_posting_fraction=0.02)
    data = clustered_vectors(rng, cfg.n_vectors + 40, cfg.dim, n_clusters=24)
    return cfg, data[:3000], data[3000:3020], data[3020:], \
        FusionANNSIndex.build(data[:3000], cfg)


def test_inserted_vectors_are_findable(index_and_data, rng):
    cfg, data, new_vecs, queries, index = index_and_data
    new_ids = index.insert(new_vecs)
    assert len(new_ids) == 20
    # querying AT an inserted vector must return it as the nearest
    hits = 0
    for i, v in enumerate(new_vecs):
        res = index.query(v, k=1)
        hits += int(res.ids[0] == new_ids[i])
    assert hits >= 18     # tight clusters; PQ may swap exact ties


def test_insert_preserves_existing_recall(index_and_data):
    cfg, data, new_vecs, queries, index = index_and_data
    gt = ground_truth(data, queries, 10)
    before = recall_at_k(np.stack(
        [index.query(q).ids for q in queries]), gt, 10)
    index.insert(new_vecs)
    full = np.concatenate([data, new_vecs.astype(data.dtype)])
    gt2 = ground_truth(full, queries, 10)
    after = recall_at_k(np.stack(
        [index.query(q).ids for q in queries]), gt2, 10)
    assert after >= before - 0.1


def test_delete_tombstones(index_and_data):
    cfg, data, new_vecs, queries, index = index_and_data
    q = data[5]
    res = index.query(q, k=5)
    victim = res.ids[0]
    index.delete(np.array([victim]))
    res2 = index.query(q, k=5)
    assert victim not in set(res2.ids.tolist())


def test_insert_extends_all_tiers(index_and_data):
    cfg, data, new_vecs, queries, index = index_and_data
    n0 = len(index.ssd.vectors)
    p0 = index.ssd.layout.n_pages
    index.insert(new_vecs)
    assert len(index.ssd.vectors) == n0 + 20          # SSD tier
    assert index.codes.shape[0] == n0 + 20            # HBM tier
    assert index.ssd.layout.n_pages > p0              # fresh pages
    total_members = sum(len(m) for m in index.posting.members)
    assert total_members >= n0 + 20                   # DRAM metadata
