"""Incremental index maintenance (segmented streaming updates, DESIGN.md
§10), including the fused batched path (updates x batching: tombstones
and fresh appends must be honored by every executor window size, not just
window=1) and the PR-9 regression tests for the pre-segmentation races:
torn multi-tier publication on insert, and tombstone filters indexing
past their array on fresh ids."""

import threading

import numpy as np
import pytest

from repro.core.engine import ground_truth, recall_at_k
from repro.serve.client import SearchRequest


@pytest.fixture()
def index_and_data(anns_bundle, fresh_index):
    b = anns_bundle
    return b.cfg, b.data, b.new_vecs, b.queries, fresh_index


def test_inserted_vectors_are_findable(index_and_data, rng):
    cfg, data, new_vecs, queries, index = index_and_data
    new_ids = index.insert(new_vecs)
    assert len(new_ids) == 20
    # querying AT an inserted vector must return it as the nearest
    hits = 0
    for i, v in enumerate(new_vecs):
        res = index.query(v, k=1)
        hits += int(res.ids[0] == new_ids[i])
    assert hits >= 18     # tight clusters; PQ may swap exact ties


def test_insert_preserves_existing_recall(index_and_data):
    cfg, data, new_vecs, queries, index = index_and_data
    gt = ground_truth(data, queries, 10)
    before = recall_at_k(np.stack(
        [index.query(q).ids for q in queries]), gt, 10)
    index.insert(new_vecs)
    full = np.concatenate([data, new_vecs.astype(data.dtype)])
    gt2 = ground_truth(full, queries, 10)
    after = recall_at_k(np.stack(
        [index.query(q).ids for q in queries]), gt2, 10)
    assert after >= before - 0.1


def test_delete_tombstones(index_and_data):
    cfg, data, new_vecs, queries, index = index_and_data
    q = data[5]
    res = index.query(q, k=5)
    victim = res.ids[0]
    index.delete(np.array([victim]))
    res2 = index.query(q, k=5)
    assert victim not in set(res2.ids.tolist())


def test_inserted_vectors_findable_by_fused_batch(index_and_data):
    """Fresh appends must be visible to the fused batched path: the HBM
    code placement is invalidated by insert, and the union scan covers the
    new ids."""
    cfg, data, new_vecs, queries, index = index_and_data
    new_ids = index.insert(new_vecs)
    res = index.query_batch_fused(new_vecs, k=1)
    hits = sum(int(r.ids[0] == nid) for r, nid in zip(res, new_ids))
    assert hits >= 18     # tight clusters; PQ may swap exact ties


def test_delete_tombstones_honored_by_fused_batch(index_and_data):
    """Tombstoned ids must be filtered from the fused batched path too
    (candidate collection runs before the union scan)."""
    cfg, data, new_vecs, queries, index = index_and_data
    base = index.query_batch_fused(queries[:4], k=5)
    victims = np.array([r.ids[0] for r in base])
    index.delete(victims)
    res = index.query_batch_fused(queries[:4], k=5)
    gone = set(victims.tolist())
    for r in res:
        assert not (set(r.ids.tolist()) & gone)
    # single-query and batched paths agree after the delete
    singles = [index.query(q, k=5) for q in queries[:4]]
    for s, f in zip(singles, res):
        np.testing.assert_array_equal(s.ids, f.ids)


def test_updates_respected_by_batching_service(index_and_data):
    """End-to-end: the dynamic-batching service (executor-backed) sees
    inserts and deletes immediately."""
    from repro.serve.anns_service import BatchingANNSService
    cfg, data, new_vecs, queries, index = index_and_data
    new_ids = index.insert(new_vecs)
    victim = new_ids[0]
    index.delete(np.array([victim]))
    svc = BatchingANNSService(index, max_batch=8, max_wait_s=0.0)
    for v in new_vecs[:8]:
        svc.submit(SearchRequest(query=v))
    responses = svc.drain()
    assert len(responses) == 8
    for r in responses:
        assert victim not in set(r.ids.tolist())
    # the other inserted ids are findable through the service
    by_rid = sorted(responses, key=lambda r: r.rid)
    hits = sum(int(r.ids[0] == nid)
               for r, nid in zip(by_rid[1:8], new_ids[1:8]))
    assert hits >= 5


def test_insert_lands_in_delta_then_compaction_seals_all_tiers(
        index_and_data):
    """Segmented semantics: insert touches ONLY the delta segment (cheap,
    atomic); compaction seals the rows into every immutable tier."""
    cfg, data, new_vecs, queries, index = index_and_data
    n0 = len(index.ssd.vectors)
    p0 = index.ssd.layout.n_pages
    e0 = index.epoch
    index.insert(new_vecs)
    assert index.delta_size == 20
    assert index.epoch == e0 + 1
    assert len(index.ssd.vectors) == n0               # sealed tiers
    assert index.codes.shape[0] == n0                 # untouched by insert
    assert index.n_total == n0 + 20                   # ids still published
    sealed = index.compact()
    assert sealed == 20 and index.delta_size == 0
    assert len(index.ssd.vectors) == n0 + 20          # SSD tier
    assert index.codes.shape[0] == n0 + 20            # HBM tier
    assert index.ssd.layout.n_pages > p0              # fresh pages
    total_members = sum(len(m) for m in index.posting.members)
    assert total_members >= n0 + 20                   # DRAM metadata


def test_query_ids_stable_across_compaction(index_and_data):
    """A vector's global id is assigned at insert and survives sealing."""
    cfg, data, new_vecs, queries, index = index_and_data
    new_ids = index.insert(new_vecs)
    pre = [index.query(v, k=1).ids[0] for v in new_vecs]
    index.compact()
    post = [index.query(v, k=1).ids[0] for v in new_vecs]
    hits = sum(int(a == b == nid)
               for a, b, nid in zip(pre, post, new_ids))
    assert hits >= 18     # tight clusters; PQ may swap exact ties


def test_delete_of_unpublished_id_raises(index_and_data):
    """PR-9 regression (tombstone race): deleting an id that was never
    published must be a ValueError, not silent corruption of (or an
    IndexError in) a tombstone array that does not cover it."""
    cfg, data, new_vecs, queries, index = index_and_data
    with pytest.raises(ValueError):
        index.delete(np.array([index.n_total]))
    with pytest.raises(ValueError):
        index.delete(np.array([-1]))
    # inserted-then-deleted works at every point of the lifecycle
    new_ids = index.insert(new_vecs)
    index.delete(new_ids[:1])                   # delta-owned tombstone
    index.compact()
    index.delete(new_ids[1:2])                  # sealed tombstone
    res = index.query(new_vecs[0], k=5)
    assert new_ids[0] not in set(res.ids.tolist())
    assert new_ids[1] not in set(index.query(new_vecs[1], k=5).ids.tolist())


def test_view_publication_is_atomic_across_tiers(index_and_data):
    """PR-9 regression (torn-tier race): a view pinned at ANY moment —
    including mid-insert/mid-compaction from another thread — must have
    posting ids, codes, and tombstones describing exactly the same sealed
    prefix.  Pre-segmentation, posting.members was extended before the
    codes rebinding, so a concurrent reader could gather out of range."""
    cfg, data, new_vecs, queries, index = index_and_data
    stop = threading.Event()
    errors = []

    def mutate():
        try:
            rng = np.random.default_rng(7)
            while not stop.is_set():
                ids = index.insert(
                    rng.normal(size=(3, data.shape[1])).astype(np.float32))
                index.delete(ids[:1])
                index.compact()
        except BaseException as exc:   # noqa: BLE001 — surfaced below
            errors.append(exc)

    t = threading.Thread(target=mutate)
    t.start()
    try:
        for _ in range(60):
            view = index.view()
            n_sealed, n_rows = view.n_sealed, view.n_rows
            # physical tiers (codes/posting/id_of) describe the same row
            # space; id-space tiers (tombstones/row_of) the same id space.
            # n_rows < n_sealed is LEGAL once seal-time purge has dropped
            # tombstoned rows (PR 10) — torn would be the tiers diverging.
            assert view.codes.shape[0] == n_rows
            assert len(view.posting.primary) == n_rows
            assert len(view.id_of) == n_rows
            assert len(view.row_of) == n_sealed
            assert n_rows <= n_sealed
            for q in queries[:2]:
                ids = view.candidate_ids(q, cfg.top_m)
                if len(ids):
                    assert ids.max() < n_sealed
            # the full pipeline never sees a torn binding either
            index.query(queries[0], k=5)
    finally:
        stop.set()
        t.join()
    assert not errors, errors
