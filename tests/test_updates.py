"""Incremental index maintenance (SPFresh-style insert/delete), including
the fused batched path (updates x batching: tombstones and fresh appends
must be honored by every executor window size, not just window=1)."""

import numpy as np
import pytest

from repro.core.engine import ground_truth, recall_at_k
from repro.serve.client import SearchRequest


@pytest.fixture()
def index_and_data(anns_bundle, fresh_index):
    b = anns_bundle
    return b.cfg, b.data, b.new_vecs, b.queries, fresh_index


def test_inserted_vectors_are_findable(index_and_data, rng):
    cfg, data, new_vecs, queries, index = index_and_data
    new_ids = index.insert(new_vecs)
    assert len(new_ids) == 20
    # querying AT an inserted vector must return it as the nearest
    hits = 0
    for i, v in enumerate(new_vecs):
        res = index.query(v, k=1)
        hits += int(res.ids[0] == new_ids[i])
    assert hits >= 18     # tight clusters; PQ may swap exact ties


def test_insert_preserves_existing_recall(index_and_data):
    cfg, data, new_vecs, queries, index = index_and_data
    gt = ground_truth(data, queries, 10)
    before = recall_at_k(np.stack(
        [index.query(q).ids for q in queries]), gt, 10)
    index.insert(new_vecs)
    full = np.concatenate([data, new_vecs.astype(data.dtype)])
    gt2 = ground_truth(full, queries, 10)
    after = recall_at_k(np.stack(
        [index.query(q).ids for q in queries]), gt2, 10)
    assert after >= before - 0.1


def test_delete_tombstones(index_and_data):
    cfg, data, new_vecs, queries, index = index_and_data
    q = data[5]
    res = index.query(q, k=5)
    victim = res.ids[0]
    index.delete(np.array([victim]))
    res2 = index.query(q, k=5)
    assert victim not in set(res2.ids.tolist())


def test_inserted_vectors_findable_by_fused_batch(index_and_data):
    """Fresh appends must be visible to the fused batched path: the HBM
    code placement is invalidated by insert, and the union scan covers the
    new ids."""
    cfg, data, new_vecs, queries, index = index_and_data
    new_ids = index.insert(new_vecs)
    res = index.query_batch_fused(new_vecs, k=1)
    hits = sum(int(r.ids[0] == nid) for r, nid in zip(res, new_ids))
    assert hits >= 18     # tight clusters; PQ may swap exact ties


def test_delete_tombstones_honored_by_fused_batch(index_and_data):
    """Tombstoned ids must be filtered from the fused batched path too
    (candidate collection runs before the union scan)."""
    cfg, data, new_vecs, queries, index = index_and_data
    base = index.query_batch_fused(queries[:4], k=5)
    victims = np.array([r.ids[0] for r in base])
    index.delete(victims)
    res = index.query_batch_fused(queries[:4], k=5)
    gone = set(victims.tolist())
    for r in res:
        assert not (set(r.ids.tolist()) & gone)
    # single-query and batched paths agree after the delete
    singles = [index.query(q, k=5) for q in queries[:4]]
    for s, f in zip(singles, res):
        np.testing.assert_array_equal(s.ids, f.ids)


def test_updates_respected_by_batching_service(index_and_data):
    """End-to-end: the dynamic-batching service (executor-backed) sees
    inserts and deletes immediately."""
    from repro.serve.anns_service import BatchingANNSService
    cfg, data, new_vecs, queries, index = index_and_data
    new_ids = index.insert(new_vecs)
    victim = new_ids[0]
    index.delete(np.array([victim]))
    svc = BatchingANNSService(index, max_batch=8, max_wait_s=0.0)
    for v in new_vecs[:8]:
        svc.submit(SearchRequest(query=v))
    responses = svc.drain()
    assert len(responses) == 8
    for r in responses:
        assert victim not in set(r.ids.tolist())
    # the other inserted ids are findable through the service
    by_rid = sorted(responses, key=lambda r: r.rid)
    hits = sum(int(r.ids[0] == nid)
               for r, nid in zip(by_rid[1:8], new_ids[1:8]))
    assert hits >= 5


def test_insert_extends_all_tiers(index_and_data):
    cfg, data, new_vecs, queries, index = index_and_data
    n0 = len(index.ssd.vectors)
    p0 = index.ssd.layout.n_pages
    index.insert(new_vecs)
    assert len(index.ssd.vectors) == n0 + 20          # SSD tier
    assert index.codes.shape[0] == n0 + 20            # HBM tier
    assert index.ssd.layout.n_pages > p0              # fresh pages
    total_members = sum(len(m) for m in index.posting.members)
    assert total_members >= n0 + 20                   # DRAM metadata
