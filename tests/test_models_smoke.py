"""Required per-arch smoke tests: every assigned architecture x shape runs a
REDUCED forward/train step on CPU, asserting output shapes + no NaNs."""

import jax
import numpy as np
import pytest

from repro.configs import shapes_for
from repro.configs.registry import ARCH_IDS, get_config
from repro.models.api import build_cell, realize

CASES = [(a, s.shape_id) for a in ARCH_IDS
         for s in shapes_for(get_config(a, reduced=True))]


@pytest.mark.slow
@pytest.mark.parametrize("arch,shape", CASES,
                         ids=[f"{a}-{s}" for a, s in CASES])
def test_smoke_cell(arch, shape):
    cell = build_cell(arch, shape, mesh=None, reduced=True)
    args = realize(cell)
    out = jax.jit(cell.fn)(*args)
    leaves = jax.tree_util.tree_leaves(out)
    assert leaves, "step returned nothing"
    for leaf in leaves:
        arr = np.asarray(leaf)
        if np.issubdtype(arr.dtype, np.floating):
            assert np.isfinite(arr).all(), f"NaN/Inf in {arch}/{shape}"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_instantiates(arch):
    """The FULL published configs must at least build their abstract cell
    (shapes/specs consistent) without allocation."""
    cfg = get_config(arch)
    shape = shapes_for(cfg)[0].shape_id
    cell = build_cell(arch, shape, mesh=None, reduced=False)
    assert cell.args


@pytest.mark.slow
def test_lm_train_loss_is_sane():
    """Reduced LM: initial loss ~ ln(vocab)."""
    import jax.numpy as jnp
    from repro.data.synthetic import lm_batch
    from repro.models import transformer as tfm
    cfg = get_config("qwen3-0.6b", reduced=True)
    params = tfm.init_lm(jax.random.key(0), cfg)
    rng = np.random.default_rng(0)
    b = lm_batch(rng, 2, 32, cfg.vocab_size)
    loss, _ = tfm.lm_loss(params, {k: jnp.asarray(v) for k, v in b.items()},
                          cfg, dtype=jnp.float32)
    assert abs(float(loss) - np.log(cfg.vocab_size)) < 0.5
