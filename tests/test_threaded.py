"""Threaded serving runtime (ISSUE 3 acceptance).

Contract under test:
* with the pump thread + ticker enabled, N producer threads submitting
  mixed-``k`` requests get BIT-IDENTICAL ids to a synchronous ``run()``
  of the same queries;
* ``ticket.events`` shows at least one out-of-order ``finish`` retirement
  under ``inflight_depth >= 2`` — the ticker retires a younger window
  whose scan landed while the pump thread is still re-ranking an older
  one;
* graceful shutdown (``stop()``) drains the queue and leaves ZERO pending
  futures;
* ``BatchTicket.wait()`` raises :class:`FutureError` naming the stalled
  window instead of returning silently with pending futures (satellite
  regression).

The out-of-order probe injects a deterministic delay into the heavy
query's re-rank (monkeypatched ``heuristic_rerank``) — results are
unchanged, but the older window reliably out-lives its younger
neighbours' retirement, so the probe does not depend on scheduler luck.
"""

import threading
import time

import numpy as np
import pytest

from repro.core import executor as executor_mod
from repro.core.futures import (BackpressureError, FutureError, BatchTicket,
                                QueryFuture)
from repro.serve.anns_service import BatchingANNSService
from repro.serve.client import SearchRequest

HEAVY_K = 10          # requests with this k get a delayed re-rank (probe)


def _finishes(events):
    return [wi for kind, wi in events if kind == "finish"]


def _out_of_order(events) -> bool:
    """True when some younger window finished before an older one."""
    fins = _finishes(events)
    return any(fins[i] > fins[i + 1] for i in range(len(fins) - 1))


@pytest.fixture
def slow_heavy_rerank(monkeypatch):
    """Delay the re-rank of k == HEAVY_K queries (ids unchanged)."""
    real = executor_mod.heuristic_rerank

    def delayed(query, candidate_ids, ssd, k, **kw):
        if k == HEAVY_K:
            time.sleep(0.02)
        return real(query, candidate_ids, ssd, k, **kw)

    monkeypatch.setattr(executor_mod, "heuristic_rerank", delayed)


def test_threaded_stress_parity_out_of_order_shutdown(anns_bundle,
                                                      slow_heavy_rerank):
    """The acceptance stress test: 8 producers, mixed k, one replica."""
    b = anns_bundle
    n_producers = 8
    per_producer = 6
    ks = [HEAVY_K, 1, 3, 5, 1, 7, 2, 4]
    svc = BatchingANNSService(b.index, max_batch=8, max_wait_s=0.003,
                              scan_window=1, inflight_depth=3,
                              threaded=True)
    futures = {}
    errors = []

    def producer(tid):
        for i in range(per_producer):
            qi = (tid * per_producer + i) % len(b.queries)
            k = ks[(tid + i) % len(ks)]
            while True:
                try:
                    fut = svc.submit(SearchRequest(query=b.queries[qi], k=k))
                    break
                except BackpressureError:
                    time.sleep(1e-3)
            futures[(tid, i)] = (qi, k, fut)

    threads = [threading.Thread(target=producer, args=(t,))
               for t in range(n_producers)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    # resolve every future from the submitting side (condition-variable
    # waits against the pump thread)
    results = {}
    for key, (qi, k, fut) in futures.items():
        try:
            results[key] = (qi, k, fut.result(timeout=120).ids)
        except Exception as exc:              # noqa: BLE001 — fail the test
            errors.append((key, exc))
    assert not errors, errors

    # a deterministic out-of-order wave: one heavy window followed by
    # light ones — the ticker retires the lights while the pump thread is
    # still inside the heavy re-rank
    wave = [svc.submit(SearchRequest(query=b.queries[0], k=HEAVY_K))]
    wave += [svc.submit(SearchRequest(query=b.queries[i], k=1)) for i in range(1, 8)]
    for f in wave:
        f.result(timeout=120)

    svc.stop()

    # 1) bit-identical ids to the synchronous path
    for qi, k, ids in results.values():
        np.testing.assert_array_equal(
            ids, b.index.query(b.queries[qi], k=k).ids)
    # 2) at least one out-of-order finish under inflight_depth >= 2
    assert any(_out_of_order(ev) for ev in svc.ticket_events), \
        [(len(ev), _finishes(ev)) for ev in svc.ticket_events]
    # 3) shutdown left zero pending futures anywhere
    assert all(fut.done() for _, _, fut in futures.values())
    assert all(f.done() for f in wave)
    assert not svc._queue and svc._serving == 0


def test_threaded_matches_sync_service(anns_bundle):
    """Same queries through the threaded and synchronous harnesses give
    identical ids (threading is a scheduling choice, not a result knob)."""
    b = anns_bundle
    sync = BatchingANNSService(b.index, max_batch=4, max_wait_s=0.0,
                               scan_window=2, inflight_depth=2)
    sync_futs = [sync.submit(SearchRequest(query=q)) for q in b.queries[:8]]
    sync.drain()

    thr = BatchingANNSService(b.index, max_batch=4, max_wait_s=0.002,
                              scan_window=2, inflight_depth=2,
                              threaded=True)
    thr_futs = [thr.submit(SearchRequest(query=q)) for q in b.queries[:8]]
    got = [f.result(timeout=120).ids for f in thr_futs]
    thr.stop()
    ref = [f.result().ids for f in sync_futs]
    np.testing.assert_array_equal(np.stack(ref), np.stack(got))


def test_threaded_shutdown_drains(anns_bundle):
    """stop() is a graceful drain: queued-but-unserved requests are still
    served before the pump thread exits."""
    b = anns_bundle
    svc = BatchingANNSService(b.index, max_batch=4, max_wait_s=5.0,
                              threaded=True)
    futs = [svc.submit(SearchRequest(query=q)) for q in b.queries[:10]]
    svc.stop()                                # immediate shutdown request
    assert all(f.done() for f in futs)
    assert not svc._queue
    for q, f in zip(b.queries, futs):
        np.testing.assert_array_equal(f.result().ids,
                                      b.index.query(q).ids)


def test_blocking_future_waits_for_pump_thread(anns_bundle):
    """result() on a threaded-service future is a real blocking wait: no
    driving from the caller, the pump thread resolves it."""
    b = anns_bundle
    with BatchingANNSService(b.index, max_batch=64,
                             max_wait_s=0.01) as svc:
        fut = svc.submit(SearchRequest(query=b.queries[0]))
        assert fut._driver is None            # nothing to drive: we wait
        resp = fut.result(timeout=120)
        np.testing.assert_array_equal(resp.ids,
                                      b.index.query(b.queries[0]).ids)
    assert svc._pump_thread is None and svc._ticker_thread is None


def test_threaded_cancel_and_deadline(anns_bundle):
    b = anns_bundle
    svc = BatchingANNSService(b.index, max_batch=8, max_wait_s=0.01,
                              threaded=True)
    live = svc.submit(SearchRequest(query=b.queries[0]))
    dead = svc.submit(SearchRequest(query=b.queries[1], deadline_s=0.0))
    gone = svc.submit(SearchRequest(query=b.queries[2]))
    assert gone.cancel()
    np.testing.assert_array_equal(live.result(timeout=120).ids,
                                  b.index.query(b.queries[0]).ids)
    with pytest.raises(Exception):
        dead.result(timeout=120)
    svc.stop()
    assert gone.cancelled() and dead.done() and live.done()


def test_poison_request_resolves_future_and_replica_survives(anns_bundle):
    """A request that makes the batch fail (wrong dim) must resolve its
    future with FutureError — not hang its waiter — and must NOT kill the
    pump thread: the replica keeps serving."""
    b = anns_bundle
    svc = BatchingANNSService(b.index, max_batch=1, max_wait_s=0.001,
                              threaded=True)
    bad = svc.submit(SearchRequest(query=np.ones(7, np.float32)))  # dim mismatch vs the index
    with pytest.raises(FutureError):
        bad.result(timeout=60)
    good = svc.submit(SearchRequest(query=b.queries[0]))           # replica still alive
    np.testing.assert_array_equal(good.result(timeout=60).ids,
                                  b.index.query(b.queries[0]).ids)
    assert svc.stats.get("pump_errors", 0) >= 1
    svc.stop()


# ----------------------------------------------------- wait() stall (sat. 2)

def test_ticket_wait_stall_raises_future_error():
    """Satellite regression: wait() with pending futures and a stalled
    producer must raise FutureError naming the problem, not return
    silently so results() fails far from the cause."""
    fut = QueryFuture(tag=7)
    ticket = BatchTicket([fut])
    with pytest.raises(FutureError, match="still pending"):
        ticket.wait()

    # a dispatched-but-never-finished window is named in the error
    fut2 = QueryFuture(tag=3)
    ticket2 = BatchTicket([fut2], events=[("dispatch", 0)])
    with pytest.raises(FutureError, match=r"stalled window\(s\) \[0\]"):
        ticket2.wait()
