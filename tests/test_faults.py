"""Deterministic fault injection for the threaded serving runtime
(ISSUE 4 satellite).

Every fault here is injected at a seam (monkeypatched class/module
attribute or an event-gated wrapper), never with sleeps-and-hope:

* **lost/stalled scan window** — ``_InflightQueue.commit`` swallows the
  window (the dispatch happened, the scan never lands anywhere the pump
  can see).  ``BatchTicket.wait()`` must raise :class:`FutureError`
  NAMING the stalled window, and through the threaded service the same
  fault must resolve the waiting futures with that error instead of
  hanging them — then the replica keeps serving once the fault clears;
* **cancel-after-retire race** — a ``cancel()`` that loses the race
  against retirement returns False and leaves the result intact; a
  cancel that lands between dispatch and retirement skips ONLY its own
  re-rank (counted via a wrapped ``heuristic_rerank``);
* **poison batch** — a request that fails its batch (dim mismatch)
  resolves only that batch's futures with :class:`FutureError`; the
  replica's pump thread survives, and the ROUTER keeps serving on every
  replica.
"""

import threading
import time

import numpy as np
import pytest

from repro.core import executor as executor_mod
from repro.core.futures import CancelledError, FutureError
from repro.serve.anns_service import BatchingANNSService
from repro.serve.router import ReplicaRouter
from repro.serve.client import SearchRequest


def _swallow_commit(self, w):
    """Fault: the depth slot is released but the window never becomes
    retirable — a scan that was dispatched and then lost."""
    self._reserved -= 1


# ------------------------------------------------------------ stalled scan

def test_lost_window_stall_raises_naming_window(anns_bundle, monkeypatch):
    b = anns_bundle
    monkeypatch.setattr(executor_mod._InflightQueue, "commit",
                        _swallow_commit)
    ticket = b.index.executor.submit(b.queries[:2], b.index.plan(window=1))
    with pytest.raises(FutureError, match=r"stalled window\(s\) \[0, 1\]"):
        ticket.wait()
    assert not ticket.futures[0].done() and not ticket.futures[1].done()


def test_stalled_scan_resolves_futures_and_replica_recovers(anns_bundle,
                                                            monkeypatch):
    """Through the threaded service: the stall must surface on the
    request futures (naming the window) — never hang their waiters — and
    the replica must keep serving after the fault clears."""
    b = anns_bundle
    svc = BatchingANNSService(b.index, max_batch=2, max_wait_s=0.001,
                              threaded=True)
    with monkeypatch.context() as m:
        m.setattr(executor_mod._InflightQueue, "commit", _swallow_commit)
        doomed = svc.submit(SearchRequest(query=b.queries[0]))
        with pytest.raises(FutureError, match=r"stalled window"):
            doomed.result(timeout=60)
    # fault cleared: same replica, same pump thread, normal service
    good = svc.submit(SearchRequest(query=b.queries[1]))
    np.testing.assert_array_equal(good.result(timeout=60).ids,
                                  b.index.query(b.queries[1]).ids)
    assert svc.stats.get("pump_errors", 0) >= 1
    svc.stop()
    assert not svc._queue and svc._serving == 0


# --------------------------------------------------- cancel-vs-retire races

def test_cancel_after_retire_loses_and_keeps_result(anns_bundle):
    b = anns_bundle
    with BatchingANNSService(b.index, max_batch=4,
                             max_wait_s=0.001) as svc:
        fut = svc.submit(SearchRequest(query=b.queries[0]))
        resp = fut.result(timeout=60)          # retired: race already lost
        assert fut.cancel() is False
        assert not fut.cancelled() and fut.done()
        # the stored result survives the late cancel
        np.testing.assert_array_equal(fut.result().ids, resp.ids)
        np.testing.assert_array_equal(resp.ids,
                                      b.index.query(b.queries[0]).ids)


def test_cancel_between_dispatch_and_retire_skips_only_own_rerank(
        anns_bundle, monkeypatch):
    """Both windows are dispatched (scans in flight), nothing retired yet;
    cancelling query 1 must skip exactly its re-rank and leave query 0
    bit-identical."""
    b = anns_bundle
    calls = []
    real = executor_mod.heuristic_rerank

    def counting(query, candidate_ids, ssd, k, **kw):
        calls.append(len(candidate_ids))
        return real(query, candidate_ids, ssd, k, **kw)

    monkeypatch.setattr(executor_mod, "heuristic_rerank", counting)
    ticket = b.index.executor.submit(
        b.queries[:2], b.index.plan(window=1, inflight_depth=2))
    assert ticket.futures[1].cancel()
    ticket.wait()
    assert ticket.futures[1].cancelled()
    with pytest.raises(CancelledError):
        ticket.futures[1].result()
    assert len(calls) == 1                     # only query 0 re-ranked
    np.testing.assert_array_equal(ticket.futures[0].result().ids,
                                  b.index.query(b.queries[0]).ids)


# ------------------------------------------------------------- poison batch

def test_poison_batch_fails_own_futures_router_keeps_serving(anns_bundle):
    b = anns_bundle
    router = ReplicaRouter(b.index, n_replicas=2, policy="round_robin",
                           threaded=True, max_batch=1, max_wait_s=0.001)
    bad = router.submit(SearchRequest(query=np.ones(7, np.float32)))    # dim mismatch
    with pytest.raises(FutureError):
        bad.result(timeout=60)
    # both replicas still serve after the poison batch (round-robin
    # guarantees the poisoned replica gets fresh traffic too)
    goods = [router.submit(SearchRequest(query=q)) for q in b.queries[:4]]
    for q, f in zip(b.queries[:4], goods):
        np.testing.assert_array_equal(f.result(timeout=60).ids,
                                      b.index.query(q).ids)
    roll = router.stats_rollup()
    assert roll["routed"] == [3, 2]            # poison + 2 / 2 goods
    assert sum(s.get("pump_errors", 0) for s in roll["per_replica"]) >= 1
    router.stop()
    for svc in router.replicas:
        assert not svc._queue and svc._serving == 0


def test_poison_batch_does_not_poison_batchmates_futures_forever(
        anns_bundle):
    """A poison request coalesced WITH a good one fails that whole batch
    (its own futures), but a resubmission of the good query on the healed
    queue succeeds — the failure never outlives its batch."""
    b = anns_bundle
    svc = BatchingANNSService(b.index, max_batch=4, max_wait_s=10.0)
    bad = svc.submit(SearchRequest(query=np.ones(7, np.float32)))
    good = svc.submit(SearchRequest(query=b.queries[0]))
    # sync harness: the pump re-raises the original fault AFTER resolving
    # the batch futures with FutureError
    with pytest.raises(Exception):
        svc.pump(force=True)
    assert isinstance(bad.exception(), FutureError)
    assert isinstance(good.exception(), FutureError)
    retry = svc.submit(SearchRequest(query=b.queries[0]))
    svc.drain()
    np.testing.assert_array_equal(retry.result().ids,
                                  b.index.query(b.queries[0]).ids)
