"""Optimizer + gradient compression."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _propshim import given, settings, strategies as st

from repro.optim.adamw import (OptimizerConfig, adamw_init, adamw_update,
                               cosine_lr, global_norm)
from repro.optim.compress import compress_decompress, ef_compress_grads, \
    ef_init


def test_adamw_optimizes_quadratic():
    cfg = OptimizerConfig(lr=0.1, warmup_steps=1, total_steps=200,
                          weight_decay=0.0, clip_norm=100.0)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = adamw_init(params)
    for _ in range(150):
        grads = {"w": 2.0 * params["w"]}
        params, state, _ = adamw_update(grads, state, params, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.2


def test_grad_clipping():
    cfg = OptimizerConfig(lr=1e-3, clip_norm=1.0, warmup_steps=0,
                          total_steps=10)
    params = {"w": jnp.zeros(4)}
    state = adamw_init(params)
    big = {"w": jnp.full(4, 1e6)}
    new, state, m = adamw_update(big, state, params, cfg)
    assert np.isfinite(np.asarray(new["w"])).all()
    assert float(m["grad_norm"]) > 1.0    # recorded pre-clip


def test_cosine_schedule_shape():
    cfg = OptimizerConfig(lr=1.0, warmup_steps=10, total_steps=100,
                          min_lr_frac=0.1)
    lrs = [float(cosine_lr(jnp.asarray(s), cfg)) for s in range(101)]
    assert lrs[0] == 0.0
    assert abs(lrs[10] - 1.0) < 1e-6
    assert lrs[100] == pytest.approx(0.1, rel=1e-3)
    assert all(a >= b - 1e-9 for a, b in zip(lrs[10:], lrs[11:]))


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 999), bits=st.sampled_from([4, 8]))
def test_ef_invariant(seed, bits):
    """Error feedback: transmitted + residual == grad + old residual."""
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.standard_normal(64), jnp.float32)
    r = jnp.asarray(0.1 * rng.standard_normal(64), jnp.float32)
    dq, new_r = compress_decompress(g + r, bits)
    np.testing.assert_allclose(np.asarray(dq + new_r), np.asarray(g + r),
                               rtol=1e-5, atol=1e-5)


def test_compression_reduces_information_but_bounded():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.standard_normal(1000), jnp.float32)
    dq, res = compress_decompress(g, 8)
    scale = float(jnp.max(jnp.abs(g))) / 127
    assert float(jnp.max(jnp.abs(res))) <= scale  # quantisation bound


def test_ef_training_converges():
    """int8-EF AdamW still optimizes (convergence sanity)."""
    cfg = OptimizerConfig(lr=0.1, warmup_steps=1, total_steps=300,
                          weight_decay=0.0)
    params = {"w": jnp.asarray([4.0, -2.0, 1.0])}
    state = adamw_init(params)
    residual = ef_init(params)
    for _ in range(200):
        grads = {"w": 2.0 * params["w"]}
        grads, residual = ef_compress_grads(grads, residual, 8)
        params, state, _ = adamw_update(grads, state, params, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.3


def test_global_norm():
    t = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
    assert float(global_norm(t)) == pytest.approx(5.0)
