"""Serving correctness: the decode/KV-cache path must agree with the
teacher-forced forward pass (the strongest cache test there is)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.models import transformer as tfm
from repro.serve.engine import LMServer, ServeConfig


@pytest.mark.parametrize("arch", [
    "qwen3-0.6b",
    pytest.param("chatglm3-6b", marks=pytest.mark.slow),
    pytest.param("deepseek-v2-lite-16b", marks=pytest.mark.slow),
    pytest.param("qwen3-moe-30b-a3b", marks=pytest.mark.slow)])
def test_decode_matches_forward(arch):
    """Per-position logits from step-by-step decode == full forward.

    MoE configs get a high capacity factor so the *training* path drops no
    tokens either (decode never drops by construction)."""
    import dataclasses
    cfg = get_config(arch, reduced=True)
    if cfg.moe:
        cfg = dataclasses.replace(cfg, capacity_factor=16.0)
    params = tfm.init_lm(jax.random.key(0), cfg)
    rng = np.random.default_rng(0)
    B, S = 2, 12
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    full_logits = tfm.lm_forward(params, tokens, cfg, dtype=jnp.float32)

    cache = tfm.init_kv_cache(cfg, B, S, dtype=jnp.float32)
    step = jax.jit(lambda p, c, t, pos: tfm.lm_decode_step(
        p, c, t, pos, cfg, dtype=jnp.float32), static_argnums=())
    outs = []
    for pos in range(S):
        logits, cache = step(params, cache, tokens[:, pos:pos + 1], pos)
        outs.append(np.asarray(logits[:, 0]))
    dec = np.stack(outs, axis=1)
    np.testing.assert_allclose(dec, np.asarray(full_logits),
                               rtol=2e-3, atol=2e-3)


def test_server_generates(rng):
    cfg = get_config("qwen3-0.6b", reduced=True)
    params = tfm.init_lm(jax.random.key(0), cfg)
    server = LMServer(params, cfg, ServeConfig(max_len=32))
    prompts = rng.integers(0, cfg.vocab_size, (2, 4), dtype=np.int32)
    out = server.generate(prompts, 8)
    assert out["tokens"].shape == (2, 8)
    assert (out["tokens"] >= 0).all() and (out["tokens"] < cfg.vocab_size).all()


def test_generation_deterministic_greedy(rng):
    cfg = get_config("qwen3-0.6b", reduced=True)
    params = tfm.init_lm(jax.random.key(0), cfg)
    server = LMServer(params, cfg, ServeConfig(max_len=32, temperature=0.0))
    prompts = rng.integers(0, cfg.vocab_size, (1, 4), dtype=np.int32)
    a = server.generate(prompts, 6)["tokens"]
    b = server.generate(prompts, 6)["tokens"]
    np.testing.assert_array_equal(a, b)


def test_rag_pipeline(rng):
    import dataclasses
    from repro.configs.anns_datasets import SIFT_SMALL
    from repro.core.engine import FusionANNSIndex
    from repro.data.synthetic import clustered_vectors
    from repro.serve.engine import RAGPipeline

    acfg = dataclasses.replace(SIFT_SMALL, n_vectors=1500, dim=16,
                               n_posting_fraction=0.02)
    data = clustered_vectors(rng, acfg.n_vectors, acfg.dim, n_clusters=12)
    index = FusionANNSIndex.build(data, acfg)
    cfg = get_config("qwen3-0.6b", reduced=True)
    params = tfm.init_lm(jax.random.key(0), cfg)
    server = LMServer(params, cfg, ServeConfig(max_len=32))
    ragp = RAGPipeline(index, server)
    out = ragp.answer(data[3], rng.integers(0, cfg.vocab_size, (1, 4),
                                            dtype=np.int32), n_tokens=4,
                      k=acfg.top_k)
    assert out["tokens"].shape == (1, 4)
    assert len(out["retrieved_ids"]) == acfg.top_k
    assert out["retrieval_stats"].ios >= 0

    # routed retrieval tier (DESIGN.md §5): bit-identical retrieved ids
    from repro.serve.router import ReplicaRouter
    with ReplicaRouter(index, n_replicas=2, policy="jsq", max_batch=4,
                       max_wait_s=0.001) as router:
        routed = RAGPipeline(index, server, router=router)
        out2 = routed.answer(data[3],
                             rng.integers(0, cfg.vocab_size, (1, 4),
                                          dtype=np.int32), n_tokens=4,
                             k=acfg.top_k)
    np.testing.assert_array_equal(out2["retrieved_ids"],
                                  out["retrieved_ids"])
