"""SSD-tier invariants: packing, dedup, buffer (paper §4.3)."""

import numpy as np
import pytest
from _propshim import given, settings, strategies as st

from repro.core.io_sim import (IOStats, PageBuffer, SSDSim, StorageLayout,
                               pack_buckets_maxmin)


@settings(max_examples=50, deadline=None)
@given(sizes=st.lists(st.integers(0, 100), min_size=1, max_size=40),
       per_page=st.integers(1, 32))
def test_maxmin_packing_valid(sizes, per_page):
    groups, n_pages = pack_buckets_maxmin(sizes, per_page)
    # every remainder bucket appears exactly once
    flat = [b for g in groups for b in g]
    expect = [i for i, s in enumerate(sizes) if s % per_page]
    assert sorted(flat) == sorted(expect)
    # no shared page overflows
    for g in groups:
        assert sum(sizes[b] % per_page for b in g) <= per_page
    # page count >= lower bound (total vectors / per_page)
    assert n_pages >= -(-sum(sizes) // per_page)


@settings(max_examples=25, deadline=None)
@given(n=st.integers(10, 400), n_clusters=st.integers(1, 20),
       vec_bytes=st.sampled_from([128, 256, 384]), seed=st.integers(0, 99))
def test_layout_maps_every_vector(n, n_clusters, vec_bytes, seed):
    rng = np.random.default_rng(seed)
    primary = rng.integers(0, n_clusters, n).astype(np.int64)
    lay = StorageLayout.build(primary, n_clusters, vec_bytes)
    assert lay.page_of.shape == (n,)
    assert (lay.page_of >= 0).all() and (lay.page_of < lay.n_pages).all()
    # page occupancy never exceeds per_page
    occ = np.bincount(lay.page_of)
    assert occ.max() <= lay.per_page


def test_optimized_layout_uses_fewer_or_equal_pages(rng):
    primary = rng.integers(0, 16, 500).astype(np.int64)
    opt = StorageLayout.build(primary, 16, 384, optimized=True)
    raw = StorageLayout.build(primary, 16, 384, optimized=False)
    assert opt.n_pages <= raw.n_pages + 16  # within remainder slack


def test_same_cluster_vectors_share_pages(rng):
    """Spatial locality: vectors of one bucket occupy contiguous pages."""
    primary = np.repeat(np.arange(4), 100).astype(np.int64)
    lay = StorageLayout.build(primary, 4, 384)
    for c in range(4):
        pages = np.unique(lay.page_of[primary == c])
        # 100 vectors * 384B / 4096 ~ 10 pages
        assert len(pages) <= 11


def _mk_ssd(rng, n=300, intra=True, buf=True, buffer_pages=64):
    data = rng.standard_normal((n, 32)).astype(np.float32)
    primary = rng.integers(0, 10, n).astype(np.int64)
    lay = StorageLayout.build(primary, 10, 128)
    return data, SSDSim(data, lay, buffer_pages=buffer_pages,
                        intra_merge=intra, use_buffer=buf)


def test_fetch_returns_correct_vectors(rng):
    data, ssd = _mk_ssd(rng)
    stats = ssd.begin_query()
    ids = np.array([5, 17, 42, 5, 99])
    out = ssd.fetch(ids, stats)
    np.testing.assert_array_equal(out, data[ids])


def test_intra_batch_merge_reduces_ios(rng):
    data, ssd_on = _mk_ssd(rng, intra=True, buf=False)
    _, ssd_off = _mk_ssd(rng, intra=False, buf=False)
    ids = np.arange(60)          # dense range -> many same-page hits
    s_on, s_off = ssd_on.begin_query(), ssd_off.begin_query()
    ssd_on.fetch(ids, s_on)
    ssd_off.fetch(ids, s_off)
    assert s_on.ios < s_off.ios
    assert s_on.pages_requested == s_off.pages_requested == 60


def test_buffer_dedups_across_batches(rng):
    data, ssd = _mk_ssd(rng, buf=True)
    stats = ssd.begin_query()
    ids = np.arange(40)
    ssd.fetch(ids, stats)
    first = stats.ios
    ssd.fetch(ids, stats)        # second mini-batch, same pages
    assert stats.ios == first    # all buffer hits
    assert stats.buffer_hits > 0


def test_buffer_scope_is_per_query(rng):
    data, ssd = _mk_ssd(rng, buf=True)
    s1 = ssd.begin_query()
    ssd.fetch(np.arange(20), s1)
    s2 = ssd.begin_query()       # new query clears the buffer
    ssd.fetch(np.arange(20), s2)
    assert s2.ios == s1.ios and s2.buffer_hits == 0


def test_buffer_is_inter_batch_only(rng):
    """Satellite (Fig. 12 attribution): with ``intra_merge=False`` the
    page buffer must NOT absorb same-page repeats inside one ``fetch()``
    — intra-batch dedup is the OTHER mechanism.  Buffer insertions are
    deferred to the end of the mini-batch."""
    n = 64
    data = rng.standard_normal((n, 32)).astype(np.float32)
    primary = np.zeros(n, np.int64)
    lay = StorageLayout.build(primary, 1, 128)     # 32 vecs/page -> 2 pages
    ssd = SSDSim(data, lay, intra_merge=False, use_buffer=True)
    stats = ssd.begin_query()
    ids = np.array([0, 1, 2, 33, 34])              # page 0 x3, page 1 x2
    ssd.fetch(ids, stats)
    assert stats.ios == 5                          # one I/O per request
    assert stats.buffer_hits == 0                  # nothing absorbed intra
    ssd.fetch(ids, stats)                          # next mini-batch
    assert stats.ios == 5                          # inter-batch: all hits
    assert stats.buffer_hits == 5


def test_dedup_attribution_ordering(rng):
    """Each mechanism only removes its own class of repeats: within one
    mini-batch buffer-only == no-dedup, and across batches the full
    config never beats buffer-only by more than the intra-batch merges."""
    ids = np.concatenate([np.arange(40), np.arange(20)])   # dup-heavy
    configs = {}
    for name, (intra, buf) in {"full": (True, True),
                               "buf_only": (False, True),
                               "none": (False, False)}.items():
        _, ssd = _mk_ssd(np.random.default_rng(7), intra=intra, buf=buf)
        stats = ssd.begin_query()
        ssd.fetch(ids, stats)                      # single mini-batch
        configs[name] = stats.ios
    assert configs["buf_only"] == configs["none"]  # buffer: inter only
    assert configs["full"] <= configs["buf_only"]


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 999), intra=st.sampled_from([True, False]),
       buf=st.sampled_from([True, False]), n_batches=st.integers(1, 6),
       buffer_pages=st.sampled_from([2, 8, 64]))
def test_dedup_counters_sum_to_pages_saved(seed, intra, buf, n_batches,
                                           buffer_pages):
    """Satellite (PR-3 Fig. 12 attribution lock-in): the two dedup
    mechanisms each count their OWN saves, and under any randomized
    workload they sum EXACTLY to the total pages saved —

        pages_requested - ios == intra_merged + buffer_hits

    with each counter pinned to zero when its mechanism is disabled (so
    neither mechanism can silently absorb the other's class of repeats,
    even under LRU eviction pressure)."""
    rng = np.random.default_rng(seed)
    data, ssd = _mk_ssd(rng, intra=intra, buf=buf,
                        buffer_pages=buffer_pages)
    stats = ssd.begin_query()
    id_rng = np.random.default_rng(seed + 1)
    for _ in range(n_batches):
        ids = id_rng.integers(0, 300, int(id_rng.integers(1, 60)))
        ssd.fetch(ids, stats)
    assert stats.pages_requested - stats.ios \
        == stats.intra_merged + stats.buffer_hits
    if not intra:
        assert stats.intra_merged == 0
    if not buf:
        assert stats.buffer_hits == 0
    assert stats.bytes_read == stats.ios * ssd.layout.page_bytes


def test_dedup_counter_merge_is_additive(rng):
    data, ssd = _mk_ssd(rng)
    s1, s2 = ssd.begin_query(), ssd.begin_query()
    ssd.fetch(np.arange(40), s1)
    ssd.fetch(np.concatenate([np.arange(20), np.arange(20)]), s2)
    m = s1.merge(s2)
    for f in ("ios", "pages_requested", "buffer_hits", "intra_merged",
              "bytes_read"):
        assert getattr(m, f) == getattr(s1, f) + getattr(s2, f)
    assert m.pages_requested - m.ios == m.intra_merged + m.buffer_hits


def test_lru_eviction(rng):
    buf = PageBuffer(capacity_pages=2)
    buf.insert(1), buf.insert(2), buf.insert(3)
    assert not buf.hit(1) and buf.hit(2) and buf.hit(3)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 999), n_ids=st.integers(1, 80))
def test_dedup_never_increases_ios(seed, n_ids):
    rng = np.random.default_rng(seed)
    data, ssd_opt = _mk_ssd(rng, intra=True, buf=True)
    rng = np.random.default_rng(seed)
    data, ssd_raw = _mk_ssd(rng, intra=False, buf=False)
    ids = np.random.default_rng(seed).integers(0, 300, n_ids)
    s_o, s_r = ssd_opt.begin_query(), ssd_raw.begin_query()
    o = ssd_opt.fetch(ids, s_o)
    r = ssd_raw.fetch(ids, s_r)
    np.testing.assert_array_equal(o, r)      # dedup never changes results
    assert s_o.ios <= s_r.ios
