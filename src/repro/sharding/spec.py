"""Logical sharding rules mapped onto physical mesh axes.

The framework uses a 2-D single-pod mesh ``("data", "model")`` and a 3-D
multi-pod mesh ``("pod", "data", "model")``.  Model code never names physical
axes directly; it asks the active :class:`Rules` for a logical axis:

  * ``batch``  — data parallel (pod x data on multi-pod meshes)
  * ``fsdp``   — weight sharding axis #1 (ZeRO-3 style; the "data" axis)
  * ``tensor`` — weight sharding axis #2 / sequence parallel axis ("model")
  * ``expert`` — expert parallel axis (aliases "tensor")
  * ``corpus`` — ANNS corpus row shards (all axes; the paper's pinned-HBM tier)

This keeps every model definition mesh-shape agnostic: the same code lowers on
1-device CPU test meshes, the 256-chip single pod and the 512-chip 2-pod mesh.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axis = Union[None, str, Tuple[str, ...]]


@dataclasses.dataclass(frozen=True)
class Rules:
    """Logical → physical axis mapping."""

    batch: Axis = "data"
    fsdp: Axis = "data"
    tensor: Axis = "model"
    expert: Axis = "model"
    corpus: Axis = ("data", "model")

    def spec(self, *logical: Optional[str]) -> P:
        """Build a PartitionSpec from logical axis names (None = replicated)."""
        out = []
        for name in logical:
            if name is None:
                out.append(None)
            else:
                out.append(getattr(self, name))
        return P(*out)


SINGLE_POD_RULES = Rules(
    batch="data",
    fsdp="data",
    tensor="model",
    expert="model",
    corpus=("data", "model"),
)

MULTI_POD_RULES = Rules(
    batch=("pod", "data"),
    fsdp="data",
    tensor="model",
    expert="model",
    corpus=("pod", "data", "model"),
)

# Single-device (tests / examples): everything replicated but specs stay valid
# because a (1, 1) mesh carries both axis names.
LOCAL_RULES = SINGLE_POD_RULES


def rules_for_mesh(mesh: Mesh) -> Rules:
    return MULTI_POD_RULES if "pod" in mesh.axis_names else SINGLE_POD_RULES


def local_rules_for_mesh(mesh: Mesh) -> Rules:
    """Rules used inside shard_map bodies (same mapping; kept for symmetry)."""
    return rules_for_mesh(mesh)


if hasattr(jax, "shard_map"):                      # jax >= 0.6 spelling
    def shard_map_compat(body, mesh, in_specs, out_specs):
        """``jax.shard_map`` across jax versions (0.4.x moved it under
        ``jax.experimental`` and called the check flag ``check_rep``)."""
        return jax.shard_map(body, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
else:                                              # jax 0.4.x spelling
    from jax.experimental.shard_map import shard_map as _shard_map_04

    def shard_map_compat(body, mesh, in_specs, out_specs):
        """``jax.shard_map`` across jax versions (0.4.x moved it under
        ``jax.experimental`` and called the check flag ``check_rep``)."""
        return _shard_map_04(body, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_rep=False)


def constrain(x, mesh: Mesh, spec: P):
    """with_sharding_constraint that is a no-op outside jit-with-mesh."""
    if mesh is None or mesh.empty:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def named(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)
