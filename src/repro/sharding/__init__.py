from repro.sharding.spec import (  # noqa: F401
    Rules,
    SINGLE_POD_RULES,
    MULTI_POD_RULES,
    LOCAL_RULES,
    constrain,
    local_rules_for_mesh,
    rules_for_mesh,
)
