"""Uniform Arch API: builds, for every (arch x shape) cell, the step
function + abstract inputs + shardings.  Used by launch/dryrun.py (AOT
lower+compile), tests (reduced smoke execution) and benchmarks.

``build_cell(arch_id, shape_id, mesh, reduced)`` returns a :class:`Cell`:
  * ``fn``            — the step callable (train_step / prefill / serve_step /
                        retrieval)
  * ``args``          — pytree of jax.ShapeDtypeStruct (dry-run) or a builder
                        for real arrays (smoke)
  * ``in_shardings``  — matching pytree of NamedSharding (None local)
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import base as cfgs
from repro.configs.base import GNNConfig, LMConfig, RecsysConfig, ShapeCell
from repro.configs.registry import get_config
from repro.core.topk import sharded_topk
from repro.models import gnn, recsys, transformer as tfm
from repro.models.layers import LOCAL_CTX, ShardCtx
from repro.optim.adamw import OptimizerConfig, adamw_init, adamw_update, \
    opt_state_specs
from repro.sharding.spec import Rules, rules_for_mesh


@dataclasses.dataclass
class Cell:
    arch: str
    shape_id: str
    step: str
    fn: Callable
    args: Tuple[Any, ...]
    in_shardings: Optional[Tuple[Any, ...]]
    donate_argnums: Tuple[int, ...] = ()
    init_fn: Optional[Callable] = None      # real param init (smoke tests)
    bounds: Optional[Dict[str, int]] = None  # int-leaf upper bounds by name


def realize(cell: Cell, seed: int = 0):
    """Materialise real (small) arguments for a cell — used by smoke tests.
    Params come from the arch's real init; int leaves are bounded by
    ``cell.bounds`` (matched by path substring); float leaves ~ 0.1*N(0,1)."""
    rng = np.random.default_rng(seed)
    bounds = cell.bounds or {}

    def conc(path, x):
        if not isinstance(x, jax.ShapeDtypeStruct):
            return x
        name = jax.tree_util.keystr(path)
        if jnp.issubdtype(x.dtype, jnp.integer):
            hi = 2
            for key, b in bounds.items():
                if key in name:
                    hi = b
                    break
            return jnp.asarray(rng.integers(0, max(hi, 1), x.shape), x.dtype)
        return jnp.asarray(0.1 * rng.standard_normal(x.shape), x.dtype)

    args = list(cell.args)
    if cell.init_fn is not None:
        params = cell.init_fn(jax.random.key(seed))
        if cell.step == "train_step":
            args[0] = {"params": params, "opt": adamw_init(params)}
        else:
            args[0] = params
    rest = jax.tree_util.tree_map_with_path(
        conc, tuple(args[1:]),
        is_leaf=lambda v: isinstance(v, jax.ShapeDtypeStruct))
    return (args[0],) + tuple(rest)


def _sds(tree):
    return jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def _shardings(mesh: Optional[Mesh], spec_tree):
    if mesh is None:
        return None
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s),
        spec_tree, is_leaf=lambda x: isinstance(x, P))


def _ctx(mesh: Optional[Mesh], rules: Optional[Rules] = None) -> ShardCtx:
    if mesh is None:
        return LOCAL_CTX
    return ShardCtx(mesh=mesh, rules=rules or rules_for_mesh(mesh))


OPT = OptimizerConfig()


# ---------------------------------------------------------------------------
# Generic train-step wrapper (loss_fn closed over config/ctx)
# ---------------------------------------------------------------------------

def _make_train_step(loss_fn):
    def train_step(state, batch):
        def lf(p):
            return loss_fn(p, batch)
        (_, metrics), grads = jax.value_and_grad(lf, has_aux=True)(
            state["params"])
        new_p, new_opt, om = adamw_update(grads, state["opt"],
                                          state["params"], OPT)
        return {"params": new_p, "opt": new_opt}, {**metrics, **om}
    return train_step


def _state_structs(init_fn, specs, mesh):
    params = jax.eval_shape(init_fn)
    state = {"params": params, "opt": jax.eval_shape(
        lambda: adamw_init(params))}
    sh = None
    if mesh is not None:
        spec_tree = {"params": specs, "opt": opt_state_specs(specs)}
        sh = _shardings(mesh, spec_tree)
    return state, sh


# ---------------------------------------------------------------------------
# LM cells
# ---------------------------------------------------------------------------

def _lm_cell(arch: str, cfg: LMConfig, cell: ShapeCell,
             mesh: Optional[Mesh], dims: Dict[str, int]) -> Cell:
    rules = rules_for_mesh(mesh) if mesh is not None else Rules()
    B, S = dims["global_batch"], dims["seq_len"]
    V = cfg.vocab_size
    specs = tfm.lm_param_specs(cfg, rules)
    init_k = lambda key: tfm.init_lm(key, cfg)

    if cell.step == "train_step":
        ctx = _ctx(mesh, rules)
        loss = functools.partial(tfm.lm_loss, cfg=cfg, ctx=ctx)
        fn = _make_train_step(lambda p, b: loss(p, b))
        state, state_sh = _state_structs(
            lambda: tfm.init_lm(jax.random.key(0), cfg), specs, mesh)
        batch = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
                 "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        batch_sh = _shardings(mesh, {
            "tokens": P(rules.batch, rules.tensor),
            "labels": P(rules.batch, rules.tensor)})
        return Cell(arch, cell.shape_id, "train_step", fn,
                    (state, batch), (state_sh, batch_sh) if mesh else None,
                    donate_argnums=(0,), init_fn=init_k)

    if cell.step == "prefill":
        ctx = _ctx(mesh, rules)
        fn = functools.partial(tfm.lm_prefill, cfg=cfg, ctx=ctx)
        params = jax.eval_shape(lambda: tfm.init_lm(jax.random.key(0), cfg))
        tokens = jax.ShapeDtypeStruct((B, S), jnp.int32)
        sh = (_shardings(mesh, specs),
              _shardings(mesh, P(rules.batch, rules.tensor))) \
            if mesh else None
        return Cell(arch, cell.shape_id, "prefill", fn, (params, tokens),
                    sh, init_fn=init_k)

    # serve_step (decode): KV cache sequence-sharded.  long_500k (B=1)
    # shards T over every mesh axis; decode_32k shards B over batch axes and
    # T over the model axis.
    # §Perf iteration E1: serving params are bf16 and sharded over the
    # tensor/expert axes ONLY (fsdp=None) — FSDP weight all-gathers per
    # decode step are the dominant collective otherwise (3.9 GB/step on
    # deepseek).  REPRO_OPT_SERVE_PARAMS=0 restores the training layout.
    import os as _os
    opt_serve = _os.environ.get("REPRO_OPT_SERVE_PARAMS", "1") == "1"
    if dims["global_batch"] == 1 and mesh is not None:
        rules = dataclasses.replace(
            rules, batch=None,
            tensor=tuple(mesh.axis_names))       # T gets all axes
        seq_axes = rules.tensor
    else:
        seq_axes = rules.tensor
    ctx = _ctx(mesh, dataclasses.replace(rules, tensor=None) if
               dims["global_batch"] == 1 and mesh is not None else rules)

    def serve_step(params, cache, tokens, pos):
        return tfm.lm_decode_step(params, cache, tokens, pos, cfg, ctx)

    params = jax.eval_shape(lambda: tfm.init_lm(jax.random.key(0), cfg))
    if opt_serve:
        params = jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, jnp.bfloat16)
            if jnp.issubdtype(x.dtype, jnp.floating) else x, params)
    cache = jax.eval_shape(
        lambda: tfm.init_kv_cache(cfg, B, S))
    tokens = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    sh = None
    if mesh is not None:
        # params always tensor-shard over "model" only (16-way keeps every
        # weight dim divisible; the all-axes tensor rule of long_500k is
        # for the KV cache, not weights)
        serve_rules = (dataclasses.replace(rules, fsdp=None, tensor="model")
                       if opt_serve else rules)
        serve_specs = tfm.lm_param_specs(cfg, serve_rules) if opt_serve \
            else specs
        cache_specs = tfm.kv_cache_specs(cfg, rules, seq_axes=seq_axes)
        sh = (_shardings(mesh, serve_specs), _shardings(mesh, cache_specs),
              _shardings(mesh, P(rules.batch, None)),
              NamedSharding(mesh, P()))
    return Cell(arch, cell.shape_id, "serve_step", serve_step,
                (params, cache, tokens, pos), sh, donate_argnums=(1,),
                init_fn=init_k)


# ---------------------------------------------------------------------------
# GNN cells
# ---------------------------------------------------------------------------

def _pad_to(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


def _gnn_cell(arch: str, cfg: GNNConfig, cell: ShapeCell,
              mesh: Optional[Mesh], dims: Dict[str, int]) -> Cell:
    rules = rules_for_mesh(mesh) if mesh is not None else Rules()
    ctx = _ctx(mesh, rules)
    n_dev = 1 if mesh is None else mesh.size
    d_feat = dims.get("d_feat", cfg.d_feat)
    n_classes = dims.get("n_classes", cfg.n_classes)
    init_k = lambda key: gnn.init_sage(key, cfg, d_feat, n_classes)
    init_fn = lambda: init_k(jax.random.key(0))
    specs = gnn.sage_param_specs(cfg, rules)

    if cell.shape_id == "minibatch_lg":
        B = dims["batch_nodes"]
        f0, f1 = dims["fanout0"], dims["fanout1"]

        def loss_fn(p, b):
            logits = gnn.sage_forward_minibatch(
                p, b["feats0"], b["feats1"], b["feats2"], cfg)
            return gnn.sage_loss(logits, b["labels"])
        fn = _make_train_step(loss_fn)
        state, state_sh = _state_structs(init_fn, specs, mesh)
        batch = {
            "feats0": jax.ShapeDtypeStruct((B, d_feat), jnp.float32),
            "feats1": jax.ShapeDtypeStruct((B, f0, d_feat), jnp.float32),
            "feats2": jax.ShapeDtypeStruct((B, f0, f1, d_feat), jnp.float32),
            "labels": jax.ShapeDtypeStruct((B,), jnp.int32),
        }
        bspec = {"feats0": P(rules.batch, None),
                 "feats1": P(rules.batch, None, None),
                 "feats2": P(rules.batch, None, None, None),
                 "labels": P(rules.batch)}
        return Cell(arch, cell.shape_id, "train_step", fn,
                    (state, batch),
                    (state_sh, _shardings(mesh, bspec)) if mesh else None,
                    donate_argnums=(0,), init_fn=init_k)

    # full-graph (sm / ogb_products) and molecule: edge-sharded aggregation.
    # +1 dummy node absorbs padding edges; labels mask excludes it.
    # REPRO_OPT_GNN=1 (default): dst-partitioned aggregation (§Perf
    # hillclimb B) — nodes padded to the mesh size, edges carry weights.
    import os
    use_dstpart = (mesh is not None and cell.shape_id != "molecule"
                   and os.environ.get("REPRO_OPT_GNN", "1") == "1")
    n_nodes = dims["n_nodes"] * dims.get("batch", 1) + 1
    if use_dstpart:
        n_nodes = _pad_to(n_nodes, n_dev)
    n_edges = _pad_to(dims["n_edges"] * dims.get("batch", 1),
                      max(n_dev, 1))
    is_mol = cell.shape_id == "molecule"
    n_graphs = dims.get("batch", 1)

    def loss_fn(p, b):
        if is_mol:
            logits = gnn.sage_forward_batched(
                p, b["features"], b["edges"], b["graph_ids"], n_graphs, cfg,
                ctx)
            return gnn.sage_loss(logits, b["labels"])
        if use_dstpart:
            logits = gnn.sage_forward_full_dstpart(
                p, b["features"], b["edges"], b["edge_weight"], cfg, ctx)
        else:
            logits = gnn.sage_forward_full(p, b["features"], b["edges"],
                                           cfg, ctx)
        return gnn.sage_loss(logits, b["labels"], b["mask"])

    fn = _make_train_step(loss_fn)
    state, state_sh = _state_structs(init_fn, specs, mesh)
    corpus = rules.corpus
    batch = {
        "features": jax.ShapeDtypeStruct((n_nodes, d_feat), jnp.float32),
        "edges": jax.ShapeDtypeStruct((n_edges, 2), jnp.int32),
    }
    bspec = {"features": P(None, None), "edges": P(corpus, None)}
    if is_mol:
        batch["graph_ids"] = jax.ShapeDtypeStruct((n_nodes,), jnp.int32)
        batch["labels"] = jax.ShapeDtypeStruct((n_graphs,), jnp.int32)
        bspec["graph_ids"] = P(None)
        bspec["labels"] = P(None)
    else:
        batch["labels"] = jax.ShapeDtypeStruct((n_nodes,), jnp.int32)
        batch["mask"] = jax.ShapeDtypeStruct((n_nodes,), jnp.float32)
        bspec["labels"] = P(None)
        bspec["mask"] = P(None)
        if use_dstpart:
            batch["edge_weight"] = jax.ShapeDtypeStruct((n_edges,),
                                                        jnp.float32)
            bspec["edge_weight"] = P(corpus)
    return Cell(arch, cell.shape_id, "train_step", fn, (state, batch),
                (state_sh, _shardings(mesh, bspec)) if mesh else None,
                donate_argnums=(0,), init_fn=init_k)


# ---------------------------------------------------------------------------
# RecSys cells
# ---------------------------------------------------------------------------

def _recsys_cell(arch: str, cfg: RecsysConfig, cell: ShapeCell,
                 mesh: Optional[Mesh], dims: Dict[str, int]) -> Cell:
    rules = rules_for_mesh(mesh) if mesh is not None else Rules()
    B = dims.get("batch", 1)
    if B == 1 and mesh is not None:          # retrieval_cand: replicate batch
        rules = dataclasses.replace(rules, batch=None)
    ctx = _ctx(mesh, rules)
    kind = cfg.kind

    # iteration C2b: only large serving batches use the tensor-axis table
    # resharding (see recsys.dlrm_param_specs docstring)
    bulk = cell.step == "serve_step" and B >= 16384
    if kind == "dlrm":
        init_k = lambda key: recsys.init_dlrm(key, cfg)
        specs = recsys.dlrm_param_specs(cfg, rules, bulk_serving=bulk)
    elif kind == "wide_deep":
        init_k = lambda key: recsys.init_wide_deep(key, cfg)
        specs = recsys.wide_deep_param_specs(cfg, rules, bulk_serving=bulk)
    elif kind == "bert4rec":
        init_k = lambda key: recsys.init_bert4rec(key, cfg)
        specs = recsys.bert4rec_param_specs(cfg, rules)
    elif kind == "mind":
        init_k = lambda key: recsys.init_mind(key, cfg)
        specs = recsys.mind_param_specs(cfg, rules)
    else:
        raise ValueError(kind)
    init_fn = lambda: init_k(jax.random.key(0))

    n_neg = 127

    def batch_struct():
        if kind == "dlrm":
            return ({"dense": jax.ShapeDtypeStruct((B, cfg.n_dense),
                                                   jnp.float32),
                     "sparse_ids": jax.ShapeDtypeStruct(
                         (B, cfg.n_sparse, cfg.multi_hot), jnp.int32),
                     "labels": jax.ShapeDtypeStruct((B,), jnp.float32)},
                    {"dense": P(rules.batch, None),
                     "sparse_ids": P(rules.batch, None, None),
                     "labels": P(rules.batch)})
        if kind == "wide_deep":
            return ({"sparse_ids": jax.ShapeDtypeStruct(
                        (B, cfg.n_sparse, cfg.multi_hot), jnp.int32),
                     "labels": jax.ShapeDtypeStruct((B,), jnp.float32)},
                    {"sparse_ids": P(rules.batch, None, None),
                     "labels": P(rules.batch)})
        if kind == "bert4rec":
            return ({"item_ids": jax.ShapeDtypeStruct((B, cfg.seq_len),
                                                      jnp.int32),
                     "mask_pos": jax.ShapeDtypeStruct((B,), jnp.int32),
                     "pos_items": jax.ShapeDtypeStruct((B,), jnp.int32),
                     "neg_items": jax.ShapeDtypeStruct((B, n_neg),
                                                       jnp.int32)},
                    {"item_ids": P(rules.batch, None),
                     "mask_pos": P(rules.batch),
                     "pos_items": P(rules.batch),
                     "neg_items": P(rules.batch, None)})
        return ({"hist_ids": jax.ShapeDtypeStruct((B, cfg.hist_len),
                                                  jnp.int32),
                 "pos_items": jax.ShapeDtypeStruct((B,), jnp.int32),
                 "neg_items": jax.ShapeDtypeStruct((B, n_neg), jnp.int32)},
                {"hist_ids": P(rules.batch, None),
                 "pos_items": P(rules.batch),
                 "neg_items": P(rules.batch, None)})

    def loss_fn(p, b):
        if kind == "dlrm":
            logit = recsys.dlrm_forward(p, b["dense"], b["sparse_ids"], cfg,
                                        ctx)
            return recsys.bce_loss(logit, b["labels"])
        if kind == "wide_deep":
            logit = recsys.wide_deep_forward(p, b["sparse_ids"], cfg, ctx)
            return recsys.bce_loss(logit, b["labels"])
        if kind == "bert4rec":
            return recsys.bert4rec_sampled_loss(
                p, b["item_ids"], b["mask_pos"], b["pos_items"],
                b["neg_items"], cfg, ctx)
        return recsys.mind_sampled_loss(
            p, b["hist_ids"], b["pos_items"], b["neg_items"], cfg, ctx)

    if cell.step == "train_step":
        fn = _make_train_step(loss_fn)
        state, state_sh = _state_structs(init_fn, specs, mesh)
        batch, bspec = batch_struct()
        return Cell(arch, cell.shape_id, "train_step", fn, (state, batch),
                    (state_sh, _shardings(mesh, bspec)) if mesh else None,
                    donate_argnums=(0,), init_fn=init_k)

    params = jax.eval_shape(init_fn)
    psh = _shardings(mesh, specs) if mesh else None

    if cell.step == "serve_step":
        k = 100

        def serve_step(p, b):
            if kind == "dlrm":
                return jax.nn.sigmoid(
                    recsys.dlrm_forward(p, b["dense"], b["sparse_ids"], cfg,
                                        ctx))
            if kind == "wide_deep":
                return jax.nn.sigmoid(
                    recsys.wide_deep_forward(p, b["sparse_ids"], cfg, ctx))
            if kind == "bert4rec":
                u = recsys.bert4rec_user_embedding(p, b["item_ids"], cfg, ctx)
                return recsys.score_all_items(u, p["item_embed"], k, ctx)
            # MIND: max over interests; score interest-by-interest inside a
            # fori_loop so only ONE (B, V_shard) score buffer is ever live
            # (an unrolled python loop co-allocates all K of them: +12 GiB
            # at serve_bulk scale).
            interests = recsys.mind_interests(p, b["hist_ids"], cfg, ctx)

            def one(i, best):
                v, _ = recsys.score_all_items(
                    jax.lax.dynamic_index_in_dim(interests, i, 1, False),
                    p["item_embed"], k, ctx)
                return jnp.maximum(best, v.astype(jnp.float32))
            best = jnp.full((B, k), -1e30, jnp.float32)
            return jax.lax.fori_loop(0, cfg.n_interests, one, best)

        batch, bspec = batch_struct()
        # serving batches don't need labels
        batch = {kk: v for kk, v in batch.items()
                 if kk not in ("labels", "pos_items", "neg_items",
                               "mask_pos")}
        bspec = {kk: v for kk, v in bspec.items() if kk in batch}
        return Cell(arch, cell.shape_id, "serve_step", serve_step,
                    (params, batch),
                    (psh, _shardings(mesh, bspec)) if mesh else None,
                    init_fn=init_k)

    # retrieval_cand: 1 query vs n_candidates rows of the item/first table.
    # The table has 2^20 rows (mesh-divisible); candidates beyond
    # n_candidates (exactly 10^6) are masked out of the top-k.
    n_cand = dims["n_candidates"]
    k = 100

    # scores follow the table's own row sharding (corpus for retrieval
    # deployments — iteration C2b keeps the tensor reshard for bulk only)
    row_axes = rules.corpus

    def retrieval(p, query):
        table = p["item_embed"] if "item_embed" in p else p["tables"][0]
        cand = table.astype(query.dtype)
        scores = jnp.einsum("bd,vd->bv", query, cand)
        V = cand.shape[0]
        if V > n_cand:
            scores = jnp.where(jnp.arange(V)[None] < n_cand, scores, -1e30)
        if ctx.mesh is not None:
            scores = jax.lax.with_sharding_constraint(
                scores, NamedSharding(ctx.mesh, P(None, row_axes)))
            return sharded_topk(scores, k, ctx, shard_axes=row_axes,
                                batch_axes=None)
        return jax.lax.top_k(scores, k)

    query = jax.ShapeDtypeStruct((B, cfg.embed_dim), jnp.float32)
    sh = (psh, NamedSharding(mesh, P(None, None))) if mesh else None
    return Cell(arch, cell.shape_id, "retrieval", retrieval, (params, query),
                sh, init_fn=init_k)


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------

def get_shape_cell(cfg, shape_id: str) -> ShapeCell:
    for c in cfgs.shapes_for(cfg):
        if c.shape_id == shape_id:
            return c
    raise KeyError(shape_id)


REDUCED_DIMS = {
    "seq_len": 64, "global_batch": 4, "batch": 4, "n_candidates": 512,
    "n_nodes": 64, "n_edges": 128, "batch_nodes": 8, "fanout0": 3,
    "fanout1": 2, "d_feat": 16, "n_classes": 4,
}


def build_cell(arch_id: str, shape_id: str, mesh: Optional[Mesh] = None,
               reduced: bool = False,
               dim_overrides: Optional[Dict[str, int]] = None) -> Cell:
    cfg = get_config(arch_id, reduced=reduced)
    cell = get_shape_cell(cfg, shape_id)
    dims = dict(cell.dims)
    if reduced:
        dims = {k: min(v, REDUCED_DIMS.get(k, v)) for k, v in dims.items()}
        if "batch" in dims and shape_id == "molecule":
            dims["batch"] = 4
    if dim_overrides:
        dims.update(dim_overrides)
    if isinstance(cfg, LMConfig):
        return _lm_cell(arch_id, cfg, cell, mesh, dims)
    if isinstance(cfg, GNNConfig):
        return _gnn_cell(arch_id, cfg, cell, mesh, dims)
    if isinstance(cfg, RecsysConfig):
        return _recsys_cell(arch_id, cfg, cell, mesh, dims)
    raise TypeError(type(cfg))
