"""RecSys architectures: DLRM, Wide&Deep, BERT4Rec, MIND.

The embedding LOOKUP is the hot path; JAX has no nn.EmbeddingBag, so we
implement it with ``jnp.take`` + ``jax.ops.segment_sum`` (ragged form) and a
dense fast path for fixed multi-hot (see kernel taxonomy §RecSys).  Tables
are row-sharded; huge-vocab scoring uses the two-level sharded top-k from
``repro.core.topk`` (the same collective the FusionANNS scan uses).
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import RecsysConfig
from repro.core.topk import sharded_topk
from repro.models.layers import ShardCtx, LOCAL_CTX, rms_norm, \
    blockwise_attention
from repro.sharding.spec import Rules

# §Perf hillclimb C: row-shard the ranking tables over the 16-way tensor
# axis only (all-reduce group 16 instead of 256) and gather in bf16.
# REPRO_OPT_RECSYS=0 restores the corpus-sharded f32 baseline (ablation).
import os
OPT_LOOKUP = os.environ.get("REPRO_OPT_RECSYS", "1") == "1"
_GATHER_DT = jnp.bfloat16 if OPT_LOOKUP else None


# ---------------------------------------------------------------------------
# EmbeddingBag
# ---------------------------------------------------------------------------

def embedding_bag_ragged(table: jax.Array, flat_ids: jax.Array,
                         segment_ids: jax.Array, n_bags: int,
                         mode: str = "mean") -> jax.Array:
    """EmbeddingBag over ragged bags: gather rows then segment-reduce.

    table (V, d); flat_ids (L,); segment_ids (L,) bag of each id."""
    rows = jnp.take(table, flat_ids, axis=0)                   # (L, d)
    if mode == "sum":
        return jax.ops.segment_sum(rows, segment_ids, num_segments=n_bags)
    if mode == "mean":
        s = jax.ops.segment_sum(rows, segment_ids, num_segments=n_bags)
        c = jax.ops.segment_sum(jnp.ones_like(flat_ids, rows.dtype),
                                segment_ids, num_segments=n_bags)
        return s / jnp.maximum(c, 1.0)[:, None]
    if mode == "max":
        return jax.ops.segment_max(rows, segment_ids, num_segments=n_bags)
    raise ValueError(mode)


def embedding_bag_dense(tables: jax.Array, ids: jax.Array,
                        mode: str = "mean",
                        gather_dtype=None) -> jax.Array:
    """Fixed multi-hot fast path.  tables (T, V, d), ids (B, T, M) ->
    (B, T, d).

    ``gather_dtype=bf16`` halves the bytes the partitioned gather's
    mask+all-reduce moves across the mesh (§Perf hillclimb C: the lookup
    collective is the serve_bulk bottleneck)."""
    if gather_dtype is not None:
        tables = tables.astype(gather_dtype)
    gathered = jax.vmap(lambda tab, idx: jnp.take(tab, idx, axis=0),
                        in_axes=(0, 1), out_axes=1)(tables, ids)  # (B,T,M,d)
    if mode == "sum":
        return gathered.sum(axis=2)
    if mode == "mean":
        return gathered.mean(axis=2)
    raise ValueError(mode)


def _mlp_init(rng, dims, name_prefix=""):
    out = []
    keys = jax.random.split(rng, len(dims) - 1)
    for i, k in enumerate(keys):
        std = 1.0 / math.sqrt(dims[i])
        out.append({"w": std * jax.random.normal(
            k, (dims[i], dims[i + 1]), jnp.float32),
            "b": jnp.zeros((dims[i + 1],), jnp.float32)})
    return out


def _mlp_apply(layers, x, final_act=None):
    for i, p in enumerate(layers):
        x = x @ p["w"].astype(x.dtype) + p["b"].astype(x.dtype)
        if i < len(layers) - 1:
            x = jax.nn.relu(x)
        elif final_act is not None:
            x = final_act(x)
    return x


def _mlp_specs(dims, r: Rules):
    # Ranking MLPs are small (<=1024 wide) with awkward dims (13, 415...):
    # replicated; the embedding tables carry all the memory and get sharded.
    return [{"w": P(None, None), "b": P(None)} for _ in range(len(dims) - 1)]


# ---------------------------------------------------------------------------
# DLRM [arXiv:1906.00091]
# ---------------------------------------------------------------------------

def _dlrm_top_dims(cfg: RecsysConfig):
    """Top-MLP input = pairwise dots among (bot_out + n_sparse) features
    concat bot_out (MLPerf DLRM); cfg.top_mlp lists the layer widths."""
    n_f = cfg.n_sparse + 1
    d_in = n_f * (n_f - 1) // 2 + cfg.embed_dim
    return [d_in] + list(cfg.top_mlp)


def init_dlrm(rng, cfg: RecsysConfig):
    k1, k2, k3 = jax.random.split(rng, 3)
    d = cfg.embed_dim
    return {
        "tables": 0.05 * jax.random.normal(
            k1, (cfg.n_sparse, cfg.vocab_size, d), jnp.float32),
        "bot": _mlp_init(k2, list(cfg.bot_mlp)),
        "top": _mlp_init(k3, _dlrm_top_dims(cfg)),
    }


def dlrm_param_specs(cfg: RecsysConfig, r: Rules,
                     bulk_serving: bool = False):
    """Iteration C2b: bulk-serving deployments reshard the tables to the
    16-way tensor axis (small all-reduce groups for the lookup); training
    keeps 256-way corpus sharding (16x less optimizer/table bytes per
    device).  Resharding happens at deployment load via
    train.checkpoint.restore(shardings=...)."""
    rows = r.tensor if (OPT_LOOKUP and bulk_serving) else r.corpus
    return {
        "tables": P(None, rows, None),
        "bot": _mlp_specs(list(cfg.bot_mlp), r),
        "top": _mlp_specs(_dlrm_top_dims(cfg), r),
    }


def dlrm_forward(params, dense, sparse_ids, cfg: RecsysConfig,
                 ctx: ShardCtx = LOCAL_CTX):
    """dense (B, 13) f32; sparse_ids (B, 26, M) int32 -> logit (B,)."""
    x = _mlp_apply(params["bot"], dense)                       # (B, d)
    # iteration C2: bf16 lookups only when the batch amortises the one-off
    # table downcast (serve_bulk yes; serve_p99/train no)
    gdt = _GATHER_DT if sparse_ids.shape[0] >= 16384 else None
    emb = embedding_bag_dense(params["tables"], sparse_ids,
                              gather_dtype=gdt)                # (B, T, d)
    emb = emb.astype(x.dtype)
    emb = ctx.constrain(emb, "batch", None, None)
    feats = jnp.concatenate([x[:, None], emb], axis=1)         # (B, T+1, d)
    inter = jnp.einsum("bid,bjd->bij", feats, feats)           # (B, F, F)
    n_f = feats.shape[1]
    iu, ju = jnp.triu_indices(n_f, k=1)
    flat = inter[:, iu, ju]                                    # (B, F(F-1)/2)
    top_in = jnp.concatenate([x, flat], axis=-1)
    return _mlp_apply(params["top"], top_in)[:, 0]


# ---------------------------------------------------------------------------
# Wide & Deep [arXiv:1606.07792]
# ---------------------------------------------------------------------------

def init_wide_deep(rng, cfg: RecsysConfig):
    k1, k2, k3 = jax.random.split(rng, 3)
    d = cfg.embed_dim
    deep_dims = [cfg.n_sparse * d] + list(cfg.mlp) + [1]
    return {
        "tables": 0.05 * jax.random.normal(
            k1, (cfg.n_sparse, cfg.vocab_size, d), jnp.float32),
        "wide": 0.01 * jax.random.normal(
            k2, (cfg.n_sparse, cfg.vocab_size, 1), jnp.float32),
        "deep": _mlp_init(k3, deep_dims),
        "bias": jnp.zeros((), jnp.float32),
    }


def wide_deep_param_specs(cfg: RecsysConfig, r: Rules,
                          bulk_serving: bool = False):
    d = cfg.embed_dim
    deep_dims = [cfg.n_sparse * d] + list(cfg.mlp) + [1]
    rows = r.tensor if (OPT_LOOKUP and bulk_serving) else r.corpus
    return {
        "tables": P(None, rows, None),
        "wide": P(None, rows, None),
        "deep": _mlp_specs(deep_dims, r),
        "bias": P(),
    }


def wide_deep_forward(params, sparse_ids, cfg: RecsysConfig,
                      ctx: ShardCtx = LOCAL_CTX):
    """sparse_ids (B, T, M) -> logit (B,)."""
    B = sparse_ids.shape[0]
    gdt = _GATHER_DT if B >= 16384 else None                   # iteration C2
    emb = embedding_bag_dense(params["tables"], sparse_ids,
                              gather_dtype=gdt)                # (B, T, d)
    emb = ctx.constrain(emb, "batch", None, None).astype(jnp.float32)
    deep = _mlp_apply(params["deep"], emb.reshape(B, -1))[:, 0]
    wide = embedding_bag_dense(params["wide"], sparse_ids,
                               mode="sum").astype(jnp.float32).sum(
        axis=(1, 2))
    return deep + wide + params["bias"].astype(deep.dtype)


# ---------------------------------------------------------------------------
# BERT4Rec [arXiv:1904.06690]
# ---------------------------------------------------------------------------

def init_bert4rec(rng, cfg: RecsysConfig):
    d, V = cfg.embed_dim, cfg.vocab_size
    keys = jax.random.split(rng, 3 + cfg.n_blocks)
    blocks = []
    for i in range(cfg.n_blocks):
        ks = jax.random.split(keys[3 + i], 4)
        std = 0.02
        blocks.append({
            "ln1": jnp.ones((d,), jnp.float32),
            "ln2": jnp.ones((d,), jnp.float32),
            "wqkv": std * jax.random.normal(ks[0], (d, 3 * d), jnp.float32),
            "wo": std * jax.random.normal(ks[1], (d, d), jnp.float32),
            "wi": std * jax.random.normal(ks[2], (d, 4 * d), jnp.float32),
            "wof": std * jax.random.normal(ks[3], (4 * d, d), jnp.float32),
        })
    return {
        "item_embed": 0.02 * jax.random.normal(keys[0], (V, d), jnp.float32),
        "pos_embed": 0.02 * jax.random.normal(
            keys[1], (cfg.seq_len, d), jnp.float32),
        "final_ln": jnp.ones((d,), jnp.float32),
        "blocks": blocks,
    }


def bert4rec_param_specs(cfg: RecsysConfig, r: Rules):
    blk = {"ln1": P(None), "ln2": P(None), "wqkv": P(None, None),
           "wo": P(None, None), "wi": P(None, None), "wof": P(None, None)}
    return {"item_embed": P(r.corpus, None), "pos_embed": P(None, None),
            "final_ln": P(None),
            "blocks": [dict(blk) for _ in range(cfg.n_blocks)]}


def bert4rec_encode(params, item_ids, cfg: RecsysConfig,
                    ctx: ShardCtx = LOCAL_CTX, dtype=jnp.float32):
    """item_ids (B, S) -> sequence repr (B, S, d).  Bidirectional blocks."""
    B, S = item_ids.shape
    d, H = cfg.embed_dim, cfg.n_heads
    x = (jnp.take(params["item_embed"], item_ids, axis=0)
         + params["pos_embed"][None, :S]).astype(dtype)
    x = ctx.constrain(x, "batch", None, None)
    for p in params["blocks"]:
        h = rms_norm(x, p["ln1"])
        qkv = h @ p["wqkv"].astype(dtype)
        q, k, v = jnp.split(qkv.reshape(B, S, 3 * H, d // H), 3, axis=2)
        a = blockwise_attention(q, k, v, causal=False,
                                block_size=min(512, S))
        x = x + a.reshape(B, S, d) @ p["wo"].astype(dtype)
        h = rms_norm(x, p["ln2"])
        x = x + jax.nn.gelu(h @ p["wi"].astype(dtype)) @ p["wof"].astype(dtype)
    return rms_norm(x, params["final_ln"])


def bert4rec_sampled_loss(params, item_ids, mask_pos, pos_items, neg_items,
                          cfg: RecsysConfig, ctx: ShardCtx = LOCAL_CTX):
    """Sampled-softmax masked-item loss.

    mask_pos (B,) masked position; pos_items (B,); neg_items (B, n_neg)."""
    h = bert4rec_encode(params, item_ids, cfg, ctx)            # (B, S, d)
    hm = jnp.take_along_axis(
        h, mask_pos[:, None, None].astype(jnp.int32), axis=1)[:, 0]  # (B, d)
    cand = jnp.concatenate([pos_items[:, None], neg_items], axis=1)
    ce = jnp.take(params["item_embed"], cand, axis=0).astype(h.dtype)
    logits = jnp.einsum("bd,bnd->bn", hm, ce).astype(jnp.float32)
    loss = jnp.mean(jax.scipy.special.logsumexp(logits, -1) - logits[:, 0])
    acc = jnp.mean(jnp.argmax(logits, -1) == 0)
    return loss, {"loss": loss, "accuracy": acc}


def bert4rec_user_embedding(params, item_ids, cfg: RecsysConfig,
                            ctx: ShardCtx = LOCAL_CTX):
    h = bert4rec_encode(params, item_ids, cfg, ctx)
    return h[:, -1]                                            # (B, d)


# ---------------------------------------------------------------------------
# MIND [arXiv:1904.08030] — multi-interest capsule routing
# ---------------------------------------------------------------------------

def init_mind(rng, cfg: RecsysConfig):
    k1, k2, k3 = jax.random.split(rng, 3)
    d = cfg.embed_dim
    return {
        "item_embed": 0.02 * jax.random.normal(
            k1, (cfg.vocab_size, d), jnp.float32),
        "bilinear": (1.0 / math.sqrt(d)) * jax.random.normal(
            k2, (d, d), jnp.float32),
        "proj": _mlp_init(k3, [d, 2 * d, d]),
    }


def mind_param_specs(cfg: RecsysConfig, r: Rules):
    return {"item_embed": P(r.corpus, None), "bilinear": P(None, None),
            "proj": _mlp_specs([cfg.embed_dim, 2 * cfg.embed_dim,
                                cfg.embed_dim], r)}


def _squash(z):
    n2 = jnp.sum(jnp.square(z), axis=-1, keepdims=True)
    return (n2 / (1.0 + n2)) * z / jnp.sqrt(n2 + 1e-9)


def mind_interests(params, hist_ids, cfg: RecsysConfig,
                   ctx: ShardCtx = LOCAL_CTX):
    """hist_ids (B, L) -> interest capsules (B, K, d) via dynamic routing."""
    B, Lh = hist_ids.shape
    K = cfg.n_interests
    e = jnp.take(params["item_embed"], hist_ids, axis=0)       # (B, L, d)
    e = ctx.constrain(e, "batch", None, None)
    eS = e @ params["bilinear"].astype(e.dtype)                # (B, L, d)

    def routing_iter(b, _):
        c = jax.nn.softmax(b, axis=1)                          # over K
        z = jnp.einsum("bkl,bld->bkd", c, eS)
        u = _squash(z)
        b_new = b + jnp.einsum("bkd,bld->bkl", u, eS)
        return b_new, u

    b0 = jnp.zeros((B, K, Lh), e.dtype)
    b_fin, us = jax.lax.scan(routing_iter, b0,
                             jnp.arange(cfg.capsule_iters))
    u = us[-1]                                                 # (B, K, d)
    return _mlp_apply(params["proj"], u)


def mind_sampled_loss(params, hist_ids, pos_items, neg_items,
                      cfg: RecsysConfig, ctx: ShardCtx = LOCAL_CTX,
                      pow_p: float = 2.0):
    interests = mind_interests(params, hist_ids, cfg, ctx)     # (B, K, d)
    cand = jnp.concatenate([pos_items[:, None], neg_items], axis=1)
    ce = jnp.take(params["item_embed"], cand, axis=0)          # (B, N, d)
    # label-aware attention: target attends over interests (train time)
    att = jnp.einsum("bkd,bnd->bkn", interests, ce)
    w = jax.nn.softmax(jnp.power(jnp.maximum(att, 0.0) + 1e-6, pow_p), axis=1)
    user = jnp.einsum("bkn,bkd->bnd", w, interests)            # (B, N, d)
    logits = jnp.sum(user * ce, axis=-1).astype(jnp.float32)
    loss = jnp.mean(jax.scipy.special.logsumexp(logits, -1) - logits[:, 0])
    acc = jnp.mean(jnp.argmax(logits, -1) == 0)
    return loss, {"loss": loss, "accuracy": acc}


# ---------------------------------------------------------------------------
# Shared serving / retrieval heads
# ---------------------------------------------------------------------------

def score_all_items(user_emb, item_table, k, ctx: ShardCtx,
                    shard_axes=None):
    """user_emb (B, d) x item_table (V, d) -> top-k (vals, ids).

    The (B, V) score matrix is sharded over ``shard_axes`` on V (default:
    the ``tensor`` axis, since batch already occupies the data axes) and
    reduced with the two-level top-k — only k pairs/shard cross the network.
    """
    # score matmul in bf16 (the (B,V) matrix is the footprint driver at
    # serve_bulk scale: 262144 x 2^20); top-k on bf16 values is exact
    # enough for retrieval, values reported back in f32 by callers.
    scores = jnp.einsum("bd,vd->bv", user_emb.astype(jnp.bfloat16),
                        item_table.astype(jnp.bfloat16))
    if ctx.mesh is not None:
        axes = shard_axes if shard_axes is not None else ctx.rules.tensor
        scores = ctx.constrain(scores, "batch", "tensor")
        return sharded_topk(scores, k, ctx, shard_axes=axes)
    return jax.lax.top_k(scores, k)


def bce_loss(logits, labels):
    logits = logits.astype(jnp.float32)
    labels = labels.astype(jnp.float32)
    loss = jnp.mean(jnp.maximum(logits, 0) - logits * labels
                    + jnp.log1p(jnp.exp(-jnp.abs(logits))))
    acc = jnp.mean((logits > 0) == (labels > 0.5))
    return loss, {"loss": loss, "accuracy": acc}
