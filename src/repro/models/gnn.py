"""GraphSAGE [arXiv:1706.02216] in pure JAX.

Message passing is ``gather(src) -> segment_sum(dst)`` over an edge index —
JAX has no CSR SpMM, so this IS the system's sparse layer (see kernel
taxonomy §GNN).  Three execution modes:

  * full-graph: edges (E, 2) + features (N, F); edges sharded over all mesh
    axes, per-shard partial aggregates all-reduced by GSPMD.
  * minibatch: dense sampled-neighborhood tensors from the uniform fanout
    sampler in ``repro.data.graphs`` (B, f0, F) / (B, f0, f1, F).
  * batched small graphs: block-diagonal flattening + per-graph readout.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import GNNConfig
from repro.models.layers import ShardCtx, LOCAL_CTX
from repro.sharding.spec import Rules, shard_map_compat


def init_sage(rng: jax.Array, cfg: GNNConfig,
              d_feat: Optional[int] = None,
              n_classes: Optional[int] = None) -> Dict[str, Any]:
    d_feat = d_feat or cfg.d_feat
    n_classes = n_classes or cfg.n_classes
    dims = [d_feat] + [cfg.d_hidden] * (cfg.n_layers - 1) + [n_classes]
    params: Dict[str, Any] = {"layers": []}
    keys = jax.random.split(rng, cfg.n_layers)
    for i in range(cfg.n_layers):
        k1, k2 = jax.random.split(keys[i])
        fan = dims[i]
        std = 1.0 / math.sqrt(fan)
        params["layers"].append({
            "w_self": std * jax.random.normal(k1, (dims[i], dims[i + 1]),
                                              jnp.float32),
            "w_neigh": std * jax.random.normal(k2, (dims[i], dims[i + 1]),
                                               jnp.float32),
            "b": jnp.zeros((dims[i + 1],), jnp.float32),
        })
    return params


def sage_param_specs(cfg: GNNConfig, r: Rules) -> Dict[str, Any]:
    # SAGE weights are tiny (d_feat x 128) and d_feat is rarely divisible by
    # the mesh (1433, 602, 100...): replicate, shard the *edges* instead.
    layer = {"w_self": P(None, None), "w_neigh": P(None, None), "b": P(None)}
    return {"layers": [dict(layer) for _ in range(cfg.n_layers)]}


def _mean_aggregate(h: jax.Array, edges: jax.Array, n_nodes: int,
                    ctx: ShardCtx, weights=None, dst_offset=None
                    ) -> jax.Array:
    """h (N, d), edges (E, 2) src->dst; returns mean over in-neighbors.

    ``weights`` (E,) lets the pipeline pad edge shards exactly (w=0 pads);
    ``dst_offset`` localises dst ids inside a dst-partitioned shard."""
    src, dst = edges[:, 0], edges[:, 1]
    if dst_offset is not None:
        dst = dst - dst_offset
    if weights is None:
        weights = jnp.ones((edges.shape[0],), h.dtype)
    msgs = jnp.take(h, src, axis=0) * weights[:, None].astype(h.dtype)
    agg = jax.ops.segment_sum(msgs, dst, num_segments=n_nodes)
    deg = jax.ops.segment_sum(weights.astype(h.dtype), dst,
                              num_segments=n_nodes)
    return agg / jnp.maximum(deg, 1.0)[:, None]


def _sage_layer(h_self, h_neigh, p, *, final: bool):
    out = (h_self @ p["w_self"].astype(h_self.dtype)
           + h_neigh @ p["w_neigh"].astype(h_self.dtype)
           + p["b"].astype(h_self.dtype))
    if final:
        return out
    out = jax.nn.relu(out)
    # L2 normalise (GraphSAGE §3.1 line 7)
    norm = jnp.linalg.norm(out.astype(jnp.float32), axis=-1, keepdims=True)
    return (out.astype(jnp.float32) / jnp.maximum(norm, 1e-6)).astype(out.dtype)


def sage_forward_full(params, feats, edges, cfg: GNNConfig,
                      ctx: ShardCtx = LOCAL_CTX, weights=None) -> jax.Array:
    """Full-graph forward: feats (N, F), edges (E, 2) -> logits (N, C)."""
    n_nodes = feats.shape[0]
    h = feats
    for i, p in enumerate(params["layers"]):
        h_neigh = _mean_aggregate(h, edges, n_nodes, ctx, weights)
        h = _sage_layer(h, h_neigh, p, final=(i == cfg.n_layers - 1))
    return h


def sage_forward_full_dstpart(params, feats, edges, weights,
                              cfg: GNNConfig, ctx: ShardCtx) -> jax.Array:
    """§Perf hillclimb B: dst-partitioned full-graph forward.

    Pipeline invariant: edges are range-partitioned by dst (device i holds
    exactly the edges whose dst lies in its node range; shards padded with
    w=0 edges).  Each device aggregates ONLY its own N/P nodes — the
    full-size partial-aggregate psum of the baseline disappears; the only
    collective left is the (N, d_hidden) all_gather of layer-1 outputs.
    """
    assert ctx.mesh is not None
    r = ctx.rules
    axes = r.corpus
    axes_t = (axes,) if isinstance(axes, str) else tuple(axes)
    n_shards = 1
    for a in axes_t:
        n_shards *= ctx.mesh.shape[a]
    n_nodes = feats.shape[0]
    assert n_nodes % n_shards == 0, (n_nodes, n_shards)
    n_loc = n_nodes // n_shards
    p1, p2 = params["layers"]
    assert cfg.n_layers == 2

    def body(feats, edges_l, w_l, p1, p2):
        me = jax.lax.axis_index(axes_t)
        lo = me * n_loc
        neigh = _mean_aggregate(feats, edges_l, n_loc, None, w_l,
                                dst_offset=lo)
        self_l = jax.lax.dynamic_slice_in_dim(feats, lo, n_loc)
        h1_l = _sage_layer(self_l, neigh, p1, final=False)
        # iteration B2: gather hidden states in 16 bits (halves the one
        # remaining collective; SAGE hiddens are L2-normalised, bf16-safe).
        # Shipped as u16 bit-patterns: integer collectives are immune to
        # the CPU backend's bf16->f32 float-normalisation (EXPERIMENTS.md
        # §Perf 0b), and TPU moves the same bytes either way.
        h1_bits = jax.lax.bitcast_convert_type(
            h1_l.astype(jnp.bfloat16), jnp.uint16)
        h1_g = jax.lax.all_gather(h1_bits, axes_t, axis=0, tiled=True)
        h1 = jax.lax.bitcast_convert_type(
            h1_g, jnp.bfloat16).astype(h1_l.dtype)                 # (N, d)
        neigh2 = _mean_aggregate(h1, edges_l, n_loc, None, w_l,
                                 dst_offset=lo)
        return _sage_layer(h1_l, neigh2, p2, final=True)

    pspec = jax.tree_util.tree_map(lambda x: P(*([None] * x.ndim)), p1)
    return shard_map_compat(
        body, mesh=ctx.mesh,
        in_specs=(P(None, None), P(axes, None), P(axes), pspec, pspec),
        out_specs=P(axes, None),
    )(feats, edges, weights, p1, p2)


def sage_forward_minibatch(params, feats0, feats1, feats2,
                           cfg: GNNConfig) -> jax.Array:
    """Sampled 2-hop forward.

    feats0 (B, F) batch nodes; feats1 (B, f0, F) 1-hop; feats2 (B, f0, f1, F)
    2-hop.  Layer 1 runs on (self=1-hop, neigh=2-hop) and (self=batch,
    neigh=1-hop); layer 2 combines them.
    """
    assert cfg.n_layers == 2
    p1, p2 = params["layers"]
    h1_hop1 = _sage_layer(feats1, feats2.mean(axis=2), p1, final=False)
    h1_self = _sage_layer(feats0, feats1.mean(axis=1), p1, final=False)
    return _sage_layer(h1_self, h1_hop1.mean(axis=1), p2, final=True)


def sage_forward_batched(params, feats, edges, graph_ids, n_graphs,
                         cfg: GNNConfig, ctx: ShardCtx = LOCAL_CTX):
    """Block-diagonal batched small graphs + mean readout -> (G, C)."""
    node_logits = sage_forward_full(params, feats, edges, cfg, ctx)
    summed = jax.ops.segment_sum(node_logits, graph_ids,
                                 num_segments=n_graphs)
    counts = jax.ops.segment_sum(
        jnp.ones((feats.shape[0],), node_logits.dtype), graph_ids,
        num_segments=n_graphs)
    return summed / jnp.maximum(counts, 1.0)[:, None]


def sage_loss(logits, labels, mask=None):
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    per = lse - ll
    if mask is None:
        mask = jnp.ones_like(per)
    n = jnp.maximum(jnp.sum(mask), 1.0)
    loss = jnp.sum(per * mask) / n
    acc = jnp.sum((jnp.argmax(logits, -1) == labels) * mask) / n
    return loss, {"loss": loss, "accuracy": acc}
