"""Decoder-only LM covering the five assigned LM architectures.

One code path parameterised by :class:`LMConfig`:
  * MHA / GQA (+ optional QKV bias, per-head qk RMSNorm, partial RoPE)
  * MLA (DeepSeek-V2) with compressed-KV absorbed decode
  * dense SwiGLU FFN or expert-parallel MoE (+ shared experts, first-k-dense)

Layers are a ``lax.scan`` over stacked weights (HLO size independent of
depth); every block is wrapped in ``jax.checkpoint`` (full remat) so the
blockwise attention never saves score matrices.
"""

from __future__ import annotations

import functools
import os
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from jax.ad_checkpoint import checkpoint_name as _checkpoint_name

from repro.configs.base import LMConfig
from repro.models import layers as L
from repro.models.layers import ShardCtx, LOCAL_CTX
from repro.sharding.spec import Rules


# ---------------------------------------------------------------------------
# Init + partition specs
# ---------------------------------------------------------------------------

def _block_shapes(cfg: LMConfig, moe: bool, d_ff: int) -> Dict[str, Any]:
    D, H, Hk, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    s: Dict[str, Any] = {"ln1": (D,), "ln2": (D,)}
    if cfg.mla:
        qk = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
        s.update(
            wq=(D, H * qk),
            wdkv=(D, cfg.kv_lora_rank + cfg.qk_rope_head_dim),
            kv_norm=(cfg.kv_lora_rank,),
            wuk=(cfg.kv_lora_rank, H * cfg.qk_nope_head_dim),
            wuv=(cfg.kv_lora_rank, H * cfg.v_head_dim),
            wo=(H * cfg.v_head_dim, D),
        )
    else:
        s.update(wq=(D, H * dh), wk=(D, Hk * dh), wv=(D, Hk * dh),
                 wo=(H * dh, D))
        if cfg.qkv_bias:
            s.update(bq=(H * dh,), bk=(Hk * dh,), bv=(Hk * dh,))
        if cfg.qk_norm:
            s.update(q_norm=(dh,), k_norm=(dh,))
    if moe:
        F = cfg.moe_d_ff
        s.update(router=(D, cfg.n_experts),
                 w1=(cfg.n_experts, D, 2 * F),
                 w2=(cfg.n_experts, F, D))
        if cfg.n_shared_experts:
            Fs = F * cfg.n_shared_experts
            s.update(ws1=(D, 2 * Fs), ws2=(Fs, D))
    else:
        s.update(wi=(D, 2 * d_ff), wof=(d_ff, D))
    return s


def _block_specs(cfg: LMConfig, r: Rules, moe: bool) -> Dict[str, P]:
    fs, tp, ep = r.fsdp, r.tensor, r.expert
    s: Dict[str, P] = {"ln1": P(None, None), "ln2": P(None, None)}
    if cfg.mla:
        s.update(wq=P(None, fs, tp), wdkv=P(None, fs, None),
                 kv_norm=P(None, None),
                 wuk=P(None, fs, tp), wuv=P(None, fs, tp),
                 wo=P(None, tp, fs))
    else:
        s.update(wq=P(None, fs, tp), wk=P(None, fs, tp), wv=P(None, fs, tp),
                 wo=P(None, tp, fs))
        if cfg.qkv_bias:
            s.update(bq=P(None, tp), bk=P(None, tp), bv=P(None, tp))
        if cfg.qk_norm:
            s.update(q_norm=P(None, None), k_norm=P(None, None))
    if moe:
        s.update(router=P(None, fs, None),
                 w1=P(None, ep, fs, None), w2=P(None, ep, None, fs))
        if cfg.n_shared_experts:
            s.update(ws1=P(None, fs, tp), ws2=P(None, tp, fs))
    else:
        s.update(wi=P(None, fs, tp), wof=P(None, tp, fs))
    return s


def _init_stack(rng, shapes: Dict[str, Any], n: int, d_model: int):
    out = {}
    keys = jax.random.split(rng, len(shapes))
    for key, (name, shape) in zip(keys, sorted(shapes.items())):
        full = (n,) + tuple(shape)
        if name.startswith(("ln", "q_norm", "k_norm", "kv_norm")):
            out[name] = jnp.ones(full, jnp.float32)
        elif name.startswith("b"):
            out[name] = jnp.zeros(full, jnp.float32)
        else:
            std = 0.02 if name != "wo" and name != "wof" and name != "w2" \
                else 0.02 / math.sqrt(2 * max(n, 1))
            out[name] = (std * jax.random.normal(key, full, jnp.float32))
    return out


def init_lm(rng: jax.Array, cfg: LMConfig) -> Dict[str, Any]:
    k_e, k_b, k_d, k_h = jax.random.split(rng, 4)
    n_moe = cfg.n_layers - cfg.first_k_dense if cfg.moe else 0
    n_main = n_moe if cfg.moe else cfg.n_layers
    params: Dict[str, Any] = {
        "embed": 0.02 * jax.random.normal(
            k_e, (cfg.vocab_size, cfg.d_model), jnp.float32),
        "blocks": _init_stack(
            k_b, _block_shapes(cfg, cfg.moe, cfg.d_ff), n_main, cfg.d_model),
        "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
    }
    if cfg.moe and cfg.first_k_dense:
        params["dense_blocks"] = _init_stack(
            k_d, _block_shapes(cfg, False, cfg.dense_d_ff or cfg.d_ff),
            cfg.first_k_dense, cfg.d_model)
    if not cfg.tie_embeddings:
        params["lm_head"] = 0.02 * jax.random.normal(
            k_h, (cfg.d_model, cfg.vocab_size), jnp.float32)
    return params


def lm_param_specs(cfg: LMConfig, r: Rules) -> Dict[str, Any]:
    specs: Dict[str, Any] = {
        "embed": P(r.tensor, r.fsdp),
        "blocks": _block_specs(cfg, r, cfg.moe),
        "final_norm": P(None),
    }
    if cfg.moe and cfg.first_k_dense:
        specs["dense_blocks"] = _block_specs(cfg, r, False)
    if not cfg.tie_embeddings:
        specs["lm_head"] = P(r.fsdp, r.tensor)
    return specs


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

def _attn(x, p, cfg: LMConfig, rope, ctx: ShardCtx, *, causal=True,
          q_offset=0):
    B, S, D = x.shape
    H, Hk, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    dt = x.dtype
    q = jnp.einsum("bsd,dq->bsq", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dq->bsq", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dq->bsq", x, p["wv"].astype(dt))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    q = q.reshape(B, S, H, dh)
    k = k.reshape(B, S, Hk, dh)
    v = v.reshape(B, S, Hk, dh)
    if cfg.qk_norm:
        q = L.rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = L.rms_norm(k, p["k_norm"], cfg.norm_eps)
    cos, sin = rope
    q = L.apply_rope(q, cos, sin, cfg.rope_fraction)
    k = L.apply_rope(k, cos, sin, cfg.rope_fraction)
    o = L.blockwise_attention(q, k, v, causal=causal, q_offset=q_offset)
    o = ctx.constrain(o, "batch", "tensor", None, None)
    return jnp.einsum("bsq,qd->bsd", o.reshape(B, S, H * dh),
                      p["wo"].astype(dt))


def _mla_attn(x, p, cfg: LMConfig, positions, ctx: ShardCtx):
    B, S, D = x.shape
    H = cfg.n_heads
    pr = {k: (v.reshape(v.shape[0], H, -1)
              if k in ("wq", "wuk", "wuv") else v) for k, v in p.items()}
    pr["wq"] = p["wq"].reshape(D, H, -1)
    pr["wuk"] = p["wuk"].reshape(cfg.kv_lora_rank, H, cfg.qk_nope_head_dim)
    pr["wuv"] = p["wuv"].reshape(cfg.kv_lora_rank, H, cfg.v_head_dim)
    q, k, v, _ = L.mla_qkv(x, pr, cfg, positions)
    scale = 1.0 / math.sqrt(cfg.qk_nope_head_dim + cfg.qk_rope_head_dim)
    o = L.blockwise_attention(q, k, v, causal=True, scale=scale)
    o = ctx.constrain(o, "batch", "tensor", None, None)
    return jnp.einsum("bsq,qd->bsd", o.reshape(B, S, H * cfg.v_head_dim),
                      p["wo"].astype(x.dtype))


def _ffn_or_moe(x, p, cfg: LMConfig, ctx: ShardCtx, moe: bool,
                seq_sharded: bool = True):
    if not moe:
        return L.swiglu_ffn(x, p["wi"].astype(x.dtype),
                            p["wof"].astype(x.dtype))
    shared = (p.get("ws1"), p.get("ws2"))
    return L.moe_block(x, p["router"], p["w1"], p["w2"], shared[0], shared[1],
                       cfg=cfg, ctx=ctx, seq_sharded=seq_sharded)


def _block(x, p, cfg: LMConfig, rope, positions, ctx: ShardCtx, moe: bool,
           seq_sharded: bool = True):
    h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
    if cfg.mla:
        a = _mla_attn(h, p, cfg, positions, ctx)
    else:
        a = _attn(h, p, cfg, rope, ctx)
    # §Perf iteration 0c: name the attention output so the remat policy can
    # keep it (skips the whole attention recompute in backward) while
    # everything else stays rematerialised.
    x = x + _checkpoint_name(a, "attn_out")
    x = ctx.constrain(x, "batch", "tensor", None)
    h = L.rms_norm(x, p["ln2"], cfg.norm_eps)
    x = x + _ffn_or_moe(h, p, cfg, ctx, moe, seq_sharded)
    return ctx.constrain(x, "batch", "tensor", None)


# full  = recompute everything (default — measured best, §Perf 0c)
# save_attn = keep per-layer attention outputs.  Measured NO gain: the
#   flash custom_vjp's own residuals (q,k,v,out,lse) are not covered by a
#   named-output policy, so its forward recomputes regardless; saving the
#   output only adds +1.5 GiB.  Kept as an ablation switch.
_REMAT_POLICY = os.environ.get("REPRO_REMAT_POLICY", "full")


def _scan_blocks(x, stack, fn):
    policy = (jax.checkpoint_policies.save_only_these_names("attn_out")
              if _REMAT_POLICY == "save_attn" else None)

    def body(carry, p_l):
        return jax.checkpoint(fn, policy=policy)(carry, p_l), None
    out, _ = jax.lax.scan(body, x, stack)
    return out


# ---------------------------------------------------------------------------
# Forward / loss
# ---------------------------------------------------------------------------

def lm_forward(params, tokens, cfg: LMConfig, ctx: ShardCtx = LOCAL_CTX,
               dtype=jnp.bfloat16):
    """tokens (B, S) -> logits (B, S, V)."""
    B, S = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0).astype(dtype)
    x = ctx.constrain(x, "batch", "tensor", None)
    positions = jnp.arange(S)
    rope = L.rope_tables(positions, int(cfg.d_head * cfg.rope_fraction) // 2 * 2,
                         cfg.rope_theta)
    if cfg.mla:
        rope = None  # MLA computes its own tables over the rope sub-dims
    if cfg.moe and cfg.first_k_dense:
        fn = functools.partial(_block, cfg=cfg, rope=rope,
                               positions=positions, ctx=ctx, moe=False)
        x = _scan_blocks(x, params["dense_blocks"],
                         lambda c, p: fn(c, p))
    fn = functools.partial(_block, cfg=cfg, rope=rope, positions=positions,
                           ctx=ctx, moe=cfg.moe)
    x = _scan_blocks(x, params["blocks"], lambda c, p: fn(c, p))
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(dtype))
    return ctx.constrain(logits, "batch", "tensor", None)


def lm_loss(params, batch, cfg: LMConfig, ctx: ShardCtx = LOCAL_CTX,
            dtype=jnp.bfloat16):
    logits = lm_forward(params, batch["tokens"], cfg, ctx, dtype)
    labels = batch["labels"]
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    mask = batch.get("mask", jnp.ones_like(labels, jnp.float32))
    n = jnp.maximum(jnp.sum(mask), 1.0)
    loss = jnp.sum((lse - ll) * mask) / n
    acc = jnp.sum((jnp.argmax(logits, -1) == labels) * mask) / n
    return loss, {"loss": loss, "accuracy": acc, "tokens": n}


# ---------------------------------------------------------------------------
# Serving: prefill + decode with KV cache
# ---------------------------------------------------------------------------

def init_kv_cache(cfg: LMConfig, batch: int, max_len: int,
                  dtype=jnp.bfloat16) -> Dict[str, jax.Array]:
    n_moe = cfg.n_layers - cfg.first_k_dense if cfg.moe else 0
    n_main = n_moe if cfg.moe else cfg.n_layers
    if cfg.mla:
        cache = {
            "ckv": jnp.zeros((n_main, batch, max_len, cfg.kv_lora_rank), dtype),
            "kpe": jnp.zeros((n_main, batch, max_len, cfg.qk_rope_head_dim),
                             dtype),
        }
        if cfg.first_k_dense:
            cache["ckv_dense"] = jnp.zeros(
                (cfg.first_k_dense, batch, max_len, cfg.kv_lora_rank), dtype)
            cache["kpe_dense"] = jnp.zeros(
                (cfg.first_k_dense, batch, max_len, cfg.qk_rope_head_dim),
                dtype)
        return cache
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.d_head)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def kv_cache_specs(cfg: LMConfig, r: Rules, *, seq_axes) -> Dict[str, P]:
    """Cache is sequence-sharded over ``seq_axes`` (see DESIGN.md: decode
    attention reductions partition over the sharded T dim)."""
    if cfg.mla:
        specs = {"ckv": P(None, r.batch, seq_axes, None),
                 "kpe": P(None, r.batch, seq_axes, None)}
        if cfg.first_k_dense:
            specs["ckv_dense"] = P(None, r.batch, seq_axes, None)
            specs["kpe_dense"] = P(None, r.batch, seq_axes, None)
        return specs
    return {"k": P(None, r.batch, seq_axes, None, None),
            "v": P(None, r.batch, seq_axes, None, None)}


def _decode_attn_gqa(x, p, cfg: LMConfig, kc, vc, pos, ctx: ShardCtx):
    B, S, D = x.shape
    H, Hk, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    dt = x.dtype
    q = jnp.einsum("bsd,dq->bsq", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dq->bsq", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dq->bsq", x, p["wv"].astype(dt))
    if cfg.qkv_bias:
        q, k, v = q + p["bq"].astype(dt), k + p["bk"].astype(dt), \
            v + p["bv"].astype(dt)
    q = q.reshape(B, 1, H, dh)
    k = k.reshape(B, 1, Hk, dh)
    v = v.reshape(B, 1, Hk, dh)
    if cfg.qk_norm:
        q = L.rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = L.rms_norm(k, p["k_norm"], cfg.norm_eps)
    rd = int(cfg.d_head * cfg.rope_fraction) // 2 * 2
    cos, sin = L.rope_tables(jnp.full((B, 1), pos), rd, cfg.rope_theta)
    q = L.apply_rope(q, cos, sin, cfg.rope_fraction)
    k = L.apply_rope(k, cos, sin, cfg.rope_fraction)
    kc = jax.lax.dynamic_update_slice_in_dim(kc, k.astype(kc.dtype), pos, 1)
    vc = jax.lax.dynamic_update_slice_in_dim(vc, v.astype(vc.dtype), pos, 1)
    cache_len = jnp.full((B,), pos + 1, jnp.int32)
    o = L.decode_attention(q, kc, vc, cache_len)
    out = jnp.einsum("bsq,qd->bsd", o.reshape(B, 1, H * dh),
                     p["wo"].astype(dt))
    return out, kc, vc


def _decode_attn_mla(x, p, cfg: LMConfig, ckv_c, kpe_c, pos, ctx: ShardCtx):
    B = x.shape[0]
    dt = x.dtype
    lr, rd = cfg.kv_lora_rank, cfg.qk_rope_head_dim
    ckr = jnp.einsum("bsd,dc->bsc", x, p["wdkv"].astype(dt))
    c_kv, k_pe = ckr[..., :lr], ckr[..., lr:]
    c_kv = L.rms_norm(c_kv, p["kv_norm"], cfg.norm_eps)
    cos, sin = L.rope_tables(jnp.full((B, 1), pos), rd, cfg.rope_theta)
    k_pe = L.apply_rope(k_pe[:, :, None, :], cos, sin)[:, :, 0]
    ckv_c = jax.lax.dynamic_update_slice_in_dim(
        ckv_c, c_kv.astype(ckv_c.dtype), pos, 1)
    kpe_c = jax.lax.dynamic_update_slice_in_dim(
        kpe_c, k_pe.astype(kpe_c.dtype), pos, 1)
    pr = dict(p)
    pr["wq"] = p["wq"].reshape(cfg.d_model, cfg.n_heads, -1)
    pr["wuk"] = p["wuk"].reshape(lr, cfg.n_heads, cfg.qk_nope_head_dim)
    pr["wuv"] = p["wuv"].reshape(lr, cfg.n_heads, cfg.v_head_dim)
    cache_len = jnp.full((B,), pos + 1, jnp.int32)
    o = L.mla_decode_absorbed(x, pr, cfg, ckv_c, kpe_c, cache_len,
                              jnp.full((B, 1), pos))
    out = jnp.einsum("bsq,qd->bsd",
                     o.reshape(B, 1, cfg.n_heads * cfg.v_head_dim),
                     p["wo"].astype(dt))
    return out, ckv_c, kpe_c


def lm_decode_step(params, cache, tokens, pos, cfg: LMConfig,
                   ctx: ShardCtx = LOCAL_CTX, dtype=jnp.bfloat16):
    """One decode step: tokens (B, 1) at position ``pos`` (scalar int32).

    Returns (logits (B, 1, V), updated cache)."""
    B = tokens.shape[0]
    x = jnp.take(params["embed"], tokens, axis=0).astype(dtype)
    x = ctx.constrain(x, "batch", None, None)

    def body_factory(moe):
        def body(x, sliced):
            p_l, caches = sliced
            h = L.rms_norm(x, p_l["ln1"], cfg.norm_eps)
            if cfg.mla:
                a, c0, c1 = _decode_attn_mla(h, p_l, cfg, caches[0], caches[1],
                                             pos, ctx)
            else:
                a, c0, c1 = _decode_attn_gqa(h, p_l, cfg, caches[0], caches[1],
                                             pos, ctx)
            x = x + a
            h = L.rms_norm(x, p_l["ln2"], cfg.norm_eps)
            x = x + _ffn_or_moe(h, p_l, cfg, ctx, moe, seq_sharded=False)
            return ctx.constrain(x, "batch", None, None), (c0, c1)
        return body

    def scan_stack(x, stack, caches, moe):
        def step(carry, xs):
            p_l = xs[0]
            cs = (xs[1], xs[2])
            new_x, new_cs = body_factory(moe)(carry, (p_l, cs))
            return new_x, new_cs
        x, new_caches = jax.lax.scan(step, x, (stack, caches[0], caches[1]))
        return x, new_caches

    if cfg.mla:
        c_names = ("ckv", "kpe")
    else:
        c_names = ("k", "v")

    new_cache = dict(cache)
    if cfg.moe and cfg.first_k_dense:
        x, (cd0, cd1) = scan_stack(
            x, params["dense_blocks"],
            (cache[c_names[0] + "_dense"], cache[c_names[1] + "_dense"]),
            moe=False)
        new_cache[c_names[0] + "_dense"] = cd0
        new_cache[c_names[1] + "_dense"] = cd1
    x, (c0, c1) = scan_stack(x, params["blocks"],
                             (cache[c_names[0]], cache[c_names[1]]),
                             moe=cfg.moe)
    new_cache[c_names[0]] = c0
    new_cache[c_names[1]] = c1

    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(dtype))
    return logits, new_cache


def lm_prefill(params, tokens, cfg: LMConfig, ctx: ShardCtx = LOCAL_CTX,
               dtype=jnp.bfloat16):
    """Prefill pass: returns last-position logits (B, V).  (The dry-run cell
    lowers the attention/FFN pipeline at (32, 32768); cache write-back is the
    decode path's job and is exercised by serve_step.)"""
    logits = lm_forward(params, tokens, cfg, ctx, dtype)
    return logits[:, -1]
