"""Model building blocks (pure JAX, GSPMD-shardable).

Design notes (see DESIGN.md):
  * Attention is blockwise/flash-style (``lax.scan`` over KV blocks) so the
    score matrix never materialises; activations are sequence-sharded over the
    ``model`` axis during train/prefill, so no head-divisibility constraint.
  * Decode attention is written as plain global ops over a KV cache that is
    sequence-sharded; GSPMD partitions the softmax/contraction reductions
    (verified in the dry-run HLO).
  * MoE is expert-parallel via ``shard_map`` + ``all_to_all`` over the
    ``model`` axis with capacity-bounded, cumsum-slotted dispatch.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import LMConfig
from repro.sharding.spec import Rules, shard_map_compat


@dataclasses.dataclass(frozen=True)
class ShardCtx:
    """Mesh + logical rules threaded through model code (None = local)."""

    mesh: Optional[Mesh] = None
    rules: Rules = Rules()

    def constrain(self, x, *logical):
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, jax.sharding.NamedSharding(self.mesh, self.rules.spec(*logical)))


LOCAL_CTX = ShardCtx()

_NEG_INF = -1e30  # finite mask value: avoids (-inf) - (-inf) = nan paths


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32)).astype(dtype)


def layer_norm(x: jax.Array, w: jax.Array, b: jax.Array,
               eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# Rotary position embedding (NeoX-half style; ``fraction`` < 1 rotates only
# the leading dims of each head — ChatGLM's "2d" RoPE uses fraction=0.5).
# ---------------------------------------------------------------------------

def rope_tables(positions: jax.Array, rotary_dim: int,
                theta: float) -> Tuple[jax.Array, jax.Array]:
    """positions (...,) -> cos/sin tables (..., rotary_dim // 2)."""
    half = rotary_dim // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions.astype(jnp.float32)[..., None] * freq  # (..., half)
    return jnp.cos(angles), jnp.sin(angles)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array,
               fraction: float = 1.0) -> jax.Array:
    """x: (B, S, H, dh); cos/sin: (B, S, half) or (S, half)."""
    dh = x.shape[-1]
    rotary_dim = int(dh * fraction)
    if rotary_dim % 2:
        rotary_dim -= 1
    half = rotary_dim // 2
    xr, xp = x[..., :rotary_dim], x[..., rotary_dim:]
    x1, x2 = xr[..., :half], xr[..., half:]
    # x is (B, S, H, dh); cos/sin come as (S, half) or (B, S, half).
    if cos.ndim == 2:
        cos, sin = cos[None, :, None, :], sin[None, :, None, :]
    elif cos.ndim == 3:
        cos, sin = cos[:, :, None, :], sin[:, :, None, :]
    cos = cos.astype(jnp.float32)
    sin = sin.astype(jnp.float32)
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate(
        [x1f * cos - x2f * sin, x2f * cos + x1f * sin], axis=-1)
    return jnp.concatenate([out.astype(x.dtype), xp], axis=-1)


# ---------------------------------------------------------------------------
# Blockwise (flash-style) attention: lax.scan over KV blocks, f32 running
# (max, sumexp, acc).  Supports GQA broadcast and causal masking at a global
# query offset (used by chunked prefill).
# ---------------------------------------------------------------------------

def _attention_fwd_scan(q, k, v, causal: bool, q_offset: int,
                        block_size: int, scale: float):
    """Streaming flash forward.  Returns (out (B,S,H,dhv) in q.dtype,
    lse (B,Hk,G,S) f32)."""
    B, S, H, dh = q.shape
    T, Hk = k.shape[1], k.shape[2]
    dhv = v.shape[-1]
    G = H // Hk
    bs = min(block_size, T)
    n_blocks = T // bs
    assert n_blocks * bs == T, f"T={T} not divisible by block {bs}"

    qf = (q.astype(jnp.float32) * scale).reshape(B, S, Hk, G, dh)
    kb = jnp.moveaxis(k.astype(jnp.float32).reshape(B, n_blocks, bs, Hk, dh),
                      1, 0)
    vb = jnp.moveaxis(v.astype(jnp.float32).reshape(B, n_blocks, bs, Hk, dhv),
                      1, 0)
    q_pos = q_offset + jnp.arange(S)

    def body(carry, blk):
        m, l, acc = carry
        kblk, vblk, blk_idx = blk
        # q (B,S,Hk,G,dh)=bskgd, k (B,bs,Hk,dh)=btkd -> scores (B,Hk,G,S,bs)
        s = jnp.einsum("bskgd,btkd->bkgst", qf, kblk,
                       preferred_element_type=jnp.float32)
        if causal:
            k_pos = blk_idx * bs + jnp.arange(bs)
            mask = q_pos[:, None] >= k_pos[None, :]          # (S, bs)
            s = jnp.where(mask[None, None, None], s, _NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bkgst,btkd->bskgd", p, vblk,
                        preferred_element_type=jnp.float32)
        acc_new = acc * jnp.moveaxis(corr, (1, 2, 3), (2, 3, 1))[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Hk, G, S), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hk, G, S), jnp.float32)
    a0 = jnp.zeros((B, S, Hk, G, dhv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0), (kb, vb, jnp.arange(n_blocks)))
    lse = m + jnp.log(jnp.maximum(l, 1e-30))             # (B,Hk,G,S)
    l_t = jnp.moveaxis(l, (1, 2, 3), (2, 3, 1))          # (B,S,Hk,G)
    out = acc / jnp.maximum(l_t, 1e-30)[..., None]
    return out.reshape(B, S, H, dhv).astype(q.dtype), lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _flash_attention(q, k, v, causal, q_offset, block_size, scale):
    return _attention_fwd_scan(q, k, v, causal, q_offset, block_size, scale)[0]


def _flash_fwd(q, k, v, causal, q_offset, block_size, scale):
    out, lse = _attention_fwd_scan(q, k, v, causal, q_offset, block_size,
                                   scale)
    return out, (q, k, v, out, lse)


def _flash_bwd(causal, q_offset, block_size, scale, res, dout):
    """Flash backward: recompute scores per KV block from the saved
    logsumexp — residuals are O(S), never O(S*T).  (The naive grad-of-scan
    stacks score-sized residuals per block; see EXPERIMENTS.md §Perf.)"""
    q, k, v, out, lse = res
    B, S, H, dh = q.shape
    T, Hk = k.shape[1], k.shape[2]
    dhv = v.shape[-1]
    G = H // Hk
    bs = min(block_size, T)
    n_blocks = T // bs

    qf = q.astype(jnp.float32).reshape(B, S, Hk, G, dh)
    do = dout.astype(jnp.float32).reshape(B, S, Hk, G, dhv)
    of = out.astype(jnp.float32).reshape(B, S, Hk, G, dhv)
    # D = rowsum(dO * O): (B,Hk,G,S)
    delta = jnp.moveaxis(jnp.sum(do * of, -1), (1, 2, 3), (3, 1, 2))
    kb = jnp.moveaxis(k.astype(jnp.float32).reshape(B, n_blocks, bs, Hk, dh),
                      1, 0)
    vb = jnp.moveaxis(v.astype(jnp.float32).reshape(B, n_blocks, bs, Hk, dhv),
                      1, 0)
    q_pos = q_offset + jnp.arange(S)

    def body(dq_acc, blk):
        kblk, vblk, blk_idx = blk
        s = scale * jnp.einsum("bskgd,btkd->bkgst", qf, kblk,
                               preferred_element_type=jnp.float32)
        if causal:
            k_pos = blk_idx * bs + jnp.arange(bs)
            mask = q_pos[:, None] >= k_pos[None, :]
            s = jnp.where(mask[None, None, None], s, _NEG_INF)
        p = jnp.exp(s - lse[..., None])                    # (B,Hk,G,S,bs)
        dv_b = jnp.einsum("bkgst,bskgd->btkd", p, do,
                          preferred_element_type=jnp.float32)
        dp = jnp.einsum("bskgd,btkd->bkgst", do, vblk,
                        preferred_element_type=jnp.float32)
        ds = p * (dp - delta[..., None])
        dq_acc = dq_acc + scale * jnp.einsum(
            "bkgst,btkd->bskgd", ds, kblk,
            preferred_element_type=jnp.float32)
        dk_b = scale * jnp.einsum("bkgst,bskgd->btkd", ds, qf,
                                  preferred_element_type=jnp.float32)
        return dq_acc, (dk_b, dv_b)

    dq0 = jnp.zeros((B, S, Hk, G, dh), jnp.float32)
    dq, (dk_blocks, dv_blocks) = jax.lax.scan(
        body, dq0, (kb, vb, jnp.arange(n_blocks)))
    dk = jnp.moveaxis(dk_blocks, 0, 1).reshape(B, T, Hk, dh)
    dv = jnp.moveaxis(dv_blocks, 0, 1).reshape(B, T, Hk, dhv)
    return (dq.reshape(B, S, H, dh).astype(q.dtype),
            dk.astype(k.dtype), dv.astype(v.dtype))


_flash_attention.defvjp(_flash_fwd, _flash_bwd)

# Toggle for the §Perf before/after ablation (naive grad-of-scan path).
import os as _os
FLASH_VJP = _os.environ.get("REPRO_FLASH_VJP", "1") == "1"


def blockwise_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                        *, causal: bool = True, q_offset: int = 0,
                        block_size: int = 512,
                        scale: Optional[float] = None) -> jax.Array:
    """q: (B,S,H,dh) k/v: (B,T,Hk,dh[v]) -> (B,S,H,dhv).  Flash-style
    streaming forward; custom flash VJP (O(S) residuals) unless FLASH_VJP
    is disabled for ablation."""
    dh = q.shape[-1]
    scale = scale if scale is not None else 1.0 / math.sqrt(dh)
    if FLASH_VJP:
        return _flash_attention(q, k, v, causal, q_offset, block_size, scale)
    return _attention_fwd_scan(q, k, v, causal, q_offset, block_size,
                               scale)[0]


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     cache_len: jax.Array, *,
                     scale: Optional[float] = None) -> jax.Array:
    """Single-step decode: q (B,1,H,dh) against a (possibly sequence-sharded)
    KV cache (B,T,Hk,dh).  Written as global ops; GSPMD partitions the
    reductions over the sharded T dim (flash-combine emerges from the
    all-reduce of max/sum/weighted-V).

    The cache is consumed in its own dtype with f32 ACCUMULATION
    (preferred_element_type) — an explicit .astype(f32) would let XLA hoist
    the convert out of the layer scan and carry the whole cache stack in
    f32 (observed: +2.5x HBM on qwen1.5-4b decode_32k; EXPERIMENTS.md §Perf).
    """
    B, _, H, dh = q.shape
    T, Hk = k_cache.shape[1], k_cache.shape[2]
    G = H // Hk
    scale = scale if scale is not None else 1.0 / math.sqrt(dh)
    qf = (q.astype(jnp.float32) * scale).astype(k_cache.dtype) \
        .reshape(B, Hk, G, dh)
    s = jnp.einsum("bkgd,btkd->bkgt", qf, k_cache,
                   preferred_element_type=jnp.float32)
    valid = jnp.arange(T)[None] < cache_len[:, None]          # (B, T)
    s = jnp.where(valid[:, None, None], s, _NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bkgt,btkd->bkgd", p.astype(v_cache.dtype), v_cache,
                   preferred_element_type=jnp.float32)
    o = o / jnp.maximum(l, 1e-30)
    return o.reshape(B, 1, H, dh).astype(q.dtype)


# ---------------------------------------------------------------------------
# FFN
# ---------------------------------------------------------------------------

def swiglu_ffn(x: jax.Array, wi: jax.Array, wo: jax.Array) -> jax.Array:
    """wi: (D, 2F) fused gate|up; wo: (F, D)."""
    gu = jnp.einsum("bsd,df->bsf", x, wi.astype(x.dtype))
    gate, up = jnp.split(gu, 2, axis=-1)
    return jnp.einsum("bsf,fd->bsd", jax.nn.silu(gate) * up, wo.astype(x.dtype))


# ---------------------------------------------------------------------------
# Expert-parallel MoE (shard_map + all_to_all over the ``expert`` axis)
# ---------------------------------------------------------------------------

def _capacity(n_tokens: int, top_k: int, n_experts: int, factor: float) -> int:
    c = int(math.ceil(n_tokens * top_k / n_experts * factor))
    return max(c, top_k)


def _router(x, router_w, cfg: LMConfig):
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32),
                        router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eids = jax.lax.top_k(probs, cfg.moe_top_k)          # (t, k)
    gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)
    return gates * cfg.router_scale, eids


def _expert_slots(eids, n_experts: int, cap: int):
    """Rank of each (token, k) pair within its expert (cumsum-slotting)."""
    eid_flat = eids.reshape(-1)                                # (t*k,)
    onehot = jax.nn.one_hot(eid_flat, n_experts, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) - onehot
    pos_flat = jnp.take_along_axis(pos, eid_flat[:, None], axis=1)[:, 0]
    return eid_flat, pos_flat


def _expert_ffn(buf, w1, w2, dtype):
    gu = jnp.einsum("ecd,edf->ecf", buf, w1.astype(dtype))
    gate, up = jnp.split(gu, 2, axis=-1)
    return jnp.einsum("ecf,efd->ecd", jax.nn.silu(gate) * up,
                      w2.astype(dtype))


def _moe_local_a2a(x, router_w, w1, w2, *, cfg: LMConfig, axis: str,
                   n_shards: int):
    """Sharded-token mode: each device owns distinct tokens; dispatch via
    all_to_all over the expert axis."""
    t, D = x.shape
    E, k = cfg.n_experts, cfg.moe_top_k
    cap = _capacity(t, k, E, cfg.capacity_factor)
    gates, eids = _router(x, router_w, cfg)
    eid_flat, pos_flat = _expert_slots(eids, E, cap)
    keep = pos_flat < cap
    slot = jnp.where(keep, eid_flat * cap + pos_flat, E * cap)  # drop bucket
    tok_idx = jnp.repeat(jnp.arange(t), k)
    buf = jnp.zeros((E * cap + 1, D), x.dtype).at[slot].add(x[tok_idx])
    buf = buf[:-1].reshape(E, cap, D)
    if n_shards > 1:
        # (E, cap, D) -> (E/p, cap*p, D): route experts to their owner shard.
        buf = jax.lax.all_to_all(buf, axis, split_axis=0, concat_axis=1,
                                 tiled=True)
    y = _expert_ffn(buf, w1, w2, x.dtype)
    if n_shards > 1:
        y = jax.lax.all_to_all(y, axis, split_axis=1, concat_axis=0,
                               tiled=True)                     # back: (E,cap,D)
    y = y.reshape(E * cap, D)
    y = jnp.concatenate([y, jnp.zeros((1, D), y.dtype)], axis=0)
    y_pair = y[slot] * (gates.reshape(-1)[:, None]).astype(y.dtype)
    return jnp.zeros((t, D), y.dtype).at[tok_idx].add(y_pair)


def _moe_local_replicated(x, router_w, w1, w2, *, cfg: LMConfig, axis: str,
                          n_shards: int):
    """Replicated-token mode (decode): every device sees the same tokens,
    computes only its local experts, partial outputs psum'd over the axis."""
    t, D = x.shape
    E, k = cfg.n_experts, cfg.moe_top_k
    e_loc = E // n_shards
    # decode path: capacity = t (an expert can receive at most t tokens) —
    # dropping tokens at decode would corrupt generation.
    cap = min(t, _capacity(t, k, E, 1e9))
    gates, eids = _router(x, router_w, cfg)
    eid_flat, pos_flat = _expert_slots(eids, E, cap)
    my = jax.lax.axis_index(axis) if n_shards > 1 else 0
    lo = my * e_loc
    local = (eid_flat >= lo) & (eid_flat < lo + e_loc)
    keep = local & (pos_flat < cap)
    slot = jnp.where(keep, (eid_flat - lo) * cap + pos_flat, e_loc * cap)
    tok_idx = jnp.repeat(jnp.arange(t), k)
    buf = jnp.zeros((e_loc * cap + 1, D), x.dtype).at[slot].add(x[tok_idx])
    y = _expert_ffn(buf[:-1].reshape(e_loc, cap, D), w1, w2, x.dtype)
    y = y.reshape(e_loc * cap, D)
    y = jnp.concatenate([y, jnp.zeros((1, D), y.dtype)], axis=0)
    y_pair = y[slot] * (gates.reshape(-1)[:, None]).astype(y.dtype)
    out = jnp.zeros((t, D), y.dtype).at[tok_idx].add(y_pair)
    if n_shards > 1:
        out = jax.lax.psum(out, axis)
    return out


def moe_block(x: jax.Array, router_w, w1, w2, shared_w1, shared_w2,
              *, cfg: LMConfig, ctx: ShardCtx,
              seq_sharded: bool = True) -> jax.Array:
    """x: (B, S, D).  Experts sharded over the ``expert`` ('model') axis.

    seq_sharded=True (train/prefill): tokens are sequence-sharded over the
    expert axis -> a2a dispatch.  False (decode, S not shardable): tokens
    replicated over the expert axis -> local-expert compute + psum combine.
    """
    B, S, D = x.shape

    if ctx.mesh is None:
        flat = _moe_local_a2a(x.reshape(B * S, D), router_w, w1, w2,
                              cfg=cfg, axis="", n_shards=1)
        out = flat.reshape(B, S, D)
    else:
        r = ctx.rules
        axis = r.expert
        n_shards = ctx.mesh.shape[axis]
        fn = _moe_local_a2a if seq_sharded else _moe_local_replicated
        x_spec = (P(r.batch, r.tensor, None) if seq_sharded
                  else P(r.batch, None, None))

        def body(xl, rwl, w1l, w2l):
            b, s, d = xl.shape
            yl = fn(xl.reshape(b * s, d), rwl, w1l, w2l,
                    cfg=cfg, axis=axis, n_shards=n_shards)
            return yl.reshape(b, s, d)

        out = shard_map_compat(
            body, mesh=ctx.mesh,
            in_specs=(x_spec, P(None, None),
                      P(r.expert, None, None), P(r.expert, None, None)),
            out_specs=x_spec,
        )(x, router_w, w1, w2)

    if shared_w1 is not None:
        out = out + swiglu_ffn(x, shared_w1.astype(x.dtype),
                               shared_w2.astype(x.dtype))
    return out


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2): train form expands c_kv; decode uses the absorbed form
# against the compressed cache (c_kv, k_pe) — see DESIGN.md §2.
# ---------------------------------------------------------------------------

def mla_qkv(x, p, cfg: LMConfig, positions):
    """Returns q (B,S,H,qk_dim), k (B,S,H,qk_dim), v (B,S,H,v_dim) and the
    compressed (c_kv, k_pe) pair for cache insertion."""
    B, S, D = x.shape
    H = cfg.n_heads
    nd, rd, vd, lr = (cfg.qk_nope_head_dim, cfg.qk_rope_head_dim,
                      cfg.v_head_dim, cfg.kv_lora_rank)
    q = jnp.einsum("bsd,dhq->bshq", x, p["wq"].astype(x.dtype))
    q_nope, q_pe = q[..., :nd], q[..., nd:]
    ckr = jnp.einsum("bsd,dc->bsc", x, p["wdkv"].astype(x.dtype))
    c_kv, k_pe = ckr[..., :lr], ckr[..., lr:]
    c_kv = rms_norm(c_kv, p["kv_norm"], cfg.norm_eps)
    cos, sin = rope_tables(positions, rd, cfg.rope_theta)
    q_pe = apply_rope(q_pe, cos, sin)
    k_pe = apply_rope(k_pe[:, :, None, :], cos, sin)[:, :, 0]  # shared head
    k_nope = jnp.einsum("bsc,chn->bshn", c_kv, p["wuk"].astype(x.dtype))
    v = jnp.einsum("bsc,chv->bshv", c_kv, p["wuv"].astype(x.dtype))
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_pe[:, :, None], (B, S, H, rd))], -1)
    qq = jnp.concatenate([q_nope, q_pe], -1)
    return qq, k, v, (c_kv, k_pe)


def mla_decode_absorbed(x, p, cfg: LMConfig, ckv_cache, kpe_cache,
                        cache_len, positions):
    """x: (B,1,D); caches: (B,T,lora) / (B,T,rd) sequence-sharded."""
    B, S, D = x.shape
    H = cfg.n_heads
    nd, rd, vd, lr = (cfg.qk_nope_head_dim, cfg.qk_rope_head_dim,
                      cfg.v_head_dim, cfg.kv_lora_rank)
    q = jnp.einsum("bsd,dhq->bshq", x, p["wq"].astype(x.dtype))
    q_nope, q_pe = q[..., :nd], q[..., nd:]
    cos, sin = rope_tables(positions, rd, cfg.rope_theta)
    q_pe = apply_rope(q_pe, cos, sin)
    # absorb through W_UK: (B,1,H,nd) x (lora,H,nd) -> (B,1,H,lora).
    # Cache consumed in its own dtype + f32 accumulation (see
    # decode_attention note on convert-hoisting).  XLA-CPU's DotThunk lacks
    # BF16xBF16=F32 for this contraction shape — execute in f32 there
    # (TPU keeps the bf16 MXU path).
    cdt = ckv_cache.dtype
    if jax.default_backend() == "cpu":
        cdt = jnp.float32
    q_t = jnp.einsum("bshn,chn->bshc", q_nope.astype(jnp.float32),
                     p["wuk"].astype(jnp.float32)).astype(cdt)
    scale = 1.0 / math.sqrt(nd + rd)
    s = (jnp.einsum("bshc,btc->bhst", q_t, ckv_cache,
                    preferred_element_type=jnp.float32)
         + jnp.einsum("bshr,btr->bhst", q_pe.astype(cdt), kpe_cache,
                      preferred_element_type=jnp.float32)) * scale
    T = ckv_cache.shape[1]
    valid = jnp.arange(T)[None] < cache_len[:, None]
    s = jnp.where(valid[:, None, None], s, _NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    pr = jnp.exp(s - m)
    l = jnp.sum(pr, axis=-1, keepdims=True)
    o_c = jnp.einsum("bhst,btc->bshc",
                     (pr / jnp.maximum(l, 1e-30)).astype(cdt), ckv_cache,
                     preferred_element_type=jnp.float32)
    o = jnp.einsum("bshc,chv->bshv", o_c, p["wuv"].astype(jnp.float32))
    return o.astype(x.dtype)                                  # (B,1,H,vd)
