"""Analytic "useful work" (MODEL_FLOPS) per (arch x shape) cell.

LM convention: 6·N·D for training (N = params, D = tokens; MoE uses
N_active), plus the causal-attention term 12·L·H·dh·S per token /2 (causal)
— MaxText-style MFU accounting.  Forward-only passes are 1/3 of train.
"""

from __future__ import annotations

from typing import Dict

from repro.configs.base import GNNConfig, LMConfig, RecsysConfig


def _lm_attn_fwd_flops(cfg: LMConfig, B: int, S: int) -> float:
    # scores + values: 2 * 2 * H*dh * S^2/2 (causal) per layer
    if cfg.mla:
        d_qk = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
        per_layer = 2.0 * B * S * S / 2 * cfg.n_heads * (d_qk
                                                         + cfg.v_head_dim)
    else:
        per_layer = 4.0 * B * S * S / 2 * cfg.n_heads * cfg.d_head
    return cfg.n_layers * per_layer


def lm_model_flops(cfg: LMConfig, step: str, dims: Dict[str, int]) -> float:
    B, S = dims["global_batch"], dims["seq_len"]
    n_active = cfg.n_active_params()
    if step == "train_step":
        return 6.0 * n_active * B * S + 3.0 * _lm_attn_fwd_flops(cfg, B, S)
    if step == "prefill":
        return 2.0 * n_active * B * S + _lm_attn_fwd_flops(cfg, B, S)
    # decode: 1 token per sequence; attention reads the whole cache
    if cfg.mla:
        attn = cfg.n_layers * B * S * (
            2.0 * cfg.n_heads * cfg.kv_lora_rank * 2
            + 2.0 * cfg.n_heads * cfg.qk_rope_head_dim)
    else:
        attn = cfg.n_layers * B * S * 4.0 * cfg.n_heads * cfg.d_head
    return 2.0 * n_active * B + attn


def gnn_model_flops(cfg: GNNConfig, shape_id: str,
                    dims: Dict[str, int]) -> float:
    d_feat = dims.get("d_feat", cfg.d_feat)
    n_classes = dims.get("n_classes", cfg.n_classes)
    dh = cfg.d_hidden
    if shape_id == "minibatch_lg":
        B = dims["batch_nodes"]
        f0, f1 = dims["fanout0"], dims["fanout1"]
        n_l1 = B * (1 + f0)                    # nodes transformed at layer 1
        fwd = (2.0 * n_l1 * 2 * d_feat * dh    # self+neigh matmuls
               + 2.0 * B * 2 * dh * n_classes
               + 2.0 * B * f0 * f1 * d_feat)   # aggregation adds
        return 3.0 * fwd
    n_nodes = dims["n_nodes"] * dims.get("batch", 1)
    n_edges = dims["n_edges"] * dims.get("batch", 1)
    fwd = (2.0 * n_nodes * 2 * d_feat * dh
           + 2.0 * n_nodes * 2 * dh * n_classes
           + 2.0 * n_edges * (d_feat + dh))    # two rounds of segment_sum
    return 3.0 * fwd


def recsys_model_flops(cfg: RecsysConfig, step: str,
                       dims: Dict[str, int]) -> float:
    B = dims.get("batch", 1)
    d = cfg.embed_dim

    def mlp_flops(dims_list, batch):
        return sum(2.0 * batch * a * b
                   for a, b in zip(dims_list[:-1], dims_list[1:]))

    if cfg.kind == "dlrm":
        n_f = cfg.n_sparse + 1
        top = [n_f * (n_f - 1) // 2 + d] + list(cfg.top_mlp)
        fwd = (mlp_flops(list(cfg.bot_mlp), B) + mlp_flops(top, B)
               + 2.0 * B * n_f * n_f * d          # dot interaction
               + B * cfg.n_sparse * cfg.multi_hot * d)  # bag reduce
    elif cfg.kind == "wide_deep":
        deep = [cfg.n_sparse * d] + list(cfg.mlp) + [1]
        fwd = mlp_flops(deep, B) + B * cfg.n_sparse * (d + 1)
    elif cfg.kind == "bert4rec":
        S, db = cfg.seq_len, cfg.embed_dim
        per_blk = (2.0 * B * S * db * 3 * db + 2.0 * B * S * db * db
                   + 4.0 * B * S * db * 4 * db
                   + 4.0 * B * S * S * db)
        fwd = cfg.n_blocks * per_blk
    else:                                        # mind
        Lh, K = cfg.hist_len, cfg.n_interests
        fwd = (2.0 * B * Lh * d * d              # bilinear
               + cfg.capsule_iters * 4.0 * B * K * Lh * d
               + mlp_flops([d, 2 * d, d], B * K))
    if step == "train_step":
        n_neg = 128
        fwd += 2.0 * B * n_neg * d
        return 3.0 * fwd
    if step == "retrieval":
        return 2.0 * B * dims["n_candidates"] * d
    if cfg.kind in ("bert4rec", "mind"):
        fwd += 2.0 * B * cfg.vocab_size * d      # score all items
    return fwd


def model_flops(cfg, step: str, shape_id: str, dims: Dict[str, int]) -> float:
    if isinstance(cfg, LMConfig):
        return lm_model_flops(cfg, step, dims)
    if isinstance(cfg, GNNConfig):
        return gnn_model_flops(cfg, shape_id, dims)
    if isinstance(cfg, RecsysConfig):
        return recsys_model_flops(cfg, step, dims)
    raise TypeError(type(cfg))
