"""Roofline-term extraction from compiled dry-run artifacts.

Hardware constants (TPU v5e target):
  peak bf16:     197 TFLOP/s per chip
  HBM bandwidth: 819 GB/s per chip
  ICI link:      ~50 GB/s per chip (effective per-direction)

``cost_analysis``/``memory_analysis`` on an SPMD-partitioned executable
describe the *per-device* program, so all three terms below are per-chip
seconds directly comparable to each other:

  compute    = flops / PEAK_FLOPS
  memory     = bytes_accessed / HBM_BW
  collective = sum(operand bytes of all-gather/all-reduce/reduce-scatter/
               all-to-all/collective-permute in the post-SPMD HLO) / ICI_BW
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional, Tuple

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # B/s / chip
ICI_BW = 50e9                # B/s / link (per chip, effective)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?(%?[\w.\-]+)\s*=\s*(.+?)\s+"
                     r"([a-z][\w\-]*)\(")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(shape_str: str) -> int:
    """Bytes of an HLO type string, incl. tuples '(f32[2,3], u8[4])'."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum *operand* bytes per collective kind from post-SPMD HLO text.

    Two-pass: (1) map instruction name -> result bytes, (2) for each
    collective, sum the result-bytes of its operands (start/done pairs are
    counted once via the -start form; plain forms counted directly)."""
    sizes: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if m:
            sizes[m.group(1).lstrip("%")] = _shape_bytes(m.group(2))

    out = {k: 0 for k in _COLLECTIVES}
    opnd = re.compile(r"%?([\w.\-]+)")
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        op = m.group(3)
        base = op[:-6] if op.endswith("-start") else op
        if base not in _COLLECTIVES or op.endswith("-done"):
            continue
        # operands: inside the first (...) after the op name
        try:
            args = line.split(op + "(", 1)[1]
        except IndexError:
            continue
        depth, buf = 1, []
        for ch in args:
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
            buf.append(ch)
        arg_str = "".join(buf)
        total = 0
        for name in opnd.findall(arg_str):
            if name in sizes:
                total += sizes[name]
        if total == 0:
            total = _shape_bytes(m.group(2))   # fallback: result bytes
        out[base] += total
    return out


@dataclasses.dataclass
class Roofline:
    flops: float                 # per-device HLO flops (loop-aware)
    hbm_bytes: float             # per-device bytes accessed (loop-aware)
    coll_bytes: float            # per-device collective operand bytes
    coll_breakdown: Dict[str, int]
    model_flops: float           # global analytic "useful" flops
    n_chips: int
    xla_flops: float = 0.0       # XLA cost_analysis (loop bodies counted 1x)
    xla_bytes: float = 0.0

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        t = {"compute": self.t_compute, "memory": self.t_memory,
             "collective": self.t_collective}
        return max(t, key=t.get)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / (HLO_FLOPs x chips): remat/redundancy waste."""
        denom = self.flops * self.n_chips
        return self.model_flops / denom if denom else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the dominant-roofline bound achieved by useful work:
        (model-flops time at peak) / (dominant term)."""
        t_ideal = self.model_flops / (self.n_chips * PEAK_FLOPS)
        t_dom = max(self.t_compute, self.t_memory, self.t_collective)
        return t_ideal / t_dom if t_dom else 0.0

    def to_dict(self) -> Dict:
        return {
            "flops_per_chip": self.flops,
            "hbm_bytes_per_chip": self.hbm_bytes,
            "coll_bytes_per_chip": self.coll_bytes,
            "coll_breakdown": self.coll_breakdown,
            "model_flops": self.model_flops,
            "n_chips": self.n_chips,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
            "xla_flops_per_chip": self.xla_flops,
            "xla_bytes_per_chip": self.xla_bytes,
        }


def from_compiled(compiled, model_flops: float, n_chips: int,
                  hlo_text: Optional[str] = None) -> Roofline:
    """Primary source: the loop-aware text analyzer (hlo_cost) — XLA's
    cost_analysis counts while bodies once, which under-counts every
    scan-over-layers program.  XLA's numbers are kept as reference."""
    from repro.analysis import hlo_cost

    cost = compiled.cost_analysis()
    if isinstance(cost, list):            # older jax returns [dict]
        cost = cost[0]
    xla_flops = float(cost.get("flops", 0.0))
    xla_bytes = float(cost.get("bytes accessed", 0.0))
    text = hlo_text if hlo_text is not None else compiled.as_text()
    c = hlo_cost.analyze(text)
    return Roofline(flops=max(c.flops, xla_flops),
                    hbm_bytes=max(c.bytes, xla_bytes),
                    coll_bytes=float(sum(c.coll.values())),
                    coll_breakdown={k: int(v) for k, v in c.coll.items()},
                    model_flops=model_flops, n_chips=n_chips,
                    xla_flops=xla_flops, xla_bytes=xla_bytes)
