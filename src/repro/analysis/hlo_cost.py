"""Loop-aware HLO cost model (text-based).

XLA's ``compiled.cost_analysis()`` counts ``while`` bodies ONCE — useless for
scan-over-layers programs (everything here is scanned).  This analyzer walks
the post-SPMD HLO text, multiplies loop bodies by their
``known_trip_count`` (printed in ``backend_config``), and accumulates:

  * flops        — dot ops (2*K*numel(result)) + elementwise (1/elem)
  * hbm bytes    — fusion/op boundary operand+result bytes
  * collective operand bytes, per kind (all-reduce / all-gather /
    reduce-scatter / all-to-all / collective-permute)

All numbers describe the *per-device* partitioned module.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%([\w.\-]+)\s*\((.*)\)\s*->")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+?)\s+"
    r"([a-z][a-z0-9\-]*)\((.*)$")
_OPND_RE = re.compile(r"%([\w.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_CALLS_RE = re.compile(r"calls=%([\w.\-]+)")
_BODY_RE = re.compile(r"body=%([\w.\-]+)")
_COND_RE = re.compile(r"condition=%([\w.\-]+)")

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "exponential", "log", "tanh", "rsqrt", "sqrt", "power",
    "select", "compare", "and", "or", "xor", "not", "floor", "ceil",
    "round-nearest-afz", "round-nearest-even", "sign", "atan2", "cosine",
    "sine", "erf", "exponential-minus-one", "log-plus-one", "clamp",
}
_ZERO_BYTES = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "reshape",
}
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_numel_bytes(shape_str: str) -> Tuple[int, int]:
    numel_total, bytes_total = 0, 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        numel_total += n
        bytes_total += n * _DTYPE_BYTES[dt]
    return numel_total, bytes_total


@dataclasses.dataclass
class Instr:
    name: str
    shape: str
    op: str
    rest: str


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _COLLECTIVES})

    def __iadd__(self, o: "Cost"):
        self.flops += o.flops
        self.bytes += o.bytes
        for k in self.coll:
            self.coll[k] += o.coll[k]
        return self

    def scaled(self, f: float) -> "Cost":
        return Cost(self.flops * f, self.bytes * f,
                    {k: v * f for k, v in self.coll.items()})


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.comps: Dict[str, List[Instr]] = {}
        self.shapes: Dict[str, str] = {}
        self.entry: Optional[str] = None
        self._parse(hlo_text)
        self._memo: Dict[str, Cost] = {}

    def _parse(self, text: str) -> None:
        cur: Optional[str] = None
        for line in text.splitlines():
            mc = _COMP_RE.match(line)
            if mc and line.rstrip().endswith("{"):
                cur = mc.group(1)
                self.comps[cur] = []
                if line.startswith("ENTRY"):
                    self.entry = cur
                # typed params in the header
                for pm in re.finditer(r"([\w.\-]+):\s*([^,()]+(?:\([^)]*\))?)",
                                      mc.group(2)):
                    self.shapes.setdefault(pm.group(1), pm.group(2))
                continue
            if cur is None:
                continue
            if line.strip() == "}":
                cur = None
                continue
            mi = _INSTR_RE.match(line)
            if mi:
                ins = Instr(mi.group(1), mi.group(2), mi.group(3),
                            mi.group(4))
                self.comps[cur].append(ins)
                self.shapes[ins.name] = ins.shape

    def _operands(self, ins: Instr) -> List[str]:
        # operand list = %names inside the first balanced paren group
        depth, buf = 1, []
        for ch in ins.rest:
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
            buf.append(ch)
        return _OPND_RE.findall("".join(buf))

    def _operand_bytes(self, ins: Instr) -> int:
        return sum(_shape_numel_bytes(self.shapes.get(n, ""))[1]
                   for n in self._operands(ins))

    def _fusion_boundary_bytes(self, ins: Instr, called: str) -> float:
        """Slice-aware fusion boundary bytes (matches HloCostAnalysis
        semantics): a parameter consumed only by dynamic-slice/gather
        contributes the *slice* bytes, not the full buffer; a root
        dynamic-update-slice writes only the update region."""
        comp = self.comps.get(called, [])
        params: Dict[int, str] = {}
        consumers: Dict[str, List[Instr]] = {}
        for i in comp:
            if i.op == "parameter":
                try:
                    params[int(i.rest.split(")")[0])] = i.name
                except ValueError:
                    pass
            for opnd in self._operands(i):
                consumers.setdefault(opnd, []).append(i)
        operands = self._operands(ins)
        total = 0.0
        for pos, opnd in enumerate(operands):
            full = _shape_numel_bytes(self.shapes.get(opnd, ""))[1]
            pname = params.get(pos)
            uses = consumers.get(pname, []) if pname else []
            if uses and all(u.op in ("dynamic-slice", "gather", "slice")
                            for u in uses):
                total += sum(_shape_numel_bytes(u.shape)[1] for u in uses)
            else:
                total += full
        # root write: in-place dynamic-update-slice only touches the update
        root = comp[-1] if comp else None
        if root is not None and root.op == "dynamic-update-slice":
            ops = self._operands(root)
            upd = (_shape_numel_bytes(self.shapes.get(ops[1], ""))[1]
                   if len(ops) > 1 else 0)
            total += upd
        else:
            total += _shape_numel_bytes(ins.shape)[1]
        return total

    def _instr_cost(self, ins: Instr) -> Cost:
        c = Cost()
        numel, rbytes = _shape_numel_bytes(ins.shape)
        op = ins.op
        base = op[:-6] if op.endswith("-start") else op
        if op.endswith("-done"):
            return c
        if base in _COLLECTIVES:
            ob = self._operand_bytes(ins)
            if ob == 0:
                ob = rbytes
            # bytes a device moves over ICI: all-gather RECEIVES the full
            # result; reduce-scatter/all-reduce/a2a move ~operand bytes.
            moved = rbytes if base == "all-gather" else ob
            c.coll[base] += moved
            c.bytes += ob + rbytes
            return c
        if op == "fusion":
            m = _CALLS_RE.search(ins.rest)
            if m:
                inner = self.comp_cost(m.group(1))
                c.flops += inner.flops
                for k in c.coll:
                    c.coll[k] += inner.coll[k]
                c.bytes += self._fusion_boundary_bytes(ins, m.group(1))
            else:
                c.bytes += self._operand_bytes(ins) + rbytes
            return c
        if op in ("dynamic-slice", "slice", "gather"):
            # reads only the slice; indices negligible
            c.bytes += 2.0 * rbytes
            return c
        if op == "dynamic-update-slice":
            ops = self._operands(ins)
            upd = (_shape_numel_bytes(self.shapes.get(ops[1], ""))[1]
                   if len(ops) > 1 else rbytes)
            c.bytes += 2.0 * upd
            return c
        if op == "scatter":
            ops = self._operands(ins)
            upd = (_shape_numel_bytes(self.shapes.get(ops[2], ""))[1]
                   if len(ops) > 2 else rbytes)
            c.bytes += 3.0 * upd
            return c
        if op == "while":
            trip = 1
            mt = _TRIP_RE.search(ins.rest)
            if mt:
                trip = int(mt.group(1))
            body = _BODY_RE.search(ins.rest)
            cond = _COND_RE.search(ins.rest)
            if body:
                c += self.comp_cost(body.group(1)).scaled(trip)
            if cond:
                c += self.comp_cost(cond.group(1)).scaled(trip)
            return c
        if op == "dot":
            k = 1
            mc = _CDIMS_RE.search(ins.rest)
            ops = self._operands(ins)
            if mc and ops:
                lhs_shape = self.shapes.get(ops[0], "")
                sm = _SHAPE_RE.search(lhs_shape)
                if sm:
                    dims = [int(d) for d in sm.group(2).split(",") if d]
                    for ci in mc.group(1).split(","):
                        if ci and int(ci) < len(dims):
                            k *= dims[int(ci)]
            c.flops += 2.0 * numel * k
            c.bytes += self._operand_bytes(ins) + rbytes
            return c
        if op in _ELEMENTWISE:
            c.flops += numel
            c.bytes += self._operand_bytes(ins) + rbytes
            return c
        if op == "reduce":
            ops = self._operands(ins)
            if ops:
                on, ob = _shape_numel_bytes(self.shapes.get(ops[0], ""))
                c.flops += on
                c.bytes += ob + rbytes
            return c
        if op in _ZERO_BYTES:
            return c
        # default: memory op (copy, gather, scatter, slice, sort, ...)
        c.bytes += self._operand_bytes(ins) + rbytes
        return c

    def comp_cost(self, name: str) -> Cost:
        if name in self._memo:
            return self._memo[name]
        total = Cost()
        self._memo[name] = total           # guard vs cycles
        for ins in self.comps.get(name, []):
            total += self._instr_cost(ins)
        return total

    def entry_cost(self) -> Cost:
        assert self.entry, "no ENTRY computation found"
        return self.comp_cost(self.entry)


def analyze(hlo_text: str) -> Cost:
    return HloCostModel(hlo_text).entry_cost()
