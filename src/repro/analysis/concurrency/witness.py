"""Lock hierarchy + runtime lock-order witness.

This module is deliberately import-light (stdlib only, no jax/numpy): it
is imported by ``core/futures.py`` and every serving module at startup.

Declared hierarchy (DESIGN.md §9)
---------------------------------
Outer locks rank HIGHER; a thread may acquire a lock only while every
lock it already holds ranks strictly above it.  Acquisition therefore
always descends::

    autoscaler > client > router > service > tenant > compaction
               > coalescer > executor > inflight > ticket > future

``compaction`` guards index mutation (the segmented index's delta append
/ tombstone / seal-publish critical sections, ``core/segments.py``); it
sits below ``service``/``router`` so a serving layer may mutate its index
while holding its own lock, and above ``coalescer``/``executor`` so the
mutation path can never invert against a dispatch.  ``tenant`` guards
the tenant manager's quota buckets and per-tenant books
(``serve/tenants.py``): it is never held across a backend call, and it
sits BELOW ``service`` because the accounting runs in future
done-callbacks, which the batching service fires while holding its own
lock.  ``inflight`` is the
executor's ``_InflightQueue`` lock: it is acquired first when claiming or
retiring a depth slot, with the owning ticket's bookkeeping lock nested
inside it (descending), so a stall-checking ``BatchTicket.wait()`` can
never observe a claimed-but-uncounted window.

Factories
---------
Every lock in the serving stack is created through :func:`make_lock`,
:func:`make_rlock`, or :func:`make_condition` with its rank name — the
static passes read ranks straight out of these calls, and the purity lint
(PU03) rejects bare ``threading.Lock()`` anywhere else.  With
``LINT_LOCKS`` unset the factories return plain ``threading`` primitives
(zero overhead); with ``LINT_LOCKS=1`` they return instrumented
:class:`OrderedLock` objects that record every nested acquisition edge in
the process-wide :data:`WITNESS` and log any order inversion against the
hierarchy.  ``LINT_LOCKS=strict`` additionally RAISES
:class:`LockOrderViolation` at the offending acquire (unit tests; the
stress gates use record mode so a violation fails the test cleanly via
the conftest guard instead of wedging a pump thread mid-protocol).
"""

from __future__ import annotations

import os
import sys
import threading
from typing import Dict, List, Optional, Set, Tuple

__all__ = ["HIERARCHY", "LEVEL", "LockOrderViolation", "OrderedLock",
           "Witness", "WITNESS", "enabled", "strict",
           "make_lock", "make_rlock", "make_condition"]

# innermost first: LEVEL[x] < LEVEL[y] means x must be acquired inside y
HIERARCHY: Tuple[str, ...] = ("future", "ticket", "inflight", "executor",
                              "coalescer", "compaction", "tenant",
                              "service", "router", "client", "autoscaler")
LEVEL: Dict[str, int] = {name: i for i, name in enumerate(HIERARCHY)}


class LockOrderViolation(BaseException):
    """A lock was acquired while holding a lock at or below its level.

    Subclasses ``BaseException`` on purpose: the serving stack's pump and
    ticker loops survive ``Exception`` (a poison batch must not kill a
    replica), but a lock-order inversion is a latent deadlock and must
    never be absorbed by those handlers.
    """


def enabled() -> bool:
    return bool(os.environ.get("LINT_LOCKS"))


def strict() -> bool:
    return os.environ.get("LINT_LOCKS", "").lower() == "strict"


class Witness:
    """Process-wide recorder of actual nested lock acquisitions.

    Thread-local held-lock stacks; a shared edge set ``(outer_rank,
    inner_rank)`` and a violation log.  ``strict=True`` raises at the
    offending acquire instead of only recording.
    """

    def __init__(self, strict: bool = False):
        self.strict = strict
        self._tls = threading.local()
        # meta-lock for the shared edge/violation registries only; it is
        # never held across a ranked-lock acquire, so it cannot deadlock
        self._reg = threading.Lock()
        self.edges: Set[Tuple[str, str]] = set()
        self.violations: List[Dict[str, object]] = []

    # ------------------------------------------------------------ per-thread
    def _stack(self) -> List["OrderedLock"]:
        st = getattr(self._tls, "stack", None)
        if st is None:
            st = self._tls.stack = []
        return st

    # ------------------------------------------------------------- protocol
    def before_acquire(self, lock: "OrderedLock") -> None:
        """Order check — runs BEFORE the blocking acquire, so a genuine
        inversion is reported rather than deadlocking silently."""
        held = self._stack()
        if not held:
            return
        bad = []
        for h in held:
            if h is lock:               # re-entrant acquire (RLock): fine
                continue
            with self._reg:
                self.edges.add((h.rank, lock.rank))
            if LEVEL[h.rank] <= LEVEL[lock.rank]:
                bad.append(h.rank)
        if bad:
            frame = sys._getframe(2)
            site = f"{frame.f_code.co_filename}:{frame.f_lineno}"
            record = {"thread": threading.current_thread().name,
                      "held": [h.rank for h in held],
                      "acquiring": lock.rank, "site": site}
            with self._reg:
                self.violations.append(record)
            if self.strict:
                raise LockOrderViolation(
                    f"acquiring {lock.rank!r} (level {LEVEL[lock.rank]}) "
                    f"while holding {bad!r} at or below it "
                    f"(held stack: {[h.rank for h in held]}) at {site}; "
                    f"declared hierarchy: {' < '.join(HIERARCHY)}")

    def after_acquire(self, lock: "OrderedLock") -> None:
        self._stack().append(lock)

    def on_release(self, lock: "OrderedLock", count: int = 1) -> None:
        st = self._stack()
        for _ in range(count):
            # releases may be non-LIFO (condition-variable hand-offs):
            # drop the newest frame for THIS lock, wherever it sits
            for i in range(len(st) - 1, -1, -1):
                if st[i] is lock:
                    del st[i]
                    break

    def held_count(self, lock: "OrderedLock") -> int:
        return sum(1 for h in self._stack() if h is lock)

    # -------------------------------------------------------------- reading
    def witnessed_edges(self) -> Set[Tuple[str, str]]:
        with self._reg:
            return set(self.edges)

    def drain_violations(self) -> List[Dict[str, object]]:
        with self._reg:
            out, self.violations = self.violations, []
            return out

    def reset(self) -> None:
        with self._reg:
            self.edges.clear()
            self.violations.clear()


#: the process-wide witness the factories bind to under LINT_LOCKS
WITNESS = Witness()


class OrderedLock:
    """Rank-aware wrapper over ``threading.Lock``/``RLock``.

    Implements the full lock protocol plus the private hooks
    ``threading.Condition`` uses (``_is_owned`` / ``_release_save`` /
    ``_acquire_restore``), so ``threading.Condition(OrderedLock(...))``
    behaves exactly like a Condition over the raw primitive while keeping
    the witness's held-stack bookkeeping correct across ``wait()`` (the
    lock is fully released while parked, so no false inversions against a
    parked pump thread)."""

    __slots__ = ("rank", "_inner", "_witness", "_reentrant")

    def __init__(self, rank: str, witness: Optional[Witness] = None, *,
                 reentrant: bool = False):
        if rank not in LEVEL:
            raise ValueError(f"unknown lock rank {rank!r}; "
                             f"one of {HIERARCHY}")
        self.rank = rank
        self._reentrant = reentrant
        self._inner = threading.RLock() if reentrant else threading.Lock()
        self._witness = witness if witness is not None else WITNESS

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        self._witness.before_acquire(self)
        got = self._inner.acquire(blocking, timeout)
        if got:
            self._witness.after_acquire(self)
        return got

    def release(self) -> None:
        self._inner.release()
        self._witness.on_release(self)

    def __enter__(self) -> "OrderedLock":
        self.acquire()
        return self

    def __exit__(self, *exc) -> None:
        self.release()

    def locked(self) -> bool:
        return self._inner.locked()

    def __repr__(self) -> str:
        kind = "RLock" if self._reentrant else "Lock"
        return f"OrderedLock({self.rank!r}, {kind})"

    # ---------------------------------------------- Condition integration
    def _is_owned(self) -> bool:
        inner_owned = getattr(self._inner, "_is_owned", None)
        if inner_owned is not None:
            return inner_owned()
        if self._inner.acquire(False):      # plain Lock heuristic, as in
            self._inner.release()           # threading.Condition
            return False
        return True

    def _release_save(self):
        """Fully release (any re-entrant depth) for ``Condition.wait``."""
        depth = max(self._witness.held_count(self), 1)
        saver = getattr(self._inner, "_release_save", None)
        state = saver() if saver is not None else self._inner.release()
        self._witness.on_release(self, count=depth)
        return (state, depth)

    def _acquire_restore(self, saved) -> None:
        state, depth = saved
        self._witness.before_acquire(self)
        restorer = getattr(self._inner, "_acquire_restore", None)
        if restorer is not None:
            restorer(state)
        else:
            self._inner.acquire()
        for _ in range(depth):
            self._witness.after_acquire(self)


# ---------------------------------------------------------------------------
# Factories — the only place serving code creates locks
# ---------------------------------------------------------------------------

def _witness() -> Witness:
    WITNESS.strict = strict()
    return WITNESS


def make_lock(rank: str) -> threading.Lock:
    """A mutex at ``rank``: plain ``threading.Lock`` normally, an
    instrumented :class:`OrderedLock` under ``LINT_LOCKS``."""
    if enabled():
        return OrderedLock(rank, _witness(), reentrant=False)
    if rank not in LEVEL:
        raise ValueError(f"unknown lock rank {rank!r}; one of {HIERARCHY}")
    return threading.Lock()


def make_rlock(rank: str) -> threading.RLock:
    """Re-entrant variant of :func:`make_lock`."""
    if enabled():
        return OrderedLock(rank, _witness(), reentrant=True)
    if rank not in LEVEL:
        raise ValueError(f"unknown lock rank {rank!r}; one of {HIERARCHY}")
    return threading.RLock()


def make_condition(rank: str, lock=None) -> threading.Condition:
    """A condition variable at ``rank``.  Pass ``lock`` to share an
    existing factory-made lock (e.g. a service's ``_cv`` over its
    ``_lock``); otherwise a fresh non-reentrant lock at ``rank`` backs
    it."""
    if lock is None:
        lock = make_lock(rank)
    return threading.Condition(lock)
