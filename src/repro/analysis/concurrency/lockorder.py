"""Static lock-order analyzer (LO01/LO02/LO03).

Extracts the cross-module lock-acquisition graph and checks every edge
against the declared hierarchy in :mod:`.witness` (outer locks rank
higher; acquisition must strictly descend).

Resolution strategy, in order of preference:

1. **Lexical nesting** — ``with self._inner:`` inside ``with self._outer:``
   yields edge ``(outer_rank, inner_rank)``.  Ranks come from the witness
   factory call on the attribute's declaration
   (``self._lock = make_lock("router")``).
2. **Same-class summaries** — a call ``self.m()`` under a held lock
   contributes every rank ``m`` may transitively acquire.
3. **Unique-name cross-class resolution** — ``obj.m()`` resolves when
   exactly one class in the analyzed fileset defines ``m`` (e.g.
   ``_set_result`` only exists on ``QueryFuture``).  Ambiguous names are
   skipped rather than guessed.
4. **Annotations** — ``# acquires: <rank>`` on a statement declares what
   an unresolvable call or local-variable ``with`` may take.

Codes: LO01 — an edge that contradicts the hierarchy; LO02 — a cycle in
the acquisition graph; LO03 — a rank name the hierarchy doesn't declare.
"""

from __future__ import annotations

import ast
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.analysis.concurrency.diagnostics import Diagnostic, SourceFile
from repro.analysis.concurrency.guarded import (ClassLocks, _self_attr,
                                                collect_class_locks)
from repro.analysis.concurrency.witness import HIERARCHY, LEVEL

# (outer_rank, inner_rank, path, line)
Edge = Tuple[str, str, str, int]

_MODULE = "<module>"


def _lock_primitive_receiver(fn: ast.AST, locks: ClassLocks) -> bool:
    """True for ``self.<lockattr>.wait()`` etc. — methods ON a lock/cond
    object are threading primitives, never repo methods, so unique-name
    resolution must not fire on them (``self._cond.wait`` is
    ``Condition.wait``, not ``BatchTicket.wait``)."""
    if isinstance(fn, ast.Attribute):
        attr = _self_attr(fn.value)
        return attr is not None and attr in locks.locks
    return False


class _Method:
    def __init__(self, path: str, cls: str, name: str,
                 node: ast.AST, locks: ClassLocks, sf: SourceFile):
        self.path = path
        self.cls = cls
        self.name = name
        self.node = node
        self.locks = locks
        self.sf = sf
        self.key = (path, cls, name)
        self.direct: Set[str] = set()     # ranks acquired in this body
        self.callees: Set[Tuple[str, str, str]] = set()
        self.summary: Set[str] = set()    # transitive closure (fixpoint)


def _rank_of(locks: ClassLocks, attr: str) -> Optional[str]:
    return locks.rank.get(locks.canonical(attr))


class _BodyScanner(ast.NodeVisitor):
    """First pass per method: direct rank acquisitions + resolvable callees."""

    def __init__(self, m: _Method, registry: Dict[str, List[_Method]],
                 diags: List[Diagnostic]):
        self.m = m
        self.registry = registry
        self.diags = diags

    def _annotated(self, line: int) -> List[str]:
        ranks = self.m.sf.acquires(line)
        for r in ranks:
            if r not in LEVEL:
                self.diags.append(Diagnostic(
                    self.m.path, line, "LO03",
                    f"acquires names unknown rank {r!r}; "
                    f"hierarchy: {' < '.join(HIERARCHY)}"))
        return [r for r in ranks if r in LEVEL]

    def visit_With(self, node: ast.With) -> None:
        for item in node.items:
            attr = _self_attr(item.context_expr)
            if attr is not None:
                rank = _rank_of(self.m.locks, attr)
                if rank is not None:
                    self.m.direct.add(rank)
        self.m.direct.update(self._annotated(node.lineno))
        self.generic_visit(node)

    def visit_Call(self, node: ast.Call) -> None:
        self.m.direct.update(self._annotated(node.lineno))
        fn = node.func
        name: Optional[str] = None
        same_class = False
        if _lock_primitive_receiver(fn, self.m.locks):
            name = None
        elif isinstance(fn, ast.Attribute):
            name = fn.attr
            same_class = isinstance(fn.value, ast.Name) \
                and fn.value.id == "self"
        elif isinstance(fn, ast.Name):
            name = fn.id
        if name is not None:
            target = self._resolve(name, same_class)
            if target is not None:
                self.m.callees.add(target.key)
        self.generic_visit(node)

    def visit_Expr(self, node: ast.Expr) -> None:
        self.m.direct.update(self._annotated(node.lineno))
        self.generic_visit(node)

    def _resolve(self, name: str, same_class: bool) -> Optional[_Method]:
        if same_class:
            for cand in self.registry.get(name, []):
                if cand.path == self.m.path and cand.cls == self.m.cls:
                    return cand
            return None
        cands = self.registry.get(name, [])
        return cands[0] if len(cands) == 1 else None


class _EdgeExtractor(ast.NodeVisitor):
    """Second pass: walk with a held-rank stack, emitting graph edges."""

    def __init__(self, m: _Method, summaries: Dict[Tuple, Set[str]],
                 registry: Dict[str, List[_Method]], edges: List[Edge]):
        self.m = m
        self.summaries = summaries
        self.registry = registry
        self.edges = edges
        self.held: List[str] = []

    def _emit(self, ranks: Set[str], line: int) -> None:
        for outer in self.held:
            for inner in ranks:
                if inner != outer:
                    self.edges.append((outer, inner, self.m.path, line))

    def _annotated(self, line: int) -> Set[str]:
        return {r for r in self.m.sf.acquires(line) if r in LEVEL}

    def visit_With(self, node: ast.With) -> None:
        got: List[str] = []
        for item in node.items:
            attr = _self_attr(item.context_expr)
            if attr is not None:
                rank = _rank_of(self.m.locks, attr)
                if rank is not None:
                    got.append(rank)
        ann = self._annotated(node.lineno)
        self._emit(set(got) | ann, node.lineno)
        self.held.extend(got)
        # annotated ranks on a with-line describe the context manager's own
        # acquisitions (held for the body)
        self.held.extend(sorted(ann))
        self.generic_visit(node)
        del self.held[len(self.held) - len(got) - len(ann):]

    def visit_Call(self, node: ast.Call) -> None:
        acquired = set(self._annotated(node.lineno))
        fn = node.func
        name: Optional[str] = None
        same_class = False
        if _lock_primitive_receiver(fn, self.m.locks):
            name = None
        elif isinstance(fn, ast.Attribute):
            name = fn.attr
            same_class = isinstance(fn.value, ast.Name) \
                and fn.value.id == "self"
        elif isinstance(fn, ast.Name):
            name = fn.id
        if name is not None:
            target = None
            if same_class:
                for cand in self.registry.get(name, []):
                    if cand.path == self.m.path and cand.cls == self.m.cls:
                        target = cand
                        break
            else:
                cands = self.registry.get(name, [])
                target = cands[0] if len(cands) == 1 else None
            if target is not None:
                acquired |= self.summaries.get(target.key, set())
        if acquired:
            self._emit(acquired, node.lineno)
        self.generic_visit(node)

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        saved = self.held
        self.held = []
        for attr in self.m.sf.holds(node.lineno):
            rank = _rank_of(self.m.locks, attr)
            if rank is not None:
                self.held.append(rank)
        for stmt in node.body:
            self.visit(stmt)
        self.held = saved

    visit_AsyncFunctionDef = visit_FunctionDef

    def visit_Lambda(self, node: ast.Lambda) -> None:
        saved, self.held = self.held, []
        self.visit(node.body)
        self.held = saved


def _collect_methods(sources: Sequence[SourceFile],
                     diags: List[Diagnostic]) -> List[_Method]:
    methods: List[_Method] = []
    for sf in sources:
        if sf.tree is None:
            continue
        for cls in [n for n in ast.walk(sf.tree)
                    if isinstance(n, ast.ClassDef)]:
            locks = collect_class_locks(cls)
            for attr, rank in locks.rank.items():
                if rank not in LEVEL:
                    line = next((n.lineno for n in ast.walk(cls)
                                 if isinstance(n, ast.Assign)
                                 and _self_attr(n.targets[0]) == attr), 1)
                    diags.append(Diagnostic(
                        sf.path, line, "LO03",
                        f"lock self.{attr} declares unknown rank {rank!r}; "
                        f"hierarchy: {' < '.join(HIERARCHY)}"))
            for meth in cls.body:
                if isinstance(meth, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    methods.append(_Method(sf.path, cls.name, meth.name,
                                           meth, locks, sf))
        for node in sf.tree.body:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                methods.append(_Method(sf.path, _MODULE, node.name, node,
                                       ClassLocks(), sf))
    return methods


def extract_edges(sources: Sequence[SourceFile],
                  diags: List[Diagnostic]) -> List[Edge]:
    methods = _collect_methods(sources, diags)
    registry: Dict[str, List[_Method]] = {}
    for m in methods:
        registry.setdefault(m.name, []).append(m)

    for m in methods:
        scanner = _BodyScanner(m, registry, diags)
        for stmt in m.node.body:
            scanner.visit(stmt)

    # fixpoint: propagate acquired ranks through resolved calls
    summaries = {m.key: set(m.direct) for m in methods}
    by_key = {m.key: m for m in methods}
    changed = True
    while changed:
        changed = False
        for m in methods:
            s = summaries[m.key]
            before = len(s)
            for callee in m.callees:
                s |= summaries.get(callee, set())
            if len(s) != before:
                changed = True
    for m in methods:
        m.summary = summaries[m.key]

    edges: List[Edge] = []
    for m in methods:
        ex = _EdgeExtractor(m, summaries, registry, edges)
        for attr in m.sf.holds(m.node.lineno):
            rank = _rank_of(m.locks, attr)
            if rank is not None:
                ex.held.append(rank)
        for stmt in m.node.body:
            ex.visit(stmt)
    return edges


def _find_cycle(graph: Dict[str, Set[str]]) -> Optional[List[str]]:
    WHITE, GREY, BLACK = 0, 1, 2
    color = {n: WHITE for n in graph}
    path: List[str] = []

    def dfs(n: str) -> Optional[List[str]]:
        color[n] = GREY
        path.append(n)
        for nxt in sorted(graph.get(n, ())):
            if color.get(nxt, WHITE) == GREY:
                return path[path.index(nxt):] + [nxt]
            if color.get(nxt, WHITE) == WHITE:
                found = dfs(nxt)
                if found:
                    return found
        path.pop()
        color[n] = BLACK
        return None

    for n in sorted(graph):
        if color[n] == WHITE:
            found = dfs(n)
            if found:
                return found
    return None


def check_files(sources: Sequence[SourceFile]) -> List[Diagnostic]:
    diags: List[Diagnostic] = []
    edges = extract_edges(sources, diags)

    seen: Set[Tuple[str, str, str, int]] = set()
    graph: Dict[str, Set[str]] = {}
    site: Dict[Tuple[str, str], Tuple[str, int]] = {}
    for outer, inner, path, line in edges:
        graph.setdefault(outer, set()).add(inner)
        graph.setdefault(inner, set())
        site.setdefault((outer, inner), (path, line))
        if LEVEL[inner] >= LEVEL[outer]:
            key = (outer, inner, path, line)
            if key not in seen:
                seen.add(key)
                diags.append(Diagnostic(
                    path, line, "LO01",
                    f"acquires {inner!r} (level {LEVEL[inner]}) while "
                    f"holding {outer!r} (level {LEVEL[outer]}); hierarchy "
                    f"requires strictly descending acquisition "
                    f"({' < '.join(HIERARCHY)})"))

    cycle = _find_cycle(graph)
    if cycle is not None:
        path, line = site[(cycle[0], cycle[1])]
        diags.append(Diagnostic(
            path, line, "LO02",
            f"lock-acquisition cycle: {' -> '.join(cycle)}"))
    return diags
