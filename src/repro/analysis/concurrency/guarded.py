"""Guarded-by checker (GB01/GB02).

Fields declared with a trailing ``# guarded-by: <lockattr>`` comment may
only be read or written while ``self.<lockattr>`` is held — either inside
a lexical ``with self.<lockattr>:`` block, or in a method whose ``def``
line carries ``# holds: <lockattr>``.

Lock attributes are recognised from their construction site: the witness
factories (``make_lock``/``make_rlock``/``make_condition``) or bare
``threading.Lock/RLock/Condition`` calls (the latter are PU03 findings,
but the guard analysis still honours them).  A condition built over an
existing lock — ``make_condition(rank, self._lock)`` or
``threading.Condition(self._lock)`` — aliases that lock: holding either
name satisfies a guard on the other.

``__init__``, ``__getstate__``, and ``__setstate__`` are exempt: the
object is thread-confined during construction and (un)pickling.
"""

from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.concurrency.diagnostics import Diagnostic, SourceFile

_EXEMPT_METHODS = {"__init__", "__getstate__", "__setstate__", "__del__",
                   "__repr__"}

_FACTORIES = {"make_lock", "make_rlock", "make_condition"}
_THREADING_CTORS = {"Lock", "RLock", "Condition"}


def _call_name(call: ast.Call) -> Optional[str]:
    fn = call.func
    if isinstance(fn, ast.Name):
        return fn.id
    if isinstance(fn, ast.Attribute):
        return fn.attr
    return None


def _is_lock_ctor(call: ast.Call) -> bool:
    name = _call_name(call)
    if name in _FACTORIES:
        return True
    if name in _THREADING_CTORS and isinstance(call.func, ast.Attribute) \
            and isinstance(call.func.value, ast.Name) \
            and call.func.value.id == "threading":
        return True
    return False


def _self_attr(node: ast.AST) -> Optional[str]:
    if isinstance(node, ast.Attribute) and isinstance(node.value, ast.Name) \
            and node.value.id == "self":
        return node.attr
    return None


class ClassLocks:
    """Lock attributes of one class, with condition-over-lock aliasing."""

    def __init__(self) -> None:
        self.locks: Set[str] = set()
        self.rank: Dict[str, str] = {}         # attr -> declared rank
        self._alias: Dict[str, str] = {}       # attr -> canonical attr

    def add(self, attr: str, call: ast.Call) -> None:
        self.locks.add(attr)
        name = _call_name(call)
        if name in _FACTORIES and call.args \
                and isinstance(call.args[0], ast.Constant) \
                and isinstance(call.args[0].value, str):
            self.rank[attr] = call.args[0].value
        # make_condition(rank, self._lock) / threading.Condition(self._lock)
        base = None
        if name == "make_condition" and len(call.args) >= 2:
            base = _self_attr(call.args[1])
        elif name == "Condition" and call.args:
            base = _self_attr(call.args[0])
        if base is not None:
            self._alias[attr] = base

    def canonical(self, attr: str) -> str:
        seen = set()
        while attr in self._alias and attr not in seen:
            seen.add(attr)
            attr = self._alias[attr]
        return attr


def collect_class_locks(cls: ast.ClassDef) -> ClassLocks:
    locks = ClassLocks()
    for node in ast.walk(cls):
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.value, ast.Call) \
                and _is_lock_ctor(node.value):
            attr = _self_attr(node.targets[0])
            if attr is not None:
                locks.add(attr, node.value)
    return locks


def _guarded_fields(cls: ast.ClassDef, sf: SourceFile,
                    locks: ClassLocks) -> Tuple[Dict[str, str],
                                                List[Diagnostic]]:
    """``# guarded-by:`` annotated field declarations -> lock attr."""
    fields: Dict[str, str] = {}
    diags: List[Diagnostic] = []
    for node in ast.walk(cls):
        targets: List[ast.AST] = []
        if isinstance(node, ast.Assign):
            targets = list(node.targets)
        elif isinstance(node, (ast.AnnAssign, ast.AugAssign)):
            targets = [node.target]
        else:
            continue
        # the annotation may trail any line of a multi-line declaration
        guard = None
        for ln in range(node.lineno, (node.end_lineno or node.lineno) + 1):
            guard = sf.guarded_by(ln)
            if guard is not None:
                break
        if guard is None:
            continue
        if locks.canonical(guard) not in locks.locks:
            diags.append(Diagnostic(
                sf.path, node.lineno, "GB02",
                f"guarded-by names unknown lock {guard!r} "
                f"(class declares: {sorted(locks.locks) or 'none'})"))
            continue
        for t in targets:
            attr = _self_attr(t)
            if attr is not None:
                fields[attr] = locks.canonical(guard)
    return fields, diags


class _MethodChecker(ast.NodeVisitor):
    def __init__(self, sf: SourceFile, locks: ClassLocks,
                 fields: Dict[str, str], method: str):
        self.sf = sf
        self.locks = locks
        self.fields = fields
        self.method = method
        self.held: Set[str] = set()
        self.diags: List[Diagnostic] = []

    # -------------------------------------------------------------- scopes
    def _with_locks(self, node: ast.With) -> Set[str]:
        got: Set[str] = set()
        for item in node.items:
            attr = _self_attr(item.context_expr)
            if attr is not None and attr in self.locks.locks:
                got.add(self.locks.canonical(attr))
        return got

    def visit_With(self, node: ast.With) -> None:
        got = self._with_locks(node)
        added = got - self.held
        self.held |= added
        self.generic_visit(node)
        self.held -= added

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        # nested def: runs later, possibly on another thread — its body
        # starts from its own ``# holds:`` annotation, not our held set
        saved = self.held
        self.held = {self.locks.canonical(a)
                     for a in self.sf.holds(node.lineno)}
        for stmt in node.body:
            self.visit(stmt)
        self.held = saved

    visit_AsyncFunctionDef = visit_FunctionDef

    def visit_Lambda(self, node: ast.Lambda) -> None:
        saved, self.held = self.held, set()
        self.visit(node.body)
        self.held = saved

    # ------------------------------------------------------------- accesses
    def visit_Attribute(self, node: ast.Attribute) -> None:
        attr = _self_attr(node)
        if attr is not None and attr in self.fields:
            need = self.fields[attr]
            if need not in self.held:
                kind = "write" if isinstance(node.ctx,
                                             (ast.Store, ast.Del)) else "read"
                self.diags.append(Diagnostic(
                    self.sf.path, node.lineno, "GB01",
                    f"{kind} of self.{attr} (guarded-by {need}) in "
                    f"{self.method}() without holding it — wrap in "
                    f"'with self.{need}:' or annotate the def "
                    f"'# holds: {need}'"))
        self.generic_visit(node)


def check_file(sf: SourceFile) -> List[Diagnostic]:
    diags: List[Diagnostic] = []
    if sf.tree is None:
        return diags
    for cls in [n for n in ast.walk(sf.tree) if isinstance(n, ast.ClassDef)]:
        locks = collect_class_locks(cls)
        fields, fdiags = _guarded_fields(cls, sf, locks)
        diags.extend(fdiags)
        if not fields:
            continue
        for meth in cls.body:
            if not isinstance(meth, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if meth.name in _EXEMPT_METHODS:
                continue
            chk = _MethodChecker(sf, locks, fields, meth.name)
            chk.held = {locks.canonical(a) for a in sf.holds(meth.lineno)}
            unknown = [a for a in sf.holds(meth.lineno)
                       if locks.canonical(a) not in locks.locks]
            for a in unknown:
                diags.append(Diagnostic(
                    sf.path, meth.lineno, "GB02",
                    f"holds names unknown lock {a!r}"))
            for stmt in meth.body:
                chk.visit(stmt)
            diags.extend(chk.diags)
    return diags
