"""CLI: ``python -m repro.analysis.concurrency --check src/``."""

from __future__ import annotations

import argparse
import sys

from repro.analysis.concurrency import collect_files, run_checks


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.concurrency",
        description="Concurrency static analysis: guarded-by (GB*), "
                    "lock-order (LO*), and hot-path purity (PU*) lints.")
    ap.add_argument("--check", nargs="+", metavar="PATH", required=True,
                    help="files or directories to analyze")
    ap.add_argument("--only", nargs="*", metavar="FAMILY",
                    choices=("guarded", "lockorder", "purity"),
                    help="restrict to the named pass families")
    ap.add_argument("-q", "--quiet", action="store_true",
                    help="suppress the summary line")
    args = ap.parse_args(argv)

    diags = run_checks(args.check, checks=args.only or None)
    for d in diags:
        print(d)
    if not args.quiet:
        n_files = len(collect_files(args.check))
        if diags:
            print(f"{len(diags)} finding(s) in {n_files} file(s)",
                  file=sys.stderr)
        else:
            print(f"concurrency lint clean: {n_files} file(s)",
                  file=sys.stderr)
    return 1 if diags else 0


if __name__ == "__main__":
    sys.exit(main())
