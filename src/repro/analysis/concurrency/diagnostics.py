"""Shared diagnostic/annotation plumbing for the concurrency lints.

Annotation grammar (DESIGN.md §9)
---------------------------------
All annotations are line comments; the key phrase may be followed by free
prose::

    self._queue = deque()        # guarded-by: _lock
    def _compact_locked(self):   # holds: _lock
        ...
    r.live_load()                # acquires: service
    return self._state != _PENDING  # lint-ok: GB01 lock-free fast path

* ``guarded-by: <attr>`` — on a ``self.<field> = ...`` declaration: the
  field may only be touched while ``self.<attr>`` is held.
* ``holds: <attr>[, <attr>]`` — on a ``def`` line: the caller guarantees
  these locks are held for the whole body.
* ``acquires: <rank>[, <rank>]`` — on any statement: it may acquire locks
  of the named rank(s) (for cross-object calls / local-alias ``with``
  blocks the AST pass cannot resolve).
* ``lint-ok: CODE reason`` — suppress one diagnostic of ``CODE`` on this
  line (or the line below, when written alone on its own line).  The
  reason is mandatory: a bare ``lint-ok: CODE`` surfaces as LT00.
"""

from __future__ import annotations

import ast
import dataclasses
import io
import re
import tokenize
from typing import Dict, List, Optional


@dataclasses.dataclass(frozen=True)
class Diagnostic:
    path: str
    line: int
    code: str
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: [{self.code}] {self.message}"


@dataclasses.dataclass(frozen=True)
class Suppression:
    line: int
    code: str
    reason: str


_GUARDED_RE = re.compile(r"guarded-by:\s*([A-Za-z_]\w*)")
_HOLDS_RE = re.compile(r"holds:\s*([A-Za-z_]\w*(?:\s*,\s*[A-Za-z_]\w*)*)")
_ACQUIRES_RE = re.compile(
    r"acquires:\s*([A-Za-z_]\w*(?:\s*,\s*[A-Za-z_]\w*)*)")
_LINT_OK_RE = re.compile(r"lint-ok:\s*([A-Z]{2}\d{2})\s*(.*)")


class SourceFile:
    """One parsed module: AST + per-line comment map + annotation lookup."""

    def __init__(self, path: str, text: str):
        self.path = path
        self.text = text
        self.lines = text.splitlines()
        self.parse_error: Optional[Diagnostic] = None
        try:
            self.tree: Optional[ast.Module] = ast.parse(text, filename=path)
        except SyntaxError as exc:
            self.tree = None
            self.parse_error = Diagnostic(
                path, exc.lineno or 1, "LT01", f"syntax error: {exc.msg}")
        self.comments: Dict[int, str] = {}
        try:
            for tok in tokenize.generate_tokens(io.StringIO(text).readline):
                if tok.type == tokenize.COMMENT:
                    self.comments[tok.start[0]] = tok.string
        except (tokenize.TokenError, IndentationError):
            pass

    @classmethod
    def load(cls, path: str) -> "SourceFile":
        with open(path, encoding="utf-8") as fh:
            return cls(path, fh.read())

    # ----------------------------------------------------------- annotations
    def comment_at(self, line: int) -> str:
        return self.comments.get(line, "")

    def guarded_by(self, line: int) -> Optional[str]:
        m = _GUARDED_RE.search(self.comment_at(line))
        return m.group(1) if m else None

    def holds(self, line: int) -> List[str]:
        m = _HOLDS_RE.search(self.comment_at(line))
        return [s.strip() for s in m.group(1).split(",")] if m else []

    def acquires(self, line: int) -> List[str]:
        m = _ACQUIRES_RE.search(self.comment_at(line))
        return [s.strip() for s in m.group(1).split(",")] if m else []

    def suppression_at(self, line: int) -> Optional[Suppression]:
        """A ``lint-ok`` matching ``line``: trailing on the line itself, or
        written alone on the line above."""
        for ln in (line, line - 1):
            m = _LINT_OK_RE.search(self.comment_at(ln))
            if m is None:
                continue
            if ln == line - 1:
                # the preceding line must be comment-only, or its
                # suppression belongs to that line's own code
                stripped = self.lines[ln - 1].strip() \
                    if 0 < ln <= len(self.lines) else ""
                if not stripped.startswith("#"):
                    continue
            return Suppression(ln, m.group(1), m.group(2).strip())
        return None
