"""Concurrency static-analysis suite (DESIGN.md §9).

The serving stack is a real threaded runtime — pump + ticker threads,
per-future condition variables, dispatch/backend/router/autoscaler locks —
whose discipline was previously enforced only by code review.  This
package turns that discipline into checked invariants:

* :mod:`.guarded` — the **guarded-by checker**: fields annotated
  ``# guarded-by: _lock`` on their declaration are verified to be read and
  written only inside a ``with self._lock:`` scope (or in a method the
  caller annotates ``# holds: _lock``).
* :mod:`.lockorder` — the **lock-order analyzer**: a static pass that
  extracts the cross-module lock-acquisition graph (lexical ``with``
  nesting, same-class call resolution, unambiguous cross-class method
  names, and ``# acquires: <rank>`` annotations) and fails on cycles or
  on any edge that contradicts the declared hierarchy in
  :data:`.witness.HIERARCHY`.
* :mod:`.purity` — the **hot-path purity lints**: no device sync or host
  materialisation (``block_until_ready``, ``np.asarray``, ``.item()``,
  ``float()``) while holding a lock; no lock acquisition or Python side
  effects inside ``jax.jit``/Pallas-traced functions; no bare
  ``threading.Lock()`` outside the instrumented :mod:`.witness` wrapper.
* :mod:`.witness` — the **runtime witness**: ``make_lock``/``make_rlock``/
  ``make_condition`` factories every serving-stack lock goes through.
  Plain ``threading`` primitives by default; under ``LINT_LOCKS=1`` they
  return instrumented :class:`~.witness.OrderedLock` objects that record
  actual nested-acquisition edges and flag order inversions against the
  declared hierarchy (the stress gates run with the witness on).

Entry point::

    python -m repro.analysis.concurrency --check src/

Diagnostics come back as ``file:line: [CODE] message``.  Suppress a single
finding with a trailing ``# lint-ok: CODE reason`` comment (on the flagged
line or the line above); a suppression without a reason is itself a
finding (LT00).
"""

from __future__ import annotations

import os
from typing import List, Optional, Sequence

from repro.analysis.concurrency.diagnostics import Diagnostic, SourceFile
from repro.analysis.concurrency import guarded, lockorder, purity
from repro.analysis.concurrency.witness import (HIERARCHY, LEVEL,
                                                LockOrderViolation,
                                                OrderedLock, Witness,
                                                make_condition, make_lock,
                                                make_rlock)

__all__ = ["run_checks", "collect_files", "Diagnostic", "SourceFile",
           "HIERARCHY", "LEVEL", "LockOrderViolation", "OrderedLock",
           "Witness", "make_lock", "make_rlock", "make_condition"]

# files the purity pass treats as jit/Pallas-traced scope (PU02): every
# kernel module plus the shard_map bodies in core/distributed.py
_JIT_SCOPE_MARKERS = (os.sep + os.path.join("repro", "kernels") + os.sep,
                      os.path.join("core", "distributed.py"))


def _in_jit_scope(path: str) -> bool:
    return any(m in path for m in _JIT_SCOPE_MARKERS)


def collect_files(paths: Sequence[str]) -> List[str]:
    """Expand files/directories into the sorted .py file set to analyze."""
    out: List[str] = []
    for p in paths:
        if os.path.isfile(p):
            out.append(p)
            continue
        for root, dirs, files in os.walk(p):
            dirs[:] = [d for d in dirs if d != "__pycache__"]
            out.extend(os.path.join(root, f) for f in files
                       if f.endswith(".py"))
    return sorted(set(out))


def run_checks(paths: Sequence[str],
               checks: Optional[Sequence[str]] = None) -> List[Diagnostic]:
    """Run the requested pass families (default: all three) over ``paths``
    and return the surviving diagnostics, sorted by file/line.

    ``checks`` selects from ``{"guarded", "lockorder", "purity"}``.
    Suppressions (``# lint-ok: CODE reason``) are applied here so every
    family shares one grammar; reasonless suppressions surface as LT00.
    """
    want = set(checks) if checks is not None else \
        {"guarded", "lockorder", "purity"}
    sources = [SourceFile.load(f) for f in collect_files(paths)]
    diags: List[Diagnostic] = []
    for sf in sources:
        if sf.parse_error is not None:
            diags.append(sf.parse_error)
            continue
        if "guarded" in want:
            diags.extend(guarded.check_file(sf))
        if "purity" in want:
            diags.extend(purity.check_file(sf,
                                           jit_scope=_in_jit_scope(sf.path)))
    if "lockorder" in want:
        diags.extend(lockorder.check_files(
            [sf for sf in sources if sf.parse_error is None]))
    out: List[Diagnostic] = []
    for d in diags:
        sf = next((s for s in sources if s.path == d.path), None)
        if sf is None:
            out.append(d)
            continue
        sup = sf.suppression_at(d.line)
        if sup is not None and sup.code == d.code:
            if not sup.reason:
                out.append(Diagnostic(
                    d.path, sup.line, "LT00",
                    f"suppression of {d.code} without a reason "
                    f"(grammar: '# lint-ok: {d.code} <why>')"))
            continue
        out.append(d)
    out.sort(key=lambda d: (d.path, d.line, d.code))
    return out
