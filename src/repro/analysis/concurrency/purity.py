"""Hot-path purity lints (PU01/PU02/PU03).

* **PU01 — device sync under a lock.**  Inside a held-lock scope
  (``with self._lock:`` or a ``# holds:``-annotated method) a call that
  synchronises with the device or materialises an array on the host —
  ``block_until_ready()``, ``.item()``, ``np.asarray``/``np.array``,
  ``jax.device_get``, ``float()`` on a non-constant — stalls every thread
  queued on that lock for a device round-trip.  Snapshot under the lock,
  materialise outside.
* **PU02 — Python side effects in traced code.**  Functions traced by
  ``jax.jit`` or Pallas run their Python bodies once, at trace time: a
  lock acquisition, ``print``, ``time.*``, ``open`` or ``.item()`` there
  is at best dead code and at worst a deadlock baked into the trace.
  Scope: ``kernels/`` and ``core/distributed.py``.  Traced functions are
  found by decorator (``jax.jit``, ``functools.partial(jax.jit, ...)``),
  by ``jax.jit(fn)`` assignment, by being handed to ``pallas_call``, by
  naming convention (``*_kernel``, ``_local_*``), and transitively
  through same-module calls and nested defs.
* **PU03 — bare lock construction.**  ``threading.Lock/RLock/Condition``
  anywhere outside :mod:`.witness` bypasses the rank factories, making
  the lock invisible to both the static order analysis and the runtime
  witness.  Use ``make_lock(rank)`` / ``make_rlock`` / ``make_condition``.
"""

from __future__ import annotations

import ast
import os
from typing import List, Optional, Set

from repro.analysis.concurrency.diagnostics import Diagnostic, SourceFile
from repro.analysis.concurrency.guarded import (_self_attr,
                                                collect_class_locks)

_WITNESS_SUFFIX = os.path.join("analysis", "concurrency", "witness.py")

_SYNC_ATTR_CALLS = {"block_until_ready", "item"}
_SYNC_QUALIFIED = {("np", "asarray"), ("np", "array"),
                   ("numpy", "asarray"), ("numpy", "array"),
                   ("jax", "device_get")}
_EFFECT_NAME_CALLS = {"print", "open", "input"}
_EFFECT_MODULES = {"threading", "time"}
_LOCKISH_FRAGMENTS = ("lock", "cond", "_cv", "mutex")


def _qualified(call: ast.Call) -> Optional[tuple]:
    fn = call.func
    if isinstance(fn, ast.Attribute) and isinstance(fn.value, ast.Name):
        return (fn.value.id, fn.attr)
    return None


# ---------------------------------------------------------------------------
# PU01 — sync/materialisation under a held lock
# ---------------------------------------------------------------------------

class _SyncUnderLock(ast.NodeVisitor):
    def __init__(self, sf: SourceFile, lock_attrs: Set[str]):
        self.sf = sf
        self.lock_attrs = lock_attrs
        self.depth = 0          # held-lock nesting depth
        self.diags: List[Diagnostic] = []

    def visit_With(self, node: ast.With) -> None:
        got = sum(1 for item in node.items
                  if (_self_attr(item.context_expr) or "") in self.lock_attrs)
        self.depth += got
        self.generic_visit(node)
        self.depth -= got

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        saved = self.depth
        self.depth = 1 if any(a in self.lock_attrs
                              for a in self.sf.holds(node.lineno)) else 0
        for stmt in node.body:
            self.visit(stmt)
        self.depth = saved

    visit_AsyncFunctionDef = visit_FunctionDef

    def visit_Call(self, node: ast.Call) -> None:
        if self.depth > 0:
            what = self._sync_kind(node)
            if what is not None:
                self.diags.append(Diagnostic(
                    self.sf.path, node.lineno, "PU01",
                    f"{what} while holding a lock — every thread queued on "
                    f"it stalls for the device round-trip; snapshot under "
                    f"the lock and materialise outside"))
        self.generic_visit(node)

    def _sync_kind(self, node: ast.Call) -> Optional[str]:
        fn = node.func
        if isinstance(fn, ast.Attribute) and fn.attr in _SYNC_ATTR_CALLS:
            return f".{fn.attr}()"
        q = _qualified(node)
        if q in _SYNC_QUALIFIED:
            return f"{q[0]}.{q[1]}()"
        if isinstance(fn, ast.Name) and fn.id == "float" and node.args \
                and not isinstance(node.args[0], ast.Constant):
            return "float() on a non-constant"
        return None


# ---------------------------------------------------------------------------
# PU02 — side effects inside traced functions
# ---------------------------------------------------------------------------

def _is_jit_decorator(dec: ast.AST) -> bool:
    if isinstance(dec, ast.Attribute) and dec.attr == "jit":
        return True
    if isinstance(dec, ast.Name) and dec.id == "jit":
        return True
    if isinstance(dec, ast.Call):
        fn = dec.func
        is_partial = (isinstance(fn, ast.Attribute) and fn.attr == "partial")\
            or (isinstance(fn, ast.Name) and fn.id == "partial")
        if is_partial and dec.args:
            return _is_jit_decorator(dec.args[0])
        return _is_jit_decorator(fn)
    return False


def _traced_roots(tree: ast.Module) -> Set[str]:
    roots: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if any(_is_jit_decorator(d) for d in node.decorator_list):
                roots.add(node.name)
            if node.name.endswith("_kernel") or \
                    node.name.startswith("_local_"):
                roots.add(node.name)
        elif isinstance(node, ast.Call):
            fn = node.func
            name = fn.attr if isinstance(fn, ast.Attribute) else \
                fn.id if isinstance(fn, ast.Name) else None
            if name == "pallas_call" and node.args \
                    and isinstance(node.args[0], ast.Name):
                roots.add(node.args[0].id)
            if name == "jit":
                for arg in node.args:
                    if isinstance(arg, ast.Name):
                        roots.add(arg.id)
    return roots


def _callees(fn: ast.AST) -> Set[str]:
    out: Set[str] = set()
    for node in ast.walk(fn):
        if isinstance(node, ast.Call) and isinstance(node.func, ast.Name):
            out.add(node.func.id)
    return out


class _TracedEffects(ast.NodeVisitor):
    def __init__(self, sf: SourceFile, fname: str):
        self.sf = sf
        self.fname = fname
        self.diags: List[Diagnostic] = []

    def visit_Call(self, node: ast.Call) -> None:
        what: Optional[str] = None
        fn = node.func
        if isinstance(fn, ast.Name) and fn.id in _EFFECT_NAME_CALLS:
            what = f"{fn.id}()"
        q = _qualified(node)
        if q is not None and q[0] in _EFFECT_MODULES:
            what = f"{q[0]}.{q[1]}()"
        if q in _SYNC_QUALIFIED:
            what = f"{q[0]}.{q[1]}()"
        if isinstance(fn, ast.Attribute) and \
                fn.attr in ("item", "block_until_ready", "acquire"):
            what = f".{fn.attr}()"
        if what is not None:
            self.diags.append(Diagnostic(
                self.sf.path, node.lineno, "PU02",
                f"{what} inside jit/Pallas-traced {self.fname}() — traced "
                f"bodies run once at trace time; side effects and host "
                f"syncs don't belong in them"))
        self.generic_visit(node)

    def visit_With(self, node: ast.With) -> None:
        for item in node.items:
            expr = item.context_expr
            name = expr.attr if isinstance(expr, ast.Attribute) else \
                expr.id if isinstance(expr, ast.Name) else ""
            if any(f in name.lower() for f in _LOCKISH_FRAGMENTS):
                self.diags.append(Diagnostic(
                    self.sf.path, node.lineno, "PU02",
                    f"lock acquisition ('with {name}') inside jit/Pallas-"
                    f"traced {self.fname}()"))
        self.generic_visit(node)


def _check_traced(sf: SourceFile) -> List[Diagnostic]:
    assert sf.tree is not None
    funcs = {node.name: node for node in sf.tree.body
             if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef))}
    traced = {n for n in _traced_roots(sf.tree) if n in funcs}
    # transitive same-module callees join the traced set
    changed = True
    while changed:
        changed = False
        for name in list(traced):
            for callee in _callees(funcs[name]):
                if callee in funcs and callee not in traced:
                    traced.add(callee)
                    changed = True
    diags: List[Diagnostic] = []
    for name in sorted(traced):
        chk = _TracedEffects(sf, name)
        for stmt in funcs[name].body:    # nested defs visited implicitly
            chk.visit(stmt)
        diags.extend(chk.diags)
    return diags


# ---------------------------------------------------------------------------
# PU03 — bare threading lock constructors
# ---------------------------------------------------------------------------

def _check_bare_locks(sf: SourceFile) -> List[Diagnostic]:
    assert sf.tree is not None
    diags: List[Diagnostic] = []
    if sf.path.endswith(_WITNESS_SUFFIX):
        return diags
    from_imports: Set[str] = set()
    for node in ast.walk(sf.tree):
        if isinstance(node, ast.ImportFrom) and node.module == "threading":
            from_imports.update(a.asname or a.name for a in node.names)
    for node in ast.walk(sf.tree):
        if not isinstance(node, ast.Call):
            continue
        q = _qualified(node)
        name = None
        if q is not None and q[0] == "threading" and \
                q[1] in ("Lock", "RLock", "Condition"):
            name = f"threading.{q[1]}"
        elif isinstance(node.func, ast.Name) and \
                node.func.id in ("Lock", "RLock", "Condition") and \
                node.func.id in from_imports:
            name = node.func.id
        if name is not None:
            diags.append(Diagnostic(
                sf.path, node.lineno, "PU03",
                f"bare {name}() bypasses the lock-rank factories; use "
                f"make_lock/make_rlock/make_condition from "
                f"repro.analysis.concurrency.witness"))
    return diags


# ---------------------------------------------------------------------------

def check_file(sf: SourceFile, jit_scope: bool = False) -> List[Diagnostic]:
    if sf.tree is None:
        return []
    diags: List[Diagnostic] = []
    # PU01: per class, using its recognised lock attributes
    for cls in [n for n in ast.walk(sf.tree) if isinstance(n, ast.ClassDef)]:
        locks = collect_class_locks(cls)
        if not locks.locks:
            continue
        for meth in cls.body:
            if not isinstance(meth, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            chk = _SyncUnderLock(sf, locks.locks)
            chk.depth = 1 if any(a in locks.locks
                                 for a in sf.holds(meth.lineno)) else 0
            for stmt in meth.body:
                chk.visit(stmt)
            diags.extend(chk.diags)
    if jit_scope:
        diags.extend(_check_traced(sf))
    diags.extend(_check_bare_locks(sf))
    return diags
