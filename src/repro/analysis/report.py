"""Render EXPERIMENTS.md tables from dryrun_results.jsonl."""

from __future__ import annotations

import json
import sys
from typing import Dict, List


def load(path: str) -> List[Dict]:
    return [json.loads(l) for l in open(path) if l.strip()]


def fmt_bytes(b: float) -> str:
    return f"{b/2**30:.2f}"


def dryrun_table(recs: List[Dict]) -> str:
    rows = ["| arch | shape | step | mesh | chips | compile s | peak GiB/dev"
            " | dominant collective |",
            "|---|---|---|---|---|---|---|---|"]
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        if not r.get("ok"):
            rows.append(f"| {r['arch']} | {r['shape']} | - | {r['mesh']} |"
                        f" - | - | - | FAILED: {r.get('error','')[:40]} |")
            continue
        coll = r["roofline"]["coll_breakdown"]
        dom = max(coll, key=coll.get) if any(coll.values()) else "-"
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['step']} | {r['mesh']} | "
            f"{r['n_chips']} | {r.get('t_compile_s','-')} | "
            f"{fmt_bytes(r['memory']['peak_bytes_per_device'])} | "
            f"{dom} ({coll.get(dom,0)/2**30:.2f} GiB) |")
    return "\n".join(rows)


def roofline_table(recs: List[Dict], mesh: str = "single") -> str:
    rows = ["| arch | shape | t_compute s | t_memory s | t_collective s | "
            "bottleneck | MODEL_FLOPS | useful ratio | roofline frac | "
            "one-line fix |",
            "|---|---|---|---|---|---|---|---|---|---|"]
    fixes = {
        "compute": "increase arithmetic intensity / larger per-chip tiles",
        "memory": "fuse score/softmax chains into VMEM (Pallas), bf16 "
                  "activations, cut remat recompute",
        "collective": "reshard to smaller groups / reduce-scatter instead "
                      "of all-reduce / overlap with compute",
    }
    for r in sorted((x for x in recs if x.get("ok") and x["mesh"] == mesh),
                    key=lambda r: (r["arch"], r["shape"])):
        ro = r["roofline"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {ro['t_compute_s']:.3e} | "
            f"{ro['t_memory_s']:.3e} | {ro['t_collective_s']:.3e} | "
            f"**{ro['bottleneck']}** | {ro['model_flops']:.2e} | "
            f"{ro['useful_flops_ratio']:.2f} | "
            f"{ro['roofline_fraction']:.3f} | {fixes[ro['bottleneck']]} |")
    return "\n".join(rows)


def main() -> None:
    path = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun_results.jsonl"
    recs = load(path)
    print("## Dry-run\n")
    print(dryrun_table(recs))
    print("\n## Roofline (single pod, 256 chips)\n")
    print(roofline_table(recs, "single"))
    print("\n## Roofline (multi-pod, 512 chips)\n")
    print(roofline_table(recs, "multi"))


if __name__ == "__main__":
    main()
