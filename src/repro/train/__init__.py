from repro.train.loop import TrainConfig, init_state, make_train_step, run  # noqa: F401
from repro.train import checkpoint  # noqa: F401
from repro.train.fault import (  # noqa: F401
    FaultInjector,
    StepDeadline,
    StragglerTimeout,
    WorkerFailure,
    reshard_state,
    supervise,
)
