"""Training loop: jit'd train_step factory (grad-accum microbatching,
optional gradient compression), metrics, periodic checkpointing."""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.optim.adamw import OptimizerConfig, adamw_init, adamw_update
from repro.optim.compress import ef_compress_grads, ef_init
from repro.train import checkpoint as ckpt_lib


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    opt: OptimizerConfig = OptimizerConfig()
    microbatches: int = 1               # gradient accumulation steps
    grad_compress_bits: int = 0         # 0 = off; 8 = int8 EF compression
    ckpt_every: int = 0
    ckpt_dir: str = ""
    keep_ckpts: int = 3


def make_train_step(loss_fn: Callable, tcfg: TrainConfig):
    """loss_fn(params, batch) -> (loss, metrics).  Returns jit-able
    train_step(state, batch) -> (state, metrics); state = (params,
    opt_state[, ef_residuals])."""

    def compute_grads(params, batch):
        if tcfg.microbatches <= 1:
            (_, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
            return grads, metrics
        # grad accumulation over the leading batch dim via lax.scan
        def split(x):
            b = x.shape[0]
            mb = tcfg.microbatches
            return x.reshape(mb, b // mb, *x.shape[1:])
        micro = jax.tree_util.tree_map(split, batch)

        def body(acc, mb):
            (_, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, mb)
            acc = jax.tree_util.tree_map(jnp.add, acc, grads)
            return acc, metrics
        zeros = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        grads, metrics = jax.lax.scan(body, zeros, micro)
        grads = jax.tree_util.tree_map(
            lambda g: g / tcfg.microbatches, grads)
        metrics = jax.tree_util.tree_map(lambda m: m.mean(), metrics)
        return grads, metrics

    def train_step(state, batch):
        params, opt_state = state["params"], state["opt"]
        grads, metrics = compute_grads(params, batch)
        if tcfg.grad_compress_bits:
            grads, residuals = ef_compress_grads(
                grads, state["ef"], tcfg.grad_compress_bits)
        new_params, new_opt, opt_metrics = adamw_update(
            grads, opt_state, params, tcfg.opt)
        metrics = {**metrics, **opt_metrics}
        new_state = {"params": new_params, "opt": new_opt}
        if tcfg.grad_compress_bits:
            new_state["ef"] = residuals
        return new_state, metrics

    return train_step


def init_state(params, tcfg: TrainConfig):
    state = {"params": params, "opt": adamw_init(params)}
    if tcfg.grad_compress_bits:
        state["ef"] = ef_init(params)
    return state


def run(train_step, state, batches, tcfg: TrainConfig, *,
        start_step: int = 0, log_every: int = 10,
        on_step: Optional[Callable[[int], None]] = None):
    """Drive the loop over an iterable of batches.  ``on_step`` is the fault
    injection / monitoring hook used by the supervisor tests."""
    history = []
    step = start_step
    t0 = time.time()
    for batch in batches:
        if on_step is not None:
            on_step(step)
        state, metrics = train_step(state, batch)
        step += 1
        if step % log_every == 0 or step == start_step + 1:
            m = {k: float(v) for k, v in metrics.items()}
            m["step"] = step
            m["wall"] = time.time() - t0
            history.append(m)
        if tcfg.ckpt_every and step % tcfg.ckpt_every == 0:
            ckpt_lib.save(tcfg.ckpt_dir, step, state, keep=tcfg.keep_ckpts)
    return state, step, history
