"""Sharded, atomic, resharding-capable checkpointing (numpy-based).

Layout:  <dir>/step_<N>/proc_<i>.npz + manifest.json
Atomicity: written to ``step_<N>.tmp`` then os.rename (crash-safe).
Resharding: restore() takes target shardings — arrays are device_put with
the *new* sharding, so elastic shrink/grow of the data axis "just works"
(the full array is reconstructed host-side from all process files; on a real
multi-host cluster each process writes its addressable shards and restore
re-slices — the manifest records shard indices for that path).
"""

from __future__ import annotations

import json
import os
import shutil
import time
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten(tree) -> Dict[str, Any]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return {jax.tree_util.keystr(path): leaf for path, leaf in flat}


def save(ckpt_dir: str, step: int, tree, *, keep: int = 3) -> str:
    """Atomically persist ``tree`` (params/opt_state/whatever pytree)."""
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten(tree)
    arrays = {}
    manifest = {"step": step, "time": time.time(), "keys": [],
                "process_count": jax.process_count()}
    for i, (key, leaf) in enumerate(sorted(flat.items())):
        name = f"a{i}"
        arrays[name] = np.asarray(jax.device_get(leaf))
        manifest["keys"].append({"key": key, "name": name,
                                 "shape": list(arrays[name].shape),
                                 "dtype": str(arrays[name].dtype)})
    np.savez(os.path.join(tmp, f"proc_{jax.process_index()}.npz"), **arrays)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: str, keep: int) -> None:
    steps = sorted(d for d in os.listdir(ckpt_dir)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")
             and os.path.exists(os.path.join(ckpt_dir, d, "manifest.json"))]
    return max(steps) if steps else None


def restore(ckpt_dir: str, like, *, step: Optional[int] = None,
            shardings=None):
    """Restore into the structure of ``like``; device_put with ``shardings``
    (a matching pytree of NamedSharding / None) — this is where elastic
    resharding happens."""
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(d, f"proc_{jax.process_index()}.npz"))
    by_key = {e["key"]: data[e["name"]] for e in manifest["keys"]}

    flat_like, tdef = jax.tree_util.tree_flatten(like)
    paths = [jax.tree_util.keystr(p) for p, _ in
             jax.tree_util.tree_flatten_with_path(like)[0]]
    flat_sh = (tdef.flatten_up_to(shardings) if shardings is not None
               else [None] * len(flat_like))
    out = []
    for path, proto, sh in zip(paths, flat_like, flat_sh):
        arr = by_key[path]
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jax.numpy.asarray(arr))
    return tdef.unflatten(out), step
