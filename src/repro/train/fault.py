"""Fault tolerance: supervised training with checkpoint/restart, bounded
retries, straggler deadlines, and elastic data-axis resizing.

On a real cluster the failure signal is a missing heartbeat / XLA collective
timeout; here failures are injected by tests through ``FaultInjector`` and
the supervisor exercises exactly the recovery path production would take:
catch -> restore latest checkpoint -> (optionally shrink the mesh) -> resume.
"""

from __future__ import annotations

import dataclasses
import logging
import time
from typing import Any, Callable, Dict, Iterable, List, Optional

import jax
import numpy as np

from repro.train import checkpoint as ckpt_lib
from repro.train.loop import TrainConfig, run

log = logging.getLogger("repro.fault")


class WorkerFailure(RuntimeError):
    """Simulated node failure."""


class StragglerTimeout(RuntimeError):
    """Step exceeded its deadline (straggler mitigation trigger)."""


@dataclasses.dataclass
class FaultInjector:
    """Raises WorkerFailure at the given global steps (each fires once)."""

    fail_at_steps: List[int] = dataclasses.field(default_factory=list)
    fired: set = dataclasses.field(default_factory=set)

    def __call__(self, step: int) -> None:
        if step in self.fail_at_steps and step not in self.fired:
            self.fired.add(step)
            raise WorkerFailure(f"injected failure at step {step}")


@dataclasses.dataclass
class StepDeadline:
    """Straggler mitigation: track per-step wall time; steps beyond
    ``deadline_s`` raise so the supervisor can re-dispatch (in this
    single-host harness that means: record + continue)."""

    deadline_s: float = 60.0
    history: List[float] = dataclasses.field(default_factory=list)
    _t: float = 0.0

    def start(self) -> None:
        self._t = time.time()

    def finish(self) -> None:
        dt = time.time() - self._t
        self.history.append(dt)
        if dt > self.deadline_s:
            raise StragglerTimeout(f"step took {dt:.1f}s > {self.deadline_s}s")

    def p99(self) -> float:
        return float(np.percentile(self.history, 99)) if self.history else 0.0


def supervise(make_train_step: Callable[[], Callable],
              init_state_fn: Callable[[], Any],
              batch_iter_fn: Callable[[int], Iterable],
              tcfg: TrainConfig,
              *,
              total_steps: int,
              max_restarts: int = 5,
              on_step: Optional[Callable[[int], None]] = None,
              shardings_fn: Optional[Callable[[], Any]] = None):
    """Run to ``total_steps`` surviving worker failures.

    Returns (state, restarts, history)."""
    restarts = 0
    history: List[Dict] = []
    train_step = make_train_step()
    last = ckpt_lib.latest_step(tcfg.ckpt_dir) if tcfg.ckpt_dir else None
    if last is not None:
        proto = jax.eval_shape(init_state_fn)
        state, step = ckpt_lib.restore(
            tcfg.ckpt_dir, proto,
            shardings=shardings_fn() if shardings_fn else None)
    else:
        state, step = init_state_fn(), 0

    while step < total_steps:
        try:
            state, step, h = run(
                train_step, state,
                batch_iter_fn(total_steps - step), tcfg,
                start_step=step, on_step=on_step)
            history.extend(h)
        except WorkerFailure as e:
            restarts += 1
            log.warning("worker failure (%s); restart %d/%d",
                        e, restarts, max_restarts)
            if restarts > max_restarts:
                raise
            last = ckpt_lib.latest_step(tcfg.ckpt_dir)
            if last is None:
                state, step = init_state_fn(), 0
            else:
                proto = jax.eval_shape(init_state_fn)
                state, step = ckpt_lib.restore(
                    tcfg.ckpt_dir, proto,
                    shardings=shardings_fn() if shardings_fn else None)
    return state, restarts, history


def reshard_state(state, new_shardings):
    """Elastic resize: re-place every array under the new mesh/shardings.
    (Grow/shrink of the data axis; array *values* are unchanged.)"""
    return jax.tree_util.tree_map(
        lambda x, s: jax.device_put(jax.device_get(x), s) if s is not None
        else x, state, new_shardings)
