"""Host-side edge partitioning for the dst-partitioned GNN path
(§Perf hillclimb B): range-partition edges by destination node, pad every
shard to equal length with zero-weight edges."""

from __future__ import annotations

from typing import Tuple

import numpy as np


def pad_nodes(features: np.ndarray, labels: np.ndarray, mask: np.ndarray,
              n_shards: int):
    """Pad node arrays so n_nodes % n_shards == 0 (pad rows masked out)."""
    n = len(features)
    pad = (-n) % n_shards
    if pad:
        features = np.pad(features, ((0, pad), (0, 0)))
        labels = np.pad(labels, (0, pad))
        mask = np.pad(mask, (0, pad))
    return features, labels, mask


def partition_edges_by_dst(edges: np.ndarray, n_nodes: int, n_shards: int
                           ) -> Tuple[np.ndarray, np.ndarray]:
    """-> (edges (n_shards*E_max, 2) grouped by owning shard, weights).

    Every shard gets the same edge count (padded with w=0 self-edges on the
    shard's first node, which contribute nothing to the weighted mean)."""
    assert n_nodes % n_shards == 0
    n_loc = n_nodes // n_shards
    dst = edges[:, 1]
    shard = dst // n_loc
    groups = [edges[shard == i] for i in range(n_shards)]
    e_max = max((len(g) for g in groups), default=1) or 1
    out_e = np.zeros((n_shards * e_max, 2), edges.dtype)
    out_w = np.zeros((n_shards * e_max,), np.float32)
    for i, g in enumerate(groups):
        s = i * e_max
        out_e[s:s + len(g)] = g
        out_w[s:s + len(g)] = 1.0
        # pads: dst inside shard i's range (node i*n_loc), weight 0
        out_e[s + len(g):s + e_max] = [0, i * n_loc]
    return out_e, out_w
