"""Graph utilities: synthetic graphs, CSR, and the uniform neighbor sampler
required by the ``minibatch_lg`` cell (GraphSAGE fanout sampling)."""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import numpy as np


def random_graph(rng: np.random.Generator, n_nodes: int, n_edges: int,
                 d_feat: int, n_classes: int) -> Dict[str, np.ndarray]:
    """Preferential-attachment-flavoured random graph (power-law-ish degree)."""
    # Bias destinations toward low ids -> heavy-tailed in-degree.
    src = rng.integers(0, n_nodes, n_edges)
    dst = (n_nodes * rng.power(3.0, n_edges)).astype(np.int64) % n_nodes
    edges = np.stack([src, dst], axis=1).astype(np.int32)
    return {
        "edges": edges,
        "features": rng.standard_normal((n_nodes, d_feat)).astype(np.float32),
        "labels": rng.integers(0, n_classes, n_nodes).astype(np.int32),
    }


def build_csr(edges: np.ndarray, n_nodes: int) -> Tuple[np.ndarray, np.ndarray]:
    """edge list (E,2) src->dst  =>  CSR over *incoming* edges per dst."""
    dst = edges[:, 1]
    order = np.argsort(dst, kind="stable")
    sorted_src = edges[order, 0].astype(np.int32)
    counts = np.bincount(dst, minlength=n_nodes)
    indptr = np.zeros(n_nodes + 1, np.int64)
    np.cumsum(counts, out=indptr[1:])
    return indptr, sorted_src


def neighbor_sample(rng: np.random.Generator, indptr: np.ndarray,
                    indices: np.ndarray, nodes: np.ndarray,
                    fanout: int) -> np.ndarray:
    """Uniform with-replacement fanout sampling: (B,) -> (B, fanout).

    Isolated nodes sample themselves (self-loop fallback)."""
    starts = indptr[nodes]
    degs = indptr[nodes + 1] - starts
    r = rng.integers(0, np.maximum(degs, 1)[:, None],
                     (len(nodes), fanout))
    picked = indices[np.minimum(starts[:, None] + r,
                                len(indices) - 1 if len(indices) else 0)] \
        if len(indices) else np.zeros((len(nodes), fanout), np.int32)
    picked = np.where(degs[:, None] > 0, picked, nodes[:, None])
    return picked.astype(np.int32)


def sample_two_hop(rng: np.random.Generator, indptr, indices, batch_nodes,
                   fanouts: Tuple[int, int], features: np.ndarray):
    """Returns the dense minibatch tensors for sage_forward_minibatch."""
    f0, f1 = fanouts
    hop1 = neighbor_sample(rng, indptr, indices, batch_nodes, f0)   # (B,f0)
    hop2 = neighbor_sample(rng, indptr, indices, hop1.reshape(-1), f1)
    hop2 = hop2.reshape(len(batch_nodes), f0, f1)
    return (features[batch_nodes],
            features[hop1],
            features[hop2])


def block_diagonal_batch(rng: np.random.Generator, n_graphs: int,
                         nodes_per: int, edges_per: int, d_feat: int,
                         n_classes: int) -> Dict[str, np.ndarray]:
    """Batched small molecules flattened into one block-diagonal graph."""
    offs = np.arange(n_graphs)[:, None] * nodes_per
    src = rng.integers(0, nodes_per, (n_graphs, edges_per)) + offs
    dst = rng.integers(0, nodes_per, (n_graphs, edges_per)) + offs
    edges = np.stack([src.reshape(-1), dst.reshape(-1)], 1).astype(np.int32)
    n_nodes = n_graphs * nodes_per
    return {
        "edges": edges,
        "features": rng.standard_normal((n_nodes, d_feat)).astype(np.float32),
        "graph_ids": np.repeat(np.arange(n_graphs), nodes_per).astype(
            np.int32),
        "labels": rng.integers(0, n_classes, n_graphs).astype(np.int32),
    }
