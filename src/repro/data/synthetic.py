"""Synthetic data generators (host-side numpy; deterministic by seed).

``clustered_vectors`` draws from a Gaussian-mixture so IVF clustering and the
paper's "re-rank candidates are spatially close" locality claim (§4.3) are
actually exercised rather than vacuous, as they would be on iid uniform data.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import numpy as np


def lm_batch(rng: np.random.Generator, batch: int, seq: int,
             vocab: int) -> Dict[str, np.ndarray]:
    tokens = rng.integers(0, vocab, (batch, seq + 1), dtype=np.int32)
    return {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}


def clustered_vectors(rng: np.random.Generator, n: int, dim: int,
                      n_clusters: Optional[int] = None,
                      spread: float = 0.15,
                      dtype=np.float32) -> np.ndarray:
    n_clusters = n_clusters or max(8, n // 500)
    centers = rng.standard_normal((n_clusters, dim)).astype(np.float32)
    assign = rng.integers(0, n_clusters, n)
    x = centers[assign] + spread * rng.standard_normal((n, dim)).astype(
        np.float32)
    if np.issubdtype(dtype, np.integer):
        lo = np.iinfo(dtype).min
        hi = np.iinfo(dtype).max
        x = np.clip(np.round(128 * x), lo, hi)
    return x.astype(dtype)


def recsys_dlrm_batch(rng: np.random.Generator, batch: int, n_dense: int,
                      n_sparse: int, vocab: int,
                      multi_hot: int = 1) -> Dict[str, np.ndarray]:
    return {
        "dense": rng.standard_normal((batch, n_dense)).astype(np.float32),
        "sparse_ids": rng.integers(0, vocab, (batch, n_sparse, multi_hot),
                                   dtype=np.int32),
        "labels": rng.integers(0, 2, (batch,)).astype(np.float32),
    }


def recsys_sparse_batch(rng: np.random.Generator, batch: int, n_sparse: int,
                        vocab: int, multi_hot: int = 1):
    return {
        "sparse_ids": rng.integers(0, vocab, (batch, n_sparse, multi_hot),
                                   dtype=np.int32),
        "labels": rng.integers(0, 2, (batch,)).astype(np.float32),
    }


def recsys_seq_batch(rng: np.random.Generator, batch: int, seq: int,
                     vocab: int, n_neg: int = 127) -> Dict[str, np.ndarray]:
    return {
        "item_ids": rng.integers(0, vocab, (batch, seq), dtype=np.int32),
        "mask_pos": rng.integers(0, seq, (batch,), dtype=np.int32),
        "pos_items": rng.integers(0, vocab, (batch,), dtype=np.int32),
        "neg_items": rng.integers(0, vocab, (batch, n_neg), dtype=np.int32),
    }
