from repro.data.synthetic import (  # noqa: F401
    clustered_vectors,
    lm_batch,
    recsys_dlrm_batch,
    recsys_seq_batch,
    recsys_sparse_batch,
)
from repro.data.graphs import (  # noqa: F401
    block_diagonal_batch,
    build_csr,
    neighbor_sample,
    random_graph,
)
