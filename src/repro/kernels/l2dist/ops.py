"""jit'd wrapper for the exact-L2 kernel (padding glue)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.l2dist.l2dist import l2dist
from repro.kernels.l2dist.ref import l2dist_ref


@functools.partial(jax.jit, static_argnames=("use_kernel", "interpret",
                                             "block_q", "block_n"))
def l2_distances(queries: jax.Array, vectors: jax.Array, *,
                 use_kernel: bool = True, interpret: bool = True,
                 block_q: int = 128, block_n: int = 512) -> jax.Array:
    if not use_kernel:
        return l2dist_ref(queries, vectors)
    b, d = queries.shape
    n, _ = vectors.shape
    bq = min(block_q, b)
    bn = min(block_n, n)
    pb, pn = (-b) % bq, (-n) % bn
    if pb:
        queries = jnp.concatenate(
            [queries, jnp.zeros((pb, d), queries.dtype)], 0)
    if pn:
        vectors = jnp.concatenate(
            [vectors, jnp.zeros((pn, d), vectors.dtype)], 0)
    out = l2dist(queries, vectors, block_q=bq, block_n=bn,
                 interpret=interpret)
    return out[:b, :n]
