"""Pure-jnp oracle for the exact-L2 re-rank kernel."""

from __future__ import annotations

import jax.numpy as jnp


def l2dist_ref(queries: jnp.ndarray, vectors: jnp.ndarray) -> jnp.ndarray:
    """queries (B, D), vectors (N, D) -> squared L2 (B, N) f32."""
    q = queries.astype(jnp.float32)
    v = vectors.astype(jnp.float32)
    return (jnp.sum(q * q, -1)[:, None]
            - 2.0 * q @ v.T
            + jnp.sum(v * v, -1)[None, :])
