"""Pallas TPU kernel: exact squared-L2 distances (re-rank step ⑧).

‖q−v‖² = ‖q‖² − 2·q·vᵀ + ‖v‖²: a (bq, D)x(D, bn) MXU matmul with a fused
row/col-norm epilogue.  Tiles are MXU-aligned (bq, bn multiples of 8/128
when shapes allow); D is kept whole per tile (ANNS dims are 96–384, well
under VMEM budget: bq*D + bn*D + bq*bn floats)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _l2_kernel(q_ref, v_ref, out_ref):
    q = q_ref[...].astype(jnp.float32)           # (bq, D)
    v = v_ref[...].astype(jnp.float32)           # (bn, D)
    dots = jax.lax.dot_general(q, v, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32)
    qn = jnp.sum(q * q, axis=-1)[:, None]
    vn = jnp.sum(v * v, axis=-1)[None, :]
    out_ref[...] = qn - 2.0 * dots + vn


def l2dist(queries: jax.Array, vectors: jax.Array, *, block_q: int = 128,
           block_n: int = 512, interpret: bool = True) -> jax.Array:
    """(B, D) x (N, D) -> (B, N) f32.  B % block_q == 0, N % block_n == 0
    (ops.py pads)."""
    b, d = queries.shape
    n, dv = vectors.shape
    assert d == dv
    bq = min(block_q, b)
    bn = min(block_n, n)
    assert b % bq == 0 and n % bn == 0
    grid = (b // bq, n // bn)
    return pl.pallas_call(
        _l2_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bq, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, n), jnp.float32),
        interpret=interpret,
    )(queries, vectors)
