from repro.kernels.l2dist.ops import l2_distances  # noqa: F401
from repro.kernels.l2dist.ref import l2dist_ref  # noqa: F401
