"""jit'd wrappers for the ADC kernels (padding + merge glue)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.pq_adc.pq_adc import (pq_adc_scan, pq_adc_scan_batch,
                                         pq_adc_scan_fused, pq_adc_scan_topk)
from repro.kernels.pq_adc.ref import (build_luts_ref, pq_adc_batch_ref,
                                      pq_adc_ref)


def _pad_codes(codes: jax.Array, block_n: int):
    n = codes.shape[0]
    pad = (-n) % block_n
    if pad:
        codes = jnp.concatenate(
            [codes, jnp.zeros((pad, codes.shape[1]), codes.dtype)], axis=0)
    return codes, n, pad


@functools.partial(jax.jit, static_argnames=("block_n", "use_kernel",
                                             "interpret"))
def pq_adc(codes: jax.Array, lut: jax.Array, *, block_n: int = 2048,
           use_kernel: bool = True, interpret: bool = True) -> jax.Array:
    """distances (N,) f32.  use_kernel=False falls back to the jnp oracle
    (identical results; used on CPU hot paths where interpret-mode Pallas
    is slow)."""
    if not use_kernel:
        return pq_adc_ref(codes, lut)
    padded, n, pad = _pad_codes(codes, min(block_n, max(codes.shape[0], 8)))
    bn = min(block_n, padded.shape[0])
    out = pq_adc_scan(padded, lut, block_n=bn, interpret=interpret)
    return out[:n]


@functools.partial(jax.jit, static_argnames=("block_n", "use_kernel",
                                             "interpret"))
def pq_adc_batch(codes: jax.Array, luts: jax.Array, *, block_n: int = 2048,
                 use_kernel: bool = True, interpret: bool = True):
    """Batched queries: (N, M) x (B, M, K) -> (B, N) distances."""
    if not use_kernel:
        return pq_adc_batch_ref(codes, luts)
    padded, n, pad = _pad_codes(codes, min(block_n, max(codes.shape[0], 8)))
    bn = min(block_n, padded.shape[0])
    out = pq_adc_scan_batch(padded, luts, block_n=bn, interpret=interpret)
    return out[:, :n]


@functools.partial(jax.jit, static_argnames=("topk", "block_n", "use_kernel",
                                             "interpret"))
def pq_adc_topk_batch(codes: jax.Array, luts: jax.Array, topk: int, *,
                      mask: jax.Array = None, block_n: int = 2048,
                      use_kernel: bool = True, interpret: bool = True):
    """Batched fused scan + per-query (optionally masked) top-k.

    codes (N, M) x luts (B, M, K) [x mask (B, N) bool] ->
    (dists (B, tk), row indices (B, tk)) ascending, tk = min(topk, N).
    ``mask`` is the executor's per-query candidate membership: False rows
    (other queries' candidates, padding) score +inf and sort last — this is
    the single-device form of the per-shard scan in core.distributed."""
    d = pq_adc_batch(codes, luts, block_n=block_n, use_kernel=use_kernel,
                     interpret=interpret)
    if mask is not None:
        d = jnp.where(mask, d, jnp.inf)
    neg, ids = jax.lax.top_k(-d, min(topk, d.shape[1]))
    return -neg, ids


@functools.partial(jax.jit, static_argnames=("topk", "block_n", "use_kernel",
                                             "interpret"))
def pq_adc_topk(codes: jax.Array, lut: jax.Array, topk: int, *,
                block_n: int = 2048, use_kernel: bool = True,
                interpret: bool = True):
    """Fused scan + top-k: returns (dists (tk,), ids (tk,)) ascending with
    tk = min(topk, N) — only REAL rows, never padding.

    Two ISSUE-6 fixes live here and in the kernel:
    * padding rows are masked to +inf INSIDE each block before its partial
      top-k (``n`` rides into ``pq_adc_scan_topk``), so a mostly-padding
      final block can't evict genuine candidates before the merge;
    * the output is truncated to min(topk, N): with the per-block mask in
      place every block keeps its real rows preferentially, so the first
      min(topk, N) merged entries are guaranteed finite — +inf padding
      ids can no longer leak into rerank candidate lists when N < topk.
    """
    n = codes.shape[0]
    tk_out = min(topk, n)
    if not use_kernel:
        d = pq_adc_ref(codes, lut)
        neg, ids = jax.lax.top_k(-d, tk_out)
        return -neg, ids
    padded, n, pad = _pad_codes(codes, min(block_n, max(n, 8)))
    bn = min(block_n, padded.shape[0])
    tk = min(topk, bn)
    vals, ids = pq_adc_scan_topk(padded, lut, tk, n=n, block_n=bn,
                                 interpret=interpret)
    neg, pos = jax.lax.top_k(-vals, tk_out)
    return -neg, ids[pos]


@jax.jit
def quantize_luts(luts: jax.Array):
    """fig10 accuracy levels: asymmetric int8 quantisation of the ADC
    tables, per (query, subquantizer).  (B, M, K) f32 ->
    (q8 (B, M, K) int8, scale (B, M) f32, zp (B, M) f32);
    dequant is (q8 + 128) * scale + zp, accumulated in fp32."""
    lo = jnp.min(luts, axis=-1, keepdims=True)
    hi = jnp.max(luts, axis=-1, keepdims=True)
    scale = jnp.maximum(hi - lo, 1e-12) / 255.0
    q8 = (jnp.round((luts - lo) / scale) - 128.0).astype(jnp.int8)
    return q8, scale[..., 0], lo[..., 0]


# the LUT build is its OWN dispatch on purpose: when the (B, M, K) table
# expression is traced into the same jit as the gather below, XLA:CPU fuses
# it INTO the gather's loop fusion and recomputes sum((cb - q)^2) per
# lookup (~3x slower; optimization_barrier doesn't help — it materialises
# the 67 MB gather instead).  Built separately, the table lands as a jit
# PARAMETER and the scan compiles to one gather+reduce loop fusion.
_build_luts = jax.jit(build_luts_ref)


@functools.partial(jax.jit, static_argnames=("topk",))
def _fused_rows_scan(codes, luts, rows, topk: int):
    """One dispatch: u8 row gather + LUT gather + sum over M + pad mask +
    per-query top-k over the candidate segment.  ``luts`` MUST be a traced
    parameter (see _build_luts)."""
    b, s = rows.shape
    m = codes.shape[1]
    k = luts.shape[-1]
    rsafe = jnp.maximum(rows, 0)
    crow = codes.at[rsafe].get(mode="promise_in_bounds")      # (B, S, M)
    idx = (crow.astype(jnp.int32)
           + (jnp.arange(m, dtype=jnp.int32) * k)[None, None, :]
           + (jnp.arange(b, dtype=jnp.int32) * (m * k))[:, None, None])
    flat = luts.reshape(-1)
    d = jnp.sum(flat.at[idx].get(mode="promise_in_bounds"), axis=-1)
    d = jnp.where(rows >= 0, d, jnp.inf)
    neg, pos = jax.lax.top_k(-d, min(topk, s))
    # rows carries -1 at pad slots, so ids inherit the "no candidate"
    # marker for free (+inf distance rides along)
    return -neg, jnp.take_along_axis(rows, pos, axis=1)


@functools.partial(jax.jit, static_argnames=("topk",))
def _fused_rows_scan_int8(codes, q8, scale, zp, rows, topk: int):
    """int8-LUT variant of _fused_rows_scan: gather int8 table entries,
    dequantise per element, accumulate in fp32 (the "fp32 merge")."""
    b, s = rows.shape
    m = codes.shape[1]
    k = q8.shape[-1]
    rsafe = jnp.maximum(rows, 0)
    crow = codes.at[rsafe].get(mode="promise_in_bounds")
    idx = (crow.astype(jnp.int32)
           + (jnp.arange(m, dtype=jnp.int32) * k)[None, None, :]
           + (jnp.arange(b, dtype=jnp.int32) * (m * k))[:, None, None])
    g = q8.reshape(-1).at[idx].get(
        mode="promise_in_bounds").astype(jnp.float32)         # (B, S, M)
    d = jnp.sum((g + 128.0) * scale[:, None, :] + zp[:, None, :], axis=-1)
    d = jnp.where(rows >= 0, d, jnp.inf)
    neg, pos = jax.lax.top_k(-d, min(topk, s))
    return -neg, jnp.take_along_axis(rows, pos, axis=1)


def pq_adc_fused_topk(codes: jax.Array, queries: jax.Array,
                      codebooks: jax.Array, rows: jax.Array, topk: int, *,
                      lut_int8: bool = False, use_kernel: bool = True,
                      block_s: int = 2048, interpret: bool = True):
    """The fused query pipeline (ISSUE-6 tentpole): LUT build -> ADC scan
    -> partial top-k over each query's OWN candidate rows, one device
    round-trip per scan window.

    codes (N, M) uint8 (the whole HBM tier — no per-window candidate
    gather); queries (B, M*dsub) f32 with any OPQ rotation already
    applied; codebooks (M, K, dsub) f32; rows (B, S) int32 global row ids,
    -1 = pad, each query's ids sorted ascending (makes top-k tie-breaks
    match the dense masked scan bit-exactly).  Returns
    (dists (B, tk), row ids (B, tk)) ascending, tk = min(topk, S); slots
    past a query's candidate count come back as (+inf, -1), never as a
    padding row id.

    ``use_kernel=True`` runs the single Pallas kernel (LUT resident in
    VMEM across the grid, int8 scratch under ``lut_int8``);
    ``use_kernel=False`` is the CPU hot path: a tiny LUT-build dispatch
    plus ONE fused gather/scan/top-k jit (2.2-3.4x the unfused dense
    masked scan at fig9 shapes — see benchmarks/kernels_bench.py)."""
    b, s = rows.shape
    tk_out = min(topk, s)
    if not use_kernel:
        luts = _build_luts(codebooks, queries)
        if lut_int8:
            q8, scale, zp = quantize_luts(luts)
            return _fused_rows_scan_int8(codes, q8, scale, zp, rows, tk_out)
        return _fused_rows_scan(codes, luts, rows, tk_out)
    bs = min(block_s, max(s, 8))
    pad = (-s) % bs
    if pad:
        rows = jnp.concatenate(
            [rows, jnp.full((b, pad), -1, rows.dtype)], axis=1)
    vals, ids = pq_adc_scan_fused(codes, queries, codebooks, rows, tk_out,
                                  block_s=bs, lut_int8=lut_int8,
                                  interpret=interpret)
    neg, pos = jax.lax.top_k(-vals, min(tk_out, vals.shape[1]))
    return -neg, jnp.take_along_axis(ids, pos, axis=1)
