"""jit'd wrappers for the ADC kernels (padding + merge glue)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.pq_adc.pq_adc import (pq_adc_scan, pq_adc_scan_batch,
                                         pq_adc_scan_topk)
from repro.kernels.pq_adc.ref import pq_adc_batch_ref, pq_adc_ref


def _pad_codes(codes: jax.Array, block_n: int):
    n = codes.shape[0]
    pad = (-n) % block_n
    if pad:
        codes = jnp.concatenate(
            [codes, jnp.zeros((pad, codes.shape[1]), codes.dtype)], axis=0)
    return codes, n, pad


@functools.partial(jax.jit, static_argnames=("block_n", "use_kernel",
                                             "interpret"))
def pq_adc(codes: jax.Array, lut: jax.Array, *, block_n: int = 2048,
           use_kernel: bool = True, interpret: bool = True) -> jax.Array:
    """distances (N,) f32.  use_kernel=False falls back to the jnp oracle
    (identical results; used on CPU hot paths where interpret-mode Pallas
    is slow)."""
    if not use_kernel:
        return pq_adc_ref(codes, lut)
    padded, n, pad = _pad_codes(codes, min(block_n, max(codes.shape[0], 8)))
    bn = min(block_n, padded.shape[0])
    out = pq_adc_scan(padded, lut, block_n=bn, interpret=interpret)
    return out[:n]


@functools.partial(jax.jit, static_argnames=("block_n", "use_kernel",
                                             "interpret"))
def pq_adc_batch(codes: jax.Array, luts: jax.Array, *, block_n: int = 2048,
                 use_kernel: bool = True, interpret: bool = True):
    """Batched queries: (N, M) x (B, M, K) -> (B, N) distances."""
    if not use_kernel:
        return pq_adc_batch_ref(codes, luts)
    padded, n, pad = _pad_codes(codes, min(block_n, max(codes.shape[0], 8)))
    bn = min(block_n, padded.shape[0])
    out = pq_adc_scan_batch(padded, luts, block_n=bn, interpret=interpret)
    return out[:, :n]


@functools.partial(jax.jit, static_argnames=("topk", "block_n", "use_kernel",
                                             "interpret"))
def pq_adc_topk_batch(codes: jax.Array, luts: jax.Array, topk: int, *,
                      mask: jax.Array = None, block_n: int = 2048,
                      use_kernel: bool = True, interpret: bool = True):
    """Batched fused scan + per-query (optionally masked) top-k.

    codes (N, M) x luts (B, M, K) [x mask (B, N) bool] ->
    (dists (B, tk), row indices (B, tk)) ascending, tk = min(topk, N).
    ``mask`` is the executor's per-query candidate membership: False rows
    (other queries' candidates, padding) score +inf and sort last — this is
    the single-device form of the per-shard scan in core.distributed."""
    d = pq_adc_batch(codes, luts, block_n=block_n, use_kernel=use_kernel,
                     interpret=interpret)
    if mask is not None:
        d = jnp.where(mask, d, jnp.inf)
    neg, ids = jax.lax.top_k(-d, min(topk, d.shape[1]))
    return -neg, ids


@functools.partial(jax.jit, static_argnames=("topk", "block_n", "use_kernel",
                                             "interpret"))
def pq_adc_topk(codes: jax.Array, lut: jax.Array, topk: int, *,
                block_n: int = 2048, use_kernel: bool = True,
                interpret: bool = True):
    """Fused scan + top-k: returns (dists (topk,), ids (topk,)) ascending."""
    n = codes.shape[0]
    if not use_kernel:
        d = pq_adc_ref(codes, lut)
        neg, ids = jax.lax.top_k(-d, min(topk, n))
        return -neg, ids
    padded, n, pad = _pad_codes(codes, min(block_n, max(n, 8)))
    bn = min(block_n, padded.shape[0])
    tk = min(topk, bn)
    vals, ids = pq_adc_scan_topk(padded, lut, tk, block_n=bn,
                                 interpret=interpret)
    # mask padding ids, then global merge
    vals = jnp.where(ids < n, vals, jnp.inf)
    neg, pos = jax.lax.top_k(-vals, min(topk, vals.shape[0]))
    return -neg, ids[pos]
