"""Pure-jnp oracle for the PQ ADC scan kernel (Eq. 1)."""

from __future__ import annotations

import jax.numpy as jnp


def pq_adc_ref(codes: jnp.ndarray, lut: jnp.ndarray) -> jnp.ndarray:
    """codes (N, M) uint8, lut (M, K) f32 -> distances (N,) f32."""
    m, k = lut.shape
    flat = lut.reshape(-1)
    idx = codes.astype(jnp.int32) + (jnp.arange(m, dtype=jnp.int32)
                                     * k)[None, :]
    return jnp.sum(jnp.take(flat, idx), axis=-1)


def pq_adc_batch_ref(codes: jnp.ndarray, luts: jnp.ndarray) -> jnp.ndarray:
    """codes (N, M), luts (B, M, K) -> (B, N)."""
    b, m, k = luts.shape
    flat = luts.reshape(b, m * k)
    idx = codes.astype(jnp.int32) + (jnp.arange(m, dtype=jnp.int32)
                                     * k)[None, :]
    return jnp.sum(flat[:, idx], axis=-1)
