"""Pure-jnp oracle for the PQ ADC scan kernel (Eq. 1)."""

from __future__ import annotations

import jax.numpy as jnp


def pq_adc_ref(codes: jnp.ndarray, lut: jnp.ndarray) -> jnp.ndarray:
    """codes (N, M) uint8, lut (M, K) f32 -> distances (N,) f32."""
    m, k = lut.shape
    flat = lut.reshape(-1)
    idx = codes.astype(jnp.int32) + (jnp.arange(m, dtype=jnp.int32)
                                     * k)[None, :]
    return jnp.sum(jnp.take(flat, idx), axis=-1)


def pq_adc_batch_ref(codes: jnp.ndarray, luts: jnp.ndarray) -> jnp.ndarray:
    """codes (N, M), luts (B, M, K) -> (B, N)."""
    b, m, k = luts.shape
    flat = luts.reshape(b, m * k)
    idx = codes.astype(jnp.int32) + (jnp.arange(m, dtype=jnp.int32)
                                     * k)[None, :]
    return jnp.sum(flat[:, idx], axis=-1)


def build_luts_ref(codebooks: jnp.ndarray, queries: jnp.ndarray
                   ) -> jnp.ndarray:
    """ADC distance tables, batched: codebooks (M, K, dsub), queries
    (B, M*dsub) -> (B, M, K) squared-L2 per sub-space (Eq. 1's table).
    Oracle for the fused kernel's in-VMEM LUT build stage."""
    b = queries.shape[0]
    m, k, dsub = codebooks.shape
    qs = queries.astype(jnp.float32).reshape(b, m, 1, dsub)
    return jnp.sum((codebooks[None] - qs) ** 2, axis=-1)


def pq_adc_rows_ref(codes: jnp.ndarray, luts: jnp.ndarray,
                    rows: jnp.ndarray) -> jnp.ndarray:
    """Segmented per-query scan oracle: codes (N, M) uint8, luts
    (B, M, K) f32, rows (B, S) int32 row ids into ``codes`` (-1 = pad)
    -> distances (B, S) f32 with +inf at pad slots.

    This is the parity anchor for the fused query kernel: each query
    scans only ITS candidate rows (the paper's per-query candidate-list
    formulation), instead of a dense (B, N) scan masked afterwards."""
    b, m, k = luts.shape
    rsafe = jnp.maximum(rows, 0)
    crow = jnp.take(codes, rsafe, axis=0)                     # (B, S, M)
    idx = (crow.astype(jnp.int32)
           + (jnp.arange(m, dtype=jnp.int32) * k)[None, None, :]
           + (jnp.arange(b, dtype=jnp.int32) * (m * k))[:, None, None])
    d = jnp.sum(jnp.take(luts.reshape(-1), idx), axis=-1)     # (B, S)
    return jnp.where(rows >= 0, d, jnp.inf)
