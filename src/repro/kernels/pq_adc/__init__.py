from repro.kernels.pq_adc.ops import pq_adc, pq_adc_batch, pq_adc_topk, pq_adc_topk_batch  # noqa: F401
from repro.kernels.pq_adc.ref import pq_adc_ref, pq_adc_batch_ref  # noqa: F401
