from repro.kernels.pq_adc.ops import (pq_adc, pq_adc_batch,  # noqa: F401
                                      pq_adc_fused_topk, pq_adc_topk,
                                      pq_adc_topk_batch, quantize_luts)
from repro.kernels.pq_adc.ref import (build_luts_ref,  # noqa: F401
                                      pq_adc_batch_ref, pq_adc_ref,
                                      pq_adc_rows_ref)
