"""Pallas TPU kernel: PQ ADC scan (paper step ⑥, TPU-native — DESIGN.md §2).

The (M, K) distance LUT (≤ 128 KB for M ≤ 128, K = 256, f32) is pinned in
VMEM for the whole grid; PQ codes stream HBM→VMEM in (block_n, M) uint8
tiles.  Arithmetic intensity is ~2 FLOP/byte → the kernel is sized for
bandwidth: block_n * M bytes per grid step, one f32 row out.

Unlike the paper's CUDA kernel (one thread per dimension + coordinator
accumulation + spinlock hash dedup), the TPU formulation is a vectorised
flat-index gather over the VMEM-resident LUT with a sum over M — no atomics
exist in Pallas and none are needed (dedup is a separate sort-based pass).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _adc_kernel(codes_ref, lut_ref, out_ref, *, m: int, k: int):
    codes = codes_ref[...]                       # (block_n, M) uint8
    lut_flat = lut_ref[...].reshape(m * k)       # (M*K,) f32 in VMEM
    idx = codes.astype(jnp.int32) + (jnp.arange(m, dtype=jnp.int32)
                                     * k)[None, :]
    vals = jnp.take(lut_flat, idx.reshape(-1), axis=0)
    out_ref[...] = jnp.sum(vals.reshape(codes.shape), axis=-1)


def pq_adc_scan(codes: jax.Array, lut: jax.Array, *, block_n: int = 2048,
                interpret: bool = True) -> jax.Array:
    """codes (N, M) uint8, lut (M, K) f32 -> distances (N,) f32.

    N must be a multiple of block_n (callers pad; ops.py handles it)."""
    n, m = codes.shape
    mk, k = lut.shape
    assert mk == m, (m, mk)
    assert n % block_n == 0, (n, block_n)
    grid = (n // block_n,)
    return pl.pallas_call(
        functools.partial(_adc_kernel, m=m, k=k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, m), lambda i: (i, 0)),   # stream codes
            pl.BlockSpec((m, k), lambda i: (0, 0)),         # LUT resident
        ],
        out_specs=pl.BlockSpec((block_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=interpret,
    )(codes, lut)


def _adc_batch_kernel(codes_ref, luts_ref, out_ref, *, m: int, k: int,
                      n_q: int):
    """Batched-query ADC: codes tile (block_n, M) is read ONCE from HBM and
    scanned against ALL ``n_q`` LUTs resident in VMEM (n_q*M*K*4 B; 2 MB at
    B=64, M=32).  This is the §Perf hillclimb-A kernel: HBM traffic drops
    from B x codes-bytes (per-query scan) to 1 x codes-bytes per batch."""
    codes = codes_ref[...]                       # (block_n, M) uint8
    luts = luts_ref[...]                         # (n_q, M, K) f32
    idx = codes.astype(jnp.int32) + (jnp.arange(m, dtype=jnp.int32)
                                     * k)[None, :]            # (bn, M)
    flat = luts.reshape(n_q, m * k)              # (B, M*K)
    vals = jnp.take(flat, idx.reshape(-1), axis=1)            # (B, bn*M)
    out_ref[...] = jnp.sum(
        vals.reshape(n_q, codes.shape[0], m), axis=-1)        # (B, bn)


def pq_adc_scan_batch(codes: jax.Array, luts: jax.Array, *,
                      block_n: int = 2048,
                      interpret: bool = True) -> jax.Array:
    """codes (N, M) uint8, luts (B, M, K) f32 -> distances (B, N) f32."""
    n, m = codes.shape
    b, mk, k = luts.shape
    assert mk == m and n % block_n == 0
    grid = (n // block_n,)
    return pl.pallas_call(
        functools.partial(_adc_batch_kernel, m=m, k=k, n_q=b),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, m), lambda i: (i, 0)),
            pl.BlockSpec((b, m, k), lambda i: (0, 0, 0)),   # LUTs resident
        ],
        out_specs=pl.BlockSpec((b, block_n), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((b, n), jnp.float32),
        interpret=interpret,
    )(codes, luts)


def _adc_topk_kernel(codes_ref, lut_ref, vals_ref, idx_ref, *,
                     m: int, k: int, topk: int, block_n: int, n: int):
    """Fused scan + per-block top-k: each grid step emits only (topk) pairs
    instead of block_n distances — the HBM write traffic drops by
    block_n/topk (the §Perf 'fused partial top-k' optimisation).

    Padding rows (global id >= ``n``) are masked to +inf BEFORE the
    per-block top-k: a mostly-padding final block must never evict genuine
    candidates from its partial top-k (they would be unrecoverable at the
    merge — the ISSUE-6 padding-eviction bug)."""
    i = pl.program_id(0)
    codes = codes_ref[...]
    lut_flat = lut_ref[...].reshape(m * k)
    idx = codes.astype(jnp.int32) + (jnp.arange(m, dtype=jnp.int32)
                                     * k)[None, :]
    vals = jnp.take(lut_flat, idx.reshape(-1), axis=0)
    dist = jnp.sum(vals.reshape(codes.shape), axis=-1)      # (block_n,)
    gids = (jax.lax.broadcasted_iota(jnp.int32, (block_n, 1), 0).squeeze(-1)
            + i * block_n)
    dist = jnp.where(gids < n, dist, jnp.inf)
    neg, pos = jax.lax.top_k(-dist, topk)
    vals_ref[...] = -neg
    idx_ref[...] = (pos + i * block_n).astype(jnp.int32)


def pq_adc_scan_topk(codes: jax.Array, lut: jax.Array, topk: int, *,
                     n: int = None, block_n: int = 2048,
                     interpret: bool = True):
    """Fused ADC scan + block-local top-k.

    ``n`` is the REAL row count (rows past it are padding, masked to +inf
    inside each block before its partial top-k).  Returns
    (vals (n_blocks*topk,), global_ids (n_blocks*topk,)); callers finish
    with one small lax.top_k merge (ops.pq_adc_topk)."""
    n_padded, m = codes.shape
    _, k = lut.shape
    if n is None:
        n = n_padded
    assert n_padded % block_n == 0 and topk <= block_n
    grid = (n_padded // block_n,)
    return pl.pallas_call(
        functools.partial(_adc_topk_kernel, m=m, k=k, topk=topk,
                          block_n=block_n, n=n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, m), lambda i: (i, 0)),
            pl.BlockSpec((m, k), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((topk,), lambda i: (i,)),
            pl.BlockSpec((topk,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_padded // block_n * topk,), jnp.float32),
            jax.ShapeDtypeStruct((n_padded // block_n * topk,), jnp.int32),
        ],
        interpret=interpret,
    )(codes, lut)


def _adc_fused_kernel(rows_ref, codes_ref, queries_ref, cb_ref,
                      vals_ref, ids_ref, *scratch,
                      m: int, k: int, dsub: int, tk: int, lut_int8: bool):
    """One kernel per scan window: LUT build (query x codebooks) + ADC scan
    + block-local partial top-k (no full sort).

    * The (B, M, K) LUT is built ONCE at grid step 0 into VMEM scratch and
      stays resident across the whole grid — the BANG-style shared-memory
      pipeline (PAPERS.md) mapped to Pallas.
    * Candidate row-id tiles (B, block_s) stream through; pad slots
      (row id -1) score +inf BEFORE the partial top-k, so padding can
      never evict a genuine candidate (the bug fixed in _adc_topk_kernel,
      not ported here).
    * Only (dist, id) pairs exit to HBM: block_s slots in, tk pairs out.
    * ``lut_int8=True`` is the paper's fig10 accuracy-level variant: the
      LUT is quantised to int8 with a per-(query, subquantizer) scale and
      zero-point at build time (4x less VMEM), and dequantised per lookup
      with the accumulation kept in fp32 (the "fp32 merge").
    """
    @pl.when(pl.program_id(0) == 0)
    def _():
        q = queries_ref[...].astype(jnp.float32)
        q = q.reshape(q.shape[0], m, 1, dsub)                 # (B, M, 1, ds)
        lut = jnp.sum((cb_ref[...][None] - q) ** 2, axis=-1)  # (B, M, K)
        if lut_int8:
            lut8_ref, scale_ref, zp_ref = scratch
            lo = jnp.min(lut, axis=-1, keepdims=True)
            hi = jnp.max(lut, axis=-1, keepdims=True)
            scale = jnp.maximum(hi - lo, 1e-12) / 255.0
            lut8_ref[...] = (jnp.round((lut - lo) / scale)
                             - 128.0).astype(jnp.int8)
            scale_ref[...] = scale[..., 0]
            zp_ref[...] = lo[..., 0]
        else:
            scratch[0][...] = lut

    rows = rows_ref[...]                                      # (B, block_s)
    b, block_s = rows.shape
    rsafe = jnp.maximum(rows, 0)
    crow = jnp.take(codes_ref[...], rsafe.reshape(-1),
                    axis=0).reshape(b, block_s, m)            # (B, bs, M)
    idx = (crow.astype(jnp.int32)
           + (jnp.arange(m, dtype=jnp.int32) * k)[None, None, :]
           + (jnp.arange(b, dtype=jnp.int32) * (m * k))[:, None, None])
    if lut_int8:
        lut8_ref, scale_ref, zp_ref = scratch
        g = jnp.take(lut8_ref[...].reshape(-1), idx.reshape(-1),
                     axis=0).reshape(b, block_s, m).astype(jnp.float32)
        # dequantise per element, accumulate in fp32 (the "fp32 merge")
        dist = jnp.sum((g + 128.0) * scale_ref[...][:, None, :]
                       + zp_ref[...][:, None, :], axis=-1)
    else:
        g = jnp.take(scratch[0][...].reshape(-1), idx.reshape(-1), axis=0)
        dist = jnp.sum(g.reshape(b, block_s, m), axis=-1)     # (B, bs)
    dist = jnp.where(rows >= 0, dist, jnp.inf)
    neg, pos = jax.lax.top_k(-dist, tk)
    vals_ref[...] = -neg
    # pad slots carry row id -1 — an explicit "no candidate" marker the
    # merge keeps attached to its +inf distance
    ids_ref[...] = jnp.take_along_axis(rows, pos, axis=1)


def pq_adc_scan_fused(codes: jax.Array, queries: jax.Array,
                      codebooks: jax.Array, rows: jax.Array, topk: int, *,
                      block_s: int = 2048, lut_int8: bool = False,
                      interpret: bool = True):
    """Fused LUT->ADC->top-k over per-query candidate rows.

    codes (N, M) uint8 resident; queries (B, M*dsub) f32 (rotation already
    applied); codebooks (M, K, dsub) f32; rows (B, S) int32 candidate row
    ids (-1 = pad, S a multiple of ``block_s``).  Returns
    (vals (B, n_blocks*tk), ids (B, n_blocks*tk)) with tk =
    min(topk, block_s); callers finish with one small merge
    (ops.pq_adc_fused_topk)."""
    n, m = codes.shape
    mk, k, dsub = codebooks.shape
    b, s = rows.shape
    assert mk == m and s % block_s == 0, (m, mk, s, block_s)
    tk = min(topk, block_s)
    grid = (s // block_s,)
    if lut_int8:
        scratch = [pltpu.VMEM((b, m, k), jnp.int8),
                   pltpu.VMEM((b, m), jnp.float32),
                   pltpu.VMEM((b, m), jnp.float32)]
    else:
        scratch = [pltpu.VMEM((b, m, k), jnp.float32)]
    return pl.pallas_call(
        functools.partial(_adc_fused_kernel, m=m, k=k, dsub=dsub, tk=tk,
                          lut_int8=lut_int8),
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, block_s), lambda i: (0, i)),    # stream rows
            pl.BlockSpec((n, m), lambda i: (0, 0)),          # codes resident
            pl.BlockSpec(queries.shape, lambda i: (0, 0)),   # resident
            pl.BlockSpec((m, k, dsub), lambda i: (0, 0, 0)),  # resident
        ],
        out_specs=[
            pl.BlockSpec((b, tk), lambda i: (0, i)),
            pl.BlockSpec((b, tk), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, s // block_s * tk), jnp.float32),
            jax.ShapeDtypeStruct((b, s // block_s * tk), jnp.int32),
        ],
        scratch_shapes=scratch,
        interpret=interpret,
    )(rows, codes, queries, codebooks)
