"""Pallas TPU kernel: PQ ADC scan (paper step ⑥, TPU-native — DESIGN.md §2).

The (M, K) distance LUT (≤ 128 KB for M ≤ 128, K = 256, f32) is pinned in
VMEM for the whole grid; PQ codes stream HBM→VMEM in (block_n, M) uint8
tiles.  Arithmetic intensity is ~2 FLOP/byte → the kernel is sized for
bandwidth: block_n * M bytes per grid step, one f32 row out.

Unlike the paper's CUDA kernel (one thread per dimension + coordinator
accumulation + spinlock hash dedup), the TPU formulation is a vectorised
flat-index gather over the VMEM-resident LUT with a sum over M — no atomics
exist in Pallas and none are needed (dedup is a separate sort-based pass).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _adc_kernel(codes_ref, lut_ref, out_ref, *, m: int, k: int):
    codes = codes_ref[...]                       # (block_n, M) uint8
    lut_flat = lut_ref[...].reshape(m * k)       # (M*K,) f32 in VMEM
    idx = codes.astype(jnp.int32) + (jnp.arange(m, dtype=jnp.int32)
                                     * k)[None, :]
    vals = jnp.take(lut_flat, idx.reshape(-1), axis=0)
    out_ref[...] = jnp.sum(vals.reshape(codes.shape), axis=-1)


def pq_adc_scan(codes: jax.Array, lut: jax.Array, *, block_n: int = 2048,
                interpret: bool = True) -> jax.Array:
    """codes (N, M) uint8, lut (M, K) f32 -> distances (N,) f32.

    N must be a multiple of block_n (callers pad; ops.py handles it)."""
    n, m = codes.shape
    mk, k = lut.shape
    assert mk == m, (m, mk)
    assert n % block_n == 0, (n, block_n)
    grid = (n // block_n,)
    return pl.pallas_call(
        functools.partial(_adc_kernel, m=m, k=k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, m), lambda i: (i, 0)),   # stream codes
            pl.BlockSpec((m, k), lambda i: (0, 0)),         # LUT resident
        ],
        out_specs=pl.BlockSpec((block_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=interpret,
    )(codes, lut)


def _adc_batch_kernel(codes_ref, luts_ref, out_ref, *, m: int, k: int,
                      n_q: int):
    """Batched-query ADC: codes tile (block_n, M) is read ONCE from HBM and
    scanned against ALL ``n_q`` LUTs resident in VMEM (n_q*M*K*4 B; 2 MB at
    B=64, M=32).  This is the §Perf hillclimb-A kernel: HBM traffic drops
    from B x codes-bytes (per-query scan) to 1 x codes-bytes per batch."""
    codes = codes_ref[...]                       # (block_n, M) uint8
    luts = luts_ref[...]                         # (n_q, M, K) f32
    idx = codes.astype(jnp.int32) + (jnp.arange(m, dtype=jnp.int32)
                                     * k)[None, :]            # (bn, M)
    flat = luts.reshape(n_q, m * k)              # (B, M*K)
    vals = jnp.take(flat, idx.reshape(-1), axis=1)            # (B, bn*M)
    out_ref[...] = jnp.sum(
        vals.reshape(n_q, codes.shape[0], m), axis=-1)        # (B, bn)


def pq_adc_scan_batch(codes: jax.Array, luts: jax.Array, *,
                      block_n: int = 2048,
                      interpret: bool = True) -> jax.Array:
    """codes (N, M) uint8, luts (B, M, K) f32 -> distances (B, N) f32."""
    n, m = codes.shape
    b, mk, k = luts.shape
    assert mk == m and n % block_n == 0
    grid = (n // block_n,)
    return pl.pallas_call(
        functools.partial(_adc_batch_kernel, m=m, k=k, n_q=b),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, m), lambda i: (i, 0)),
            pl.BlockSpec((b, m, k), lambda i: (0, 0, 0)),   # LUTs resident
        ],
        out_specs=pl.BlockSpec((b, block_n), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((b, n), jnp.float32),
        interpret=interpret,
    )(codes, luts)


def _adc_topk_kernel(codes_ref, lut_ref, vals_ref, idx_ref, *,
                     m: int, k: int, topk: int, block_n: int):
    """Fused scan + per-block top-k: each grid step emits only (topk) pairs
    instead of block_n distances — the HBM write traffic drops by
    block_n/topk (the §Perf 'fused partial top-k' optimisation)."""
    i = pl.program_id(0)
    codes = codes_ref[...]
    lut_flat = lut_ref[...].reshape(m * k)
    idx = codes.astype(jnp.int32) + (jnp.arange(m, dtype=jnp.int32)
                                     * k)[None, :]
    vals = jnp.take(lut_flat, idx.reshape(-1), axis=0)
    dist = jnp.sum(vals.reshape(codes.shape), axis=-1)      # (block_n,)
    neg, pos = jax.lax.top_k(-dist, topk)
    vals_ref[...] = -neg
    idx_ref[...] = (pos + i * block_n).astype(jnp.int32)


def pq_adc_scan_topk(codes: jax.Array, lut: jax.Array, topk: int, *,
                     block_n: int = 2048, interpret: bool = True):
    """Fused ADC scan + block-local top-k.

    Returns (vals (n_blocks*topk,), global_ids (n_blocks*topk,)); callers
    finish with one small lax.top_k merge (ops.pq_adc_topk)."""
    n, m = codes.shape
    _, k = lut.shape
    assert n % block_n == 0 and topk <= block_n
    grid = (n // block_n,)
    return pl.pallas_call(
        functools.partial(_adc_topk_kernel, m=m, k=k, topk=topk,
                          block_n=block_n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, m), lambda i: (i, 0)),
            pl.BlockSpec((m, k), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((topk,), lambda i: (i,)),
            pl.BlockSpec((topk,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n // block_n * topk,), jnp.float32),
            jax.ShapeDtypeStruct((n // block_n * topk,), jnp.int32),
        ],
        interpret=interpret,
    )(codes, lut)
