"""Pallas TPU flash-attention forward (the §Roofline answer for the
memory-bound LM train/prefill cells: score blocks live in VMEM, never HBM).

Canonical revisited-grid structure: grid = (B, Hk, G, S/bq, T/bk) with the
innermost dimension sweeping KV blocks while the output block index ignores
it — running (m, l, acc) persist in VMEM scratch across those revisits and
the normalised output is written on the last KV step.  Causal blocks wholly
above the diagonal are skipped via @pl.when.

VMEM working set per grid step: q(bq,dh) + k/v(bk,dh) + scores(bq,bk) +
acc(bq,dh) floats — MXU-aligned for bq,bk multiples of 128 and dh 128.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, bq: int, bk: int,
                  n_kv: int):
    qi = pl.program_id(3)
    ki = pl.program_id(4)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    def _step():
        q = q_ref[0, 0, 0].astype(jnp.float32) * scale     # (bq, dh)
        k = k_ref[0, 0].astype(jnp.float32)                # (bk, dh)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if causal:
            q_pos = qi * bq + jnp.arange(bq)
            k_pos = ki * bk + jnp.arange(bk)
            s = jnp.where(q_pos[:, None] >= k_pos[None, :], s, _NEG_INF)
        m_prev, l_prev = m_scr[...], l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_prev * corr + jnp.sum(p, axis=-1)
        m_scr[...] = m_new
        acc_scr[...] = (acc_scr[...] * corr[:, None]
                        + jax.lax.dot_general(
                            p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))

    if causal:
        # skip KV blocks entirely above the diagonal
        pl.when(ki * bk <= qi * bq + bq - 1)(_step)
    else:
        _step()

    @pl.when(ki == n_kv - 1)
    def _finish():
        out = acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)[:, None]
        o_ref[0, 0, 0] = out.astype(o_ref.dtype)


def flash_attention_fwd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, scale=None,
                        block_q: int = 128, block_k: int = 128,
                        interpret: bool = True) -> jax.Array:
    """q (B,S,H,dh), k/v (B,T,Hk,dh) -> (B,S,H,dh).  GQA via the G grid dim
    (no KV replication in memory — each (kh, g) step reads the same KV
    block)."""
    B, S, H, dh = q.shape
    T, Hk = k.shape[1], k.shape[2]
    G = H // Hk
    scale = scale if scale is not None else 1.0 / math.sqrt(dh)
    bq = min(block_q, S)
    bk = min(block_k, T)
    assert S % bq == 0 and T % bk == 0, (S, bq, T, bk)
    n_kv = T // bk
    # layout: q (B, Hk, G, S, dh); kv (B, Hk, T, dh)
    q5 = q.reshape(B, S, Hk, G, dh).transpose(0, 2, 3, 1, 4)
    k4 = k.transpose(0, 2, 1, 3)
    v4 = v.transpose(0, 2, 1, 3)
    grid = (B, Hk, G, S // bq, n_kv)
    out = pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, causal=causal,
                          bq=bq, bk=bk, n_kv=n_kv),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, 1, bq, dh),
                         lambda b, kh, g, i, j: (b, kh, g, i, 0)),
            pl.BlockSpec((1, 1, bk, dh),
                         lambda b, kh, g, i, j: (b, kh, j, 0)),
            pl.BlockSpec((1, 1, bk, dh),
                         lambda b, kh, g, i, j: (b, kh, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, bq, dh),
                               lambda b, kh, g, i, j: (b, kh, g, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hk, G, S, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, dh), jnp.float32),
        ],
        interpret=interpret,
    )(q5, k4, v4)
    return out.transpose(0, 3, 1, 2, 4).reshape(B, S, H, dh)
