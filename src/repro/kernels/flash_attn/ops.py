"""jit'd wrapper for the flash-attention forward kernel.

On TPU this is the production forward for the memory-bound train/prefill
cells (scores never leave VMEM — see EXPERIMENTS.md §Roofline); on CPU the
interpret path validates correctness and `models.layers.blockwise_attention`
remains the lowering used by the dry-run."""

from __future__ import annotations

import functools

import jax

from repro.kernels.flash_attn.flash_attn import flash_attention_fwd
from repro.kernels.flash_attn.ref import flash_attn_ref


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "use_kernel", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 128,
                    block_k: int = 128, use_kernel: bool = True,
                    interpret: bool = True):
    if not use_kernel:
        return flash_attn_ref(q, k, v, causal=causal)
    bq = min(block_q, q.shape[1])
    bk = min(block_k, k.shape[1])
    return flash_attention_fwd(q, k, v, causal=causal, block_q=bq,
                               block_k=bk, interpret=interpret)
