"""Pure-jnp oracle for the flash-attention forward kernel (GQA-aware)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def flash_attn_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                   causal: bool = True, scale=None) -> jnp.ndarray:
    """q (B,S,H,dh), k/v (B,T,Hk,dh) -> (B,S,H,dh)."""
    B, S, H, dh = q.shape
    T, Hk = k.shape[1], k.shape[2]
    G = H // Hk
    scale = scale if scale is not None else 1.0 / np.sqrt(dh)
    qf = q.astype(jnp.float32).reshape(B, S, Hk, G, dh) * scale
    s = jnp.einsum("bskgd,btkd->bkgst", qf, k.astype(jnp.float32))
    if causal:
        mask = jnp.arange(S)[:, None] >= jnp.arange(T)[None, :]
        s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgst,btkd->bskgd", p, v.astype(jnp.float32))
    return o.reshape(B, S, H, dh).astype(q.dtype)
