"""AdamW + cosine schedule + global-norm clipping (from scratch, no optax)."""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def cosine_lr(step, cfg: OptimizerConfig):
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def adamw_init(params) -> Dict[str, Any]:
    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros_like(p, jnp.float32), params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.copy, zeros),
            "step": jnp.zeros((), jnp.int32)}


def opt_state_specs(param_specs) -> Dict[str, Any]:
    return {"m": param_specs, "v": param_specs, "step": P()}


def adamw_update(grads, state, params, cfg: OptimizerConfig):
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = cosine_lr(step, cfg)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay \
            * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state["m"])
    flat_v = tdef.flatten_up_to(state["v"])
    new = [upd(g, m, v, p) for g, m, v, p in
           zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = tdef.unflatten([n[0] for n in new])
    new_m = tdef.unflatten([n[1] for n in new])
    new_v = tdef.unflatten([n[2] for n in new])
    return new_p, {"m": new_m, "v": new_v, "step": step}, \
        {"grad_norm": gnorm, "lr": lr}


def apply_updates(params, updates):
    return jax.tree_util.tree_map(lambda p, u: p + u, params, updates)
