"""int8 error-feedback gradient compression for the DP all-reduce.

On a real cluster this halves/quarters the gradient all-reduce bytes (the
dominant collective for pure-DP scaling); error feedback keeps convergence
(1-bit Adam / EF-SGD lineage).  The quantise->dequantise pair is inserted
*before* the psum so XLA reduces int8-scaled tensors; here we model it as
q(dq(g)) + residual carry, which is numerically identical on 1 device and
unit-tested for the EF invariant (residual + transmitted == original).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def compress_decompress(g: jax.Array, bits: int = 8):
    """Symmetric per-tensor int quantisation; returns (dequantised, residual)."""
    gf = g.astype(jnp.float32)
    qmax = 2.0 ** (bits - 1) - 1
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / qmax
    q = jnp.clip(jnp.round(gf / scale), -qmax, qmax)
    dq = q * scale
    return dq.astype(g.dtype), (gf - dq).astype(jnp.float32)


def ef_init(params):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros_like(p, jnp.float32), params)


def ef_compress_grads(grads, residuals, bits: int = 8):
    """Error-feedback: compress (grad + residual), carry the new residual."""
    def one(g, r):
        dq, new_r = compress_decompress(g.astype(jnp.float32) + r, bits)
        return dq, new_r
    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_r = tdef.flatten_up_to(residuals)
    pairs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return tdef.unflatten([p[0] for p in pairs]), \
        tdef.unflatten([p[1] for p in pairs])
