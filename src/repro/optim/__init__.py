from repro.optim.adamw import (  # noqa: F401
    OptimizerConfig,
    adamw_init,
    adamw_update,
    apply_updates,
    cosine_lr,
    global_norm,
    opt_state_specs,
)
from repro.optim.compress import (  # noqa: F401
    compress_decompress,
    ef_compress_grads,
    ef_init,
)
