"""Multi-host launch glue for real TPU pods.

On a v5e pod each host runs this same program; `init_distributed()` wires
jax.distributed from the scheduler environment (GKE/TPU-VM metadata or
explicit env), after which `jax.devices()` spans the pod and
`make_production_mesh()` builds the global mesh.  Per-host data sharding
follows `host_batch_slice`.

This container has a single process; the functions degrade to no-ops so
every launcher works unchanged locally (unit-tested)."""

from __future__ import annotations

import os
from typing import Optional, Tuple

import jax


def init_distributed(coordinator: Optional[str] = None,
                     num_processes: Optional[int] = None,
                     process_id: Optional[int] = None) -> None:
    """Initialise jax.distributed from args or environment.

    Env fallbacks: COORDINATOR_ADDRESS, NUM_PROCESSES, PROCESS_ID (generic),
    or TPU-VM metadata handled natively by jax when nothing is set."""
    coordinator = coordinator or os.environ.get("COORDINATOR_ADDRESS")
    num = num_processes or int(os.environ.get("NUM_PROCESSES", "0")) or None
    pid = process_id if process_id is not None else (
        int(os.environ["PROCESS_ID"]) if "PROCESS_ID" in os.environ else None)
    if coordinator is None and num is None:
        return                      # single-process (local/dev)
    jax.distributed.initialize(coordinator_address=coordinator,
                               num_processes=num, process_id=pid)


def host_batch_slice(global_batch: int) -> Tuple[int, int]:
    """(start, size) of this host's slice of the global batch — the data
    pipeline loads only its slice (per-host sharded input)."""
    n_proc = jax.process_count()
    assert global_batch % n_proc == 0, (global_batch, n_proc)
    per = global_batch // n_proc
    return jax.process_index() * per, per


def local_device_put_sharded(global_arrays, shardings):
    """Place per-host array slices as a global jax.Array
    (jax.make_array_from_process_local_data wrapper)."""
    return jax.tree_util.tree_map(
        lambda x, s: jax.make_array_from_process_local_data(s, x),
        global_arrays, shardings)


def is_coordinator() -> bool:
    return jax.process_index() == 0
