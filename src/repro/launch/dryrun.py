import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape)
cell on the production meshes, record memory/cost/collective analysis.

MUST be run as its own process (512 placeholder host devices are locked in
at jax init — see the two lines above, which precede every other import).

Usage:
  python -m repro.launch.dryrun --all [--mesh single|multi|both]
  python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k --mesh single
  python -m repro.launch.dryrun --anns            # FusionANNS sharded-scan cell

Results append to JSONL (default dryrun_results.jsonl); completed cells are
skipped on re-run (resume support)."""

import argparse
import json
import time
import traceback

import jax
import numpy as np

from repro.analysis.model_flops import model_flops
from repro.analysis import roofline as rl
from repro.configs import shapes_for
from repro.configs.registry import ARCH_IDS, get_config
from repro.launch.mesh import make_production_mesh


def run_cell(arch: str, shape_id: str, multi_pod: bool, out_path: str,
             hlo_dir: str = "") -> dict:
    from repro.models.api import build_cell   # jax already initialised
    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = get_config(arch)
    cell = build_cell(arch, shape_id, mesh=mesh)
    t0 = time.time()
    jitted = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                     donate_argnums=cell.donate_argnums)
    with mesh:
        lowered = jitted.lower(*cell.args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        hlo = compiled.as_text()
    dims = next(c for c in shapes_for(cfg) if c.shape_id == shape_id).dims
    mf = model_flops(cfg, cell.step, shape_id, dims)
    roof = rl.from_compiled(compiled, mf, mesh.size, hlo_text=hlo)
    rec = {
        "arch": arch, "shape": shape_id, "step": cell.step,
        "mesh": "multi" if multi_pod else "single",
        "n_chips": mesh.size,
        "t_lower_s": round(t_lower, 1), "t_compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "generated_code_bytes": mem.generated_code_size_in_bytes,
            "peak_bytes_per_device": (mem.argument_size_in_bytes
                                      + mem.temp_size_in_bytes),
        },
        "roofline": roof.to_dict(),
        "ok": True,
    }
    if hlo_dir:
        os.makedirs(hlo_dir, exist_ok=True)
        tag = f"{arch}_{shape_id}_{rec['mesh']}".replace("/", "_")
        with open(os.path.join(hlo_dir, tag + ".hlo.txt"), "w") as f:
            f.write(hlo)
    return rec


def run_anns_cell(multi_pod: bool) -> dict:
    """The paper's own distributed cell: billion-scale sharded ADC scan +
    two-level top-n merge (SIFT1B config: 1B x M=32 codes pinned in HBM).
    REPRO_OPT_ANNS=0 lowers the per-query-map baseline (§Perf ablation)."""
    import jax.numpy as jnp
    from repro.core.distributed import sharded_adc_topn_batch
    from repro.models.layers import ShardCtx
    from repro.sharding.spec import rules_for_mesh

    blocked = os.environ.get("REPRO_OPT_ANNS", "1") == "1"
    mesh = make_production_mesh(multi_pod=multi_pod)
    ctx = ShardCtx(mesh=mesh, rules=rules_for_mesh(mesh))
    n, m, k, batch, top_n = 2 ** 30, 32, 256, 64, 512
    codes = jax.ShapeDtypeStruct((n, m), jnp.uint8)
    luts = jax.ShapeDtypeStruct((batch, m, k), jnp.float32)
    from jax.sharding import NamedSharding, PartitionSpec as P
    sh = (NamedSharding(mesh, P(ctx.rules.corpus, None)),
          NamedSharding(mesh, P(None, None, None)))

    def scan_step(codes, luts):
        return sharded_adc_topn_batch(codes, luts, top_n, ctx,
                                      blocked=blocked)

    t0 = time.time()
    with mesh:
        lowered = jax.jit(scan_step, in_shardings=sh).lower(codes, luts)
        compiled = lowered.compile()
        mem = compiled.memory_analysis()
        hlo = compiled.as_text()
    # useful work: batch x N x M lookups ~ 2 flop each (gather+add)
    mf = 2.0 * batch * n * m
    roof = rl.from_compiled(compiled, mf, mesh.size, hlo_text=hlo)
    return {
        "arch": "fusionanns", "shape": f"scan_1b_b{batch}",
        "step": "anns_scan", "mesh": "multi" if multi_pod else "single",
        "n_chips": mesh.size,
        "t_compile_s": round(time.time() - t0, 1),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "peak_bytes_per_device": (mem.argument_size_in_bytes
                                      + mem.temp_size_in_bytes),
        },
        "roofline": roof.to_dict(),
        "ok": True,
    }


def _done_cells(path: str):
    done = set()
    if os.path.exists(path):
        with open(path) as f:
            for line in f:
                try:
                    r = json.loads(line)
                    if r.get("ok"):
                        done.add((r["arch"], r["shape"], r["mesh"]))
                except json.JSONDecodeError:
                    pass
    return done


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS + ("fusionanns",))
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--anns", action="store_true")
    ap.add_argument("--out", default="dryrun_results.jsonl")
    ap.add_argument("--hlo-dir", default="")
    args = ap.parse_args()

    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    jobs = []
    if args.all:
        for arch in ARCH_IDS:
            cfg = get_config(arch)
            for sc in shapes_for(cfg):
                for mp in meshes:
                    jobs.append((arch, sc.shape_id, mp))
        for mp in meshes:
            jobs.append(("fusionanns", "anns", mp))
    elif args.anns or args.arch == "fusionanns":
        jobs = [("fusionanns", "anns", mp) for mp in meshes]
    else:
        jobs = [(args.arch, args.shape, mp) for mp in meshes]

    done = _done_cells(args.out)
    for arch, shape, mp in jobs:
        mesh_name = "multi" if mp else "single"
        key = (arch, f"scan_1b_b64" if arch == "fusionanns" else shape,
               mesh_name)
        if key in done:
            print(f"SKIP {key}", flush=True)
            continue
        print(f"RUN  {arch} {shape} {mesh_name}", flush=True)
        try:
            rec = (run_anns_cell(mp) if arch == "fusionanns"
                   else run_cell(arch, shape, mp, args.out, args.hlo_dir))
            print(f"  ok: compile={rec.get('t_compile_s')}s "
                  f"peak={rec['memory']['peak_bytes_per_device']/2**30:.2f}GiB"
                  f" bottleneck={rec['roofline']['bottleneck']}", flush=True)
        except Exception as e:  # noqa: BLE001 — record the failure, continue
            rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
                   "ok": False, "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-2000:]}
            print(f"  FAIL {type(e).__name__}: {e}", flush=True)
        with open(args.out, "a") as f:
            f.write(json.dumps(rec) + "\n")


if __name__ == "__main__":
    main()
