"""Production mesh definitions.

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module does not touch jax device state; dryrun.py sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import to obtain placeholder devices."""

from __future__ import annotations

from typing import List

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips (data, model).
    Multi-pod: 2x16x16 = 512 chips (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(n_devices: int | None = None, *, multi_pod: bool = False):
    """Small mesh over whatever devices exist (tests)."""
    n = n_devices or len(jax.devices())
    if multi_pod:
        assert n % 2 == 0 and n >= 8
        return jax.make_mesh((2, 2, n // 4), ("pod", "data", "model"))
    if n == 1:
        return jax.make_mesh((1, 1), ("data", "model"))
    return jax.make_mesh((2, n // 2), ("data", "model"))


def split_mesh(mesh, n_replicas: int) -> List[jax.sharding.Mesh]:
    """Carve ``mesh`` into ``n_replicas`` DISJOINT sub-meshes (multi-replica
    serving: each replica's executor row-shards the corpus over its own
    device group, so per-replica ADC scans never contend for a chip).

    The leading mesh axis is split when divisible; otherwise the device
    array is flattened and re-folded so any ``n_replicas`` dividing the
    device count works.  Every sub-mesh keeps the parent's axis names
    (sharding rules and ``corpus``-axis specs stay valid unchanged)."""
    if n_replicas < 1:
        raise ValueError(f"n_replicas must be >= 1, got {n_replicas}")
    if n_replicas == 1:
        return [mesh]
    devs = np.asarray(mesh.devices)
    total = devs.size
    if total % n_replicas:
        raise ValueError(
            f"cannot split {total} devices into {n_replicas} replicas")
    per = total // n_replicas
    if devs.shape[0] % n_replicas == 0:
        groups = np.split(devs, n_replicas, axis=0)
    else:                      # re-fold: (n_replicas, 1, ..., per)
        shape = (1,) * (devs.ndim - 1) + (per,)
        groups = [g.reshape(shape)
                  for g in np.split(devs.reshape(-1), n_replicas)]
    return [jax.sharding.Mesh(g, mesh.axis_names) for g in groups]


def recarve_mesh(mesh, n_groups: int) -> List[jax.sharding.Mesh]:
    """Re-carve ``mesh`` into ``n_groups`` disjoint sub-meshes for an
    ELASTIC replica set (serve/autoscaler.py): unlike :func:`split_mesh`,
    ``n_groups`` need not divide the device count — the flattened device
    list is cut into contiguous near-equal groups (sizes differ by at
    most one), so the autoscaler can move 8 devices between 3 and 4
    replicas without a rebuild.  Equal divisions keep :func:`split_mesh`
    semantics exactly (same grouping, same axis folding).  Every sub-mesh
    keeps the parent's axis names, so ``corpus``-axis sharding specs stay
    valid; an executor re-attached to its new group
    (``QueryExecutor.attach_mesh``) re-places its HBM shard on the next
    dispatch."""
    if n_groups < 1:
        raise ValueError(f"n_groups must be >= 1, got {n_groups}")
    devs = np.asarray(mesh.devices)
    total = devs.size
    if n_groups > total:
        raise ValueError(
            f"cannot carve {total} device(s) into {n_groups} groups")
    if total % n_groups == 0:
        return split_mesh(mesh, n_groups)
    flat = devs.reshape(-1)
    base, extra = divmod(total, n_groups)
    groups, at = [], 0
    for gi in range(n_groups):
        size = base + (1 if gi < extra else 0)
        shape = (1,) * (devs.ndim - 1) + (size,)
        groups.append(flat[at:at + size].reshape(shape))
        at += size
    return [jax.sharding.Mesh(g, mesh.axis_names) for g in groups]
