"""Production mesh definitions.

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module does not touch jax device state; dryrun.py sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import to obtain placeholder devices."""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips (data, model).
    Multi-pod: 2x16x16 = 512 chips (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(n_devices: int | None = None, *, multi_pod: bool = False):
    """Small mesh over whatever devices exist (tests)."""
    n = n_devices or len(jax.devices())
    if multi_pod:
        assert n % 2 == 0 and n >= 8
        return jax.make_mesh((2, 2, n // 4), ("pod", "data", "model"))
    if n == 1:
        return jax.make_mesh((1, 1), ("data", "model"))
    return jax.make_mesh((2, n // 2), ("data", "model"))
