"""Production mesh definitions.

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module does not touch jax device state; dryrun.py sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import to obtain placeholder devices."""

from __future__ import annotations

from typing import List

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips (data, model).
    Multi-pod: 2x16x16 = 512 chips (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(n_devices: int | None = None, *, multi_pod: bool = False):
    """Small mesh over whatever devices exist (tests)."""
    n = n_devices or len(jax.devices())
    if multi_pod:
        assert n % 2 == 0 and n >= 8
        return jax.make_mesh((2, 2, n // 4), ("pod", "data", "model"))
    if n == 1:
        return jax.make_mesh((1, 1), ("data", "model"))
    return jax.make_mesh((2, n // 2), ("data", "model"))


def split_mesh(mesh, n_replicas: int) -> List[jax.sharding.Mesh]:
    """Carve ``mesh`` into ``n_replicas`` DISJOINT sub-meshes (multi-replica
    serving: each replica's executor row-shards the corpus over its own
    device group, so per-replica ADC scans never contend for a chip).

    The leading mesh axis is split when divisible; otherwise the device
    array is flattened and re-folded so any ``n_replicas`` dividing the
    device count works.  Every sub-mesh keeps the parent's axis names
    (sharding rules and ``corpus``-axis specs stay valid unchanged)."""
    if n_replicas < 1:
        raise ValueError(f"n_replicas must be >= 1, got {n_replicas}")
    if n_replicas == 1:
        return [mesh]
    devs = np.asarray(mesh.devices)
    total = devs.size
    if total % n_replicas:
        raise ValueError(
            f"cannot split {total} devices into {n_replicas} replicas")
    per = total // n_replicas
    if devs.shape[0] % n_replicas == 0:
        groups = np.split(devs, n_replicas, axis=0)
    else:                      # re-fold: (n_replicas, 1, ..., per)
        shape = (1,) * (devs.ndim - 1) + (per,)
        groups = [g.reshape(shape)
                  for g in np.split(devs.reshape(-1), n_replicas)]
    return [jax.sharding.Mesh(g, mesh.axis_names) for g in groups]
