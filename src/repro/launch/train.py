"""Training launcher.

  python -m repro.launch.train --arch qwen3-0.6b --reduced --steps 50

Full-scale runs use the production mesh (on real TPU pods this process is
per-host with jax.distributed.initialize; on CPU it runs the reduced config
end-to-end with checkpointing + the fault supervisor)."""

from __future__ import annotations

import argparse
import json
import os

import jax
import numpy as np

from repro.configs.registry import ARCH_IDS, get_config
from repro.data.synthetic import lm_batch
from repro.models import transformer as tfm
from repro.models.layers import LOCAL_CTX
from repro.optim.adamw import OptimizerConfig
from repro.train.loop import TrainConfig, init_state, make_train_step, run


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen3-0.6b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--grad-compress-bits", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--log", default="")
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=args.reduced)
    params = tfm.init_lm(jax.random.key(0), cfg)
    n_params = sum(int(np.prod(p.shape))
                   for p in jax.tree_util.tree_leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M")

    tcfg = TrainConfig(
        opt=OptimizerConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 5),
                            total_steps=args.steps),
        microbatches=args.microbatches,
        grad_compress_bits=args.grad_compress_bits,
        ckpt_every=args.ckpt_every, ckpt_dir=args.ckpt_dir)

    import jax.numpy as jnp

    def loss_fn(p, batch):
        return tfm.lm_loss(p, batch, cfg, LOCAL_CTX, dtype=jnp.float32)

    step_fn = jax.jit(make_train_step(loss_fn, tcfg), donate_argnums=(0,))
    state = init_state(params, tcfg)

    rng = np.random.default_rng(0)

    def batches():
        for _ in range(args.steps):
            b = lm_batch(rng, args.batch, args.seq, cfg.vocab_size)
            yield {k: jnp.asarray(v) for k, v in b.items()}

    state, step, history = run(step_fn, state, batches(), tcfg, log_every=10)
    for h in history:
        print(json.dumps({k: round(v, 4) if isinstance(v, float) else v
                          for k, v in h.items()}))
    if args.log:
        with open(args.log, "w") as f:
            json.dump(history, f)
    first, last = history[0]["loss"], history[-1]["loss"]
    print(f"loss {first:.3f} -> {last:.3f} over {step} steps")


if __name__ == "__main__":
    main()
