"""Serving launcher: ANNS service and/or LM decode demo.

  python -m repro.launch.serve --mode anns --n 20000 --queries 50
  python -m repro.launch.serve --mode lm --arch qwen3-0.6b --reduced
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import numpy as np

from repro.configs.anns_datasets import SIFT_SMALL
from repro.configs.registry import ARCH_IDS, get_config
from repro.core.engine import FusionANNSIndex, ground_truth, recall_at_k
from repro.data.synthetic import clustered_vectors
from repro.models import transformer as tfm
from repro.serve.engine import LMServer, ServeConfig


def serve_anns(args) -> None:
    rng = np.random.default_rng(0)
    cfg = dataclasses.replace(SIFT_SMALL, n_vectors=args.n)
    data = clustered_vectors(rng, cfg.n_vectors, cfg.dim,
                             n_clusters=max(8, args.n // 400))
    t0 = time.time()
    index = FusionANNSIndex.build(data, cfg)
    print(f"index built in {time.time()-t0:.1f}s "
          f"(clusters={index.posting.n_clusters}, "
          f"replication={index.posting.replication_factor():.2f}x)")
    queries = clustered_vectors(rng, args.queries, cfg.dim,
                                n_clusters=max(8, args.n // 400))
    gt = ground_truth(data, queries, cfg.top_k)
    t0 = time.time()
    results = index.batch_query(queries)
    dt = time.time() - t0
    rec = recall_at_k(np.stack([r.ids for r in results]), gt, cfg.top_k)
    print(json.dumps({
        "recall@10": round(rec, 4),
        "qps_host": round(len(queries) / dt, 1),
        "mean_ios": round(float(np.mean([r.stats.ios for r in results])), 2),
        "mean_h2d_bytes": int(np.mean([r.stats.h2d_bytes for r in results])),
        "early_stop_rate": round(float(np.mean(
            [r.stats.early_stopped for r in results])), 3),
    }))


def serve_lm(args) -> None:
    cfg = get_config(args.arch, reduced=args.reduced)
    params = tfm.init_lm(jax.random.key(0), cfg)
    server = LMServer(params, cfg, ServeConfig(max_len=args.max_len))
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len),
                           dtype=np.int32)
    out = server.generate(prompts, args.gen_tokens)
    print(json.dumps({"tokens_per_s": round(out["tokens_per_s"], 1),
                      "wall_s": round(out["wall_s"], 2),
                      "shape": list(out["tokens"].shape)}))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=("anns", "lm"), default="anns")
    ap.add_argument("--n", type=int, default=20000)
    ap.add_argument("--queries", type=int, default=50)
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen3-0.6b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--gen-tokens", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=64)
    args = ap.parse_args()
    if args.mode == "anns":
        serve_anns(args)
    else:
        serve_lm(args)


if __name__ == "__main__":
    main()
