"""Qwen3-0.6B [hf:Qwen/Qwen3 family]: GQA kv=8, qk_norm (per-head RMSNorm)."""

from repro.configs.base import LMConfig

CONFIG = LMConfig(
    name="qwen3-0.6b",
    n_layers=28,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    d_head=128,
    d_ff=3072,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
)

REDUCED = LMConfig(
    name="qwen3-0.6b-reduced",
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=2,
    d_head=32,
    d_ff=384,
    vocab_size=512,
    qk_norm=True,
    tie_embeddings=True,
)
