"""DLRM-RM2 [arXiv:1906.00091]: 13 dense + 26 sparse, dot interaction."""

from repro.configs.base import RecsysConfig

CONFIG = RecsysConfig(
    name="dlrm-rm2",
    kind="dlrm",
    embed_dim=64,
    n_sparse=26,
    n_dense=13,
    vocab_size=1_048_576,  # 2^20 (~10^6 rows, mesh-divisible)
    bot_mlp=(13, 512, 256, 64),
    top_mlp=(512, 512, 256, 1),
    interaction="dot",
    multi_hot=1,
)

REDUCED = RecsysConfig(
    name="dlrm-rm2-reduced",
    kind="dlrm",
    embed_dim=16,
    n_sparse=6,
    n_dense=13,
    vocab_size=512,
    bot_mlp=(13, 32, 16),
    top_mlp=(64, 32, 1),
    interaction="dot",
    multi_hot=1,
)
