"""Wide&Deep [arXiv:1606.07792]: 40 sparse fields, embed 32, MLP 1024-512-256."""

from repro.configs.base import RecsysConfig

CONFIG = RecsysConfig(
    name="wide-deep",
    kind="wide_deep",
    embed_dim=32,
    n_sparse=40,
    vocab_size=1_048_576,  # 2^20 (~10^6 rows, mesh-divisible)
    mlp=(1024, 512, 256),
    interaction="concat",
    multi_hot=1,
)

REDUCED = RecsysConfig(
    name="wide-deep-reduced",
    kind="wide_deep",
    embed_dim=8,
    n_sparse=6,
    vocab_size=512,
    mlp=(64, 32),
    interaction="concat",
    multi_hot=1,
)
