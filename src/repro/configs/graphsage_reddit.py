"""GraphSAGE [arXiv:1706.02216]: 2 layers, d=128, mean agg, fanout 25-10."""

from repro.configs.base import GNNConfig

CONFIG = GNNConfig(
    name="graphsage-reddit",
    n_layers=2,
    d_hidden=128,
    aggregator="mean",
    sample_sizes=(25, 10),
    d_feat=602,
    n_classes=41,
)

REDUCED = GNNConfig(
    name="graphsage-reduced",
    n_layers=2,
    d_hidden=32,
    aggregator="mean",
    sample_sizes=(5, 3),
    d_feat=16,
    n_classes=4,
)
