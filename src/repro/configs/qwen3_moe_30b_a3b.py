"""Qwen3-30B-A3B [hf:Qwen/Qwen3-30B-A3B]: MoE 128 experts top-8, GQA kv=4."""

from repro.configs.base import LMConfig

CONFIG = LMConfig(
    name="qwen3-moe-30b-a3b",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    d_head=128,
    d_ff=768,            # per-expert intermediate
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1_000_000.0,
    moe=True,
    n_experts=128,
    n_shared_experts=0,
    moe_top_k=8,
    moe_d_ff=768,
)

REDUCED = LMConfig(
    name="qwen3-moe-reduced",
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=2,
    d_head=32,
    d_ff=64,
    vocab_size=512,
    qk_norm=True,
    moe=True,
    n_experts=8,
    n_shared_experts=0,
    moe_top_k=2,
    moe_d_ff=64,
)
