"""Qwen1.5-4B [hf:Qwen/Qwen1.5-4B family]: dense, MHA (kv=heads), QKV bias."""

from repro.configs.base import LMConfig

CONFIG = LMConfig(
    name="qwen1.5-4b",
    n_layers=40,
    d_model=2560,
    n_heads=20,
    n_kv_heads=20,
    d_head=128,
    d_ff=6912,
    vocab_size=151936,
    qkv_bias=True,
    rope_theta=5_000_000.0,
)

REDUCED = LMConfig(
    name="qwen1.5-4b-reduced",
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=4,
    d_head=32,
    d_ff=352,
    vocab_size=512,
    qkv_bias=True,
)
