"""ChatGLM3-6B [arXiv:2406.12793]: GQA kv=2, 2D RoPE (half dims), QKV bias."""

from repro.configs.base import LMConfig

CONFIG = LMConfig(
    name="chatglm3-6b",
    n_layers=28,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    d_head=128,
    d_ff=13696,
    vocab_size=65024,
    qkv_bias=True,
    rope_fraction=0.5,   # GLM applies rotary to half of each head's dims
)

REDUCED = LMConfig(
    name="chatglm3-6b-reduced",
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=2,
    d_head=32,
    d_ff=416,
    vocab_size=512,
    qkv_bias=True,
    rope_fraction=0.5,
)
