"""``--arch <id>`` registry: maps arch ids to (CONFIG, REDUCED)."""

from __future__ import annotations

import importlib
from typing import Any, Tuple

_ARCH_MODULES = {
    "qwen1.5-4b": "repro.configs.qwen1_5_4b",
    "chatglm3-6b": "repro.configs.chatglm3_6b",
    "qwen3-0.6b": "repro.configs.qwen3_0_6b",
    "qwen3-moe-30b-a3b": "repro.configs.qwen3_moe_30b_a3b",
    "deepseek-v2-lite-16b": "repro.configs.deepseek_v2_lite_16b",
    "graphsage-reddit": "repro.configs.graphsage_reddit",
    "bert4rec": "repro.configs.bert4rec",
    "wide-deep": "repro.configs.wide_deep",
    "mind": "repro.configs.mind",
    "dlrm-rm2": "repro.configs.dlrm_rm2",
}

ARCH_IDS = tuple(_ARCH_MODULES)


def get_config(arch_id: str, reduced: bool = False) -> Any:
    mod = importlib.import_module(_ARCH_MODULES[arch_id])
    return mod.REDUCED if reduced else mod.CONFIG


def get_both(arch_id: str) -> Tuple[Any, Any]:
    mod = importlib.import_module(_ARCH_MODULES[arch_id])
    return mod.CONFIG, mod.REDUCED
