"""BERT4Rec [arXiv:1904.06690]: bidirectional seq recommender, d=64."""

from repro.configs.base import RecsysConfig

CONFIG = RecsysConfig(
    name="bert4rec",
    kind="bert4rec",
    embed_dim=64,
    n_blocks=2,
    n_heads=2,
    seq_len=200,
    vocab_size=1_048_576,   # 2^20 rows (~10^6; mesh-divisible), retrieval scores exactly 1M
    interaction="bidir-seq",
)

REDUCED = RecsysConfig(
    name="bert4rec-reduced",
    kind="bert4rec",
    embed_dim=16,
    n_blocks=2,
    n_heads=2,
    seq_len=16,
    vocab_size=512,
    interaction="bidir-seq",
)
