from repro.configs.base import (  # noqa: F401
    ANNSConfig,
    GNNConfig,
    LMConfig,
    RecsysConfig,
    ShapeCell,
    LM_SHAPES,
    GNN_SHAPES,
    RECSYS_SHAPES,
    shapes_for,
)
from repro.configs.registry import ARCH_IDS, get_config, get_both  # noqa: F401
