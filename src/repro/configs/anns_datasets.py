"""FusionANNS index configs for the paper's three billion-scale datasets
(Table 1) plus reduced variants used by tests/benches on CPU."""

from repro.configs.base import ANNSConfig

SIFT1B = ANNSConfig(
    name="sift1b", n_vectors=1_000_000_000, dim=128, dtype="uint8",
    pq_m=32, top_m=64, top_n=512, top_k=10,
)
SPACEV1B = ANNSConfig(
    name="spacev1b", n_vectors=1_000_000_000, dim=100, dtype="int8",
    pq_m=25, top_m=64, top_n=512, top_k=10,
)
DEEP1B = ANNSConfig(
    name="deep1b", n_vectors=1_000_000_000, dim=96, dtype="float32",
    pq_m=24, top_m=64, top_n=512, top_k=10,
)

# Reduced, CPU-runnable index configs (same structure, small N).
SIFT_SMALL = ANNSConfig(
    name="sift-small", n_vectors=20_000, dim=32, dtype="float32",
    pq_m=8, n_posting_fraction=0.02, top_m=16, top_n=128, top_k=10,
    rerank_batch=16, graph_degree=12,
)
SIFT_MEDIUM = ANNSConfig(
    name="sift-medium", n_vectors=100_000, dim=64, dtype="float32",
    pq_m=16, n_posting_fraction=0.01, top_m=32, top_n=256, top_k=10,
    rerank_batch=32, graph_degree=16,
)

DATASETS = {c.name: c for c in
            (SIFT1B, SPACEV1B, DEEP1B, SIFT_SMALL, SIFT_MEDIUM)}
