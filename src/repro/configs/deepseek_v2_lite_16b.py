"""DeepSeek-V2-Lite (16B total / 2.4B active) [arXiv:2405.04434].

MLA: kv_lora_rank=512, qk_nope=128, qk_rope=64, v_head=128, 16 heads.
MoE: 64 routed experts top-6 + 2 shared (assignment header says "MoE 64e
top-6"; the parenthetical "160 routed" matches full V2, not Lite — we follow
the primary 64e spec and arXiv:2405.04434 Lite appendix), moe_d_ff=1408,
first layer dense with d_ff=10944.
"""

from repro.configs.base import LMConfig

CONFIG = LMConfig(
    name="deepseek-v2-lite-16b",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_head=128,
    d_ff=1408,
    vocab_size=102400,
    mla=True,
    kv_lora_rank=512,
    qk_nope_head_dim=128,
    qk_rope_head_dim=64,
    v_head_dim=128,
    moe=True,
    n_experts=64,
    n_shared_experts=2,
    moe_top_k=6,
    moe_d_ff=1408,
    first_k_dense=1,
    dense_d_ff=10944,
)

REDUCED = LMConfig(
    name="deepseek-v2-lite-reduced",
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=4,
    d_head=32,
    d_ff=96,
    vocab_size=512,
    mla=True,
    kv_lora_rank=64,
    qk_nope_head_dim=32,
    qk_rope_head_dim=16,
    v_head_dim=32,
    moe=True,
    n_experts=8,
    n_shared_experts=1,
    moe_top_k=2,
    moe_d_ff=96,
    first_k_dense=1,
    dense_d_ff=256,
)
