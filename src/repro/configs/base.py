"""Config dataclasses + the (arch x shape) cell definitions.

Every assigned architecture gets a module ``repro.configs.<arch_id>`` that
exports ``CONFIG`` (the full published config) and ``REDUCED`` (a tiny config
of the same family for CPU smoke tests).  ``repro.configs.registry`` maps the
``--arch`` ids to them.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab_size: int
    qkv_bias: bool = False
    qk_norm: bool = False
    # Fraction of head dims that receive rotary embedding (ChatGLM "2d" RoPE
    # rotates only the first half of each head).
    rope_fraction: float = 1.0
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    # --- MoE ---
    moe: bool = False
    n_experts: int = 0
    n_shared_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    first_k_dense: int = 0          # deepseek: first k layers use dense FFN
    dense_d_ff: int = 0             # d_ff of those dense layers
    router_scale: float = 1.0       # deepseek routed_scaling_factor
    # --- MLA ---
    mla: bool = False
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0

    @property
    def is_gqa(self) -> bool:
        return self.n_kv_heads < self.n_heads

    def n_params(self) -> int:
        """Analytic parameter count (used for 6*N*D model FLOPs)."""
        d, H, Hk, dh, L, V = (self.d_model, self.n_heads, self.n_kv_heads,
                              self.d_head, self.n_layers, self.vocab_size)
        if self.mla:
            qk_d = self.qk_nope_head_dim + self.qk_rope_head_dim
            attn = (d * H * qk_d                      # W_Q
                    + d * (self.kv_lora_rank + self.qk_rope_head_dim)  # W_DKV
                    + self.kv_lora_rank * H * (self.qk_nope_head_dim
                                               + self.v_head_dim)      # W_UK/UV
                    + H * self.v_head_dim * d)        # W_O
        else:
            attn = d * (H + 2 * Hk) * dh + H * dh * d
            if self.qkv_bias:
                attn += (H + 2 * Hk) * dh
        per_layer = attn
        n_dense = self.first_k_dense if self.moe else L
        if self.moe:
            moe_layers = L - self.first_k_dense
            ffn_moe = 3 * d * self.moe_d_ff * (self.n_experts
                                               + self.n_shared_experts)
            router = d * self.n_experts
            dense_ff = self.dense_d_ff or self.d_ff
            total_ffn = (moe_layers * (ffn_moe + router)
                         + self.first_k_dense * 3 * d * dense_ff)
        else:
            total_ffn = L * 3 * d * self.d_ff
        total = L * per_layer + total_ffn + 2 * V * d + (2 * L + 1) * d
        return int(total)

    def n_active_params(self) -> int:
        """Activated params per token (MoE: only routed top-k + shared)."""
        if not self.moe:
            return self.n_params()
        d, L = self.d_model, self.n_layers
        moe_layers = L - self.first_k_dense
        full = self.n_params()
        all_experts = moe_layers * 3 * d * self.moe_d_ff * self.n_experts
        active = moe_layers * 3 * d * self.moe_d_ff * self.moe_top_k
        return int(full - all_experts + active)


@dataclasses.dataclass(frozen=True)
class GNNConfig:
    name: str
    n_layers: int = 2
    d_hidden: int = 128
    aggregator: str = "mean"
    sample_sizes: Tuple[int, ...] = (25, 10)
    d_feat: int = 602
    n_classes: int = 41


@dataclasses.dataclass(frozen=True)
class RecsysConfig:
    name: str
    kind: str                       # dlrm | wide_deep | bert4rec | mind
    embed_dim: int
    n_sparse: int = 0
    vocab_size: int = 1_000_000     # rows per sparse table (or item vocab)
    n_dense: int = 0
    bot_mlp: Tuple[int, ...] = ()
    top_mlp: Tuple[int, ...] = ()
    mlp: Tuple[int, ...] = ()
    interaction: str = "dot"
    # bert4rec
    n_blocks: int = 0
    n_heads: int = 0
    seq_len: int = 0
    # mind
    n_interests: int = 0
    capsule_iters: int = 0
    hist_len: int = 50
    multi_hot: int = 1              # ids per sparse field (embedding bag size)


@dataclasses.dataclass(frozen=True)
class ANNSConfig:
    """FusionANNS index configuration (paper §4)."""

    name: str
    n_vectors: int
    dim: int
    dtype: str = "float32"           # raw vector dtype on the SSD tier
    pq_m: int = 32                   # sub-spaces (bytes per PQ code)
    pq_nbits: int = 8                # 256 centroids / sub-space
    n_posting_fraction: float = 0.10 # posting lists = 10% of N (paper §4.1)
    replication_eps: float = 0.10    # Eq. 2 epsilon
    max_replicas: int = 8            # paper: each vector in <= 8 clusters
    graph_degree: int = 32           # navigation graph out-degree
    top_m: int = 64                  # nearest posting lists per query
    top_n: int = 256                 # candidates sent to re-ranking
    top_k: int = 10                  # final neighbours
    rerank_batch: int = 32           # mini-batch size (Alg. 1 BatchSize)
    rerank_eps: float = 0.05         # Alg. 1 epsilon (change-rate threshold)
    rerank_beta: int = 2             # Alg. 1 beta (stability count)
    page_bytes: int = 4096           # SSD page
    dram_buffer_pages: int = 1024    # per-query DRAM page buffer


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    """One (architecture x input-shape) dry-run cell."""

    shape_id: str
    step: str                      # train_step | prefill | serve_step | forward
    dims: Dict[str, int]


LM_SHAPES = (
    ShapeCell("train_4k", "train_step", dict(seq_len=4096, global_batch=256)),
    ShapeCell("prefill_32k", "prefill", dict(seq_len=32768, global_batch=32)),
    ShapeCell("decode_32k", "serve_step", dict(seq_len=32768, global_batch=128)),
    ShapeCell("long_500k", "serve_step", dict(seq_len=524288, global_batch=1)),
)

GNN_SHAPES = (
    ShapeCell("full_graph_sm", "train_step",
              dict(n_nodes=2708, n_edges=10556, d_feat=1433, n_classes=7)),
    ShapeCell("minibatch_lg", "train_step",
              dict(n_nodes=232965, n_edges=114615892, batch_nodes=1024,
                   fanout0=15, fanout1=10, d_feat=602, n_classes=41)),
    ShapeCell("ogb_products", "train_step",
              dict(n_nodes=2449029, n_edges=61859140, d_feat=100,
                   n_classes=47)),
    ShapeCell("molecule", "train_step",
              dict(n_nodes=30, n_edges=64, batch=128, d_feat=16, n_classes=2)),
)

RECSYS_SHAPES = (
    ShapeCell("train_batch", "train_step", dict(batch=65536)),
    ShapeCell("serve_p99", "serve_step", dict(batch=512)),
    ShapeCell("serve_bulk", "serve_step", dict(batch=262144)),
    ShapeCell("retrieval_cand", "retrieval", dict(batch=1, n_candidates=1_000_000)),
)


def shapes_for(cfg: Any) -> Tuple[ShapeCell, ...]:
    if isinstance(cfg, LMConfig):
        return LM_SHAPES
    if isinstance(cfg, GNNConfig):
        return GNN_SHAPES
    if isinstance(cfg, RecsysConfig):
        return RECSYS_SHAPES
    raise TypeError(f"no shapes for {type(cfg)}")
