"""MIND [arXiv:1904.08030]: multi-interest capsule network, 4 interests."""

from repro.configs.base import RecsysConfig

CONFIG = RecsysConfig(
    name="mind",
    kind="mind",
    embed_dim=64,
    n_interests=4,
    capsule_iters=3,
    hist_len=50,
    vocab_size=1_048_576,  # 2^20 (~10^6 rows, mesh-divisible)
    interaction="multi-interest",
)

REDUCED = RecsysConfig(
    name="mind-reduced",
    kind="mind",
    embed_dim=16,
    n_interests=2,
    capsule_iters=2,
    hist_len=8,
    vocab_size=512,
    interaction="multi-interest",
)
