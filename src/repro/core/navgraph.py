"""In-memory navigation graph over posting-list centroids (paper §4.1).

SPTAG-flavoured incremental kNN-graph build: vertices are added one by one,
connected to their current top-R nearest, and neighbours back-update under a
max-degree cap.  Search is best-first beam search (the CPU stage ② of the
online pipeline).  A device-side ``lax.while_loop`` variant exists for
completeness (tests prove it matches), but production placement is CPU,
exactly as in the paper."""

from __future__ import annotations

import dataclasses
import heapq
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class NavGraph:
    points: np.ndarray                 # (C, D) centroids
    neighbors: np.ndarray              # (C, R) int32, -1 padded
    entry: int                         # search entry point (medoid-ish)
    # SPTAG pairs the graph with space-partition TREES that provide seeds
    # for traversal; a kNN graph over tight clusters is otherwise a set of
    # disconnected cliques.  Stand-in with the same O(sqrt(C)) lookup and
    # geometric coverage: a 2-level k-means hierarchy over the vertices —
    # query -> nearest super-centroids -> their member vertices as seeds.
    super_centroids: Optional[np.ndarray] = None   # (S, D)
    super_assign: Optional[np.ndarray] = None      # (C,) vertex -> super

    def seed_beam(self, query: np.ndarray, n_super: int = 3,
                  per_super: int = 3) -> np.ndarray:
        if self.super_centroids is None:
            return np.array([self.entry], np.int64)
        ds = np.sum((self.super_centroids - query) ** 2, -1)
        out = [np.array([self.entry], np.int64)]
        for s in np.argsort(ds)[:n_super]:
            members = np.where(self.super_assign == s)[0]
            if not len(members):
                continue
            dm = np.sum((self.points[members] - query) ** 2, -1)
            out.append(members[np.argsort(dm)[:per_super]])
        return np.unique(np.concatenate(out))


def _seed_tree(points: np.ndarray):
    """2-level k-means hierarchy (the SPTAG-tree stand-in)."""
    from repro.core.clustering import _kmeans
    c = len(points)
    s = max(2, int(np.ceil(np.sqrt(c))))
    rng = np.random.default_rng(0)
    supers = _kmeans(rng, points.astype(np.float32), s, iters=6)
    d2 = (np.sum(points ** 2, -1)[:, None] - 2.0 * points @ supers.T
          + np.sum(supers ** 2, -1)[None])
    return supers, np.argmin(d2, -1).astype(np.int32)


def knn_graph_exact(points: np.ndarray, degree: int = 32,
                    chunk: int = 2048) -> NavGraph:
    """Exact kNN graph via chunked brute force (fast path for <=50k points;
    used by the DiskANN-like baseline where graph quality, not build
    algorithm, is what matters)."""
    c = len(points)
    r = min(degree, c - 1)
    neighbors = np.empty((c, r), np.int32)
    norms = np.sum(points ** 2, -1)
    for s in range(0, c, chunk):
        blk = points[s:s + chunk]
        d2 = (np.sum(blk ** 2, -1)[:, None] - 2.0 * blk @ points.T
              + norms[None])
        d2[np.arange(len(blk)), s + np.arange(len(blk))] = np.inf
        idx = np.argpartition(d2, r - 1, axis=1)[:, :r]
        dd = np.take_along_axis(d2, idx, axis=1)
        neighbors[s:s + chunk] = np.take_along_axis(
            idx, np.argsort(dd, axis=1), axis=1)
    entry = int(np.argmin(np.sum(
        (points - points.mean(0, keepdims=True)) ** 2, -1)))
    supers, assign = _seed_tree(points)
    return NavGraph(points=points.astype(np.float32), neighbors=neighbors,
                    entry=entry, super_centroids=supers, super_assign=assign)


def build_navgraph(points: np.ndarray, degree: int = 32,
                   ef_build: int = 64) -> NavGraph:
    """Navigation-graph construction.

    <=50k vertices (every config in this repo; SPANN keeps the centroid
    count at a RAM-friendly fraction of N): exact kNN adjacency — highest
    quality, BLAS-fast.  Beyond that, SPTAG-style incremental insertion
    where each vertex links to its top-``degree`` nearest found by seeded
    graph search over the partial graph (kept for the 100M-centroid scale
    where O(C^2) is impossible)."""
    if len(points) <= 50_000:
        return knn_graph_exact(points.astype(np.float32), degree=degree)
    c, d = points.shape
    r = min(degree, max(c - 1, 1))
    nbrs: List[List[Tuple[float, int]]] = [[] for _ in range(c)]

    def link(u: int, v: int, dist: float) -> None:
        lst = nbrs[u]
        heapq.heappush(lst, (-dist, v))
        if len(lst) > r:
            heapq.heappop(lst)             # drop farthest

    bootstrap = min(c, 2 * r)
    for i in range(1, c):
        if i <= bootstrap:
            cand = np.arange(i)
        else:
            cand = _search_ids(points, nbrs, points[i], ef_build, entry=0)
        dd = np.sum((points[cand] - points[i]) ** 2, -1)
        order = np.argsort(dd)[:r]
        for j in order:
            v, dist = int(cand[j]), float(dd[j])
            link(i, v, dist)
            link(v, i, dist)

    neighbors = np.full((c, r), -1, np.int32)
    for i, lst in enumerate(nbrs):
        ids = [v for _, v in sorted(lst, reverse=True)]
        neighbors[i, :len(ids)] = ids[:r]
    entry = int(np.argmin(np.sum(
        (points - points.mean(0, keepdims=True)) ** 2, -1)))
    supers, assign = _seed_tree(points)
    return NavGraph(points=points, neighbors=neighbors, entry=entry,
                    super_centroids=supers, super_assign=assign)


def _search_ids(points, nbrs_dyn, query, ef, entry=0) -> np.ndarray:
    """Best-first search over the under-construction adjacency (build helper)."""
    visited = {entry}
    d0 = float(np.sum((points[entry] - query) ** 2))
    cand = [(d0, entry)]
    best = [(-d0, entry)]
    while cand:
        dist, u = heapq.heappop(cand)
        if dist > -best[0][0] and len(best) >= ef:
            break
        for _, v in nbrs_dyn[u]:
            if v in visited:
                continue
            visited.add(v)
            dv = float(np.sum((points[v] - query) ** 2))
            if len(best) < ef or dv < -best[0][0]:
                heapq.heappush(cand, (dv, v))
                heapq.heappush(best, (-dv, v))
                if len(best) > ef:
                    heapq.heappop(best)
    return np.array([v for _, v in best], np.int64)


def search(graph: NavGraph, query: np.ndarray, top_m: int,
           ef: Optional[int] = None) -> np.ndarray:
    """CPU best-first beam search -> ids of the top-m nearest centroids
    (online stage ②).  ef defaults to 2*top_m."""
    ef = ef or max(2 * top_m, 32)
    points, neighbors = graph.points, graph.neighbors
    visited = np.zeros(len(points), bool)
    cand: List[Tuple[float, int]] = []
    best: List[Tuple[float, int]] = []
    for entry in graph.seed_beam(query):
        entry = int(entry)
        visited[entry] = True
        d0 = float(np.sum((points[entry] - query) ** 2))
        heapq.heappush(cand, (d0, entry))
        heapq.heappush(best, (-d0, entry))
    while cand:
        dist, u = heapq.heappop(cand)
        if len(best) >= ef and dist > -best[0][0]:
            break
        for v in neighbors[u]:
            if v < 0 or visited[v]:
                continue
            visited[v] = True
            dv = float(np.sum((points[v] - query) ** 2))
            if len(best) < ef or dv < -best[0][0]:
                heapq.heappush(cand, (dv, v))
                heapq.heappush(best, (-dv, v))
                if len(best) > ef:
                    heapq.heappop(best)
    out = sorted(((-nd, v) for nd, v in best))
    return np.array([v for _, v in out[:top_m]], np.int32)


def search_jax(points: jax.Array, neighbors: jax.Array, entry: int,
               query: jax.Array, top_m: int, max_steps: int = 64,
               seeds: Optional[jax.Array] = None):
    """Device-side best-first search (bounded ``lax.while_loop``) keeping a
    fixed-size beam.  Semantically matches ``search`` up to beam ties."""
    c, r = neighbors.shape
    ef = max(2 * top_m, 32)

    def dist_to(idx):
        return jnp.sum((points[idx] - query) ** 2, -1)

    if seeds is not None:
        sd = dist_to(seeds)
        neg, pos = jax.lax.top_k(-sd, min(4, seeds.shape[0]))
        init = jnp.concatenate(
            [seeds[pos].astype(jnp.int32), jnp.asarray([entry], jnp.int32)])
    else:
        init = jnp.asarray([entry], jnp.int32)
    n0 = init.shape[0]
    beam_ids = jnp.full((ef,), entry, jnp.int32).at[:n0].set(init)
    beam_d = jnp.full((ef,), jnp.inf, jnp.float32).at[:n0].set(dist_to(init))
    expanded = jnp.zeros((ef,), bool)
    visited = jnp.zeros((c,), bool).at[init].set(True)

    def cond(state):
        beam_ids, beam_d, expanded, visited, steps = state
        frontier = jnp.logical_and(~expanded, jnp.isfinite(beam_d))
        return jnp.logical_and(steps < max_steps, jnp.any(frontier))

    def body(state):
        beam_ids, beam_d, expanded, visited, steps = state
        masked = jnp.where(expanded, jnp.inf, beam_d)
        u_slot = jnp.argmin(masked)
        u = beam_ids[u_slot]
        expanded = expanded.at[u_slot].set(True)
        nb = neighbors[u]                                    # (R,)
        valid = jnp.logical_and(nb >= 0, ~visited[jnp.maximum(nb, 0)])
        nd = jnp.where(valid, dist_to(jnp.maximum(nb, 0)), jnp.inf)
        visited = visited.at[jnp.maximum(nb, 0)].set(
            jnp.logical_or(visited[jnp.maximum(nb, 0)], valid))
        # merge beam with the R candidates, keep best ef
        all_d = jnp.concatenate([beam_d, nd])
        all_i = jnp.concatenate([beam_ids, nb.astype(jnp.int32)])
        all_e = jnp.concatenate([expanded, jnp.zeros((r,), bool)])
        neg, pos = jax.lax.top_k(-all_d, ef)
        return (all_i[pos], -neg, all_e[pos], visited, steps + 1)

    beam_ids, beam_d, expanded, visited, _ = jax.lax.while_loop(
        cond, body, (beam_ids, beam_d, expanded, visited, 0))
    neg, pos = jax.lax.top_k(-beam_d, top_m)
    return beam_ids[pos], -neg
