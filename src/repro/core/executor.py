"""Unified query execution: ``QueryPlan`` -> ``QueryExecutor``.

DESIGN
======
Every public query entry point on :class:`~repro.core.engine.FusionANNSIndex`
(``query``, ``batch_query``, ``query_batch_fused``) and the serving
front-end (``serve.anns_service.BatchingANNSService``) runs the SAME stage
list, parameterized only by the batch window:

  ① graph-traverse   navigation graph over centroids (DRAM tier, host)
  ② collect + dedup  posting-list vector-IDs, tombstone filter (host)
  ③ union dedup      inter-query candidate dedup across the window — the
                     paper's §4.3 redundancy insight applied to the HBM scan
  ④ LUT build        per-query ADC tables on the accelerator
  ⑤ sharded ADC scan PQ codes row-sharded across the device mesh
                     (``core.distributed``); each shard scans its rows,
                     takes a per-shard top-n, and only (distance, id) pairs
                     cross the interconnect — §4.2's "IDs only" discipline
                     across devices
  ⑥ top-n merge      global merge of shard-local top-ns + host-side
                     (distance, id) lexicographic ordering, so sharded and
                     single-device scans return bit-identical rankings
  ⑦ heuristic rerank Algorithm 1 against the SSD tier (host)

Tier placement (unchanged from engine.py): navigation graph + posting-list
IDs in host numpy ("DRAM"); PQ codes + codebooks in jax arrays ("HBM",
row-sharded over the ``corpus`` mesh axes when a mesh is attached); raw
vectors behind the 4 KB-page SSD simulator.

Windows + overlap: ``QueryPlan.window`` splits a batch into fixed-size scan
windows; ``overlap_rerank=True`` dispatches window t+1's (async) device
scan before re-ranking window t on the host, overlapping rerank I/O with
the next scan — the executor-level analogue of the paper's CPU/GPU
pipelining.

Per-query accounting is shared: a window of size B attributes ``u = |union|``
scanned candidates and ``4u/B`` host->device bytes to each member, so
``query`` (B=1) and the fused paths report through one ``QueryStats``
schema.
"""

from __future__ import annotations

import dataclasses
import time
from typing import TYPE_CHECKING, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import pq
from repro.core.rerank import heuristic_rerank
from repro.models.layers import ShardCtx

if TYPE_CHECKING:  # pragma: no cover
    from repro.core.engine import FusionANNSIndex


@dataclasses.dataclass
class QueryStats:
    ios: int
    pages_requested: int
    buffer_hits: int
    ssd_bytes: int
    h2d_bytes: int               # vector-IDs sent CPU -> accelerator
    candidates_scanned: int      # PQ distance calculations (union, per window)
    rerank_batches: int
    rerank_scored: int
    early_stopped: bool
    t_graph: float = 0.0
    t_scan: float = 0.0
    t_rerank: float = 0.0


@dataclasses.dataclass
class QueryResult:
    ids: np.ndarray
    dists: np.ndarray
    stats: QueryStats


@dataclasses.dataclass(frozen=True)
class QueryPlan:
    """Per-run knobs for one pass through the unified stage list."""

    k: int
    top_m: int
    top_n: int
    rerank_batch: int = 32
    rerank_eps: float = 0.05
    rerank_beta: int = 2
    disable_early_stop: bool = False
    window: int = 0              # scan-window size; 0 = whole batch at once
    overlap_rerank: bool = False  # overlap window t rerank with t+1 scan

    @staticmethod
    def from_config(cfg, *, k: Optional[int] = None,
                    top_m: Optional[int] = None, top_n: Optional[int] = None,
                    **kw) -> "QueryPlan":
        return QueryPlan(k=k or cfg.top_k, top_m=top_m or cfg.top_m,
                         top_n=top_n or cfg.top_n,
                         rerank_batch=cfg.rerank_batch,
                         rerank_eps=cfg.rerank_eps, rerank_beta=cfg.rerank_beta,
                         **kw)


@dataclasses.dataclass
class _Window:
    """One dispatched scan window (device work possibly still in flight)."""

    queries: np.ndarray
    per_q: List[np.ndarray]      # stage ② ids per query
    union: np.ndarray            # stage ③ deduped candidate union
    vals: jax.Array              # (B, tk) masked top-n distances
    pos: jax.Array               # (B, tk) positions into the padded bucket
    t_graph: float
    t_scan_host: float           # host-side LUT/gather/dispatch time


class QueryExecutor:
    """Runs the stage list against one index, optionally mesh-sharded."""

    def __init__(self, index: "FusionANNSIndex",
                 ctx: Optional[ShardCtx] = None):
        self.index = index
        self.ctx = ctx if ctx is not None else ShardCtx()
        self._placed: Optional[jax.Array] = None
        self._placed_src = None

    # ------------------------------------------------------------- sharding
    def attach_mesh(self, mesh) -> "QueryExecutor":
        """Row-shard the HBM tier (PQ codes) over ``mesh``'s corpus axes."""
        from repro.sharding.spec import rules_for_mesh
        self.ctx = ShardCtx(mesh=mesh, rules=rules_for_mesh(mesh))
        self._placed = None          # free the previous mesh's placement
        self._placed_src = None
        return self

    def _n_shards(self) -> int:
        if self.ctx.mesh is None:
            return 1
        axes = self.ctx.rules.corpus
        axes_t = (axes,) if isinstance(axes, str) else tuple(axes)
        n = 1
        for a in axes_t:
            n *= self.ctx.mesh.shape[a]
        return n

    def _device_codes(self) -> jax.Array:
        """HBM-tier codes; placed row-sharded once per codes version (insert
        invalidates the placement by rebinding ``index.codes``)."""
        codes = self.index.codes
        if self.ctx.mesh is None:
            return codes
        if self._placed_src is not codes:
            from jax.sharding import NamedSharding, PartitionSpec as P
            shards = self._n_shards()
            pad = (-codes.shape[0]) % shards
            placed = codes if not pad else jnp.concatenate(
                [codes, jnp.zeros((pad, codes.shape[1]), codes.dtype)],
                axis=0)
            self._placed = jax.device_put(placed, NamedSharding(
                self.ctx.mesh, P(self.ctx.rules.corpus, None)))
            self._placed_src = codes
        return self._placed

    # --------------------------------------------------------------- stages
    def _dispatch(self, queries: np.ndarray, plan: QueryPlan) -> _Window:
        """Stages ①-⑥: host traversal + async device scan for one window."""
        from repro.core.distributed import sharded_adc_topn_window
        idx = self.index
        t0 = time.perf_counter()
        per_q = [idx.candidate_ids(q, plan.top_m) for q in queries]
        union = (np.unique(np.concatenate(per_q)).astype(np.int64)
                 if sum(len(p) for p in per_q) else np.zeros((0,), np.int64))
        t1 = time.perf_counter()

        u = len(union)
        shards = self._n_shards()
        bucket = max(64, shards, 1 << int(np.ceil(np.log2(max(u, 1)))))
        bucket += (-bucket) % shards
        padded = np.zeros(bucket, np.int64)
        padded[:u] = union
        # per-query membership: only a query's own candidates compete in its
        # top-n (identical semantics at every window size)
        mask = np.zeros((len(queries), bucket), bool)
        for qi, ids_q in enumerate(per_q):
            mask[qi, np.searchsorted(union, ids_q)] = True

        luts = pq.adc_lut_batch(idx.codebook, jnp.asarray(
            np.stack([idx._lut_query(np.asarray(q, np.float32))
                      for q in queries])))
        cand = jnp.take(self._device_codes(), jnp.asarray(padded), axis=0)
        mask_dev = jnp.asarray(mask)
        if self.ctx.mesh is not None:
            from jax.sharding import NamedSharding, PartitionSpec as P
            corpus = self.ctx.rules.corpus
            cand = jax.device_put(cand, NamedSharding(
                self.ctx.mesh, P(corpus, None)))
            mask_dev = jax.device_put(mask_dev, NamedSharding(
                self.ctx.mesh, P(None, corpus)))
        vals, pos = sharded_adc_topn_window(
            cand, luts, mask_dev, min(plan.top_n, bucket), self.ctx,
            use_kernel=idx.use_kernel)
        return _Window(queries=queries, per_q=per_q, union=union,
                       vals=vals, pos=pos, t_graph=t1 - t0,
                       t_scan_host=time.perf_counter() - t1)

    def _finish(self, w: _Window, plan: QueryPlan) -> List[QueryResult]:
        """Stages ⑥-⑦: block on the scan, merge, re-rank against the SSD."""
        idx = self.index
        B = len(w.queries)
        u = len(w.union)
        t0 = time.perf_counter()
        vals = np.asarray(w.vals)          # blocks until the scan lands
        pos = np.asarray(w.pos)
        # host dispatch time + blocking wait: under overlap_rerank the gap
        # between dispatch and finish belongs to the PREVIOUS window's
        # rerank, so wall-clock-since-dispatch would double-count it
        t_scan = w.t_scan_host + (time.perf_counter() - t0)
        out: List[QueryResult] = []
        for qi, q in enumerate(w.queries):
            good = np.isfinite(vals[qi])
            ids_sel = w.union[pos[qi][good]]
            d_sel = vals[qi][good]
            # ascending (distance, id): makes sharded == unsharded exactly
            order = np.lexsort((ids_sel, d_sel))
            n_eff = min(plan.top_n, len(w.per_q[qi]))
            order_ids = ids_sel[order][:n_eff]
            t2 = time.perf_counter()
            rr = heuristic_rerank(
                np.asarray(q, np.float32), order_ids, idx.ssd, plan.k,
                batch_size=plan.rerank_batch, eps=plan.rerank_eps,
                beta=plan.rerank_beta,
                disable_early_stop=plan.disable_early_stop)
            stats = QueryStats(
                ios=rr.io.ios, pages_requested=rr.io.pages_requested,
                buffer_hits=rr.io.buffer_hits, ssd_bytes=rr.io.bytes_read,
                h2d_bytes=4 * u // max(B, 1),    # amortised union transfer
                candidates_scanned=u,            # union, ONCE per window
                rerank_batches=rr.batches_run,
                rerank_scored=rr.candidates_scored,
                early_stopped=rr.early_stopped,
                t_graph=w.t_graph / max(B, 1), t_scan=t_scan / max(B, 1),
                t_rerank=time.perf_counter() - t2)
            out.append(QueryResult(ids=rr.ids, dists=rr.dists, stats=stats))
        return out

    # ------------------------------------------------------------------ run
    def run(self, queries: np.ndarray, plan: QueryPlan) -> List[QueryResult]:
        queries = np.atleast_2d(np.asarray(queries, np.float32))
        if not len(queries):
            return []
        W = plan.window or len(queries)
        results: List[QueryResult] = []
        pending: Optional[_Window] = None
        for s in range(0, len(queries), W):
            dispatched = self._dispatch(queries[s:s + W], plan)
            if pending is not None:          # overlap: t+1 scan in flight
                results.extend(self._finish(pending, plan))
                pending = None
            if plan.overlap_rerank:
                pending = dispatched
            else:
                results.extend(self._finish(dispatched, plan))
        if pending is not None:
            results.extend(self._finish(pending, plan))
        return results

    def run_one(self, query: np.ndarray, plan: QueryPlan) -> QueryResult:
        return self.run(np.asarray(query, np.float32)[None], plan)[0]
