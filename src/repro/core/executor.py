"""Unified query execution: ``QueryPlan`` -> ``QueryExecutor``.

DESIGN
======
Every public query entry point on :class:`~repro.core.engine.FusionANNSIndex`
(``query``, ``batch_query``, ``query_batch_fused``) and the serving
front-end (``serve.anns_service.BatchingANNSService``) runs the SAME stage
list, parameterized only by the batch window:

  ① graph-traverse   navigation graph over centroids (DRAM tier, host)
  ② collect + dedup  posting-list vector-IDs, tombstone filter (host)
  ③ union dedup      inter-query candidate dedup across the window — the
                     paper's §4.3 redundancy insight applied to the HBM scan
  ④ LUT build        per-query ADC tables on the accelerator
  ⑤ sharded ADC scan PQ codes row-sharded across the device mesh
                     (``core.distributed``); each shard scans its rows,
                     takes a per-shard top-n, and only (distance, id) pairs
                     cross the interconnect — §4.2's "IDs only" discipline
                     across devices
  ⑥ top-n merge      global merge of shard-local top-ns + host-side
                     (distance, id) lexicographic ordering, so sharded and
                     single-device scans return bit-identical rankings
  ⑦ heuristic rerank Algorithm 1 against the SSD tier (host)

Tier placement (unchanged from engine.py): navigation graph + posting-list
IDs in host numpy ("DRAM"); PQ codes + codebooks in jax arrays ("HBM",
row-sharded over the ``corpus`` mesh axes when a mesh is attached); raw
vectors behind the 4 KB-page SSD simulator.

Windows + pipelining: ``QueryPlan.window`` splits a batch into fixed-size
scan windows.  The in-flight machinery is an explicit ``_InflightQueue``
of dispatched-but-unretired windows with a configurable depth: depth d
keeps the scans of windows t+1..t+d in flight (jax async dispatch) while
the host re-ranks window t — the executor-level analogue of the paper's
CPU/GPU pipelining.  ``overlap_rerank=True`` is the legacy spelling of
depth 2; ``inflight_depth`` sets it directly.

Submission is the primary API (DESIGN.md §3): ``submit(queries, plan)``
returns a :class:`~repro.core.futures.BatchTicket` immediately after host
traversal + device dispatch of the first ``depth`` windows; per-query
:class:`~repro.core.futures.QueryFuture`\\ s expose ``done()/result()/
cancel()``.  ``run()`` is submit-then-wait, so every legacy path returns
bit-identical ids.  Per-request knobs ride along as ``PlanOverrides``:
a batched window honors heterogeneous ``k``/``top_n``/deadlines without
splitting the scan (the scan uses the window-max ``top_n``; each query's
merge + re-rank applies its own effective plan).

Per-query accounting is shared: a window of size B attributes ``u = |union|``
scanned candidates and ``4u/B`` host->device bytes to each member, so
``query`` (B=1) and the fused paths report through one ``QueryStats``
schema.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import TYPE_CHECKING, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.concurrency.witness import make_lock
from repro.core import pq
from repro.core.filters import Predicate
from repro.core.futures import BatchTicket, DeadlineExceeded, QueryFuture
from repro.core.rerank import heuristic_rerank
from repro.models.layers import ShardCtx

if TYPE_CHECKING:  # pragma: no cover
    from repro.core.engine import FusionANNSIndex


# additive QueryStats counters accumulated per served response — the single
# source of truth for every backend's ``stats_rollup()`` (executor, batching
# service, replica router), so the three can't drift.  Canonical home is
# here next to the schema; ``serve.anns_service`` re-exports it.
QUERY_STATS_FIELDS = ("ios", "pages_requested", "buffer_hits", "ssd_bytes",
                      "h2d_bytes", "candidates_scanned",
                      "candidates_prefilter", "rerank_batches",
                      "rerank_scored")


@dataclasses.dataclass
class QueryStats:
    ios: int
    pages_requested: int
    buffer_hits: int
    ssd_bytes: int
    h2d_bytes: int               # vector-IDs sent CPU -> accelerator
    candidates_scanned: int      # PQ distance calculations (union, per window)
    candidates_prefilter: int    # union size BEFORE the predicate filter —
    #                              scanned/prefilter is the observed
    #                              selectivity, proving filtering happened
    #                              at collection, not after top-k
    rerank_batches: int
    rerank_scored: int
    early_stopped: bool
    t_graph: float = 0.0
    t_scan: float = 0.0
    t_rerank: float = 0.0


@dataclasses.dataclass
class QueryResult:
    ids: np.ndarray
    dists: np.ndarray
    stats: QueryStats


@dataclasses.dataclass(frozen=True)
class QueryPlan:
    """Per-run knobs for one pass through the unified stage list."""

    k: int
    top_m: int
    top_n: int
    rerank_batch: int = 32
    rerank_eps: float = 0.05
    rerank_beta: int = 2
    disable_early_stop: bool = False
    window: int = 0              # scan-window size; 0 = whole batch at once
    overlap_rerank: bool = False  # legacy spelling of inflight_depth=2
    inflight_depth: int = 0      # dispatched windows in flight; 0 = auto
    deadline_s: Optional[float] = None  # relative to submit(); None = never
    fused: bool = False          # stage ④⑤⑥ in one LUT→ADC→top-k pipeline
    lut_int8: bool = False       # fig10 accuracy level: int8 ADC tables
    # metadata predicate (core/filters.py) applied at candidate collection
    # — stage ②/⑤ row lists shrink BEFORE the ADC scan (DESIGN.md §11)
    filter: Optional[Predicate] = None

    @staticmethod
    def from_config(cfg, *, k: Optional[int] = None,
                    top_m: Optional[int] = None, top_n: Optional[int] = None,
                    **kw) -> "QueryPlan":
        # explicit ``is None`` so k=0 / top_n=0 are honored, not conflated
        # with "use the config default"
        return QueryPlan(k=cfg.top_k if k is None else k,
                         top_m=cfg.top_m if top_m is None else top_m,
                         top_n=cfg.top_n if top_n is None else top_n,
                         rerank_batch=cfg.rerank_batch,
                         rerank_eps=cfg.rerank_eps, rerank_beta=cfg.rerank_beta,
                         **kw)

    def override(self, ov: Optional["PlanOverrides"] = None,
                 **kw) -> "QueryPlan":
        """Layered plan merge: non-None fields of ``ov`` (then ``kw``) win.
        Explicit zeros are honored; only ``None`` means "keep the base"."""
        merged = {}
        if ov is not None:
            merged.update({f.name: getattr(ov, f.name)
                           for f in dataclasses.fields(ov)})
        merged.update(kw)
        return dataclasses.replace(
            self, **{name: v for name, v in merged.items() if v is not None})

    def effective_depth(self) -> int:
        """In-flight window depth: explicit ``inflight_depth`` wins; else
        the legacy ``overlap_rerank`` flag maps to depth 2 (one window
        re-ranking while one scan is in flight)."""
        if self.inflight_depth:
            return max(1, self.inflight_depth)
        return 2 if self.overlap_rerank else 1


@dataclasses.dataclass(frozen=True)
class PlanOverrides:
    """Per-request layer merged onto a window's base :class:`QueryPlan`.

    Only the knobs that make sense per-query inside a shared scan window:
    the scan itself runs once at the window-max ``top_n``; ``k``/``top_n``
    shape each query's merge + re-rank, ``top_m`` its graph traversal, and
    ``deadline_s`` (relative to ``submit()``) bounds when its re-rank may
    still start."""

    k: Optional[int] = None
    top_m: Optional[int] = None
    top_n: Optional[int] = None
    deadline_s: Optional[float] = None
    filter: Optional[Predicate] = None

    def merge_into(self, plan: QueryPlan) -> QueryPlan:
        return plan.override(self)


@dataclasses.dataclass
class _Window:
    """One dispatched scan window (device work possibly still in flight)."""

    queries: np.ndarray
    plans: List[QueryPlan]       # effective (override-merged) plan per query
    per_q: List[np.ndarray]      # stage ② ids per query
    union: np.ndarray            # stage ③ deduped candidate union
    vals: jax.Array              # (B, tk) masked top-n distances
    pos: jax.Array               # (B, tk) positions into the padded bucket
    t_graph: float
    t_scan_host: float           # host-side LUT/gather/dispatch time
    start: int = 0               # global index of this window's first query
    wi: int = 0                  # window index within the ticket
    ids_global: bool = False     # fused path: ``pos`` holds physical row ids
    prefilter: int = 0           # union size before the predicate filter
    # the IndexView pinned at dispatch (DESIGN.md §10): candidate
    # collection, the scan, re-rank, and the delta merge in
    # ``_finish_into`` all read THIS epoch's binding, so a concurrent
    # insert/delete/compaction can never tear a window mid-pipeline
    view: Optional[object] = None


class _InflightQueue:
    """Queue of dispatched-but-unretired windows, bounded by depth.

    Depth 1 is the fully synchronous executor; depth d keeps up to d device
    scans in flight while the host re-ranks the oldest window — the
    explicit home of the pipelining that PR 1 buried inside ``run()``.

    Thread-safety (PR 3, re-ranked PR 9): every method must run under
    ``self._lock`` (rank ``inflight``, one level ABOVE the ticket's
    bookkeeping lock).  Callers acquire it first and nest the ticket
    lock's ``busy`` accounting INSIDE the inflight critical section —
    descending per the hierarchy — so a stall-checking
    ``BatchTicket.wait()`` can never observe ``busy == 0`` while a
    window sits claimed-but-uncounted between the two locks.  Two-phase
    dispatch keeps the slow host traversal OUT of both locks:
    ``reserve()`` claims a depth slot (counted by ``full()``),
    ``commit(w)`` fills it, keeping the queue ordered by window index
    even when a pump thread and a ticker dispatch concurrently.
    ``pop_ready()`` removes ANY window whose scan has landed — the
    out-of-order retirement path — while ``pop()`` stays FIFO for the
    blocking pump."""

    def __init__(self, depth: int):
        self.depth = max(1, depth)
        self._lock = make_lock("inflight")
        self._q: deque = deque()         # guarded-by: _lock
        self._reserved = 0               # guarded-by: _lock

    def __len__(self) -> int:            # holds: _lock
        return len(self._q)

    def full(self) -> bool:              # holds: _lock
        return len(self._q) + self._reserved >= self.depth

    def reserve(self) -> None:           # holds: _lock
        self._reserved += 1

    def cancel_reservation(self) -> None:    # holds: _lock
        self._reserved -= 1

    def commit(self, w: _Window) -> None:    # holds: _lock
        """Fill a reserved slot, keeping windows ordered by ``wi``."""
        self._reserved -= 1
        i = len(self._q)
        while i > 0 and self._q[i - 1].wi > w.wi:
            i -= 1
        self._q.insert(i, w)

    def head(self) -> _Window:           # holds: _lock
        return self._q[0]

    def pop(self) -> _Window:            # holds: _lock
        return self._q.popleft()

    def pop_ready(self, ready) -> Optional[_Window]:    # holds: _lock
        """Remove and return the first window (any position) whose scan
        has landed, or None."""
        for i, w in enumerate(self._q):
            if ready(w):
                del self._q[i]
                return w
        return None


class QueryExecutor:
    """Runs the stage list against one index, optionally mesh-sharded."""

    def __init__(self, index: "FusionANNSIndex",
                 ctx: Optional[ShardCtx] = None, *, mesh=None):
        self.index = index
        # serializes stage ①-⑥ host work (traversal + LUT + device dispatch)
        # across threads: a pump thread and a ticker may both refill depth
        # slots, and the placement cache write must not race.  Created
        # before attach_mesh below, which takes it.
        self._dispatch_lock = make_lock("executor")
        # Backend-protocol state (DESIGN.md §6): the executor is the
        # queueless backend — submit dispatches immediately, retirement is
        # caller-driven — but it reports through the same rollup schema as
        # the service and the router
        self._backend_lock = make_lock("executor")
        self.ctx = ctx if ctx is not None else ShardCtx()
        self._placed: Optional[jax.Array] = None    # guarded-by: _dispatch_lock
        self._placed_src = None                     # guarded-by: _dispatch_lock
        if mesh is not None:
            self.attach_mesh(mesh)
        self._request_tickets: List[BatchTicket] = []   # guarded-by: _backend_lock
        self._next_rid = 0                          # guarded-by: _backend_lock
        # responses served since the last drain(); bounded like the
        # latency window so a long-lived caller that only ever reads
        # futures (never drains) stays O(1) memory
        self._undrained: deque = deque(maxlen=8192)     # guarded-by: _backend_lock
        self._latencies: deque = deque(maxlen=8192)     # guarded-by: _backend_lock
        self.query_stats = dict.fromkeys(QUERY_STATS_FIELDS, 0)  # guarded-by: _backend_lock
        self.query_stats["served"] = 0

    # locks are not deepcopy/pickle-able (``fresh_index`` deep-copies the
    # engine, which may carry a cached executor); a copy gets its own locks
    # and drops in-flight request tickets (their pump closures don't copy)
    def __getstate__(self):
        state = self.__dict__.copy()
        state.pop("_dispatch_lock", None)
        state.pop("_backend_lock", None)
        state.pop("_request_tickets", None)
        state.pop("_planner", None)        # owns a lock; rebuilt lazily
        return state

    def __setstate__(self, state):
        self.__dict__.update(state)
        self._dispatch_lock = make_lock("executor")
        self._backend_lock = make_lock("executor")
        self._request_tickets = []

    # ----------------------------------------------------------- adaptive
    @property
    def planner(self):
        """Lazy deadline-adaptive accuracy resolver (DESIGN.md §11):
        observes served ``QueryStats`` and suggests per-request
        ``top_m``/``top_n`` overrides that the perf model predicts meet a
        deadline.  Created on first use so non-adaptive serving pays
        nothing; its own lock is ``executor``-ranked, and ``observe()``
        must never be called while holding another executor-rank lock."""
        pl = getattr(self, "_planner", None)
        if pl is None:
            from repro.core.perf_model import AdaptivePlanner
            dim = int(self.index.ssd.vectors.shape[1])
            pl = AdaptivePlanner(self.index.cfg, dim=dim)
            self._planner = pl
        return pl

    # ------------------------------------------------------------- sharding
    def attach_mesh(self, mesh) -> "QueryExecutor":
        """Row-shard the HBM tier (PQ codes) over ``mesh``'s corpus axes.

        ``mesh`` may be a SUB-mesh — a disjoint device group carved from a
        larger mesh via ``launch.mesh.split_mesh`` (multi-replica serving:
        each replica's executor scans its own group, so concurrent
        replicas never contend for a chip).  Every device operand is
        committed to the mesh at dispatch, so nothing leaks onto devices
        outside the group."""
        from repro.sharding.spec import rules_for_mesh
        rules = rules_for_mesh(mesh)
        # a router recarve may retarget this executor while a pump thread
        # is mid-dispatch: the ctx + placement-cache swap must not
        # interleave with a _device_codes() read of the old placement
        with self._dispatch_lock:
            self.ctx = ShardCtx(mesh=mesh, rules=rules)
            self._placed = None      # free the previous mesh's placement
            self._placed_src = None
        return self

    def _n_shards(self) -> int:      # holds: _dispatch_lock
        if self.ctx.mesh is None:
            return 1
        axes = self.ctx.rules.corpus
        axes_t = (axes,) if isinstance(axes, str) else tuple(axes)
        n = 1
        for a in axes_t:
            n *= self.ctx.mesh.shape[a]
        return n

    def _device_codes(self, codes: jax.Array) -> jax.Array:  # holds: _dispatch_lock
        """HBM-tier placement of the pinned view's sealed codes, row-sharded
        once per codes version.  Only compaction rebinds the code array
        under the segmented index — delta inserts no longer invalidate the
        placement, so streaming ingest stops thrashing the HBM cache."""
        if self.ctx.mesh is None:
            return codes
        if self._placed_src is not codes:
            from jax.sharding import NamedSharding, PartitionSpec as P
            shards = self._n_shards()
            pad = (-codes.shape[0]) % shards
            placed = codes if not pad else jnp.concatenate(
                [codes, jnp.zeros((pad, codes.shape[1]), codes.dtype)],
                axis=0)
            self._placed = jax.device_put(placed, NamedSharding(
                self.ctx.mesh, P(self.ctx.rules.corpus, None)))
            self._placed_src = codes
        return self._placed

    # --------------------------------------------------------------- stages
    def _dispatch(self, queries: np.ndarray,
                  plans: Sequence[QueryPlan]) -> _Window:  # holds: _dispatch_lock
        """Stages ①-⑥: host traversal + async device scan for one window.

        Heterogeneous per-query plans share the window's scan: traversal
        uses each query's ``top_m``; the scan runs once at the window-max
        ``top_n`` and each query truncates to its own at merge time."""
        from repro.core.distributed import sharded_adc_topn_window
        idx = self.index
        # pin ONE epoch's consistent multi-tier binding for the whole
        # window (DESIGN.md §10): everything below — traversal, gather,
        # scan, and later the re-rank + delta merge — reads this view
        view = idx.view()
        t0 = time.perf_counter()
        # predicate filtering happens HERE, inside candidate collection:
        # per_q holds only matching ids, so the scan below never spends
        # ADC work on a row the filter would discard.  The pre-filter
        # union size rides along as the selectivity witness.
        pairs = [view.collect_candidates(q, p.top_m, filt=p.filter)
                 for q, p in zip(queries, plans)]
        per_q = [p[0] for p in pairs]
        union = (np.unique(np.concatenate(per_q)).astype(np.int64)
                 if sum(len(p) for p in per_q) else np.zeros((0,), np.int64))
        if any(p.filter is not None for p in plans):
            pre_lists = [p[1] for p in pairs]
            prefilter = (len(np.unique(np.concatenate(pre_lists)))
                         if sum(len(p) for p in pre_lists) else 0)
        else:
            prefilter = len(union)
        t1 = time.perf_counter()

        if plans[0].fused:
            return self._dispatch_fused(queries, plans, per_q, union,
                                        view=view, t_graph=t1 - t0,
                                        prefilter=prefilter)
        u = len(union)
        shards = self._n_shards()
        bucket = max(64, shards, 1 << int(np.ceil(np.log2(max(u, 1)))))
        bucket += (-bucket) % shards
        # physical code rows for the gather: ids and rows diverge once a
        # seal-time purge has run (view.row_of maps id -> row; union never
        # contains a purged id because the tombstone filter ran first)
        padded = np.zeros(bucket, np.int64)
        padded[:u] = view.row_of[union]
        # per-query membership: only a query's own candidates compete in its
        # top-n (identical semantics at every window size)
        mask = np.zeros((len(queries), bucket), bool)
        for qi, ids_q in enumerate(per_q):
            mask[qi, np.searchsorted(union, ids_q)] = True

        luts = pq.adc_lut_batch(idx.codebook, jnp.asarray(
            np.stack([idx._lut_query(np.asarray(q, np.float32))
                      for q in queries])))
        cand = jnp.take(self._device_codes(view.codes), jnp.asarray(padded),
                        axis=0)
        mask_dev = jnp.asarray(mask)
        if self.ctx.mesh is not None:
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.core.distributed import replicate_to_mesh
            corpus = self.ctx.rules.corpus
            cand = jax.device_put(cand, NamedSharding(
                self.ctx.mesh, P(corpus, None)))
            mask_dev = jax.device_put(mask_dev, NamedSharding(
                self.ctx.mesh, P(None, corpus)))
            # commit the LUTs too: on a SUB-mesh an uncommitted operand
            # sits on the process default device, which may belong to a
            # sibling replica's group — compute must follow THIS mesh
            luts = replicate_to_mesh(luts, self.ctx)
        scan_top_n = max(p.top_n for p in plans)
        vals, pos = sharded_adc_topn_window(
            cand, luts, mask_dev, min(scan_top_n, bucket), self.ctx,
            use_kernel=idx.use_kernel)
        return _Window(queries=queries, plans=list(plans), per_q=per_q,
                       union=union, vals=vals, pos=pos, t_graph=t1 - t0,
                       t_scan_host=time.perf_counter() - t1, view=view,
                       prefilter=prefilter)

    def _dispatch_fused(self, queries: np.ndarray,
                        plans: Sequence[QueryPlan], per_q, union, *,
                        view, t_graph: float,
                        prefilter: int = 0) -> _Window:  # holds: _dispatch_lock
        """Fused form of stages ④⑤⑥ (``plan.fused``): one LUT→ADC→top-k
        pipeline per shard over per-query candidate ROW LISTS.  No union
        bucket, membership mask, or candidate gather ever materialises —
        the scan reads the resident HBM codes directly and only (distance,
        global-id) pairs come back.  Trades the §4.3 inter-query dedup of
        the scan itself for one dispatch; stats keep ``candidates_scanned``
        = |union| so the two paths report through one schema."""
        from repro.core.distributed import (replicate_to_mesh,
                                            sharded_adc_topn_rows)
        idx = self.index
        t1 = time.perf_counter()
        maxlen = max((len(p) for p in per_q), default=0)
        S = max(64, 1 << int(np.ceil(np.log2(max(maxlen, 1)))))
        rows = np.full((len(queries), S), -1, np.int32)
        for qi, ids_q in enumerate(per_q):
            # candidate ids are np.unique'd => ascending, and row_of is
            # strictly increasing over live ids, so the physical row lists
            # stay ascending — pinning top-k tie-breaks to
            # smallest-row == smallest-id, same as the dense path
            rows[qi, :len(ids_q)] = view.row_of[ids_q]
        qrot = jnp.asarray(np.stack(
            [idx._lut_query(np.asarray(q, np.float32)) for q in queries]))
        rows_dev = jnp.asarray(rows)
        codebooks = idx.codebook.codebooks
        if self.ctx.mesh is not None:
            qrot = replicate_to_mesh(qrot, self.ctx)
            rows_dev = replicate_to_mesh(rows_dev, self.ctx)
            codebooks = replicate_to_mesh(codebooks, self.ctx)
        scan_top_n = max(p.top_n for p in plans)
        vals, gids = sharded_adc_topn_rows(
            self._device_codes(view.codes), qrot, codebooks, rows_dev,
            min(scan_top_n, S), self.ctx, use_kernel=idx.use_kernel,
            lut_int8=plans[0].lut_int8)
        return _Window(queries=queries, plans=list(plans), per_q=per_q,
                       union=union, vals=vals, pos=gids, t_graph=t_graph,
                       t_scan_host=time.perf_counter() - t1,
                       ids_global=True, view=view, prefilter=prefilter)

    def _finish_into(self, w: _Window, futures: Sequence[QueryFuture],
                     deadlines: Sequence[Optional[float]]) -> None:
        """Stages ⑥-⑦: block on the scan, merge, re-rank against the SSD,
        and resolve ``futures[w.start + qi]`` per query.  Cancelled futures
        skip their re-rank; expired deadlines resolve to
        :class:`~repro.core.futures.DeadlineExceeded` instead of starting
        one."""
        idx = self.index
        B = len(w.queries)
        u = len(w.union)
        t0 = time.perf_counter()
        vals = np.asarray(w.vals)          # blocks until the scan lands
        pos = np.asarray(w.pos)
        # host dispatch time + blocking wait: with depth > 1 the gap
        # between dispatch and finish belongs to the PREVIOUS windows'
        # rerank, so wall-clock-since-dispatch would double-count it
        t_scan = w.t_scan_host + (time.perf_counter() - t0)
        for qi, q in enumerate(w.queries):
            fut = futures[w.start + qi]
            if fut.done():                 # cancelled while queued/in flight
                continue
            dl = deadlines[w.start + qi]
            if dl is not None and time.perf_counter() > dl:
                fut._set_exception(DeadlineExceeded(
                    f"deadline passed before re-rank of query "
                    f"{w.start + qi}"))
                continue
            p = w.plans[qi]
            good = np.isfinite(vals[qi])
            # fused windows return physical code rows directly (mapped
            # back to global ids through the pinned view); dense windows
            # return positions into the padded candidate bucket, whose
            # backing ``union`` already holds global ids
            ids_sel = (w.view.id_of[pos[qi][good]] if w.ids_global
                       else w.union[pos[qi][good]])
            d_sel = vals[qi][good]
            # ascending (distance, id): makes sharded == unsharded exactly
            order = np.lexsort((ids_sel, d_sel))
            n_eff = min(p.top_n, len(w.per_q[qi]))
            order_ids = ids_sel[order][:n_eff]
            t2 = time.perf_counter()
            q32 = np.asarray(q, np.float32)
            # the SSD tier is row-indexed: purge-surviving rows pack the
            # pages, so the re-rank walks physical rows and the result ids
            # map back through id_of (monotone — ordering is unchanged)
            rr = heuristic_rerank(
                q32, w.view.row_of[order_ids], idx.ssd, p.k,
                batch_size=p.rerank_batch, eps=p.rerank_eps,
                beta=p.rerank_beta,
                disable_early_stop=p.disable_early_stop)
            rr_ids = w.view.id_of[rr.ids] if len(rr.ids) else \
                rr.ids.astype(np.int64)
            ids_out, dists_out = rr_ids, rr.dists
            # delta merge (DESIGN.md §10): the pinned view's unsealed rows
            # are scanned exactly (under the SAME predicate) and merged on
            # (dist, id) — both streams are exact squared-L2, and delta
            # ids (>= n_sealed) never appear in the sealed posting lists,
            # so this is a disjoint k-way merge, bit-identical across
            # replicas at one epoch
            if w.view is not None and len(w.view.delta):
                d_ids, d_d2 = w.view.delta_scan(q32, filt=p.filter)
                if len(d_ids):
                    all_ids = np.concatenate([rr_ids.astype(np.int64),
                                              d_ids])
                    all_d = np.concatenate(
                        [rr.dists, d_d2.astype(rr.dists.dtype)])
                    sel = np.lexsort((all_ids, all_d))[:p.k]
                    ids_out = all_ids[sel]
                    dists_out = all_d[sel]
            stats = QueryStats(
                ios=rr.io.ios, pages_requested=rr.io.pages_requested,
                buffer_hits=rr.io.buffer_hits, ssd_bytes=rr.io.bytes_read,
                h2d_bytes=4 * u // max(B, 1),    # amortised union transfer
                candidates_scanned=u,            # union, ONCE per window
                candidates_prefilter=w.prefilter,
                rerank_batches=rr.batches_run,
                rerank_scored=rr.candidates_scored,
                early_stopped=rr.early_stopped,
                t_graph=w.t_graph / max(B, 1), t_scan=t_scan / max(B, 1),
                t_rerank=time.perf_counter() - t2)
            fut._set_result(QueryResult(ids=ids_out, dists=dists_out,
                                        stats=stats))

    # --------------------------------------------------------------- submit
    def submit(self, queries, plan: Optional[QueryPlan] = None,
               overrides: Optional[Sequence[Optional[PlanOverrides]]] = None
               ):
        """Asynchronous entry point: host-traverse + device-dispatch up to
        ``plan.effective_depth()`` windows, then return a
        :class:`~repro.core.futures.BatchTicket` whose per-query futures
        resolve on demand.

        Remaining windows stay host-side and are dispatched as depth slots
        free up — the pump prefers dispatching window t+1 over blocking on
        window t's scan, which is exactly the paper's CPU/GPU overlap.

        Backend-protocol form (DESIGN.md §6): called with a single
        :class:`~repro.serve.client.SearchRequest` instead of a query
        array, returns a :class:`~repro.core.futures.QueryFuture`
        resolving to a :class:`~repro.serve.client.SearchResponse`."""
        from repro.serve.client import SearchRequest
        if isinstance(queries, SearchRequest):
            return self._submit_request(queries)
        if plan is None:
            raise TypeError("submit(queries, plan) requires a QueryPlan "
                            "(only the SearchRequest form may omit it)")
        queries = np.atleast_2d(np.asarray(queries, np.float32))
        n = len(queries)
        if overrides is not None and len(overrides) != n:
            raise ValueError(f"{len(overrides)} overrides for {n} queries")
        plans = [plan if overrides is None or overrides[i] is None
                 else overrides[i].merge_into(plan) for i in range(n)]
        futures = [QueryFuture(tag=i) for i in range(n)]
        ticket = BatchTicket(futures)
        if n == 0:
            return ticket
        t_submit = time.perf_counter()
        deadlines = [None if p.deadline_s is None else t_submit + p.deadline_s
                     for p in plans]
        W = plan.window or n
        starts = list(range(0, n, W))
        inflight = _InflightQueue(plan.effective_depth())
        cursor = [0]          # next undispatched window; under inflight._lock
        lock, cond, busy = ticket._lock, ticket._cond, ticket._busy

        def _claim_dispatch() -> Optional[int]:
            """Claim the next window index + a depth slot, or None when
            nothing is dispatchable.  Takes the inflight lock first and
            bumps the ticket's ``busy`` INSIDE it (rank descends:
            inflight > ticket), so a stall-checking ``wait()`` — which
            must take the inflight lock to observe an empty queue — can
            never see the claim without its busy count."""
            with inflight._lock:               # acquires: inflight
                if cursor[0] < len(starts) and not inflight.full():
                    wi = cursor[0]
                    cursor[0] += 1
                    inflight.reserve()
                    with lock:                 # acquires: ticket
                        busy[0] += 1
                    return wi
            return None

        def _do_dispatch(wi: int) -> None:
            """Stage ①-⑥ for a claimed window — slow host work runs outside
            both locks so a concurrent retire can overlap it."""
            s = starts[wi]
            try:
                with self._dispatch_lock:
                    w = self._dispatch(queries[s:s + W], plans[s:s + W])
            except BaseException as exc:
                for qi in range(s, min(s + W, n)):
                    futures[qi]._set_exception(exc)
                with inflight._lock:           # acquires: inflight
                    inflight.cancel_reservation()
                    with cond:                 # acquires: ticket
                        busy[0] -= 1
                        cond.notify_all()
                raise
            w.start, w.wi = s, wi
            with inflight._lock:               # acquires: inflight
                inflight.commit(w)
                with cond:                     # acquires: ticket
                    ticket.events.append(("dispatch", wi))
                    busy[0] -= 1
                    cond.notify_all()

        def _retire(w: _Window) -> None:
            """Stage ⑥-⑦ for a popped window.  The ``finish`` event is
            recorded when the re-rank COMPLETES (before ``busy`` drops), so
            concurrent retirement shows up as out-of-window-order
            finishes."""
            try:
                self._finish_into(w, futures, deadlines)
            except BaseException as exc:
                for qi in range(len(w.queries)):
                    futures[w.start + qi]._set_exception(exc)
                raise
            finally:
                with cond:                         # acquires: ticket
                    ticket.events.append(("finish", w.wi))
                    busy[0] -= 1
                    cond.notify_all()

        def _pump() -> bool:
            """Blocking progress: prefer dispatching window t+1 over
            blocking on window t's scan (the paper's CPU/GPU overlap);
            retirement is FIFO from this path."""
            wi = _claim_dispatch()
            if wi is not None:
                _do_dispatch(wi)
                return True
            w = None
            with inflight._lock:                   # acquires: inflight
                if len(inflight):
                    w = inflight.pop()
                    with lock:                     # acquires: ticket
                        busy[0] += 1
            if w is not None:
                _retire(w)
                return True
            return False

        def _poll() -> bool:
            """Non-blocking progress (the ticker's entry point): retire ANY
            window whose scan landed — out of order when an older window is
            mid-re-rank on another thread — then refill depth slots."""
            from repro.core.distributed import window_scan_ready
            progressed = False
            while True:
                with inflight._lock:               # acquires: inflight
                    w = inflight.pop_ready(
                        lambda x: window_scan_ready(x.vals, x.pos))
                    if w is not None:
                        with lock:                 # acquires: ticket
                            busy[0] += 1
                if w is not None:
                    _retire(w)
                    progressed = True
                    continue
                wi = _claim_dispatch()
                if wi is None:
                    return progressed
                _do_dispatch(wi)
                progressed = True

        ticket._pump = _pump
        ticket._poll = _poll
        for f in futures:
            f._driver = _pump
        # eager phase: fill the in-flight depth before handing back
        while True:
            wi = _claim_dispatch()
            if wi is None:
                break
            _do_dispatch(wi)
        return ticket

    # ------------------------------------------------------------------ run
    def run(self, queries: np.ndarray, plan: QueryPlan) -> List[QueryResult]:
        """Submit-then-wait: bit-identical ids to ``submit()``/``result()``
        for the same plan, by construction."""
        return self.submit(queries, plan).results()

    def run_one(self, query: np.ndarray, plan: QueryPlan) -> QueryResult:
        return self.run(np.asarray(query, np.float32)[None], plan)[0]

    # ------------------------------------------------- Backend protocol
    # (DESIGN.md §6) — the executor is the queueless backend: submit
    # dispatches the request's scan window immediately (jax async
    # dispatch); retirement is caller-driven (``result()`` drives) or
    # opportunistic via ``drain()``.

    def _submit_request(self, request) -> QueryFuture:
        from repro.serve.client import response_from_result
        plan = QueryPlan.from_config(self.index.cfg, k=request.k,
                                     top_n=request.top_n,
                                     deadline_s=request.deadline_s,
                                     filter=request.filter)
        if request.adaptive and request.deadline_s is not None:
            sug = self.planner.suggest(request.deadline_s)
            if sug is not None:
                # the resolver's accuracy level shapes the scan; an
                # EXPLICIT request top_n still wins over the adaptive one
                plan = plan.override(
                    top_m=sug["top_m"],
                    top_n=None if request.top_n is not None
                    else sug["top_n"])
        t0 = time.perf_counter()
        ticket = self.submit(request.query[None], plan)
        inner = ticket.futures[0]
        with self._backend_lock:
            rid = self._next_rid
            self._next_rid += 1
            self._request_tickets = [t for t in self._request_tickets
                                     if not t.done()]
            self._request_tickets.append(ticket)

        def _drive() -> bool:
            try:
                inner.result()             # resolves ``out`` via callback
            except BaseException:          # noqa: BLE001 — stays on inner
                pass
            return True

        out = QueryFuture(tag=request.tag if request.tag is not None
                          else rid, driver=_drive)

        def _on_done(f: QueryFuture):
            latency = time.perf_counter() - t0
            try:
                res = f.result()
            except BaseException as exc:   # noqa: BLE001 — deadline/cancel
                out._set_exception(exc)
                return
            resp = response_from_result(res, latency_s=latency, rid=rid,
                                        tag=request.tag,
                                        tenant=request.tenant)
            with self._backend_lock:
                self._undrained.append(resp)
                self._latencies.append(latency)
                for field in QUERY_STATS_FIELDS:
                    self.query_stats[field] += getattr(res.stats, field)
                self.query_stats["served"] += 1
            # feed the adaptive resolver OUTSIDE _backend_lock: the
            # planner's lock is executor-ranked too, and same-rank
            # nesting is a witnessed lock-order violation
            pl = getattr(self, "_planner", None)
            if pl is not None:
                pl.observe(res.stats)
            out._set_result(resp)

        inner.add_done_callback(_on_done)
        # cancelling the client-facing future skips the query's re-rank
        out.add_done_callback(
            lambda f: inner.cancel() if f.cancelled() else None)
        return out

    def drain(self) -> List:
        """Retire every outstanding request-path ticket and return the
        responses served since the last drain (exceptions stay on their
        futures, matching the service/router drain contract)."""
        with self._backend_lock:
            tickets = list(self._request_tickets)
        for t in tickets:
            t.wait()
        with self._backend_lock:
            self._request_tickets = [t for t in self._request_tickets
                                     if not t.done()]
            out = list(self._undrained)
            self._undrained.clear()
        return out

    def stop(self) -> "QueryExecutor":
        """No threads to stop; equivalent to a final ``drain()``."""
        self.drain()
        return self

    def live_load(self) -> int:
        """Pending request-path futures (the executor has no queue, so
        this is exactly the in-flight count)."""
        with self._backend_lock:
            return sum(1 for t in self._request_tickets
                       for f in t.futures if not f.done())

    def latency_percentiles(self) -> Dict[str, float]:
        """p50/p99 of submit->resolve latency over request-path serves."""
        with self._backend_lock:
            snap = list(self._latencies)
        lat = np.asarray(snap)       # materialise OUTSIDE the lock (PU01)
        if not len(lat):
            return {"p50": 0.0, "p99": 0.0, "n": 0}
        return {"p50": float(np.percentile(lat, 50)),
                "p99": float(np.percentile(lat, 99)), "n": len(lat)}

    def stats_rollup(self) -> Dict[str, object]:
        """The shared rollup shape: summed ``QueryStats`` counters of every
        request-path response plus the served count."""
        with self._backend_lock:
            totals = {f: self.query_stats[f] for f in QUERY_STATS_FIELDS}
            served = self.query_stats["served"]
        return {"served": served, "requests": served,
                "query_stats": totals}
