from repro.core.engine import (  # noqa: F401
    FusionANNSIndex,
    QueryResult,
    QueryStats,
    ground_truth,
    recall_at_k,
)
from repro.core.executor import (  # noqa: F401
    PlanOverrides,
    QueryExecutor,
    QueryPlan,
)
from repro.core.futures import (  # noqa: F401
    BackpressureError,
    BatchTicket,
    CancelledError,
    DeadlineExceeded,
    QueryFuture,
)
from repro.core.topk import sharded_topk  # noqa: F401
