from repro.core.engine import (  # noqa: F401
    FusionANNSIndex,
    QueryResult,
    QueryStats,
    ground_truth,
    recall_at_k,
)
from repro.core.executor import QueryExecutor, QueryPlan  # noqa: F401
from repro.core.topk import sharded_topk  # noqa: F401
