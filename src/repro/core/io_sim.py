"""SSD tier simulation with exact 4 KB-page semantics (paper §4.3).

Implements the optimised storage layout (per-centroid buckets,
first-fit-decreasing remainder bin-packing so partial pages are shared),
the vec->page mapping table, Direct-I/O page reads, and the two dedup
mechanisms:

  * intra-mini-batch: requests hitting the same page within ONE ``fetch()``
    are merged,
  * inter-mini-batch: a (per-query) DRAM page buffer absorbs repeats
    ACROSS ``fetch()`` calls.

The two mechanisms are strictly separated for the Fig. 12 per-mechanism
attribution: the page buffer only serves pages read by *previous*
mini-batches, so disabling ``intra_merge`` really does charge one I/O per
same-page request inside a batch (insertions into the buffer are deferred
to the end of the fetch).  Every mechanism can be disabled independently.
I/O counts and byte volumes are exact; latency is modelled by the analytic
device model in ``core.baselines`` (no NVMe in this container — DESIGN.md §7).

Thread-safety: the per-query DRAM buffer is thread-local, so the threaded
serving runtime (PR 3) can re-rank two queries concurrently — each
re-ranking thread sees its own per-query buffer scope and per-query I/O
accounting stays exact.
"""

from __future__ import annotations

import dataclasses
import threading
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass
class IOStats:
    ios: int = 0                 # page reads issued to the "SSD"
    pages_requested: int = 0     # before any dedup
    buffer_hits: int = 0         # inter-mini-batch dedup (DRAM page buffer)
    intra_merged: int = 0        # intra-mini-batch dedup (same-page merge)
    bytes_read: int = 0

    def merge(self, other: "IOStats") -> "IOStats":
        return IOStats(self.ios + other.ios,
                       self.pages_requested + other.pages_requested,
                       self.buffer_hits + other.buffer_hits,
                       self.intra_merged + other.intra_merged,
                       self.bytes_read + other.bytes_read)


class PageBuffer:
    """LRU DRAM page buffer (inter-mini-batch dedup)."""

    def __init__(self, capacity_pages: int):
        self.capacity = capacity_pages
        self._lru: "OrderedDict[int, bool]" = OrderedDict()

    def hit(self, page: int) -> bool:
        if page in self._lru:
            self._lru.move_to_end(page)
            return True
        return False

    def insert(self, page: int) -> None:
        self._lru[page] = True
        self._lru.move_to_end(page)
        while len(self._lru) > self.capacity:
            self._lru.popitem(last=False)

    def clear(self) -> None:
        self._lru.clear()


def pack_buckets_maxmin(bucket_sizes: Sequence[int], per_page: int
                        ) -> Tuple[List[List[int]], int]:
    """First-fit-decreasing packing of bucket *remainders* into shared
    pages (§4.3's shared-page layout).

    Full pages are dedicated; remainders are sorted descending and each is
    placed into the FIRST open page with room (first-fit-decreasing — not
    the max-min pairing the name suggests; the name is kept for API
    stability).  Returns (groups of bucket-ids sharing a page, total pages
    used)."""
    full_pages = sum(s // per_page for s in bucket_sizes)
    rema = [(s % per_page, i) for i, s in enumerate(bucket_sizes)
            if s % per_page]
    rema.sort(reverse=True)
    groups: List[List[int]] = []
    loads: List[int] = []
    for size, bid in rema:
        placed = False
        for gi in range(len(groups)):
            if loads[gi] + size <= per_page:
                groups[gi].append(bid)
                loads[gi] += size
                placed = True
                break
        if not placed:
            groups.append([bid])
            loads.append(size)
    return groups, full_pages + len(groups)


@dataclasses.dataclass
class StorageLayout:
    """vec_id -> page mapping under the optimised bucket layout."""

    page_of: np.ndarray            # (N,) int64 page id per vector
    n_pages: int
    per_page: int
    page_bytes: int

    @staticmethod
    def build(primary_cluster: np.ndarray, n_clusters: int,
              vec_bytes: int, page_bytes: int = 4096,
              optimized: bool = True) -> "StorageLayout":
        """``primary_cluster[v]`` = the single bucket that stores v (no
        duplicates across buckets — paper §4.3).  ``optimized=False`` lays
        vectors out in insertion order (the straw-man layout)."""
        n = len(primary_cluster)
        per_page = max(1, page_bytes // vec_bytes)
        page_of = np.empty(n, np.int64)
        if not optimized:
            page_of[:] = np.arange(n) // per_page
            return StorageLayout(page_of, int(page_of.max()) + 1 if n else 0,
                                 per_page, page_bytes)
        # group vectors by bucket; remainders share pages via max-min
        order = np.argsort(primary_cluster, kind="stable")
        sizes = np.bincount(primary_cluster, minlength=n_clusters)
        groups, n_pages = pack_buckets_maxmin(sizes.tolist(), per_page)
        # assign pages: first the full pages bucket-by-bucket, then groups
        page = 0
        starts = np.zeros(n_clusters + 1, np.int64)
        np.cumsum(sizes, out=starts[1:])
        slot_page = np.empty(n, np.int64)   # page of the i-th sorted vector
        rem_start: Dict[int, int] = {}
        for c in range(n_clusters):
            full = sizes[c] // per_page
            for f in range(full):
                s = starts[c] + f * per_page
                slot_page[s:s + per_page] = page
                page += 1
            rem_start[c] = starts[c] + full * per_page
        for grp in groups:
            for bid in grp:
                s = rem_start[bid]
                e = starts[bid] + sizes[bid]
                slot_page[s:e] = page
            page += 1
        page_of[order] = slot_page
        return StorageLayout(page_of, page, per_page, page_bytes)


class SSDSim:
    """Raw-vector store with page-granular reads + dedup mechanisms."""

    def __init__(self, vectors: np.ndarray, layout: StorageLayout,
                 buffer_pages: int = 1024, *,
                 intra_merge: bool = True, use_buffer: bool = True):
        self.vectors = vectors
        self.layout = layout
        self.intra_merge = intra_merge
        self.use_buffer = use_buffer
        self.buffer_pages = buffer_pages
        # one DRAM buffer per re-ranking thread: a query's re-rank runs
        # entirely on one thread, so per-query scoping survives the
        # threaded runtime's concurrent retirements
        self._tls = threading.local()

    @property
    def buffer(self) -> PageBuffer:
        buf = getattr(self._tls, "buffer", None)
        if buf is None:
            buf = PageBuffer(self.buffer_pages)
            self._tls.buffer = buf
        return buf

    # thread-local state is not deepcopy/pickle-able; a copy starts with
    # fresh (empty) per-thread buffers, which is also semantically right
    def __getstate__(self):
        state = self.__dict__.copy()
        state.pop("_tls", None)
        return state

    def __setstate__(self, state):
        self.__dict__.update(state)
        self._tls = threading.local()

    def begin_query(self) -> IOStats:
        """Per-query buffer scope (the paper's DRAM buffer is per-query
        working memory)."""
        self.buffer.clear()
        return IOStats()

    def fetch(self, vec_ids: np.ndarray, stats: IOStats) -> np.ndarray:
        """One re-ranking mini-batch: returns the raw vectors, accounting
        page I/O with intra-batch merge + buffer dedup.

        Buffer insertions are deferred until the whole mini-batch is
        accounted: the buffer is the INTER-mini-batch mechanism, so with
        ``intra_merge=False`` same-page requests inside one batch each
        cost an I/O instead of being silently absorbed by the buffer
        (keeps the Fig. 12 per-mechanism attribution honest)."""
        pages = self.layout.page_of[vec_ids]
        stats.pages_requested += len(pages)
        wanted = pages if not self.intra_merge else np.unique(pages)
        # per-mechanism attribution invariant (Fig. 12):
        #   pages_requested - ios == intra_merged + buffer_hits
        stats.intra_merged += len(pages) - len(wanted)
        buf = self.buffer
        read_this_batch: List[int] = []       # read order (dups included)
        for p in wanted:
            p = int(p)
            if self.use_buffer and buf.hit(p):
                stats.buffer_hits += 1
                continue
            stats.ios += 1
            stats.bytes_read += self.layout.page_bytes
            read_this_batch.append(p)
        if self.use_buffer:
            # sequential inserts in read order: LRU recency matches the
            # actual read sequence (a repeat moves its page to the tail)
            for p in read_this_batch:
                buf.insert(p)
        return self.vectors[vec_ids]


@dataclasses.dataclass
class PostingListStore:
    """SPANN-style layout: whole posting lists stored contiguously on SSD;
    a query reads each selected list in full (multi-page I/Os)."""

    list_pages: np.ndarray        # pages per posting list
    page_bytes: int = 4096

    @staticmethod
    def build(member_counts: Sequence[int], entry_bytes: int,
              page_bytes: int = 4096) -> "PostingListStore":
        pages = np.array([max(1, int(np.ceil(c * entry_bytes / page_bytes)))
                          for c in member_counts], np.int64)
        return PostingListStore(pages, page_bytes)

    def read_lists(self, list_ids: np.ndarray, stats: IOStats) -> None:
        # one I/O per list (SPANN issues large sequential reads), but the
        # byte volume spans all its pages
        pages = self.list_pages[list_ids]
        stats.ios += len(list_ids)
        stats.pages_requested += int(pages.sum())
        stats.bytes_read += int(pages.sum()) * self.page_bytes
