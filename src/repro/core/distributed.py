"""Distributed FusionANNS scan: PQ codes row-sharded across every device's
HBM (the paper's "pinned in GPU HBM" tier, scaled to a pod — DESIGN.md §2).

Per query batch: each device ADC-scans its code shard (Pallas kernel on TPU,
jnp oracle under interpret/CPU), takes a *local* top-n, and one small
``all_gather`` of (dist, global-id) pairs merges shards — vector contents
never cross the interconnect, exactly the paper's ID-only invariant."""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.kernels.pq_adc.ref import pq_adc_ref
from repro.models.layers import ShardCtx
from repro.sharding.spec import shard_map_compat as _shard_map


def window_scan_ready(*arrays) -> bool:
    """True when every device buffer backing a window's scan outputs has
    landed (jax async dispatch done).  Used by the futures layer for
    non-blocking progress (``BatchTicket.poll``): a window whose scan is
    ready can be retired without stalling the host.  Conservatively falls
    back to True (retire-and-block, still correct) on runtimes without
    ``jax.Array.is_ready``."""
    for a in arrays:
        is_ready = getattr(a, "is_ready", None)
        if is_ready is None:
            continue
        try:
            if not is_ready():
                return False
        except Exception:       # noqa: BLE001 — deleted/donated buffers
            continue
    return True


def replicate_to_mesh(x: jax.Array, ctx: ShardCtx) -> jax.Array:
    """Commit ``x`` replicated onto ``ctx.mesh``'s devices.

    On a full mesh this is what jit would do implicitly for an uncommitted
    operand; on a SUB-mesh (multi-replica serving: one replica owns a
    disjoint device group carved from the shared mesh) it matters — an
    uncommitted array lives on the process default device, which may not
    belong to this replica's group at all, and compute-follows-data would
    otherwise drag the scan off the replica's devices (contending with a
    sibling replica's scan).  No-op without a mesh."""
    if ctx.mesh is None:
        return x
    spec = P(*((None,) * x.ndim))
    return jax.device_put(x, NamedSharding(ctx.mesh, spec))


def _gather_merge_batched(vals, gids, axes, n_shards: int, tk_out: int):
    """Shared tail of the batched shard bodies: all_gather the per-shard
    (dist, global-id) pairs along the query-local axis and merge."""
    if n_shards > 1:
        vals = jax.lax.all_gather(vals, axes, axis=1, tiled=True)
        gids = jax.lax.all_gather(gids, axes, axis=1, tiled=True)
    neg, pos = jax.lax.top_k(-vals, tk_out)
    return -neg, jnp.take_along_axis(gids, pos, axis=1)


def _local_scan_topn(codes, lut, top_n: int, axes, n_shards: int):
    n_loc = codes.shape[0]
    dist = pq_adc_ref(codes, lut)                     # (n_loc,) f32
    tk = min(top_n, n_loc)
    neg, idx = jax.lax.top_k(-dist, tk)
    me = jax.lax.axis_index(axes) if n_shards > 1 else 0
    gids = idx + me * n_loc
    vals = -neg
    if n_shards > 1:
        vals = jax.lax.all_gather(vals, axes, axis=0, tiled=True)
        gids = jax.lax.all_gather(gids, axes, axis=0, tiled=True)
    neg, pos = jax.lax.top_k(-vals, tk)
    return -neg, gids[pos]


def sharded_adc_topn(codes: jax.Array, lut: jax.Array, top_n: int,
                     ctx: ShardCtx) -> Tuple[jax.Array, jax.Array]:
    """codes (N, M) uint8 sharded over ``corpus`` axes; lut (M, K) f32
    replicated -> (dists (top_n,), global ids (top_n,)) replicated."""
    if ctx.mesh is None:
        dist = pq_adc_ref(codes, lut)
        neg, ids = jax.lax.top_k(-dist, min(top_n, codes.shape[0]))
        return -neg, ids
    axes = ctx.rules.corpus
    axes_t = (axes,) if isinstance(axes, str) else tuple(axes)
    n_shards = 1
    for a in axes_t:
        n_shards *= ctx.mesh.shape[a]
    body = functools.partial(_local_scan_topn, top_n=top_n, axes=axes_t,
                             n_shards=n_shards)
    return _shard_map(
        body, mesh=ctx.mesh,
        in_specs=(P(axes, None), P(None, None)),
        out_specs=(P(), P()),
    )(codes, lut)


def _local_scan_topn_blocked(codes, luts, top_n: int, axes, n_shards: int,
                             block_n: int = 65536):
    """§Perf hillclimb A (jnp form): scan code BLOCKS, scoring all B
    queries per block in one flat gather, with a running per-query top-n —
    the Pallas `pq_adc_batch` kernel is the VMEM-resident version of this
    loop (LUTs + accumulators never leave VMEM)."""
    n_loc, m = codes.shape
    b, _, k = luts.shape
    bn = min(block_n, n_loc)
    n_blocks = n_loc // bn
    assert n_blocks * bn == n_loc, (n_loc, bn)
    flat = luts.reshape(b, m * k)
    tk = min(top_n, bn)

    def body(carry, blk_idx):
        run_v, run_i = carry
        blk = jax.lax.dynamic_slice_in_dim(codes, blk_idx * bn, bn)
        idx = blk.astype(jnp.int32) + (jnp.arange(m, dtype=jnp.int32)
                                       * k)[None, :]
        vals = jnp.take(flat, idx.reshape(-1), axis=1)        # (B, bn*M)
        dist = jnp.sum(vals.reshape(b, bn, m), axis=-1)       # (B, bn)
        neg, pos = jax.lax.top_k(-dist, tk)
        ids = pos + blk_idx * bn
        cat_v = jnp.concatenate([run_v, -neg], axis=1)
        cat_i = jnp.concatenate([run_i, ids], axis=1)
        neg2, pos2 = jax.lax.top_k(-cat_v, tk)
        return (-neg2, jnp.take_along_axis(cat_i, pos2, axis=1)), None

    init = (jnp.full((b, tk), jnp.inf, jnp.float32),
            jnp.full((b, tk), -1, jnp.int32))
    (vals, ids), _ = jax.lax.scan(body, init, jnp.arange(n_blocks))
    me = jax.lax.axis_index(axes) if n_shards > 1 else 0
    gids = ids + me * n_loc
    return _gather_merge_batched(vals, gids, axes, n_shards, tk)


def sharded_adc_topn_batch(codes: jax.Array, luts: jax.Array, top_n: int,
                           ctx: ShardCtx, *, blocked: bool = True
                           ) -> Tuple[jax.Array, jax.Array]:
    """Batched queries: luts (B, M, K) replicated -> ((B, top_n) x2).

    The scan is the bandwidth-bound stage; queries amortise the code
    traffic (each code byte is read once per *batch*, not per query).
    ``blocked=False`` falls back to the per-query map (the §Perf baseline).
    """
    if ctx.mesh is None:
        def one(lut):
            d = pq_adc_ref(codes, lut)
            neg, ids = jax.lax.top_k(-d, min(top_n, codes.shape[0]))
            return -neg, ids
        return jax.lax.map(one, luts)
    axes = ctx.rules.corpus
    axes_t = (axes,) if isinstance(axes, str) else tuple(axes)
    n_shards = 1
    for a in axes_t:
        n_shards *= ctx.mesh.shape[a]

    if blocked:
        def body(codes_l, luts_l):
            return _local_scan_topn_blocked(codes_l, luts_l, top_n, axes_t,
                                            n_shards)
    else:
        def body(codes_l, luts_l):
            def one(lut):
                return _local_scan_topn(codes_l, lut, top_n, axes_t,
                                        n_shards)
            return jax.lax.map(one, luts_l)

    return _shard_map(
        body, mesh=ctx.mesh,
        in_specs=(P(axes, None), P(None, None, None)),
        out_specs=(P(None, None), P(None, None)),
    )(codes, luts)


def _local_scan_topn_window(codes, luts, mask, top_n: int, axes,
                            n_shards: int, use_kernel: bool):
    """Per-shard body of the executor's windowed scan: score this shard's
    candidate rows for all B queries, mask non-members / padding to +inf,
    take a per-shard per-query top-n, and all_gather only the (distance,
    global-position) pairs before the global merge."""
    from repro.kernels.pq_adc.ops import pq_adc_batch
    n_loc = codes.shape[0]
    dist = pq_adc_batch(codes, luts, use_kernel=use_kernel)   # (B, n_loc)
    dist = jnp.where(mask, dist, jnp.inf)
    tk = min(top_n, n_loc)
    neg, idx = jax.lax.top_k(-dist, tk)
    me = jax.lax.axis_index(axes) if n_shards > 1 else 0
    gids = idx + me * n_loc
    return _gather_merge_batched(-neg, gids, axes, n_shards,
                                 min(top_n, n_loc * n_shards))


def sharded_adc_topn_window(codes: jax.Array, luts: jax.Array,
                            mask: jax.Array, top_n: int, ctx: ShardCtx, *,
                            use_kernel: bool = False
                            ) -> Tuple[jax.Array, jax.Array]:
    """Executor stage ⑤: candidate-bucket scan with per-query membership.

    codes (N, M) uint8 row-sharded over the ``corpus`` axes; luts (B, M, K)
    and mask (B, N) bool (True where row N is one of query B's candidates;
    padding rows all-False) -> (dists (B, tk), bucket positions (B, tk))
    replicated, tk = min(top_n, N).  Masked-out slots surface as +inf.
    Single-device (``ctx.mesh is None``) falls back to the fused kernel
    wrapper — identical results, so sharded == unsharded is testable."""
    if ctx.mesh is None:
        from repro.kernels.pq_adc.ops import pq_adc_topk_batch
        return pq_adc_topk_batch(codes, luts, top_n, mask=mask,
                                 use_kernel=use_kernel)
    axes = ctx.rules.corpus
    axes_t = (axes,) if isinstance(axes, str) else tuple(axes)
    n_shards = 1
    for a in axes_t:
        n_shards *= ctx.mesh.shape[a]
    body = functools.partial(_local_scan_topn_window, top_n=top_n,
                             axes=axes_t, n_shards=n_shards,
                             use_kernel=use_kernel)
    return _shard_map(
        body, mesh=ctx.mesh,
        in_specs=(P(axes, None), P(None, None, None), P(None, axes)),
        out_specs=(P(None, None), P(None, None)),
    )(codes, luts, mask)


def _local_scan_topn_rows(codes, queries, codebooks, rows, top_n: int,
                          axes, n_shards: int, use_kernel: bool,
                          lut_int8: bool):
    """Per-shard body of the FUSED windowed scan: each query scans its own
    candidate-row list.  ``rows`` holds GLOBAL row ids (replicated); this
    shard scores only the ids that land in its local range, surfaces the
    rest as +inf, and all_gathers (distance, global-id) pairs — still the
    paper's ID-only interconnect invariant."""
    from repro.kernels.pq_adc.ops import pq_adc_fused_topk
    n_loc = codes.shape[0]
    me = jax.lax.axis_index(axes) if n_shards > 1 else 0
    local = rows - me * n_loc
    mine = (rows >= 0) & (local >= 0) & (local < n_loc)
    # keep ascending-id order inside the shard: misses -> -1 pads
    local = jnp.where(mine, local, -1)
    vals, lids = pq_adc_fused_topk(codes, queries, codebooks, local,
                                   top_n, use_kernel=use_kernel,
                                   lut_int8=lut_int8)
    gids = jnp.where(lids >= 0, lids + me * n_loc, -1)
    return _gather_merge_batched(vals, gids, axes, n_shards,
                                 min(top_n, rows.shape[1]))


def sharded_adc_topn_rows(codes: jax.Array, queries: jax.Array,
                          codebooks: jax.Array, rows: jax.Array,
                          top_n: int, ctx: ShardCtx, *,
                          use_kernel: bool = False, lut_int8: bool = False
                          ) -> Tuple[jax.Array, jax.Array]:
    """Executor stage ⑤, fused form (`fused=` plan knob): LUT build + ADC
    scan + partial top-k in one pipeline per shard, per-query candidate
    ROW LISTS instead of a dense (B, N) mask.

    codes (N, M) uint8 row-sharded over the ``corpus`` axes; queries
    (B, M*dsub) f32 (OPQ rotation pre-applied) and codebooks (M, K, dsub)
    replicated; rows (B, S) int32 GLOBAL row ids, -1 = pad, ascending per
    query -> (dists (B, tk), GLOBAL ids (B, tk)) replicated with
    tk = min(top_n, S).  Empty slots come back as (+inf, -1).  Unlike
    `sharded_adc_topn_window`, the ids are global rows, not bucket
    positions — no candidate union/gather ever materialises."""
    if ctx.mesh is None:
        from repro.kernels.pq_adc.ops import pq_adc_fused_topk
        return pq_adc_fused_topk(codes, queries, codebooks, rows, top_n,
                                 use_kernel=use_kernel, lut_int8=lut_int8)
    axes = ctx.rules.corpus
    axes_t = (axes,) if isinstance(axes, str) else tuple(axes)
    n_shards = 1
    for a in axes_t:
        n_shards *= ctx.mesh.shape[a]
    body = functools.partial(_local_scan_topn_rows, top_n=top_n,
                             axes=axes_t, n_shards=n_shards,
                             use_kernel=use_kernel, lut_int8=lut_int8)
    return _shard_map(
        body, mesh=ctx.mesh,
        in_specs=(P(axes, None), P(None, None), P(None, None, None),
                  P(None, None)),
        out_specs=(P(None, None), P(None, None)),
    )(codes, queries, codebooks, rows)
