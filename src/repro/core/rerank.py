"""Heuristic re-ranking — paper §4.2, Algorithm 1.

Host version (numpy): the production placement — the CPU re-ranks using raw
vectors fetched from the SSD tier (``core.io_sim``), max-heap top-k, change
rate Δ = |S_n − S_n∩S_{n−1}|/k, early termination after β stable batches.

Device version (``lax.while_loop``): same control flow with a fixed-size
top-k buffer, for TPU-resident re-ranking when raw vectors live in HBM
(beyond-paper mode used by the distributed engine).
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.io_sim import IOStats, SSDSim


@dataclasses.dataclass
class RerankResult:
    ids: np.ndarray                 # (k,) final neighbour ids (ascending dist)
    dists: np.ndarray               # (k,)
    batches_run: int
    candidates_scored: int
    io: IOStats
    early_stopped: bool


def heuristic_rerank(query: np.ndarray, candidate_ids: np.ndarray,
                     ssd: SSDSim, k: int, *, batch_size: int = 32,
                     eps: float = 0.05, beta: int = 2,
                     disable_early_stop: bool = False) -> RerankResult:
    """Algorithm 1.  ``candidate_ids`` must be sorted by ascending PQ
    distance (the GPU's output order — step ⑦)."""
    q = query.astype(np.float32)
    stats = ssd.begin_query()
    heap: list = []                 # max-heap via negated dists
    stability = 0
    batches = 0
    scored = 0
    early = False
    n = len(candidate_ids)

    def heap_ids() -> set:
        return {vid for _, vid in heap}

    for start in range(0, n, batch_size):
        prev = heap_ids()
        batch = candidate_ids[start:start + batch_size]
        vecs = ssd.fetch(batch, stats)                     # I/O + dedup
        d = np.sum((vecs.astype(np.float32) - q[None]) ** 2, axis=1)
        for dist, vid in zip(d, batch):
            scored += 1
            if len(heap) < k:
                heapq.heappush(heap, (-dist, int(vid)))
            elif dist < -heap[0][0]:
                heapq.heapreplace(heap, (-dist, int(vid)))
        batches += 1
        cur = heap_ids()
        delta = len(cur - prev) / max(k, 1)                # Eq. 3
        if not disable_early_stop:
            if delta < eps:
                stability += 1
                if stability >= beta:
                    early = True
                    break
            else:
                stability = 0

    order = sorted(((-nd, vid) for nd, vid in heap))
    ids = np.array([vid for _, vid in order], np.int32)
    dd = np.array([d for d, _ in order], np.float32)
    return RerankResult(ids=ids, dists=dd, batches_run=batches,
                        candidates_scored=scored, io=stats,
                        early_stopped=early)


def heuristic_rerank_jax(query: jax.Array, cand_vectors: jax.Array,
                         cand_ids: jax.Array, k: int, *,
                         batch_size: int = 32, eps: float = 0.05,
                         beta: int = 2):
    """Device-side Algorithm 1 over HBM-resident candidates.

    cand_vectors (n, D) sorted by PQ distance; returns (ids (k,), dists (k,),
    batches_run).  Distances of unprocessed batches never affect the heap —
    the while_loop stops exactly like the host version.

    The tail batch (``n % batch_size`` candidates) is scored too: inputs
    are padded to a whole number of batches and the pad rows carry +inf
    distance / id -1, so they can never displace a real candidate."""
    n, d = cand_vectors.shape
    n_batches = -(-n // batch_size)           # ceil: include the tail batch
    pad = n_batches * batch_size - n
    if pad:
        cand_vectors = jnp.concatenate(
            [cand_vectors, jnp.zeros((pad, d), cand_vectors.dtype)], axis=0)
        cand_ids = jnp.concatenate(
            [cand_ids, jnp.full((pad,), -1, cand_ids.dtype)], axis=0)
    q = query.astype(jnp.float32)

    top_d0 = jnp.full((k,), jnp.inf, jnp.float32)
    top_i0 = jnp.full((k,), -1, jnp.int32)

    def body(state):
        b, top_d, top_i, stab, done = state
        start = b * batch_size
        vecs = jax.lax.dynamic_slice_in_dim(cand_vectors, start, batch_size)
        ids = jax.lax.dynamic_slice_in_dim(cand_ids, start, batch_size)
        dist = jnp.sum((vecs.astype(jnp.float32) - q[None]) ** 2, axis=1)
        valid = start + jnp.arange(batch_size) < n
        dist = jnp.where(valid, dist, jnp.inf)    # mask tail padding
        all_d = jnp.concatenate([top_d, dist])
        all_i = jnp.concatenate([top_i, ids.astype(jnp.int32)])
        neg, pos = jax.lax.top_k(-all_d, k)
        new_d, new_i = -neg, all_i[pos]
        # Δ = fraction of heap slots replaced this batch (Eq. 3)
        changed = jnp.sum(~jnp.isin(new_i, top_i)) / k
        stab = jnp.where(changed < eps, stab + 1, 0)
        done = stab >= beta
        return b + 1, new_d, new_i, stab, done

    def cond(state):
        b, _, _, _, done = state
        return jnp.logical_and(b < n_batches, ~done)

    b, top_d, top_i, stab, done = jax.lax.while_loop(
        cond, body, (0, top_d0, top_i0, 0, False))
    return top_i, top_d, b
