"""Two-level distributed top-k (shard-local top-k -> all_gather -> merge).

This is the collective pattern FusionANNS needs for its sharded ADC scan
(step 7: per-shard candidate lists merged into the global top-n), and it is
reused by the recsys retrieval/serving steps (score vs 10^6 items).  Only
(k x n_shards) (value, id) pairs cross the interconnect — never the scores.
"""

from __future__ import annotations

from typing import Optional, Tuple, Union

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.models.layers import ShardCtx
from repro.sharding.spec import shard_map_compat

Axes = Union[str, Tuple[str, ...]]


def _axes_tuple(axes: Axes) -> Tuple[str, ...]:
    return (axes,) if isinstance(axes, str) else tuple(axes)


def local_topk_merge(vals, idx, k):
    """Merge per-shard (vals, idx) of shape (..., n*k) into global top-k."""
    v, pos = jax.lax.top_k(vals, k)
    gi = jnp.take_along_axis(idx, pos, axis=-1)
    return v, gi


def sharded_topk(scores: jax.Array, k: int, ctx: ShardCtx, *,
                 shard_axes: Axes, batch_axes: Axes = "batch",
                 largest: bool = True) -> Tuple[jax.Array, jax.Array]:
    """scores (B, V) with V sharded over ``shard_axes`` -> (vals, global_ids)
    each (B, k), replicated over ``shard_axes``.

    ``shard_axes`` are *physical* mesh axis names; ``batch_axes`` is the
    logical rule name for the batch dim (resolved via ctx.rules).
    """
    sign = 1.0 if largest else -1.0
    if ctx.mesh is None:
        v, i = jax.lax.top_k(sign * scores, k)
        return sign * v, i
    axes = _axes_tuple(shard_axes)
    n_shards = 1
    for a in axes:
        n_shards *= ctx.mesh.shape[a]
    b_spec = getattr(ctx.rules, batch_axes) if isinstance(batch_axes, str) \
        and hasattr(ctx.rules, batch_axes) else batch_axes

    def body(s):
        v_loc = s.shape[-1]
        v, i = jax.lax.top_k(sign * s, min(k, v_loc))
        me = jax.lax.axis_index(axes)
        gi = i + me * v_loc
        if n_shards > 1:
            v = jax.lax.all_gather(v, axes, axis=-1, tiled=True)
            gi = jax.lax.all_gather(gi, axes, axis=-1, tiled=True)
        vv, gg = local_topk_merge(v, gi, k)
        return sign * vv, gg

    return shard_map_compat(
        body, mesh=ctx.mesh,
        in_specs=P(b_spec, axes),
        out_specs=(P(b_spec, None), P(b_spec, None)),
    )(scores)
