"""Product quantisation (paper §2.2): codebook training (k-means per
sub-space, vectorised over sub-spaces), encoding, and the ADC distance-table
machinery of Eq. (1)."""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class PQCodebook:
    """codebooks: (M, K, dsub) — M sub-spaces, K=2^nbits centroids each."""

    codebooks: jax.Array

    @property
    def m(self) -> int:
        return self.codebooks.shape[0]

    @property
    def k(self) -> int:
        return self.codebooks.shape[1]

    @property
    def dsub(self) -> int:
        return self.codebooks.shape[2]


def _split_subspaces(x: jax.Array, m: int) -> jax.Array:
    n, d = x.shape
    assert d % m == 0, f"dim {d} not divisible by M={m}"
    return x.reshape(n, m, d // m).transpose(1, 0, 2)          # (M, N, dsub)


def train_codebooks(rng: jax.Array, data: jax.Array, m: int,
                    nbits: int = 8, iters: int = 12) -> PQCodebook:
    """Vectorised per-sub-space k-means (Lloyd), k-means|| style sample init."""
    k = 2 ** nbits
    sub = _split_subspaces(data.astype(jnp.float32), m)        # (M, N, ds)
    n = sub.shape[1]
    init_idx = jax.random.choice(rng, n, (k,), replace=n < k)
    centers = sub[:, init_idx]                                 # (M, K, ds)

    def step(centers, _):
        # assign: (M, N) nearest center per sub-vector
        d2 = (jnp.sum(sub ** 2, -1)[:, :, None]
              - 2.0 * jnp.einsum("mnd,mkd->mnk", sub, centers)
              + jnp.sum(centers ** 2, -1)[:, None, :])
        assign = jnp.argmin(d2, axis=-1)                       # (M, N)
        onehot = jax.nn.one_hot(assign, k, dtype=jnp.float32)  # (M, N, K)
        sums = jnp.einsum("mnk,mnd->mkd", onehot, sub)
        cnts = jnp.sum(onehot, axis=1)                         # (M, K)
        new = jnp.where(cnts[..., None] > 0,
                        sums / jnp.maximum(cnts[..., None], 1.0), centers)
        return new, None

    centers, _ = jax.lax.scan(step, centers, None, length=iters)
    return PQCodebook(codebooks=centers)


def encode(cb: PQCodebook, data: jax.Array) -> jax.Array:
    """-> PQ codes (N, M) uint8 (nbits=8)."""
    sub = _split_subspaces(data.astype(jnp.float32), cb.m)     # (M, N, ds)
    d2 = (jnp.sum(sub ** 2, -1)[:, :, None]
          - 2.0 * jnp.einsum("mnd,mkd->mnk", sub, cb.codebooks)
          + jnp.sum(cb.codebooks ** 2, -1)[:, None, :])
    return jnp.argmin(d2, axis=-1).T.astype(jnp.uint8)         # (N, M)


def decode(cb: PQCodebook, codes: jax.Array) -> jax.Array:
    """Approximate reconstruction (tests)."""
    n, m = codes.shape
    rows = jnp.take_along_axis(
        cb.codebooks, codes.T[:, :, None].astype(jnp.int32), axis=1)
    return rows.transpose(1, 0, 2).reshape(n, -1)


def adc_lut(cb: PQCodebook, query: jax.Array) -> jax.Array:
    """Distance lookup table for one query: (M, K) squared-L2 per sub-space
    (paper step 1 — built on the accelerator)."""
    qs = query.astype(jnp.float32).reshape(cb.m, 1, cb.dsub)
    return jnp.sum((cb.codebooks - qs) ** 2, axis=-1)          # (M, K)


def adc_lut_batch(cb: PQCodebook, queries: jax.Array) -> jax.Array:
    """(B, D) -> (B, M, K)."""
    return jax.vmap(lambda q: adc_lut(cb, q))(queries)


def adc_distances_ref(lut: jax.Array, codes: jax.Array) -> jax.Array:
    """Pure-jnp ADC scan (Eq. 1): sum_m lut[m, codes[n, m]].

    This is the oracle for the Pallas kernel in kernels/pq_adc."""
    m, k = lut.shape
    flat = lut.reshape(-1)
    idx = codes.astype(jnp.int32) + (jnp.arange(m, dtype=jnp.int32)
                                     * k)[None, :]
    return jnp.sum(jnp.take(flat, idx), axis=-1)               # (N,)


def exact_l2(query: jax.Array, vectors: jax.Array) -> jax.Array:
    q = query.astype(jnp.float32)
    v = vectors.astype(jnp.float32)
    return jnp.sum((v - q[None, :]) ** 2, axis=-1)
