"""Baseline ANNS systems the paper compares against (§2.3, §6), implemented
over the same substrate so I/O counts and byte volumes are apples-to-apples:

  * SPANN-like      — posting lists (raw vectors) on SSD, exact distances
  * HI+GPU          — SPANN + accelerator distances (lists cross PCIe)
  * HI+PQ           — PQ-compressed lists on SSD, CPU ADC + re-rank
  * HI+PQ+GPU       — compressed lists -> PCIe -> accelerator ADC + re-rank
  * RUMMY-like      — all in host memory, lists cross PCIe per query
  * DiskANN-like    — graph on SSD, one page per visited node

Each query returns (ids, QueryStats-compatible demand numbers)."""

from __future__ import annotations

import dataclasses
import heapq
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.configs.base import ANNSConfig
from repro.core import navgraph as ng, pq
from repro.core.engine import FusionANNSIndex
from repro.core.io_sim import IOStats, PostingListStore, SSDSim, StorageLayout
from repro.core.perf_model import QueryDemand
from repro.core.rerank import heuristic_rerank
import jax.numpy as jnp


@dataclasses.dataclass
class BaselineResult:
    ids: np.ndarray
    demand: QueryDemand
    io: IOStats


def _exact_topk(query, vecs, ids, k):
    d = np.sum((vecs.astype(np.float32) - query.astype(np.float32)) ** 2, -1)
    order = np.argsort(d)[:k]
    return ids[order]


class SpannLike:
    """Hierarchical indexing only: navgraph -> read top-m raw posting lists
    from SSD -> exact distances on CPU."""

    def __init__(self, index: FusionANNSIndex, data: np.ndarray):
        self.index = index
        self.data = data
        cfg = index.cfg
        entry_bytes = data.dtype.itemsize * data.shape[1] + 4
        self.store = PostingListStore.build(
            [len(m) for m in index.posting.members], entry_bytes,
            cfg.page_bytes)

    def query(self, q: np.ndarray, k: int, top_m: int) -> BaselineResult:
        cids = ng.search(self.index.graph, q.astype(np.float32), top_m)
        stats = IOStats()
        self.store.read_lists(cids, stats)
        ids = np.concatenate([self.index.posting.members[c] for c in cids])
        ids = np.unique(ids)
        out = _exact_topk(q, self.data[ids], ids, k)
        demand = QueryDemand(
            ssd_ios=stats.pages_requested,        # pages touched (Fig. 12c)
            ssd_requests=stats.ios,               # large sequential reads
            ssd_bytes=stats.bytes_read,
            cpu_dist_ops=len(ids) * self.data.shape[1],
            graph_hops=top_m * 2)
        return BaselineResult(out, demand, stats)


class HIGpu(SpannLike):
    """SPANN + GPU distances: raw lists also cross PCIe (CudaMemcpy)."""

    def query(self, q, k, top_m):
        r = super().query(q, k, top_m)
        d = r.demand
        vec_bytes = self.data.dtype.itemsize * self.data.shape[1]
        n_cand = d.cpu_dist_ops / self.data.shape[1]
        r.demand = QueryDemand(
            ssd_ios=d.ssd_ios, ssd_requests=d.ssd_requests,
            ssd_bytes=d.ssd_bytes,
            h2d_bytes=n_cand * vec_bytes,
            gpu_lookups=n_cand * self.data.shape[1],  # dist on accelerator
            graph_hops=d.graph_hops)
        return r


class HIPq:
    """PQ-compressed posting lists on SSD; CPU ADC; re-rank over the
    *straw-man* raw layout (no bucketing, no dedup) — §2.3's combination."""

    def __init__(self, index: FusionANNSIndex, data: np.ndarray,
                 gpu: bool = False):
        self.index = index
        self.data = data
        self.gpu = gpu
        cfg = index.cfg
        self.codes_np = np.asarray(index.codes)
        self.store = PostingListStore.build(
            [len(m) for m in index.posting.members], cfg.pq_m + 4,
            cfg.page_bytes)
        # straw-man raw-vector layout: insertion order, no page sharing
        layout = StorageLayout.build(
            index.posting.primary, index.posting.n_clusters,
            vec_bytes=data.dtype.itemsize * data.shape[1],
            page_bytes=cfg.page_bytes, optimized=False)
        self.raw = SSDSim(data, layout, buffer_pages=0,
                          intra_merge=False, use_buffer=False)

    def query(self, q, k, top_m, top_n) -> BaselineResult:
        cfg = self.index.cfg
        cids = ng.search(self.index.graph, q.astype(np.float32), top_m)
        stats = IOStats()
        self.store.read_lists(cids, stats)           # compressed lists I/O
        ids = np.unique(np.concatenate(
            [self.index.posting.members[c] for c in cids]))
        lut = np.asarray(pq.adc_lut(self.index.codebook, jnp.asarray(q)))
        codes = self.codes_np[ids]
        dist = lut[np.arange(cfg.pq_m)[None, :], codes.astype(np.int32)] \
            .sum(-1)
        order = ids[np.argsort(dist)[:top_n]]
        # fixed-size re-rank (no heuristic early stop), straw-man layout
        rstats = self.raw.begin_query()
        vecs = self.raw.fetch(order, rstats)
        out = _exact_topk(q, vecs, order, k)
        io = stats.merge(rstats)
        demand = QueryDemand(
            ssd_ios=io.pages_requested,
            ssd_requests=stats.ios + rstats.ios,
            ssd_bytes=io.bytes_read,
            h2d_bytes=(len(ids) * cfg.pq_m if self.gpu else 0),
            gpu_lookups=(len(ids) * cfg.pq_m if self.gpu else 0),
            cpu_lookups=(0 if self.gpu else len(ids) * cfg.pq_m),
            cpu_dist_ops=len(order) * self.data.shape[1],
            graph_hops=top_m * 2)
        return BaselineResult(out, demand, io)


class RummyLike:
    """GPU-accelerated in-memory IVF: no SSD I/O, but the selected raw
    posting lists cross PCIe every query (the reordered-pipelining system's
    steady-state traffic)."""

    def __init__(self, index: FusionANNSIndex, data: np.ndarray):
        self.index = index
        self.data = data

    def query(self, q, k, top_m) -> BaselineResult:
        cids = ng.search(self.index.graph, q.astype(np.float32), top_m)
        ids = np.unique(np.concatenate(
            [self.index.posting.members[c] for c in cids]))
        out = _exact_topk(q, self.data[ids], ids, k)
        vec_bytes = self.data.dtype.itemsize * self.data.shape[1]
        demand = QueryDemand(
            h2d_bytes=len(ids) * vec_bytes,
            gpu_lookups=len(ids) * self.data.shape[1],
            graph_hops=top_m * 2)
        return BaselineResult(out, demand, IOStats())


class DiskAnnLike:
    """Graph-based on-SSD search: one 4 KB page per visited node (vector +
    adjacency in the node record), best-first beam search."""

    def __init__(self, data: np.ndarray, degree: int = 32,
                 seed: int = 0, sample_build: Optional[int] = None):
        self.data = data.astype(np.float32)
        # exact kNN graph (BLAS-fast) — the search I/O behaviour is what the
        # comparison needs, not Vamana's build heuristics
        self.graph = ng.knn_graph_exact(self.data, degree=degree)

    def query(self, q, k, ef: int = 128) -> BaselineResult:
        points, neighbors = self.graph.points, self.graph.neighbors
        visited = set()
        cand, best = [], []
        ios = 0
        for entry in self.graph.seed_beam(q):
            entry = int(entry)
            visited.add(entry)
            d0 = float(np.sum((points[entry] - q) ** 2))
            heapq.heappush(cand, (d0, entry))
            heapq.heappush(best, (-d0, entry))
            ios += 1
        while cand:
            dist, u = heapq.heappop(cand)
            if len(best) >= ef and dist > -best[0][0]:
                break
            for v in neighbors[u]:
                if v < 0 or v in visited:
                    continue
                visited.add(int(v))
                ios += 1                       # each node record = 1 page
                dv = float(np.sum((points[v] - q) ** 2))
                if len(best) < ef or dv < -best[0][0]:
                    heapq.heappush(cand, (dv, int(v)))
                    heapq.heappush(best, (-dv, int(v)))
                    if len(best) > ef:
                        heapq.heappop(best)
        out = sorted(((-nd, v) for nd, v in best))[:k]
        ids = np.array([v for _, v in out], np.int64)
        demand = QueryDemand(ssd_ios=ios, ssd_bytes=ios * 4096,
                             cpu_dist_ops=ios * self.data.shape[1],
                             graph_hops=ios)
        return BaselineResult(ids, demand, IOStats(ios=ios,
                                                   bytes_read=ios * 4096))
