"""Filtered search: per-row metadata attributes + predicate objects
(DESIGN.md §11).

Real deployments multiplex many workloads over one index: recsys queries
constrained to a category, RAG queries constrained to a tenant's corpus,
freshness windows over an ingest timestamp.  The paper's pipeline has a
natural place to honor such constraints *cheaply*: candidate collection
(stages ②③⑤) already materializes explicit id lists before the ADC scan,
so a row mask applied THERE shrinks the scan itself — selectivity reduces
work — instead of discarding rows after top-k (which silently degrades
recall for selective predicates).

Two pieces, both purely functional:

* :class:`AttributeTable` — named small-int/categorical columns (e.g.
  ``category``/``tenant``/``timestamp``), one value per row, carried
  through every tier: the sealed segment (ID-space, survives compaction
  and snapshots) and the delta segment (appended alongside vectors).
  Missing values are :data:`UNSET` (``-1``) and NEVER match a predicate
  — fail-closed, which is what makes tenant base predicates an isolation
  boundary rather than a convention.
* Predicates — hashable frozen dataclasses :class:`Eq` / :class:`In` /
  :class:`Range` / :class:`And`, compiled against a table to a boolean
  row mask by :meth:`Predicate.mask`.  Hashability is load-bearing: the
  predicate folds into coalescing keys (``serve/client.coalesce_key``)
  so a filtered request can never attach to an unfiltered leader.
  ``predicate_to_json``/``predicate_from_json`` round-trip the grammar
  over the HTTP edge.

Attribute values are conventionally non-negative ints; categorical
string attributes are dictionary-encoded by the caller.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Mapping, Optional, Sequence, Tuple

import numpy as np

__all__ = ["UNSET", "AttributeTable", "Predicate", "Eq", "In", "Range",
           "And", "combine", "predicate_to_json", "predicate_from_json"]

#: Sentinel for "this row has no value in this column".  Rows whose
#: column is UNSET never match any predicate over that column.
UNSET = -1


# ---------------------------------------------------------------------------
# Attribute store
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AttributeTable:
    """Columnar per-row metadata, snapshotted functionally like
    :class:`~repro.core.segments.DeltaSegment`: every mutation returns a
    NEW table, so a published :class:`~repro.core.segments.IndexView`
    holds attributes that can never change under its readers.

    A column absent from ``columns`` reads as all-:data:`UNSET`, so
    tables built before a column existed keep working (and keep failing
    closed) when new ingest starts carrying it.
    """

    n: int
    columns: Mapping[str, np.ndarray] = \
        dataclasses.field(default_factory=dict)

    @staticmethod
    def _as_col(values, n: int) -> np.ndarray:
        col = np.asarray(values, np.int64)
        if col.shape != (n,):
            raise ValueError(
                f"attribute column must be shape ({n},), got {col.shape}")
        return col

    @classmethod
    def empty(cls, n: int) -> "AttributeTable":
        return cls(n=int(n), columns={})

    @classmethod
    def from_columns(cls, n: int,
                     values: Optional[Mapping[str, Sequence[int]]]
                     ) -> "AttributeTable":
        if not values:
            return cls.empty(n)
        return cls(n=int(n), columns={
            str(name): cls._as_col(col, int(n))
            for name, col in values.items()})

    def lookup(self, name: str, rows: np.ndarray) -> np.ndarray:
        """Column values at ``rows``; all-:data:`UNSET` for a column this
        table has never seen (fail-closed)."""
        rows = np.asarray(rows, np.int64)
        col = self.columns.get(name)
        if col is None:
            return np.full(rows.shape, UNSET, np.int64)
        return col[rows]

    def append(self, count: int,
               values: Optional[Mapping[str, Sequence[int]]] = None
               ) -> "AttributeTable":
        """``count`` new rows; ``values`` maps column -> per-row ints.
        Columns absent on either side backfill with :data:`UNSET`."""
        count = int(count)
        new = {str(k): self._as_col(v, count)
               for k, v in (values or {}).items()}
        cols: Dict[str, np.ndarray] = {}
        for name in set(self.columns) | set(new):
            old_col = self.columns.get(
                name, np.full(self.n, UNSET, np.int64))
            new_col = new.get(name, np.full(count, UNSET, np.int64))
            cols[name] = np.concatenate([old_col, new_col])
        return AttributeTable(n=self.n + count, columns=cols)

    def extend(self, other: "AttributeTable") -> "AttributeTable":
        """Concatenate another table's rows after this one's (compaction:
        sealed attrs + the sealed delta prefix's attrs)."""
        return self.append(other.n, {name: other.lookup(name,
                                                        np.arange(other.n))
                                     for name in other.columns})

    def head(self, count: int) -> "AttributeTable":
        return AttributeTable(
            n=int(count),
            columns={k: v[:count] for k, v in self.columns.items()})

    def drop_prefix(self, count: int) -> "AttributeTable":
        return AttributeTable(
            n=self.n - int(count),
            columns={k: v[count:] for k, v in self.columns.items()})


# ---------------------------------------------------------------------------
# Predicates
# ---------------------------------------------------------------------------

class Predicate:
    """Base class; concrete predicates are hashable frozen dataclasses."""

    def mask(self, attrs: AttributeTable, rows: np.ndarray) -> np.ndarray:
        """Boolean mask over ``rows`` (row indices into ``attrs``)."""
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class Eq(Predicate):
    column: str
    value: int

    def mask(self, attrs: AttributeTable, rows: np.ndarray) -> np.ndarray:
        vals = attrs.lookup(self.column, rows)
        return (vals != UNSET) & (vals == int(self.value))


@dataclasses.dataclass(frozen=True)
class In(Predicate):
    column: str
    values: Tuple[int, ...]

    def __post_init__(self):
        # canonical sorted-unique tuple: In("c", (2, 1, 2)) == In("c",
        # (1, 2)) — equal predicates must coalesce to equal keys
        object.__setattr__(
            self, "values", tuple(sorted({int(v) for v in self.values})))

    def mask(self, attrs: AttributeTable, rows: np.ndarray) -> np.ndarray:
        vals = attrs.lookup(self.column, rows)
        return (vals != UNSET) & np.isin(
            vals, np.asarray(self.values, np.int64))


@dataclasses.dataclass(frozen=True)
class Range(Predicate):
    """Half-open interval ``lo <= value < hi``."""

    column: str
    lo: int
    hi: int

    def mask(self, attrs: AttributeTable, rows: np.ndarray) -> np.ndarray:
        vals = attrs.lookup(self.column, rows)
        return (vals != UNSET) & (vals >= int(self.lo)) \
            & (vals < int(self.hi))


@dataclasses.dataclass(frozen=True)
class And(Predicate):
    children: Tuple[Predicate, ...]

    def __post_init__(self):
        object.__setattr__(self, "children", tuple(self.children))

    def mask(self, attrs: AttributeTable, rows: np.ndarray) -> np.ndarray:
        rows = np.asarray(rows, np.int64)
        out = np.ones(rows.shape, bool)
        for child in self.children:
            out &= child.mask(attrs, rows)
        return out


def combine(a: Optional[Predicate],
            b: Optional[Predicate]) -> Optional[Predicate]:
    """Conjunction with ``None`` = no constraint.  The tenant layer uses
    this to stamp a base predicate UNDER a request's own filter — the
    request can only ever narrow its tenant's view, never widen it."""
    if a is None:
        return b
    if b is None:
        return a
    return And((a, b))


# ---------------------------------------------------------------------------
# Wire form (HTTP edge)
# ---------------------------------------------------------------------------

def predicate_to_json(p: Optional[Predicate]):
    """``{"eq": [col, v]}`` / ``{"in": [col, [...]]}`` /
    ``{"range": [col, lo, hi]}`` / ``{"and": [...]}``."""
    if p is None:
        return None
    if isinstance(p, Eq):
        return {"eq": [p.column, int(p.value)]}
    if isinstance(p, In):
        return {"in": [p.column, [int(v) for v in p.values]]}
    if isinstance(p, Range):
        return {"range": [p.column, int(p.lo), int(p.hi)]}
    if isinstance(p, And):
        return {"and": [predicate_to_json(c) for c in p.children]}
    raise TypeError(f"not a predicate: {type(p).__name__}")


def predicate_from_json(doc) -> Optional[Predicate]:
    if doc is None:
        return None
    if not isinstance(doc, dict) or len(doc) != 1:
        raise ValueError(
            "predicate must be a one-key object: eq/in/range/and")
    (kind, spec), = doc.items()
    try:
        if kind == "eq":
            col, value = spec
            return Eq(str(col), int(value))
        if kind == "in":
            col, values = spec
            return In(str(col), tuple(int(v) for v in values))
        if kind == "range":
            col, lo, hi = spec
            return Range(str(col), int(lo), int(hi))
        if kind == "and":
            kids = tuple(predicate_from_json(c) for c in spec)
            if any(k is None for k in kids):
                raise ValueError("null child")
            return And(kids)
    except (TypeError, ValueError) as exc:
        raise ValueError(f"malformed {kind!r} predicate: {exc}") from None
    raise ValueError(f"unknown predicate kind {kind!r}")
