"""FusionANNS engine: offline index build (§3 Offline) + the 8-step online
query pipeline (§3 Online).

Tier placement in this build (DESIGN.md §2):
  * navigation graph + posting-list vector-IDs  -> host numpy ("DRAM")
  * PQ codes + codebooks                        -> jax arrays ("HBM";
    sharded via core.distributed on a mesh)
  * raw vectors                                 -> SSDSim (4 KB page model)
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ANNSConfig
from repro.core import clustering, navgraph as ng, pq
from repro.core.io_sim import IOStats, SSDSim, StorageLayout
from repro.core.rerank import RerankResult, heuristic_rerank
from repro.kernels.pq_adc.ops import pq_adc, pq_adc_topk


@functools.partial(jax.jit, static_argnames=("top_n", "use_kernel"))
def _scan_topn(cand_codes, lut, n_valid, top_n: int, use_kernel: bool):
    """Bucketed ADC scan + top-n with padded-slot masking."""
    d = pq_adc(cand_codes, lut, use_kernel=use_kernel)
    d = jnp.where(jnp.arange(d.shape[0]) < n_valid, d, jnp.inf)
    neg, idx = jax.lax.top_k(-d, top_n)
    return -neg, idx


@dataclasses.dataclass
class QueryStats:
    ios: int
    pages_requested: int
    buffer_hits: int
    ssd_bytes: int
    h2d_bytes: int               # vector-IDs sent CPU -> accelerator
    candidates_scanned: int      # PQ distance calculations
    rerank_batches: int
    rerank_scored: int
    early_stopped: bool
    t_graph: float = 0.0
    t_scan: float = 0.0
    t_rerank: float = 0.0


@dataclasses.dataclass
class QueryResult:
    ids: np.ndarray
    dists: np.ndarray
    stats: QueryStats


@dataclasses.dataclass
class FusionANNSIndex:
    cfg: ANNSConfig
    codebook: pq.PQCodebook          # HBM tier
    codes: jax.Array                 # (N, M) uint8, HBM tier
    posting: clustering.PostingLists  # DRAM tier: IDs only
    graph: ng.NavGraph               # DRAM tier
    ssd: SSDSim                      # SSD tier: raw vectors
    use_kernel: bool = False         # Pallas interpret is slow on CPU hosts
    # beyond-paper: OPQ rotation (core/opq.py); applied to queries before
    # the LUT build only — clustering/graph/re-rank stay in raw space.
    rotation: Optional[np.ndarray] = None

    def _lut_query(self, q: np.ndarray) -> np.ndarray:
        return q @ self.rotation if self.rotation is not None else q

    # ------------------------------------------------------------------ build
    @staticmethod
    def build(data: np.ndarray, cfg: ANNSConfig, seed: int = 0,
              *, intra_merge: bool = True, use_buffer: bool = True,
              optimized_layout: bool = True,
              use_opq: bool = False) -> "FusionANNSIndex":
        n, d = data.shape
        rng = np.random.default_rng(seed)
        key = jax.random.key(seed)
        # 1. posting lists (hierarchical balanced clustering + Eq.2 replicas)
        n_clusters = max(4, int(n * cfg.n_posting_fraction))
        posting = clustering.build_posting_lists(
            rng, data.astype(np.float32), n_clusters,
            eps=cfg.replication_eps, max_replicas=cfg.max_replicas)
        # 2. navigation graph over centroids (DRAM)
        graph = ng.build_navgraph(posting.centroids, degree=cfg.graph_degree)
        # 3. PQ codes pinned in HBM (optionally OPQ-rotated — beyond-paper)
        rotation = None
        if use_opq:
            from repro.core.opq import train_opq
            ocb, _ = train_opq(key, data, cfg.pq_m, cfg.pq_nbits)
            cb, rotation = ocb.cb, ocb.rotation
            codes = pq.encode(cb, jnp.asarray(
                data.astype(np.float32) @ rotation))
        else:
            cb = pq.train_codebooks(key, jnp.asarray(data, jnp.float32),
                                    cfg.pq_m, cfg.pq_nbits)
            codes = pq.encode(cb, jnp.asarray(data, jnp.float32))
        # 4. raw vectors on SSD, bucketed by primary centroid (§4.3)
        layout = StorageLayout.build(
            posting.primary, posting.n_clusters,
            vec_bytes=data.dtype.itemsize * d, page_bytes=cfg.page_bytes,
            optimized=optimized_layout)
        ssd = SSDSim(data, layout, buffer_pages=cfg.dram_buffer_pages,
                     intra_merge=intra_merge, use_buffer=use_buffer)
        # NOTE: intermediate posting-list *contents* are discarded here —
        # only the ID metadata survives in DRAM (paper §4.1).
        return FusionANNSIndex(cfg=cfg, codebook=cb, codes=codes,
                               posting=posting, graph=graph, ssd=ssd,
                               rotation=rotation)

    # --------------------------------------------------------------- updates
    # SPFresh-style incremental maintenance (the paper's cited sibling,
    # SOSP'23): appends go to fresh SSD pages bucketed by their primary
    # centroid; deletes are tombstoned and filtered at candidate collection.
    tombstones: Optional[np.ndarray] = None

    def insert(self, vectors: np.ndarray) -> np.ndarray:
        """Append vectors to all three tiers.  Returns their new ids."""
        from repro.core.clustering import assign_with_replication
        n_old = len(self.ssd.vectors)
        new_pl = assign_with_replication(
            vectors.astype(np.float32), self.posting.centroids,
            eps=self.cfg.replication_eps, max_replicas=self.cfg.max_replicas)
        new_ids = np.arange(n_old, n_old + len(vectors), dtype=np.int64)
        # DRAM tier: extend the ID metadata
        for c in range(self.posting.n_clusters):
            mem = new_pl.members[c]
            if len(mem):
                self.posting.members[c] = np.concatenate(
                    [self.posting.members[c],
                     (mem + n_old).astype(np.int32)])
        self.posting.primary = np.concatenate(
            [self.posting.primary, new_pl.primary])
        # HBM tier: encode + append PQ codes (rotated if OPQ)
        enc_in = vectors.astype(np.float32)
        if self.rotation is not None:
            enc_in = enc_in @ self.rotation
        new_codes = pq.encode(self.codebook, jnp.asarray(enc_in))
        self.codes = jnp.concatenate([self.codes, new_codes], axis=0)
        # SSD tier: fresh pages, bucketed by primary centroid
        lay = self.ssd.layout
        order = np.argsort(new_pl.primary, kind="stable")
        new_pages = lay.n_pages + (np.arange(len(vectors))
                                   // lay.per_page)
        page_of = np.empty(len(vectors), np.int64)
        page_of[order] = new_pages
        lay.page_of = np.concatenate([lay.page_of, page_of])
        lay.n_pages = int(lay.page_of.max()) + 1
        self.ssd.vectors = np.concatenate(
            [self.ssd.vectors, vectors.astype(self.ssd.vectors.dtype)])
        if self.tombstones is not None:
            self.tombstones = np.concatenate(
                [self.tombstones, np.zeros(len(vectors), bool)])
        return new_ids

    def delete(self, ids: np.ndarray) -> None:
        """Tombstone ids (compaction is an offline rebuild, as in SPFresh)."""
        if self.tombstones is None:
            self.tombstones = np.zeros(len(self.ssd.vectors), bool)
        self.tombstones[np.asarray(ids, np.int64)] = True

    # ------------------------------------------------------------------ query
    def candidate_ids(self, query: np.ndarray, top_m: int,
                      dedup: bool = True) -> np.ndarray:
        """Stages ②③⑤: graph traversal -> ID collection -> dedup."""
        cids = ng.search(self.graph, query.astype(np.float32), top_m)
        ids = np.concatenate([self.posting.members[c] for c in cids]) \
            if len(cids) else np.zeros((0,), np.int32)
        if dedup:
            ids = np.unique(ids)
        if self.tombstones is not None and len(ids):
            ids = ids[~self.tombstones[ids]]
        return ids

    def query(self, query: np.ndarray, *, k: Optional[int] = None,
              top_m: Optional[int] = None, top_n: Optional[int] = None,
              disable_early_stop: bool = False) -> QueryResult:
        cfg = self.cfg
        k = k or cfg.top_k
        top_m = top_m or cfg.top_m
        top_n = top_n or cfg.top_n

        t0 = time.perf_counter()
        ids = self.candidate_ids(query, top_m)        # ②③⑤ (host)
        t1 = time.perf_counter()

        # ①④⑥⑦: LUT + ADC scan + top-n on the accelerator.  Only the
        # vector-IDs cross the host->device boundary (4 B each).  IDs are
        # padded to a power-of-two bucket so the jit cache stays warm across
        # queries with different candidate counts.
        lut = pq.adc_lut(self.codebook, jnp.asarray(self._lut_query(query)))
        n_ids = len(ids)
        bucket = max(64, 1 << int(np.ceil(np.log2(max(n_ids, 1)))))
        padded = np.full(bucket, -1, np.int64)
        padded[:n_ids] = ids
        cand_codes = jnp.take(self.codes, jnp.asarray(np.maximum(padded, 0)),
                              axis=0)
        n_eff = min(top_n, n_ids)
        dists, local = _scan_topn(cand_codes, lut, n_ids, min(top_n, bucket),
                                  self.use_kernel)
        local = np.asarray(local)[:n_eff]
        order_ids = ids[local[local < n_ids]]
        t2 = time.perf_counter()

        # ⑧: heuristic re-ranking against the SSD tier (host).
        rr = heuristic_rerank(
            query, order_ids, self.ssd, k,
            batch_size=cfg.rerank_batch, eps=cfg.rerank_eps,
            beta=cfg.rerank_beta, disable_early_stop=disable_early_stop)
        t3 = time.perf_counter()

        stats = QueryStats(
            ios=rr.io.ios, pages_requested=rr.io.pages_requested,
            buffer_hits=rr.io.buffer_hits, ssd_bytes=rr.io.bytes_read,
            h2d_bytes=4 * len(ids), candidates_scanned=len(ids),
            rerank_batches=rr.batches_run, rerank_scored=rr.candidates_scored,
            early_stopped=rr.early_stopped,
            t_graph=t1 - t0, t_scan=t2 - t1, t_rerank=t3 - t2)
        return QueryResult(ids=rr.ids, dists=rr.dists, stats=stats)

    def batch_query(self, queries: np.ndarray, **kw) -> List[QueryResult]:
        return [self.query(q, **kw) for q in queries]

    def query_batch_fused(self, queries: np.ndarray, *,
                          k: Optional[int] = None,
                          top_m: Optional[int] = None,
                          top_n: Optional[int] = None) -> List[QueryResult]:
        """Beyond-paper batched mode (the TPU adaptation's natural shape):
        one ADC scan over the UNION of the batch's candidate ids with all B
        LUTs resident (kernels.pq_adc_batch), per-query masking + top-n.

        Inter-query dedup: concurrent queries share posting lists, so the
        union is much smaller than B x |cand| — the same redundancy insight
        the paper exploits on the SSD tier (§4.3), applied to the HBM scan.
        Re-ranking stays per-query on the host (unchanged semantics)."""
        cfg = self.cfg
        k = k or cfg.top_k
        top_m = top_m or cfg.top_m
        top_n = top_n or cfg.top_n
        B = len(queries)

        t0 = time.perf_counter()
        per_q = [self.candidate_ids(q, top_m) for q in queries]
        union = np.unique(np.concatenate(per_q)) if per_q else \
            np.zeros((0,), np.int64)
        t1 = time.perf_counter()

        u = len(union)
        bucket = max(64, 1 << int(np.ceil(np.log2(max(u, 1)))))
        padded = np.zeros(bucket, np.int64)
        padded[:u] = union
        cand_codes = jnp.take(self.codes, jnp.asarray(padded), axis=0)
        luts = pq.adc_lut_batch(self.codebook, jnp.asarray(
            np.stack([self._lut_query(q) for q in queries])))
        from repro.kernels.pq_adc.ops import pq_adc_batch
        dists = np.asarray(pq_adc_batch(cand_codes, luts,
                                        use_kernel=self.use_kernel))  # (B,bk)
        # per-query mask: only the query's own candidates compete
        pos_of = {int(v): i for i, v in enumerate(union)}
        results: List[QueryResult] = []
        t2 = time.perf_counter()
        for qi, q in enumerate(queries):
            ids_q = per_q[qi]
            cols = np.fromiter((pos_of[int(v)] for v in ids_q), np.int64,
                               len(ids_q))
            d_q = dists[qi, cols]
            order_ids = ids_q[np.argsort(d_q)[:min(top_n, len(ids_q))]]
            rr = heuristic_rerank(q, order_ids, self.ssd, k,
                                  batch_size=cfg.rerank_batch,
                                  eps=cfg.rerank_eps, beta=cfg.rerank_beta)
            stats = QueryStats(
                ios=rr.io.ios, pages_requested=rr.io.pages_requested,
                buffer_hits=rr.io.buffer_hits, ssd_bytes=rr.io.bytes_read,
                h2d_bytes=4 * u // B,            # amortised union transfer
                candidates_scanned=u,            # union, ONCE per batch
                rerank_batches=rr.batches_run,
                rerank_scored=rr.candidates_scored,
                early_stopped=rr.early_stopped,
                t_graph=(t1 - t0) / B, t_scan=(t2 - t1) / B)
            results.append(QueryResult(ids=rr.ids, dists=rr.dists,
                                       stats=stats))
        return results


# ---------------------------------------------------------------------------
# Evaluation helpers
# ---------------------------------------------------------------------------

def ground_truth(data: np.ndarray, queries: np.ndarray, k: int,
                 chunk: int = 4096) -> np.ndarray:
    """Exact top-k ids per query (brute force, chunked)."""
    q = queries.astype(np.float32)
    out = np.empty((len(q), k), np.int64)
    d2_best = None
    for qi in range(0, len(q), 128):
        qb = q[qi:qi + 128]
        d2 = np.empty((len(qb), len(data)), np.float32)
        for s in range(0, len(data), chunk):
            blk = data[s:s + chunk].astype(np.float32)
            d2[:, s:s + chunk] = (np.sum(qb ** 2, -1)[:, None]
                                  - 2.0 * qb @ blk.T
                                  + np.sum(blk ** 2, -1)[None])
        idx = np.argpartition(d2, k - 1, axis=1)[:, :k]
        dd = np.take_along_axis(d2, idx, axis=1)
        out[qi:qi + len(qb)] = np.take_along_axis(
            idx, np.argsort(dd, axis=1), axis=1)
    return out


def recall_at_k(result_ids: np.ndarray, gt_ids: np.ndarray, k: int) -> float:
    """Recall@k — |result ∩ gt| / k, averaged over queries."""
    hits = 0
    for r, g in zip(np.atleast_2d(result_ids), np.atleast_2d(gt_ids)):
        hits += len(set(r[:k].tolist()) & set(g[:k].tolist()))
    return hits / (len(np.atleast_2d(gt_ids)) * k)
