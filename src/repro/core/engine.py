"""FusionANNS engine: offline index build (§3 Offline) + the 8-step online
query pipeline (§3 Online).

Tier placement in this build (DESIGN.md §2):
  * navigation graph + posting-list vector-IDs  -> host numpy ("DRAM")
  * PQ codes + codebooks                        -> jax arrays ("HBM";
    sharded via core.distributed on a mesh)
  * raw vectors                                 -> SSDSim (4 KB page model)
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ANNSConfig
from repro.core import clustering, navgraph as ng, pq
# QueryStats / QueryResult live in executor.py now; re-exported here so
# ``from repro.core.engine import QueryResult`` keeps working.
from repro.core.executor import (PlanOverrides, QueryExecutor,  # noqa: F401
                                 QueryPlan, QueryResult, QueryStats)
from repro.core.futures import BatchTicket, QueryFuture  # noqa: F401
from repro.core.io_sim import IOStats, SSDSim, StorageLayout


@dataclasses.dataclass
class FusionANNSIndex:
    cfg: ANNSConfig
    codebook: pq.PQCodebook          # HBM tier
    codes: jax.Array                 # (N, M) uint8, HBM tier
    posting: clustering.PostingLists  # DRAM tier: IDs only
    graph: ng.NavGraph               # DRAM tier
    ssd: SSDSim                      # SSD tier: raw vectors
    use_kernel: bool = False         # Pallas interpret is slow on CPU hosts
    # beyond-paper: OPQ rotation (core/opq.py); applied to queries before
    # the LUT build only — clustering/graph/re-rank stay in raw space.
    rotation: Optional[np.ndarray] = None

    def _lut_query(self, q: np.ndarray) -> np.ndarray:
        return q @ self.rotation if self.rotation is not None else q

    # ------------------------------------------------------------------ build
    @staticmethod
    def build(data: np.ndarray, cfg: ANNSConfig, seed: int = 0,
              *, intra_merge: bool = True, use_buffer: bool = True,
              optimized_layout: bool = True,
              use_opq: bool = False) -> "FusionANNSIndex":
        n, d = data.shape
        rng = np.random.default_rng(seed)
        key = jax.random.key(seed)
        # 1. posting lists (hierarchical balanced clustering + Eq.2 replicas)
        n_clusters = max(4, int(n * cfg.n_posting_fraction))
        posting = clustering.build_posting_lists(
            rng, data.astype(np.float32), n_clusters,
            eps=cfg.replication_eps, max_replicas=cfg.max_replicas)
        # 2. navigation graph over centroids (DRAM)
        graph = ng.build_navgraph(posting.centroids, degree=cfg.graph_degree)
        # 3. PQ codes pinned in HBM (optionally OPQ-rotated — beyond-paper)
        rotation = None
        if use_opq:
            from repro.core.opq import train_opq
            ocb, _ = train_opq(key, data, cfg.pq_m, cfg.pq_nbits)
            cb, rotation = ocb.cb, ocb.rotation
            codes = pq.encode(cb, jnp.asarray(
                data.astype(np.float32) @ rotation))
        else:
            cb = pq.train_codebooks(key, jnp.asarray(data, jnp.float32),
                                    cfg.pq_m, cfg.pq_nbits)
            codes = pq.encode(cb, jnp.asarray(data, jnp.float32))
        # 4. raw vectors on SSD, bucketed by primary centroid (§4.3)
        layout = StorageLayout.build(
            posting.primary, posting.n_clusters,
            vec_bytes=data.dtype.itemsize * d, page_bytes=cfg.page_bytes,
            optimized=optimized_layout)
        ssd = SSDSim(data, layout, buffer_pages=cfg.dram_buffer_pages,
                     intra_merge=intra_merge, use_buffer=use_buffer)
        # NOTE: intermediate posting-list *contents* are discarded here —
        # only the ID metadata survives in DRAM (paper §4.1).
        return FusionANNSIndex(cfg=cfg, codebook=cb, codes=codes,
                               posting=posting, graph=graph, ssd=ssd,
                               rotation=rotation)

    # --------------------------------------------------------------- updates
    # SPFresh-style incremental maintenance (the paper's cited sibling,
    # SOSP'23): appends go to fresh SSD pages bucketed by their primary
    # centroid; deletes are tombstoned and filtered at candidate collection.
    tombstones: Optional[np.ndarray] = None

    def insert(self, vectors: np.ndarray) -> np.ndarray:
        """Append vectors to all three tiers.  Returns their new ids."""
        from repro.core.clustering import assign_with_replication
        n_old = len(self.ssd.vectors)
        new_pl = assign_with_replication(
            vectors.astype(np.float32), self.posting.centroids,
            eps=self.cfg.replication_eps, max_replicas=self.cfg.max_replicas)
        new_ids = np.arange(n_old, n_old + len(vectors), dtype=np.int64)
        # DRAM tier: extend the ID metadata
        for c in range(self.posting.n_clusters):
            mem = new_pl.members[c]
            if len(mem):
                self.posting.members[c] = np.concatenate(
                    [self.posting.members[c],
                     (mem + n_old).astype(np.int32)])
        self.posting.primary = np.concatenate(
            [self.posting.primary, new_pl.primary])
        # HBM tier: encode + append PQ codes (rotated if OPQ)
        enc_in = vectors.astype(np.float32)
        if self.rotation is not None:
            enc_in = enc_in @ self.rotation
        new_codes = pq.encode(self.codebook, jnp.asarray(enc_in))
        self.codes = jnp.concatenate([self.codes, new_codes], axis=0)
        # SSD tier: fresh pages, bucketed by primary centroid
        lay = self.ssd.layout
        order = np.argsort(new_pl.primary, kind="stable")
        new_pages = lay.n_pages + (np.arange(len(vectors))
                                   // lay.per_page)
        page_of = np.empty(len(vectors), np.int64)
        page_of[order] = new_pages
        lay.page_of = np.concatenate([lay.page_of, page_of])
        lay.n_pages = int(lay.page_of.max()) + 1
        self.ssd.vectors = np.concatenate(
            [self.ssd.vectors, vectors.astype(self.ssd.vectors.dtype)])
        if self.tombstones is not None:
            self.tombstones = np.concatenate(
                [self.tombstones, np.zeros(len(vectors), bool)])
        return new_ids

    def delete(self, ids: np.ndarray) -> None:
        """Tombstone ids (compaction is an offline rebuild, as in SPFresh)."""
        if self.tombstones is None:
            self.tombstones = np.zeros(len(self.ssd.vectors), bool)
        self.tombstones[np.asarray(ids, np.int64)] = True

    # ------------------------------------------------------------------ query
    def candidate_ids(self, query: np.ndarray, top_m: int,
                      dedup: bool = True) -> np.ndarray:
        """Stages ②③⑤: graph traversal -> ID collection -> dedup."""
        cids = ng.search(self.graph, query.astype(np.float32), top_m)
        ids = np.concatenate([self.posting.members[c] for c in cids]) \
            if len(cids) else np.zeros((0,), np.int32)
        if dedup:
            ids = np.unique(ids)
        if self.tombstones is not None and len(ids):
            ids = ids[~self.tombstones[ids]]
        return ids

    @property
    def executor(self) -> QueryExecutor:
        """The unified QueryPlan -> QueryExecutor pipeline (core.executor).
        Shared by all three public query paths; call
        ``.executor.attach_mesh(mesh)`` to row-shard the HBM tier."""
        ex = getattr(self, "_executor", None)
        if ex is None:
            ex = QueryExecutor(self)
            self._executor = ex
        return ex

    def make_executor(self, mesh=None) -> QueryExecutor:
        """A FRESH executor over this index (multi-replica serving: each
        replica owns its own executor, optionally attached to a disjoint
        sub-mesh from ``launch.mesh.split_mesh``).  All executors share
        the index's tiers — posting lists, tombstones, SSD sim, and the
        ``codes`` binding — so inserts/deletes propagate to every replica:
        an insert rebinds ``self.codes`` and each executor re-places its
        HBM shard on its next dispatch."""
        return QueryExecutor(self, mesh=mesh)

    def plan(self, *, k: Optional[int] = None, top_m: Optional[int] = None,
             top_n: Optional[int] = None, **kw) -> QueryPlan:
        return QueryPlan.from_config(self.cfg, k=k, top_m=top_m,
                                     top_n=top_n, **kw)

    def submit(self, queries: np.ndarray, *, k: Optional[int] = None,
               top_m: Optional[int] = None, top_n: Optional[int] = None,
               overrides: Optional[List[Optional[PlanOverrides]]] = None,
               **kw) -> BatchTicket:
        """Futures-first entry point (DESIGN.md §3): host traversal + async
        device dispatch, then return immediately.  ``kw`` passes plan knobs
        through (``window=``, ``inflight_depth=``, ``deadline_s=``, ...);
        ``overrides`` carries per-query ``PlanOverrides`` for mixed-``k``
        windows."""
        return self.executor.submit(
            queries, self.plan(k=k, top_m=top_m, top_n=top_n, **kw),
            overrides=overrides)

    def search(self, request):
        """Typed single-request serve (DESIGN.md §6): accepts a
        :class:`~repro.serve.client.SearchRequest` and returns its
        :class:`~repro.serve.client.SearchResponse` through the shared
        executor's Backend-protocol path — same ids as :meth:`query`."""
        return self.executor.submit(request).result()

    def query(self, query: np.ndarray, *, k: Optional[int] = None,
              top_m: Optional[int] = None, top_n: Optional[int] = None,
              disable_early_stop: bool = False) -> QueryResult:
        """Single query == a window of one through the unified executor."""
        return self.executor.run_one(query, self.plan(
            k=k, top_m=top_m, top_n=top_n,
            disable_early_stop=disable_early_stop))

    def batch_query(self, queries: np.ndarray, *, k: Optional[int] = None,
                    top_m: Optional[int] = None, top_n: Optional[int] = None,
                    disable_early_stop: bool = False) -> List[QueryResult]:
        """Per-query windows (window=1): no inter-query candidate sharing."""
        return self.executor.run(queries, self.plan(
            k=k, top_m=top_m, top_n=top_n,
            disable_early_stop=disable_early_stop, window=1))

    def query_batch_fused(self, queries: np.ndarray, *,
                          k: Optional[int] = None,
                          top_m: Optional[int] = None,
                          top_n: Optional[int] = None) -> List[QueryResult]:
        """Beyond-paper batched mode (the TPU adaptation's natural shape):
        one ADC scan over the UNION of the batch's candidate ids with all B
        LUTs resident, per-query masking + top-n — inter-query dedup is the
        paper's §4.3 redundancy insight applied to the HBM scan.  One window
        through the unified executor; identical per-query semantics."""
        return self.executor.run(queries, self.plan(
            k=k, top_m=top_m, top_n=top_n))


# ---------------------------------------------------------------------------
# Evaluation helpers
# ---------------------------------------------------------------------------

def ground_truth(data: np.ndarray, queries: np.ndarray, k: int,
                 chunk: int = 4096) -> np.ndarray:
    """Exact top-k ids per query (brute force, chunked)."""
    q = queries.astype(np.float32)
    out = np.empty((len(q), k), np.int64)
    d2_best = None
    for qi in range(0, len(q), 128):
        qb = q[qi:qi + 128]
        d2 = np.empty((len(qb), len(data)), np.float32)
        for s in range(0, len(data), chunk):
            blk = data[s:s + chunk].astype(np.float32)
            d2[:, s:s + chunk] = (np.sum(qb ** 2, -1)[:, None]
                                  - 2.0 * qb @ blk.T
                                  + np.sum(blk ** 2, -1)[None])
        idx = np.argpartition(d2, k - 1, axis=1)[:, :k]
        dd = np.take_along_axis(d2, idx, axis=1)
        out[qi:qi + len(qb)] = np.take_along_axis(
            idx, np.argsort(dd, axis=1), axis=1)
    return out


def recall_at_k(result_ids: np.ndarray, gt_ids: np.ndarray, k: int) -> float:
    """Recall@k — |result ∩ gt| / k, averaged over queries."""
    hits = 0
    for r, g in zip(np.atleast_2d(result_ids), np.atleast_2d(gt_ids)):
        hits += len(set(r[:k].tolist()) & set(g[:k].tolist()))
    return hits / (len(np.atleast_2d(gt_ids)) * k)
