"""FusionANNS engine: offline index build (§3 Offline) + the 8-step online
query pipeline (§3 Online).

Tier placement in this build (DESIGN.md §2):
  * navigation graph + posting-list vector-IDs  -> host numpy ("DRAM")
  * PQ codes + codebooks                        -> jax arrays ("HBM";
    sharded via core.distributed on a mesh)
  * raw vectors                                 -> SSDSim (4 KB page model)

Updates (DESIGN.md §10): the index is SEGMENTED.  The built tiers are
immutable sealed segments described by one epoch-stamped
:class:`~repro.core.segments.IndexView`; inserts land in a small mutable
delta segment (scanned exactly, merged after the PQ scan + re-rank),
deletes tombstone in the owning segment, and :meth:`compact` — usually
driven by the background :class:`~repro.core.segments.SegmentCompactor`
— seals the delta into the immutable tiers under the ``compaction``-
ranked witness lock.  Readers never lock: they pin ``index.view()`` once
per scan window.
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.concurrency.witness import make_condition, make_lock
from repro.configs.base import ANNSConfig
from repro.core import clustering, navgraph as ng, pq
# QueryStats / QueryResult live in executor.py now; re-exported here so
# ``from repro.core.engine import QueryResult`` keeps working.
from repro.core.executor import (PlanOverrides, QueryExecutor,  # noqa: F401
                                 QueryPlan, QueryResult, QueryStats)
from repro.core.filters import AttributeTable
from repro.core.futures import BatchTicket, QueryFuture  # noqa: F401
from repro.core.io_sim import IOStats, SSDSim, StorageLayout
from repro.core.segments import DeltaSegment, IndexView, SegmentCompactor

# v2 (DESIGN.md §11): + per-row attribute columns and the seal-time purge
# id map (``id_of``).  v1 snapshots still load — identity id map, no
# attributes.
SNAPSHOT_FORMAT_VERSION = 2
_SNAPSHOT_COMPAT_VERSIONS = (1, 2)
_SNAPSHOT_MANIFEST = "manifest.json"
_SNAPSHOT_ARRAYS = "arrays.npz"


class FusionANNSIndex:
    """The four-tier index with segmented streaming updates.

    Immutable-per-epoch state (codes, posting lists, sealed tombstones,
    nav graph, delta segment) lives in ``self._view`` — an
    :class:`IndexView` published by one atomic reference assignment under
    ``_mut_lock`` (rank ``compaction``).  Readers access it lock-free via
    :meth:`view` / the compatibility properties below; mutators
    (:meth:`insert`, :meth:`delete`, :meth:`compact`) never let a reader
    observe torn multi-tier state because every published view's tiers
    describe exactly the same id range.
    """

    def __init__(self, cfg: ANNSConfig, codebook: pq.PQCodebook,
                 codes: jax.Array, posting: clustering.PostingLists,
                 graph: ng.NavGraph, ssd: SSDSim,
                 use_kernel: bool = False,
                 rotation: Optional[np.ndarray] = None,
                 tombstones: Optional[np.ndarray] = None,
                 attributes=None, id_of: Optional[np.ndarray] = None):
        self.cfg = cfg
        self.codebook = codebook                 # HBM tier
        self.ssd = ssd                           # SSD tier: raw vectors
        self.use_kernel = use_kernel             # Pallas interpret is slow on CPU
        # beyond-paper: OPQ rotation (core/opq.py); applied to queries
        # before the LUT build only — clustering/graph/re-rank raw space.
        self.rotation = rotation
        # id-space size: with a seal-time-purged snapshot the tombstone
        # array covers MORE ids than there are physical code rows
        n_ids = (int(codes.shape[0]) if tombstones is None
                 else int(len(tombstones)))
        tomb = (np.zeros(n_ids, bool) if tombstones is None
                else np.asarray(tombstones, bool))
        self._mut_lock = make_lock("compaction")
        self._mut_cond = make_condition("compaction", self._mut_lock)
        self._compacting = False                 # guarded-by: _mut_lock
        self._compactor: Optional[SegmentCompactor] = None
        dim = int(ssd.vectors.shape[1])
        attrs = (AttributeTable.from_columns(n_ids, attributes)
                 if attributes else None)
        self._view = IndexView(
            epoch=0, codes=codes, posting=posting, tombstones=tomb,
            graph=graph, delta=DeltaSegment.empty(n_ids, dim),
            attrs=attrs, id_of=id_of)

    # deepcopy/pickle: locks and threads are per-process; a copy starts
    # with fresh ones (and no background compactor)
    def __getstate__(self):
        state = self.__dict__.copy()
        for key in ("_mut_lock", "_mut_cond", "_compactor", "_executor"):
            state.pop(key, None)
        state["_compacting"] = False
        return state

    def __setstate__(self, state):
        self.__dict__.update(state)
        self._mut_lock = make_lock("compaction")
        self._mut_cond = make_condition("compaction", self._mut_lock)
        self._compactor = None

    # ------------------------------------------------------ view plumbing
    def view(self) -> IndexView:
        """Pin the current epoch's consistent binding of every tier.
        Lock-free: one attribute read of an atomically-published ref."""
        return self._view

    @property
    def epoch(self) -> int:
        """Bumped by every successful insert/delete/compact publish; the
        coalescer keys on it so waiters never attach across a mutation."""
        return self._view.epoch

    @property
    def codes(self) -> jax.Array:
        return self._view.codes

    @property
    def posting(self) -> clustering.PostingLists:
        return self._view.posting

    @property
    def tombstones(self) -> np.ndarray:
        return self._view.tombstones

    @property
    def graph(self) -> ng.NavGraph:
        return self._view.graph

    @property
    def n_total(self) -> int:
        return self._view.n_total

    @property
    def delta_size(self) -> int:
        return len(self._view.delta)

    def _lut_query(self, q: np.ndarray) -> np.ndarray:
        return q @ self.rotation if self.rotation is not None else q

    # ------------------------------------------------------------------ build
    @staticmethod
    def build(data: np.ndarray, cfg: ANNSConfig, seed: int = 0,
              *, intra_merge: bool = True, use_buffer: bool = True,
              optimized_layout: bool = True,
              use_opq: bool = False,
              attributes=None) -> "FusionANNSIndex":
        n, d = data.shape
        rng = np.random.default_rng(seed)
        key = jax.random.key(seed)
        # 1. posting lists (hierarchical balanced clustering + Eq.2 replicas)
        n_clusters = max(4, int(n * cfg.n_posting_fraction))
        posting = clustering.build_posting_lists(
            rng, data.astype(np.float32), n_clusters,
            eps=cfg.replication_eps, max_replicas=cfg.max_replicas)
        # 2. navigation graph over centroids (DRAM)
        graph = ng.build_navgraph(posting.centroids, degree=cfg.graph_degree)
        # 3. PQ codes pinned in HBM (optionally OPQ-rotated — beyond-paper)
        rotation = None
        if use_opq:
            from repro.core.opq import train_opq
            ocb, _ = train_opq(key, data, cfg.pq_m, cfg.pq_nbits)
            cb, rotation = ocb.cb, ocb.rotation
            codes = pq.encode(cb, jnp.asarray(
                data.astype(np.float32) @ rotation))
        else:
            cb = pq.train_codebooks(key, jnp.asarray(data, jnp.float32),
                                    cfg.pq_m, cfg.pq_nbits)
            codes = pq.encode(cb, jnp.asarray(data, jnp.float32))
        # 4. raw vectors on SSD, bucketed by primary centroid (§4.3)
        layout = StorageLayout.build(
            posting.primary, posting.n_clusters,
            vec_bytes=data.dtype.itemsize * d, page_bytes=cfg.page_bytes,
            optimized=optimized_layout)
        ssd = SSDSim(data, layout, buffer_pages=cfg.dram_buffer_pages,
                     intra_merge=intra_merge, use_buffer=use_buffer)
        # NOTE: intermediate posting-list *contents* are discarded here —
        # only the ID metadata survives in DRAM (paper §4.1).
        return FusionANNSIndex(cfg=cfg, codebook=cb, codes=codes,
                               posting=posting, graph=graph, ssd=ssd,
                               rotation=rotation, attributes=attributes)

    # --------------------------------------------------------------- updates
    def insert(self, vectors: np.ndarray,
               attributes=None) -> np.ndarray:
        """Append vectors to the delta segment; returns their new ids.

        ``attributes`` maps column name -> per-row ints (filtered search,
        DESIGN.md §11); columns absent here backfill UNSET and never
        match a predicate.  O(rows) — no clustering, PQ encode, or SSD
        traffic here; sealing is compaction's job.  The ids are published
        atomically WITH the rows (one view swap), so a concurrent query
        either sees none of the batch or a fully-consistent binding of
        all of it — never ids pointing past the end of any tier (the
        pre-segmentation race).
        """
        vecs = np.atleast_2d(np.asarray(vectors, np.float32))
        with self._mut_cond:  # acquires: compaction
            cur = self._view
            new_ids = np.arange(cur.n_total, cur.n_total + len(vecs),
                                dtype=np.int64)
            self._view = dataclasses.replace(
                cur, epoch=cur.epoch + 1,
                delta=cur.delta.append(vecs, attributes))
            self._mut_cond.notify_all()          # wake the compactor
        return new_ids

    def delete(self, ids: np.ndarray) -> None:
        """Tombstone ids in their owning segment (sealed array copy-on-
        write, or a functional delta update).  Deleting an id that was
        never published (``>= n_total``) raises ``ValueError`` instead of
        silently corrupting a tombstone array that does not cover it."""
        idarr = np.atleast_1d(np.asarray(ids, np.int64))
        with self._mut_cond:  # acquires: compaction
            cur = self._view
            if len(idarr) and (int(idarr.min()) < 0
                               or int(idarr.max()) >= cur.n_total):
                bad = idarr[(idarr < 0) | (idarr >= cur.n_total)]
                raise ValueError(
                    f"delete: id(s) {bad[:8].tolist()} not published — "
                    f"index currently holds ids [0, {cur.n_total})")
            sealed = idarr[idarr < cur.n_sealed]
            local = idarr[idarr >= cur.n_sealed] - cur.delta.base
            tomb = cur.tombstones
            if len(sealed):
                tomb = tomb.copy()
                tomb[sealed] = True
            delta = cur.delta.tombstone(local) if len(local) else cur.delta
            self._view = dataclasses.replace(
                cur, epoch=cur.epoch + 1, tombstones=tomb, delta=delta)

    def compact(self, *, wait: bool = True) -> int:
        """Seal the current delta into the immutable tiers.  Returns the
        number of rows sealed (0 if the delta was empty, or if another
        thread is already compacting and ``wait=False``).

        Three phases: (1) claim — snapshot the delta prefix under the
        lock and take the single-compactor token; (2) seal — re-cluster,
        PQ-encode, and extend the SSD tier OUTSIDE the lock (queries,
        inserts, and deletes keep flowing); (3) publish — one
        epoch-bumped view swap under the lock.  Inserts that raced phase
        2 stay in the (shrunk) delta; deletes that raced it land in the
        sealed tombstone array, so nothing is lost either way.
        """
        with self._mut_cond:  # acquires: compaction
            while self._compacting:
                if not wait:
                    return 0
                self._mut_cond.wait()
            view0 = self._view
            d0 = len(view0.delta)
            if d0 == 0:
                return 0
            self._compacting = True
        try:
            self._seal(view0, d0)
        finally:
            with self._mut_cond:  # acquires: compaction
                self._compacting = False
                self._mut_cond.notify_all()
        return d0

    def _seal(self, view0: IndexView, d0: int) -> None:
        """Phase 2+3 of :meth:`compact` — heavy work lock-free, publish
        atomic.  Only ever runs under the ``_compacting`` token, so
        ``view0``'s sealed tiers are still current at publish time (only
        compaction replaces them).

        Rows tombstoned at claim time are PURGED here, not carried: they
        get no PQ code, no posting membership, no SSD page (the ROADMAP
        streaming-index follow-on).  Global ids stay stable — the id
        space keeps counting purged rows — so the published view carries
        ``id_of``/``row_of`` maps between physical rows and ids; both are
        strictly increasing, which keeps candidate lists ascending and
        tie-breaks identical across compactions."""
        delta_vecs = view0.delta.vectors[:d0]
        snap_tomb = view0.delta.tombstoned[:d0]
        n_sealed = view0.n_sealed
        live_local = np.flatnonzero(~snap_tomb)
        n_live = len(live_local)
        live_vecs = delta_vecs[live_local]
        live_gids = (n_sealed + live_local).astype(np.int64)
        # DRAM tier: cluster the SURVIVORS against the EXISTING centroids
        # (deterministic — replicas stay in lockstep replaying the same
        # ops).  Posting members are physical ROW indices.
        members = list(view0.posting.members)
        primary = view0.posting.primary
        new_pl = None
        if n_live:
            new_pl = clustering.assign_with_replication(
                live_vecs, view0.posting.centroids,
                eps=self.cfg.replication_eps,
                max_replicas=self.cfg.max_replicas)
            for c in range(view0.posting.n_clusters):
                mem = new_pl.members[c]
                if len(mem):
                    members[c] = np.concatenate(
                        [members[c],
                         (mem + view0.n_rows).astype(np.int32)])
            primary = np.concatenate([primary, new_pl.primary])
        posting = clustering.PostingLists(
            centroids=view0.posting.centroids, members=members,
            primary=primary)
        # HBM tier: PQ-encode the survivors (rotated if OPQ) + append
        codes = view0.codes
        if n_live:
            enc_in = live_vecs
            if self.rotation is not None:
                enc_in = enc_in @ self.rotation
            new_codes = pq.encode(self.codebook, jnp.asarray(enc_in))
            codes = jnp.concatenate([view0.codes, new_codes], axis=0)
        # SSD tier: fresh pages bucketed by primary centroid (§4.3).
        # Prefix-preserving rebinds — rows a published view can name never
        # move, so readers of any older view stay consistent mid-seal.
        if n_live:
            lay = self.ssd.layout
            order = np.argsort(new_pl.primary, kind="stable")
            new_pages = lay.n_pages + np.arange(n_live) // lay.per_page
            page_of = np.empty(n_live, np.int64)
            page_of[order] = new_pages
            self.ssd.vectors = np.concatenate(
                [self.ssd.vectors,
                 live_vecs.astype(self.ssd.vectors.dtype)])
            lay.page_of = np.concatenate([lay.page_of, page_of])
            lay.n_pages = int(lay.page_of.max()) + 1
        id_of = np.concatenate([view0.id_of, live_gids])
        # publish: sealed tombstones take the PUBLISH-time delta flags —
        # a delete that raced the seal missed the purge above (its row IS
        # encoded), but the candidate-collection tombstone filter still
        # drops it.  Purged ids stay tombstoned-True in id space forever.
        # Attributes are id-space: ALL d0 rows carry over (harmless for
        # purged ids — the tombstone filter runs before any attr lookup).
        with self._mut_cond:  # acquires: compaction
            cur = self._view
            tomb = np.concatenate([cur.tombstones,
                                   cur.delta.tombstoned[:d0]])
            self._view = IndexView(
                epoch=cur.epoch + 1, codes=codes, posting=posting,
                tombstones=tomb, graph=cur.graph,
                delta=cur.delta.drop_prefix(d0),
                attrs=cur.attrs.extend(cur.delta.attrs.head(d0)),
                id_of=id_of)
            self._mut_cond.notify_all()

    def start_compactor(self, *, min_delta: int = 64,
                        poll_s: float = 0.05) -> SegmentCompactor:
        """Run background compaction off the pump thread: seals the delta
        whenever it reaches ``min_delta`` rows."""
        if self._compactor is None:
            self._compactor = SegmentCompactor(
                self, min_delta=min_delta, poll_s=poll_s).start()
        return self._compactor

    def stop_compactor(self, *, flush: bool = False) -> None:
        compactor = self._compactor
        if compactor is not None:
            self._compactor = None
            compactor.stop(flush=flush)

    # ------------------------------------------------------------- snapshots
    def save_snapshot(self, path: str) -> str:
        """Checkpoint every tier — PQ codes + codebooks, nav graph,
        posting lists, SSD layout + raw vectors, tombstones, and the live
        delta segment — to ``path/`` (manifest.json + arrays.npz).

        The view ref is pinned under the compaction lock; materialization
        and file I/O run outside it.  SSD arrays are truncated to the
        view's sealed prefix, so a compaction racing the save cannot leak
        rows the captured view does not publish.  A replica restored via
        :meth:`load_snapshot` answers queries with bit-identical ids.
        """
        with self._mut_cond:  # acquires: compaction
            view = self._view
        n_sealed = view.n_sealed
        n_rows = view.n_rows                  # physical rows (<= n_sealed)
        lay = self.ssd.layout
        page_of = np.asarray(lay.page_of[:n_rows], np.int64)
        arrays: Dict[str, np.ndarray] = {
            "codes": np.asarray(view.codes, np.uint8),
            "codebooks": np.asarray(self.codebook.codebooks, np.float32),
            "graph_points": view.graph.points,
            "graph_neighbors": view.graph.neighbors,
            "posting_centroids": view.posting.centroids,
            "posting_primary": view.posting.primary,
            "posting_members_flat": (
                np.concatenate(view.posting.members)
                if view.posting.n_clusters else np.zeros(0, np.int32)),
            "posting_offsets": np.cumsum(
                [0] + [len(m) for m in view.posting.members]).astype(np.int64),
            "tombstones": view.tombstones,
            "ssd_vectors": np.asarray(self.ssd.vectors[:n_rows]),
            "ssd_page_of": page_of,
            "id_of": view.id_of,
            "delta_vectors": view.delta.vectors,
            "delta_tombstoned": view.delta.tombstoned,
        }
        for name, col in view.attrs.columns.items():
            arrays[f"attr_sealed_{name}"] = col
        for name, col in view.delta.attrs.columns.items():
            arrays[f"attr_delta_{name}"] = col
        if self.rotation is not None:
            arrays["rotation"] = np.asarray(self.rotation, np.float32)
        if view.graph.super_centroids is not None:
            arrays["graph_super_centroids"] = view.graph.super_centroids
            arrays["graph_super_assign"] = view.graph.super_assign
        manifest = {
            "format_version": SNAPSHOT_FORMAT_VERSION,
            "epoch": int(view.epoch),
            "n_sealed": int(n_sealed),
            "n_rows": int(n_rows),
            "attr_sealed_cols": sorted(view.attrs.columns),
            "attr_delta_cols": sorted(view.delta.attrs.columns),
            "use_kernel": bool(self.use_kernel),
            "cfg": dataclasses.asdict(self.cfg),
            "graph_entry": int(view.graph.entry),
            "ssd": {
                "n_pages": int(page_of.max()) + 1 if n_rows else 0,
                "per_page": int(lay.per_page),
                "page_bytes": int(lay.page_bytes),
                "buffer_pages": int(self.ssd.buffer_pages),
                "intra_merge": bool(self.ssd.intra_merge),
                "use_buffer": bool(self.ssd.use_buffer),
            },
        }
        os.makedirs(path, exist_ok=True)
        with open(os.path.join(path, _SNAPSHOT_MANIFEST), "w") as fh:
            json.dump(manifest, fh, indent=1)
        np.savez(os.path.join(path, _SNAPSHOT_ARRAYS), **arrays)
        return path

    @classmethod
    def load_snapshot(cls, path: str) -> "FusionANNSIndex":
        """Rebuild a full index — sealed tiers AND delta segment, at the
        saved epoch — from a :meth:`save_snapshot` directory.  This is how
        ``ReplicaRouter.add_replica`` hydrates a newcomer from disk
        instead of re-clustering/re-encoding from raw data."""
        with open(os.path.join(path, _SNAPSHOT_MANIFEST)) as fh:
            manifest = json.load(fh)
        if manifest["format_version"] not in _SNAPSHOT_COMPAT_VERSIONS:
            raise ValueError(
                f"snapshot format {manifest['format_version']} not in "
                f"{_SNAPSHOT_COMPAT_VERSIONS}")
        with np.load(os.path.join(path, _SNAPSHOT_ARRAYS)) as npz:
            arr = {k: npz[k] for k in npz.files}
        cfg = ANNSConfig(**manifest["cfg"])
        offsets = arr["posting_offsets"]
        flat = arr["posting_members_flat"]
        posting = clustering.PostingLists(
            centroids=arr["posting_centroids"],
            members=[flat[offsets[i]:offsets[i + 1]]
                     for i in range(len(offsets) - 1)],
            primary=arr["posting_primary"])
        graph = ng.NavGraph(
            points=arr["graph_points"], neighbors=arr["graph_neighbors"],
            entry=manifest["graph_entry"],
            super_centroids=arr.get("graph_super_centroids"),
            super_assign=arr.get("graph_super_assign"))
        ssd_meta = manifest["ssd"]
        layout = StorageLayout(
            page_of=arr["ssd_page_of"], n_pages=ssd_meta["n_pages"],
            per_page=ssd_meta["per_page"], page_bytes=ssd_meta["page_bytes"])
        ssd = SSDSim(arr["ssd_vectors"], layout,
                     buffer_pages=ssd_meta["buffer_pages"],
                     intra_merge=ssd_meta["intra_merge"],
                     use_buffer=ssd_meta["use_buffer"])
        codes = jnp.asarray(arr["codes"])
        # v1 snapshots carry no id map / attributes: identity + empty
        id_of = arr.get("id_of")
        n_sealed = int(manifest["n_sealed"])
        sealed_attrs = AttributeTable.from_columns(
            n_sealed, {name: arr[f"attr_sealed_{name}"]
                       for name in manifest.get("attr_sealed_cols", [])})
        delta_attrs = AttributeTable.from_columns(
            len(arr["delta_vectors"]),
            {name: arr[f"attr_delta_{name}"]
             for name in manifest.get("attr_delta_cols", [])})
        index = cls(cfg=cfg, codebook=pq.PQCodebook(
                        codebooks=jnp.asarray(arr["codebooks"])),
                    codes=codes, posting=posting, graph=graph, ssd=ssd,
                    use_kernel=manifest["use_kernel"],
                    rotation=arr.get("rotation"),
                    tombstones=arr["tombstones"], id_of=id_of)
        # restore the delta + epoch too: a hydrated replica must answer
        # bit-identically to the donor, including its unsealed tail
        index._view = IndexView(
            epoch=manifest["epoch"], codes=codes, posting=posting,
            tombstones=np.asarray(arr["tombstones"], bool), graph=graph,
            delta=DeltaSegment(base=n_sealed,
                               vectors=arr["delta_vectors"],
                               tombstoned=np.asarray(
                                   arr["delta_tombstoned"], bool),
                               attrs=delta_attrs),
            attrs=sealed_attrs, id_of=id_of)
        return index

    # ------------------------------------------------------------------ query
    def candidate_ids(self, query: np.ndarray, top_m: int,
                      dedup: bool = True) -> np.ndarray:
        """Stages ②③⑤ against the current view's sealed segments."""
        return self._view.candidate_ids(query, top_m, dedup)

    @property
    def executor(self) -> QueryExecutor:
        """The unified QueryPlan -> QueryExecutor pipeline (core.executor).
        Shared by all three public query paths; call
        ``.executor.attach_mesh(mesh)`` to row-shard the HBM tier."""
        ex = getattr(self, "_executor", None)
        if ex is None:
            ex = QueryExecutor(self)
            self._executor = ex
        return ex

    def make_executor(self, mesh=None) -> QueryExecutor:
        """A FRESH executor over this index (multi-replica serving: each
        replica owns its own executor, optionally attached to a disjoint
        sub-mesh from ``launch.mesh.split_mesh``).  All executors share
        the index's published view — an executor pins ``index.view()``
        per scan window, so every insert/delete/compaction epoch reaches
        every replica at its next dispatch."""
        return QueryExecutor(self, mesh=mesh)

    def plan(self, *, k: Optional[int] = None, top_m: Optional[int] = None,
             top_n: Optional[int] = None, **kw) -> QueryPlan:
        return QueryPlan.from_config(self.cfg, k=k, top_m=top_m,
                                     top_n=top_n, **kw)

    def submit(self, queries: np.ndarray, *, k: Optional[int] = None,
               top_m: Optional[int] = None, top_n: Optional[int] = None,
               overrides: Optional[List[Optional[PlanOverrides]]] = None,
               **kw) -> BatchTicket:
        """Futures-first entry point (DESIGN.md §3): host traversal + async
        device dispatch, then return immediately.  ``kw`` passes plan knobs
        through (``window=``, ``inflight_depth=``, ``deadline_s=``, ...);
        ``overrides`` carries per-query ``PlanOverrides`` for mixed-``k``
        windows."""
        return self.executor.submit(
            queries, self.plan(k=k, top_m=top_m, top_n=top_n, **kw),
            overrides=overrides)

    def search(self, request):
        """Typed single-request serve (DESIGN.md §6): accepts a
        :class:`~repro.serve.client.SearchRequest` and returns its
        :class:`~repro.serve.client.SearchResponse` through the shared
        executor's Backend-protocol path — same ids as :meth:`query`."""
        return self.executor.submit(request).result()

    def query(self, query: np.ndarray, *, k: Optional[int] = None,
              top_m: Optional[int] = None, top_n: Optional[int] = None,
              disable_early_stop: bool = False) -> QueryResult:
        """Single query == a window of one through the unified executor."""
        return self.executor.run_one(query, self.plan(
            k=k, top_m=top_m, top_n=top_n,
            disable_early_stop=disable_early_stop))

    def batch_query(self, queries: np.ndarray, *, k: Optional[int] = None,
                    top_m: Optional[int] = None, top_n: Optional[int] = None,
                    disable_early_stop: bool = False) -> List[QueryResult]:
        """Per-query windows (window=1): no inter-query candidate sharing."""
        return self.executor.run(queries, self.plan(
            k=k, top_m=top_m, top_n=top_n,
            disable_early_stop=disable_early_stop, window=1))

    def query_batch_fused(self, queries: np.ndarray, *,
                          k: Optional[int] = None,
                          top_m: Optional[int] = None,
                          top_n: Optional[int] = None) -> List[QueryResult]:
        """Beyond-paper batched mode (the TPU adaptation's natural shape):
        one ADC scan over the UNION of the batch's candidate ids with all B
        LUTs resident, per-query masking + top-n — inter-query dedup is the
        paper's §4.3 redundancy insight applied to the HBM scan.  One window
        through the unified executor; identical per-query semantics."""
        return self.executor.run(queries, self.plan(
            k=k, top_m=top_m, top_n=top_n))


# ---------------------------------------------------------------------------
# Evaluation helpers
# ---------------------------------------------------------------------------

def ground_truth(data: np.ndarray, queries: np.ndarray, k: int,
                 chunk: int = 4096) -> np.ndarray:
    """Exact top-k ids per query (brute force, chunked)."""
    q = queries.astype(np.float32)
    out = np.empty((len(q), k), np.int64)
    d2_best = None
    for qi in range(0, len(q), 128):
        qb = q[qi:qi + 128]
        d2 = np.empty((len(qb), len(data)), np.float32)
        for s in range(0, len(data), chunk):
            blk = data[s:s + chunk].astype(np.float32)
            d2[:, s:s + chunk] = (np.sum(qb ** 2, -1)[:, None]
                                  - 2.0 * qb @ blk.T
                                  + np.sum(blk ** 2, -1)[None])
        idx = np.argpartition(d2, k - 1, axis=1)[:, :k]
        dd = np.take_along_axis(d2, idx, axis=1)
        out[qi:qi + len(qb)] = np.take_along_axis(
            idx, np.argsort(dd, axis=1), axis=1)
    return out


def recall_at_k(result_ids: np.ndarray, gt_ids: np.ndarray, k: int) -> float:
    """Recall@k — |result ∩ gt| / k, averaged over queries."""
    hits = 0
    for r, g in zip(np.atleast_2d(result_ids), np.atleast_2d(gt_ids)):
        hits += len(set(r[:k].tolist()) & set(g[:k].tolist()))
    return hits / (len(np.atleast_2d(gt_ids)) * k)
