"""Futures for asynchronous query submission (the PR-2 API redesign).

The paper's throughput rests on keeping the CPU re-rank of batch *t*
overlapped with the GPU scan of batch *t+1* (§3, §4.2).  On the jax port
the "stream" is jax's async dispatch: device work is in flight the moment
the scan is traced, and the host only blocks when it *reads* the result.
This module gives that overlap a public shape:

* :class:`QueryFuture` — one per submitted query.  ``done()/result()/
  cancel()/exception()`` mirror ``concurrent.futures`` semantics, but the
  harness is synchronous: a pending future *drives* its producer (the
  executor's in-flight queue, or the serving pump loop) from ``result()``
  instead of parking a thread.
* :class:`BatchTicket` — the handle ``QueryExecutor.submit`` returns
  immediately after host traversal + device dispatch.  It owns the pump
  that retires in-flight scan windows in FIFO order and the
  ``events`` ordering probe (``("dispatch", t)`` / ``("finish", t)``)
  that tests use to assert the host dispatched window t+1 before blocking
  on window t.

Cancellation is per-query and takes effect at the per-query stage: the
shared window scan is already in flight on the device, so ``cancel()``
skips the query's SSD re-rank (the expensive host stage) and leaves the
scan untouched.  Deadlines behave the same way: they are checked when the
query's re-rank would start, never mid-kernel.
"""

from __future__ import annotations

import time
from typing import Any, Callable, List, Optional, Tuple

__all__ = [
    "QueryFuture", "BatchTicket",
    "FutureError", "CancelledError", "DeadlineExceeded", "BackpressureError",
]


class FutureError(RuntimeError):
    """Base class for query-future failures."""


class CancelledError(FutureError):
    """Raised by ``result()``/``exception()`` on a cancelled future."""


class DeadlineExceeded(FutureError):
    """The request's deadline passed before its re-rank stage started."""


class BackpressureError(FutureError):
    """Admission control: the serving queue is full; retry later."""


_PENDING, _CANCELLED, _DONE, _ERROR = range(4)


class QueryFuture:
    """Result handle for one submitted query.

    ``result()`` drives the producer (``_driver`` — set by whoever created
    the future) until this future resolves; there is no thread to wait on.
    """

    __slots__ = ("_state", "_result", "_exc", "_driver", "tag")

    def __init__(self, tag: Any = None,
                 driver: Optional[Callable[[], bool]] = None):
        self._state = _PENDING
        self._result: Any = None
        self._exc: Optional[BaseException] = None
        self._driver = driver
        self.tag = tag

    # -------------------------------------------------------------- queries
    def done(self) -> bool:
        """True once resolved — with a result, an exception, or cancelled."""
        return self._state != _PENDING

    def cancelled(self) -> bool:
        return self._state == _CANCELLED

    # ------------------------------------------------------------- commands
    def cancel(self) -> bool:
        """Cancel if still pending.  The shared scan is not recalled (it is
        already on the device); the query's re-rank is skipped.  Returns
        True if this call (or a previous one) cancelled the future."""
        if self._state == _CANCELLED:
            return True
        if self._state != _PENDING:
            return False
        self._state = _CANCELLED
        return True

    def result(self, timeout: Optional[float] = None) -> Any:
        deadline = (time.perf_counter() + timeout
                    if timeout is not None else None)
        while self._state == _PENDING:
            if deadline is not None and time.perf_counter() > deadline:
                raise TimeoutError("QueryFuture.result timed out")
            if self._driver is None or not self._driver():
                raise FutureError(
                    "QueryFuture is pending but its producer made no "
                    "progress (was the service queue dropped?)")
        if self._state == _CANCELLED:
            raise CancelledError("query was cancelled")
        if self._state == _ERROR:
            raise self._exc
        return self._result

    def exception(self, timeout: Optional[float] = None
                  ) -> Optional[BaseException]:
        """The stored exception (None if the future holds a result).
        Drives the producer like ``result()``; raises on cancellation."""
        deadline = (time.perf_counter() + timeout
                    if timeout is not None else None)
        while self._state == _PENDING:
            if deadline is not None and time.perf_counter() > deadline:
                raise TimeoutError("QueryFuture.exception timed out")
            if self._driver is None or not self._driver():
                raise FutureError("QueryFuture is pending with no producer")
        if self._state == _CANCELLED:
            raise CancelledError("query was cancelled")
        return self._exc

    # ------------------------------------------------- producer-side setters
    def _set_result(self, value: Any) -> None:
        if self._state == _PENDING:
            self._state = _DONE
            self._result = value

    def _set_exception(self, exc: BaseException) -> None:
        if self._state == _PENDING:
            self._state = _ERROR
            self._exc = exc


class BatchTicket:
    """Handle for one ``submit()`` call: the per-query futures plus the
    pump that makes progress on the in-flight window queue.

    ``events`` records ``("dispatch", t)`` / ``("finish", t)`` in host
    order — the ordering probe for the pipelining contract ("dispatch
    window t+1 before blocking on window t's scan").
    """

    def __init__(self, futures: List[QueryFuture],
                 events: Optional[List[Tuple[str, int]]] = None):
        self.futures = futures
        self.events: List[Tuple[str, int]] = events if events is not None \
            else []
        self._pump: Callable[[], bool] = lambda: False
        self._poll: Callable[[], bool] = lambda: False

    def __len__(self) -> int:
        return len(self.futures)

    def done(self) -> bool:
        return all(f.done() for f in self.futures)

    def poll(self) -> bool:
        """Non-blocking progress: retire leading windows whose device scan
        already landed, and dispatch queued windows into freed depth slots.
        Returns True if anything advanced."""
        return self._poll()

    def wait(self) -> "BatchTicket":
        """Drive the pump until every future is resolved.  Exceptions stay
        stored on their futures; ``wait()`` itself never raises them."""
        while not self.done():
            if not self._pump():
                break
        return self

    def results(self) -> List[Any]:
        """``wait()`` then collect in submission order.  Re-raises the
        first stored exception (cancellation / deadline), so plain callers
        that never cancel get a clean ``List[QueryResult]``."""
        self.wait()
        return [f.result() for f in self.futures]
