"""Futures for asynchronous query submission (the PR-2 API redesign,
made thread-safe in PR 3).

The paper's throughput rests on keeping the CPU re-rank of batch *t*
overlapped with the GPU scan of batch *t+1* (§3, §4.2).  On the jax port
the "stream" is jax's async dispatch: device work is in flight the moment
the scan is traced, and the host only blocks when it *reads* the result.
This module gives that overlap a public shape:

* :class:`QueryFuture` — one per submitted query.  ``done()/result()/
  cancel()/exception()`` mirror ``concurrent.futures`` semantics.  Two
  producer styles coexist:

  - **driver-based** (synchronous harness): a pending future *drives* its
    producer (the executor's in-flight queue, or the serving pump loop)
    from ``result()`` instead of parking a thread;
  - **blocking** (threaded serving runtime): a dedicated pump thread owns
    progress, and ``result()``/``exception()`` are real waits on the
    future's condition variable until the producer resolves it.

  State transitions (``_set_result``/``_set_exception``/``cancel``) are
  atomic under a per-future lock, so producer threads, ticker threads,
  and caller threads may touch one future concurrently.
* :class:`BatchTicket` — the handle ``QueryExecutor.submit`` returns
  immediately after host traversal + device dispatch.  It owns the pump
  that retires in-flight scan windows and the ``events`` ordering probe
  (``("dispatch", t)`` / ``("finish", t)``) that tests use to assert the
  host dispatched window t+1 before blocking on window t.  A ``finish``
  event is recorded when the window's re-rank *completes*, so a ticker
  thread retiring a younger window while an older one is still re-ranking
  shows up as out-of-window-order ``finish`` events.

Cancellation is per-query and takes effect at the per-query stage: the
shared window scan is already in flight on the device, so ``cancel()``
skips the query's SSD re-rank (the expensive host stage) and leaves the
scan untouched.  Deadlines behave the same way: they are checked when the
query's re-rank would start, never mid-kernel.
"""

from __future__ import annotations

import time
from typing import Any, Callable, List, Optional, Tuple

from repro.analysis.concurrency.witness import make_condition, make_rlock

__all__ = [
    "QueryFuture", "BatchTicket",
    "FutureError", "CancelledError", "DeadlineExceeded", "BackpressureError",
]


class FutureError(RuntimeError):
    """Base class for query-future failures."""


class CancelledError(FutureError):
    """Raised by ``result()``/``exception()`` on a cancelled future."""


class DeadlineExceeded(FutureError):
    """The request's deadline passed before its re-rank stage started."""


class BackpressureError(FutureError):
    """Admission control: the serving queue is full; retry later."""


_PENDING, _CANCELLED, _DONE, _ERROR = range(4)

# bounded condition-variable wait so a caller parked on a future whose
# producer died still re-checks state (and any caller timeout) regularly
_WAIT_SLICE_S = 0.05


class QueryFuture:
    """Result handle for one submitted query.

    ``result()`` drives the producer (``_driver`` — set by whoever created
    the future) until this future resolves, or — for ``blocking=True``
    futures owned by a pump thread — waits on the future's condition
    variable until the producer resolves it.
    """

    __slots__ = ("_state", "_result", "_exc", "_driver", "_blocking",
                 "_cond", "_callbacks", "tag")

    def __init__(self, tag: Any = None,
                 driver: Optional[Callable[[], bool]] = None,
                 blocking: bool = False):
        self._cond = make_condition("future")
        self._state = _PENDING                   # guarded-by: _cond
        self._result: Any = None                 # guarded-by: _cond
        self._exc: Optional[BaseException] = None   # guarded-by: _cond
        self._driver = driver
        self._blocking = blocking
        self._callbacks: List[Callable[["QueryFuture"], None]] = []  # guarded-by: _cond
        self.tag = tag

    # -------------------------------------------------------------- queries
    def done(self) -> bool:
        """True once resolved — with a result, an exception, or cancelled."""
        # _state transitions are monotonic (pending -> terminal) and an
        # int read is atomic in CPython: a stale False means "poll again"
        # lint-ok: GB01 lock-free fast path on a monotonic state word
        return self._state != _PENDING

    def cancelled(self) -> bool:
        # lint-ok: GB01 lock-free fast path, same monotonicity as done()
        return self._state == _CANCELLED

    # ------------------------------------------------------------- commands
    def cancel(self) -> bool:
        """Cancel if still pending.  The shared scan is not recalled (it is
        already on the device); the query's re-rank is skipped.  Returns
        True if this call (or a previous one) cancelled the future."""
        with self._cond:
            if self._state == _CANCELLED:
                return True
            if self._state != _PENDING:
                return False
            self._state = _CANCELLED
            self._cond.notify_all()
        self._run_callbacks()
        return True

    # ------------------------------------------------------------ callbacks
    def add_done_callback(self, fn: Callable[["QueryFuture"], None]) -> None:
        """Call ``fn(self)`` exactly once when this future resolves — with
        a result, an exception, or a cancellation.  If the future is
        already resolved the callback fires immediately, in the calling
        thread; otherwise it fires in whichever thread resolves the future
        (producer thread, ticker, or a caller driving the sync harness).

        The registered-vs-fired decision is atomic under the per-future
        lock, so a callback registered concurrently with resolution never
        fires twice and never gets lost.  Callbacks run OUTSIDE the lock
        (an asyncio bridge calling ``loop.call_soon_threadsafe`` from the
        callback must not deadlock against a caller holding it); a raising
        callback does not poison the future or its other callbacks."""
        with self._cond:
            if self._state == _PENDING:
                self._callbacks.append(fn)
                return
        try:
            fn(self)
        except Exception:                  # noqa: BLE001 — callback's problem
            pass

    def _run_callbacks(self) -> None:
        with self._cond:
            cbs, self._callbacks = self._callbacks, []
        for fn in cbs:
            try:
                fn(self)
            except Exception:              # noqa: BLE001 — callback's problem
                pass

    # ----------------------------------------------------------------- wait
    def _await(self, timeout: Optional[float], what: str) -> None:
        """Block (or drive) until resolved; raises TimeoutError on caller
        timeout and FutureError when no producer can make progress."""
        deadline = (time.perf_counter() + timeout
                    if timeout is not None else None)
        while True:
            with self._cond:
                if self._state != _PENDING:
                    return
                if deadline is not None and time.perf_counter() > deadline:
                    raise TimeoutError(f"QueryFuture.{what} timed out")
                driver, blocking = self._driver, self._blocking
                if driver is None:
                    if not blocking:
                        raise FutureError(
                            "QueryFuture is pending with no producer "
                            "(was the service queue dropped?)")
                    # a pump thread owns progress: park on the condition
                    # variable until it resolves us (bounded slices so a
                    # dead producer or a caller timeout is still noticed)
                    slice_s = _WAIT_SLICE_S if deadline is None else \
                        min(_WAIT_SLICE_S,
                            max(deadline - time.perf_counter(), 0.0))
                    self._cond.wait(slice_s)
                    continue
            # drive OUTSIDE the lock: the producer resolves futures (and
            # takes their locks) from inside its own critical sections
            if not driver():
                if not blocking:
                    raise FutureError(
                        "QueryFuture is pending but its producer made no "
                        "progress (was the service queue dropped?)")
                time.sleep(0.0005)

    def result(self, timeout: Optional[float] = None) -> Any:
        self._await(timeout, "result")
        with self._cond:
            if self._state == _CANCELLED:
                raise CancelledError("query was cancelled")
            if self._state == _ERROR:
                raise self._exc
            return self._result

    def exception(self, timeout: Optional[float] = None
                  ) -> Optional[BaseException]:
        """The stored exception (None if the future holds a result).
        Waits/drives like ``result()``; raises on cancellation."""
        self._await(timeout, "exception")
        with self._cond:
            if self._state == _CANCELLED:
                raise CancelledError("query was cancelled")
            return self._exc

    # ------------------------------------------------- producer-side setters
    def _set_result(self, value: Any) -> None:
        with self._cond:
            if self._state != _PENDING:
                return
            self._result = value
            self._state = _DONE
            self._cond.notify_all()
        self._run_callbacks()

    def _set_exception(self, exc: BaseException) -> None:
        with self._cond:
            if self._state != _PENDING:
                return
            self._exc = exc
            self._state = _ERROR
            self._cond.notify_all()
        self._run_callbacks()


class BatchTicket:
    """Handle for one ``submit()`` call: the per-query futures plus the
    pump that makes progress on the in-flight window queue.

    ``events`` records ``("dispatch", t)`` / ``("finish", t)`` in host
    order — the ordering probe for the pipelining contract ("dispatch
    window t+1 before blocking on window t's scan").  ``finish`` is
    appended when the window's re-rank completes, so concurrent retirement
    (pump thread + ticker) surfaces as out-of-window-order finishes.

    Thread-safety: ``_lock``/``_cond`` guard the event list and the
    ``_busy`` work-in-progress counter (windows currently being dispatched
    or retired by some thread); the executor's pump/poll closures maintain
    them.  ``wait()`` blocks on ``_cond`` instead of spinning when another
    thread holds the only remaining work.
    """

    def __init__(self, futures: List[QueryFuture],
                 events: Optional[List[Tuple[str, int]]] = None):
        self.futures = futures
        self._lock = make_rlock("ticket")
        self._cond = make_condition("ticket", self._lock)
        self.events: List[Tuple[str, int]] = events if events is not None \
            else []                              # guarded-by: _lock
        self._pump: Callable[[], bool] = lambda: False
        self._poll: Callable[[], bool] = lambda: False
        # windows mid-dispatch/mid-retire, any thread
        self._busy = [0]                         # guarded-by: _lock

    def __len__(self) -> int:
        return len(self.futures)

    def done(self) -> bool:
        return all(f.done() for f in self.futures)

    def poll(self) -> bool:
        """Non-blocking progress: retire any window whose device scan
        already landed (possibly out of order — younger windows may finish
        while an older one is still re-ranking on another thread), and
        dispatch queued windows into freed depth slots.  Returns True if
        anything advanced."""
        return self._poll()

    def _stall_message(self) -> str:             # holds: _lock
        pending = [f.tag for f in self.futures if not f.done()]
        disp = {wi for kind, wi in self.events if kind == "dispatch"}
        fin = {wi for kind, wi in self.events if kind == "finish"}
        stalled = sorted(disp - fin)
        where = (f"stalled window(s) {stalled}" if stalled
                 else "window(s) never dispatched")
        return (f"BatchTicket.wait(): producer made no progress but "
                f"{len(pending)} future(s) are still pending "
                f"(tags {pending[:8]}{'...' if len(pending) > 8 else ''}); "
                f"{where}")

    def wait(self) -> "BatchTicket":
        """Drive the pump until every future is resolved.  Exceptions stay
        stored on their futures; ``wait()`` itself never raises them —
        but a genuine stall (no dispatchable or retirable work, no other
        thread mid-window, futures still pending) raises
        :class:`FutureError` naming the stalled window instead of
        returning silently and letting ``results()`` fail far from the
        cause."""
        while not self.done():
            if self._pump():
                continue
            # nothing to dispatch or retire HERE — either another thread
            # is mid-window (wait for it) or the ticket is truly stalled
            with self._cond:
                if self._busy[0] > 0:
                    self._cond.wait(_WAIT_SLICE_S)
                    continue
            if self.done():
                break
            with self._cond:
                msg = self._stall_message()
            raise FutureError(msg)
        # barrier: let concurrent retirements finish their bookkeeping
        # (the finish event is appended before _busy drops to 0)
        with self._cond:
            while self._busy[0] > 0:
                self._cond.wait(_WAIT_SLICE_S)
        return self

    def results(self) -> List[Any]:
        """``wait()`` then collect in submission order.  Re-raises the
        first stored exception (cancellation / deadline), so plain callers
        that never cancel get a clean ``List[QueryResult]``."""
        self.wait()
        return [f.result() for f in self.futures]
