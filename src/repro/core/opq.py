"""OPQ: optimized product quantization (beyond-paper PQ-quality lever).

Learns an orthonormal rotation R so that sub-space energy is balanced
before PQ (Ge et al., OPQ, CVPR'13 — standard companion to IVF-PQ systems;
FAISS applies it by default at billion scale).  Alternating minimisation:
  E-step: PQ-encode R·x;  M-step: R <- Procrustes(X, decoded codes).
Drop-in: wrap the codebook; queries rotate once before the LUT build."""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import pq


@dataclasses.dataclass(frozen=True)
class OPQCodebook:
    rotation: np.ndarray          # (D, D) orthonormal
    cb: pq.PQCodebook

    @property
    def m(self) -> int:
        return self.cb.m


def train_opq(key: jax.Array, data: np.ndarray, m: int, nbits: int = 8,
              iters: int = 4, kmeans_iters: int = 8
              ) -> Tuple[OPQCodebook, float]:
    """Returns (codebook, final mean squared reconstruction error)."""
    x = np.asarray(data, np.float32)
    n, d = x.shape
    r = np.eye(d, dtype=np.float32)
    cb = None
    err = np.inf
    for _ in range(iters):
        xr = x @ r
        cb = pq.train_codebooks(key, jnp.asarray(xr), m, nbits,
                                iters=kmeans_iters)
        recon = np.asarray(pq.decode(cb, pq.encode(cb, jnp.asarray(xr))))
        err = float(np.mean(np.sum((xr - recon) ** 2, -1)))
        # Procrustes: R = argmin ||XR - recon||  =>  R = U V^T of X^T recon
        u, _, vt = np.linalg.svd(x.T @ recon, full_matrices=False)
        r = (u @ vt).astype(np.float32)
    return OPQCodebook(rotation=r, cb=cb), err


def encode(ocb: OPQCodebook, data: np.ndarray) -> jax.Array:
    return pq.encode(ocb.cb, jnp.asarray(
        np.asarray(data, np.float32) @ ocb.rotation))


def adc_lut(ocb: OPQCodebook, query: np.ndarray) -> jax.Array:
    """Rotation preserves L2, so rotated-space ADC distances estimate the
    original-space distances directly."""
    return pq.adc_lut(ocb.cb, jnp.asarray(
        np.asarray(query, np.float32) @ ocb.rotation))


def reconstruction_error(ocb: OPQCodebook, data: np.ndarray) -> float:
    xr = np.asarray(data, np.float32) @ ocb.rotation
    recon = np.asarray(pq.decode(ocb.cb, encode(ocb, data)))
    return float(np.mean(np.sum((xr - recon) ** 2, -1)))
