"""Posting-list construction (paper §4.1): hierarchical balanced clustering
+ the ε-replication closure of Eq. (2) with the ≤8-replica cap."""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class PostingLists:
    centroids: np.ndarray            # (C, D) f32
    members: List[np.ndarray]        # per-cluster vector-ids (with replicas)
    primary: np.ndarray              # (N,) nearest-cluster id per vector

    @property
    def n_clusters(self) -> int:
        return len(self.members)

    def replication_factor(self) -> float:
        total = sum(len(m) for m in self.members)
        return total / max(len(self.primary), 1)


def _kmeans(rng: np.random.Generator, data: np.ndarray, k: int,
            iters: int = 10, chunk: int = 65536) -> np.ndarray:
    """Plain Lloyd k-means (numpy, chunked distance) — the leaf step of the
    hierarchical balanced clustering."""
    n = len(data)
    centers = data[rng.choice(n, size=k, replace=n < k)].astype(np.float32)
    for _ in range(iters):
        assign = np.empty(n, np.int32)
        for s in range(0, n, chunk):
            blk = data[s:s + chunk]
            d2 = (np.sum(blk ** 2, -1)[:, None]
                  - 2.0 * blk @ centers.T + np.sum(centers ** 2, -1)[None])
            assign[s:s + chunk] = np.argmin(d2, -1)
        for c in range(k):
            pts = data[assign == c]
            if len(pts):
                centers[c] = pts.mean(0)
    return centers


def hierarchical_balanced_clustering(
        rng: np.random.Generator, data: np.ndarray, n_clusters: int,
        branch: int = 8, max_leaf: Optional[int] = None) -> np.ndarray:
    """Recursively k-means-split the largest partition until ``n_clusters``
    leaves exist (keeps leaves balanced — the paper's [34] lineage).
    Returns centroids (n_clusters, D)."""
    parts: List[np.ndarray] = [np.arange(len(data))]
    while len(parts) < n_clusters:
        parts.sort(key=len)
        big = parts.pop()                      # split the largest
        k = min(branch, max(2, n_clusters - len(parts)))
        if len(big) <= k:
            parts.append(big)
            break
        centers = _kmeans(rng, data[big], k, iters=6)
        d2 = (np.sum(data[big] ** 2, -1)[:, None]
              - 2.0 * data[big] @ centers.T
              + np.sum(centers ** 2, -1)[None])
        assign = np.argmin(d2, -1)
        new = [big[assign == c] for c in range(k)]
        parts.extend(p for p in new if len(p))
    cents = np.stack([data[p].mean(0) if len(p) else data[0]
                      for p in parts[:n_clusters]]).astype(np.float32)
    # polish with a few global Lloyd rounds
    return _kmeans_polish(data, cents, iters=4)


def _kmeans_polish(data: np.ndarray, centers: np.ndarray,
                   iters: int = 4, chunk: int = 65536) -> np.ndarray:
    for _ in range(iters):
        sums = np.zeros_like(centers)
        cnts = np.zeros(len(centers))
        for s in range(0, len(data), chunk):
            blk = data[s:s + chunk]
            d2 = (np.sum(blk ** 2, -1)[:, None]
                  - 2.0 * blk @ centers.T + np.sum(centers ** 2, -1)[None])
            a = np.argmin(d2, -1)
            np.add.at(sums, a, blk)
            np.add.at(cnts, a, 1)
        nz = cnts > 0
        centers[nz] = sums[nz] / cnts[nz, None]
    return centers


def assign_with_replication(data: np.ndarray, centroids: np.ndarray,
                            eps: float = 0.10, max_replicas: int = 8,
                            chunk: int = 32768) -> PostingLists:
    """Eq. (2): v ∈ C_i  ⇔  Dist(v, C_i) ≤ (1+ε)·Dist(v, C_1), capped at
    ``max_replicas`` clusters per vector."""
    n = len(data)
    c = len(centroids)
    r = min(max_replicas, c)
    members: List[List[int]] = [[] for _ in range(c)]
    primary = np.empty(n, np.int32)
    for s in range(0, n, chunk):
        blk = data[s:s + chunk].astype(np.float32)
        d2 = (np.sum(blk ** 2, -1)[:, None]
              - 2.0 * blk @ centroids.T + np.sum(centroids ** 2, -1)[None])
        idx = np.argpartition(d2, r - 1, axis=1)[:, :r]
        dd = np.take_along_axis(d2, idx, axis=1)
        order = np.argsort(dd, axis=1)
        idx = np.take_along_axis(idx, order, axis=1)
        dd = np.take_along_axis(dd, order, axis=1)
        primary[s:s + chunk] = idx[:, 0]
        # Eq. 2 threshold on *distances* (squared dist => (1+eps)^2)
        thresh = (1.0 + eps) ** 2 * dd[:, :1]
        ok = dd <= thresh
        for row in range(len(blk)):
            vid = s + row
            for j in range(r):
                if ok[row, j]:
                    members[idx[row, j]].append(vid)
    return PostingLists(
        centroids=centroids.astype(np.float32),
        members=[np.asarray(m, np.int32) for m in members],
        primary=primary)


def build_posting_lists(rng: np.random.Generator, data: np.ndarray,
                        n_clusters: int, eps: float = 0.10,
                        max_replicas: int = 8) -> PostingLists:
    cents = hierarchical_balanced_clustering(rng, data, n_clusters)
    return assign_with_replication(data, cents, eps, max_replicas)
