"""Analytic device model: turns the *measured* per-query I/O counts, byte
volumes and compute demands into QPS/latency curves vs thread count.

This is the calibrated stand-in for wall-clock on the paper's testbed (no
NVMe/GPU in this container — DESIGN.md §7).  Rates mirror the paper's
hardware: Samsung 990Pro (~1.2M IOPS 4K rand, ~7 GB/s), PCIe 3.0 x16
(~12 GB/s effective), V100 HBM2 (900 GB/s), 64-core Xeon.

Throughput: each resource r has capacity C_r and per-query demand d_r;
QPS(T) = min(T / L_1, min_r C_r / d_r) where L_1 is the single-thread
latency; latency(T) = T / QPS(T) (Little's law) — matching the paper's
observation that SPANN saturates SSD *bandwidth* at 4 threads while
FusionANNS rides the IOPS/PCIe-light path to 64.
"""

from __future__ import annotations

import dataclasses
from typing import Dict

import numpy as np


@dataclasses.dataclass(frozen=True)
class DeviceModel:
    ssd_iops: float = 1.2e6            # 4K random read command rate
    ssd_bw: float = 7.0e9              # B/s
    ssd_lat: float = 60e-6             # s per command (QD1)
    pcie_bw: float = 12.0e9            # B/s host<->accelerator
    gpu_lookup_rate: float = 2.0e11    # ADC LUT lookups/s (HBM-bw bound)
    cpu_lookup_rate: float = 5.0e7     # per-thread ADC lookups/s — random
    #                                    DRAM access bound (the paper's §2.2
    #                                    argument for GPU placement)
    cpu_dist_rate: float = 2.0e9       # per-thread f32 mul-adds/s
    graph_hop_time: float = 1.5e-6     # s per navgraph hop (measured-ish)
    n_threads_max: int = 64


@dataclasses.dataclass
class QueryDemand:
    """Per-query resource demands (from measured engine stats).

    ssd_requests = discrete I/O commands (what IOPS/latency bind on);
    ssd_ios      = 4 KB pages touched (the Fig. 12c "I/O numbers" metric);
    for random-4K systems the two coincide."""

    ssd_ios: float = 0.0
    ssd_requests: float = -1.0         # -1 -> same as ssd_ios
    ssd_bytes: float = 0.0
    h2d_bytes: float = 0.0
    gpu_lookups: float = 0.0           # M lookups per scanned candidate
    cpu_lookups: float = 0.0           # CPU-side ADC (MI(CPU) variant)
    cpu_dist_ops: float = 0.0          # exact-distance mul-adds (rerank etc.)
    graph_hops: float = 0.0

    @property
    def requests(self) -> float:
        return self.ssd_ios if self.ssd_requests < 0 else self.ssd_requests


def demand_from_stats(totals: Dict[str, float], n: float, *, pq_m: int,
                      dim: int, top_m: int) -> QueryDemand:
    """Mean per-query demand from summed ``QueryStats`` counters covering
    ``n`` responses — the ONE stats-to-demand conversion shared by the
    benchmark harness (``benchmarks.common.fusion_demand``) and the
    router's replica-scaling sweep (``ReplicaRouter.measured_demand``)."""
    n = max(n, 1)
    return QueryDemand(
        ssd_ios=totals["ios"] / n,
        ssd_bytes=totals["ssd_bytes"] / n,
        h2d_bytes=totals["h2d_bytes"] / n,
        gpu_lookups=totals["candidates_scanned"] / n * pq_m,
        cpu_dist_ops=totals["rerank_scored"] / n * dim,
        graph_hops=2.0 * top_m)


def single_thread_latency(d: QueryDemand, hw: DeviceModel) -> float:
    io = d.requests * hw.ssd_lat + d.ssd_bytes / hw.ssd_bw
    pcie = d.h2d_bytes / hw.pcie_bw
    gpu = d.gpu_lookups / hw.gpu_lookup_rate
    cpu = (d.cpu_lookups / hw.cpu_lookup_rate
           + d.cpu_dist_ops / hw.cpu_dist_rate
           + d.graph_hops * hw.graph_hop_time)
    return io + pcie + gpu + cpu


def qps_at_threads(d: QueryDemand, hw: DeviceModel, threads: int) -> float:
    l1 = single_thread_latency(d, hw)
    caps = []
    if d.requests:
        caps.append(hw.ssd_iops / d.requests)
    if d.ssd_bytes:
        caps.append(hw.ssd_bw / d.ssd_bytes)
    if d.h2d_bytes:
        caps.append(hw.pcie_bw / d.h2d_bytes)
    if d.gpu_lookups:
        caps.append(hw.gpu_lookup_rate / d.gpu_lookups)
    cpu_time = (d.cpu_lookups / hw.cpu_lookup_rate
                + d.cpu_dist_ops / hw.cpu_dist_rate
                + d.graph_hops * hw.graph_hop_time)
    if cpu_time:
        caps.append(threads / cpu_time)
    caps.append(threads / max(l1, 1e-12))
    return min(caps)


def latency_at_threads(d: QueryDemand, hw: DeviceModel, threads: int) -> float:
    return threads / max(qps_at_threads(d, hw, threads), 1e-9)


def sweep_threads(d: QueryDemand, hw: DeviceModel,
                  threads=(1, 2, 4, 8, 16, 32, 64)) -> Dict[int, Dict]:
    return {t: {"qps": qps_at_threads(d, hw, t),
                "latency_ms": 1e3 * latency_at_threads(d, hw, t)}
            for t in threads}


def qps_at_replicas(d: QueryDemand, hw: DeviceModel, n_replicas: int,
                    threads_per_replica: int = 8) -> float:
    """Multi-replica operating point: one mesh carved into ``n_replicas``
    disjoint device groups (serve/router.py), each replica running its own
    pump + ``threads_per_replica`` host serving threads.

    Accelerator-side capacities SCALE with replicas — every group brings
    its own HBM slice and host<->device links (gpu_lookup_rate, pcie_bw
    x n) — while the box's SSD is shared and host threads total
    ``n x t``.  QPS therefore rides ``n x t / L_1`` until a shared
    resource binds, which is the router's whole premise: replicas add
    serving-pipeline concurrency, not index capacity."""
    caps = [hw.ssd_iops / d.requests if d.requests else np.inf,
            hw.ssd_bw / d.ssd_bytes if d.ssd_bytes else np.inf,
            n_replicas * hw.pcie_bw / d.h2d_bytes if d.h2d_bytes
            else np.inf,
            n_replicas * hw.gpu_lookup_rate / d.gpu_lookups
            if d.gpu_lookups else np.inf]
    threads = n_replicas * threads_per_replica
    cpu_time = (d.cpu_lookups / hw.cpu_lookup_rate
                + d.cpu_dist_ops / hw.cpu_dist_rate
                + d.graph_hops * hw.graph_hop_time)
    if cpu_time:
        caps.append(threads / cpu_time)
    caps.append(threads / max(single_thread_latency(d, hw), 1e-12))
    return float(min(caps))


def sweep_replicas(d: QueryDemand, hw: DeviceModel,
                   replicas=(1, 2, 4),
                   threads_per_replica: int = 8) -> Dict[int, float]:
    return {n: qps_at_replicas(d, hw, n, threads_per_replica)
            for n in replicas}


def max_useful_replicas(d: QueryDemand, hw: DeviceModel, *,
                        threads_per_replica: int = 8,
                        min_gain: float = 1.02, cap: int = 64) -> int:
    """The autoscaler's sanity bound (serve/autoscaler.py): the largest
    replica count at which adding one more replica still improves modelled
    QPS by at least ``min_gain``x.  Past this point a SHARED resource
    (SSD IOPS/bandwidth in this model) binds, so growing the replica set
    burns devices without serving more traffic — the autoscaler never
    scales above it no matter what the load signals say."""
    n = 1
    prev = qps_at_replicas(d, hw, 1, threads_per_replica)
    while n < cap:
        nxt = qps_at_replicas(d, hw, n + 1, threads_per_replica)
        if prev <= 0 or nxt < prev * min_gain:
            break
        prev, n = nxt, n + 1
    return n
