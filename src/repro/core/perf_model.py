"""Analytic device model: turns the *measured* per-query I/O counts, byte
volumes and compute demands into QPS/latency curves vs thread count.

This is the calibrated stand-in for wall-clock on the paper's testbed (no
NVMe/GPU in this container — DESIGN.md §7).  Rates mirror the paper's
hardware: Samsung 990Pro (~1.2M IOPS 4K rand, ~7 GB/s), PCIe 3.0 x16
(~12 GB/s effective), V100 HBM2 (900 GB/s), 64-core Xeon.

Throughput: each resource r has capacity C_r and per-query demand d_r;
QPS(T) = min(T / L_1, min_r C_r / d_r) where L_1 is the single-thread
latency; latency(T) = T / QPS(T) (Little's law) — matching the paper's
observation that SPANN saturates SSD *bandwidth* at 4 threads while
FusionANNS rides the IOPS/PCIe-light path to 64.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

from repro.analysis.concurrency.witness import make_lock


@dataclasses.dataclass(frozen=True)
class DeviceModel:
    ssd_iops: float = 1.2e6            # 4K random read command rate
    ssd_bw: float = 7.0e9              # B/s
    ssd_lat: float = 60e-6             # s per command (QD1)
    pcie_bw: float = 12.0e9            # B/s host<->accelerator
    gpu_lookup_rate: float = 2.0e11    # ADC LUT lookups/s (HBM-bw bound)
    cpu_lookup_rate: float = 5.0e7     # per-thread ADC lookups/s — random
    #                                    DRAM access bound (the paper's §2.2
    #                                    argument for GPU placement)
    cpu_dist_rate: float = 2.0e9       # per-thread f32 mul-adds/s
    graph_hop_time: float = 1.5e-6     # s per navgraph hop (measured-ish)
    n_threads_max: int = 64


@dataclasses.dataclass
class QueryDemand:
    """Per-query resource demands (from measured engine stats).

    ssd_requests = discrete I/O commands (what IOPS/latency bind on);
    ssd_ios      = 4 KB pages touched (the Fig. 12c "I/O numbers" metric);
    for random-4K systems the two coincide."""

    ssd_ios: float = 0.0
    ssd_requests: float = -1.0         # -1 -> same as ssd_ios
    ssd_bytes: float = 0.0
    h2d_bytes: float = 0.0
    gpu_lookups: float = 0.0           # M lookups per scanned candidate
    cpu_lookups: float = 0.0           # CPU-side ADC (MI(CPU) variant)
    cpu_dist_ops: float = 0.0          # exact-distance mul-adds (rerank etc.)
    graph_hops: float = 0.0

    @property
    def requests(self) -> float:
        return self.ssd_ios if self.ssd_requests < 0 else self.ssd_requests


def demand_from_stats(totals: Dict[str, float], n: float, *, pq_m: int,
                      dim: int, top_m: int) -> QueryDemand:
    """Mean per-query demand from summed ``QueryStats`` counters covering
    ``n`` responses — the ONE stats-to-demand conversion shared by the
    benchmark harness (``benchmarks.common.fusion_demand``) and the
    router's replica-scaling sweep (``ReplicaRouter.measured_demand``)."""
    n = max(n, 1)
    return QueryDemand(
        ssd_ios=totals["ios"] / n,
        ssd_bytes=totals["ssd_bytes"] / n,
        h2d_bytes=totals["h2d_bytes"] / n,
        gpu_lookups=totals["candidates_scanned"] / n * pq_m,
        cpu_dist_ops=totals["rerank_scored"] / n * dim,
        graph_hops=2.0 * top_m)


def single_thread_latency(d: QueryDemand, hw: DeviceModel) -> float:
    io = d.requests * hw.ssd_lat + d.ssd_bytes / hw.ssd_bw
    pcie = d.h2d_bytes / hw.pcie_bw
    gpu = d.gpu_lookups / hw.gpu_lookup_rate
    cpu = (d.cpu_lookups / hw.cpu_lookup_rate
           + d.cpu_dist_ops / hw.cpu_dist_rate
           + d.graph_hops * hw.graph_hop_time)
    return io + pcie + gpu + cpu


def qps_at_threads(d: QueryDemand, hw: DeviceModel, threads: int) -> float:
    l1 = single_thread_latency(d, hw)
    caps = []
    if d.requests:
        caps.append(hw.ssd_iops / d.requests)
    if d.ssd_bytes:
        caps.append(hw.ssd_bw / d.ssd_bytes)
    if d.h2d_bytes:
        caps.append(hw.pcie_bw / d.h2d_bytes)
    if d.gpu_lookups:
        caps.append(hw.gpu_lookup_rate / d.gpu_lookups)
    cpu_time = (d.cpu_lookups / hw.cpu_lookup_rate
                + d.cpu_dist_ops / hw.cpu_dist_rate
                + d.graph_hops * hw.graph_hop_time)
    if cpu_time:
        caps.append(threads / cpu_time)
    caps.append(threads / max(l1, 1e-12))
    return min(caps)


def latency_at_threads(d: QueryDemand, hw: DeviceModel, threads: int) -> float:
    return threads / max(qps_at_threads(d, hw, threads), 1e-9)


def sweep_threads(d: QueryDemand, hw: DeviceModel,
                  threads=(1, 2, 4, 8, 16, 32, 64)) -> Dict[int, Dict]:
    return {t: {"qps": qps_at_threads(d, hw, t),
                "latency_ms": 1e3 * latency_at_threads(d, hw, t)}
            for t in threads}


def qps_at_replicas(d: QueryDemand, hw: DeviceModel, n_replicas: int,
                    threads_per_replica: int = 8) -> float:
    """Multi-replica operating point: one mesh carved into ``n_replicas``
    disjoint device groups (serve/router.py), each replica running its own
    pump + ``threads_per_replica`` host serving threads.

    Accelerator-side capacities SCALE with replicas — every group brings
    its own HBM slice and host<->device links (gpu_lookup_rate, pcie_bw
    x n) — while the box's SSD is shared and host threads total
    ``n x t``.  QPS therefore rides ``n x t / L_1`` until a shared
    resource binds, which is the router's whole premise: replicas add
    serving-pipeline concurrency, not index capacity."""
    caps = [hw.ssd_iops / d.requests if d.requests else np.inf,
            hw.ssd_bw / d.ssd_bytes if d.ssd_bytes else np.inf,
            n_replicas * hw.pcie_bw / d.h2d_bytes if d.h2d_bytes
            else np.inf,
            n_replicas * hw.gpu_lookup_rate / d.gpu_lookups
            if d.gpu_lookups else np.inf]
    threads = n_replicas * threads_per_replica
    cpu_time = (d.cpu_lookups / hw.cpu_lookup_rate
                + d.cpu_dist_ops / hw.cpu_dist_rate
                + d.graph_hops * hw.graph_hop_time)
    if cpu_time:
        caps.append(threads / cpu_time)
    caps.append(threads / max(single_thread_latency(d, hw), 1e-12))
    return float(min(caps))


def sweep_replicas(d: QueryDemand, hw: DeviceModel,
                   replicas=(1, 2, 4),
                   threads_per_replica: int = 8) -> Dict[int, float]:
    return {n: qps_at_replicas(d, hw, n, threads_per_replica)
            for n in replicas}


def max_useful_replicas(d: QueryDemand, hw: DeviceModel, *,
                        threads_per_replica: int = 8,
                        min_gain: float = 1.02, cap: int = 64) -> int:
    """The autoscaler's sanity bound (serve/autoscaler.py): the largest
    replica count at which adding one more replica still improves modelled
    QPS by at least ``min_gain``x.  Past this point a SHARED resource
    (SSD IOPS/bandwidth in this model) binds, so growing the replica set
    burns devices without serving more traffic — the autoscaler never
    scales above it no matter what the load signals say."""
    n = 1
    prev = qps_at_replicas(d, hw, 1, threads_per_replica)
    while n < cap:
        nxt = qps_at_replicas(d, hw, n + 1, threads_per_replica)
        if prev <= 0 or nxt < prev * min_gain:
            break
        prev, n = nxt, n + 1
    return n


# ---------------------------------------------------------------------------
# Deadline-adaptive accuracy (DESIGN.md §11)
# ---------------------------------------------------------------------------
#
# The paper's Fig. 10 exposes accuracy as a runtime knob (heuristic
# re-rank depth / int8 LUTs); here the SAME knob becomes a per-request
# resolver: pick the most accurate level whose MODELED single-thread
# latency fits the request's ``deadline_s``.  ``top_m_frac`` scales the
# graph traversal + scan-side demands (posting lists visited -> union
# size -> H2D bytes + ADC lookups), ``top_n_frac`` the re-rank-side
# demands (SSD I/O + exact distances).

@dataclasses.dataclass(frozen=True)
class AccuracyLevel:
    name: str
    top_m_frac: float
    top_n_frac: float


# most-accurate-first: the resolver returns the FIRST level that fits,
# so an easy deadline always gets full accuracy
ACCURACY_LEVELS: Tuple[AccuracyLevel, ...] = (
    AccuracyLevel("full", 1.0, 1.0),
    AccuracyLevel("high", 0.75, 0.75),
    AccuracyLevel("balanced", 0.5, 0.5),
    AccuracyLevel("fast", 0.25, 0.25),
    AccuracyLevel("turbo", 0.125, 0.125),
)


def scale_demand(d: QueryDemand, level: AccuracyLevel,
                 selectivity: float = 1.0) -> QueryDemand:
    """Predicted demand at a reduced accuracy level.  ``selectivity``
    (scanned/prefilter candidate ratio, <= 1) lets a caller predict a
    FILTERED workload's demand from unfiltered measurements — both scan
    and re-rank work shrink with it, because filtering happens at
    candidate collection (not post-top-k)."""
    m = level.top_m_frac * selectivity
    n = level.top_n_frac * selectivity
    return QueryDemand(
        ssd_ios=d.ssd_ios * n,
        ssd_requests=(d.ssd_requests if d.ssd_requests < 0
                      else d.ssd_requests * n),
        ssd_bytes=d.ssd_bytes * n,
        h2d_bytes=d.h2d_bytes * m,
        gpu_lookups=d.gpu_lookups * m,
        cpu_lookups=d.cpu_lookups * m,
        cpu_dist_ops=d.cpu_dist_ops * n,
        graph_hops=d.graph_hops * level.top_m_frac)


def resolve_accuracy(deadline_s: float, demand: QueryDemand,
                     hw: DeviceModel, *, selectivity: float = 1.0,
                     levels: Tuple[AccuracyLevel, ...] = ACCURACY_LEVELS,
                     headroom: float = 1.0) -> AccuracyLevel:
    """The most accurate level whose modeled latency fits
    ``deadline_s * headroom``; the cheapest level when none does (a
    best-effort answer beats none — the deadline machinery downstream
    still expires truly hopeless requests)."""
    for level in levels:
        lat = single_thread_latency(
            scale_demand(demand, level, selectivity), hw)
        if lat <= deadline_s * headroom:
            return level
    return levels[-1]


class AdaptivePlanner:
    """Observes served ``QueryStats`` and suggests per-request plan
    overrides that the device model predicts meet a deadline.

    Holds an EWMA of per-query demand (at whatever accuracy recent
    traffic ran) plus the observed filter selectivity; ``suggest()``
    resolves an accuracy level against that baseline and converts its
    fractions into concrete ``top_m``/``top_n`` values.  Thread-safe:
    one ``executor``-ranked lock over the EWMA state — callers must not
    hold another executor-rank lock (same-rank nesting is a witnessed
    lock-order violation)."""

    def __init__(self, cfg, hw: Optional[DeviceModel] = None, *, dim: int,
                 pq_m: Optional[int] = None, alpha: float = 0.25,
                 headroom: float = 0.9):
        self.cfg = cfg
        self.hw = hw if hw is not None else DeviceModel()
        self.dim = int(dim)
        self.pq_m = int(cfg.pq_m if pq_m is None else pq_m)
        self.alpha = float(alpha)
        self.headroom = float(headroom)
        self._lock = make_lock("executor")
        self._demand: Optional[QueryDemand] = None  # guarded-by: _lock
        self._selectivity = 1.0                     # guarded-by: _lock
        self._n_observed = 0                        # guarded-by: _lock

    def observe(self, stats) -> None:
        """Fold one served query's ``QueryStats`` into the EWMA."""
        totals = {"ios": stats.ios, "ssd_bytes": stats.ssd_bytes,
                  "h2d_bytes": stats.h2d_bytes,
                  "candidates_scanned": stats.candidates_scanned,
                  "rerank_scored": stats.rerank_scored}
        d = demand_from_stats(totals, 1, pq_m=self.pq_m, dim=self.dim,
                              top_m=self.cfg.top_m)
        sel = (stats.candidates_scanned
               / max(stats.candidates_prefilter, 1))
        a = self.alpha
        with self._lock:  # acquires: executor
            if self._demand is None:
                self._demand = d
                self._selectivity = sel
            else:
                prev = self._demand
                self._demand = QueryDemand(**{
                    f.name: (1 - a) * getattr(prev, f.name)
                    + a * getattr(d, f.name)
                    for f in dataclasses.fields(QueryDemand)})
                self._selectivity = (1 - a) * self._selectivity + a * sel
            self._n_observed += 1

    def suggest(self, deadline_s: Optional[float]) -> Optional[Dict]:
        """Plan override for one request, or None when no adaptation is
        needed (no deadline, nothing observed yet, or full accuracy
        already fits).  The observed demand already reflects the live
        selectivity, so the resolver runs at selectivity=1."""
        if deadline_s is None:
            return None
        with self._lock:  # acquires: executor
            d = self._demand
            sel = self._selectivity
        if d is None:
            return None
        level = resolve_accuracy(deadline_s, d, self.hw,
                                 headroom=self.headroom)
        if level.top_m_frac >= 1.0 and level.top_n_frac >= 1.0:
            return None
        return {"level": level.name,
                "selectivity": sel,
                "top_m": max(1, int(round(self.cfg.top_m
                                          * level.top_m_frac))),
                "top_n": max(self.cfg.top_k,
                             int(round(self.cfg.top_n
                                       * level.top_n_frac)))}
