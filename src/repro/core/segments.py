"""Segmented streaming index: immutable epoch-stamped views + a mutable
delta segment + background compaction (DESIGN.md §10).

The update model (SVFusion-style real-time ingest on top of the paper's
SPFresh-cited maintenance path):

  * Every reader — executor dispatch, candidate collection, the delta
    merge in ``_finish_into`` — works against ONE :class:`IndexView`
    pinned at the start of its window.  Views are frozen dataclasses
    published by a single atomic reference assignment, so a reader can
    never observe torn multi-tier state (the PR-9 race class: posting
    ids pointing past the end of the code array, tombstone filters
    IndexError-ing on fresh ids).
  * Inserts append to the small mutable *delta segment* — raw float32
    rows scanned exactly and merged into the top-k after the PQ scan +
    re-rank.  No clustering, PQ encode, or SSD traffic on the insert
    path.
  * Deletes tombstone in the owning segment: a copy-on-write flip of the
    sealed tombstone array, or a functional update of the delta's flags.
  * A background :class:`SegmentCompactor` (its critical sections under
    the ``compaction``-ranked witness lock) seals the delta into the
    immutable PQ/posting/SSD tiers — re-cluster against the existing
    centroids, PQ-encode, purge delta tombstones — while queries keep
    serving against the old view; the swap is one epoch-bumped reference
    assignment, so every executor/replica picks up the new binding at
    its next dispatch.
"""

from __future__ import annotations

import dataclasses
import threading
from typing import TYPE_CHECKING, Optional, Tuple

import numpy as np

from repro.core import navgraph as ng
from repro.core.filters import AttributeTable, Predicate

if TYPE_CHECKING:                                   # pragma: no cover
    import jax
    from repro.core.clustering import PostingLists


# ---------------------------------------------------------------------------
# Delta segment
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DeltaSegment:
    """The mutable tail of the index, snapshotted functionally.

    Every mutation returns a NEW ``DeltaSegment`` (arrays are never
    written in place), so a published :class:`IndexView` holds a delta
    that can never change under its readers.  Global ids are positional:
    row ``i`` is vector ``base + i``; compaction seals a PREFIX of the
    rows, so surviving rows keep their global ids with a higher base.
    """

    base: int                   # global id of row 0
    vectors: np.ndarray         # (D, dim) float32, raw (un-rotated) space
    tombstoned: np.ndarray      # (D,) bool
    # per-row metadata columns (core/filters.py), local row-space; None
    # normalizes to an empty table so pre-filter constructors keep working
    attrs: Optional[AttributeTable] = None

    def __post_init__(self):
        if self.attrs is None:
            object.__setattr__(
                self, "attrs", AttributeTable.empty(len(self.vectors)))

    @staticmethod
    def empty(base: int, dim: int) -> "DeltaSegment":
        return DeltaSegment(base=int(base),
                            vectors=np.zeros((0, dim), np.float32),
                            tombstoned=np.zeros((0,), bool))

    def __len__(self) -> int:
        return len(self.vectors)

    @property
    def ids(self) -> np.ndarray:
        """Global ids of every row (including tombstoned ones)."""
        return np.arange(self.base, self.base + len(self.vectors),
                         dtype=np.int64)

    def live_count(self) -> int:
        return int(len(self.tombstoned) - np.count_nonzero(self.tombstoned))

    def append(self, vectors: np.ndarray,
               attributes=None) -> "DeltaSegment":
        vecs = np.atleast_2d(vectors)
        return DeltaSegment(
            base=self.base,
            vectors=np.concatenate([self.vectors, vecs]),
            tombstoned=np.concatenate(
                [self.tombstoned, np.zeros(len(vecs), bool)]),
            attrs=self.attrs.append(len(vecs), attributes))

    def tombstone(self, local_ids: np.ndarray) -> "DeltaSegment":
        flags = self.tombstoned.copy()
        flags[local_ids] = True
        return DeltaSegment(base=self.base, vectors=self.vectors,
                            tombstoned=flags, attrs=self.attrs)

    def drop_prefix(self, n: int) -> "DeltaSegment":
        """The segment left after sealing rows ``[0, n)`` — survivors keep
        their global ids because the base advances by exactly ``n``."""
        return DeltaSegment(base=self.base + int(n),
                            vectors=self.vectors[n:],
                            tombstoned=self.tombstoned[n:],
                            attrs=self.attrs.drop_prefix(n))

    def scan(self, query: np.ndarray,
             filt: Optional[Predicate] = None
             ) -> Tuple[np.ndarray, np.ndarray]:
        """Exact squared-L2 over live rows matching ``filt`` -> (global
        ids, dists).

        Same metric as ``heuristic_rerank``'s SSD re-scoring, so the two
        result streams merge with one lexsort on ``(dist, id)``.  The
        predicate applies BEFORE the distance computation — selectivity
        shrinks the delta scan exactly like it shrinks the sealed one.
        """
        live = np.flatnonzero(~self.tombstoned)
        if filt is not None and len(live):
            live = live[filt.mask(self.attrs, live)]
        if not len(live):
            return (np.zeros((0,), np.int64), np.zeros((0,), np.float32))
        vecs = self.vectors[live]
        diff = vecs - query.astype(np.float32)[None]
        d2 = np.einsum("ij,ij->i", diff, diff).astype(np.float32)
        return self.base + live.astype(np.int64), d2


# ---------------------------------------------------------------------------
# Immutable view
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class IndexView:
    """One consistent, epoch-stamped binding of every tier.

    Published by atomic reference assignment (``index._view = view``);
    readers pin a view once per scan window and never lock.  All arrays
    reachable from a view are treated as immutable: compaction builds
    fresh posting/tombstone/code objects instead of extending in place,
    and ``SSDSim``/``StorageLayout`` extension is prefix-preserving
    (sealed rows never move), so a reader holding an old view stays
    internally consistent forever.
    """

    epoch: int
    codes: "jax.Array"          # (n_rows, M) uint8 — sealed PQ segment(s)
    posting: "PostingLists"     # sealed DRAM ID metadata (row-space members)
    tombstones: np.ndarray      # (n_sealed,) bool — ID-space
    graph: ng.NavGraph
    delta: DeltaSegment
    # per-row metadata columns, ID-space over the sealed prefix (the
    # tombstone filter runs first, so purged ids never reach a lookup)
    attrs: Optional[AttributeTable] = None
    # seal-time purge indirection (DESIGN.md §11): compaction drops
    # tombstoned delta rows instead of encoding them, so physical code/SSD
    # rows and global ids diverge.  ``id_of`` maps physical row -> global
    # id (strictly increasing); ``row_of`` maps global id -> physical row
    # (-1 for purged ids).  None normalizes to the identity, so
    # constructors predating the purge keep working unchanged.
    id_of: Optional[np.ndarray] = None      # (n_rows,) int64
    row_of: Optional[np.ndarray] = None     # (n_sealed,) int64

    def __post_init__(self):
        if self.attrs is None:
            object.__setattr__(
                self, "attrs", AttributeTable.empty(self.n_sealed))
        if self.id_of is None:
            object.__setattr__(
                self, "id_of", np.arange(self.n_sealed, dtype=np.int64))
        if self.row_of is None:
            object.__setattr__(
                self, "row_of",
                row_of_from_id_of(self.id_of, self.n_sealed))

    @property
    def n_sealed(self) -> int:
        """Sealed ids ever published (id-space; includes purged ids)."""
        return len(self.tombstones)

    @property
    def n_rows(self) -> int:
        """Physical sealed rows (``== len(codes)``; <= n_sealed)."""
        return len(self.id_of)

    @property
    def n_total(self) -> int:
        return self.n_sealed + len(self.delta)

    # ------------------------------------------------------------- queries
    def candidate_ids(self, query: np.ndarray, top_m: int,
                      dedup: bool = True,
                      filt: Optional[Predicate] = None) -> np.ndarray:
        """Stages ②③⑤ over the SEALED segments: graph traversal -> row
        collection -> dedup -> tombstone filter -> predicate filter.
        Posting members are physical ROW indices; the ids returned are
        global and ``< n_sealed`` by construction — posting lists,
        tombstones, and the id map in one view always describe the same
        sealed prefix, which is the whole-of-PR-9 fix for the torn-tier
        gathers.  ``filt`` drops non-matching ids HERE, before any ADC
        work is attributed to them."""
        return self.collect_candidates(query, top_m, dedup=dedup,
                                       filt=filt)[0]

    def collect_candidates(self, query: np.ndarray, top_m: int,
                           dedup: bool = True,
                           filt: Optional[Predicate] = None
                           ) -> Tuple[np.ndarray, np.ndarray]:
        """``(filtered_ids, prefilter_ids)`` — the second array is the
        candidate set BEFORE the predicate (after dedup + tombstones), so
        callers can prove selectivity shrank the scan
        (``QueryStats.candidates_prefilter``).  Same object twice when
        ``filt is None``."""
        cids = ng.search(self.graph, query.astype(np.float32), top_m)
        rows = np.concatenate([self.posting.members[c] for c in cids]) \
            if len(cids) else np.zeros((0,), np.int32)
        if dedup:
            rows = np.unique(rows)
        # id_of is strictly increasing, so row order == id order and the
        # dedup above also dedups ids
        ids = self.id_of[rows] if len(rows) else \
            np.zeros((0,), np.int64)
        if len(ids):
            ids = ids[~self.tombstones[ids]]
        if filt is None:
            return ids, ids
        return (ids[filt.mask(self.attrs, ids)] if len(ids) else ids), ids

    def delta_scan(self, query: np.ndarray,
                   filt: Optional[Predicate] = None
                   ) -> Tuple[np.ndarray, np.ndarray]:
        """Exact scan of the delta segment -> (global ids, squared-L2)."""
        return self.delta.scan(query, filt=filt)


def row_of_from_id_of(id_of: np.ndarray, n_ids: int) -> np.ndarray:
    """Invert a physical-row -> global-id map; purged ids map to -1."""
    row_of = np.full(int(n_ids), -1, np.int64)
    row_of[id_of] = np.arange(len(id_of), dtype=np.int64)
    return row_of


# ---------------------------------------------------------------------------
# Background compaction
# ---------------------------------------------------------------------------

class SegmentCompactor:
    """Background thread sealing the delta whenever it holds at least
    ``min_delta`` rows.

    Parks on the index's ``compaction``-ranked condition; inserts notify
    it, so sealing starts within one wakeup of the threshold being
    crossed (``poll_s`` bounds the latency when a notify is missed).
    The heavy work — re-cluster, PQ encode, SSD extension — runs in
    :meth:`FusionANNSIndex.compact` OUTSIDE the lock; only the
    claim/publish critical sections hold it, so inserts, deletes, and
    queries keep flowing mid-compaction.
    """

    def __init__(self, index, *, min_delta: int = 64,
                 poll_s: float = 0.05):
        self.index = index
        self.min_delta = int(min_delta)
        self.poll_s = float(poll_s)
        self._stop_requested = False    # written under index._mut_cond
        self._thread: Optional[threading.Thread] = None

    def start(self) -> "SegmentCompactor":
        if self._thread is not None:
            return self
        self._thread = threading.Thread(
            target=self._loop, name="segment-compactor", daemon=True)
        self._thread.start()
        return self

    def _loop(self) -> None:
        idx = self.index
        while True:
            with idx._mut_cond:  # acquires: compaction
                while (not self._stop_requested
                       and len(idx._view.delta) < self.min_delta):
                    idx._mut_cond.wait(self.poll_s)
                if self._stop_requested:
                    return
            idx.compact()

    def stop(self, *, flush: bool = False) -> None:
        """Stop the thread; with ``flush=True`` seal any remaining delta
        rows after it exits (drain-to-sealed for snapshot-heavy tests)."""
        t = self._thread
        if t is not None:
            with self.index._mut_cond:  # acquires: compaction
                self._stop_requested = True
                self.index._mut_cond.notify_all()
            t.join(timeout=30.0)
            self._thread = None
            self._stop_requested = False
        if flush:
            self.index.compact()
