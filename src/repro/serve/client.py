"""One serving API: typed requests/responses, a common ``Backend``
protocol, and sync + asyncio front doors (the PR-5 API redesign).

PRs 1-4 grew three divergent submit surfaces — ``QueryExecutor.submit``,
``BatchingANNSService.submit``, ``ReplicaRouter.submit`` — with argument
sprawl and three different result shapes (``QueryResult``, ``Response``,
and a routed-future shim).  This module collapses them into one contract
(DESIGN.md §6):

* :class:`SearchRequest` / :class:`SearchResponse` — the typed request/
  response pair every serving path speaks.  A response always exposes
  ``ids`` / ``dists`` / ``stats`` (the shared ``QueryStats`` schema) plus
  ``latency_s`` (submit→resolve) and the serving attribution fields.
* :class:`Backend` — the protocol the executor, the batching service, and
  the replica router all implement: ``submit(request) -> QueryFuture``
  (resolving to a :class:`SearchResponse`), ``drain()`` (returns the
  responses served since the last drain — the service/router drain
  contracts are unified here), ``stop()``, ``live_load()``,
  ``latency_percentiles()``, ``stats_rollup()``.  Any front end composes
  with any backend.
* :class:`ANNSClient` — the synchronous front door: ``search()`` blocks
  through admission (no :class:`BackpressureError` reaches the caller)
  and returns the response.
* :class:`AsyncANNSClient` — the asyncio front door over the router (or
  any backend): ``await client.search(req)``, ``search_many()`` streaming
  results in completion order, backpressure that AWAITS admission instead
  of raising, and deadlines mapped to asyncio timeouts.  One event loop
  drives thousands of in-flight requests over N threaded replicas; the
  bridge is ``QueryFuture.add_done_callback`` +
  ``loop.call_soon_threadsafe`` — no thread per request.
* :func:`coalesce_key` / :class:`RequestCoalescer` — the PR-7 coalescing
  hooks (DESIGN.md §8): identical in-flight queries (same query bytes AND
  same effective plan knobs — k, top_n, deadline_s, fused, lut_int8)
  share ONE backend submit.  Late arrivals get a fresh *attached* future
  mirroring the leader's via ``add_done_callback``; cancelling an
  attached waiter never cancels the shared backend future.  The HTTP
  edge (``serve/edge.py``) turns this on by default; any
  ``AsyncANNSClient`` can opt in via ``coalescer=``.
"""

from __future__ import annotations

import asyncio
import dataclasses
import threading
import time
from typing import (Any, AsyncIterator, Callable, Dict, Iterable, List,
                    Optional,
                    Protocol, Sequence, runtime_checkable)

import numpy as np

from repro.analysis.concurrency.witness import make_lock
from repro.core.executor import QueryResult, QueryStats
from repro.core.futures import (BackpressureError, DeadlineExceeded,
                                QueryFuture)

__all__ = ["SearchRequest", "SearchResponse", "Backend", "ANNSClient",
           "AsyncANNSClient", "as_request", "coalesce_key",
           "RequestCoalescer"]


# ---------------------------------------------------------------------------
# Typed request / response
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SearchRequest:
    """One search, fully specified.  ``None`` knobs mean "the index
    config's default" (merged via ``PlanOverrides`` — explicit zeros are
    honored, only ``None`` defers)."""

    query: np.ndarray
    k: Optional[int] = None             # results wanted
    top_n: Optional[int] = None         # re-rank candidate budget
    deadline_s: Optional[float] = None  # relative to submit(); None = never
    tag: Any = None                     # caller correlation handle
    tenant: Optional[str] = None        # multi-tenant attribution (the HTTP
    #                                     edge stamps this from the API key)
    filter: Optional[Any] = None        # metadata predicate (core/filters);
    #                                     the tenant layer conjoins its base
    #                                     predicate underneath this one
    adaptive: bool = False              # let the deadline-adaptive planner
    #                                     pick top_m/top_n for deadline_s

    def __post_init__(self):
        self.query = np.asarray(self.query, np.float32)


@dataclasses.dataclass
class SearchResponse:
    """What every serving path resolves to.

    ``ids``/``dists``/``stats`` are the query result proper; ``latency_s``
    is submit→resolve wall clock; ``rid``/``tag`` correlate with the
    request; the ``t_queue_s``/``t_serve_s``/``batch_size`` attribution
    fields are filled by the batching tiers (a direct executor serve
    reports ``batch_size=1`` and zero queueing)."""

    ids: np.ndarray
    dists: np.ndarray
    stats: QueryStats
    latency_s: float = 0.0
    rid: int = -1
    tag: Any = None
    tenant: Optional[str] = None     # rides from the request (edge auth)
    t_queue_s: float = 0.0           # time waiting for the batch window
    t_serve_s: float = 0.0           # batch execution time (shared)
    batch_size: int = 1


def as_request(query, k: Optional[int] = None, *,
               top_n: Optional[int] = None,
               deadline_s: Optional[float] = None,
               tag: Any = None, tenant: Optional[str] = None,
               filter: Optional[Any] = None,
               adaptive: Optional[bool] = None) -> SearchRequest:
    """Normalize a raw query vector + kwargs into a :class:`SearchRequest`
    (the front-door convenience used by :class:`ANNSClient` /
    :class:`AsyncANNSClient`; backend ``submit`` methods take the typed
    request only).  A ready-made request passes through untouched —
    unless explicit kwargs ride along, which override its fields (a
    fresh request, never a mutation) instead of being silently dropped."""
    if isinstance(query, SearchRequest):
        over = {name: v for name, v in (
            ("k", k), ("top_n", top_n), ("deadline_s", deadline_s),
            ("tag", tag), ("tenant", tenant), ("filter", filter),
            ("adaptive", adaptive)) if v is not None}
        return dataclasses.replace(query, **over) if over else query
    return SearchRequest(query=query, k=k, top_n=top_n,
                         deadline_s=deadline_s, tag=tag, tenant=tenant,
                         filter=filter, adaptive=bool(adaptive))


def response_from_result(res: QueryResult, *, latency_s: float,
                         rid: int = -1, tag: Any = None,
                         tenant: Optional[str] = None,
                         t_queue_s: float = 0.0, t_serve_s: float = 0.0,
                         batch_size: int = 1) -> SearchResponse:
    """Wrap an executor :class:`QueryResult` in the uniform response."""
    return SearchResponse(ids=res.ids, dists=res.dists, stats=res.stats,
                          latency_s=latency_s, rid=rid, tag=tag,
                          tenant=tenant, t_queue_s=t_queue_s,
                          t_serve_s=t_serve_s, batch_size=batch_size)


# ---------------------------------------------------------------------------
# The Backend protocol
# ---------------------------------------------------------------------------

@runtime_checkable
class Backend(Protocol):
    """The uniform serving surface (DESIGN.md §6).

    Implemented by :class:`~repro.core.executor.QueryExecutor` (no queue:
    dispatch at submit, caller-driven retirement),
    :class:`~repro.serve.anns_service.BatchingANNSService` (dynamic
    batching, one replica), and :class:`~repro.serve.router.ReplicaRouter`
    (N replicas over disjoint device groups).  Every ``submit`` future
    resolves to a :class:`SearchResponse`."""

    def submit(self, request: SearchRequest) -> QueryFuture: ...  # noqa: E704

    def drain(self) -> List[SearchResponse]: ...                  # noqa: E704

    def stop(self): ...                                           # noqa: E704

    def live_load(self) -> int: ...                               # noqa: E704

    def latency_percentiles(self) -> Dict[str, float]: ...        # noqa: E704

    def stats_rollup(self) -> Dict[str, object]: ...              # noqa: E704


# ---------------------------------------------------------------------------
# Request coalescing (PR 7 — DESIGN.md §8)
# ---------------------------------------------------------------------------

def coalesce_key(request: SearchRequest, *, fused: bool = False,
                 lut_int8: bool = False,
                 epoch: Optional[int] = None) -> tuple:
    """Identity of the backend work a request triggers: the query bytes
    plus EVERY effective plan knob — ``k``/``top_n``/``deadline_s`` from
    the request and the serving stack's ``fused``/``lut_int8`` accuracy
    knobs — plus the index's segment-list ``epoch``.  Two requests may
    share one backend submit iff their keys are equal; anything that
    could change the returned ids (or the latency contract, for
    deadlines) keys separately.  The epoch is what keeps coalescing
    honest under streaming updates (DESIGN.md §10): an insert/delete/
    compaction bumps it, so a request arriving after a mutation never
    attaches to a leader dispatched against the pre-mutation view.
    ``filter``, ``tenant``, and ``adaptive`` key separately too
    (DESIGN.md §11): the predicate changes the candidate set, the tenant
    determines the base predicate the tenant layer will stamp (two
    tenants' identical queries must NEVER share a scan — isolation, not
    just correctness), and an adaptive request may serve at a reduced
    accuracy level.  Only ``tag`` is correlation metadata outside the
    key — attached waiters get their own tag stamped onto the shared
    response."""
    q = np.ascontiguousarray(np.asarray(request.query, np.float32))
    return (q.tobytes(), q.shape, request.k, request.top_n,
            request.deadline_s, bool(fused), bool(lut_int8), epoch,
            request.filter, request.tenant, bool(request.adaptive))


class RequestCoalescer:
    """Share one backend submit among identical in-flight requests.

    The first arrival for a key is the LEADER: ``claim()`` hands back the
    key, the caller performs the real (possibly awaited) backend submit,
    then ``publish()`` binds the backend future.  Late arrivals for the
    same key get an ATTACHED future — a fresh :class:`QueryFuture`
    mirroring the leader's via ``add_done_callback``, with their own
    ``tag``/``tenant`` stamped onto the shared :class:`SearchResponse`.
    Cancelling an attached waiter flips only that waiter; the shared
    backend future (and every other waiter) is untouched.  When the
    leader's future resolves the key retires, so a later identical
    request starts a fresh submit (coalescing is an IN-FLIGHT dedup, not
    a response cache).

    Thread-safe: the edge's event loop, replica pump threads (resolving
    leaders), and sync callers may all touch one coalescer."""

    def __init__(self, *, fused: bool = False, lut_int8: bool = False,
                 epoch_source: Optional[Callable[[], int]] = None):
        self.fused = fused
        self.lut_int8 = lut_int8
        # () -> current index epoch (e.g. ``lambda: backend.epoch``);
        # sampled at claim time so a mutation between two identical
        # requests forces the second into its own leader submit
        self.epoch_source = epoch_source
        self._lock = make_lock("coalescer")
        # key -> [master future or None (leader mid-admission), waiters]
        self._inflight: Dict[tuple, list] = {}    # guarded-by: _lock
        self.stats: Dict[str, int] = {
            "leaders": 0, "attached": 0}          # guarded-by: _lock

    def key(self, request: SearchRequest) -> tuple:
        epoch = (None if self.epoch_source is None
                 else int(self.epoch_source()))
        return coalesce_key(request, fused=self.fused,
                            lut_int8=self.lut_int8, epoch=epoch)

    def live(self) -> int:
        """Keys currently in flight (leader submitted or mid-admission)."""
        with self._lock:
            return len(self._inflight)

    def claim(self, request: SearchRequest):
        """Returns ``(True, key)`` when the caller must perform the real
        backend submit (leader; follow with ``publish``/``abandon``), or
        ``(False, attached_future)`` when an identical request is already
        in flight."""
        k = self.key(request)
        with self._lock:
            entry = self._inflight.get(k)
            if entry is not None:
                master = entry[0]
                if master is None or not master.done():
                    self.stats["attached"] += 1
                    fut = self._make_attached(request)
                    if master is None:       # leader still mid-admission
                        entry[1].append((fut, request))
                    else:
                        self._mirror(master, fut, request)
                    return False, fut
                # leader resolved between retire and this claim: recycle
                del self._inflight[k]
            self._inflight[k] = [None, []]
            self.stats["leaders"] += 1
            return True, k

    def publish(self, key: tuple, master: QueryFuture) -> None:
        """Leader's backend submit succeeded: bind the shared future and
        wire every waiter that queued up during admission."""
        with self._lock:
            entry = self._inflight.get(key)
            if entry is None:
                return
            entry[0] = master
            waiters, entry[1] = entry[1], []
        for fut, req in waiters:
            self._mirror(master, fut, req)
        master.add_done_callback(lambda _f: self._retire(key, master))

    def abandon(self, key: tuple, exc: Optional[BaseException]) -> None:
        """Leader's submit failed (client closed, admission error): fail
        any queued waiters and free the key for the next arrival."""
        with self._lock:
            entry = self._inflight.pop(key, None)
        if entry is None:
            return
        for fut, _req in entry[1]:
            if exc is not None:
                fut._set_exception(exc)
            else:
                fut.cancel()

    # ------------------------------------------------------------- internal
    def _retire(self, key: tuple, master: QueryFuture) -> None:
        with self._lock:
            entry = self._inflight.get(key)
            if entry is not None and entry[0] is master:
                del self._inflight[key]

    @staticmethod
    def _make_attached(request: SearchRequest) -> QueryFuture:
        # blocking=True: resolution always comes from the leader's resolver
        # thread via the mirror callback — there is no driver to run
        return QueryFuture(tag=request.tag, blocking=True)

    @staticmethod
    def _mirror(master: QueryFuture, fut: QueryFuture,
                request: SearchRequest) -> None:
        def _copy(f: QueryFuture):
            if fut.done():                  # waiter cancelled on its own
                return
            try:
                resp = f.result()
            except BaseException as exc:    # noqa: BLE001 — incl. Cancelled
                fut._set_exception(exc)
                return
            if isinstance(resp, SearchResponse):
                resp = dataclasses.replace(resp, tag=request.tag,
                                           tenant=request.tenant)
            fut._set_result(resp)
        master.add_done_callback(_copy)


# ---------------------------------------------------------------------------
# Synchronous front door
# ---------------------------------------------------------------------------

class ANNSClient:
    """Blocking client over any :class:`Backend`.

    ``search()`` never surfaces :class:`BackpressureError`: a rejected
    submission waits (``admission_wait_s`` backoff) for the backend to
    drain a slot, then retries — the caller sees admission latency, not an
    exception."""

    def __init__(self, backend: Backend, *, admission_wait_s: float = 1e-3,
                 admission_timeout_s: Optional[float] = None):
        self.backend = backend
        self.admission_wait_s = admission_wait_s
        self.admission_timeout_s = admission_timeout_s
        # a sync client is routinely shared by N producer threads (the
        # examples' drive_producers shape): counters and the stray buffer
        # are lock-guarded so none of them undercount under contention
        self._lock = make_lock("client")
        self.stats: Dict[str, int] = {
            "submitted": 0, "admission_waits": 0}  # guarded-by: _lock
        # responses a caller-driven backend served while WE drained it to
        # free admission slots: the drain contract owes them to whoever
        # calls drain(), so they stay reachable here instead of vanishing
        self.stray_responses: List[SearchResponse] = []  # guarded-by: _lock

    def submit(self, request, k: Optional[int] = None, *,
               top_n: Optional[int] = None,
               deadline_s: Optional[float] = None,
               tag: Any = None) -> QueryFuture:
        """Admit one request (blocking through backpressure); returns the
        backend's future.

        A threaded backend frees slots on its own: rejection becomes a
        plain sleep-retry (never a full-idle ``drain()`` barrier, and the
        backend owner's undrained-responses buffer is left alone).  A
        caller-driven sync-harness backend only makes progress when WE
        pump it: prefer its ``pump()`` surface (keeps the drain contract
        intact); failing that, fall back to ``drain()`` after repeated
        rejections, stashing the responses in ``stray_responses``."""
        req = as_request(request, k, top_n=top_n, deadline_s=deadline_s,
                         tag=tag)
        t0 = time.perf_counter()
        tries = 0
        pump = getattr(self.backend, "pump", None)
        while True:
            try:
                fut = self.backend.submit(req)
            except BackpressureError:
                with self._lock:
                    self.stats["admission_waits"] += 1
                if (self.admission_timeout_s is not None and
                        time.perf_counter() - t0 > self.admission_timeout_s):
                    raise
                tries += 1
                if getattr(self.backend, "threaded", False):
                    # threads free slots on their own; NEVER drain (a
                    # full-idle barrier under sustained traffic, and it
                    # would steal the owner's undrained buffer)
                    time.sleep(self.admission_wait_s)
                elif pump is not None:
                    pump(force=True)       # we ARE the sync harness's pump
                else:
                    time.sleep(self.admission_wait_s)
                    if tries % 16 == 0:    # no progress: caller-driven,
                        drained = self.backend.drain()  # no pump surface
                        with self._lock:
                            self.stray_responses.extend(drained)
                continue
            with self._lock:
                self.stats["submitted"] += 1
            return fut

    def search(self, request, k: Optional[int] = None, *,
               top_n: Optional[int] = None,
               deadline_s: Optional[float] = None,
               tag: Any = None,
               timeout: Optional[float] = None) -> SearchResponse:
        return self.submit(request, k, top_n=top_n, deadline_s=deadline_s,
                           tag=tag).result(timeout=timeout)

    def search_many(self, requests: Iterable, *,
                    timeout: Optional[float] = None) -> List[SearchResponse]:
        """Submit everything (blocking through admission), resolve in
        submission order."""
        futs = [self.submit(r) for r in requests]
        return [f.result(timeout=timeout) for f in futs]


# ---------------------------------------------------------------------------
# Asyncio front door
# ---------------------------------------------------------------------------

class AsyncANNSClient:
    """One event loop over any :class:`Backend` — the deployment front
    door (ROADMAP: "an asyncio front door over the router").

    * **bridge** — each backend :class:`QueryFuture` is mirrored into an
      ``asyncio.Future`` via ``add_done_callback`` +
      ``loop.call_soon_threadsafe``: the replica pump thread that resolves
      the query wakes the loop, no thread parks per request.  A backend
      running the caller-driven sync harness (no pump thread) is detected
      and driven from the loop's default thread pool, serialized so the
      single-driver assumption of that harness holds.
    * **admission** — ``max_inflight`` is a client-side
      ``asyncio.Semaphore``; past it, callers AWAIT a slot.  A backend
      :class:`BackpressureError` is absorbed the same way: the coroutine
      sleeps ``admission_poll_s`` and retries until admitted.  ``search``
      never raises ``BackpressureError``.
    * **deadlines** — ``request.deadline_s`` rides to the backend (which
      expires the re-rank) AND bounds the await via ``asyncio.wait_for``;
      an asyncio timeout cancels the backend future and surfaces
      :class:`DeadlineExceeded`, so both expiry paths look identical to
      the caller.
    * **streaming** — ``search_many()`` yields responses in COMPLETION
      order (``asyncio.as_completed``), so a slow re-rank never
      head-of-line-blocks finished neighbours.
    """

    def __init__(self, backend: Backend, *, max_inflight: int = 256,
                 admission_poll_s: float = 1e-3,
                 coalescer: Optional[RequestCoalescer] = None):
        self.backend = backend
        self.max_inflight = max_inflight
        self.admission_poll_s = admission_poll_s
        # optional in-flight dedup of identical requests (DESIGN.md §8):
        # followers attach to the leader's backend future instead of
        # consuming a backend queue slot
        self.coalescer = coalescer
        self._sem = asyncio.Semaphore(max_inflight)
        self._inflight: set = set()        # bridged asyncio futures
        # serializes sync-harness drives; ranked "client" because driving
        # qfut.result() pumps the service (and its ticket/future locks)
        # underneath — client must sit above service in the hierarchy
        self._drive_lock = make_lock("client")
        self.stats: Dict[str, int] = {
            "submitted": 0, "completed": 0, "admission_waits": 0,
            "deadline_timeouts": 0, "coalesced": 0}
        self._closed = False

    # ------------------------------------------------------------- plumbing
    def _settle(self, qfut: QueryFuture) -> None:
        """Thread-pool driver for sync-harness backends: resolve ``qfut``
        by driving its producer.  Exceptions land on the future (the
        bridge callback reads them); serialization keeps the caller-driven
        harness single-driver."""
        with self._drive_lock:
            try:
                qfut.result()
            except BaseException:          # noqa: BLE001 — stays on qfut
                pass

    def _bridge(self, qfut: QueryFuture,
                loop: asyncio.AbstractEventLoop) -> asyncio.Future:
        """Mirror a backend future into the loop.  Resolution (any thread)
        schedules the hand-off; a bridged future the loop already
        cancelled (deadline timeout) is left alone."""
        afut = loop.create_future()

        def _publish(res, exc):
            if afut.done():                # cancelled by wait_for
                return
            if exc is not None:
                afut.set_exception(exc)
            else:
                afut.set_result(res)

        def _on_done(f: QueryFuture):
            try:
                res, exc = f.result(), None
            except BaseException as e:     # noqa: BLE001 — incl. Cancelled
                res, exc = None, e
            loop.call_soon_threadsafe(_publish, res, exc)

        qfut.add_done_callback(_on_done)
        if not qfut.done() and getattr(qfut, "_driver", None) is not None:
            # caller-driven harness: nobody else will resolve this future;
            # drive it off-loop (bounded by the default executor pool)
            loop.run_in_executor(None, self._settle, qfut)
        return afut

    async def _admit(self, req: SearchRequest) -> QueryFuture:
        """Submit, AWAITING admission on backpressure instead of raising
        (the redesign's contract: admission latency, not exceptions)."""
        while True:
            try:
                fut = self.backend.submit(req)
            except BackpressureError:
                self.stats["admission_waits"] += 1
                await asyncio.sleep(self.admission_poll_s)
                continue
            self.stats["submitted"] += 1
            return fut

    async def _submit_or_attach(self, req: SearchRequest) -> QueryFuture:
        """The coalescing hook: a request identical to one already in
        flight (same :func:`coalesce_key`) attaches to the leader's
        backend future instead of submitting — ONE backend submit serves
        the whole duplicate burst.  Cancelling an attached future (the
        deadline/teardown paths above) never cancels the shared one."""
        if self.coalescer is None:
            return await self._admit(req)
        leader, handle = self.coalescer.claim(req)
        if not leader:
            self.stats["coalesced"] += 1
            return handle
        try:
            qfut = await self._admit(req)
        except BaseException as exc:       # noqa: BLE001 — incl. Cancelled
            self.coalescer.abandon(handle, exc)
            raise
        self.coalescer.publish(handle, qfut)
        return qfut

    # ---------------------------------------------------------------- public
    async def search(self, request, k: Optional[int] = None, *,
                     top_n: Optional[int] = None,
                     deadline_s: Optional[float] = None,
                     tag: Any = None) -> SearchResponse:
        """Serve one request end to end: await an inflight slot, await
        admission, await the response.  ``deadline_s`` bounds ALL of it —
        the semaphore wait and the admission retries count against the
        same budget as the scan, so a deadlined request can never wait
        past its deadline just to get admitted.  Expiry — loop-side or
        backend-side — raises :class:`DeadlineExceeded`."""
        if self._closed:
            raise RuntimeError("AsyncANNSClient is closed")
        req = as_request(request, k, top_n=top_n, deadline_s=deadline_s,
                         tag=tag)
        if req.deadline_s is None:
            return await self._search_inner(req, None)
        holder: Dict[str, QueryFuture] = {}
        try:
            return await asyncio.wait_for(self._search_inner(req, holder),
                                          req.deadline_s)
        except asyncio.TimeoutError:
            self.stats["deadline_timeouts"] += 1
            qfut = holder.get("qfut")
            if qfut is not None:           # admitted: skip its re-rank
                qfut.cancel()
            raise DeadlineExceeded(
                f"asyncio deadline of {req.deadline_s}s passed awaiting "
                f"request tag={req.tag!r}") from None

    async def _search_inner(self, req: SearchRequest,
                            holder: Optional[Dict[str, QueryFuture]]
                            ) -> SearchResponse:
        loop = asyncio.get_running_loop()
        async with self._sem:
            qfut = await self._submit_or_attach(req)
            if holder is not None:
                holder["qfut"] = qfut
            afut = self._bridge(qfut, loop)
            self._inflight.add(afut)
            try:
                resp = await afut
                self.stats["completed"] += 1
                return resp
            except asyncio.CancelledError:
                # the caller's task was cancelled (deadline timeout above,
                # a consumer bailing out of search_many, gather teardown):
                # the request is already admitted, so cancel the backend
                # future too — its re-rank is skipped and no backend
                # future outlives its awaiter
                qfut.cancel()
                raise
            finally:
                self._inflight.discard(afut)

    async def search_many(self, requests: Sequence, *,
                          return_exceptions: bool = False
                          ) -> AsyncIterator[SearchResponse]:
        """Submit a whole workload and yield responses AS THEY COMPLETE —
        each one carries its request's ``tag`` for correlation.  With
        ``return_exceptions=True`` failed requests yield their exception
        object instead of aborting the stream."""
        tasks = [asyncio.ensure_future(self.search(r)) for r in requests]
        try:
            for nxt in asyncio.as_completed(tasks):
                try:
                    yield await nxt
                except Exception as exc:   # noqa: BLE001 — per-request
                    if not return_exceptions:
                        raise
                    yield exc
        finally:
            for t in tasks:                # a consumer bailing mid-stream
                if not t.done():           # must not leak pending tasks
                    t.cancel()
            await asyncio.gather(*tasks, return_exceptions=True)

    async def drain(self) -> None:
        """Await every in-flight request (exceptions stay with their
        awaiters)."""
        while self._inflight:
            await asyncio.gather(*list(self._inflight),
                                 return_exceptions=True)

    async def aclose(self) -> None:
        """Refuse new requests, then settle all in-flight ones.  Zero
        backend futures stay pending past this call (closing BEFORE the
        drain, so no concurrent ``search()`` slips in behind it); the
        backend itself (threads, replicas) is NOT stopped — the client
        does not own it."""
        self._closed = True
        await self.drain()

    async def __aenter__(self) -> "AsyncANNSClient":
        return self

    async def __aexit__(self, *exc) -> None:
        await self.aclose()
