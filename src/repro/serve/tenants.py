"""Per-tenant namespaces over any Backend (DESIGN.md §11).

One index, many tenants: a recsys workload and a RAG workload share the
same sealed/delta segments, but each tenant must only ever see its own
rows, pay for its own traffic, and show up in its own books.  This module
is that boundary, layered as a :class:`~repro.serve.client.Backend`
wrapper so it composes with every front door (the sync/async clients, the
HTTP edge) and every backend (executor, batching service, replica
router):

* **namespace isolation** — each :class:`TenantConfig` carries a *base
  predicate* (``filter=``, e.g. ``Eq("tenant", 7)``); ``submit()``
  conjoins it UNDER the request's own filter via
  :func:`~repro.core.filters.combine`, so a request can narrow its
  tenant's view but never widen it.  Predicates fail closed (UNSET rows
  never match — ``core/filters.py``), which makes the base predicate an
  isolation boundary rather than a convention: a row without the tenant
  column is invisible to every tenant.
* **admission quotas** — a per-tenant :class:`TokenBucket` (moved here
  from the PR-7 edge; the edge re-exports it) gates ``submit`` BEFORE the
  backend sees the request.  A drained bucket raises
  :class:`QuotaExceeded` — deliberately NOT a
  :class:`~repro.core.futures.BackpressureError`, so the async client's
  admission retry loop never spins on a quota the caller has to back off
  from (the edge maps it to HTTP 429 + Retry-After).
* **per-tenant books** — submitted/ok/error counters, a bounded latency
  window with percentiles, and summed ``QueryStats`` per tenant, rolled
  up via :meth:`TenantManager.tenant_rollup` and folded into the Backend
  ``stats_rollup()``.

Locking: one ``tenant``-ranked lock guards buckets + books.  It is never
held across a backend call, and it ranks BELOW ``service`` because the
accounting runs in future done-callbacks, which the batching service
fires while holding its own lock.

Requests with ``tenant=None`` pass through untouched (no quota, no base
predicate, no books) — the open-edge/direct-caller path.  A request
naming an UNKNOWN tenant is refused (``ValueError``): fail closed, never
serve a namespace that was not provisioned.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, Deque, Dict, Optional, Sequence

import numpy as np

from repro.analysis.concurrency.witness import make_lock
from repro.core.executor import QUERY_STATS_FIELDS
from repro.core.filters import Predicate, combine
from repro.core.futures import QueryFuture
from repro.serve.client import SearchRequest

__all__ = ["TenantConfig", "TokenBucket", "QuotaExceeded", "TenantManager"]


@dataclasses.dataclass(frozen=True)
class TenantConfig:
    """One API tenant: the key that authenticates it, its rate limit
    (``rate_qps <= 0`` = unlimited; ``burst`` caps how far an idle tenant
    can pre-accumulate), and the base predicate that defines its
    namespace (``None`` = the whole index)."""

    name: str
    api_key: str
    rate_qps: float = 0.0
    burst: int = 8
    filter: Optional[Predicate] = None


class TokenBucket:
    """Classic token bucket with an injectable clock (tests tick it
    deterministically).  ``try_acquire`` never blocks; ``retry_after``
    says how long until one token exists.  Not thread-safe on its own —
    :class:`TenantManager` serializes access under its lock."""

    def __init__(self, rate: float, burst: int = 8,
                 clock: Callable[[], float] = time.monotonic):
        self.rate = float(rate)
        self.burst = max(int(burst), 1)
        self.clock = clock
        self._tokens = float(self.burst)
        self._t = clock()

    def _refill(self) -> None:
        now = self.clock()
        self._tokens = min(self.burst,
                           self._tokens + (now - self._t) * self.rate)
        self._t = now

    def try_acquire(self) -> bool:
        if self.rate <= 0:
            return True
        self._refill()
        if self._tokens >= 1.0:
            self._tokens -= 1.0
            return True
        return False

    def refund(self) -> None:
        """Return one token (an admitted request the backend then refused
        with backpressure did not actually run)."""
        if self.rate > 0:
            self._tokens = min(float(self.burst), self._tokens + 1.0)

    def retry_after(self) -> float:
        if self.rate <= 0:
            return 0.0
        self._refill()
        missing = max(1.0 - self._tokens, 0.0)
        return missing / self.rate


class QuotaExceeded(RuntimeError):
    """A tenant is over its admission quota.  Plain ``RuntimeError`` on
    purpose: the async client's admission loop retries
    ``BackpressureError`` (a transient backend condition), but a quota is
    a caller-side contract — it must surface, not spin."""

    def __init__(self, tenant: str, retry_after: float):
        super().__init__(f"tenant {tenant!r} over quota; "
                         f"retry after {retry_after:.3f}s")
        self.tenant = tenant
        self.retry_after = float(retry_after)


def _fresh_book() -> Dict[str, int]:
    return {"submitted": 0, "ok": 0, "errors": 0, "quota_rejected": 0}


class TenantManager:
    """Backend wrapper enforcing tenant namespaces, quotas, and books.

    Implements the full Backend protocol; everything it does not override
    (``insert``/``delete``/``compact``, ``fused``/``lut_int8``/
    ``threaded``, ``scaling_signals`` …) proxies to the wrapped backend,
    so the manager is a drop-in layer anywhere a backend goes."""

    def __init__(self, backend, tenants: Sequence[TenantConfig] = (), *,
                 clock: Callable[[], float] = time.monotonic):
        self.backend = backend
        self._specs: Dict[str, TenantConfig] = {t.name: t for t in tenants}
        # guards buckets + books; NEVER held across a backend call (see
        # module docstring for why it ranks below "service")
        self._lock = make_lock("tenant")
        self._buckets: Dict[str, TokenBucket] = {       # guarded-by: _lock
            t.name: TokenBucket(t.rate_qps, t.burst, clock)
            for t in tenants}
        self._books: Dict[str, Dict[str, int]] = {      # guarded-by: _lock
            t.name: _fresh_book() for t in tenants}
        self._latencies: Dict[str, Deque[float]] = {    # guarded-by: _lock
            t.name: deque(maxlen=2048) for t in tenants}
        self._totals: Dict[str, Dict[str, int]] = {     # guarded-by: _lock
            t.name: dict.fromkeys(QUERY_STATS_FIELDS, 0) for t in tenants}

    # ---------------------------------------------------------------- submit
    def submit(self, request: SearchRequest) -> QueryFuture:
        """Quota-gate, stamp the tenant's base predicate UNDER the
        request's filter, forward, and hook per-tenant accounting onto the
        backend future.  ``tenant=None`` passes through untouched; an
        unknown tenant is refused (fail closed)."""
        name = request.tenant
        if name is None:
            return self.backend.submit(request)
        spec = self._specs.get(name)
        if spec is None:
            raise ValueError(f"unknown tenant {name!r}; provisioned: "
                             f"{sorted(self._specs)}")
        with self._lock:
            bucket = self._buckets[name]
            if not bucket.try_acquire():
                self._books[name]["quota_rejected"] += 1
                wait = bucket.retry_after()
            else:
                wait = None
        if wait is not None:
            raise QuotaExceeded(name, wait)
        eff = combine(spec.filter, request.filter)
        if eff is not request.filter:
            request = dataclasses.replace(request, filter=eff)
        try:
            fut = self.backend.submit(request)
        except BaseException:
            with self._lock:                # backpressure/refusal: the
                self._buckets[name].refund()  # token was never spent on work
            raise
        with self._lock:
            self._books[name]["submitted"] += 1
        fut.add_done_callback(lambda f: self._account(name, f))
        return fut

    def _account(self, name: str, fut: QueryFuture) -> None:
        # runs in whatever thread resolved the future — possibly while the
        # batching service holds its "service" lock, which is why _lock
        # ranks below it
        try:
            resp = fut.result()
        except BaseException:               # noqa: BLE001 — incl. Cancelled
            with self._lock:
                self._books[name]["errors"] += 1
            return
        latency = float(resp.latency_s)    # materialise OUTSIDE the lock
        counts = [int(getattr(resp.stats, f)) for f in QUERY_STATS_FIELDS]
        with self._lock:
            self._books[name]["ok"] += 1
            self._latencies[name].append(latency)
            totals = self._totals[name]
            for field, c in zip(QUERY_STATS_FIELDS, counts):
                totals[field] += c

    # ----------------------------------------------------------- observation
    def tenant_names(self) -> list:
        return sorted(self._specs)

    def base_filter(self, name: str) -> Optional[Predicate]:
        return self._specs[name].filter

    def tenant_percentiles(self, name: str) -> Dict[str, float]:
        with self._lock:
            snap = list(self._latencies[name])
        lat = np.asarray(snap)       # materialise OUTSIDE the lock (PU01)
        if not len(lat):
            return {"p50": 0.0, "p99": 0.0, "n": 0}
        return {"p50": float(np.percentile(lat, 50)),
                "p99": float(np.percentile(lat, 99)), "n": len(lat)}

    def tenant_rollup(self) -> Dict[str, Dict[str, object]]:
        """Per-tenant books: counters + latency percentiles + summed
        ``QueryStats`` — the isolation witness (two tenants' rollups never
        mix)."""
        with self._lock:
            snap = {name: (dict(self._books[name]),
                           dict(self._totals[name]))
                    for name in self._specs}
        out: Dict[str, Dict[str, object]] = {}
        for name, (book, totals) in snap.items():
            out[name] = {**book, "latency": self.tenant_percentiles(name),
                         "query_stats": totals}
        return out

    # ------------------------------------------------------ Backend protocol
    def drain(self):
        return self.backend.drain()

    def stop(self):
        return self.backend.stop()

    def live_load(self) -> int:
        return self.backend.live_load()

    def latency_percentiles(self) -> Dict[str, float]:
        return self.backend.latency_percentiles()

    def stats_rollup(self) -> Dict[str, object]:
        roll = dict(self.backend.stats_rollup())
        roll["tenants"] = self.tenant_rollup()
        return roll

    @property
    def epoch(self) -> int:
        return self.backend.epoch

    def __getattr__(self, name: str):
        # everything else (insert/delete/compact, fused/lut_int8/threaded,
        # scaling_signals, pump, …) is the wrapped backend's business
        if name == "backend":              # copy/pickle re-entry guard
            raise AttributeError(name)
        return getattr(self.backend, name)
