"""ANNS serving front-end: futures-first request queue + dynamic batching,
with an optional threaded runtime (PR 3).

The paper's prototype binds one CPU thread per query (§5); the TPU
adaptation's natural unit is a *batch* per scan.  This front-end bridges
the two: requests accumulate until ``max_batch`` or ``max_wait_s`` elapses,
then one pass through the unified ``core.executor`` pipeline serves the
whole window — inter-query candidate dedup (§4.3 applied to the HBM scan),
the mesh-sharded ADC scan, and per-request latency attribution all come
from the executor, not from per-path code.

PR-2 redesign (DESIGN.md §3), re-based on the unified client API in PR 5
(DESIGN.md §6): ``submit()`` takes a typed
:class:`~repro.serve.client.SearchRequest` (raw-vector convenience lives
in :class:`~repro.serve.client.ANNSClient` / ``as_request``) and returns
a :class:`~repro.core.futures.QueryFuture` resolving DIRECTLY to a
:class:`~repro.serve.client.SearchResponse` — ``fut.result().ids`` is
the answer — with

* **admission control** — a bounded queue (``max_queue``); submissions past
  the bound raise :class:`BackpressureError` instead of growing latency.
  Only LIVE requests count against the bound: a burst of ``cancel()``
  calls compacts out of the queue at the next submission instead of
  occupying slots until the next pump;
* **per-request plans** — ``k``/``top_n`` ride to the executor as
  ``PlanOverrides``, so a mixed-``k`` batch is honored inside ONE shared
  scan window (the PR-1 service dropped ``Request.k`` on the floor);
* **deadlines + cancellation** — ``deadline_s`` expires requests at batch
  formation or before their re-rank; ``fut.cancel()`` drops a queued
  request or skips its re-rank mid-flight;
* **pipelining** — ``scan_window``/``inflight_depth`` expose the
  executor's ``_InflightQueue``: a pump batch splits into scan windows and
  the rerank of window t overlaps the in-flight scans of t+1..t+d.

Two harnesses (DESIGN.md §"Threading model"):

* **synchronous** (``threaded=False``, the default — every existing test's
  bit-identical-ids guarantee): ``pump()`` drains one batch window inline;
  a pending future drives ``pump(force=True)`` from ``result()``.
* **threaded** (``threaded=True``): a dedicated *pump thread* per replica
  forms batches and drives each ticket's FIFO retirement, while a
  background *ticker thread* calls ``BatchTicket.poll()`` so windows whose
  device scan already landed retire OUT OF ORDER while an older window is
  still re-ranking on the pump thread.  Futures are resolved by the pump
  thread; ``result()`` is a real condition-variable wait.  ``stop()``
  drains the queue gracefully (zero pending futures survive shutdown).
"""

from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.analysis.concurrency.witness import make_condition, make_rlock
from repro.core.engine import FusionANNSIndex
# QUERY_STATS_FIELDS' canonical home moved to core.executor (next to the
# QueryStats schema) in PR 5; re-exported here for existing importers
from repro.core.executor import QUERY_STATS_FIELDS, PlanOverrides
from repro.core.futures import (BackpressureError, DeadlineExceeded,
                                FutureError, QueryFuture)
from repro.serve.client import (SearchRequest, SearchResponse,
                                response_from_result)

__all__ = ["BatchingANNSService", "Request",
           "BackpressureError", "DeadlineExceeded", "QueryFuture",
           "QUERY_STATS_FIELDS"]


@dataclasses.dataclass
class Request:
    rid: int
    query: np.ndarray
    t_enqueue: float
    k: Optional[int] = None
    top_n: Optional[int] = None
    deadline: Optional[float] = None      # absolute perf_counter time
    future: Optional[QueryFuture] = None
    tag: object = None                    # caller correlation handle
    tenant: Optional[str] = None          # multi-tenant attribution (edge)
    filter: object = None                 # metadata predicate (DESIGN.md §11)
    adaptive: bool = False                # deadline-adaptive accuracy opt-in


class BatchingANNSService:
    def __init__(self, index: FusionANNSIndex, *, max_batch: int = 32,
                 max_wait_s: float = 0.002, scan_window: int = 0,
                 overlap_rerank: bool = False, inflight_depth: int = 0,
                 max_queue: int = 1024, threaded: bool = False,
                 tick_interval_s: float = 2e-4, executor=None,
                 fused: bool = False, lut_int8: bool = False):
        # ``executor`` lets a replica run its OWN pipeline instance over
        # the shared index (multi-replica routing: each replica's executor
        # is attached to a disjoint sub-mesh — serve/router.py); default is
        # the index's shared executor, as before
        self.index = index
        self.executor = executor if executor is not None else index.executor
        self.max_batch = max_batch
        self.max_wait_s = max_wait_s
        self.scan_window = scan_window
        self.overlap_rerank = overlap_rerank
        self.inflight_depth = inflight_depth
        # fused LUT→ADC→top-k scan pipeline (plan knob; DESIGN.md §2) and
        # the fig10 int8-LUT accuracy level, inherited by every batch this
        # replica serves
        self.fused = fused
        self.lut_int8 = lut_int8
        self.max_queue = max_queue
        self.tick_interval_s = tick_interval_s
        # one lock guards queue + stats + latencies; the condition wakes
        # the pump thread on submissions and shutdown
        self._lock = make_rlock("service")
        self._cv = make_condition("service", self._lock)
        self._queue: Deque[Request] = deque()     # guarded-by: _lock
        self._next_rid = 0                        # guarded-by: _lock
        self.stats: Dict[str, float] = {
            "batches": 0, "requests": 0, "mean_batch": 0.0,
            "rejected": 0, "expired": 0, "cancelled": 0}  # guarded-by: _lock
        # summed QueryStats counters of every response this replica served
        # (the router's cross-replica rollup reads these); "served" counts
        # only the responses that actually contributed — cancelled/expired
        # requests appear in ``stats`` but never here
        self.query_stats: Dict[str, int] = dict.fromkeys(
            QUERY_STATS_FIELDS, 0)                # guarded-by: _lock
        self.query_stats["served"] = 0
        # enqueue -> resolve per request; bounded so a long-lived replica's
        # percentile window stays O(1) memory (sliding, newest-wins)
        self.latencies_s: Deque[float] = deque(maxlen=8192)  # guarded-by: _lock
        # responses served since the last drain() — the Backend-protocol
        # drain contract; bounded like the latency window so a long-lived
        # replica that is never drained stays O(1) memory
        self._undrained: Deque[SearchResponse] = deque(maxlen=8192)  # guarded-by: _lock
        # per-batch executor event logs (the out-of-order retirement probe)
        self.ticket_events: Deque[List[Tuple[str, int]]] = deque(maxlen=256)  # guarded-by: _lock
        # threaded runtime
        self.threaded = False
        self._running = False                     # guarded-by: _lock
        self._ticker_stop = False
        self._serving = 0   # batches between formation+resolve; guarded-by: _lock
        self._in_flight = 0  # requests inside a forming batch; guarded-by: _lock
        # lock-free single-writer handoff: only _serve_batch_inner (pump
        # thread) writes it; the ticker reads a snapshot and tolerates
        # staleness, so it is deliberately NOT guarded
        self._active_ticket = None
        self._ticker_cv = make_condition("service")   # parks the idle ticker
        self._pump_thread: Optional[threading.Thread] = None
        self._ticker_thread: Optional[threading.Thread] = None
        if threaded:
            self.start()

    # ------------------------------------------------------------ lifecycle
    def start(self) -> "BatchingANNSService":
        """Start the pump + ticker threads (idempotent)."""
        with self._lock:
            if self._running:
                return self
            self._running = True
            self._ticker_stop = False
            self.threaded = True
        self._pump_thread = threading.Thread(
            target=self._pump_loop, name="anns-pump", daemon=True)
        self._ticker_thread = threading.Thread(
            target=self._ticker_loop, name="anns-ticker", daemon=True)
        self._pump_thread.start()
        self._ticker_thread.start()
        return self

    def stop(self) -> "BatchingANNSService":
        """Graceful shutdown: the pump thread drains every queued request
        (resolving all futures), then both threads exit.  Idempotent."""
        with self._cv:
            if not self._running and self._pump_thread is None:
                return self
            self._running = False
            self._cv.notify_all()
        if self._pump_thread is not None:
            self._pump_thread.join()
            self._pump_thread = None
        self._ticker_stop = True
        with self._ticker_cv:
            self._ticker_cv.notify_all()
        if self._ticker_thread is not None:
            self._ticker_thread.join()
            self._ticker_thread = None
        self.threaded = False
        return self

    def __enter__(self) -> "BatchingANNSService":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # --------------------------------------------------------------- submit
    def submit(self, request: SearchRequest) -> QueryFuture:
        """Enqueue one request; returns its future immediately, resolving
        to a :class:`~repro.serve.client.SearchResponse`.  ``request``
        must be a typed :class:`~repro.serve.client.SearchRequest` (the
        Backend-protocol form; raw-vector convenience lives in
        :class:`~repro.serve.client.ANNSClient` / ``as_request``).

        Raises :class:`BackpressureError` when the queue holds
        ``max_queue`` LIVE requests — cancelled requests are compacted out
        before the admission decision, so a cancel burst frees its slots
        for fresh submissions."""
        if not isinstance(request, SearchRequest):
            raise TypeError(
                "submit() takes a SearchRequest; wrap raw query vectors "
                "with as_request(...) or use ANNSClient "
                f"(got {type(request).__name__})")
        query, k, top_n = request.query, request.k, request.top_n
        deadline_s, tag = request.deadline_s, request.tag
        # materialise the query BEFORE taking the lock: np.asarray on a
        # device array is a host sync every other submitter would stall on
        q_arr = np.asarray(query, np.float32)
        with self._cv:
            if len(self._queue) >= self.max_queue:
                self._compact_locked()
            if len(self._queue) >= self.max_queue:
                self.stats["rejected"] += 1
                raise BackpressureError(
                    f"queue full ({self.max_queue} pending); retry later")
            rid = self._next_rid
            self._next_rid += 1
            now = time.perf_counter()
            # key off _running (not .threaded): both are read under _cv, and
            # the pump thread's exit check holds the same lock — so either
            # the pump thread still sees this request (blocking future), or
            # we already observe the shutdown and fall back to the caller-
            # driven future, which pump(force=True) from result() can serve
            threaded = self._running
            fut = QueryFuture(tag=rid if tag is None else tag,
                              driver=None if threaded else self._drive,
                              blocking=threaded)  # fut.tag == rid (no tag)
            self._queue.append(Request(
                rid, q_arr, now, k=k, top_n=top_n,
                deadline=None if deadline_s is None else now + deadline_s,
                future=fut, tag=tag, tenant=request.tenant,
                filter=request.filter, adaptive=request.adaptive))
            self._cv.notify_all()
        return fut

    def _compact_locked(self) -> None:            # holds: _lock
        """Eager-drop cancelled requests (must hold ``_lock``)."""
        live = deque()
        for r in self._queue:
            if r.future is not None and r.future.cancelled():
                self.stats["cancelled"] += 1
            else:
                live.append(r)
        self._queue = live

    def _drive(self) -> bool:
        """Future-side driver (synchronous harness): a pending future
        forces a pump."""
        with self._lock:
            empty = not self._queue
        if empty:
            return False
        self.pump(force=True)
        return True

    # -------------------------------------------------------------- threads
    def _pump_loop(self) -> None:
        """Dedicated pump thread: sleep until a batch window matures (or
        shutdown), serve it, repeat.  On shutdown it drains the queue so
        no future is left pending.

        A failing batch does not kill the replica: its futures were
        already resolved with the error (``_serve_batch``), the failure is
        counted, and the loop keeps serving.  Only non-``Exception``
        escapes (interpreter teardown) stop the thread — after resolving
        every queued future so no waiter hangs."""
        try:
            while True:
                with self._cv:
                    while self._running and \
                            not self._window_ready(time.perf_counter()):
                        if self._queue:
                            age = time.perf_counter() \
                                - self._queue[0].t_enqueue
                            self._cv.wait(max(self.max_wait_s - age, 1e-4))
                        else:
                            self._cv.wait()
                    if not self._running and not self._queue:
                        return
                    force = not self._running   # read under _cv, used after
                try:
                    self.pump(force=force)
                except Exception:             # noqa: BLE001 — poison batch
                    with self._lock:
                        self.stats["pump_errors"] = \
                            self.stats.get("pump_errors", 0) + 1
        except BaseException as exc:          # fail loudly, not silently
            self._fail_pending(exc)
            raise

    def _ticker_loop(self) -> None:
        """Background ticker: opportunistic out-of-order retirement.  Polls
        the in-flight ticket so windows whose device scan landed retire
        while the pump thread is still re-ranking an older window.  Parks
        on a condition variable while no ticket is active (no busy-wake on
        an idle replica); any poll error is counted and survived — losing
        the ticker must never silently degrade the replica."""
        while not self._ticker_stop:
            ticket = self._active_ticket
            if ticket is None:
                with self._ticker_cv:
                    if self._active_ticket is None and not self._ticker_stop:
                        self._ticker_cv.wait(0.05)
                continue
            try:
                ticket.poll()
            except Exception:                 # noqa: BLE001 — stay alive;
                with self._lock:              # errors live on the futures
                    self.stats["ticker_errors"] = \
                        self.stats.get("ticker_errors", 0) + 1
            time.sleep(self.tick_interval_s)

    def _fail_pending(self, exc: BaseException) -> None:
        """Resolve every queued future with ``exc`` (pump thread died)."""
        with self._cv:
            while self._queue:
                r = self._queue.popleft()
                if r.future is not None:
                    r.future._set_exception(
                        FutureError(f"serving pump failed: {exc!r}"))

    # ----------------------------------------------------------------- pump
    def _window_ready(self, now: float) -> bool:  # holds: _lock
        if not self._queue:
            return False
        if len(self._queue) >= self.max_batch:
            return True
        return (now - self._queue[0].t_enqueue) >= self.max_wait_s

    def pump(self, force: bool = False) -> List[SearchResponse]:
        """Serve at most one batch window; returns its responses.

        Cancelled requests are dropped at batch formation; requests whose
        deadline already passed resolve to :class:`DeadlineExceeded`
        without consuming a batch slot.  In the threaded runtime this runs
        on the pump thread; batch formation and stats are lock-guarded,
        the executor work runs outside the lock so submissions never block
        behind a scan."""
        now = time.perf_counter()
        batch: List[Request] = []
        with self._lock:
            if not (force and self._queue) and not self._window_ready(now):
                return []
            self._serving += 1
            while self._queue and len(batch) < self.max_batch:
                r = self._queue.popleft()
                if r.future is not None and r.future.cancelled():
                    self.stats["cancelled"] += 1
                    continue
                if r.deadline is not None and now > r.deadline:
                    self.stats["expired"] += 1
                    if r.future is not None:
                        r.future._set_exception(DeadlineExceeded(
                            f"request {r.rid} expired in queue"))
                    continue
                batch.append(r)
            self._in_flight += len(batch)
        try:
            return self._serve_batch(batch)
        finally:
            with self._lock:
                self._serving -= 1
                self._in_flight -= len(batch)

    def _serve_batch(self, batch: List[Request]) -> List[SearchResponse]:
        if not batch:
            return []
        try:
            return self._serve_batch_inner(batch)
        except BaseException as exc:
            # the batch is already out of the queue, so _fail_pending can't
            # reach it: resolve its futures here or their waiters hang
            for r in batch:
                if r.future is not None:
                    r.future._set_exception(
                        FutureError(f"serving pump failed: {exc!r}"))
            raise

    def _serve_batch_inner(self, batch: List[Request]
                           ) -> List[SearchResponse]:
        queries = np.stack([r.query for r in batch])
        plan = self.index.plan(window=self.scan_window,
                               overlap_rerank=self.overlap_rerank,
                               inflight_depth=self.inflight_depth,
                               fused=self.fused, lut_int8=self.lut_int8)
        t0 = time.perf_counter()
        # per-request knobs reach the executor as PlanOverrides — one shared
        # scan window honors a mixed-k batch (deadline re-based to submit).
        # An adaptive request with a still-live deadline lets the perf-model
        # resolver shrink its top_m/top_n to the cheapest accuracy level
        # predicted to fit (explicit caller knobs win over the suggestion).
        overrides = []
        for r in batch:
            top_m, top_n = None, r.top_n
            dl = None if r.deadline is None else r.deadline - t0
            if r.adaptive and dl is not None and dl > 0:
                sug = self.executor.planner.suggest(dl)
                if sug is not None:
                    top_m = sug["top_m"]
                    if top_n is None:
                        top_n = sug["top_n"]
            overrides.append(PlanOverrides(k=r.k, top_m=top_m, top_n=top_n,
                                           deadline_s=dl, filter=r.filter))
        ticket = self.executor.submit(queries, plan, overrides=overrides)
        # propagate cancellations that raced the batch formation
        for r, f in zip(batch, ticket.futures):
            if r.future is not None and r.future.cancelled():
                f.cancel()
        self._active_ticket = ticket          # ticker may now poll it
        with self._ticker_cv:
            self._ticker_cv.notify_all()
        try:
            ticket.wait()                     # exceptions stay on the futures
        finally:
            self._active_ticket = None
            events = list(ticket.events)      # stable: wait() barriered
            with self._lock:
                self.ticket_events.append(events)
        t_serve = time.perf_counter() - t0
        # per-request attribution: shared wall-clock + the executor's
        # per-query stage timings (res.stats.t_graph/t_scan/t_rerank)
        responses: List[SearchResponse] = []
        t_done = time.perf_counter()
        with self._lock:
            self.stats["batches"] += 1
            self.stats["requests"] += len(batch)
            self.stats["mean_batch"] = (self.stats["requests"]
                                        / self.stats["batches"])
            for r, f in zip(batch, ticket.futures):
                if f.cancelled():
                    self.stats["cancelled"] += 1
                    continue
                exc = f.exception()
                if exc is not None:
                    self.stats["expired"] += isinstance(exc, DeadlineExceeded)
                    if r.future is not None:
                        r.future._set_exception(exc)
                    continue
                res = f.result()
                resp = response_from_result(
                    res, latency_s=t_done - r.t_enqueue, rid=r.rid,
                    tag=r.tag, tenant=r.tenant, t_queue_s=t0 - r.t_enqueue,
                    t_serve_s=t_serve, batch_size=len(batch))
                for field in QUERY_STATS_FIELDS:
                    self.query_stats[field] += getattr(res.stats, field)
                self.query_stats["served"] += 1
                if r.future is not None:
                    r.future._set_result(resp)
                self.latencies_s.append(t_done - r.t_enqueue)
                self._undrained.append(resp)
                responses.append(resp)
        # feed the deadline-adaptive resolver OUTSIDE the service lock:
        # its lock is executor-ranked (below service, but observe() also
        # runs a perf-model update that must not serialize submissions).
        # The planner is lazy — it only exists once an adaptive request
        # has asked for a suggestion, so non-adaptive serving pays nothing.
        pl = getattr(self.executor, "_planner", None)
        if pl is not None:
            for resp in responses:
                pl.observe(resp.stats)
        return responses

    def drain(self) -> List[SearchResponse]:
        """Serve everything currently queued or in flight, then return the
        responses served since the last drain — the SAME objects the
        per-request futures resolve to (the unified Backend drain
        contract; pre-PR-5 the threaded harness returned an empty list).
        Synchronous harness: pumps inline; threaded harness: blocks until
        the pump thread goes idle."""
        if self.threaded:
            while True:
                with self._lock:
                    idle = not self._queue and self._serving == 0
                if idle:
                    return self._pop_undrained()
                time.sleep(1e-3)
        while True:
            with self._lock:
                empty = not self._queue
            if empty:
                break
            self.pump(force=True)
        return self._pop_undrained()

    def _pop_undrained(self) -> List[SearchResponse]:
        with self._lock:
            out = list(self._undrained)
            self._undrained.clear()
        return out

    # ---------------------------------------------------------------- stats
    @property
    def epoch(self) -> int:
        """The index's segment-list epoch (DESIGN.md §10) — exposed so
        coalescing layers key result identity on index state."""
        return self.index.epoch

    def live_load(self) -> int:
        """Admission-state load: LIVE (uncancelled) queued requests plus
        requests inside a forming or in-flight batch.  This is what the
        router's join-shortest-queue policy reads — cancelled-but-not-yet-
        compacted requests don't count, so a cancel burst doesn't repel
        traffic from an actually idle replica."""
        with self._lock:
            queued = sum(1 for r in self._queue
                         if r.future is None or not r.future.cancelled())
            return queued + self._in_flight

    def latency_percentiles(self) -> Dict[str, float]:
        """p50/p99 of per-request enqueue->resolve latency (seconds)."""
        with self._lock:
            snap = list(self.latencies_s)
        lat = np.asarray(snap)       # materialise OUTSIDE the lock (PU01)
        if not len(lat):
            return {"p50": 0.0, "p99": 0.0, "n": 0}
        return {"p50": float(np.percentile(lat, 50)),
                "p99": float(np.percentile(lat, 99)),
                "n": len(lat)}

    def stats_rollup(self) -> Dict[str, object]:
        """Single-replica rollup in the router's shape (the Backend
        protocol's uniform reporting surface): service counters plus the
        summed ``QueryStats`` of every served response."""
        with self._lock:
            out: Dict[str, object] = dict(self.stats)
            out["served"] = self.query_stats["served"]
            out["query_stats"] = {f: self.query_stats[f]
                                  for f in QUERY_STATS_FIELDS}
        return out
