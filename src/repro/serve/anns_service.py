"""ANNS serving front-end: futures-first request queue + dynamic batching.

The paper's prototype binds one CPU thread per query (§5); the TPU
adaptation's natural unit is a *batch* per scan.  This front-end bridges
the two: requests accumulate until ``max_batch`` or ``max_wait_s`` elapses,
then one pass through the unified ``core.executor`` pipeline serves the
whole window — inter-query candidate dedup (§4.3 applied to the HBM scan),
the mesh-sharded ADC scan, and per-request latency attribution all come
from the executor, not from per-path code.

PR-2 redesign (DESIGN.md §3): ``submit()`` returns a
:class:`~repro.core.futures.QueryFuture` resolving to a :class:`Response`
(``fut.result().result`` is the :class:`QueryResult`), with

* **admission control** — a bounded queue (``max_queue``); submissions past
  the bound raise :class:`BackpressureError` instead of growing latency;
* **per-request plans** — ``k``/``top_n`` ride to the executor as
  ``PlanOverrides``, so a mixed-``k`` batch is honored inside ONE shared
  scan window (the PR-1 service dropped ``Request.k`` on the floor);
* **deadlines + cancellation** — ``deadline_s`` expires requests at batch
  formation or before their re-rank; ``fut.cancel()`` drops a queued
  request or skips its re-rank mid-flight;
* **pipelining** — ``scan_window``/``inflight_depth`` expose the
  executor's ``_InflightQueue``: a pump batch splits into scan windows and
  the rerank of window t overlaps the in-flight scans of t+1..t+d.

Synchronous harness (no asyncio dependency): ``pump()`` drains one batch
window; a pending future drives ``pump(force=True)`` from ``result()``.
On a real deployment the pump loop runs in a dedicated thread per replica.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Deque, Dict, List, Optional

import numpy as np

from repro.core.engine import FusionANNSIndex, QueryResult
from repro.core.executor import PlanOverrides
from repro.core.futures import (BackpressureError, DeadlineExceeded,
                                QueryFuture)

__all__ = ["BatchingANNSService", "Request", "Response",
           "BackpressureError", "DeadlineExceeded", "QueryFuture"]


@dataclasses.dataclass
class Request:
    rid: int
    query: np.ndarray
    t_enqueue: float
    k: Optional[int] = None
    top_n: Optional[int] = None
    deadline: Optional[float] = None      # absolute perf_counter time
    future: Optional[QueryFuture] = None


@dataclasses.dataclass
class Response:
    rid: int
    result: QueryResult
    t_queue_s: float          # time spent waiting for the batch window
    t_serve_s: float          # batch execution time (shared)
    batch_size: int


class BatchingANNSService:
    def __init__(self, index: FusionANNSIndex, *, max_batch: int = 32,
                 max_wait_s: float = 0.002, scan_window: int = 0,
                 overlap_rerank: bool = False, inflight_depth: int = 0,
                 max_queue: int = 1024):
        self.index = index
        self.executor = index.executor
        self.max_batch = max_batch
        self.max_wait_s = max_wait_s
        self.scan_window = scan_window
        self.overlap_rerank = overlap_rerank
        self.inflight_depth = inflight_depth
        self.max_queue = max_queue
        self._queue: Deque[Request] = deque()
        self._next_rid = 0
        self.stats: Dict[str, float] = {
            "batches": 0, "requests": 0, "mean_batch": 0.0,
            "rejected": 0, "expired": 0, "cancelled": 0}
        # enqueue -> resolve per request; bounded so a long-lived replica's
        # percentile window stays O(1) memory (sliding, newest-wins)
        self.latencies_s: Deque[float] = deque(maxlen=8192)

    # --------------------------------------------------------------- submit
    def submit(self, query: np.ndarray, k: Optional[int] = None, *,
               top_n: Optional[int] = None,
               deadline_s: Optional[float] = None) -> QueryFuture:
        """Enqueue one request; returns its future immediately.

        Raises :class:`BackpressureError` when the queue is at
        ``max_queue`` — admission control instead of unbounded latency."""
        if len(self._queue) >= self.max_queue:
            self.stats["rejected"] += 1
            raise BackpressureError(
                f"queue full ({self.max_queue} pending); retry later")
        rid = self._next_rid
        self._next_rid += 1
        now = time.perf_counter()
        fut = QueryFuture(tag=rid, driver=self._drive)  # fut.tag == rid
        self._queue.append(Request(
            rid, np.asarray(query, np.float32), now, k=k, top_n=top_n,
            deadline=None if deadline_s is None else now + deadline_s,
            future=fut))
        return fut

    def _drive(self) -> bool:
        """Future-side driver: a pending future forces a pump."""
        if not self._queue:
            return False
        self.pump(force=True)
        return True

    # ----------------------------------------------------------------- pump
    def _window_ready(self, now: float) -> bool:
        if not self._queue:
            return False
        if len(self._queue) >= self.max_batch:
            return True
        return (now - self._queue[0].t_enqueue) >= self.max_wait_s

    def pump(self, force: bool = False) -> List[Response]:
        """Serve at most one batch window; returns its responses.

        Cancelled requests are dropped at batch formation; requests whose
        deadline already passed resolve to :class:`DeadlineExceeded`
        without consuming a batch slot."""
        now = time.perf_counter()
        if not (force and self._queue) and not self._window_ready(now):
            return []
        batch: List[Request] = []
        while self._queue and len(batch) < self.max_batch:
            r = self._queue.popleft()
            if r.future is not None and r.future.cancelled():
                self.stats["cancelled"] += 1
                continue
            if r.deadline is not None and now > r.deadline:
                self.stats["expired"] += 1
                if r.future is not None:
                    r.future._set_exception(DeadlineExceeded(
                        f"request {r.rid} expired in queue"))
                continue
            batch.append(r)
        if not batch:
            return []
        queries = np.stack([r.query for r in batch])
        plan = self.index.plan(window=self.scan_window,
                               overlap_rerank=self.overlap_rerank,
                               inflight_depth=self.inflight_depth)
        t0 = time.perf_counter()
        # per-request knobs reach the executor as PlanOverrides — one shared
        # scan window honors a mixed-k batch (deadline re-based to submit)
        overrides = [PlanOverrides(
            k=r.k, top_n=r.top_n,
            deadline_s=None if r.deadline is None else r.deadline - t0)
            for r in batch]
        ticket = self.executor.submit(queries, plan, overrides=overrides)
        # propagate cancellations that raced the batch formation
        for r, f in zip(batch, ticket.futures):
            if r.future is not None and r.future.cancelled():
                f.cancel()
        ticket.wait()                      # exceptions stay on the futures
        t_serve = time.perf_counter() - t0
        self.stats["batches"] += 1
        self.stats["requests"] += len(batch)
        self.stats["mean_batch"] = (self.stats["requests"]
                                    / self.stats["batches"])
        # per-request attribution: shared wall-clock + the executor's
        # per-query stage timings (res.stats.t_graph/t_scan/t_rerank)
        responses: List[Response] = []
        t_done = time.perf_counter()
        for r, f in zip(batch, ticket.futures):
            if f.cancelled():
                self.stats["cancelled"] += 1
                continue
            exc = f.exception()
            if exc is not None:
                self.stats["expired"] += isinstance(exc, DeadlineExceeded)
                if r.future is not None:
                    r.future._set_exception(exc)
                continue
            resp = Response(rid=r.rid, result=f.result(),
                            t_queue_s=t0 - r.t_enqueue, t_serve_s=t_serve,
                            batch_size=len(batch))
            if r.future is not None:
                r.future._set_result(resp)
            self.latencies_s.append(t_done - r.t_enqueue)
            responses.append(resp)
        return responses

    def drain(self) -> List[Response]:
        out: List[Response] = []
        while self._queue:
            out.extend(self.pump(force=True))
        return out

    # ---------------------------------------------------------------- stats
    def latency_percentiles(self) -> Dict[str, float]:
        """p50/p99 of per-request enqueue->resolve latency (seconds)."""
        if not self.latencies_s:
            return {"p50": 0.0, "p99": 0.0, "n": 0}
        lat = np.asarray(self.latencies_s)
        return {"p50": float(np.percentile(lat, 50)),
                "p99": float(np.percentile(lat, 99)),
                "n": len(lat)}
