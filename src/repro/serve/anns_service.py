"""ANNS serving front-end: request queue + dynamic batching.

The paper's prototype binds one CPU thread per query (§5); the TPU
adaptation's natural unit is a *batch* per scan.  This front-end bridges
the two: requests accumulate until ``max_batch`` or ``max_wait_s`` elapses,
then one pass through the unified ``core.executor`` pipeline serves the
whole window — inter-query candidate dedup (§4.3 applied to the HBM scan),
the mesh-sharded ADC scan, and per-request latency attribution all come
from the executor, not from per-path code.

``scan_window``/``overlap_rerank`` expose the executor's pipelining knob:
a pump batch larger than ``scan_window`` is split into scan windows and the
rerank I/O of window t overlaps the device scan of window t+1.

Synchronous harness (no asyncio dependency): callers enqueue requests and
``pump()`` drains windows; on a real deployment the pump loop runs in a
dedicated thread per replica."""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Deque, Dict, List, Optional

import numpy as np

from repro.core.engine import FusionANNSIndex, QueryResult


@dataclasses.dataclass
class Request:
    rid: int
    query: np.ndarray
    t_enqueue: float
    k: Optional[int] = None


@dataclasses.dataclass
class Response:
    rid: int
    result: QueryResult
    t_queue_s: float          # time spent waiting for the batch window
    t_serve_s: float          # batch execution time (shared)
    batch_size: int


class BatchingANNSService:
    def __init__(self, index: FusionANNSIndex, *, max_batch: int = 32,
                 max_wait_s: float = 0.002, scan_window: int = 0,
                 overlap_rerank: bool = False):
        self.index = index
        self.executor = index.executor
        self.max_batch = max_batch
        self.max_wait_s = max_wait_s
        self.scan_window = scan_window
        self.overlap_rerank = overlap_rerank
        self._queue: Deque[Request] = deque()
        self._next_rid = 0
        self.stats: Dict[str, float] = {
            "batches": 0, "requests": 0, "mean_batch": 0.0}

    def submit(self, query: np.ndarray, k: Optional[int] = None) -> int:
        rid = self._next_rid
        self._next_rid += 1
        self._queue.append(Request(rid, np.asarray(query, np.float32),
                                   time.perf_counter(), k))
        return rid

    def _window_ready(self, now: float) -> bool:
        if not self._queue:
            return False
        if len(self._queue) >= self.max_batch:
            return True
        return (now - self._queue[0].t_enqueue) >= self.max_wait_s

    def pump(self, force: bool = False) -> List[Response]:
        """Serve at most one batch window; returns its responses."""
        now = time.perf_counter()
        if not (force and self._queue) and not self._window_ready(now):
            return []
        batch = [self._queue.popleft()
                 for _ in range(min(self.max_batch, len(self._queue)))]
        queries = np.stack([r.query for r in batch])
        plan = self.index.plan(window=self.scan_window,
                               overlap_rerank=self.overlap_rerank)
        t0 = time.perf_counter()
        results = self.executor.run(queries, plan)
        t_serve = time.perf_counter() - t0
        self.stats["batches"] += 1
        self.stats["requests"] += len(batch)
        self.stats["mean_batch"] = (self.stats["requests"]
                                    / self.stats["batches"])
        # per-request attribution: shared wall-clock + the executor's
        # per-query stage timings (res.stats.t_graph/t_scan/t_rerank)
        return [Response(rid=r.rid, result=res,
                         t_queue_s=t0 - r.t_enqueue, t_serve_s=t_serve,
                         batch_size=len(batch))
                for r, res in zip(batch, results)]

    def drain(self) -> List[Response]:
        out: List[Response] = []
        while self._queue:
            out.extend(self.pump(force=True))
        return out
