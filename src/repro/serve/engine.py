"""LM serving engine: prefill + greedy/temperature decode with the KV cache,
plus the RAG front-end that wires FusionANNS retrieval into generation
(paper Fig. 1)."""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import LMConfig
from repro.models import transformer as tfm
from repro.models.layers import LOCAL_CTX, ShardCtx


@dataclasses.dataclass
class ServeConfig:
    max_len: int = 512
    temperature: float = 0.0          # 0 = greedy
    cache_dtype: Any = jnp.float32


class LMServer:
    """Static-batched decode server (one shared position counter, the
    production pattern exercised by the decode_32k / long_500k cells)."""

    def __init__(self, params, cfg: LMConfig, scfg: ServeConfig = ServeConfig(),
                 ctx: ShardCtx = LOCAL_CTX):
        self.params = params
        self.cfg = cfg
        self.scfg = scfg
        self.ctx = ctx
        self._decode = jax.jit(self._decode_impl, donate_argnums=(1,),
                               static_argnums=())

    def _decode_impl(self, params, cache, tokens, pos, key):
        logits, cache = tfm.lm_decode_step(params, cache, tokens, pos,
                                           self.cfg, self.ctx,
                                           dtype=jnp.float32)
        if self.scfg.temperature > 0:
            nxt = jax.random.categorical(
                key, logits[:, -1] / self.scfg.temperature)
        else:
            nxt = jnp.argmax(logits[:, -1], axis=-1)
        return nxt[:, None].astype(jnp.int32), cache

    def generate(self, prompts: np.ndarray, n_tokens: int,
                 seed: int = 0) -> Dict[str, Any]:
        """prompts (B, P) int32 -> generated (B, n_tokens)."""
        B, P = prompts.shape
        cache = tfm.init_kv_cache(self.cfg, B, self.scfg.max_len,
                                  dtype=self.scfg.cache_dtype)
        key = jax.random.key(seed)
        # prefill token-by-token through the decode path (correct though
        # not the fast path; the prefill cell lowers the batched version)
        toks = jnp.asarray(prompts[:, :1], jnp.int32)
        t0 = time.perf_counter()
        for p in range(P):
            toks = jnp.asarray(prompts[:, p:p + 1], jnp.int32)
            key, sub = jax.random.split(key)
            nxt, cache = self._decode(self.params, cache, toks, p, sub)
        out = [nxt]
        for i in range(n_tokens - 1):
            key, sub = jax.random.split(key)
            nxt, cache = self._decode(self.params, cache, out[-1], P + i, sub)
            out.append(nxt)
        dt = time.perf_counter() - t0
        gen = np.concatenate([np.asarray(t) for t in out], axis=1)
        return {"tokens": gen,
                "tokens_per_s": B * (P + n_tokens) / dt,
                "wall_s": dt}


class RAGPipeline:
    """Retrieval-augmented generation: FusionANNS retrieves the top-k
    context vectors for the query embedding; their ids become context
    tokens prepended to the prompt (paper Fig. 1 flow).

    Uses the futures-first retrieval API (DESIGN.md §3): ``answer`` submits
    the retrieval (host traversal + async device scan) and only blocks on
    the future when the context tokens are needed; ``answer_batch``
    pipelines a whole request window through one submission, resolving
    each retrieval future right before its generation step.

    ``router=`` swaps the retrieval tier for a
    :class:`~repro.serve.router.ReplicaRouter` (DESIGN.md §5): each
    retrieval is routed via a typed
    :class:`~repro.serve.client.SearchRequest` to one of N serving
    replicas, and the per-request future resolves to a
    :class:`~repro.serve.client.SearchResponse` — same ``ids``/``stats``
    surface as an executor :class:`~repro.core.engine.QueryResult`, so no
    adapter shim is needed (PR 5 deleted the routed-future wrapper); the
    replicas' pump threads make progress instead of ``ticket.poll()``."""

    def __init__(self, anns_index, lm_server: LMServer,
                 embed_fn: Optional[Callable] = None, router=None):
        self.index = anns_index
        self.server = lm_server
        self.embed = embed_fn or (lambda toks: None)
        self.router = router

    def _retrieve(self, query_vecs: np.ndarray, k: int,
                  inflight_depth: int = 2):
        """Submit every query; returns ``(futures, poll)`` where each
        future resolves to something with the ``ids``/``dists``/``stats``
        surface — a :class:`~repro.core.engine.QueryResult` from the
        executor ticket, or a :class:`~repro.serve.client.SearchResponse`
        from a router — and ``poll()`` opportunistically retires landed
        scan windows."""
        q = np.atleast_2d(np.asarray(query_vecs, np.float32))
        if self.router is not None:
            from repro.serve.client import SearchRequest
            return ([self.router.submit(SearchRequest(query=v, k=k))
                     for v in q], lambda: None)
        ticket = self.index.submit(q, k=k, window=1,
                                   inflight_depth=inflight_depth)
        return list(ticket.futures), ticket.poll

    def _ctx_tokens(self, res) -> np.ndarray:
        vocab = self.server.cfg.vocab_size
        return (res.ids.astype(np.int64) % vocab).astype(np.int32)

    def answer(self, query_vec: np.ndarray, prompt: np.ndarray,
               n_tokens: int = 16, k: int = 4) -> Dict[str, Any]:
        futs, _ = self._retrieve(np.asarray(query_vec, np.float32)[None], k)
        res = futs[0].result()             # scan was in flight since submit
        full = np.concatenate([self._ctx_tokens(res)[None, :], prompt],
                              axis=1)
        out = self.server.generate(full, n_tokens)
        out["retrieved_ids"] = res.ids
        out["retrieval_stats"] = res.stats
        return out

    def answer_batch(self, query_vecs: np.ndarray, prompts: np.ndarray,
                     n_tokens: int = 16, k: int = 4,
                     inflight_depth: int = 2) -> List[Dict[str, Any]]:
        """One retrieval submission for B requests: per-request scan
        windows pipeline on the device (depth ``inflight_depth``) while the
        host runs generation for already-resolved requests.  After each
        generation step the ticket is polled, so retrieval windows whose
        scan landed during generation retire opportunistically (possibly
        out of order — the PR-3 retirement path) and the next ``result()``
        returns without blocking."""
        futs, poll = self._retrieve(np.asarray(query_vecs, np.float32), k,
                                    inflight_depth=inflight_depth)
        outs: List[Dict[str, Any]] = []
        for fut, prompt in zip(futs, prompts):
            res = fut.result()
            full = np.concatenate([self._ctx_tokens(res)[None, :],
                                   prompt[None] if prompt.ndim == 1
                                   else prompt], axis=1)
            out = self.server.generate(full, n_tokens)
            out["retrieved_ids"] = res.ids
            out["retrieval_stats"] = res.stats
            outs.append(out)
            # generation kept the host busy: retire any landed scans now
            # (no-op under a router — replica pump threads own progress)
            poll()
        return outs
