"""Elastic replica autoscaling: load-driven mesh re-carving.

The :class:`~repro.serve.router.ReplicaRouter` turned multi-replica
serving into a routing problem; this module turns replica COUNT into a
control problem.  :class:`ReplicaAutoscaler` samples the router's
``scaling_signals()`` — live load, backpressure spills, queue-latency
percentiles — and grows/shrinks the replica set within
``[min_replicas, max_replicas]`` by calling the router's
``add_replica()`` / ``remove_replica(drain=True)`` actuators, each of
which re-carves the parent mesh over the new set
(``launch.mesh.recarve_mesh``) and re-attaches every survivor's executor.

Scaling decisions are HYSTERETIC — a serving tier that flaps burns its
win on HBM re-placement churn:

* **scale up** when the per-replica live load exceeds ``high_water``, or
  the spill/reject counters moved since the last tick (the current set
  demonstrably could not place demand), or queue p99 exceeds
  ``p99_bound_s`` — but never within ``scale_up_cooldown_s`` of the last
  resize, and never above the analytic model's
  :func:`~repro.core.perf_model.max_useful_replicas` bound once measured
  demand exists (past that point a shared resource binds and more
  replicas serve nothing extra).
* **scale down** only when per-replica load sat below ``low_water`` for
  ``down_ticks`` CONSECUTIVE samples with no spills in between, outside
  ``scale_down_cooldown_s`` of any resize.  The victim is the
  least-loaded replica; its removal drains (zero leaked futures) before
  the devices are re-carved over the survivors.

The control loop is a plain ``tick()`` method so tests drive it
deterministically with a fake clock; ``start()`` wraps it in a daemon
thread for live serving (examples/serve_anns.py --edge).
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, Dict, List, Optional

from repro.analysis.concurrency.witness import make_lock
from repro.serve.router import ReplicaRouter

__all__ = ["AutoscalerConfig", "ReplicaAutoscaler"]


@dataclasses.dataclass
class AutoscalerConfig:
    min_replicas: int = 1
    max_replicas: int = 4
    interval_s: float = 0.05           # background-loop sampling period
    high_water: float = 8.0            # live requests PER replica -> grow
    low_water: float = 1.0             # live requests per replica -> shrink
    p99_bound_s: Optional[float] = None   # queue p99 above this -> grow
    scale_up_cooldown_s: float = 0.1
    scale_down_cooldown_s: float = 0.5
    down_ticks: int = 3                # consecutive calm samples to shrink
    threads_per_replica: int = 8       # model-bound input
    model_min_gain: float = 1.02       # qps gain ratio that still "counts"

    def __post_init__(self) -> None:
        if self.min_replicas < 1:
            raise ValueError("min_replicas must be >= 1")
        if self.max_replicas < self.min_replicas:
            raise ValueError("max_replicas must be >= min_replicas")
        if self.low_water >= self.high_water:
            raise ValueError("low_water must be < high_water")


class ReplicaAutoscaler:
    """Drives a :class:`ReplicaRouter`'s replica count from its own load
    signals.  ``tick()`` is the whole control law (pure given the clock);
    ``start()``/``stop()`` run it on a daemon thread.

    When the router carries a ``snapshot_dir`` (DESIGN.md §10), every
    scale-up this controller triggers hydrates the new replica from a
    fresh ``save_snapshot`` of the live index — checkpoint/restore
    instead of a from-scratch rebuild, so elastic capacity arrives at
    the donor's exact epoch with bit-identical ids."""

    def __init__(self, router: ReplicaRouter,
                 config: Optional[AutoscalerConfig] = None,
                 clock: Callable[[], float] = time.monotonic, **kw):
        self.router = router
        self.cfg = config or AutoscalerConfig(**kw)
        self.clock = clock
        self._lock = make_lock("autoscaler")
        self._thread: Optional[threading.Thread] = None
        # _last_resize_t/_calm_ticks/_seen are control-thread-confined
        # (only tick() touches them, and ticks never overlap), so they are
        # deliberately unguarded
        self._stop_evt = threading.Event()
        self._last_resize_t: Optional[float] = None
        self._last_resize_was_up = False
        self._calm_ticks = 0
        # spill/reject deltas are what signal "couldn't place demand";
        # absolute counters only ever grow
        self._seen = {"spills": 0, "spill_exhausted": 0, "rejected": 0}
        self.events: List[Dict[str, object]] = []  # guarded-by: _lock
        self.stats: Dict[str, int] = {
            "ticks": 0, "scale_ups": 0, "scale_downs": 0,
            "capped_by_model": 0, "capped_by_max": 0}  # guarded-by: _lock

    # ------------------------------------------------------------- signals
    def _model_cap(self) -> Optional[int]:
        """The analytic model's ceiling on useful replicas, from measured
        demand.  None until the router has served traffic (an idle tier
        has no demand profile to model)."""
        roll = self.router.stats_rollup()
        if roll["served"] <= 0:
            return None
        from repro.core.perf_model import DeviceModel, max_useful_replicas
        return max_useful_replicas(
            self.router.measured_demand(), DeviceModel(),
            threads_per_replica=self.cfg.threads_per_replica,
            min_gain=self.cfg.model_min_gain,
            cap=self.cfg.max_replicas)

    def _in_cooldown(self, now: float, window_s: float) -> bool:
        return (self._last_resize_t is not None
                and now - self._last_resize_t < window_s)

    # ---------------------------------------------------------- control law
    def tick(self) -> Optional[str]:
        """One control-loop step: sample, decide, actuate.  Returns the
        action taken (``"scale_up"``/``"scale_down"``) or None."""
        cfg = self.cfg
        now = self.clock()
        sig = self.router.scaling_signals()
        n = sig["n_replicas"]
        per_replica = sig["live_load"] / max(n, 1)
        new_spills = (sig["spills"] - self._seen["spills"]
                      + sig["spill_exhausted"]
                      - self._seen["spill_exhausted"]
                      + sig["rejected"] - self._seen["rejected"])
        for k in self._seen:
            self._seen[k] = int(sig[k])
        with self._lock:
            self.stats["ticks"] += 1

        overloaded = per_replica > cfg.high_water or new_spills > 0
        if (cfg.p99_bound_s is not None and sig["latency_n"] > 0
                and sig["p99"] > cfg.p99_bound_s):
            overloaded = True

        action: Optional[str] = None
        if overloaded:
            self._calm_ticks = 0
            if n < cfg.max_replicas \
                    and not self._in_cooldown(now, cfg.scale_up_cooldown_s):
                cap = self._model_cap()
                if cap is not None and n >= cap:
                    with self._lock:
                        self.stats["capped_by_model"] += 1
                else:
                    slot = self.router.add_replica()
                    self._last_resize_t, self._last_resize_was_up = now, True
                    with self._lock:
                        self.stats["scale_ups"] += 1
                    action = "scale_up"
                    self._record(now, action, sig, slot=slot)
            elif n >= cfg.max_replicas:
                with self._lock:
                    self.stats["capped_by_max"] += 1
        elif per_replica < cfg.low_water:
            self._calm_ticks += 1
            # a shrink right after a grow would flap: the down-cooldown
            # window starts at the LAST resize, whichever direction
            if (self._calm_ticks >= cfg.down_ticks
                    and n > cfg.min_replicas
                    and not self._in_cooldown(
                        now, cfg.scale_down_cooldown_s)):
                slot = self.router.remove_replica(drain=True)
                self._last_resize_t, self._last_resize_was_up = now, False
                self._calm_ticks = 0
                with self._lock:
                    self.stats["scale_downs"] += 1
                action = "scale_down"
                self._record(now, action, sig, slot=slot)
        else:
            self._calm_ticks = 0
        return action

    def _record(self, now: float, action: str, sig: Dict[str, object],
                **extra) -> None:
        # sample the router BEFORE taking our lock: n_replicas takes the
        # router's lock, and nested acquisition here buys nothing
        n = self.router.n_replicas
        with self._lock:
            self.events.append({"t": now, "action": action,
                                "n_replicas": n,
                                "live_load": sig["live_load"],
                                "p99": sig["p99"], **extra})

    # ------------------------------------------------------------ lifecycle
    def start(self) -> "ReplicaAutoscaler":
        """Run ``tick()`` every ``interval_s`` on a daemon thread
        (idempotent)."""
        with self._lock:
            if self._thread is not None:
                return self
            self._stop_evt.clear()
            self._thread = threading.Thread(
                target=self._loop, name="replica-autoscaler", daemon=True)
        self._thread.start()
        return self

    def _loop(self) -> None:
        while not self._stop_evt.wait(self.cfg.interval_s):
            try:
                self.tick()
            except Exception:        # noqa: BLE001 — a bad sample must not
                with self._lock:     # kill the control loop
                    self.stats["tick_errors"] = \
                        self.stats.get("tick_errors", 0) + 1

    def stop(self) -> "ReplicaAutoscaler":
        self._stop_evt.set()
        t = self._thread
        if t is not None:
            t.join()
            self._thread = None
        return self

    def __enter__(self) -> "ReplicaAutoscaler":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()
