"""The network front door: an asyncio HTTP/JSON edge over the serving
stack (PR 7's tentpole; DESIGN.md §8).

Everything below PR 5's :class:`~repro.serve.client.AsyncANNSClient` is an
in-process API; real deployments take queries off a SOCKET.  This module
is that last hop, stdlib-only (``asyncio.start_server`` + minimal
HTTP/1.1 parsing — no web framework in the image, none needed):

* **routes** — ``POST /v1/search`` (JSON body: ``query`` plus optional
  ``k``/``top_n``/``deadline_s``/``tag``), ``GET /v1/stats`` (edge +
  client + backend counters), ``GET /healthz`` (serving/draining).
  Keep-alive HTTP/1.1: one connection serves many requests.
* **tenant auth** — when :class:`EdgeConfig.tenants` is non-empty every
  search must carry a known ``x-api-key`` header; the matching tenant's
  name is stamped on the :class:`~repro.serve.client.SearchRequest`
  (``tenant=``) and rides to the response.  The backend is wrapped in a
  :class:`~repro.serve.tenants.TenantManager` (PR 10): it stamps each
  tenant's base predicate server-side (a client can narrow but never
  widen its namespace), keeps per-tenant books, and enforces the
  per-tenant :class:`TokenBucket` quota at submit —
  :class:`~repro.serve.tenants.QuotaExceeded` maps to ``429`` with
  ``Retry-After``.  No tenants configured = an open edge.
* **filtered + adaptive search** — the search body optionally carries a
  ``filter`` predicate (DESIGN.md §11 wire grammar:
  ``{"eq": [col, v]}`` / ``{"in": …}`` / ``{"range": …}`` /
  ``{"and": […]}``) applied at candidate collection, and
  ``"adaptive": true`` opts into deadline-adaptive accuracy.
* **coalescing** — identical in-flight queries (same query bytes + plan
  knobs, :func:`~repro.serve.client.coalesce_key`) share ONE backend
  submit via the client's :class:`~repro.serve.client.RequestCoalescer`;
  a duplicate burst of N HTTP requests costs one scan.
* **structured errors** — every failure is
  ``{"error": {"code", "message"}}``: ``401 unauthorized``,
  ``429 rate_limited``, ``400 bad_request``, ``404 not_found``,
  ``413 body_too_large``, ``503 overloaded`` (edge admission guard) /
  ``503 draining``, ``504 deadline_exceeded``, ``500 internal``.
* **graceful drain** — ``aclose()`` stops accepting, lets every in-flight
  request finish (responses still flow on their keep-alive conns), closes
  idle connections, settles the client, then — only when the edge OWNS
  the backend (``own_backend=True``) — stops the router off-loop.  Zero
  futures leak at either level (tests/test_edge.py).

:class:`HttpConn` is the matching minimal keep-alive client used by the
tests, the benchmark harness (``benchmarks.common.edge_http_latency``)
and the example; production callers can use anything that speaks HTTP.
"""

from __future__ import annotations

import asyncio
import dataclasses
import json
import time
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import numpy as np

from repro.core.filters import predicate_from_json
from repro.core.futures import DeadlineExceeded
from repro.serve.client import (AsyncANNSClient, RequestCoalescer,
                                SearchRequest)
# TenantConfig/TokenBucket moved to serve/tenants.py in PR 10 (quotas are
# now router-level admission, not an edge-local check); re-exported here
# for existing importers
from repro.serve.tenants import (QuotaExceeded, TenantConfig, TenantManager,
                                 TokenBucket)

__all__ = ["TenantConfig", "EdgeConfig", "TokenBucket", "AnnsEdge",
           "HttpConn"]

_MAX_HEADER_BYTES = 16 * 1024
_MAX_HEADERS = 64


@dataclasses.dataclass
class EdgeConfig:
    host: str = "127.0.0.1"
    port: int = 0                         # 0 = ephemeral (tests)
    tenants: Sequence[TenantConfig] = ()
    max_inflight: int = 256               # client-side admission semaphore
    max_pending: int = 1024               # edge guard: live HTTP requests
    default_deadline_s: Optional[float] = None
    coalesce: bool = True
    max_body_bytes: int = 1 << 20


class _HttpError(Exception):
    def __init__(self, status: int, code: str, message: str,
                 headers: Optional[Dict[str, str]] = None):
        super().__init__(message)
        self.status, self.code, self.message = status, code, message
        self.headers = headers or {}


_STATUS_TEXT = {200: "OK", 400: "Bad Request", 401: "Unauthorized",
                404: "Not Found", 405: "Method Not Allowed",
                413: "Payload Too Large", 429: "Too Many Requests",
                500: "Internal Server Error", 503: "Service Unavailable",
                504: "Gateway Timeout"}


class AnnsEdge:
    """The HTTP front door over any Backend (normally a
    :class:`~repro.serve.router.ReplicaRouter`).

    ``own_backend=True`` makes ``aclose()`` also stop the backend (the
    example's standalone-server shape); a shared backend is left running.
    ``clock`` feeds the tenant rate limiters (injectable for tests)."""

    def __init__(self, backend, config: Optional[EdgeConfig] = None, *,
                 own_backend: bool = False,
                 clock: Callable[[], float] = time.monotonic, **overrides):
        self.backend = backend
        self.cfg = config or EdgeConfig(**overrides)
        self.own_backend = own_backend
        coalescer = None
        if self.cfg.coalesce:
            # the stack's accuracy knobs are part of result identity, so
            # they fold into every coalescing key — and so is the index's
            # segment-list epoch (DESIGN.md §10): backends expose
            # ``.epoch`` and a mutation bumps it, keeping waiters from
            # attaching to a leader dispatched against pre-mutation state
            epoch_source = ((lambda: backend.epoch)
                            if hasattr(backend, "epoch") else None)
            coalescer = RequestCoalescer(
                fused=bool(getattr(backend, "fused", False)),
                lut_int8=bool(getattr(backend, "lut_int8", False)),
                epoch_source=epoch_source)
        # tenants configured -> wrap the backend in a TenantManager: the
        # quota gate, base-predicate stamping (the request can only ever
        # narrow its tenant's namespace — isolation is server-side, a
        # client-supplied filter cannot widen it), and per-tenant query
        # books all live at the submit layer, not in the HTTP handler
        self.manager: Optional[TenantManager] = None
        if self.cfg.tenants:
            self.manager = TenantManager(backend, self.cfg.tenants,
                                         clock=clock)
        self.client = AsyncANNSClient(self.manager or backend,
                                      max_inflight=self.cfg.max_inflight,
                                      coalescer=coalescer)
        self._keys = {t.api_key: t for t in self.cfg.tenants}
        self.tenant_stats: Dict[str, Dict[str, int]] = {
            t.name: {"requests": 0, "ok": 0, "rate_limited": 0,
                     "errors": 0} for t in self.cfg.tenants}
        self.stats: Dict[str, int] = {
            "conns": 0, "requests": 0, "ok": 0, "auth_failures": 0,
            "rate_limited": 0, "bad_requests": 0, "not_found": 0,
            "deadline_expired": 0, "overloaded": 0, "draining_rejects": 0,
            "internal_errors": 0}
        self._server: Optional[asyncio.AbstractServer] = None
        self._writers: set = set()         # open connections (drain close)
        self._live_requests = 0            # requests between parse+respond
        self._idle_evt = asyncio.Event()
        self._idle_evt.set()
        self._draining = False
        self.port: Optional[int] = None

    # ------------------------------------------------------------ lifecycle
    async def start(self) -> "AnnsEdge":
        self._server = await asyncio.start_server(
            self._handle_conn, self.cfg.host, self.cfg.port)
        self.port = self._server.sockets[0].getsockname()[1]
        return self

    async def aclose(self) -> None:
        """Graceful drain, strictly ordered: (1) stop accepting, (2) let
        every in-flight request finish — their responses still flow, (3)
        close the now-idle connections, (4) settle the client (zero
        pending backend futures), (5) stop an OWNED backend off-loop."""
        self._draining = True
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
            self._server = None
        await self._idle_evt.wait()            # (2) in-flight requests
        for w in list(self._writers):          # (3) idle keep-alive conns
            w.close()
        self._writers.clear()
        await self.client.aclose()             # (4)
        if self.own_backend:                   # (5) router.stop() blocks on
            loop = asyncio.get_running_loop()  # pump joins: off-loop
            await loop.run_in_executor(None, self.backend.stop)

    async def __aenter__(self) -> "AnnsEdge":
        return await self.start()

    async def __aexit__(self, *exc) -> None:
        await self.aclose()

    # ----------------------------------------------------------- connection
    async def _handle_conn(self, reader: asyncio.StreamReader,
                           writer: asyncio.StreamWriter) -> None:
        self.stats["conns"] += 1
        self._writers.add(writer)
        try:
            while not self._draining:
                try:
                    parsed = await self._read_request(reader)
                except (asyncio.IncompleteReadError, ConnectionError):
                    return                     # peer closed between requests
                except _HttpError as exc:      # unparseable request: answer
                    self.stats["bad_requests"] += 1   # and drop the conn
                    try:
                        await self._write_response(
                            writer, exc.status,
                            {"error": {"code": exc.code,
                                       "message": exc.message}},
                            exc.headers, keep=False)
                    except ConnectionError:
                        pass
                    return
                if parsed is None:
                    return                     # clean EOF
                method, path, headers, body = parsed
                # the in-flight window covers routing AND the response
                # write: aclose() must not close this socket until the
                # bytes are out
                self._live_requests += 1
                self._idle_evt.clear()
                try:
                    try:
                        status, payload, extra = await self._route(
                            method, path, headers, body)
                    except _HttpError as exc:
                        status = exc.status
                        payload = {"error": {"code": exc.code,
                                             "message": exc.message}}
                        extra = exc.headers
                    except Exception as exc:   # noqa: BLE001 — must answer
                        self.stats["internal_errors"] += 1
                        status = 500
                        payload = {"error": {"code": "internal",
                                             "message": repr(exc)}}
                        extra = {}
                    keep = (headers.get("connection", "keep-alive").lower()
                            != "close") and not self._draining
                    try:
                        await self._write_response(writer, status, payload,
                                                   extra, keep=keep)
                    except ConnectionError:
                        return
                finally:
                    self._live_requests -= 1
                    if self._live_requests == 0:
                        self._idle_evt.set()
                if not keep:
                    return
        finally:
            self._writers.discard(writer)
            writer.close()

    async def _read_request(self, reader: asyncio.StreamReader):
        """One HTTP/1.1 request off the stream; None on clean EOF."""
        line = await reader.readline()
        if not line:
            return None
        try:
            method, path, _version = line.decode("latin1").split(None, 2)
        except ValueError:
            raise _HttpError(400, "bad_request",
                             "malformed request line") from None
        headers: Dict[str, str] = {}
        total = len(line)
        for _ in range(_MAX_HEADERS):
            h = await reader.readline()
            total += len(h)
            if total > _MAX_HEADER_BYTES:
                raise _HttpError(400, "bad_request", "headers too large")
            if h in (b"\r\n", b"\n", b""):
                break
            name, _, value = h.decode("latin1").partition(":")
            headers[name.strip().lower()] = value.strip()
        else:
            raise _HttpError(400, "bad_request", "too many headers")
        body = b""
        n = int(headers.get("content-length", 0) or 0)
        if n > self.cfg.max_body_bytes:
            raise _HttpError(413, "body_too_large",
                             f"body of {n} bytes exceeds "
                             f"{self.cfg.max_body_bytes}")
        if n:
            body = await reader.readexactly(n)
        return method.upper(), path, headers, body

    async def _write_response(self, writer: asyncio.StreamWriter,
                              status: int, payload: Dict,
                              extra: Dict[str, str], *, keep: bool) -> None:
        data = json.dumps(payload).encode()
        head = [f"HTTP/1.1 {status} {_STATUS_TEXT.get(status, 'Status')}",
                "Content-Type: application/json",
                f"Content-Length: {len(data)}",
                f"Connection: {'keep-alive' if keep else 'close'}"]
        head += [f"{k}: {v}" for k, v in extra.items()]
        writer.write(("\r\n".join(head) + "\r\n\r\n").encode() + data)
        await writer.drain()

    # -------------------------------------------------------------- routing
    async def _route(self, method: str, path: str, headers: Dict[str, str],
                     body: bytes) -> Tuple[int, Dict, Dict[str, str]]:
        if path == "/healthz":
            return 200, {"status": "draining" if self._draining
                         else "serving"}, {}
        if path == "/v1/stats" and method == "GET":
            return 200, self._stats_payload(), {}
        if path == "/v1/search":
            if method != "POST":
                raise _HttpError(405, "method_not_allowed",
                                 "POST /v1/search")
            return await self._search(headers, body)
        self.stats["not_found"] += 1
        raise _HttpError(404, "not_found", f"no route for {path}")

    def _authenticate(self, headers: Dict[str, str]
                      ) -> Optional[TenantConfig]:
        if not self._keys:
            return None                       # open edge
        key = headers.get("x-api-key")
        tenant = self._keys.get(key) if key else None
        if tenant is None:
            self.stats["auth_failures"] += 1
            raise _HttpError(401, "unauthorized",
                             "missing or unknown x-api-key")
        return tenant

    async def _search(self, headers: Dict[str, str], body: bytes
                      ) -> Tuple[int, Dict, Dict[str, str]]:
        self.stats["requests"] += 1
        if self._draining:
            self.stats["draining_rejects"] += 1
            raise _HttpError(503, "draining", "edge is draining")
        tenant = self._authenticate(headers)
        tstats = None
        if tenant is not None:
            tstats = self.tenant_stats[tenant.name]
            tstats["requests"] += 1
        if self._live_requests > self.cfg.max_pending:
            self.stats["overloaded"] += 1
            raise _HttpError(503, "overloaded",
                             f"{self.cfg.max_pending} requests in flight")
        req = self._parse_search(body,
                                 None if tenant is None else tenant.name)
        try:
            resp = await self.client.search(req)
        except QuotaExceeded as exc:
            # the TenantManager's admission gate (serve/tenants.py): the
            # backend never saw the request
            self.stats["rate_limited"] += 1
            if tstats is not None:
                tstats["rate_limited"] += 1
            raise _HttpError(
                429, "rate_limited", str(exc),
                {"Retry-After": f"{exc.retry_after:.3f}"}) from None
        except DeadlineExceeded as exc:
            self.stats["deadline_expired"] += 1
            if tstats is not None:
                tstats["errors"] += 1
            raise _HttpError(504, "deadline_exceeded", str(exc)) from None
        except Exception:
            if tstats is not None:
                tstats["errors"] += 1
            raise
        self.stats["ok"] += 1
        if tstats is not None:
            tstats["ok"] += 1
        return 200, {"ids": np.asarray(resp.ids).tolist(),
                     "dists": np.asarray(resp.dists, np.float64).tolist(),
                     "latency_s": resp.latency_s,
                     "batch_size": resp.batch_size,
                     "tenant": resp.tenant,
                     "tag": resp.tag}, {}

    def _parse_search(self, body: bytes, tenant: Optional[str]
                      ) -> SearchRequest:
        self_cfg = self.cfg
        try:
            doc = json.loads(body.decode())
        except (ValueError, UnicodeDecodeError) as exc:
            self.stats["bad_requests"] += 1
            raise _HttpError(400, "bad_request",
                             f"invalid JSON body: {exc}") from None
        if not isinstance(doc, dict) or "query" not in doc:
            self.stats["bad_requests"] += 1
            raise _HttpError(400, "bad_request",
                             'body must be a JSON object with "query"')
        try:
            query = np.asarray(doc["query"], np.float32)
            if query.ndim != 1 or query.size == 0:
                raise ValueError(f"query must be a non-empty 1-D vector, "
                                 f"got shape {query.shape}")
            k = doc.get("k")
            top_n = doc.get("top_n")
            deadline_s = doc.get("deadline_s",
                                 self_cfg.default_deadline_s)
            if k is not None:
                k = int(k)
            if top_n is not None:
                top_n = int(top_n)
            if deadline_s is not None:
                deadline_s = float(deadline_s)
            # metadata predicate (DESIGN.md §11 wire grammar) + the
            # deadline-adaptive accuracy opt-in; a malformed predicate is
            # a 400 like any other bad knob
            filt = predicate_from_json(doc.get("filter"))
            adaptive = bool(doc.get("adaptive", False))
        except (TypeError, ValueError) as exc:
            self.stats["bad_requests"] += 1
            raise _HttpError(400, "bad_request", str(exc)) from None
        return SearchRequest(query=query, k=k, top_n=top_n,
                             deadline_s=deadline_s, tag=doc.get("tag"),
                             tenant=tenant, filter=filt, adaptive=adaptive)

    def _stats_payload(self) -> Dict[str, object]:
        out: Dict[str, object] = {"edge": dict(self.stats),
                                  "tenants": {n: dict(s) for n, s in
                                              self.tenant_stats.items()},
                                  "client": dict(self.client.stats)}
        co = self.client.coalescer
        if co is not None:
            out["coalescer"] = {**co.stats, "live": co.live()}
        if self.manager is not None:
            # the submit-layer books (quota rejects, per-tenant QueryStats
            # + latency percentiles) — distinct from the HTTP counters in
            # "tenants" above
            out["tenant_service"] = self.manager.tenant_rollup()
        sig = getattr(self.backend, "scaling_signals", None)
        if sig is not None:
            out["backend"] = sig()
        else:
            out["backend"] = {"live_load": self.backend.live_load()}
        return out


# ---------------------------------------------------------------------------
# Minimal keep-alive HTTP client (tests / benchmarks / example)
# ---------------------------------------------------------------------------

class HttpConn:
    """One keep-alive HTTP/1.1 connection speaking JSON — just enough
    client to exercise the edge through a real socket (the tests' and
    benchmark harness's counterpart to :class:`AnnsEdge`)."""

    def __init__(self, reader: asyncio.StreamReader,
                 writer: asyncio.StreamWriter):
        self.reader, self.writer = reader, writer

    @classmethod
    async def open(cls, host: str, port: int) -> "HttpConn":
        reader, writer = await asyncio.open_connection(host, port)
        return cls(reader, writer)

    async def request(self, method: str, path: str,
                      body: Optional[Dict] = None,
                      headers: Optional[Dict[str, str]] = None
                      ) -> Tuple[int, Any]:
        data = b"" if body is None else json.dumps(body).encode()
        head = [f"{method} {path} HTTP/1.1", "Host: edge",
                f"Content-Length: {len(data)}",
                "Content-Type: application/json"]
        head += [f"{k}: {v}" for k, v in (headers or {}).items()]
        self.writer.write(("\r\n".join(head) + "\r\n\r\n").encode() + data)
        await self.writer.drain()
        status_line = await self.reader.readline()
        if not status_line:
            raise ConnectionError("edge closed the connection")
        status = int(status_line.split()[1])
        n = 0
        while True:
            h = await self.reader.readline()
            if h in (b"\r\n", b"\n", b""):
                break
            name, _, value = h.decode("latin1").partition(":")
            if name.strip().lower() == "content-length":
                n = int(value)
        payload = json.loads((await self.reader.readexactly(n)).decode()) \
            if n else None
        return status, payload

    async def aclose(self) -> None:
        self.writer.close()
        try:
            await self.writer.wait_closed()
        except (ConnectionError, OSError):
            pass
