"""One constructor for the whole serving stack (PR 7 satellite S6).

Both examples (and any deployment script) previously hand-rolled the
same ``ReplicaRouter(...)`` call with slightly divergent knob sets;
:func:`make_serving_stack` is the single place that turns a
:class:`ServingStackConfig` into a started router, so the serving shape
(replica count, policy, batching window, pipeline depth, accuracy knobs)
is declared once and reused everywhere — examples/serve_anns.py,
examples/rag_pipeline.py, and the HTTP edge all build on it.
"""

from __future__ import annotations

import dataclasses
import os
from typing import Optional

from repro.core.engine import FusionANNSIndex
from repro.serve.router import ReplicaRouter

__all__ = ["ServingStackConfig", "make_serving_stack"]


@dataclasses.dataclass
class ServingStackConfig:
    """The serving shape, declared once.  Field defaults mirror the
    examples' long-standing hand-rolled values (small batches + a tight
    window: latency-lean interactive serving)."""

    n_replicas: int = 2
    policy: str = "jsq"
    mesh: object = None                 # parent mesh to carve (None = host)
    threaded: bool = True
    max_batch: int = 16
    max_wait_s: float = 0.0005
    scan_window: int = 8
    inflight_depth: int = 2
    overlap_rerank: bool = False
    max_queue: int = 1024
    fused: bool = False
    lut_int8: bool = False
    # snapshot directory (DESIGN.md §10): scale-ups hydrate new replicas
    # from ``save_snapshot``/``load_snapshot`` instead of sharing the live
    # index, and ``make_serving_stack(index=None)`` boots the whole stack
    # from an existing checkpoint on disk
    snapshot_dir: Optional[str] = None


def make_serving_stack(index: Optional[FusionANNSIndex] = None,
                       config: Optional[ServingStackConfig] = None,
                       **overrides) -> ReplicaRouter:
    """Build the serving stack for ``index``: a
    :class:`~repro.serve.router.ReplicaRouter` over ``n_replicas``
    batching replicas, configured from ``config`` (or a fresh default)
    with keyword ``overrides`` applied on top.  Started when
    ``threaded=True`` (the default) — callers own the ``stop()``.

    ``index=None`` requires ``snapshot_dir`` pointing at a
    ``save_snapshot`` checkpoint: the stack hydrates its index from disk
    (replica restart without rebuilding), answering with bit-identical
    ids to the index the snapshot was taken from."""
    cfg = dataclasses.replace(config or ServingStackConfig(), **overrides)
    if index is None:
        if cfg.snapshot_dir is None or not os.path.isdir(cfg.snapshot_dir):
            raise ValueError(
                "make_serving_stack(index=None) needs snapshot_dir= "
                "pointing at an existing save_snapshot() directory")
        index = FusionANNSIndex.load_snapshot(cfg.snapshot_dir)
    return ReplicaRouter(
        index, n_replicas=cfg.n_replicas, policy=cfg.policy, mesh=cfg.mesh,
        threaded=cfg.threaded, snapshot_dir=cfg.snapshot_dir,
        max_batch=cfg.max_batch,
        max_wait_s=cfg.max_wait_s, scan_window=cfg.scan_window,
        inflight_depth=cfg.inflight_depth,
        overlap_rerank=cfg.overlap_rerank, max_queue=cfg.max_queue,
        fused=cfg.fused, lut_int8=cfg.lut_int8)
